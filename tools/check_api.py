"""Public-surface lint: diff the importable API against the committed manifest.

Imports every public module, collects its ``__all__``, and compares the
``module:name`` set against ``docs/api_manifest.txt``.  CI runs this next
to the README snippet check, so an accidental rename/removal of a public
symbol (or an accidental new export nobody documented) fails the build
instead of silently breaking downstream callers.

Usage:
  PYTHONPATH=src python tools/check_api.py            # diff (CI mode)
  PYTHONPATH=src python tools/check_api.py --write    # regenerate manifest

Intentional surface changes: update the code, run ``--write``, commit the
manifest diff alongside (and update docs/api.md).
"""
from __future__ import annotations

import importlib
import os
import sys

# every module whose __all__ is public contract
MODULES = [
    "repro.api",
    "repro.core",
    "repro.graph",
    "repro.serving",
    "repro.streams",
]

MANIFEST = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "docs",
    "api_manifest.txt",
)


def current_surface() -> set[str]:
    surface: set[str] = set()
    for modname in MODULES:
        mod = importlib.import_module(modname)
        names = getattr(mod, "__all__", None)
        if names is None:
            raise SystemExit(f"{modname}: public module must define __all__")
        for name in names:
            if not hasattr(mod, name):
                raise SystemExit(f"{modname}.__all__ lists missing name {name!r}")
            surface.add(f"{modname}:{name}")
    return surface


def read_manifest() -> set[str]:
    with open(MANIFEST) as f:
        return {
            line.strip()
            for line in f
            if line.strip() and not line.startswith("#")
        }


def main() -> int:
    surface = current_surface()
    if "--write" in sys.argv:
        with open(MANIFEST, "w") as f:
            f.write(
                "# Public API manifest — one module:name per line.\n"
                "# Regenerate with: PYTHONPATH=src python tools/check_api.py"
                " --write\n"
                "# CI (tools/check_api.py) fails on any diff against the"
                " importable surface.\n"
            )
            for entry in sorted(surface):
                f.write(entry + "\n")
        print(f"wrote {len(surface)} entries to {MANIFEST}")
        return 0

    try:
        pinned = read_manifest()
    except FileNotFoundError:
        print(f"missing manifest {MANIFEST}; run with --write", file=sys.stderr)
        return 1
    missing = sorted(pinned - surface)  # removed/renamed: breaking
    unexpected = sorted(surface - pinned)  # undocumented new exports
    for name in missing:
        print(f"MISSING (in manifest, not importable): {name}", file=sys.stderr)
    for name in unexpected:
        print(f"UNEXPECTED (importable, not in manifest): {name}",
              file=sys.stderr)
    if missing or unexpected:
        print(
            f"\npublic surface drifted ({len(missing)} missing, "
            f"{len(unexpected)} unexpected).  If intentional: "
            f"PYTHONPATH=src python tools/check_api.py --write "
            f"and commit the manifest (+ docs/api.md).",
            file=sys.stderr,
        )
        return 1
    print(f"api surface OK: {len(surface)} symbols across "
          f"{len(MODULES)} modules match {os.path.basename(MANIFEST)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
