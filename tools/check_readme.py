"""README quickstart lint: execute every fenced python block in README.md.

Keeps the README honest: the quickstart snippets are excerpts of
``examples/quickstart.py``, and this check fails CI whenever they drift from
an API that actually runs (wrong import, renamed kwarg, broken assertion).
Blocks run top-to-bottom in ONE shared namespace, like a reader pasting
them into a single session.

Usage:  PYTHONPATH=src python tools/check_readme.py [README.md]
"""
from __future__ import annotations

import re
import sys


def extract_python_blocks(text: str) -> list[str]:
    return re.findall(r"```python\n(.*?)```", text, re.S)


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "README.md"
    with open(path) as f:
        blocks = extract_python_blocks(f.read())
    if not blocks:
        print(f"{path}: no ```python blocks found", file=sys.stderr)
        return 1
    ns: dict = {"__name__": "__readme__"}
    for i, block in enumerate(blocks, 1):
        print(f"--- {path} python block {i}/{len(blocks)} "
              f"({len(block.splitlines())} lines)")
        try:
            exec(compile(block, f"{path}#block{i}", "exec"), ns)
        except Exception as e:
            print(f"FAILED block {i}: {type(e).__name__}: {e}",
                  file=sys.stderr)
            return 1
    print(f"{path}: {len(blocks)} snippet blocks executed OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
