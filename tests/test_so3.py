"""SO(3) machinery property tests: rotation equivariance of the real CG
tensor products and spherical harmonics (the NequIP substrate)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.models.gnn.so3 import cg_real, real_sh, tp_paths

RNG = np.random.default_rng(0)


def _rand_rot(rng):
    A = rng.normal(size=(3, 3))
    Q, _ = np.linalg.qr(A)
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    return Q


def _wigner(l, R, rng, npts=64):
    v = rng.normal(size=(npts, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    Y = real_sh(l, v)
    YR = real_sh(l, v @ R.T)
    D, *_ = np.linalg.lstsq(Y, YR, rcond=None)
    return D.T


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_wigner_matrices_orthogonal(seed):
    rng = np.random.default_rng(seed)
    R = _rand_rot(rng)
    for l in range(3):
        D = _wigner(l, R, rng)
        np.testing.assert_allclose(D @ D.T, np.eye(2 * l + 1), atol=1e-8)


@pytest.mark.parametrize("path", tp_paths(2))
def test_tensor_product_equivariance(path):
    l1, l2, l3 = path
    rng = np.random.default_rng(hash(path) % 2**31)
    R = _rand_rot(rng)
    C = cg_real(l1, l2, l3)
    D1, D2, D3 = (_wigner(l, R, rng) for l in (l1, l2, l3))
    a = rng.normal(size=2 * l1 + 1)
    b = rng.normal(size=2 * l2 + 1)
    lhs = np.einsum("abc,a,b->c", C, D1 @ a, D2 @ b)
    rhs = D3 @ np.einsum("abc,a,b->c", C, a, b)
    np.testing.assert_allclose(lhs, rhs, atol=1e-10)


def test_sh_orthonormality():
    """Monte-Carlo check of <Y_lm, Y_l'm'> = delta on the sphere."""
    rng = np.random.default_rng(1)
    v = rng.normal(size=(200_000, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    Ys = [real_sh(l, v) for l in range(3)]
    allY = np.concatenate(Ys, axis=1)  # [P, 9]
    gram = 4 * np.pi * (allY.T @ allY) / len(v)
    np.testing.assert_allclose(gram, np.eye(9), atol=0.05)


def test_cg_selection_rules():
    # paths violating |l1-l2| <= l3 <= l1+l2 are identically zero
    from repro.models.gnn.so3 import cg_complex

    assert np.abs(cg_complex(1, 1, 3)).max() == 0.0
    assert np.abs(cg_complex(0, 0, 1)).max() == 0.0
    # scalar x scalar -> scalar is the identity coupling
    c = cg_real(0, 0, 0)
    assert c.shape == (1, 1, 1) and abs(abs(c[0, 0, 0]) - 1.0) < 1e-12
