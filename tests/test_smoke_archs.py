"""Per-architecture smoke tests (deliverable f): every assigned arch x shape
cell instantiates a REDUCED config and runs one step on CPU, asserting
output shapes and no NaNs."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import arch
from repro.configs.base import ARCH_IDS, shapes_for

RNG = np.random.default_rng(0)


def synth_inputs(bundle):
    def fill(path, sds):
        k = getattr(path[-1], "key", "")
        if k == "key":
            return jax.random.key_data(jax.random.key(1)).astype(sds.dtype)
        if sds.dtype == jnp.int32:
            if k == "labels":
                return jnp.asarray(RNG.integers(0, 2, sds.shape).astype(np.int32))
            if k in ("src", "dst", "graph_ids", "queries"):
                return jnp.asarray(RNG.integers(0, 8, sds.shape).astype(np.int32))
            if k == "positions":
                return jnp.zeros(sds.shape, jnp.int32)
            if k == "cand_ids":
                return jnp.asarray(
                    (np.arange(int(np.prod(sds.shape))) % 100).astype(np.int32)
                ).reshape(sds.shape)
            return jnp.asarray(RNG.integers(0, 64, sds.shape).astype(np.int32))
        if sds.dtype == jnp.bool_:
            return jnp.ones(sds.shape, bool)
        return jnp.asarray(RNG.normal(size=sds.shape).astype(sds.dtype))

    return {
        name: jax.tree_util.tree_map_with_path(fill, tree)
        for name, tree in bundle.input_specs().items()
    }


CELLS = [
    (a, s.name)
    for a in ARCH_IDS
    for s in shapes_for(a)
    if arch.is_applicable(a, s.name)[0]
]


@pytest.mark.parametrize("arch_id,shape_name", CELLS)
def test_smoke_cell(arch_id, shape_name):
    b = arch.build(arch_id, shape_name, smoke=True)
    state = b.init(jax.random.key(0))
    out = b.step(*state, **synth_inputs(b))
    leaves = jax.tree_util.tree_leaves(out)
    assert leaves, "step produced no outputs"
    for x in leaves:
        if jnp.issubdtype(x.dtype, jnp.floating):
            assert bool(jnp.isfinite(x).all()), f"non-finite output in {arch_id}"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_configs_have_exact_literature_numbers(arch_id):
    cfg = arch.get_config(arch_id)
    expected = {
        "deepseek-v2-lite-16b": dict(n_layers=27, d_model=2048, n_heads=16,
                                     vocab=102400, kv_lora_rank=512),
        "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, n_heads=16,
                                vocab=151936),
        "llama3-405b": dict(n_layers=126, d_model=16384, n_heads=128,
                            n_kv_heads=8, d_ff=53248, vocab=128256),
        "yi-34b": dict(n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
                       d_ff=20480, vocab=64000),
        "llama3.2-1b": dict(n_layers=16, d_model=2048, n_heads=32,
                            n_kv_heads=8, d_ff=8192, vocab=128256),
        "gin-tu": dict(n_layers=5, d_hidden=64),
        "gcn-cora": dict(n_layers=2, d_hidden=16),
        "gatedgcn": dict(n_layers=16, d_hidden=70),
        "nequip": dict(n_layers=5, d_hidden=32, l_max=2, n_rbf=8, cutoff=5.0),
        "wide-deep": dict(n_sparse=40, embed_dim=32, mlp=(1024, 512, 256)),
        "probesim": dict(c=0.6, eps_a=0.1),
    }[arch_id]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, f"{arch_id}.{k}"
    if arch_id == "deepseek-v2-lite-16b":
        assert cfg.moe.n_routed == 64 and cfg.moe.top_k == 6 and cfg.moe.n_shared == 2
        assert cfg.moe.d_ff_expert == 1408
    if arch_id == "qwen2-moe-a2.7b":
        assert cfg.moe.n_routed == 60 and cfg.moe.top_k == 4
        assert cfg.moe.d_ff_shared == 5632


def test_param_count_estimates_sane():
    cfg = arch.get_config("llama3-405b")
    assert 380e9 < cfg.params_dense < 430e9
    ds = arch.get_config("deepseek-v2-lite-16b")
    assert 12e9 < ds.params_dense < 20e9
    assert 2e9 < ds.params_active < 4e9
