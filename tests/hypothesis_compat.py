"""Graceful degradation when ``hypothesis`` is not installed.

Property-test modules import ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` directly (the moral equivalent of
``pytest.importorskip``, but per-test instead of per-module): with
``hypothesis`` available (see requirements-dev.txt) everything behaves
normally; without it, only the property tests skip — plain tests in the same
module still run, and collection never dies with ModuleNotFoundError.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: property tests skip, the rest of the suite runs
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy construction and returns an inert object."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None

            return strategy

    st = _StrategyStub()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            def stub():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub

        return deco
