"""Per-kernel allclose tests: Pallas (interpret=True on CPU) vs jnp oracle,
swept over shapes and dtypes (deliverable c)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.probe_push.ops import probe_push
from repro.kernels.probe_push.ref import probe_push_ref
from repro.kernels.spmm_ell.ops import spmm_ell
from repro.kernels.spmm_ell.ref import spmm_ell_ref

RNG = np.random.default_rng(7)


def _ell_inputs(n, K, B, dtype):
    nbrs = RNG.integers(0, n + 1, size=(n, K)).astype(np.int32)  # some sentinels
    scores = RNG.normal(size=(n, B)).astype(dtype)
    weights = RNG.uniform(0.1, 1.0, size=n).astype(np.float32)
    return jnp.asarray(nbrs), jnp.asarray(scores), jnp.asarray(weights)


@pytest.mark.parametrize("n,K,B", [(128, 4, 8), (256, 7, 16), (384, 16, 32)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_spmm_ell_matches_ref(n, K, B, dtype):
    nbrs, scores, weights = _ell_inputs(n, K, B, dtype)
    out = spmm_ell(nbrs, scores, weights)
    ref = spmm_ell_ref(nbrs, scores, weights)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol,
    )


def test_spmm_ell_fallback_path():
    # non-tiling n exercises the oracle fallback
    nbrs, scores, weights = _ell_inputs(100, 3, 8, np.float32)
    out = spmm_ell(nbrs, scores, weights)
    ref = spmm_ell_ref(nbrs, scores, weights)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@pytest.mark.parametrize("n,K,B", [(128, 4, 8), (256, 9, 16)])
@pytest.mark.parametrize("thresh", [0.0, 0.3])
def test_probe_push_matches_ref(n, K, B, thresh):
    nbrs, scores, weights = _ell_inputs(n, K, B, np.float32)
    scores = jnp.abs(scores)
    exclude = jnp.asarray(RNG.integers(0, n + 1, size=B).astype(np.int32))
    out = probe_push(nbrs, scores, weights, exclude, prune_thresh=thresh)
    ref = probe_push_ref(nbrs, scores, weights, exclude, prune_thresh=thresh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_probe_push_excludes_rows():
    n, K, B = 128, 4, 8
    nbrs, scores, weights = _ell_inputs(n, K, B, np.float32)
    scores = jnp.abs(scores) + 0.1
    exclude = jnp.arange(B, dtype=jnp.int32) * 7
    out = np.asarray(probe_push(nbrs, scores, weights, exclude))
    for b in range(B):
        assert out[b * 7, b] == 0.0


@pytest.mark.parametrize(
    "B,S,H,Hkv,dh",
    [
        (1, 128, 2, 2, 16),  # MHA
        (2, 256, 4, 2, 32),  # GQA group 2
        (1, 128, 8, 1, 64),  # MQA
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, S, H, Hkv, dh, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, H, dh)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, dh)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, dh)), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol,
    )


def test_flash_attention_non_causal():
    q = jnp.asarray(RNG.normal(size=(1, 128, 2, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 128, 2, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 128, 2, 16)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_probe_level_kernel_integration(toy, key):
    """use_kernel=True path of the telescoped probe agrees with pure jnp."""
    from repro.core import probe_walks_telescoped, sample_walks
    from repro.graph import ell_from_edges, toy_graph

    src, dst, n = toy_graph()
    # pad nodes to 128 tile via a bigger ELL (sentinel rows)
    eg = toy["eg"]
    walks = sample_walks(key, eg, 0, n_r=8, max_len=5, sqrt_c=0.5)
    ref = probe_walks_telescoped(toy["g"], walks, sqrt_c=0.5)
    ell = probe_walks_telescoped(eg, walks, sqrt_c=0.5)
    np.testing.assert_allclose(np.asarray(ell), np.asarray(ref), atol=1e-6)


def test_lm_forward_with_flash_kernel(key):
    """use_kernel=True routes attention through the Pallas kernel (interpret
    mode on CPU) and matches the pure-jnp model forward."""
    import jax.numpy as jnp

    from repro.configs.base import TransformerConfig
    from repro.models.transformer import model as M

    cfg = TransformerConfig(
        name="t", n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, d_head=16,
        d_ff=64, vocab=64, param_dtype="float32", compute_dtype="float32",
        remat=False,
    )
    params = M.init_lm(key, cfg)
    toks = jax.random.randint(key, (1, 128), 0, 64)
    ref, _ = M.lm_forward(params, toks, cfg, use_kernel=False)
    out, _ = M.lm_forward(params, toks, cfg, use_kernel=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4)
