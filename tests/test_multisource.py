"""Fused multi-query serve path (core/multisource.py + serving engine).

Covers the tentpole contracts:
* the compacted fused probe equals the host-accumulated telescoped oracle,
  including partial pools (n_r not divisible by the lane width);
* ``multi_source(us=[u])`` IS ``single_source(u, variant='telescoped')``;
* batched results are identical to per-query results given per-query keys
  (the engine's batched ``drain()`` == serial serving property);
* COO push, ELL push and the Pallas kernel path agree.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    make_params,
    multi_source,
    multi_source_topk,
    single_source,
    simrank_power,
)
from repro.core.probe import probe_walks_telescoped
from repro.core.walks import sample_walks_batch
from repro.graph import ell_from_edges, graph_from_edges, powerlaw_graph


def _oracle(g, pool_q, u, params, n_r):
    """Host-accumulated telescoped estimate for one query's walk pool."""
    cols = probe_walks_telescoped(
        g, pool_q, sqrt_c=params.sqrt_c, eps_p=params.eps_p
    )
    ref = cols.sum(axis=1) / n_r
    if params.truncation_shift:
        ref = jnp.where(ref > 0, ref + params.eps_t / 2, ref)
    return ref.at[u].set(1.0)


@pytest.mark.parametrize("n_r,lanes", [(96, 32), (77, 32), (5, 64)])
def test_fused_equals_telescoped_oracle(toy, key, n_r, lanes):
    """Fused compacted probe == per-walk telescoped sums, for full and
    partial pools (n_r % lanes != 0 and n_r < lanes)."""
    g, eg, n = toy["g"], toy["eg"], toy["n"]
    params = make_params(n, c=0.25, eps_a=0.1, delta=0.01, n_r_override=n_r)
    us = jnp.array([0, 3], jnp.int32)
    keys = jax.random.split(key, 2)
    est = multi_source(None, g, eg, us, params, lanes=lanes, keys=keys)
    pool = sample_walks_batch(
        keys, eg, us, n_r=n_r, max_len=params.max_len, sqrt_c=params.sqrt_c
    )
    for qi in range(2):
        ref = _oracle(g, pool[qi], int(us[qi]), params, n_r)
        np.testing.assert_allclose(
            np.asarray(est[qi]), np.asarray(ref), atol=2e-5
        )


def test_single_source_is_q1_specialization(toy, key):
    params = make_params(toy["n"], c=0.25, eps_a=0.1, n_r_override=200)
    s = single_source(
        key, toy["g"], toy["eg"], 0, params, variant="telescoped", walk_chunk=32
    )
    m = multi_source(
        key, toy["g"], toy["eg"], jnp.array([0]), params, lanes=32
    )[0]
    np.testing.assert_allclose(np.asarray(s), np.asarray(m), atol=1e-5)
    assert float(s[0]) == 1.0


def test_batch_matches_per_query(small_powerlaw, key):
    """Q = 4 batch == 4 single-query calls with the same per-query keys."""
    g, eg = small_powerlaw["g"], small_powerlaw["eg"]
    params = make_params(small_powerlaw["n"], c=0.6, eps_a=0.2,
                         n_r_override=150)
    in_deg = np.asarray(g.in_deg)
    us = np.argsort(-in_deg)[:4].astype(np.int32)
    keys = jax.random.split(key, 4)
    batch = multi_source(None, g, eg, us, params, lanes=64, keys=keys)
    for i in range(4):
        solo = multi_source(
            None, g, eg, us[i : i + 1], params, lanes=64, keys=keys[i : i + 1]
        )
        np.testing.assert_allclose(
            np.asarray(batch[i]), np.asarray(solo[0]), atol=1e-5
        )


def test_fused_error_bound_toy(toy, key):
    """The fused path stays within the Thm 2 bound on the paper's graph."""
    truth = np.asarray(simrank_power(toy["g"], c=0.25, iters=60))[0]
    params = make_params(toy["n"], c=0.25, eps_a=0.1, delta=0.01)
    est = np.asarray(
        multi_source(key, toy["g"], toy["eg"], jnp.array([0]), params,
                     lanes=256)
    )[0]
    err = np.abs(est - truth)
    err[0] = 0
    assert err.max() <= params.eps_a, f"maxerr {err.max()}"


def test_push_representations_agree(key):
    """COO push, ELL push and the Pallas spmm_ell kernel give one answer."""
    src, dst, n = powerlaw_graph(128, 600, seed=1)  # n tiles by block_rows
    g = graph_from_edges(src, dst, n)
    eg = ell_from_edges(src, dst, n)
    params = make_params(n, c=0.6, eps_a=0.2, n_r_override=128)
    u = int(np.argmax(np.bincount(dst, minlength=n)))
    us = jnp.array([u], jnp.int32)
    coo = multi_source(key, g, eg, us, params, lanes=32)
    ell = multi_source(key, eg, eg, us, params, lanes=32)
    kern = multi_source(key, eg, eg, us, params, lanes=32, use_kernel=True)
    np.testing.assert_allclose(np.asarray(coo), np.asarray(ell), atol=1e-6)
    np.testing.assert_allclose(np.asarray(ell), np.asarray(kern), atol=1e-5)


def test_multi_source_topk_excludes_self(toy, key):
    params = make_params(toy["n"], c=0.25, eps_a=0.1, n_r_override=300)
    us = jnp.array([0, 2], jnp.int32)
    idx, vals = multi_source_topk(key, toy["g"], toy["eg"], us, 3, params)
    assert idx.shape == (2, 3) and vals.shape == (2, 3)
    for qi in range(2):
        assert int(us[qi]) not in np.asarray(idx[qi])
        assert (np.diff(np.asarray(vals[qi])) <= 1e-7).all()  # sorted


def test_tree_variant_partial_chunk(toy, key):
    """Host chunk loops sample exactly the remaining walks in the final
    partial chunk (no surplus sampling + masking) and stay accurate."""
    truth = np.asarray(simrank_power(toy["g"], c=0.25, iters=60))[0]
    params = make_params(toy["n"], c=0.25, eps_a=0.1, delta=0.01,
                         n_r_override=1000)  # 1000 = 3 * 384 + 232: partial
    est = np.asarray(
        single_source(key, toy["g"], toy["eg"], 0, params, variant="tree",
                      walk_chunk=384)
    )
    err = np.abs(est - truth)
    err[0] = 0
    assert err.max() <= params.eps_a + 0.05  # statistical headroom at n_r=1e3


def test_engine_drain_batched_matches_serial():
    """drain() in fused batches == the same queries served one at a time.

    Queries carry their PRNG stream from submit time, so batch composition
    (including repeat padding of the final short batch) cannot change any
    answer."""
    from repro.serving.engine import SimRankEngine

    src, dst, n = powerlaw_graph(300, 2500, seed=0)
    in_deg = np.bincount(dst, minlength=n)
    g = graph_from_edges(src, dst, n, capacity=len(src) + 64)
    eg = ell_from_edges(src, dst, n, k_max=int(in_deg.max()) + 8)
    qs = np.argsort(-in_deg)[:5].astype(int)  # 5 queries, batch_q=4: padding

    eng_a = SimRankEngine(g, eg, eps_a=0.2, top_k=5, walk_chunk=128,
                          batch_q=4, seed=7)
    for u in qs:
        eng_a.submit(int(u))
    batched = eng_a.drain(budget_walks=96)

    eng_b = SimRankEngine(g, eg, eps_a=0.2, top_k=5, walk_chunk=128,
                          batch_q=1, seed=7)
    for u in qs:
        eng_b.submit(int(u))
    serial = eng_b.drain(budget_walks=96)

    assert [r.node for r in batched] == list(qs)
    for rb, rs in zip(batched, serial):
        assert rb.node == rs.node
        np.testing.assert_allclose(rb.topk_scores, rs.topk_scores, atol=1e-5)
        assert set(rb.topk_nodes) == set(rs.topk_nodes)
    assert eng_a.stats.queries == 5
    assert eng_a.stats.steps == 2  # ceil(5 / 4) fused dispatches


def test_engine_run_query_and_updates():
    """run_query + interleaved updates on the fused engine (seed semantics)."""
    from repro.serving.engine import SimRankEngine

    src, dst, n = powerlaw_graph(300, 2500, seed=0)
    in_deg = np.bincount(dst, minlength=n)
    g = graph_from_edges(src, dst, n, capacity=len(src) + 64)
    eg = ell_from_edges(src, dst, n, k_max=int(in_deg.max()) + 8)
    eng = SimRankEngine(g, eg, eps_a=0.2, top_k=5, walk_chunk=128)
    u = int(np.argmax(in_deg))
    res = eng.run_query(u, budget_walks=256)
    assert len(res.topk_nodes) == 5
    assert u not in res.topk_nodes
    eng.insert(np.array([1, 2], np.int32), np.array([u, u], np.int32))
    res2 = eng.run_query(u, budget_walks=256)
    assert len(res2.topk_nodes) == 5
    assert eng.stats.updates == 2 and eng.stats.queries == 2
