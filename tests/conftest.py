import numpy as np
import pytest

import jax

from repro.graph import ell_from_edges, graph_from_edges, toy_graph


@pytest.fixture(scope="session")
def toy():
    src, dst, n = toy_graph()
    return dict(
        src=src,
        dst=dst,
        n=n,
        g=graph_from_edges(src, dst, n),
        eg=ell_from_edges(src, dst, n),
    )


@pytest.fixture(scope="session")
def small_powerlaw():
    from repro.graph import powerlaw_graph

    src, dst, n = powerlaw_graph(200, 1500, seed=3)
    return dict(
        src=src,
        dst=dst,
        n=n,
        g=graph_from_edges(src, dst, n),
        eg=ell_from_edges(src, dst, n),
    )


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.key(42)
