"""Roofline analysis unit tests: HLO collective parsing with while-trip
weighting, shape-byte accounting, and term classification."""
import numpy as np

from repro.launch.mesh import HW
from repro.roofline.analysis import (
    RooflineReport,
    _shape_bytes,
    parse_collectives,
)

SAMPLE_HLO = """
HloModule jit_f, entry_computation_layout={...}

%add (x: f32[], y: f32[]) -> f32[] {
  ROOT %add.5 = f32[] add(%x, %y)
}

%body (p: (s32[], f32[32,512])) -> (s32[], f32[32,512]) {
  %dot.4 = f32[32,512]{1,0} dot(%a, %b)
  %all-reduce.3 = f32[32,512]{1,0} all-reduce(%dot.4), channel_id=1, to_apply=%add
  ROOT %tuple.15 = (s32[], f32[32,512]{1,0}) tuple(%c, %all-reduce.3)
}

%cond (p: (s32[], f32[32,512])) -> pred[] {
  %constant.22 = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %constant.22), direction=LT
}

ENTRY %main (param: f32[32,512]) -> f32[32,512] {
  %ag = f32[64,512]{1,0} all-gather(%param), dimensions={0}
  %while.11 = (s32[], f32[32,512]{1,0}) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %gte = f32[32,512]{1,0} get-tuple-element(%while.11), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[32,512]") == 32 * 512 * 4
    assert _shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert _shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert _shape_bytes("pred[]") == 1


def test_parse_collectives_weights_while_bodies():
    out = parse_collectives(SAMPLE_HLO)
    # all-reduce inside the while body: 5 iterations x 32*512*4 bytes
    assert out["by_kind"]["all-reduce"] == 5 * 32 * 512 * 4
    assert out["counts"]["all-reduce"] == 5
    # all-gather at entry: once, result buffer 64*512*4
    assert out["by_kind"]["all-gather"] == 64 * 512 * 4
    assert out["counts"]["all-gather"] == 1


def test_parse_skips_async_done_pairs():
    txt = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %s = f32[8]{0} all-reduce-start(%p), channel_id=1
  %d = f32[8]{0} all-reduce-done(%s)
  ROOT %r = f32[8]{0} add(%d, %d)
}
"""
    out = parse_collectives(txt)
    assert out["counts"]["all-reduce"] == 1
    assert out["by_kind"]["all-reduce"] == 8 * 4


def test_roofline_terms_and_bottleneck():
    rep = RooflineReport(
        arch="x", shape="y", mesh="single", chips=256,
        hlo_flops=197e12,  # exactly 1 second of compute
        hlo_bytes=819e9 * 0.5,
        collective_bytes=50e9 * 2.0,
        model_flops=197e12 * 256 * 0.7,
    ).finalize(HW)
    assert abs(rep.compute_s - 1.0) < 1e-9
    assert abs(rep.memory_s - 0.5) < 1e-9
    assert abs(rep.collective_s - 2.0) < 1e-9
    assert rep.bottleneck == "collective"
    assert abs(rep.useful_flops_ratio - 0.7) < 1e-9
