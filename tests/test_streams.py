"""Temporal stream subsystem (repro.streams, DESIGN.md §9): arrival
generators, sliding-window expiry, the replay driver, and transports.

The load-bearing invariant: a TTL window maintained through the
coordinated update path (inserts append, expiry deletes oldest-first with
stable compaction) keeps the COO+ELL mirrors **bit-identical** to
rebuilding the live window from scratch in arrival order — across
insert+expire interleaves, an overflow->regrow mid-stream, and the
empty-window edge case.
"""
import numpy as np
import pytest

from repro.api.handle import GraphHandle
from repro.api.session import SimRankSession
from repro.graph import ell_from_edges, graph_from_edges
from repro.streams import (
    EventStream,
    FreshnessSLO,
    ServiceTransport,
    SessionTransport,
    SlidingWindowExpirer,
    StreamDriver,
    bursty_edge_stream,
    poisson_edge_stream,
    preferential_attachment_stream,
)

N = 40


def _empty_session(n=N, *, capacity=512, k_max=32, **kw):
    handle = GraphHandle.from_edges(
        np.empty(0, np.int32), np.empty(0, np.int32), n,
        capacity=capacity, k_max=k_max,
    )
    kw.setdefault("top_k", 8)
    return SimRankSession(handle, **kw)


def _assert_window_equals_rebuild(sess, expirer):
    """The maintained mirrors vs a from-scratch rebuild of the live
    window in arrival order — bitwise."""
    h = sess.backend.handle
    src, dst = expirer.live_edges()
    g_rb = graph_from_edges(src, dst, h.n, capacity=h.g.capacity)
    eg_rb = ell_from_edges(src, dst, h.n, k_max=h.eg.k_max)
    np.testing.assert_array_equal(np.asarray(h.g.src), np.asarray(g_rb.src))
    np.testing.assert_array_equal(np.asarray(h.g.dst), np.asarray(g_rb.dst))
    np.testing.assert_array_equal(
        np.asarray(h.g.in_deg), np.asarray(g_rb.in_deg))
    np.testing.assert_array_equal(
        np.asarray(h.g.out_deg), np.asarray(g_rb.out_deg))
    np.testing.assert_array_equal(
        np.asarray(h.eg.in_nbrs), np.asarray(eg_rb.in_nbrs))
    np.testing.assert_array_equal(
        np.asarray(h.eg.in_deg), np.asarray(eg_rb.in_deg))


# -- generators --------------------------------------------------------------


def test_poisson_stream_rate_and_invariants():
    st = poisson_edge_stream(100, rate=2_000, horizon=1.0, seed=3)
    assert len(st) > 0
    # Poisson(2000): 5-sigma band around the mean
    assert abs(len(st) - 2_000) < 5 * np.sqrt(2_000)
    assert np.all(np.diff(st.t) >= 0)
    assert st.t[0] > 0 and st.horizon <= 1.0
    assert np.all(st.src != st.dst)  # self-loop-free
    assert st.src.min() >= 0 and max(st.src.max(), st.dst.max()) < 100
    st2 = poisson_edge_stream(100, rate=2_000, horizon=1.0, seed=3)
    np.testing.assert_array_equal(st.t, st2.t)
    np.testing.assert_array_equal(st.dst, st2.dst)


def test_bursty_stream_is_clustered():
    st = bursty_edge_stream(
        100, rate_on=4_000, mean_on=0.05, mean_off=0.2, horizon=2.0, seed=5
    )
    assert len(st) > 0
    assert np.all(np.diff(st.t) >= 0) and st.horizon <= 2.0
    # on/off modulation: inter-arrival gaps are far burstier than the
    # exponential (squared-CV 1) of a flat Poisson at the same mean rate
    gaps = np.diff(st.t)
    cv2 = gaps.var() / gaps.mean() ** 2
    assert cv2 > 2.0


def test_preferential_attachment_is_skewed():
    pa = preferential_attachment_stream(200, 3_000, 1.0, seed=7)
    po = poisson_edge_stream(200, 3_000, 1.0, seed=7)
    deg_pa = np.bincount(pa.dst, minlength=200).max()
    deg_po = np.bincount(po.dst, minlength=200).max()
    assert np.all(pa.src != pa.dst)
    assert deg_pa > 3 * deg_po  # rich got richer


def test_event_stream_validation():
    with pytest.raises(ValueError, match="nondecreasing"):
        EventStream([1.0, 0.5], [0, 1], [1, 2], 10)
    with pytest.raises(ValueError, match="ragged"):
        EventStream([1.0], [0, 1], [1, 2], 10)
    with pytest.raises(ValueError, match="out of range"):
        EventStream([1.0], [0], [10], 10)
    st = EventStream([0.1, 0.2, 0.3], [0, 1, 2], [1, 2, 3], 10)
    cut = st.slice_time(0.1, 0.25)
    assert len(cut) == 1 and int(cut.src[0]) == 1
    assert [e.src for e in st.events()] == [0, 1, 2]


# -- the sliding-window expirer ----------------------------------------------


def test_expirer_fifo_cutoff_and_live_window():
    ex = SlidingWindowExpirer(ttl=5.0)
    t = np.arange(10, dtype=np.float64)  # arrivals at 0..9
    src = np.arange(10, dtype=np.int32)
    dst = (src + 1) % 10
    ex.ingest(t, src, dst)
    es, ed = ex.expire_until(7.0)  # cutoff 2.0: arrivals 0, 1, 2 expire
    np.testing.assert_array_equal(es, [0, 1, 2])  # oldest first
    np.testing.assert_array_equal(ed, [1, 2, 3])
    assert ex.live == 7 and ex.oldest_t == 3.0 and ex.expired_total == 3
    ls, _ = ex.live_edges()
    np.testing.assert_array_equal(ls, np.arange(3, 10))
    # repeated expiry at the same now is a no-op; going backwards raises
    es, _ = ex.expire_until(7.0)
    assert len(es) == 0
    with pytest.raises(ValueError, match="nondecreasing"):
        ex.expire_until(6.0)
    with pytest.raises(ValueError, match="nondecreasing"):
        ex.ingest([5.0], [0], [1])  # older than the last ingest (9.0)


def test_expire_batches_apply_equals_rebuild():
    """Expiry-derived UpdateBatches through the raw coordinated apply keep
    the mirrors bitwise-equal to a rebuild of the live window."""
    rng = np.random.default_rng(0)
    n, m = 30, 60
    src = rng.integers(0, n, m).astype(np.int32)
    dst = (src + 1 + rng.integers(0, n - 1, m).astype(np.int32)) % n
    t = np.sort(rng.uniform(0, 1, m))
    handle = GraphHandle.from_edges(src, dst, n, capacity=128, k_max=32)
    ex = SlidingWindowExpirer(ttl=0.4)
    ex.ingest(t, src, dst)
    batches = ex.expire_batches(1.0, batch_size=16, n=n)
    assert len(batches) >= 2  # delete-heavy: more than one full batch
    for b in batches:
        assert bool(b.has_deletes) and not bool(np.asarray(b.insert).any())
        applied = handle.apply_batch(b)
        live = np.asarray(b.src) < n  # sentinel-padded tail
        assert np.asarray(applied)[live].all()
    ls, ld = ex.live_edges()
    g_rb = graph_from_edges(ls, ld, n, capacity=handle.g.capacity)
    np.testing.assert_array_equal(
        np.asarray(handle.g.src), np.asarray(g_rb.src))
    np.testing.assert_array_equal(
        np.asarray(handle.g.dst), np.asarray(g_rb.dst))
    assert handle.num_edges == ex.live


# -- bitwise window == rebuild through the session update path ---------------


def _tick_window(sess, ex, stream, lo, hi):
    """Deliver arrivals in (lo, hi] and expire to hi, preserving global
    stream order (arrivals before the expiry pass at the tick edge)."""
    cut = stream.slice_time(lo, hi)
    if len(cut):
        ex.ingest(cut.t, cut.src, cut.dst)
        sess.update(inserts=(cut.src, cut.dst))
    es, ed = ex.expire_until(hi)
    if len(es):
        sess.update(deletes=(es, ed))


def test_window_equals_rebuild_interleaved():
    stream = poisson_edge_stream(N, rate=600, horizon=1.0, seed=11)
    sess = _empty_session()
    ex = SlidingWindowExpirer(ttl=0.3)
    lo = 0.0
    for hi in np.arange(0.1, 1.3, 0.1):
        _tick_window(sess, ex, stream, lo, float(hi))
        _assert_window_equals_rebuild(sess, ex)
        lo = float(hi)
    assert ex.expired_total > 0 and ex.live > 0
    assert not sess.overflow


def test_window_equals_rebuild_through_overflow_regrow():
    """Mid-stream overflow: the window outgrows a tiny initial capacity,
    auto_regrow doubles the buffers, and the bitwise invariant holds
    across the regrow (rebuilds compare at the CURRENT capacity/k_max)."""
    stream = poisson_edge_stream(N, rate=500, horizon=1.0, seed=13)
    sess = _empty_session(capacity=16, k_max=4)
    ex = SlidingWindowExpirer(ttl=0.5)
    lo = 0.0
    for hi in np.arange(0.1, 1.1, 0.1):
        _tick_window(sess, ex, stream, lo, float(hi))
        _assert_window_equals_rebuild(sess, ex)
        lo = float(hi)
    assert sess.stats.regrows > 0  # capacity really blew mid-stream
    assert sess.backend.handle.g.capacity > 16
    assert not sess.overflow  # regrow cleared the sticky flag
    assert sess.backend.handle.num_edges == ex.live


def test_window_equals_rebuild_empty_window():
    """A silent gap longer than the TTL drains the window to empty
    mid-stream; the emptied mirrors match an empty rebuild and keep the
    invariant when traffic resumes."""
    stream = poisson_edge_stream(N, rate=300, horizon=0.3, seed=17)
    sess = _empty_session()
    ex = SlidingWindowExpirer(ttl=0.1)
    lo = 0.0
    drained = False
    for hi in np.arange(0.1, 0.9, 0.1):  # arrivals stop at 0.3
        _tick_window(sess, ex, stream, lo, float(hi))
        lo = float(hi)
        _assert_window_equals_rebuild(sess, ex)
        if hi > 0.4:
            drained = True
            assert ex.live == 0
            assert sess.backend.handle.num_edges == 0
    assert drained
    # the emptied window still accepts traffic and keeps the invariant
    ex.ingest([1.0], [1], [2])
    sess.update(inserts=([1], [2]))
    _assert_window_equals_rebuild(sess, ex)
    assert sess.backend.handle.num_edges == 1


# -- the replay driver -------------------------------------------------------


def _drive(mode, **kw):
    stream = poisson_edge_stream(N, rate=400, horizon=0.5, seed=19)
    sess = _empty_session(batch_q=4)
    drv = StreamDriver(
        SessionTransport(sess, mode=mode), stream,
        ttl=0.2, tick_s=0.1, queries_per_tick=2, update_burst=32,
        k=5, budget_walks=64, slo=FreshnessSLO(staleness_p99_s=120.0),
        **kw,
    )
    return stream, sess, drv


@pytest.mark.parametrize("mode", ["drain", "epoch"])
def test_driver_applies_every_op_and_serves(mode):
    stream, sess, drv = _drive(mode)
    rep = drv.run(final_expire=True)
    # every arrival was ingested+applied and later expired+applied
    assert rep.arrivals == len(stream)
    assert rep.expired == len(stream)
    assert rep.updates_applied == 2 * len(stream)
    assert sess.backend.handle.num_edges == 0
    assert rep.queries > 0 and rep.qps > 0
    assert rep.staleness_p99_s >= rep.staleness_p50_s >= 0.0
    assert rep.version_lag_p99 >= 0.0
    assert rep.slo_met is True  # generous test SLO
    assert rep.sticky_overflow is False
    d = rep.as_dict()
    assert d["slo"]["staleness_p99_s"] == 120.0
    assert d["final_precision_at_k"] is None  # no checkpoints requested


def test_driver_pooled_checkpoints():
    stream, sess, drv = _drive("drain", checkpoint_every=3,
                               checkpoint_queries=2, expert_r=400,
                               fresh_budget=256)
    rep = drv.run()
    assert len(rep.checkpoints) >= 1
    cp = rep.checkpoints[-1]
    assert 0.0 <= cp.precision_at_k <= 1.0
    assert 0.0 <= cp.ndcg_at_k <= 1.0 + 1e-9
    assert cp.pool_size >= drv.k  # the scout really joined the pool
    assert cp.live_edges > 0
    assert rep.final_precision_at_k == cp.precision_at_k


def test_driver_sharded_backend_smoke():
    stream = poisson_edge_stream(N, rate=200, horizon=0.3, seed=23)
    handle = GraphHandle.from_edges(
        np.empty(0, np.int32), np.empty(0, np.int32), N,
        capacity=256, k_max=16,
    )
    sess = SimRankSession(handle, backend="sharded", top_k=5, batch_q=2)
    drv = StreamDriver(
        SessionTransport(sess, mode="drain"), stream,
        ttl=0.15, tick_s=0.1, queries_per_tick=1, update_burst=32,
        k=5, budget_walks=64,
    )
    rep = drv.run()
    assert rep.arrivals == len(stream)
    assert rep.updates_applied >= rep.arrivals  # inserts + some expiry
    assert rep.queries > 0
    assert rep.slo_met is None  # no SLO configured


def test_driver_service_transport():
    from repro.serving import ServiceConfig, SimRankService

    handle = GraphHandle.from_edges(
        np.empty(0, np.int32), np.empty(0, np.int32), N,
        capacity=512, k_max=32,
    )
    stream = poisson_edge_stream(N, rate=400, horizon=0.4, seed=29)
    with SimRankService(
        handle,
        config=ServiceConfig(batch_window_ms=2.0, max_batch_q=4,
                             default_budget_walks=64),
    ) as svc:
        tr = ServiceTransport(svc, tenant="stream")
        drv = StreamDriver(
            tr, stream, ttl=0.2, tick_s=0.1, queries_per_tick=2,
            update_burst=32, k=5, budget_walks=64,
        )
        rep = drv.run()
        assert rep.queries > 0
        assert svc.stats.served >= rep.queries
        assert svc.stats.updates_applied == rep.updates_applied
        assert svc.stats.errors_5xx == 0
    assert rep.arrivals == len(stream)


def test_driver_validates_inputs():
    stream = poisson_edge_stream(N, rate=100, horizon=0.2, seed=1)
    sess = _empty_session()
    tr = SessionTransport(sess)
    with pytest.raises(ValueError, match="tick_s"):
        StreamDriver(tr, stream, ttl=0.1, tick_s=0.0)
    with pytest.raises(ValueError, match="mode"):
        SessionTransport(sess, mode="warp")
    other = poisson_edge_stream(N + 1, rate=100, horizon=0.2, seed=1)
    with pytest.raises(ValueError, match="n="):
        StreamDriver(tr, other, ttl=0.1, tick_s=0.1)
    with pytest.raises(ValueError, match="ttl"):
        SlidingWindowExpirer(ttl=0.0)
