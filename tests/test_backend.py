"""Backend layer: LocalBackend extraction parity, QueryTicket serving,
ShardedBackend semantics (single-shard in-process; the 8-fake-device mesh
parity + sharded-update invariant run in a subprocess, like
test_distributed, because XLA_FLAGS must precede jax init)."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import (
    Backend,
    GraphHandle,
    LocalBackend,
    QuerySpec,
    ShardedBackend,
    ShardedGraphState,
    SimRankSession,
)
from repro.core import make_params
from repro.core.probesim import single_source, topk


@pytest.fixture()
def handle(small_powerlaw):
    d = small_powerlaw
    in_deg = np.bincount(d["dst"], minlength=d["n"])
    return GraphHandle.from_edges(
        d["src"], d["dst"], d["n"],
        capacity=len(d["src"]) + 64, k_max=int(in_deg.max()) + 8,
    )


# ---------------------------------------------------------------------------
# LocalBackend: the extraction must be bit-identical to the core calls
# ---------------------------------------------------------------------------


def test_local_backend_serve_one_bit_identical_to_core(handle, key):
    p = make_params(handle.n, c=0.6, eps_a=0.1, delta=0.01)
    be = LocalBackend(handle, params=p, walk_chunk=128)
    out = be.serve_one(
        QuerySpec(kind="single_source", node=3), key,
        variant="telescoped", n_r=p.n_r,
    )
    ref = single_source(
        key, handle.g, handle.eg, 3, p, variant="telescoped", walk_chunk=128
    )
    np.testing.assert_array_equal(out["scores"], np.asarray(ref))

    out = be.serve_one(
        QuerySpec(kind="topk", node=3, k=7), key, variant="tree", n_r=p.n_r
    )
    idx, vals = topk(
        key, handle.g, handle.eg, 3, 7, p, variant="tree", walk_chunk=128
    )
    np.testing.assert_array_equal(out["topk_nodes"], np.asarray(idx))
    np.testing.assert_array_equal(out["topk_scores"], np.asarray(vals))


def test_session_default_backend_is_local_and_shares_handle(handle):
    sess = SimRankSession(handle)
    assert isinstance(sess.backend, LocalBackend)
    assert isinstance(sess.backend, Backend)  # protocol conformance
    assert sess.backend.handle is sess.handle  # epoch donation stays valid
    assert sess.backend.dispatch_label("tree") == "tree"


def test_session_accepts_backend_instance(handle):
    p = make_params(handle.n, c=0.6, eps_a=0.1, delta=0.01)
    be = LocalBackend(handle.copy(), params=p, walk_chunk=128)
    sess = SimRankSession(be, top_k=5)
    assert sess.backend is be
    assert sess.params is p  # session adopts the backend's error budget
    env = sess.query(3)
    assert env.topk_nodes.shape == (5,)


# ---------------------------------------------------------------------------
# QueryTicket async serving
# ---------------------------------------------------------------------------


def test_ticket_result_matches_drain_bitwise(handle):
    sess_a = SimRankSession(handle, seed=7, top_k=5, batch_q=4)
    sess_b = SimRankSession(handle, seed=7, top_k=5, batch_q=4)
    nodes = [1, 2, 3]
    drained = {}
    for u in nodes:
        sess_a.submit(u)
    for u, env in zip(nodes, sess_a.drain(budget_walks=64)):
        drained[u] = env
    tickets = [sess_b.submit(u) for u in nodes]
    # force out of order: the last ticket's result() serves the batch
    last = tickets[-1].result(budget_walks=64)
    for t, u in zip(tickets, nodes):
        assert t.done
        np.testing.assert_array_equal(
            t.result().topk_scores, drained[u].topk_scores
        )
        np.testing.assert_array_equal(
            t.result().topk_nodes, drained[u].topk_nodes
        )
    assert last is tickets[-1].envelope
    assert sess_b.drain() == []  # queue fully consumed by result()


def test_ticket_partial_drain_leaves_later_batches_queued(handle):
    sess = SimRankSession(handle, seed=0, top_k=5, batch_q=2)
    tickets = [sess.submit(u) for u in [1, 2, 3, 4, 5]]
    assert all(t.poll() is None for t in tickets)
    tickets[2].result(budget_walks=64)  # serves batches [1,2] and [3,4]
    assert [t.done for t in tickets] == [True, True, True, True, False]
    rest = sess.drain(budget_walks=64)
    assert len(rest) == 1 and rest[0].node == 5
    assert tickets[4].done  # drain also fills tickets
    assert sess.pending == (0, 0)


def test_epoch_fills_tickets(handle):
    sess = SimRankSession(handle, seed=0, top_k=5, batch_q=4)
    t = sess.submit(2)
    ep = sess.epoch(inserts=(np.array([0]), np.array([1])),
                    budget_walks=64)
    assert t.done and t.poll() is ep.results[0]


# ---------------------------------------------------------------------------
# ShardedBackend semantics (single shard: runs on the plain CPU test env)
# ---------------------------------------------------------------------------


def test_handle_shard_keeps_edges_and_version_coherent(handle):
    state = handle.shard(shards=1)
    assert state.version == handle.version
    s0, d0 = handle.to_host_edges()
    s1, d1 = state.to_host_edges()
    assert sorted(zip(s0.tolist(), d0.tolist())) == sorted(
        zip(s1.tolist(), d1.tolist())
    )
    # headroom from the handle's spare COO capacity carried over
    assert state.capacity_per_shard * state.shards > state.num_edges


def test_sharded_update_then_query_equals_rebuild(handle):
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    be = ShardedBackend(handle.shard(shards=1), params=p, walk_chunk=128)
    rng = np.random.default_rng(0)
    ins_s = rng.integers(0, handle.n, 32).astype(np.int32)
    ins_d = rng.integers(0, handle.n, 32).astype(np.int32)
    assert be.apply_ops(ins_s, ins_d, True).all()
    del_s, del_d = handle.to_host_edges()
    assert be.apply_ops(del_s[:8], del_d[:8], False).all()
    assert be.version == handle.version + 2

    s2, d2 = be.to_host_edges()
    rebuilt = ShardedBackend(
        ShardedGraphState(s2, d2, handle.n, shards=1, version=be.version),
        params=p, walk_chunk=128,
    )
    k = jnp.stack([jax.random.key(11)])
    a, _, _ = be.serve_batch("single_source", [3], k, n_r=192)
    b, _, _ = rebuilt.serve_batch("single_source", [3], k, n_r=192)
    np.testing.assert_array_equal(a, b)  # exact, not tolerance


def test_sharded_delete_semantics_one_copy_per_op(handle):
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    be = ShardedBackend(handle.shard(shards=1), params=p)
    # duplicate edge: two copies live after one extra insert
    s0, d0 = handle.to_host_edges()
    e = (np.array([s0[0]], np.int32), np.array([d0[0]], np.int32))
    assert be.apply_ops(*e, True).all()
    assert be.apply_ops(*e, False).all()   # removes ONE copy
    assert be.apply_ops(*e, False).all()   # removes the second
    assert not be.apply_ops(*e, False).any()  # absent now: unapplied
    assert not be.overflow  # absent deletes are not overflow


def test_sharded_delete_one_copy_per_pair_per_batch(handle):
    """Duplicate pairs inside ONE batch delete a single copy (the
    apply_update_batch contract) — only the first op reports applied."""
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    be = ShardedBackend(handle.shard(shards=1), params=p)
    s0, d0 = handle.to_host_edges()
    e = (np.array([s0[0]], np.int32), np.array([d0[0]], np.int32))
    assert be.apply_ops(*e, True).all()  # two live copies now
    dup = (np.array([s0[0], s0[0]], np.int32),
           np.array([d0[0], d0[0]], np.int32))
    mask = be.apply_ops(*dup, False)
    assert mask.tolist() == [True, False]
    # exactly one copy left
    assert be.apply_ops(*e, False).all()
    assert not be.apply_ops(*e, False).any()


def test_sharded_overflow_sticky_and_regrow(handle):
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    m = handle.num_edges
    state = ShardedGraphState(*handle.to_host_edges(), handle.n,
                             shards=1, capacity_per_shard=m)
    be = ShardedBackend(state, params=p)
    mask = be.apply_ops(np.array([0, 1], np.int32),
                        np.array([1, 0], np.int32), True)
    assert not mask.any() and be.overflow
    assert be.version == handle.version  # nothing applied: no bump
    be.regrow()
    assert not be.overflow
    assert state.capacity_per_shard >= 2 * m
    assert be.apply_ops(np.array([0, 1], np.int32),
                        np.array([1, 0], np.int32), True).all()


def test_session_sharded_single_shard_end_to_end(handle):
    sess = SimRankSession(handle, seed=0, top_k=5, backend="sharded",
                          shards=1, walk_chunk=128)
    env = sess.query(QuerySpec(kind="topk", node=3, budget_walks=128))
    assert env.variant == "sharded[spmd]"
    assert env.topk_nodes.shape == (5,)
    assert 3 not in env.topk_nodes.tolist()
    rep = sess.update(inserts=(np.array([0, 1]), np.array([2, 3])))
    assert rep.applied == 2 and sess.version == 1
    t = sess.submit(QuerySpec(kind="single_source", node=1,
                              budget_walks=128))
    env2 = t.result()
    assert env2.version == 1
    assert env2.scores.shape == (handle.n,)
    with pytest.raises(NotImplementedError):
        sess.epoch(queries=[1])
    with pytest.raises(ValueError):
        sess.query(QuerySpec(kind="topk", node=1, variant="tree"))


def test_sharded_rejects_bad_geometry(handle):
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    with pytest.raises(ValueError, match="divisible"):
        ShardedBackend(handle.shard(shards=3), params=p)  # 1 device
    with pytest.raises(ValueError, match="probe"):
        ShardedBackend(handle.shard(shards=1), params=p, probe="nope")
    with pytest.raises(ValueError, match="use_kernel"):
        ShardedBackend(handle.shard(shards=1), params=p, use_kernel=True)
    with pytest.raises(ValueError, match="model"):
        from repro.utils.jaxcompat import make_mesh

        ShardedBackend(handle.shard(shards=1), params=p,
                       mesh=make_mesh((1,), ("data",)))


def test_session_rejects_stray_backend_args(handle):
    with pytest.raises(ValueError, match="sharded"):
        SimRankSession(handle, shards=8)  # forgot backend="sharded"
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    be = LocalBackend(handle.copy(), params=p)
    with pytest.raises(ValueError, match="geometry"):
        SimRankSession(be, shards=2)  # instance already carries geometry
    with pytest.raises(ValueError, match="not both"):
        SimRankSession(LocalBackend(handle.copy(), params=p),
                       backend="sharded")
    with pytest.raises(ValueError, match="own graph state"):
        # the positional handle would be silently shadowed
        SimRankSession(handle, backend=LocalBackend(handle.copy(), params=p))


def test_sharded_odd_edge_chunks_pad_cleanly(handle):
    """edge_chunks that don't divide the 1024 padding floor must still
    produce a probe-compatible m_pad."""
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    be = ShardedBackend(handle.shard(shards=1), params=p,
                        walk_chunk=64, edge_chunks=3)
    est, _, _ = be.serve_batch(
        "single_source", [3], jnp.stack([jax.random.key(0)]), n_r=64
    )
    assert est.shape == (1, handle.n)


def test_sharded_infers_shards_from_mesh(handle):
    """mesh= without shards= sizes the partition from the model extent."""
    from repro.utils.jaxcompat import make_mesh

    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    mesh = make_mesh((1, 1), ("data", "model"))
    be = ShardedBackend(handle, params=p, mesh=mesh)
    assert be.state.shards == 1 and be.mesh is mesh


def test_backend_instance_session_never_owns_buffers(handle):
    """A caller-supplied backend's handle was not copied — epoch() (which
    donates the mirror buffers) must refuse rather than invalidate the
    caller's arrays."""
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    be = LocalBackend(handle, params=p)
    sess = SimRankSession(be)
    with pytest.raises(ValueError, match="owned graph"):
        sess.epoch(queries=[1])


# ---------------------------------------------------------------------------
# Mesh parity on 8 fake XLA host devices (subprocess: XLA_FLAGS first)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro.api import GraphHandle, QuerySpec, SimRankSession
from repro.api.backend import ShardedBackend, ShardedGraphState
from repro.graph import powerlaw_graph

src, dst, n = powerlaw_graph(120, 900, seed=5)
in_deg = np.bincount(dst, minlength=n)
h = GraphHandle.from_edges(src, dst, n, capacity=len(src) + 256,
                           k_max=int(in_deg.max()) + 8)
BUDGET = 8192
local = SimRankSession(h, seed=0, top_k=5, walk_chunk=512)
shard = SimRankSession(h, seed=0, top_k=5, walk_chunk=512,
                       backend="sharded", shards=4)
assert len(jax.devices()) == 8
nodes = [int(u) for u in np.where(in_deg > 0)[0][:2]]
for u in nodes:
    key = jax.random.key(100 + u)
    el = local.query(QuerySpec(kind="single_source", node=u,
                               budget_walks=BUDGET, key=key,
                               variant="telescoped"))
    es = shard.query(QuerySpec(kind="single_source", node=u,
                               budget_walks=BUDGET, key=key))
    a, b = el.scores.copy(), es.scores.copy()
    a[u] = b[u] = 0.0  # different draws: tolerance-based comparison
    assert np.abs(a - b).max() < 0.03, (u, np.abs(a - b).max())
    assert np.abs(a - b).mean() < 0.004, (u, np.abs(a - b).mean())
    tl = local.query(QuerySpec(kind="topk", node=u, k=5,
                               budget_walks=BUDGET, key=key,
                               variant="telescoped"))
    ts = shard.query(QuerySpec(kind="topk", node=u, k=5,
                               budget_walks=BUDGET, key=key))
    assert len(set(tl.topk_nodes.tolist())
               & set(ts.topk_nodes.tolist())) >= 3, u

# ring probe == spmd probe (same CSR sampler stream => near-identical)
ring = SimRankSession(h, seed=0, top_k=5, walk_chunk=512,
                      backend="sharded", shards=4,
                      backend_options=dict(probe="ring"))
key = jax.random.key(42)
es = shard.query(QuerySpec(kind="single_source", node=nodes[0],
                           budget_walks=1024, key=key))
er = ring.query(QuerySpec(kind="single_source", node=nodes[0],
                          budget_walks=1024, key=key))
assert er.variant == "sharded[ring]"
assert np.abs(es.scores - er.scores).max() < 1e-4

# sharded update -> query == rebuild-and-query (exact)
rng = np.random.default_rng(3)
shard.update(inserts=(rng.integers(0, n, 64).astype(np.int32),
                      rng.integers(0, n, 64).astype(np.int32)),
             deletes=(src[:16], dst[:16]))
assert shard.version == 2
s2, d2 = shard.backend.to_host_edges()
reb = ShardedBackend(ShardedGraphState(s2, d2, n, shards=4,
                                       version=shard.version),
                     params=shard.params, walk_chunk=512)
k = jnp.stack([jax.random.key(7)])
a, _, _ = shard.backend.serve_batch("single_source", [nodes[0]], k, n_r=512)
b, _, _ = reb.serve_batch("single_source", [nodes[0]], k, n_r=512)
assert np.array_equal(a, b)
print("BACKEND_PARITY_OK")
"""


def test_sharded_backend_parity_on_fake_mesh():
    """ShardedBackend (spmd + ring) vs LocalBackend on 8 fake XLA host
    devices: tolerance-based score/topk parity, plus the exact
    sharded-update -> query == rebuild-and-query invariant."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "BACKEND_PARITY_OK" in out.stdout
