"""Backend layer: LocalBackend extraction parity, QueryTicket serving,
ShardedBackend semantics (single-shard in-process; the 8-fake-device mesh
parity + sharded-update invariant run in a subprocess, like
test_distributed, because XLA_FLAGS must precede jax init)."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import (
    Backend,
    GraphHandle,
    LocalBackend,
    QuerySpec,
    ShardedBackend,
    ShardedGraphState,
    SimRankSession,
)
from repro.core import make_params
from repro.core.probesim import single_source, topk


@pytest.fixture()
def handle(small_powerlaw):
    d = small_powerlaw
    in_deg = np.bincount(d["dst"], minlength=d["n"])
    return GraphHandle.from_edges(
        d["src"], d["dst"], d["n"],
        capacity=len(d["src"]) + 64, k_max=int(in_deg.max()) + 8,
    )


# ---------------------------------------------------------------------------
# LocalBackend: the extraction must be bit-identical to the core calls
# ---------------------------------------------------------------------------


def test_local_backend_serve_one_bit_identical_to_core(handle, key):
    p = make_params(handle.n, c=0.6, eps_a=0.1, delta=0.01)
    be = LocalBackend(handle, params=p, walk_chunk=128)
    out = be.serve_one(
        QuerySpec(kind="single_source", node=3), key,
        variant="telescoped", n_r=p.n_r,
    )
    ref = single_source(
        key, handle.g, handle.eg, 3, p, variant="telescoped", walk_chunk=128
    )
    np.testing.assert_array_equal(out["scores"], np.asarray(ref))

    out = be.serve_one(
        QuerySpec(kind="topk", node=3, k=7), key, variant="tree", n_r=p.n_r
    )
    idx, vals = topk(
        key, handle.g, handle.eg, 3, 7, p, variant="tree", walk_chunk=128
    )
    np.testing.assert_array_equal(out["topk_nodes"], np.asarray(idx))
    np.testing.assert_array_equal(out["topk_scores"], np.asarray(vals))


def test_session_default_backend_is_local_and_shares_handle(handle):
    sess = SimRankSession(handle)
    assert isinstance(sess.backend, LocalBackend)
    assert isinstance(sess.backend, Backend)  # protocol conformance
    assert sess.backend.handle is sess.handle  # epoch donation stays valid
    assert sess.backend.dispatch_label("tree") == "tree"


def test_session_accepts_backend_instance(handle):
    p = make_params(handle.n, c=0.6, eps_a=0.1, delta=0.01)
    be = LocalBackend(handle.copy(), params=p, walk_chunk=128)
    sess = SimRankSession(be, top_k=5)
    assert sess.backend is be
    assert sess.params is p  # session adopts the backend's error budget
    env = sess.query(3)
    assert env.topk_nodes.shape == (5,)


# ---------------------------------------------------------------------------
# QueryTicket async serving
# ---------------------------------------------------------------------------


def test_ticket_result_matches_drain_bitwise(handle):
    sess_a = SimRankSession(handle, seed=7, top_k=5, batch_q=4)
    sess_b = SimRankSession(handle, seed=7, top_k=5, batch_q=4)
    nodes = [1, 2, 3]
    drained = {}
    for u in nodes:
        sess_a.submit(u)
    for u, env in zip(nodes, sess_a.drain(budget_walks=64)):
        drained[u] = env
    tickets = [sess_b.submit(u) for u in nodes]
    # force out of order: the last ticket's result() serves the batch
    last = tickets[-1].result(budget_walks=64)
    for t, u in zip(tickets, nodes):
        assert t.done
        np.testing.assert_array_equal(
            t.result().topk_scores, drained[u].topk_scores
        )
        np.testing.assert_array_equal(
            t.result().topk_nodes, drained[u].topk_nodes
        )
    assert last is tickets[-1].envelope
    assert sess_b.drain() == []  # queue fully consumed by result()


def test_ticket_partial_drain_leaves_later_batches_queued(handle):
    sess = SimRankSession(handle, seed=0, top_k=5, batch_q=2)
    tickets = [sess.submit(u) for u in [1, 2, 3, 4, 5]]
    assert all(t.poll() is None for t in tickets)
    tickets[2].result(budget_walks=64)  # serves batches [1,2] and [3,4]
    assert [t.done for t in tickets] == [True, True, True, True, False]
    rest = sess.drain(budget_walks=64)
    assert len(rest) == 1 and rest[0].node == 5
    assert tickets[4].done  # drain also fills tickets
    assert sess.pending == (0, 0)


def test_epoch_fills_tickets(handle):
    sess = SimRankSession(handle, seed=0, top_k=5, batch_q=4)
    t = sess.submit(2)
    ep = sess.epoch(inserts=(np.array([0]), np.array([1])),
                    budget_walks=64)
    assert t.done and t.poll() is ep.results[0]


# ---------------------------------------------------------------------------
# ShardedBackend semantics (single shard: runs on the plain CPU test env)
# ---------------------------------------------------------------------------


def test_handle_shard_keeps_edges_and_version_coherent(handle):
    state = handle.shard(shards=1)
    assert state.version == handle.version
    s0, d0 = handle.to_host_edges()
    s1, d1 = state.to_host_edges()
    assert sorted(zip(s0.tolist(), d0.tolist())) == sorted(
        zip(s1.tolist(), d1.tolist())
    )
    # headroom from the handle's spare COO capacity carried over
    assert state.capacity_per_shard * state.shards > state.num_edges


def test_sharded_update_then_query_equals_rebuild(handle):
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    be = ShardedBackend(handle.shard(shards=1), params=p, walk_chunk=128)
    rng = np.random.default_rng(0)
    ins_s = rng.integers(0, handle.n, 32).astype(np.int32)
    ins_d = rng.integers(0, handle.n, 32).astype(np.int32)
    assert be.apply_ops(ins_s, ins_d, True).all()
    del_s, del_d = handle.to_host_edges()
    assert be.apply_ops(del_s[:8], del_d[:8], False).all()
    assert be.version == handle.version + 2

    s2, d2 = be.to_host_edges()
    rebuilt = ShardedBackend(
        ShardedGraphState(s2, d2, handle.n, shards=1, version=be.version),
        params=p, walk_chunk=128,
    )
    k = jnp.stack([jax.random.key(11)])
    a, _, _ = be.serve_batch("single_source", [3], k, n_r=192)
    b, _, _ = rebuilt.serve_batch("single_source", [3], k, n_r=192)
    np.testing.assert_array_equal(a, b)  # exact, not tolerance


def test_sharded_delete_semantics_one_copy_per_op(handle):
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    be = ShardedBackend(handle.shard(shards=1), params=p)
    # duplicate edge: two copies live after one extra insert
    s0, d0 = handle.to_host_edges()
    e = (np.array([s0[0]], np.int32), np.array([d0[0]], np.int32))
    assert be.apply_ops(*e, True).all()
    assert be.apply_ops(*e, False).all()   # removes ONE copy
    assert be.apply_ops(*e, False).all()   # removes the second
    assert not be.apply_ops(*e, False).any()  # absent now: unapplied
    assert not be.overflow  # absent deletes are not overflow


def test_sharded_delete_one_copy_per_pair_per_batch(handle):
    """Duplicate pairs inside ONE batch delete a single copy (the
    apply_update_batch contract) — only the first op reports applied."""
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    be = ShardedBackend(handle.shard(shards=1), params=p)
    s0, d0 = handle.to_host_edges()
    e = (np.array([s0[0]], np.int32), np.array([d0[0]], np.int32))
    assert be.apply_ops(*e, True).all()  # two live copies now
    dup = (np.array([s0[0], s0[0]], np.int32),
           np.array([d0[0], d0[0]], np.int32))
    mask = be.apply_ops(*dup, False)
    assert mask.tolist() == [True, False]
    # exactly one copy left
    assert be.apply_ops(*e, False).all()
    assert not be.apply_ops(*e, False).any()


def test_sharded_overflow_sticky_and_regrow(handle):
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    m = handle.num_edges
    state = ShardedGraphState(*handle.to_host_edges(), handle.n,
                             shards=1, capacity_per_shard=m)
    be = ShardedBackend(state, params=p)
    mask = be.apply_ops(np.array([0, 1], np.int32),
                        np.array([1, 0], np.int32), True)
    assert not mask.any() and be.overflow
    assert be.version == handle.version  # nothing applied: no bump
    be.regrow()
    assert not be.overflow
    assert state.capacity_per_shard >= 2 * m
    assert be.apply_ops(np.array([0, 1], np.int32),
                        np.array([1, 0], np.int32), True).all()


def test_session_sharded_single_shard_end_to_end(handle):
    sess = SimRankSession(handle, seed=0, top_k=5, backend="sharded",
                          shards=1, walk_chunk=128)
    env = sess.query(QuerySpec(kind="topk", node=3, budget_walks=128))
    assert env.variant == "sharded[spmd]"
    assert env.topk_nodes.shape == (5,)
    assert 3 not in env.topk_nodes.tolist()
    rep = sess.update(inserts=(np.array([0, 1]), np.array([2, 3])))
    assert rep.applied == 2 and sess.version == 1
    t = sess.submit(QuerySpec(kind="single_source", node=1,
                              budget_walks=128))
    env2 = t.result()
    assert env2.version == 1
    assert env2.scores.shape == (handle.n,)
    # the fused epoch is a backend stage now: it runs on the mesh too
    ep = sess.epoch(inserts=(np.array([2]), np.array([4])),
                    queries=[QuerySpec(kind="topk", node=3)],
                    budget_walks=64)
    assert ep.version == 2 and ep.updates_applied == 1
    assert ep.results[0].variant == "sharded[spmd]"
    assert ep.results[0].topk_nodes.shape == (5,)
    # the serve path sees the epoch's updates (host state replayed)
    env3 = sess.query(QuerySpec(kind="single_source", node=1,
                                budget_walks=128))
    assert env3.version == 2
    with pytest.raises(ValueError):
        sess.query(QuerySpec(kind="topk", node=1, variant="tree"))


def test_sharded_rejects_bad_geometry(handle):
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    with pytest.raises(ValueError, match="divisible"):
        ShardedBackend(handle.shard(shards=3), params=p)  # 1 device
    with pytest.raises(ValueError, match="probe"):
        ShardedBackend(handle.shard(shards=1), params=p, probe="nope")
    with pytest.raises(ValueError, match="frontier_dtype"):
        ShardedBackend(handle.shard(shards=1), params=p,
                       frontier_dtype="float16")
    # use_kernel=True is a working mesh path now (PR 10), not a rejection
    be = ShardedBackend(handle.shard(shards=1), params=p, use_kernel=True)
    assert be.use_kernel is True
    with pytest.raises(ValueError, match="model"):
        from repro.utils.jaxcompat import make_mesh

        ShardedBackend(handle.shard(shards=1), params=p,
                       mesh=make_mesh((1,), ("data",)))


def test_session_rejects_stray_backend_args(handle):
    with pytest.raises(ValueError, match="sharded"):
        SimRankSession(handle, shards=8)  # forgot backend="sharded"
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    be = LocalBackend(handle.copy(), params=p)
    with pytest.raises(ValueError, match="geometry"):
        SimRankSession(be, shards=2)  # instance already carries geometry
    with pytest.raises(ValueError, match="not both"):
        SimRankSession(LocalBackend(handle.copy(), params=p),
                       backend="sharded")
    with pytest.raises(ValueError, match="own graph state"):
        # the positional handle would be silently shadowed
        SimRankSession(handle, backend=LocalBackend(handle.copy(), params=p))


def test_sharded_odd_edge_chunks_pad_cleanly(handle):
    """edge_chunks that don't divide the 1024 padding floor must still
    produce a probe-compatible m_pad."""
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    be = ShardedBackend(handle.shard(shards=1), params=p,
                        walk_chunk=64, edge_chunks=3)
    est, _, _ = be.serve_batch(
        "single_source", [3], jnp.stack([jax.random.key(0)]), n_r=64
    )
    assert est.shape == (1, handle.n)


def test_sharded_infers_shards_from_mesh(handle):
    """mesh= without shards= sizes the partition from the model extent."""
    from repro.utils.jaxcompat import make_mesh

    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    mesh = make_mesh((1, 1), ("data", "model"))
    be = ShardedBackend(handle, params=p, mesh=mesh)
    assert be.state.shards == 1 and be.mesh is mesh


def test_backend_instance_session_owns_copy_for_epochs(handle):
    """A backend advertising the epoch stage gets epochs even when the
    caller built it: the session asks it to own-copy its graph state at
    construction, so donated epoch steps never touch the caller's
    arrays (capability detection replaced the old blanket refusal)."""
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    be = LocalBackend(handle, params=p)
    g_src_before = np.asarray(handle.g.src).copy()
    eg_before = np.asarray(handle.eg.in_nbrs).copy()
    sess = SimRankSession(be)
    assert be.handle is not handle  # own-copied at construction
    ep = sess.epoch(inserts=(np.array([0]), np.array([1])),
                    queries=[1], budget_walks=32)
    assert ep.updates_applied == 1 and sess.version == 1
    # the caller's handle (and the arrays under it) are untouched
    np.testing.assert_array_equal(np.asarray(handle.g.src), g_src_before)
    np.testing.assert_array_equal(np.asarray(handle.eg.in_nbrs), eg_before)
    assert handle.version == 0


def test_epoch_capability_detection_refuses_without_stage(handle):
    """A backend without the epoch stage still gets the clear refusal."""

    class NoEpochBackend(LocalBackend):
        supports_epoch = False

    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    sess = SimRankSession(NoEpochBackend(handle.copy(), params=p))
    with pytest.raises(NotImplementedError, match="epoch_batch"):
        sess.epoch(queries=[1])


# ---------------------------------------------------------------------------
# Sharded fused epochs (single shard: runs on the plain CPU test env)
# ---------------------------------------------------------------------------


def _epoch_mirror_equals_rebuild(backend):
    """The carried device epoch state must be bit-identical to a
    from-scratch rebuild from the (replayed) host edge list."""
    from repro.core.epoch import build_shard_epoch_graph

    st = backend._epoch_graph
    rebuilt = build_shard_epoch_graph(
        *backend.state.to_host_edges(), backend.state.n,
        shards=backend.state.shards,
        capacity_per_shard=st.capacity, k_max=st.k_max,
    )
    for f in ("src_sh", "dst_sh", "counts", "in_nbrs", "in_deg"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st, f)), np.asarray(getattr(rebuilt, f)),
            err_msg=f"epoch mirror field {f} != rebuild",
        )


def test_sharded_epoch_mirrors_equal_rebuild(handle):
    """Insert-only then mixed insert/delete epochs through the session:
    after each, the device-resident shard buffers are bit-identical to a
    from-scratch rebuild of the updated edge list."""
    sess = SimRankSession(handle, seed=0, top_k=5, batch_q=2,
                          update_batch=16, walk_chunk=128,
                          backend="sharded", shards=1)
    s0, d0 = handle.to_host_edges()
    # insert-only epoch (the O(B) append variant)
    ep = sess.epoch(inserts=(np.array([0, 1, 2]), np.array([3, 4, 5])),
                    queries=[1, 2], budget_walks=64)
    assert ep.updates_applied == 3 and ep.version == 1
    _epoch_mirror_equals_rebuild(sess.backend)
    # mixed epoch(s) (delete compaction == rebuild); drain_epochs in case
    # the batch cutter splits at a duplicate-pair conflict
    sess.queue_update(np.array([6]), np.array([7]))
    sess.queue_update(s0[:4], d0[:4], insert=False)
    for u in (1, 2):
        sess.submit(u)
    eps = sess.drain_epochs(budget_walks=64)
    assert sum(e.updates_applied for e in eps) == 5
    _epoch_mirror_equals_rebuild(sess.backend)
    assert sess.backend.state.num_edges == len(s0) + 4 - 4


def test_sharded_epoch_scores_match_local_under_shared_keys(handle):
    """Local and sharded epochs draw bit-identical walks under shared
    keys (same sampler, same ELL rows); scores agree to float summation
    order of the two probes."""
    import jax

    key = jax.random.key(123)
    ins = (np.array([0, 1, 2, 3]), np.array([4, 5, 6, 7]))
    s0, d0 = handle.to_host_edges()

    def run(backend_kw):
        sess = SimRankSession(handle, seed=0, top_k=5, batch_q=2,
                              update_batch=16, walk_chunk=128,
                              **backend_kw)
        qs = [QuerySpec(kind="single_source", node=u,
                        key=jax.random.fold_in(key, u)) for u in (1, 3)]
        ep = sess.epoch(inserts=ins, deletes=(s0[:2], d0[:2]),
                        queries=qs, budget_walks=192)
        return np.stack([r.scores for r in ep.results])

    local = run({})
    sharded = run(dict(backend="sharded", shards=1))
    assert np.abs(local - sharded).max() < 1e-4


def test_ring_backend_epoch_stamps_spmd_variant(handle):
    """The mesh epoch always telescopes through the spmd push — a ring
    backend's epoch envelopes must say so, not claim the ring served."""
    sess = SimRankSession(handle, seed=0, top_k=5, batch_q=1,
                          update_batch=8, walk_chunk=64,
                          backend="sharded", shards=1,
                          backend_options=dict(probe="ring"))
    ep = sess.epoch(inserts=(np.array([0]), np.array([1])),
                    queries=[1], budget_walks=32)
    assert ep.results[0].variant == "sharded[spmd]"
    env = sess.query(QuerySpec(kind="topk", node=1, budget_walks=32))
    assert env.variant == "sharded[ring]"  # serve path still rings


def test_sharded_epoch_overflow_regrow_midstream(handle):
    """A mid-stream capacity overflow inside the fused mesh epoch:
    skipped inserts are re-queued, the state regrows, and the retry
    epochs land every op — nothing lost, mirrors still == rebuild."""
    m = handle.num_edges
    state = ShardedGraphState(*handle.to_host_edges(), handle.n,
                              shards=1, capacity_per_shard=m + 2)
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    be = ShardedBackend(state, params=p, walk_chunk=128)
    sess = SimRankSession(be, seed=0, top_k=5, batch_q=2, update_batch=16)
    rng = np.random.default_rng(0)
    sess.queue_update(rng.integers(0, handle.n, 40).astype(np.int32),
                      rng.integers(0, handle.n, 40).astype(np.int32))
    eps = sess.drain_epochs(budget_walks=32)
    assert any(e.regrown for e in eps)
    assert sum(e.updates_applied for e in eps) == 40
    assert be.state.num_edges == m + 40
    assert not sess.overflow  # regrow cleared the sticky flag
    _epoch_mirror_equals_rebuild(be)


def test_sharded_epoch_then_host_update_stays_coherent(handle):
    """Interleaving host-path updates (update()) with fused epochs must
    invalidate and rebuild the carried device mirror — queries after the
    mix see every op exactly once."""
    sess = SimRankSession(handle, seed=0, top_k=5, batch_q=2,
                          update_batch=16, walk_chunk=128,
                          backend="sharded", shards=1)
    sess.epoch(inserts=(np.array([0]), np.array([1])), budget_walks=32)
    rep = sess.update(inserts=(np.array([2]), np.array([3])))
    assert rep.applied == 1
    ep = sess.epoch(inserts=(np.array([4]), np.array([5])),
                    queries=[1], budget_walks=64)
    assert ep.version == 3
    assert sess.backend.state.num_edges == handle.num_edges + 3
    _epoch_mirror_equals_rebuild(sess.backend)


# ---------------------------------------------------------------------------
# Lane-batched sharded serving (single shard: runs on the plain CPU env)
# ---------------------------------------------------------------------------


def test_sharded_batched_scores_match_per_query(handle):
    """The lane-batched sharded step is a pure batching of the per-query
    step: Q queries in ONE dispatch score within 1e-6 of Q single-query
    dispatches under the same per-query lane width and keys (matched
    ``wq`` => identical lane schedule and walk streams)."""
    p = make_params(handle.n, c=0.6, eps_a=0.2, delta=0.01)
    nodes = [1, 2, 3, 4]
    wq = 32  # lanes per query, held fixed across both dispatch shapes
    batched = ShardedBackend(handle.shard(shards=1), params=p,
                             walk_chunk=wq * len(nodes))
    single = ShardedBackend(handle.shard(shards=1), params=p, walk_chunk=wq)
    keys = jnp.stack([jax.random.key(40 + u) for u in nodes])
    est_b, _, _ = batched.serve_batch("single_source", nodes, keys, n_r=96)
    for i, u in enumerate(nodes):
        est_1, _, _ = single.serve_batch(
            "single_source", [u], keys[i:i + 1], n_r=96
        )
        assert np.abs(est_b[i] - est_1[0]).max() < 1e-6, u


def test_sharded_serve_scores_match_local_fused(handle):
    """Sharded drain vs local fused drain under shared per-query keys:
    the same pooled sampler and lane schedule drive both, so scores agree
    to the float-summation order of the two probes."""
    key = jax.random.key(7)

    def run(backend_kw):
        sess = SimRankSession(handle, seed=0, top_k=5, batch_q=2,
                              walk_chunk=128, **backend_kw)
        for u in (1, 3):
            sess.submit(QuerySpec(kind="single_source", node=u,
                                  key=jax.random.fold_in(key, u)))
        return np.stack([r.scores for r in sess.drain(budget_walks=192)])

    local = run({})
    sharded = run(dict(backend="sharded", shards=1))
    assert np.abs(local - sharded).max() < 1e-4


def test_sharded_serving_mirror_carried_and_invalidated(handle):
    """Repeated serving reuses the carried device mirror (the epoch-path
    ShardEpochGraph, keyed on the host mutation counter); a host-path
    update invalidates it, and the rebuilt mirror is bit-identical to a
    from-scratch rebuild of the updated edge list."""
    sess = SimRankSession(handle, seed=0, top_k=5, backend="sharded",
                          shards=1, walk_chunk=128)
    sess.query(QuerySpec(kind="single_source", node=1, budget_walks=64))
    st1 = sess.backend._epoch_graph
    assert st1 is not None
    sess.query(QuerySpec(kind="single_source", node=2, budget_walks=64))
    assert sess.backend._epoch_graph is st1  # carried, not rebuilt
    rep = sess.update(inserts=(np.array([0, 1]), np.array([2, 3])))
    assert rep.applied == 2
    env = sess.query(QuerySpec(kind="single_source", node=1,
                               budget_walks=64))
    assert env.version == 1
    assert sess.backend._epoch_graph is not st1  # update invalidated it
    _epoch_mirror_equals_rebuild(sess.backend)


# ---------------------------------------------------------------------------
# Mesh parity on 8 fake XLA host devices (subprocess: XLA_FLAGS first)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro.api import GraphHandle, QuerySpec, SimRankSession
from repro.api.backend import ShardedBackend, ShardedGraphState
from repro.graph import powerlaw_graph

src, dst, n = powerlaw_graph(120, 900, seed=5)
in_deg = np.bincount(dst, minlength=n)
h = GraphHandle.from_edges(src, dst, n, capacity=len(src) + 256,
                           k_max=int(in_deg.max()) + 8)
BUDGET = 8192
local = SimRankSession(h, seed=0, top_k=5, walk_chunk=512)
shard = SimRankSession(h, seed=0, top_k=5, walk_chunk=512,
                       backend="sharded", shards=4)
assert len(jax.devices()) == 8
nodes = [int(u) for u in np.where(in_deg > 0)[0][:2]]
for u in nodes:
    key = jax.random.key(100 + u)
    el = local.query(QuerySpec(kind="single_source", node=u,
                               budget_walks=BUDGET, key=key,
                               variant="telescoped"))
    es = shard.query(QuerySpec(kind="single_source", node=u,
                               budget_walks=BUDGET, key=key))
    a, b = el.scores.copy(), es.scores.copy()
    a[u] = b[u] = 0.0  # different draws: tolerance-based comparison
    assert np.abs(a - b).max() < 0.03, (u, np.abs(a - b).max())
    assert np.abs(a - b).mean() < 0.004, (u, np.abs(a - b).mean())
    tl = local.query(QuerySpec(kind="topk", node=u, k=5,
                               budget_walks=BUDGET, key=key,
                               variant="telescoped"))
    ts = shard.query(QuerySpec(kind="topk", node=u, k=5,
                               budget_walks=BUDGET, key=key))
    assert len(set(tl.topk_nodes.tolist())
               & set(ts.topk_nodes.tolist())) >= 3, u

# ring probe == spmd probe (same CSR sampler stream => near-identical)
ring = SimRankSession(h, seed=0, top_k=5, walk_chunk=512,
                      backend="sharded", shards=4,
                      backend_options=dict(probe="ring"))
key = jax.random.key(42)
es = shard.query(QuerySpec(kind="single_source", node=nodes[0],
                           budget_walks=1024, key=key))
er = ring.query(QuerySpec(kind="single_source", node=nodes[0],
                          budget_walks=1024, key=key))
assert er.variant == "sharded[ring]"
assert np.abs(es.scores - er.scores).max() < 1e-4

# ring vs spmd LANE-BATCHED parity: one 3-query dispatch on each probe
# (same pooled sampler stream, duplicate node with its own key included);
# both label the compiled step with the probe and lane count
assert shard.backend.batch_dispatch_label(3) == "sharded[spmd,Q=3]"
assert ring.backend.batch_dispatch_label(3) == "sharded[ring,Q=3]"
ub = [nodes[0], nodes[1], nodes[0]]
kb = jnp.stack([jax.random.key(200 + i) for i in range(3)])
ba, _, _ = shard.backend.serve_batch("single_source", ub, kb, n_r=512)
bb, _, _ = ring.backend.serve_batch("single_source", ub, kb, n_r=512)
assert np.abs(ba - bb).max() < 1e-4, np.abs(ba - bb).max()
print("RING_SPMD_BATCH_OK")

# sharded update -> query == rebuild-and-query (exact)
rng = np.random.default_rng(3)
shard.update(inserts=(rng.integers(0, n, 64).astype(np.int32),
                      rng.integers(0, n, 64).astype(np.int32)),
             deletes=(src[:16], dst[:16]))
assert shard.version == 2
s2, d2 = shard.backend.to_host_edges()
reb = ShardedBackend(ShardedGraphState(s2, d2, n, shards=4,
                                       version=shard.version),
                     params=shard.params, walk_chunk=512)
k = jnp.stack([jax.random.key(7)])
a, _, _ = shard.backend.serve_batch("single_source", [nodes[0]], k, n_r=512)
b, _, _ = reb.serve_batch("single_source", [nodes[0]], k, n_r=512)
assert np.array_equal(a, b)

# ring probe with a non-divisible column count: budget 65 at walk_chunk 64
# leaves a remainder chunk of ONE column, which the data axes (extent 2)
# do not divide — the per-chunk spmd fallback must serve it (previously a
# shard_map in_specs error), matching all-spmd to 1e-4
ring_odd = SimRankSession(h, seed=0, top_k=5, walk_chunk=64,
                          backend="sharded", shards=4,
                          backend_options=dict(probe="ring"))
spmd_odd = SimRankSession(h, seed=0, top_k=5, walk_chunk=64,
                          backend="sharded", shards=4)
key = jax.random.key(9)
eo = ring_odd.query(QuerySpec(kind="single_source", node=nodes[0],
                              budget_walks=65, key=key))
es = spmd_odd.query(QuerySpec(kind="single_source", node=nodes[0],
                              budget_walks=65, key=key))
assert np.abs(eo.scores - es.scores).max() < 1e-4
print("RING_REMAINDER_OK")

# --- fused mesh epochs on 4 shards --------------------------------------
from repro.core.epoch import build_shard_epoch_graph

def mirror_equals_rebuild(be):
    st = be._epoch_graph
    rebuilt = build_shard_epoch_graph(
        *be.state.to_host_edges(), be.state.n, shards=be.state.shards,
        capacity_per_shard=st.capacity, k_max=st.k_max)
    for f in ("src_sh", "dst_sh", "counts", "in_nbrs", "in_deg"):
        assert np.array_equal(np.asarray(getattr(st, f)),
                              np.asarray(getattr(rebuilt, f))), f

h2 = GraphHandle.from_edges(src, dst, n, capacity=len(src) + 256,
                            k_max=int(in_deg.max()) + 8)
eloc = SimRankSession(h2, seed=0, top_k=5, batch_q=2, update_batch=16,
                      walk_chunk=256)
eshd = SimRankSession(h2, seed=0, top_k=5, batch_q=2, update_batch=16,
                      walk_chunk=256, backend="sharded", shards=4)
ekey = jax.random.key(55)
ins = (rng.integers(0, n, 8).astype(np.int32),
       rng.integers(0, n, 8).astype(np.int32))
# insert-only epoch, shared per-query keys => bit-identical walks
qs_l = [QuerySpec(kind="single_source", node=u,
                  key=jax.random.fold_in(ekey, u)) for u in nodes[:2]]
qs_s = [QuerySpec(kind="single_source", node=u,
                  key=jax.random.fold_in(ekey, u)) for u in nodes[:2]]
el = eloc.epoch(inserts=ins, queries=qs_l, budget_walks=256)
es = eshd.epoch(inserts=ins, queries=qs_s, budget_walks=256)
assert el.updates_applied == es.updates_applied == 8
assert eshd.version == 1
la = np.stack([r.scores for r in el.results])
sa = np.stack([r.scores for r in es.results])
assert np.abs(la - sa).max() < 1e-3, np.abs(la - sa).max()
mirror_equals_rebuild(eshd.backend)
# mixed insert/delete epoch: device delete compaction == rebuild, bitwise
ins2 = (rng.integers(0, n, 4).astype(np.int32),
        rng.integers(0, n, 4).astype(np.int32))
el = eloc.epoch(inserts=ins2, deletes=(src[16:24], dst[16:24]),
                queries=[QuerySpec(kind="topk", node=nodes[0], k=5)],
                budget_walks=128)
es = eshd.epoch(inserts=ins2, deletes=(src[16:24], dst[16:24]),
                queries=[QuerySpec(kind="topk", node=nodes[0], k=5)],
                budget_walks=128)
assert el.updates_applied == es.updates_applied
assert len(set(el.results[0].topk_nodes.tolist())
           & set(es.results[0].topk_nodes.tolist())) >= 3
mirror_equals_rebuild(eshd.backend)
sl, dl = eloc.handle.to_host_edges()
ss, ds = eshd.backend.to_host_edges()
assert sorted(zip(sl.tolist(), dl.tolist())) == sorted(
    zip(ss.tolist(), ds.tolist()))
# overflow -> regrow mid-stream (update-only epochs; cheap apply steps)
m2 = eshd.backend.state.num_edges
tight = ShardedBackend(
    ShardedGraphState(*eshd.backend.to_host_edges(), n, shards=4,
                      capacity_per_shard=eshd.backend.state._counts.max()
                      + 2),
    params=eshd.params, walk_chunk=256)
tsess = SimRankSession(tight, seed=0, top_k=5, batch_q=2, update_batch=16)
tsess.queue_update(rng.integers(0, n, 40).astype(np.int32),
                   rng.integers(0, n, 40).astype(np.int32))
teps = tsess.drain_epochs()
assert any(e.regrown for e in teps)
assert sum(e.updates_applied for e in teps) == 40
assert tight.state.num_edges == m2 + 40 and not tsess.overflow
mirror_equals_rebuild(tight)
print("EPOCH_MESH_OK")
print("BACKEND_PARITY_OK")
"""


def test_sharded_backend_parity_on_fake_mesh():
    """ShardedBackend (spmd + ring) vs LocalBackend on 8 fake XLA host
    devices: tolerance-based score/topk parity, the exact
    sharded-update -> query == rebuild-and-query invariant, the ring
    remainder-chunk regression, and the fused mesh epochs (insert-only,
    mixed, overflow->regrow; mirrors == rebuild bitwise, scores vs local
    epochs under shared keys)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RING_SPMD_BATCH_OK" in out.stdout
    assert "RING_REMAINDER_OK" in out.stdout
    assert "EPOCH_MESH_OK" in out.stdout
    assert "BACKEND_PARITY_OK" in out.stdout
