"""ProbeSim estimator correctness: variant agreement, unbiasedness, error
bounds (Thm 1/2) and pruning behaviour — including hypothesis property tests
over random graphs."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import (
    build_prefix_tree,
    estimate_walk_reference,
    make_params,
    probe_tree_levels,
    probe_walks_telescoped,
    sample_walks,
    simrank_power,
    single_source,
    topk,
    tree_stats,
    walk_lengths,
)
from repro.graph import ell_from_edges, erdos_renyi_graph, graph_from_edges


def test_params_budget():
    p = make_params(10_000, c=0.6, eps_a=0.1, delta=0.01)
    assert p.eps + (1 + p.eps) / (1 - p.sqrt_c) * p.eps_p + p.eps_t / 2 <= p.eps_a + 1e-9
    assert p.n_r > 0 and p.max_len >= 2
    # n_r formula: 3c/eps^2 ln(n/delta)
    import math

    want = math.ceil(3 * 0.6 / p.eps**2 * math.log(10_000 / 0.01))
    assert p.n_r == want


def test_walks_start_at_u_and_terminate(toy, key):
    walks = sample_walks(key, toy["eg"], 2, n_r=500, max_len=8, sqrt_c=0.7)
    w = np.asarray(walks)
    assert (w[:, 0] == 2).all()
    n = toy["n"]
    # once sentinel, always sentinel
    dead = w == n
    assert ((~dead[:, 1:]) | dead[:, 1:] >= dead[:, :-1]).all()
    lens = np.asarray(walk_lengths(walks, n))
    assert lens.min() >= 1
    # mean length ~ 1/(1-sqrt_c) in expectation (truncation shortens a bit)
    assert 1.5 < lens.mean() < 5.0


def test_telescoped_equals_reference_random(small_powerlaw, key):
    g = small_powerlaw["g"]
    eg = small_powerlaw["eg"]
    u = int(np.argmax(np.asarray(g.in_deg)))
    walks = sample_walks(key, eg, u, n_r=16, max_len=7, sqrt_c=0.775)
    tele = probe_walks_telescoped(g, walks, sqrt_c=0.775)
    for k in range(4):
        ref = estimate_walk_reference(g, walks[k], 0.775)
        np.testing.assert_allclose(
            np.asarray(tele[:, k]), np.asarray(ref), atol=1e-5
        )


def test_tree_variant_equals_telescoped(small_powerlaw, key):
    g, eg, n = small_powerlaw["g"], small_powerlaw["eg"], small_powerlaw["n"]
    u = int(np.argmax(np.asarray(g.in_deg)))
    walks = sample_walks(key, eg, u, n_r=64, max_len=7, sqrt_c=0.775)
    tele_sum = probe_walks_telescoped(g, walks, sqrt_c=0.775).sum(axis=1)
    tree = build_prefix_tree(np.asarray(walks), n)
    tree_sum = probe_tree_levels(
        g,
        tuple(jnp.asarray(x) for x in tree.nodes),
        tuple(jnp.asarray(x) for x in tree.weights),
        tuple(jnp.asarray(x) for x in tree.parent),
        tuple(jnp.asarray(x) for x in tree.parent_node),
        sqrt_c=0.775,
    )
    np.testing.assert_allclose(
        np.asarray(tree_sum), np.asarray(tele_sum), atol=1e-4
    )
    st_ = tree_stats(tree)
    assert st_["total_columns"] <= 64 * 7


def test_error_bound_toy(toy, key):
    truth = np.asarray(simrank_power(toy["g"], c=0.25, iters=60))[0]
    params = make_params(toy["n"], c=0.25, eps_a=0.1, delta=0.01)
    est = np.asarray(
        single_source(key, toy["g"], toy["eg"], 0, params, variant="tree")
    )
    err = np.abs(est - truth)
    err[0] = 0
    assert err.max() <= params.eps_a, f"maxerr {err.max()}"


def test_pruning_only_reduces_scores(toy, key):
    params = make_params(toy["n"], c=0.25, eps_a=0.1)
    walks = sample_walks(key, toy["eg"], 0, n_r=64, max_len=6, sqrt_c=0.5)
    no_prune = probe_walks_telescoped(toy["g"], walks, sqrt_c=0.5)
    pruned = probe_walks_telescoped(
        toy["g"], walks, sqrt_c=0.5, eps_p=0.02
    )
    diff = np.asarray(no_prune - pruned)
    assert diff.min() >= -1e-6  # one-sided
    # per-walk error bounded by eps_p per prefix; coarse bound: L * eps_p
    assert diff.max() <= 6 * 0.02 + 1e-6


def test_randomized_probe_unbiased(toy, key):
    from repro.core.probe_random import randomized_probe_walk

    walk = jnp.array([0, 1, 0, 1, 8, 8], dtype=jnp.int32)  # (a,b,a,b)
    det = estimate_walk_reference(toy["g"], walk[:4], 0.5)
    trials = 3000
    keys = jax.random.split(key, trials)
    batch = jax.vmap(lambda k: randomized_probe_walk(k, toy["eg"], walk,
                                                     sqrt_c=0.5, max_len=6))(keys)
    acc = np.asarray(batch).mean(axis=0)
    np.testing.assert_allclose(acc, np.asarray(det), atol=0.03)


def test_topk(toy, key):
    truth = np.asarray(simrank_power(toy["g"], c=0.25, iters=60))[0]
    params = make_params(toy["n"], c=0.25, eps_a=0.05)
    idx, vals = topk(key, toy["g"], toy["eg"], 0, 3, params, variant="tree")
    idx = np.asarray(idx)
    true_top = np.argsort(-np.where(np.arange(8) == 0, -1, truth))[:3]
    # Def 2 guarantee: returned scores are eps_a-close to the true i-th best
    true_sorted = np.sort(truth[true_top])[::-1]
    for i in range(3):
        assert truth[idx[i]] >= true_sorted[i] - params.eps_a


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(20, 60),
    m_mult=st.integers(2, 6),
    seed=st.integers(0, 10_000),
    c=st.sampled_from([0.25, 0.6, 0.8]),
)
def test_property_telescoped_equals_reference(n, m_mult, seed, c):
    """Invariant: telescoped probe == sum of per-prefix Alg.2 probes."""
    src, dst, n = erdos_renyi_graph(n, n * m_mult, seed=seed)
    if len(src) == 0:
        return
    g = graph_from_edges(src, dst, n)
    eg = ell_from_edges(src, dst, n)
    key = jax.random.key(seed)
    sqrt_c = float(np.sqrt(c))
    walks = sample_walks(key, eg, int(dst[0]), n_r=4, max_len=6, sqrt_c=sqrt_c)
    tele = probe_walks_telescoped(g, walks, sqrt_c=sqrt_c)
    for k in range(2):
        ref = estimate_walk_reference(g, walks[k], sqrt_c)
        np.testing.assert_allclose(
            np.asarray(tele[:, k]), np.asarray(ref), atol=1e-5
        )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_estimates_are_probabilities(seed):
    """Per-walk estimates lie in [0, 1] (Thm 1's boundedness argument)."""
    src, dst, n = erdos_renyi_graph(40, 160, seed=seed)
    g = graph_from_edges(src, dst, n)
    eg = ell_from_edges(src, dst, n)
    key = jax.random.key(seed)
    walks = sample_walks(key, eg, int(dst[0]), n_r=32, max_len=8, sqrt_c=0.775)
    tele = np.asarray(probe_walks_telescoped(g, walks, sqrt_c=0.775))
    assert tele.min() >= -1e-6
    # each per-walk estimate s~_k(u, v) is itself a probability (Thm 1 proof)
    assert tele.max() <= 1.0 + 1e-5


def test_auto_variant_matches_truth(toy, key):
    """'auto' (best-of-both-worlds switch, §4.4) stays within the bound."""
    truth = np.asarray(simrank_power(toy["g"], c=0.25, iters=60))[0]
    params = make_params(toy["n"], c=0.25, eps_a=0.1, delta=0.01,
                         n_r_override=4096)
    est = np.asarray(
        single_source(key, toy["g"], toy["eg"], 0, params, variant="auto")
    )
    err = np.abs(est - truth)
    err[0] = 0
    assert err.max() <= params.eps_a
