"""Session API (repro.api): parity with the legacy surface + invariants.

The tentpole contracts:

* ``SimRankSession.query`` is BIT-IDENTICAL to the legacy core entry points
  (``single_source`` / ``topk`` / ``multi_source_topk``) under shared PRNG
  keys — the session is a new surface, not a new estimator;
* ``drain()`` reproduces the PR-1 engine's fused dispatch exactly
  (submit-time streams, repeat-padded batches);
* ``GraphHandle`` update/regrow invariants (mirror == rebuild, sticky
  overflow, version accounting) hold when driven through the session;
* the §4.4 planner resolves ``variant='auto'`` to a concrete legacy
  variant (never a new code path);
* the legacy engines and ``single_source_simple`` are deprecation shims
  that match their pre-session behavior.
"""
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import (
    GraphHandle,
    QuerySpec,
    ResultEnvelope,
    SimRankSession,
    abs_error_bound,
)
from repro.core import (
    make_params,
    multi_source,
    multi_source_topk,
    single_source,
    single_source_simple,
    topk,
)
from repro.graph import (
    ell_from_edges,
    erdos_renyi_graph,
    graph_from_edges,
    graph_to_host_edges,
    powerlaw_graph,
)


def _mirrors_equal_rebuild(h: GraphHandle):
    """COO and ELL mirrors bit-identical to a from-scratch rebuild."""
    src, dst = h.to_host_edges()
    g_rb = graph_from_edges(src, dst, h.n, capacity=h.capacity)
    eg_rb = ell_from_edges(src, dst, h.n, k_max=h.k_max)
    np.testing.assert_array_equal(np.asarray(h.g.src), np.asarray(g_rb.src))
    np.testing.assert_array_equal(np.asarray(h.g.dst), np.asarray(g_rb.dst))
    np.testing.assert_array_equal(np.asarray(h.g.in_deg), np.asarray(g_rb.in_deg))
    np.testing.assert_array_equal(np.asarray(h.eg.in_nbrs), np.asarray(eg_rb.in_nbrs))
    np.testing.assert_array_equal(np.asarray(h.eg.in_deg), np.asarray(eg_rb.in_deg))


@pytest.fixture()
def toy_handle(toy):
    return GraphHandle(g=toy["g"], eg=toy["eg"])


@pytest.fixture()
def toy_session(toy_handle):
    return SimRankSession(
        toy_handle, c=0.25, eps_a=0.1, top_k=3, batch_q=2, seed=0,
        walk_chunk=64,
    )


# ---------------------------------------------------------------------------
# GraphHandle
# ---------------------------------------------------------------------------


def test_handle_from_edges_matches_mirror_pair(toy):
    """from_edges == the legacy graph_from_edges + ell_from_edges pair."""
    h = GraphHandle.from_edges(toy["src"], toy["dst"], toy["n"])
    np.testing.assert_array_equal(np.asarray(h.g.src), np.asarray(toy["g"].src))
    np.testing.assert_array_equal(
        np.asarray(h.eg.in_nbrs), np.asarray(toy["eg"].in_nbrs)
    )
    assert h.n == toy["n"] and h.version == 0 and not h.overflow
    assert h.num_edges == int(toy["g"].num_edges)


def test_handle_rejects_mismatched_mirrors(toy):
    src, dst, n = toy["src"], toy["dst"], toy["n"]
    other = ell_from_edges(src, dst, n + 1)
    with pytest.raises(ValueError):
        GraphHandle(g=toy["g"], eg=other)


# ---------------------------------------------------------------------------
# query(): bit-parity with the legacy core entry points under shared keys
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["telescoped", "tree"])
def test_query_single_source_parity(toy_session, toy, key, variant):
    sess = toy_session
    params = make_params(toy["n"], c=0.25, eps_a=0.1, delta=0.01)
    env = sess.query(
        QuerySpec(kind="single_source", node=0, key=key, variant=variant)
    )
    ref = np.asarray(single_source(
        key, toy["g"], toy["eg"], 0, params, variant=variant, walk_chunk=64
    ))
    assert np.array_equal(env.scores, ref)  # bit-for-bit
    assert env.kind == "single_source" and env.variant == variant
    assert env.version == 0 and env.walks_used == params.n_r


def test_query_topk_parity(toy_session, toy, key):
    params = make_params(toy["n"], c=0.25, eps_a=0.1, delta=0.01)
    env = toy_session.query(
        QuerySpec(kind="topk", node=0, k=3, key=key, variant="telescoped")
    )
    idx, vals = topk(
        key, toy["g"], toy["eg"], 0, 3, params, variant="telescoped",
        walk_chunk=64,
    )
    assert np.array_equal(env.topk_nodes, np.asarray(idx))
    assert np.array_equal(env.topk_scores, np.asarray(vals))
    assert 0 not in env.topk_nodes  # query node excluded


def test_query_batched_parity(toy_session, toy, key):
    """Batched specs == multi_source(_topk): scalar key splits (legacy
    semantics), a [Q] key array passes through as per-query streams."""
    params = make_params(toy["n"], c=0.25, eps_a=0.1, delta=0.01)
    us = jnp.asarray([0, 2, 4], jnp.int32)
    env = toy_session.query(QuerySpec(kind="topk", nodes=(0, 2, 4), k=3, key=key))
    idx, vals = multi_source_topk(key, toy["g"], toy["eg"], us, 3, params, lanes=64)
    assert np.array_equal(env.topk_nodes, np.asarray(idx))
    assert np.array_equal(env.topk_scores, np.asarray(vals))

    keys = jax.random.split(key, 3)
    env2 = toy_session.query(
        QuerySpec(kind="single_source", nodes=(0, 2, 4), key=keys)
    )
    est = multi_source(None, toy["g"], toy["eg"], us, params, lanes=64, keys=keys)
    assert np.array_equal(env2.scores, np.asarray(est))


def test_drain_reproduces_fused_engine_dispatch(small_powerlaw):
    """submit/drain == the PR-1 engine formula: fold_in(seed, seq) streams,
    repeat-padded fixed-size batches through multi_source_topk."""
    g, eg, n = small_powerlaw["g"], small_powerlaw["eg"], small_powerlaw["n"]
    h = GraphHandle(g=g, eg=eg)
    qs = np.argsort(-np.asarray(g.in_deg))[:3].astype(int)  # 3 qs, batch_q=2
    sess = SimRankSession(h, c=0.6, eps_a=0.2, top_k=5, batch_q=2, seed=7,
                          walk_chunk=64)
    for u in qs:
        sess.submit(int(u))
    res = sess.drain(budget_walks=96)
    assert [r.node for r in res] == list(qs)

    params = make_params(n, c=0.6, eps_a=0.2, delta=0.01)
    streams = [jax.random.fold_in(jax.random.key(7), i) for i in range(3)]
    b0 = multi_source_topk(
        None, g, eg, jnp.asarray(qs[:2], jnp.int32), 5, params,
        lanes=64, n_r=96, keys=jnp.stack(streams[:2]),
    )
    b1 = multi_source_topk(  # final short batch: repeat-padded
        None, g, eg, jnp.asarray([qs[2], qs[2]], jnp.int32), 5, params,
        lanes=64, n_r=96, keys=jnp.stack([streams[2], streams[2]]),
    )
    assert np.array_equal(res[0].topk_scores, np.asarray(b0[1])[0])
    assert np.array_equal(res[1].topk_scores, np.asarray(b0[1])[1])
    assert np.array_equal(res[2].topk_scores, np.asarray(b1[1])[0])
    assert sess.stats.queries == 3 and sess.stats.steps == 2


def test_drain_cuts_batches_at_group_change(small_powerlaw):
    """Specs with different (kind, k, budget) never share a dispatch."""
    h = GraphHandle(g=small_powerlaw["g"], eg=small_powerlaw["eg"])
    sess = SimRankSession(h, c=0.6, eps_a=0.2, top_k=5, batch_q=4, seed=0,
                          walk_chunk=64)
    u = int(np.argmax(np.asarray(h.g.in_deg)))
    sess.submit(QuerySpec(kind="topk", node=u, k=5, budget_walks=64))
    sess.submit(QuerySpec(kind="topk", node=u, k=3, budget_walks=64))
    sess.submit(QuerySpec(kind="single_source", node=u, budget_walks=64))
    res = sess.drain()
    assert sess.stats.steps == 3  # one dispatch per group
    assert res[0].topk_nodes.shape == (5,)
    assert res[1].topk_nodes.shape == (3,)
    assert res[2].scores.shape == (h.n,)


# ---------------------------------------------------------------------------
# Planner (§4.4 promoted host-side) + error bound at the effective budget
# ---------------------------------------------------------------------------


def test_planner_resolves_auto_to_legacy_variant(toy_session, key):
    sess = toy_session
    # toy node 0 has tiny in-degree and the full n_r is large -> tree
    spec = QuerySpec(kind="single_source", node=0, variant="auto", key=key)
    assert sess.plan(spec) == "tree"
    # a capped budget comparable to the in-degree -> fused telescoped
    d = int(sess.handle.eg.in_deg[0])
    capped = QuerySpec(kind="single_source", node=0, variant="auto",
                       budget_walks=max(1, 2 * d), key=key)
    assert sess.plan(capped) == "telescoped"
    # batched specs always take the fused path
    assert sess.plan(QuerySpec(kind="topk", nodes=(0, 2), k=2)) == "telescoped"
    # auto == the explicit variant it planned, bit-for-bit
    env_auto = sess.query(spec)
    env_tree = sess.query(
        QuerySpec(kind="single_source", node=0, variant="tree", key=key)
    )
    assert env_auto.variant == "tree"
    assert np.array_equal(env_auto.scores, env_tree.scores)


def test_error_bound_at_effective_budget(toy_session, key):
    sess = toy_session
    full = sess.query(QuerySpec(kind="topk", node=0, key=key))
    capped = sess.query(QuerySpec(kind="topk", node=0, key=key, budget_walks=32))
    assert capped.walks_used == 32 and full.walks_used == sess.params.n_r
    # anytime queries report the looser bound they actually guarantee
    assert capped.error_bound > full.error_bound
    assert full.error_bound <= sess.params.eps_a + 1e-9
    assert capped.error_bound == pytest.approx(
        abs_error_bound(sess.params, n=sess.handle.n, n_r=32)
    )


# ---------------------------------------------------------------------------
# Updates through the session surface: invariants re-asserted
# ---------------------------------------------------------------------------


@pytest.fixture()
def er_session():
    src, dst, n = erdos_renyi_graph(60, 300, seed=5)
    h = GraphHandle.from_edges(
        src, dst, n,
        capacity=len(src) + 64,
        k_max=int(np.bincount(dst, minlength=n).max()) + 8,
    )
    return src, dst, SimRankSession(
        h, c=0.3, eps_a=0.3, top_k=2, batch_q=2, update_batch=8, seed=0
    )


def test_update_mirror_equals_rebuild(er_session):
    src, dst, sess = er_session
    rng = np.random.default_rng(1)
    rep = sess.update(inserts=(rng.integers(0, 60, 10), rng.integers(0, 60, 10)))
    assert rep.applied == 10 and rep.version == 1
    rep2 = sess.update(deletes=(src[:5], dst[:5]))
    assert rep2.applied == 5 and rep2.version == 2
    _mirrors_equal_rebuild(sess.handle)
    assert sess.stats.updates == 15


def test_update_multigraph_duplicate_deletes_vectorized(er_session):
    """Duplicate (s, d) pairs in ONE delete call remove one copy per op —
    the np.unique/cumsum occurrence split preserves the one-copy-per-batch
    semantics of the seed's python loop."""
    src, dst, sess = er_session
    base = sess.handle.num_edges
    fresh_s, fresh_d = (int(src[0]) + 9) % 60, int(dst[0])
    sess.update(inserts=([fresh_s] * 3, [fresh_d] * 3))
    assert sess.handle.num_edges == base + 3
    rep = sess.update(deletes=([fresh_s] * 3, [fresh_d] * 3))
    assert rep.applied == 3
    assert sess.handle.num_edges == base
    _mirrors_equal_rebuild(sess.handle)


def test_occurrence_numbers_match_seed_loop():
    from repro.api.session import _occurrence_numbers

    rng = np.random.default_rng(0)
    s = rng.integers(0, 4, 40).astype(np.int32)
    d = rng.integers(0, 4, 40).astype(np.int32)
    seen, occ_ref = {}, np.empty(40, np.int64)
    for i, (a, b) in enumerate(zip(s.tolist(), d.tolist())):  # the seed loop
        occ_ref[i] = seen.get((a, b), 0)
        seen[(a, b)] = occ_ref[i] + 1
    np.testing.assert_array_equal(_occurrence_numbers(s, d, 4), occ_ref)


def test_update_overflow_sticky_and_regrow_via_session():
    src = np.array([0, 1, 2], np.int32)
    dst = np.array([1, 2, 0], np.int32)
    h = GraphHandle.from_edges(src, dst, 6, capacity=4, k_max=2)
    sess = SimRankSession(h, c=0.3, eps_a=0.3, top_k=2, seed=0,
                          auto_regrow=False)
    rep = sess.update(inserts=([3, 4, 5], [0, 1, 2]))
    assert rep.applied == 1 and rep.overflow
    assert sorted(rep.skipped) == [(4, 1, True), (5, 2, True)]
    assert sess.overflow and sess.version == 1  # sticky + one bump
    _mirrors_equal_rebuild(sess.handle)  # the skip hit BOTH mirrors
    v = sess.version
    sess.regrow()
    assert not sess.overflow and sess.version == v  # representation change
    rep2 = sess.update(inserts=([4, 5], [1, 2]))
    assert rep2.applied == 2 and not sess.overflow
    assert sess.handle.num_edges == 6
    _mirrors_equal_rebuild(sess.handle)


def test_update_auto_regrow_retries_until_applied():
    src = np.array([0, 1, 2], np.int32)
    dst = np.array([1, 2, 0], np.int32)
    h = GraphHandle.from_edges(src, dst, 6, capacity=4, k_max=2)
    sess = SimRankSession(h, c=0.3, eps_a=0.3, top_k=2, seed=0)
    rep = sess.update(inserts=([3, 4, 5], [0, 1, 2]))
    assert rep.applied == 3 and rep.regrows >= 1 and not rep.skipped
    assert not sess.overflow and sess.handle.num_edges == 6
    _mirrors_equal_rebuild(sess.handle)


def test_update_rejects_out_of_range_ops(er_session):
    _, _, sess = er_session
    with pytest.raises(ValueError):
        sess.update(inserts=([60], [0]))
    with pytest.raises(ValueError):
        sess.queue_update([0], [-1])
    assert sess.pending == (0, 0)


# ---------------------------------------------------------------------------
# Fused epochs through the session surface
# ---------------------------------------------------------------------------


def test_epoch_scores_equal_rebuild_via_session(er_session):
    """Epoch scores on the incrementally-updated graph == multi_source on a
    from-scratch rebuild under the session's submit-time streams."""
    src, dst, sess = er_session
    n = 60
    rng = np.random.default_rng(3)
    new_s = rng.integers(0, n, 8).astype(np.int32)
    new_d = rng.integers(0, n, 8).astype(np.int32)
    queries = [1, 2]
    ep = sess.epoch(inserts=(new_s, new_d), queries=queries, budget_walks=64)
    assert ep.version == 1 and len(ep.results) == 2
    _mirrors_equal_rebuild(sess.handle)

    src2 = np.concatenate([src, new_s])
    dst2 = np.concatenate([dst, new_d])
    h_rb = GraphHandle.from_edges(src2, dst2, n, capacity=sess.handle.capacity,
                                  k_max=sess.handle.k_max)
    keys = jnp.stack(
        [jax.random.fold_in(jax.random.key(0), i) for i in range(2)]
    )
    est = np.asarray(multi_source(
        None, h_rb.g, h_rb.eg, jnp.asarray(queries, jnp.int32),
        make_params(n, c=0.3, eps_a=0.3, delta=0.01),
        lanes=256, n_r=64, keys=keys,
    ))
    for i, res in enumerate(ep.results):
        expect = est[i].copy()
        expect[queries[i]] = -np.inf  # top-k excludes the query node
        order = np.argsort(-expect, kind="stable")[:2]
        np.testing.assert_allclose(res.topk_scores, expect[order], atol=1e-5)
        assert res.version == 1 and res.walks_used == 64


def test_epoch_single_source_kind_returns_score_vectors(er_session):
    """A single_source query batch rides the SAME fused epoch (top_k=0) and
    returns full estimate vectors — queries and updates, one surface."""
    src, dst, sess = er_session
    n = 60
    specs = [QuerySpec(kind="single_source", node=u) for u in (1, 2)]
    ep = sess.epoch(inserts=(src[:1] * 0 + 7, dst[:1] * 0 + 3),
                    queries=specs, budget_walks=64)
    assert len(ep.results) == 2
    src2 = np.concatenate([src, [7]])
    dst2 = np.concatenate([dst, [3]])
    h_rb = GraphHandle.from_edges(src2, dst2, n, capacity=sess.handle.capacity,
                                  k_max=sess.handle.k_max)
    keys = jnp.stack(
        [jax.random.fold_in(jax.random.key(0), i) for i in range(2)]
    )
    est = np.asarray(multi_source(
        None, h_rb.g, h_rb.eg, jnp.asarray([1, 2], jnp.int32),
        make_params(n, c=0.3, eps_a=0.3, delta=0.01),
        lanes=256, n_r=64, keys=keys,
    ))
    for i, res in enumerate(ep.results):
        assert res.kind == "single_source" and res.scores.shape == (n,)
        np.testing.assert_allclose(res.scores, est[i], atol=1e-5)


# ---------------------------------------------------------------------------
# Deprecation shims: legacy entry points delegate and warn
# ---------------------------------------------------------------------------


def test_single_source_simple_shim_regression(toy, key):
    """The legacy bare-EllGraph form == single_source(key, eg, eg, ...)
    EXACTLY (the silent both-mirrors choice, now explicit + warned), and
    the GraphHandle form uses the proper (COO push, ELL gather) pair."""
    eg, n = toy["eg"], toy["n"]
    params = make_params(n, c=0.25, eps_a=0.1, delta=0.01)
    with pytest.warns(DeprecationWarning):
        est = single_source_simple(key, eg, 0, c=0.25, eps_a=0.1, delta=0.01)
    ref = single_source(key, eg, eg, 0, params)
    assert np.array_equal(np.asarray(est), np.asarray(ref))

    h = GraphHandle(g=toy["g"], eg=eg)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # handle form must NOT warn
        est_h = single_source_simple(key, h, 0, c=0.25, eps_a=0.1, delta=0.01)
    ref_h = single_source(key, toy["g"], eg, 0, params)
    assert np.array_equal(np.asarray(est_h), np.asarray(ref_h))


def test_engine_shims_warn_and_delegate(small_powerlaw):
    from repro.serving import DynamicEngine, SimRankEngine

    g, eg = small_powerlaw["g"], small_powerlaw["eg"]
    with pytest.warns(DeprecationWarning):
        eng = SimRankEngine(g, eg, eps_a=0.2, top_k=3, batch_q=2, seed=7,
                            walk_chunk=64)
    u = int(np.argmax(np.asarray(g.in_deg)))
    res = eng.run_query(u, budget_walks=64)
    assert isinstance(res, ResultEnvelope)
    # shim result == the session serving the same spec with the same stream
    h = GraphHandle(g=g, eg=eg)
    sess = SimRankSession(h, c=0.6, eps_a=0.2, top_k=3, batch_q=2, seed=7,
                          walk_chunk=64)
    spec = QuerySpec(kind="topk", node=u, k=3, variant="telescoped")
    ref = sess._serve_fused([(spec, sess._query_key())], 64)[0]
    assert np.array_equal(res.topk_scores, ref.topk_scores)
    assert eng.stats.queries == 1 and eng.session is not None

    with pytest.warns(DeprecationWarning):
        deng = DynamicEngine(g, eg, eps_a=0.2, top_k=3, batch_q=2,
                             update_batch=4, seed=0)
    held = deng.stats  # legacy contract: ONE live object, not a snapshot
    deng.submit(u)
    ep = deng.step(budget_walks=64)
    assert ep.results[0].version == 0
    assert deng.stats.epochs == 1 and deng.pending == (0, 0)
    assert held.epochs == 1  # the held reference stayed current


def test_engine_mirror_setters_copy_and_validate(small_powerlaw):
    """Assigning eng.g/eng.eg own-copies (donated epoch steps must never
    share caller buffers) and rejects a mismatched mirror."""
    from repro.serving import DynamicEngine

    g, eg, n = small_powerlaw["g"], small_powerlaw["eg"], small_powerlaw["n"]
    with pytest.warns(DeprecationWarning):
        eng = DynamicEngine(g, eg, eps_a=0.2, top_k=2, batch_q=2,
                            update_batch=4, seed=0)
    mine = graph_from_edges(small_powerlaw["src"], small_powerlaw["dst"], n,
                            capacity=int(g.capacity))
    before = np.asarray(mine.src).copy()
    eng.g = mine  # must copy, not alias
    eng.insert([1], [2])
    eng.submit(1)
    eng.step(budget_walks=16)  # donates the engine's buffers ...
    np.testing.assert_array_equal(np.asarray(mine.src), before)  # ... not mine
    with pytest.raises(ValueError):
        eng.eg = ell_from_edges(small_powerlaw["src"], small_powerlaw["dst"],
                                n + 1)


def test_abs_error_bound_rejects_nonpositive_budget(toy):
    params = make_params(toy["n"], c=0.25, eps_a=0.1, delta=0.01)
    with pytest.raises(ValueError):
        abs_error_bound(params, n=toy["n"], n_r=0)


def test_unowned_session_refuses_epoch(toy_handle):
    """own_graph=False shares the caller's buffers — the donating epoch
    step must refuse rather than invalidate them."""
    sess = SimRankSession(toy_handle, c=0.25, eps_a=0.1, top_k=2,
                          own_graph=False)
    with pytest.raises(ValueError, match="own_graph"):
        sess.epoch(queries=[0], budget_walks=16)
    # queries and immediate updates remain available on a shared handle
    assert sess.query(QuerySpec(kind="topk", node=0, budget_walks=32)).node == 0


def test_legacy_queryresult_positional_construction():
    from repro.serving import QueryResult

    res = QueryResult(3, np.array([1, 2]), np.array([0.5, 0.4]), 64, 0.1)
    assert isinstance(res, ResultEnvelope)
    assert res.node == 3 and res.walks_used == 64  # old field order binds
    assert list(res.topk_nodes) == [1, 2] and res.version == -1


def test_session_requires_handle(toy):
    with pytest.raises(TypeError):
        SimRankSession(toy["g"])
    with pytest.raises(ValueError):
        QuerySpec(kind="topk")  # neither node nor nodes
    with pytest.raises(ValueError):
        QuerySpec(kind="nope", node=0)


def test_session_owns_graph_state(er_session):
    """The session own-copies its handle: the caller's handle (and the
    arrays under it) are untouched by donated epoch steps."""
    src, dst, _ = er_session
    n = 60
    h = GraphHandle.from_edges(src, dst, n, capacity=len(src) + 64,
                               k_max=int(np.bincount(dst, minlength=n).max()) + 8)
    before = np.asarray(h.g.src).copy()
    sess = SimRankSession(h, c=0.3, eps_a=0.3, top_k=2, batch_q=2,
                          update_batch=4, seed=0)
    sess.epoch(inserts=([1], [2]), queries=[1], budget_walks=16)
    np.testing.assert_array_equal(np.asarray(h.g.src), before)
    assert h.version == 0 and sess.version == 1


def test_session_stats_threading(er_session):
    src, dst, sess = er_session
    sess.query(QuerySpec(kind="topk", node=1, budget_walks=32))
    sess.submit(1)
    sess.submit(2)
    sess.drain(budget_walks=32)
    sess.update(inserts=([1], [2]))
    sess.epoch(queries=[3], budget_walks=32)
    s = sess.stats
    assert s.queries == 4  # 1 query() + 2 drained + 1 epoch
    assert s.steps == 3  # query() + 1 drain batch + 1 epoch dispatch
    assert s.updates == 1 and s.epochs == 1
    assert s.as_dict()["queries"] == 4


def test_concurrent_submit_drain_thread_safe():
    """Many threads submitting (+ some draining) concurrently: every
    ticket gets exactly its own answer, bitwise-equal to a solo replay.

    This is the contract the serving collector (serving/service.py)
    builds on: handler threads call ``submit()`` while the collector
    drains, and the lock around queue mutation + ticket fill must keep
    (spec, key, ticket) triples intact under interleaving.
    """
    import threading

    src, dst, n = erdos_renyi_graph(60, 300, seed=5)
    h = GraphHandle.from_edges(src, dst, n)
    sess = SimRankSession(h, c=0.3, eps_a=0.3, top_k=3, batch_q=4, seed=0)
    sess.query(QuerySpec(kind="topk", node=0, budget_walks=16))  # warm jit

    T, PER = 8, 6
    tickets = [[None] * PER for _ in range(T)]
    barrier = threading.Barrier(T)

    def worker(t):
        barrier.wait()
        for j in range(PER):
            q = (t * PER + j) % n
            tickets[t][j] = sess.submit(QuerySpec(
                kind="topk", node=q, k=3, budget_walks=16,
                key=jax.random.key(10_000 + t * PER + j),
            ))
            if j % 3 == 2:
                sess.drain(budget_walks=16)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    sess.drain(budget_walks=16)

    assert sess.stats.queries == T * PER + 1  # + the warm-jit query()
    assert not sess.query_queue
    ref = SimRankSession(h, c=0.3, eps_a=0.3, top_k=3, batch_q=4, seed=99)
    for t in range(T):
        for j in range(PER):
            tk = tickets[t][j]
            assert tk is not None and tk.envelope is not None
            q = (t * PER + j) % n
            assert tk.envelope.node == q
            rtk = ref.submit(QuerySpec(
                kind="topk", node=q, k=3, budget_walks=16,
                key=jax.random.key(10_000 + t * PER + j),
            ))
            ref.drain()
            np.testing.assert_array_equal(
                np.asarray(tk.envelope.topk_nodes),
                np.asarray(rtk.envelope.topk_nodes),
            )
            np.testing.assert_array_equal(
                np.asarray(tk.envelope.topk_scores),
                np.asarray(rtk.envelope.topk_scores),
            )
