"""Graph substrate: structs, dynamic updates, partition, sampler, io."""
import os
import tempfile

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.graph import (
    csr_from_edges,
    ell_from_edges,
    erdos_renyi_graph,
    graph_from_edges,
    graph_to_host_edges,
    powerlaw_graph,
    push_coo,
    push_ell,
)
from repro.graph.dynamic import (
    delete_edges,
    delete_edges_ell,
    insert_edges,
    insert_edges_ell,
)
from repro.graph.io import read_edgelist, write_edgelist
from repro.graph.partition import (
    edge_balance_stats,
    partition_edges_by_dst,
    partition_nodes,
)
from repro.graph.sampler import block_shapes, sample_blocks


def test_push_coo_equals_push_ell(small_powerlaw, rng):
    g, eg, n = small_powerlaw["g"], small_powerlaw["eg"], small_powerlaw["n"]
    x = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.1, 1, n).astype(np.float32))
    a = push_coo(g, x, weights=w)
    b = push_ell(eg, x, weights=w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_degrees_consistent(small_powerlaw):
    src, dst, n = small_powerlaw["src"], small_powerlaw["dst"], small_powerlaw["n"]
    g = small_powerlaw["g"]
    np.testing.assert_array_equal(
        np.asarray(g.in_deg), np.bincount(dst, minlength=n)[:n]
    )
    np.testing.assert_array_equal(
        np.asarray(g.out_deg), np.bincount(src, minlength=n)[:n]
    )


def test_dynamic_insert_then_delete_roundtrip(toy):
    g, eg = toy["g"], toy["eg"]
    g = graph_from_edges(toy["src"], toy["dst"], toy["n"],
                         capacity=len(toy["src"]) + 16)
    eg2 = ell_from_edges(toy["src"], toy["dst"], toy["n"], k_max=8)
    new_s = jnp.array([5, 6], dtype=jnp.int32)
    new_d = jnp.array([0, 1], dtype=jnp.int32)
    g2 = insert_edges(g, new_s, new_d)
    e2 = insert_edges_ell(eg2, new_s, new_d)
    assert int(g2.num_edges) == int(g.num_edges) + 2
    assert int(e2.in_deg[0]) == int(eg2.in_deg[0]) + 1
    g3 = delete_edges(g2, new_s, new_d)
    e3 = delete_edges_ell(e2, new_s, new_d)
    assert int(g3.num_edges) == int(g.num_edges)
    np.testing.assert_array_equal(np.asarray(e3.in_deg), np.asarray(eg2.in_deg))
    # push results identical to the original graph after the round-trip
    x = jnp.ones((toy["n"], 2), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(push_coo(g3, x)), np.asarray(push_coo(g, x)), atol=1e-6
    )


def test_dynamic_updates_change_probe_results(toy, key):
    """Index-free freshness: queries reflect updates immediately."""
    from repro.core import make_params, single_source

    params = make_params(toy["n"], c=0.25, eps_a=0.1, n_r_override=512)
    g = graph_from_edges(toy["src"], toy["dst"], toy["n"],
                         capacity=len(toy["src"]) + 8)
    eg = ell_from_edges(toy["src"], toy["dst"], toy["n"], k_max=8)
    before = np.asarray(single_source(key, g, eg, 0, params, variant="tree"))
    # add edges f->a, f->b: creates fresh 2-step meeting paths
    g2 = insert_edges(g, jnp.array([5, 5], jnp.int32), jnp.array([0, 1], jnp.int32))
    eg2 = insert_edges_ell(eg, jnp.array([5, 5], jnp.int32),
                           jnp.array([0, 1], jnp.int32))
    after = np.asarray(single_source(key, g2, eg2, 0, params, variant="tree"))
    assert not np.allclose(before, after)


def test_partition_by_dst_roundtrip(small_powerlaw):
    src, dst, n = small_powerlaw["src"], small_powerlaw["dst"], small_powerlaw["n"]
    part = partition_edges_by_dst(src, dst, n, 4)
    assert part["src_sh"].shape[0] == 4
    # every live edge appears exactly once with a correctly localized dst
    total = 0
    for s in range(4):
        live = part["src_sh"][s] < part["n_pad"]
        total += live.sum()
        glob_dst = part["dst_sh"][s][live] + s * part["rows"]
        assert (glob_dst // part["rows"] == s).all()
    assert total == len(src)
    stats = edge_balance_stats(part["counts"])
    assert stats["imbalance"] >= 1.0


def test_partition_nodes_shapes():
    vals = np.arange(10, dtype=np.float32)
    out = partition_nodes(vals, 4)
    assert out.shape == (4, 3)
    np.testing.assert_array_equal(out.reshape(-1)[:10], vals)


def test_sampler_shapes_and_validity(small_powerlaw, rng):
    src, dst, n = small_powerlaw["src"], small_powerlaw["dst"], small_powerlaw["n"]
    csr_in = csr_from_edges(src, dst, n, by="dst")
    seeds = rng.choice(n, 8, replace=False).astype(np.int32)
    blocks = sample_blocks(csr_in, seeds, (3, 2), rng)
    shapes = block_shapes(8, (3, 2))
    assert blocks.nodes.shape[0] == shapes["table"]
    for h, e in enumerate(shapes["edges"]):
        assert blocks.edge_src[h].shape[0] == e
        # sampled srcs are real in-neighbors where live
        live = blocks.edge_mask[h]
        s_pos = blocks.edge_src[h][live]
        d_pos = blocks.edge_dst[h][live]
        for sp, dp in list(zip(s_pos[:20], d_pos[:20])):
            v = blocks.nodes[dp]
            u = blocks.nodes[sp]
            assert u in csr_in.neighbors(int(v))


def test_edgelist_io_roundtrip(tmp_path):
    src = np.array([0, 1, 2], dtype=np.int32)
    dst = np.array([1, 2, 0], dtype=np.int32)
    p = os.path.join(tmp_path, "g.txt")
    write_edgelist(p, src, dst)
    s2, d2, n = read_edgelist(p)
    np.testing.assert_array_equal(np.sort(s2), np.sort(src))
    assert n == 3


@settings(max_examples=10, deadline=None)
@given(n=st.integers(10, 80), m=st.integers(10, 300), seed=st.integers(0, 99))
def test_property_generators_produce_simple_graphs(n, m, seed):
    src, dst, n = powerlaw_graph(n, m, seed=seed)
    assert (src != dst).all()  # no self loops
    key = src.astype(np.int64) * n + dst
    assert len(np.unique(key)) == len(key)  # no duplicates
    assert src.min() >= 0 and dst.max() < n
