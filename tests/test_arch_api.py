"""Arch-bundle API consistency: input specs/shardings trees match, shapes
honor the assignment, applicability rules, and the params accounting."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import arch
from repro.configs.base import ARCH_IDS, LM_SHAPES, get_config, shapes_for


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_input_specs_and_shardings_align(arch_id):
    for shape in shapes_for(arch_id):
        if not arch.is_applicable(arch_id, shape.name)[0]:
            continue
        b = arch.build(arch_id, shape.name, smoke=True)
        specs = b.input_specs()
        shards = b.input_shardings()
        s1 = jax.tree_util.tree_structure(specs)
        s2 = jax.tree_util.tree_structure(
            shards, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )
        assert s1 == s2, f"{arch_id}/{shape.name}: spec/sharding trees differ"
        # every PartitionSpec rank covers its array rank
        flat_specs = jax.tree_util.tree_leaves(specs)
        flat_shards = jax.tree_util.tree_leaves(
            shards, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )
        for sds, ps in zip(flat_specs, flat_shards):
            assert len(ps) <= len(sds.shape)


def test_assignment_shapes_exact():
    lm = {s.name: s.dims for s in LM_SHAPES}
    assert lm["train_4k"] == dict(seq_len=4096, global_batch=256)
    assert lm["prefill_32k"] == dict(seq_len=32768, global_batch=32)
    assert lm["decode_32k"] == dict(seq_len=32768, global_batch=128)
    assert lm["long_500k"] == dict(seq_len=524288, global_batch=1)
    gnn = {s.name: s.dims for s in shapes_for("gcn-cora")}
    assert gnn["full_graph_sm"]["n_nodes"] == 2708
    assert gnn["minibatch_lg"]["n_edges"] == 114_615_892
    assert gnn["ogb_products"]["n_nodes"] == 2_449_029
    rec = {s.name: s.dims for s in shapes_for("wide-deep")}
    assert rec["train_batch"]["batch"] == 65536
    assert rec["retrieval_cand"]["n_candidates"] == 1_000_000


def test_long_500k_skip_rule():
    for a in ["llama3-405b", "yi-34b", "llama3.2-1b", "deepseek-v2-lite-16b",
              "qwen2-moe-a2.7b"]:
        ok, why = arch.is_applicable(a, "long_500k")
        assert not ok and "full-attention" in why
    assert arch.is_applicable("gcn-cora", "full_graph_sm") == (True, "")


def test_model_flops_positive_and_scaled():
    b_small = arch.build("llama3.2-1b", "train_4k")
    b_big = arch.build("llama3-405b", "train_4k")
    assert 0 < b_small.model_flops() < b_big.model_flops()
    # 6ND sanity: 405B x 1.05M tokens x 6
    assert b_big.model_flops() == pytest.approx(
        6 * b_big.cfg.params_active * 256 * 4096, rel=1e-6
    )


def test_moe_active_params_below_dense():
    cfg = get_config("deepseek-v2-lite-16b")
    assert cfg.params_active < cfg.params_dense / 3


def test_truncation_shift_is_one_sided(toy, key):
    from repro.core import make_params, simrank_power, single_source

    truth = np.asarray(simrank_power(toy["g"], c=0.25, iters=60))[0]
    p0 = make_params(toy["n"], c=0.25, eps_a=0.1, n_r_override=2048)
    p1 = make_params(toy["n"], c=0.25, eps_a=0.1, n_r_override=2048,
                     truncation_shift=True)
    e0 = np.asarray(single_source(key, toy["g"], toy["eg"], 0, p0))
    e1 = np.asarray(single_source(key, toy["g"], toy["eg"], 0, p1))
    # shift adds eps_t/2 to every reached node
    reached = (e0 > 0) & (np.arange(8) != 0)
    np.testing.assert_allclose(e1[reached] - e0[reached], p1.eps_t / 2,
                               atol=1e-6)
    # both stay within the bound
    for e in (e0, e1):
        err = np.abs(e - truth); err[0] = 0
        assert err.max() <= 0.1


def test_walk_termination_rate_matches_sqrt_c(key):
    """Each live step continues w.p. sqrt(c) (Def. 3) — statistical check on
    a graph where every node has in-degree > 0."""
    from repro.core import sample_walks
    from repro.graph import ell_from_edges

    n = 64
    src = np.arange(n, dtype=np.int32)
    dst = ((np.arange(n) + 1) % n).astype(np.int32)  # a big cycle
    eg = ell_from_edges(src, dst, n)
    sqrt_c = 0.7
    walks = np.asarray(
        sample_walks(key, eg, 0, n_r=20_000, max_len=6, sqrt_c=sqrt_c)
    )
    alive1 = (walks[:, 1] < n).mean()  # continued past step 1
    assert alive1 == pytest.approx(sqrt_c, abs=0.02)
    alive2 = (walks[:, 2] < n).mean()
    assert alive2 == pytest.approx(sqrt_c**2, abs=0.02)
