"""Distributed ProbeSim correctness on a local 8-device mesh.

Needs XLA_FLAGS set before jax init, so the meshed half runs in a
subprocess; it must produce results identical to the unsharded run (JAX PRNG
values are sharding-invariant)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.distributed import (
    build_sharded_graph,
    make_serve_step,
    probe_walks_sharded,
    sample_walks_sharded,
)
from repro.core.probe import probe_walks_telescoped
from repro.graph import graph_from_edges, powerlaw_graph

_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.base import ProbeSimConfig
from repro.core.distributed import build_sharded_graph, make_serve_step, graph_specs
from repro.graph import powerlaw_graph
from repro.utils.jaxcompat import make_mesh, set_mesh, specs_to_shardings
from jax.sharding import PartitionSpec as P

mesh = make_mesh((2, 4), ("data", "model"))
src, dst, n = powerlaw_graph(200, 1600, seed=3)
sg = build_sharded_graph(src, dst, n, pad_nodes=32, pad_edges=64)
cfg = ProbeSimConfig(name="t", n=n, m=len(src), c=0.6)
serve = make_serve_step(cfg, queries=2, walk_chunk=32, max_len=6, top_k=8,
                        edge_chunks=4)
queries = jnp.asarray([int(dst[0]), int(dst[1])], jnp.int32)
key = jax.random.key(7)
with set_mesh(mesh):
    jf = jax.jit(serve, in_shardings=specs_to_shardings(
        (graph_specs(sg), P(), P()), mesh=mesh))
    idx, vals = jf(sg, queries, key)
print(json.dumps(dict(idx=np.asarray(idx).tolist(),
                      vals=np.asarray(vals).tolist())))
"""


@pytest.fixture(scope="module")
def small_graph():
    src, dst, n = powerlaw_graph(200, 1600, seed=3)
    return src, dst, n


def _unsharded_serve(src, dst, n):
    from repro.configs.base import ProbeSimConfig

    sg = build_sharded_graph(src, dst, n, pad_nodes=32, pad_edges=64)
    cfg = ProbeSimConfig(name="t", n=n, m=len(src), c=0.6)
    serve = make_serve_step(cfg, queries=2, walk_chunk=32, max_len=6, top_k=8,
                            edge_chunks=4)
    queries = jnp.asarray([int(dst[0]), int(dst[1])], jnp.int32)
    return jax.jit(serve)(sg, queries, jax.random.key(7))


def test_sharded_probe_equals_telescoped(small_graph, key):
    src, dst, n = small_graph
    sg = build_sharded_graph(src, dst, n, pad_nodes=32, pad_edges=64)
    g = graph_from_edges(src, dst, n)
    walks = sample_walks_sharded(
        key, sg, jnp.asarray([int(dst[0])], jnp.int32),
        walks_per_query=16, max_len=6, sqrt_c=0.775,
    )
    # clip sentinel coding: sharded uses n_pad; local uses n
    walks_local = jnp.where(walks >= n, n, walks)
    a = probe_walks_sharded(sg, walks, sqrt_c=0.775, edge_chunks=4)[:n]
    b = probe_walks_telescoped(g, walks_local, sqrt_c=0.775)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_meshed_serve_step_matches_unsharded(small_graph):
    src, dst, n = small_graph
    idx0, vals0 = _unsharded_serve(src, dst, n)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    np.testing.assert_allclose(
        np.asarray(data["vals"]), np.asarray(vals0), atol=1e-5
    )
    # node sets should agree (order may tie-break differently)
    for q in range(2):
        assert set(data["idx"][q]) == set(np.asarray(idx0[q]).tolist())


_RING_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro.core.ring import build_ring_graph, probe_walks_ring
from repro.core.distributed import build_sharded_graph, probe_walks_sharded, sample_walks_sharded
from repro.utils.jaxcompat import make_mesh, set_mesh
mesh = make_mesh((2, 4), ("data", "model"))
from repro.graph import powerlaw_graph
src, dst, n = powerlaw_graph(200, 1600, seed=3)
rg = build_ring_graph(src, dst, n, shards=4)
sg = build_sharded_graph(src, dst, n, pad_nodes=4, pad_edges=64)
key = jax.random.key(5)
with set_mesh(mesh):
    walks = sample_walks_sharded(key, sg, jnp.asarray([int(dst[0])], jnp.int32),
                                 walks_per_query=16, max_len=6, sqrt_c=0.775)
    ref = probe_walks_sharded(sg, walks, sqrt_c=0.775, edge_chunks=4)
    walks_r = jnp.where(walks >= sg.n_pad, rg.n_pad, walks)
    out = probe_walks_ring(rg, walks_r, sqrt_c=0.775)
    np.testing.assert_allclose(np.asarray(out[:n]), np.asarray(ref[:n]), atol=1e-5)
    out16 = probe_walks_ring(rg, walks_r, sqrt_c=0.775, frontier_dtype=jnp.bfloat16)
    err = np.abs(np.asarray(out16[:n], np.float32) - np.asarray(ref[:n])).max()
    assert err < 2e-3, err
    print("RING_OK", err)
"""


def test_ring_push_matches_auto_partitioned():
    """The SS4.4/Perf ring variant (shard_map + ppermute, bf16 bitcast) is
    numerically identical (fp32) / eps-close (bf16) to the baseline."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _RING_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RING_OK" in out.stdout
