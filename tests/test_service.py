"""Serving subsystem (serving/{protocol,service,server}): wire validation,
micro-batching parity, admission control, deadlines, tenancy, updates.

The load-bearing test is ``test_microbatch_parity_and_fusion``: N client
threads x 1 query each through the live HTTP server must be bitwise-equal
to a direct session under matched streams (wire ``seed`` pins the lane
PRNG stream; the reference replays each (node, key) through ``submit()``/
``drain()`` at the same ``batch_q``, which PR 3's lane-composition
invariance makes independent of how the collector actually grouped them)
— AND the tenant session must report ``steps < queries`` (the window
really fused cross-connection traffic into lane-batched dispatches).
"""
import threading
import time

import numpy as np
import pytest

import jax

from repro.api.handle import GraphHandle
from repro.api.session import SimRankSession
from repro.api.spec import QuerySpec
from repro.serving import (
    AdmissionError,
    ProtocolError,
    ServiceClient,
    ServiceConfig,
    SimRankService,
    parse_query_request,
    parse_update_request,
    start_server,
    stop_server,
)
from repro.serving.protocol import QueryRequest


@pytest.fixture(scope="module")
def service_graph():
    rng = np.random.default_rng(7)
    n = 48
    src = rng.integers(0, n, 300)
    dst = rng.integers(0, n, 300)
    return GraphHandle.from_edges(src, dst, n), n


def _live_server(handle, **cfg_kw):
    cfg_kw.setdefault("batch_window_ms", 40.0)
    cfg_kw.setdefault("max_batch_q", 8)
    cfg_kw.setdefault("default_budget_walks", 64)
    session_kwargs = cfg_kw.pop("session_kwargs", None)
    backend = cfg_kw.pop("backend", "local")
    svc = SimRankService(
        handle, backend=backend, config=ServiceConfig(**cfg_kw),
        session_kwargs=session_kwargs,
    )
    server, thread = start_server(svc)
    return svc, server, thread


# -- protocol ----------------------------------------------------------------


def test_parse_query_request_validates():
    req = parse_query_request(
        {"node": 3, "kind": "single_source", "budget_walks": 32, "seed": 9}
    )
    assert req == QueryRequest(
        kind="single_source", node=3, budget_walks=32, seed=9
    )
    with pytest.raises(ProtocolError, match="unknown query field"):
        parse_query_request({"node": 1, "budget_walk": 8})  # the typo trap
    with pytest.raises(ProtocolError, match="requires a 'node'"):
        parse_query_request({"kind": "topk"})
    with pytest.raises(ProtocolError, match="kind"):
        parse_query_request({"node": 1, "kind": "pagerank"})
    with pytest.raises(ProtocolError, match="integer"):
        parse_query_request({"node": 1.5})
    with pytest.raises(ProtocolError, match=">= 1"):
        parse_query_request({"node": 1, "k": 0})
    with pytest.raises(ProtocolError, match="confidence requires epsilon"):
        parse_query_request({"node": 1, "confidence": 0.95})
    with pytest.raises(ProtocolError, match="finite"):
        parse_query_request({"node": 1, "epsilon": float("nan")})


def test_parse_update_request_validates():
    ins, dels = parse_update_request({"inserts": [[1, 2], [3, 4]]})
    assert ins.shape == (2, 2) and dels is None
    assert ins.tolist() == [[1, 2], [3, 4]]
    with pytest.raises(ProtocolError, match="no ops"):
        parse_update_request({"inserts": []})
    with pytest.raises(ProtocolError, match="pair"):
        parse_update_request({"inserts": [[1, 2, 3]]})
    with pytest.raises(ProtocolError, match="negative"):
        parse_update_request({"deletes": [[-1, 2]]})
    with pytest.raises(ProtocolError, match="unknown update field"):
        parse_update_request({"insert": [[1, 2]]})


# -- the tentpole: micro-batch window, bitwise parity ------------------------


def test_microbatch_parity_and_fusion(service_graph):
    """N threads x 1 query via HTTP == direct session, and steps < queries."""
    handle, n = service_graph
    svc, server, thread = _live_server(handle, batch_window_ms=60.0)
    host, port = server.server_address
    try:
        Q = 16
        results: list[dict | None] = [None] * Q
        barrier = threading.Barrier(Q)

        def go(i):
            with ServiceClient(host, port) as cl:
                barrier.wait()  # land inside one collector window
                results[i] = cl.query(
                    node=i, kind="topk", k=5, budget_walks=64, seed=500 + i
                )

        threads = [
            threading.Thread(target=go, args=(i,)) for i in range(Q)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert all(r is not None for r in results)

        # direct-session reference: same (node, key) streams, same
        # batch_q geometry; lane-composition invariance means each solo
        # replay is bitwise what the query's lane computed in whatever
        # batch the collector cut
        ref = SimRankSession(handle, batch_q=svc.config.max_batch_q)
        for i, r in enumerate(results):
            tk = ref.submit(QuerySpec(
                kind="topk", node=i, k=5, budget_walks=64,
                key=jax.random.key(500 + i),
            ))
            ref.drain()
            env = tk.envelope
            assert r["topk_nodes"] == np.asarray(env.topk_nodes).tolist()
            # JSON carries exact float64 widenings of the float32 scores:
            # the cast back must be bit-identical
            assert np.array_equal(
                np.asarray(r["topk_scores"], np.float32),
                np.asarray(env.topk_scores),
            )
            assert r["version"] == env.version
            assert r["walks_used"] == env.walks_used

        # the whole point of the window: fused dispatches, not per-query
        sess_stats = svc.stats_snapshot()["tenants"]["default"]
        assert sess_stats["queries"] == Q
        assert sess_stats["steps"] < Q
        assert sum(svc.stats.batch_hist.values()) == svc.stats.batches
        assert max(svc.stats.batch_hist) > 1  # a real multi-query cut
        assert svc.stats.errors_5xx == 0
    finally:
        stop_server(server, thread)


def test_single_source_roundtrip(service_graph):
    handle, n = service_graph
    svc, server, thread = _live_server(handle)
    host, port = server.server_address
    try:
        with ServiceClient(host, port) as cl:
            r = cl.query(
                node=2, kind="single_source", budget_walks=32, seed=11
            )
        assert len(r["scores"]) == n
        ref = SimRankSession(handle, batch_q=svc.config.max_batch_q)
        tk = ref.submit(QuerySpec(
            kind="single_source", node=2, budget_walks=32,
            key=jax.random.key(11),
        ))
        ref.drain()
        assert np.array_equal(
            np.asarray(r["scores"], np.float32), np.asarray(tk.envelope.scores)
        )
    finally:
        stop_server(server, thread)


# -- admission control / deadlines -------------------------------------------


def test_admission_control_429(service_graph):
    handle, _ = service_graph
    svc, server, thread = _live_server(
        handle, max_inflight=2, batch_window_ms=250.0, max_batch_q=64
    )
    host, port = server.server_address
    try:
        req = QueryRequest(node=1, budget_walks=16)
        svc.enqueue(req)
        svc.enqueue(req)
        with pytest.raises(AdmissionError) as ei:
            svc.enqueue(req)
        assert ei.value.retry_after_s > 0
        # and over the wire: 429 + Retry-After + machine-readable hint
        with ServiceClient(host, port) as cl:
            status, payload = cl.query_raw(node=1, budget_walks=16)
        assert status == 429
        assert payload["retry_after_s"] > 0
        assert svc.stats.rejected_429 == 2
    finally:
        stop_server(server, thread)


def test_flat_deadline_sheds_504(service_graph):
    handle, _ = service_graph
    # window far longer than the deadline: the request must expire queued
    svc, server, thread = _live_server(handle, batch_window_ms=300.0)
    host, port = server.server_address
    try:
        with ServiceClient(host, port) as cl:
            status, payload = cl.query_raw(
                node=1, budget_walks=16, deadline_s=0.01
            )
        assert status == 504
        assert "deadline" in payload["error"]
        assert svc.stats.shed_504 == 1
        assert svc.stats.errors_5xx == 0
    finally:
        stop_server(server, thread)


def test_adaptive_deadline_degrades_not_sheds(service_graph):
    """epsilon + deadline -> best-so-far certificate, not a 504."""
    handle, _ = service_graph
    svc, server, thread = _live_server(handle, batch_window_ms=1.0)
    host, port = server.server_address
    try:
        with ServiceClient(host, port) as cl:
            r = cl.query(
                node=3, epsilon=1e-6, confidence=0.99,
                budget_walks=128, deadline_s=30.0,
            )
        # an unreachable epsilon stops on budget or deadline — either way
        # the response is a 200 with an honest certificate
        assert r["certificate"] in ("budget", "deadline")
        assert r["certified_bound"] > 0
        assert r["batch_size"] == 1  # adaptive+deadline dispatches solo
    finally:
        stop_server(server, thread)


# -- tenancy / updates -------------------------------------------------------


def test_tenants_isolated_stats_shared_graph(service_graph):
    handle, _ = service_graph
    svc, server, thread = _live_server(handle)
    host, port = server.server_address
    try:
        with ServiceClient(host, port, tenant="alice") as ca, \
                ServiceClient(host, port, tenant="bob") as cb:
            ra = ca.query(node=1, budget_walks=16)
            rb = cb.query(node=2, budget_walks=16)
            assert ra["tenant"] == "alice" and rb["tenant"] == "bob"
            v0 = ra["version"]
            # an update through EITHER tenant bumps the version BOTH see
            rep = ca.update(inserts=[(5, 6)])
            assert rep["version"] == v0 + 1
            ra2 = ca.query(node=1, budget_walks=16)
            rb2 = cb.query(node=2, budget_walks=16)
            assert ra2["version"] == rb2["version"] == v0 + 1
            stats = ca.stats()
        assert set(stats["tenants"]) >= {"alice", "bob"}
        assert stats["tenants"]["alice"]["queries"] == 2
        assert stats["tenants"]["bob"]["queries"] == 2
        # distinct PRNG namespaces: session keys differ per tenant
        assert svc.session("alice") is not svc.session("bob")
        # ... over ONE shared graph object
        assert svc.session("alice").handle is svc.session("bob").handle
        with pytest.raises(ProtocolError, match="tenant"):
            svc.session("no spaces allowed")
    finally:
        stop_server(server, thread)


def test_update_validation_and_health(service_graph):
    handle, _ = service_graph
    svc, server, thread = _live_server(handle)
    host, port = server.server_address
    try:
        with ServiceClient(host, port) as cl:
            h = cl.healthz()
            assert h["status"] == "ok" and h["n"] == handle.n
            with pytest.raises(RuntimeError, match="400"):
                cl.update(inserts=[])  # no ops
            # node out of range -> 400, not a jax crash
            status, payload = cl.query_raw(node=10**6, budget_walks=16)
            assert status == 400
            assert "out of range" in payload["error"]
    finally:
        stop_server(server, thread)


def test_service_close_rejects_503(service_graph):
    handle, _ = service_graph
    svc, server, thread = _live_server(handle)
    host, port = server.server_address
    stop_server(server, thread)
    from repro.serving import ServiceClosed

    with pytest.raises(ServiceClosed):
        svc.enqueue(QueryRequest(node=1, budget_walks=16))


def test_collector_survives_group_failure(service_graph):
    """A dispatch error 500s its own batch and leaves the service live."""
    handle, _ = service_graph
    svc, server, thread = _live_server(handle)
    host, port = server.server_address
    try:
        # sabotage one tenant's session so its group throws at dispatch
        bad = svc.session("mallory")
        bad.backend = None  # AttributeError inside the collector
        with ServiceClient(host, port, tenant="mallory") as cm:
            status, payload = cm.query_raw(node=1, budget_walks=16)
        assert status == 500
        assert svc.stats.errors_5xx == 1
        # the collector thread is still alive and serving other tenants
        with ServiceClient(host, port) as cl:
            r = cl.query(node=1, budget_walks=16)
        assert r["kind"] == "topk"
    finally:
        stop_server(server, thread)


# -- per-tenant admission quotas / priority lane ------------------------------


def test_tenant_quota_greedy_vs_quiet(service_graph):
    """A greedy tenant 429s at its own share while a quiet tenant's
    queries still admit (the global bound alone would starve everyone)."""
    handle, _ = service_graph
    svc = SimRankService(handle, config=ServiceConfig(
        max_inflight=64, tenant_max_inflight=2,
        batch_window_ms=250.0, max_batch_q=64, default_budget_walks=16,
    ))
    try:
        req = QueryRequest(node=1, budget_walks=16)
        greedy = [svc.enqueue(req, "greedy"), svc.enqueue(req, "greedy")]
        with pytest.raises(AdmissionError) as ei:
            svc.enqueue(req, "greedy")  # over its share, global slots free
        assert ei.value.retry_after_s > 0
        assert svc.stats.rejected_429 == 1
        quiet = svc.enqueue(req, "quiet")  # unaffected by greedy's 429
        for item in greedy + [quiet]:
            assert item.event.wait(timeout=30.0)
            assert item.status == 200
        # quota slots freed with the responses: greedy admits again
        svc.enqueue(req, "greedy").event.wait(timeout=30.0)
        snap = svc.stats_snapshot()["service"]
        assert snap["tenant_max_inflight"] == 2
        assert snap["tenant_inflight"] == {}  # all drained
    finally:
        svc.close()


def test_cut_window_priority_lane(service_graph):
    """When pending overflows one cut, deadline-bearing queries take the
    lane slots (earliest deadline first); deadline-free keep FIFO order
    behind them, and the remainder keeps arrival order."""
    handle, _ = service_graph
    svc = SimRankService(handle, config=ServiceConfig(
        max_batch_q=2, batch_window_ms=0.0,
    ))
    svc.close()  # stop the collector; drive _cut_window by hand
    from repro.serving.service import _PendingQuery

    def pend(name, t_enq, t_deadline):
        it = _PendingQuery(None, None, "t", t_enq, t_deadline)
        it.payload = {"name": name}
        return it

    # arrival order: two deadline-free first, then two with deadlines
    svc._pending.extend([
        pend("free-a", 1.0, None),
        pend("free-b", 2.0, None),
        pend("dl-late", 3.0, 50.0),
        pend("dl-soon", 4.0, 10.0),
    ])
    cut = svc._cut_window()
    assert [it.payload["name"] for it in cut] == ["dl-soon", "dl-late"]
    assert [it.payload["name"] for it in svc._pending] == ["free-a", "free-b"]
    # under one full cut the window stays plain FIFO
    cut = svc._cut_window()
    assert [it.payload["name"] for it in cut] == ["free-a", "free-b"]
    assert not svc._pending


def test_tenant_quota_validation():
    with pytest.raises(ValueError, match="tenant_max_inflight"):
        ServiceConfig(tenant_max_inflight=0)
