"""Paper-faithfulness tests: the Section 3.2 worked example and Table 2.

The toy graph (Figure 1) was reconstructed from the running example; every
PROBE score in the paper's walkthrough must reproduce digit-for-digit, and
the Power Method must match Table 2 within its printed rounding.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    estimate_walk_reference,
    probe_prefix_reference,
    probe_walks_telescoped,
    simrank_power,
    simrank_power_host,
)
from repro.graph.generators import TOY_NODES, TOY_TABLE2

SQRT_C = 0.5  # paper example uses c' = 0.25
IDX = {ch: i for i, ch in enumerate(TOY_NODES)}
WALK = [IDX["a"], IDX["b"], IDX["a"], IDX["b"]]  # W(a) = (a, b, a, b)


def scores_of(vec, tol=1e-9):
    return {
        ch: float(vec[i]) for ch, i in IDX.items() if float(vec[i]) > tol
    }


def test_probe_prefix_2(toy):
    # S_2 = {(c, .167), (d, .5), (e, .25)}
    s = scores_of(probe_prefix_reference(toy["g"], jnp.array(WALK[:2]), SQRT_C))
    assert s == pytest.approx({"c": 1 / 6, "d": 0.5, "e": 0.25}, abs=1e-6)


def test_probe_prefix_3(toy):
    # S_3 = {(f, .021), (g, .028), (h, .028)}
    s = scores_of(probe_prefix_reference(toy["g"], jnp.array(WALK[:3]), SQRT_C))
    assert s == pytest.approx(
        {"f": 1 / 48, "g": 1 / 36, "h": 1 / 36}, abs=1e-6
    )


def test_probe_prefix_4(toy):
    # S_4 = {(b, .011), (c, .033), (e, .038), (f, .019)}; paper rounds to 3dp
    s = scores_of(probe_prefix_reference(toy["g"], jnp.array(WALK[:4]), SQRT_C))
    assert set(s) == {"b", "c", "e", "f"}
    assert s["b"] == pytest.approx(0.011, abs=1.5e-3)
    assert s["c"] == pytest.approx(0.033, abs=1.5e-3)
    assert s["e"] == pytest.approx(0.038, abs=1.5e-3)
    assert s["f"] == pytest.approx(0.019, abs=1.5e-3)


def test_walk_estimate_matches_paper(toy):
    # s~(a,*) for W(a)=(a,b,a,b): b=.011 c=.2 d=.5 e=.2877 f=.04 g=h=.028
    est = estimate_walk_reference(toy["g"], jnp.array(WALK), SQRT_C)
    s = scores_of(est)
    expected = dict(b=0.011, c=0.2, d=0.5, e=0.2877, f=0.04, g=0.028, h=0.028)
    for kk, vv in expected.items():
        assert s[kk] == pytest.approx(vv, abs=2e-3), kk


def test_telescoped_equals_reference_sum(toy):
    walk = jnp.array(WALK)[None, :]
    tele = probe_walks_telescoped(toy["g"], walk, sqrt_c=SQRT_C)[:, 0]
    ref = estimate_walk_reference(toy["g"], jnp.array(WALK), SQRT_C)
    np.testing.assert_allclose(np.asarray(tele), np.asarray(ref), atol=1e-6)


def test_power_method_matches_table2(toy):
    S = np.asarray(simrank_power(toy["g"], c=0.25, iters=60))
    for ch, want in TOY_TABLE2.items():
        assert S[0, IDX[ch]] == pytest.approx(want, abs=1e-3), ch


def test_power_method_host_agrees(toy):
    S_dev = np.asarray(simrank_power(toy["g"], c=0.25, iters=40))
    S_host = simrank_power_host(toy["src"], toy["dst"], toy["n"], c=0.25, iters=40)
    np.testing.assert_allclose(S_dev, S_host, atol=1e-5)


def test_simrank_axioms(small_powerlaw):
    """s(u,u)=1; s symmetric; s in [0,1]."""
    S = np.asarray(simrank_power(small_powerlaw["g"], c=0.6, iters=30))
    np.testing.assert_allclose(np.diag(S), 1.0)
    np.testing.assert_allclose(S, S.T, atol=1e-6)
    assert S.min() >= 0.0 and S.max() <= 1.0 + 1e-6
