"""Model-level tests: decode/forward parity, MLA latent cache, MoE routing,
chunked attention, NequIP equivariance, recsys towers."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig, MoEConfig, RecsysConfig, TransformerConfig
from repro.models.transformer import model as M

TINY_GQA = TransformerConfig(
    name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, param_dtype="float32", compute_dtype="float32",
    remat=False,
)
TINY_MLA = TransformerConfig(
    name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=256, attention="mla", kv_lora_rank=32, q_lora_rank=16,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
TINY_MOE = TransformerConfig(
    name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=256,
    moe=MoEConfig(n_routed=4, top_k=2, d_ff_expert=32, n_shared=1,
                  capacity_factor=8.0),
    param_dtype="float32", compute_dtype="float32", remat=False,
)


def _decode_vs_forward(cfg, key, steps=8):
    params = M.init_lm(key, cfg)
    toks = jax.random.randint(key, (2, steps), 0, cfg.vocab)
    caches = M.init_cache(cfg, 2, steps)
    outs = []
    for t in range(steps):
        caches, lg = M.lm_decode_step(
            params, caches, toks[:, t], jnp.full((2,), t, jnp.int32), cfg
        )
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    ref, _ = M.lm_forward(params, toks, cfg)
    return float(jnp.abs(dec - ref).max())


def test_gqa_decode_matches_forward(key):
    assert _decode_vs_forward(TINY_GQA, key) < 1e-4


def test_mla_decode_matches_forward(key):
    """The absorbed-latent decode path must equal the expanded prefill path."""
    assert _decode_vs_forward(TINY_MLA, key) < 1e-4


def test_moe_decode_matches_forward_with_headroom(key):
    assert _decode_vs_forward(TINY_MOE, key) < 1e-4


def test_chunked_attention_equals_unchunked(key):
    from repro.models.transformer.attention import sdpa

    q = jax.random.normal(key, (2, 256, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 256, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 256, 2, 16))
    full = sdpa(q, k, v, causal_offset=0, chunk_q=256)
    chunked = sdpa(q, k, v, causal_offset=0, chunk_q=64)
    unrolled = sdpa(q, k, v, causal_offset=0, chunk_q=64, unroll_chunks=True)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked), atol=2e-5)
    np.testing.assert_allclose(np.asarray(full), np.asarray(unrolled), atol=2e-5)


def test_scan_equals_unrolled_layers(key):
    import dataclasses

    params = M.init_lm(key, TINY_GQA)
    toks = jax.random.randint(key, (2, 16), 0, 256)
    a, _ = M.lm_forward(params, toks, TINY_GQA)
    cfg2 = dataclasses.replace(TINY_GQA, scan_layers=False)
    b, _ = M.lm_forward(params, toks, cfg2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_moe_all_tokens_routed_with_capacity(key):
    from repro.models.transformer.moe import init_moe, moe_forward

    p = init_moe(key, TINY_MOE, jnp.float32)
    x = jax.random.normal(key, (2, 16, 64))
    out, aux = moe_forward(p, x, TINY_MOE)
    assert out.shape == x.shape
    assert float(aux) > 0.0
    # with capacity_factor=8 no token is dropped: output != shared-only
    p2 = jax.tree_util.tree_map(jnp.zeros_like, p)
    base, _ = moe_forward(p2, x, TINY_MOE)
    assert float(jnp.abs(out - base).max()) > 1e-3


def test_moe_grad_flows_through_router(key):
    from repro.models.transformer.moe import init_moe, moe_forward

    p = init_moe(key, TINY_MOE, jnp.float32)
    x = jax.random.normal(key, (1, 8, 64))

    def loss(p):
        out, aux = moe_forward(p, x, TINY_MOE)
        return (out**2).mean() + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0.0
    assert float(jnp.abs(g["w_gate"]).sum()) > 0.0


def test_nequip_energy_invariant_forces_equivariant(key):
    from repro.models.gnn.model import gnn_forward, init_gnn

    rng = np.random.default_rng(0)
    cfg = GNNConfig(name="nq", conv="nequip", n_layers=2, d_hidden=8, l_max=2,
                    n_rbf=4, cutoff=5.0)
    p = init_gnn(key, cfg, 8)
    N, E = 20, 60
    batch = dict(
        feats=jnp.asarray(rng.normal(size=(N, 8)).astype(np.float32)),
        pos=jnp.asarray(rng.normal(size=(N, 3)).astype(np.float32)),
        src=jnp.asarray(rng.integers(0, N, E).astype(np.int32)),
        dst=jnp.asarray(rng.integers(0, N, E).astype(np.int32)),
        mask=jnp.ones(E, bool),
        graph_ids=None,
    )
    e0 = gnn_forward(p, batch, cfg)
    Q, _ = np.linalg.qr(rng.normal(size=(3, 3)))
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    batch_rot = dict(batch, pos=batch["pos"] @ jnp.asarray(Q.T, jnp.float32))
    e1 = gnn_forward(p, batch_rot, cfg)
    assert abs(float(e0[0] - e1[0])) < 5e-3  # invariant energy
    f = jax.grad(lambda pos: gnn_forward(p, dict(batch, pos=pos), cfg).sum())(
        batch["pos"]
    )
    f_rot = jax.grad(
        lambda pos: gnn_forward(p, dict(batch_rot, pos=pos), cfg).sum()
    )(batch_rot["pos"])
    # forces rotate with the frame: F(Rx) = R F(x)
    np.testing.assert_allclose(
        np.asarray(f_rot), np.asarray(f) @ Q.T, atol=5e-3
    )


def test_recsys_embedding_bag_modes():
    from repro.models.recsys.widedeep import embedding_bag

    table = jnp.arange(20, dtype=jnp.float32).reshape(10, 2)
    ids = jnp.array([1, 2, 3, 4], jnp.int32)
    seg = jnp.array([0, 0, 1, 1], jnp.int32)
    s = embedding_bag(table, ids, seg, 2, mode="sum")
    m = embedding_bag(table, ids, seg, 2, mode="mean")
    np.testing.assert_allclose(np.asarray(s[0]), np.asarray(table[1] + table[2]))
    np.testing.assert_allclose(np.asarray(m[1]),
                               np.asarray((table[3] + table[4]) / 2))


def test_recsys_retrieval_topk_is_dot_ranking(key):
    from repro.models.recsys.widedeep import init_widedeep, retrieval_scores

    cfg = RecsysConfig(name="wd", n_sparse=4, embed_dim=8, mlp=(16, 8),
                       vocab_per_field=50, n_dense=3)
    p = init_widedeep(key, cfg)
    batch = dict(
        sparse_ids=jnp.zeros((1, 4), jnp.int32),
        dense=jnp.zeros((1, 3), jnp.float32),
        cand_ids=jnp.arange(50, dtype=jnp.int32),
    )
    scores = retrieval_scores(p, batch, cfg)
    assert scores.shape == (50,)
    assert bool(jnp.isfinite(scores).all())


def test_gat_bonus_layer_and_softmax(key):
    """Bonus arch: GAT's segment softmax sums to 1 per destination and the
    layer trains."""
    import numpy as np

    from repro.models.gnn.layers import gat_layer, init_gat_layer, segment_softmax
    from repro.models.gnn.model import gnn_loss, init_gnn
    from repro.configs.base import GNNConfig

    rng = np.random.default_rng(0)
    N, E, df = 40, 160, 12
    src = jnp.asarray(rng.integers(0, N, E).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, N, E).astype(np.int32))
    mask = jnp.ones(E, bool)
    scores = jnp.asarray(rng.normal(size=E).astype(np.float32))
    alpha = segment_softmax(scores, dst, N + 1, mask)
    sums = jax.ops.segment_sum(alpha, dst, num_segments=N + 1)[:N]
    live = np.asarray(jax.ops.segment_sum(mask.astype(jnp.float32), dst,
                                          num_segments=N + 1)[:N]) > 0
    np.testing.assert_allclose(np.asarray(sums)[live], 1.0, atol=1e-5)

    cfg = GNNConfig(name="gat", conv="gat", n_layers=2, d_hidden=16, n_classes=4)
    p = init_gnn(key, cfg, df)
    batch = dict(
        feats=jnp.asarray(rng.normal(size=(N, df)).astype(np.float32)),
        src=src, dst=dst, mask=mask,
        labels=jnp.asarray(rng.integers(0, 4, N).astype(np.int32)),
        graph_ids=None,
    )
    loss, _ = gnn_loss(p, batch, cfg)
    g = jax.grad(lambda pp: gnn_loss(pp, batch, cfg)[0])(p)
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(float(loss)) and gn > 0
