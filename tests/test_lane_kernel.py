"""Fused lane-probe level kernel (PR 10): op vs jnp oracle bitwise in fp32
(interpret mode), bf16 storage parity, edge-case shapes, the pipelined
walk-sampling split, end-to-end local serve parity, and the sharded
use_kernel=True mesh paths (subprocess: XLA_FLAGS must precede jax init)."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.lane_probe.ops import lane_probe_level
from repro.kernels.lane_probe.ref import lane_probe_level_ref


def _random_level(rng, *, n=50, k=6, w=24, t=None, dtype=jnp.float32):
    """A random compacted-lane level problem: some finished columns, some
    injections, some sentinel neighbors (the padded ELL slots)."""
    t = (n + 1) if t is None else t
    nbrs = rng.integers(0, n + 1, (n, k)).astype(np.int32)  # n == sentinel
    weights = rng.random(n).astype(np.float32)
    table = rng.random((t, w)).astype(np.float32)
    dep = rng.random((n, w)).astype(np.float32)
    total = rng.random((n, w)).astype(np.float32)
    fin = rng.random(w) < 0.4
    u_p = np.where(rng.random(w) < 0.5,
                   rng.integers(0, n, w), n).astype(np.int32)
    u_prev = np.where(rng.random(w) < 0.5,
                      rng.integers(0, n, w), n).astype(np.int32)
    thr = (rng.random(w) * 0.3).astype(np.float32)
    args = [jnp.asarray(a) for a in (nbrs, weights, table, dep, total)]
    args = [a.astype(dtype) if a.dtype == jnp.float32 and i >= 2 else a
            for i, a in enumerate(args)]
    return (*args, jnp.asarray(fin), jnp.asarray(u_p), jnp.asarray(u_prev),
            jnp.asarray(thr))


def _check_bitwise(args, *, row0=0, tab0=0, n_live, prune):
    out, tot = lane_probe_level(*args, row0=row0, tab0=tab0, n_live=n_live,
                                prune=prune)
    ref_out, ref_tot = lane_probe_level_ref(
        *args, row0=row0, tab0=tab0, n_live=n_live, prune=prune
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_out))
    np.testing.assert_array_equal(np.asarray(tot), np.asarray(ref_tot))
    return np.asarray(out), np.asarray(tot)


# ---------------------------------------------------------------------------
# Kernel vs oracle — bitwise in fp32 interpret mode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("prune", [False, True])
def test_kernel_matches_oracle_bitwise(rng, prune):
    args = _random_level(rng)
    out, _ = _check_bitwise(args, n_live=50, prune=prune)
    assert np.abs(out).sum() > 0  # a non-degenerate level


def test_kernel_sharded_addressing(rng):
    """row0/tab0 offsets (spmd: tab0=row0 full-frontier gather; ring:
    tab0=0 own-block gather) match the oracle bitwise."""
    args = _random_level(rng, n=40, t=120, w=16)
    _check_bitwise(args, row0=40, tab0=40, n_live=120, prune=True)
    _check_bitwise(args, row0=80, tab0=0, n_live=120, prune=False)


def test_kernel_traced_row0(rng):
    """row0 may be a traced value (shard_map calls it per-shard)."""
    args = _random_level(rng, n=32, t=96, w=8)

    @jax.jit
    def run(r0):
        return lane_probe_level(*args, row0=r0, tab0=r0, n_live=96,
                                prune=False)

    out, tot = run(jnp.int32(32))
    ref_out, ref_tot = lane_probe_level_ref(
        *args, row0=32, tab0=32, n_live=96, prune=False
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_out))
    np.testing.assert_array_equal(np.asarray(tot), np.asarray(ref_tot))


# ---------------------------------------------------------------------------
# Edge cases
# ---------------------------------------------------------------------------


def test_all_lanes_dead(rng):
    """Every column finished with no injection: the push is exactly zero
    and the deposit moves every column's scores into the accumulator."""
    n, w = 30, 12
    args = list(_random_level(rng, n=n, w=w))
    args[5] = jnp.ones(w, bool)               # fin: all deposit
    args[6] = jnp.full(w, n, jnp.int32)       # u_p: no injection
    out, tot = _check_bitwise(tuple(args), n_live=n, prune=False)
    assert np.all(out == 0.0)
    np.testing.assert_array_equal(
        tot, np.asarray(args[4]) + np.asarray(args[3])
    )


def test_single_active_column(rng):
    """One live column among finished ones (the tail of a draining batch)."""
    n, w = 30, 9
    args = list(_random_level(rng, n=n, w=w))
    fin = np.ones(w, bool)
    fin[4] = False
    args[5] = jnp.asarray(fin)
    out, _ = _check_bitwise(tuple(args), n_live=n, prune=True)
    assert np.abs(out[:, 4]).sum() > 0
    # finished columns receive only their injections (table lanes zeroed)
    dead = np.delete(np.arange(w), 4)
    inj = np.delete(np.asarray(args[6]), 4) < n
    assert np.all((np.abs(out[:, dead]).sum(axis=0) > 0) == inj)


def test_sentinel_dump_row_contributes_nothing(rng):
    """Neighbor ids >= n_live (the ELL pad sentinel / dump row) are
    value-masked: rows whose slots are ALL sentinels push exactly zero."""
    n = 30
    args = list(_random_level(rng, n=n, w=8))
    nbrs = np.asarray(args[0]).copy()
    nbrs[7, :] = n  # row 7: nothing but sentinels
    args[0] = jnp.asarray(nbrs)
    args[7] = jnp.full(8, n, jnp.int32)  # no exclusion hits
    out, _ = _check_bitwise(tuple(args), n_live=n, prune=False)
    assert np.all(out[7] == 0.0)


@pytest.mark.parametrize("n,w", [(30, 37), (130, 24), (7, 128)])
def test_awkward_shapes(rng, n, w):
    """W not a lane multiple, R above one row tile, tiny R: the wrapper's
    padding must be invisible."""
    args = _random_level(rng, n=n, w=w)
    _check_bitwise(args, n_live=n, prune=True)


def test_bf16_storage_fp32_accumulate(rng):
    """bf16 table/dep/total storage: kernel == oracle bitwise, and the
    deposit accumulates in fp32 (a bf16-storage total still gains deposits
    smaller than its own ulp would allow after many levels)."""
    args = _random_level(rng, dtype=jnp.bfloat16)
    out, tot = _check_bitwise(args, n_live=50, prune=False)
    assert out.dtype == jnp.bfloat16 and tot.dtype == jnp.bfloat16
    f32 = lane_probe_level_ref(
        args[0], args[1], args[2].astype(jnp.float32),
        args[3].astype(jnp.float32), args[4].astype(jnp.float32),
        *args[5:], row0=0, tab0=0, n_live=50, prune=False,
    )[0]
    assert np.abs(out.astype(np.float32) - np.asarray(f32)).max() < 2e-2


# ---------------------------------------------------------------------------
# Pipelined walk sampling: row subsets of one uniform draw are bitwise
# identical to the full-pool walk (what lets tail sampling overlap level 1)
# ---------------------------------------------------------------------------


def test_walks_from_uniform_subsets_bitwise(small_powerlaw, key):
    from repro.core.walks import (
        sample_walks, walk_uniforms, walks_from_uniforms
    )

    eg = small_powerlaw["eg"]
    full = sample_walks(key, eg, 3, n_r=64, max_len=10, sqrt_c=0.77)
    cont, pick = walk_uniforms(key, n_r=64, max_len=10, sqrt_c=0.77)
    head = walks_from_uniforms(eg, 3, cont[:16], pick[:16])
    tail = walks_from_uniforms(eg, 3, cont[16:], pick[16:])
    np.testing.assert_array_equal(
        np.asarray(full), np.vstack([np.asarray(head), np.asarray(tail)])
    )


# ---------------------------------------------------------------------------
# End-to-end local serve: use_kernel=True == XLA ELL lane probe, bitwise
# ---------------------------------------------------------------------------


def test_local_serve_kernel_bitwise(small_powerlaw, key):
    from repro.core import make_params
    from repro.core.multisource import multi_source

    d = small_powerlaw
    params = make_params(d["n"], c=0.6, eps_a=0.2, n_r_override=256)
    us = jnp.array([3, 11, 3], jnp.int32)
    xla = multi_source(key, d["eg"], d["eg"], us, params, lanes=96)
    kern = multi_source(key, d["eg"], d["eg"], us, params, lanes=96,
                        use_kernel=True)
    np.testing.assert_array_equal(np.asarray(xla), np.asarray(kern))


def test_local_serve_kernel_bf16(small_powerlaw, key):
    """bf16 score storage through every level stays within 1e-3 of fp32
    on unit-scale SimRank estimates."""
    from repro.core import make_params
    from repro.core.multisource import multi_source

    d = small_powerlaw
    params = make_params(d["n"], c=0.6, eps_a=0.2, n_r_override=256)
    us = jnp.array([3, 11], jnp.int32)
    f32 = multi_source(key, d["eg"], d["eg"], us, params, lanes=96,
                       use_kernel=True)
    bf16 = multi_source(key, d["eg"], d["eg"], us, params, lanes=96,
                        use_kernel=True, kernel_dtype="bfloat16")
    assert np.abs(np.asarray(f32) - np.asarray(bf16)).max() < 1e-3


def test_local_epoch_kernel_bitwise(small_powerlaw, key):
    """The fused local epoch's probe stage under use_kernel=True matches
    the XLA epoch bitwise (same walks, same lane schedule)."""
    from repro.api import GraphHandle, LocalBackend
    from repro.core import make_params
    from repro.graph.dynamic import make_update_batch

    d = small_powerlaw
    p = make_params(d["n"], c=0.6, eps_a=0.2, delta=0.01)
    rng = np.random.default_rng(7)
    ins = (rng.integers(0, d["n"], 8).astype(np.int32),
           rng.integers(0, d["n"], 8).astype(np.int32))
    batch = make_update_batch(ins[0], ins[1], True, batch_size=8, n=d["n"])
    keys = jax.random.split(key, 2)
    outs = []
    for uk in (False, True):
        h = GraphHandle.from_edges(d["src"], d["dst"], d["n"],
                                   capacity=len(d["src"]) + 64)
        be = LocalBackend(h, params=p, walk_chunk=128, use_kernel=uk)
        applied, est, _, _ = be.epoch_batch(
            batch, [3, 11], keys, n_r=128, top_k=0
        )
        assert applied.sum() == 8
        outs.append(est)
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# Sharded mesh paths (subprocess: 8 fake host devices)
# ---------------------------------------------------------------------------

_MESH_KERNEL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro.api import GraphHandle, QuerySpec, SimRankSession
from repro.api.backend import ShardedBackend
from repro.core import make_params
from repro.graph import powerlaw_graph

src, dst, n = powerlaw_graph(120, 900, seed=5)
in_deg = np.bincount(dst, minlength=n)
h = GraphHandle.from_edges(src, dst, n, capacity=len(src) + 256,
                           k_max=int(in_deg.max()) + 8)
p = make_params(n, c=0.6, eps_a=0.2, delta=0.01)
nodes = [int(u) for u in np.where(in_deg > 0)[0][:3]]
kb = jnp.stack([jax.random.key(200 + i) for i in range(3)])

# spmd: fused kernel vs XLA scatter push (same walks, same lane schedule;
# gather- vs scatter-ordered sums => tolerance, not bitwise)
sh_x = ShardedBackend(h.shard(shards=4), params=p, walk_chunk=512)
sh_k = ShardedBackend(h.shard(shards=4), params=p, walk_chunk=512,
                      use_kernel=True)
a, _, _ = sh_x.serve_batch("single_source", nodes, kb, n_r=512)
b, _, _ = sh_k.serve_batch("single_source", nodes, kb, n_r=512)
assert np.abs(a - b).max() < 1e-4, np.abs(a - b).max()

# bf16 frontier exchange (kernel + XLA paths) vs fp32 wire
for uk in (True, False):
    bf = ShardedBackend(h.shard(shards=4), params=p, walk_chunk=512,
                        use_kernel=uk, frontier_dtype="bfloat16")
    c, _, _ = bf.serve_batch("single_source", nodes, kb, n_r=512)
    ref = b if uk else a
    assert np.abs(ref - c).max() < 1e-3, (uk, np.abs(ref - c).max())
print("SPMD_KERNEL_OK")

# ring: the kernel (identity-gather prep fusing deposit+inject+prune)
# keeps the XLA ring push => BITWISE equality
ring_x = ShardedBackend(h.shard(shards=4), params=p, walk_chunk=512,
                        probe="ring")
ring_k = ShardedBackend(h.shard(shards=4), params=p, walk_chunk=512,
                        probe="ring", use_kernel=True)
e, _, _ = ring_x.serve_batch("single_source", nodes, kb, n_r=512)
f, _, _ = ring_k.serve_batch("single_source", nodes, kb, n_r=512)
assert np.array_equal(e, f), np.abs(e - f).max()
print("RING_KERNEL_OK")

# top-k rides the same probe
_, ix, vx = sh_x.serve_batch("topk", nodes, kb, k=5, n_r=512)
_, ik, vk = sh_k.serve_batch("topk", nodes, kb, k=5, n_r=512)
assert all(len(set(ix[i].tolist()) & set(ik[i].tolist())) >= 4
           for i in range(3))

# fused mesh epoch: kernel probe stage vs the chunk-scan epoch
rng = np.random.default_rng(3)
ins = (rng.integers(0, n, 8).astype(np.int32),
       rng.integers(0, n, 8).astype(np.int32))
ekey = jax.random.key(55)
qs = lambda: [QuerySpec(kind="single_source", node=u,
                        key=jax.random.fold_in(ekey, u))
              for u in nodes[:2]]
s1 = SimRankSession(h, seed=0, top_k=5, batch_q=2, update_batch=16,
                    walk_chunk=256, backend="sharded", shards=4)
s2 = SimRankSession(h, seed=0, top_k=5, batch_q=2, update_batch=16,
                    walk_chunk=256, backend="sharded", shards=4,
                    use_kernel=True)
e1 = s1.epoch(inserts=ins, queries=qs(), budget_walks=256)
e2 = s2.epoch(inserts=ins, queries=qs(), budget_walks=256)
assert e1.updates_applied == e2.updates_applied == 8
g1 = np.stack([r.scores for r in e1.results])
g2 = np.stack([r.scores for r in e2.results])
assert np.abs(g1 - g2).max() < 1e-3, np.abs(g1 - g2).max()
print("EPOCH_KERNEL_OK")
"""


def test_sharded_kernel_parity_on_fake_mesh():
    """use_kernel=True on the mesh: spmd fused kernel vs XLA scatter
    (1e-4), bf16 frontier wire (1e-3), ring kernel bitwise, top-k overlap
    and the fused epoch's kernel probe stage — 8 fake XLA host devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _MESH_KERNEL_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SPMD_KERNEL_OK" in out.stdout
    assert "RING_KERNEL_OK" in out.stdout
    assert "EPOCH_KERNEL_OK" in out.stdout
