"""serving.straggler: deadline + retry-with-shedding dispatch policies.

These are host-side wrappers around arbitrary query callables; the tests
drive them with plain functions (controllable latency) plus one
integration case through the public session stats API (``record_retry``)
that launch/serve.py's ``on_retry`` hook uses.
"""
import time

import numpy as np
import pytest

from repro.serving.straggler import (
    DeadlineError,
    HedgePolicy,
    dispatch,
    dispatch_adaptive,
    run_with_deadline,
)


def test_run_with_deadline_returns_result():
    assert run_with_deadline(lambda x: x + 1, 41, deadline_s=5.0) == 42


def test_run_with_deadline_raises_on_miss():
    with pytest.raises(DeadlineError):
        run_with_deadline(lambda: time.sleep(2.0), deadline_s=0.05)


def test_run_with_deadline_propagates_worker_exception():
    def boom():
        raise RuntimeError("worker died")

    with pytest.raises(RuntimeError, match="worker died"):
        run_with_deadline(boom, deadline_s=5.0)


def test_dispatch_injects_budget_on_first_attempt():
    seen = {}

    def fn(**kwargs):
        seen.update(kwargs)
        return "ok"

    out = dispatch(fn, policy=HedgePolicy(deadline_s=5.0), budget=128)
    assert out == "ok"
    assert seen == {"budget_walks": 128}


def test_dispatch_no_budget_passthrough():
    """budget=None must not inject a budget kwarg at all — full-accuracy
    dispatch stays the callable's default."""
    seen = {"called": 0}

    def fn(**kwargs):
        seen["called"] += 1
        assert "budget_walks" not in kwargs
        return "ok"

    assert dispatch(fn, policy=HedgePolicy(deadline_s=5.0)) == "ok"
    assert seen["called"] == 1


def test_dispatch_sheds_budget_per_retry():
    """Each deadline miss retries with budget * shed_factor (anytime
    degradation), and the on_retry hook sees every re-dispatch."""
    budgets: list[int] = []
    retries: list[int] = []

    def fn(budget_walks=None):
        budgets.append(budget_walks)
        if budget_walks > 100:  # "too slow" until the budget is shed
            time.sleep(1.0)
        return budget_walks

    out = dispatch(
        fn,
        policy=HedgePolicy(deadline_s=0.25, max_retries=3, shed_factor=0.5),
        budget=400,
        on_retry=retries.append,
    )
    assert out == 100  # 400 -> 200 -> 100 served within deadline
    assert budgets == [400, 200, 100]
    assert retries == [1, 2]


def test_dispatch_raises_after_retry_budget_exhausted():
    calls = {"n": 0}

    def fn(budget_walks=None):
        calls["n"] += 1
        time.sleep(1.0)

    with pytest.raises(DeadlineError):
        dispatch(
            fn,
            policy=HedgePolicy(deadline_s=0.1, max_retries=2, shed_factor=0.5),
            budget=64,
        )
    assert calls["n"] == 3  # initial + max_retries re-dispatches


def test_dispatch_budget_floor_is_one():
    """Shedding never drives the injected budget below 1 walk."""
    budgets: list[int] = []

    def fn(budget_walks=None):
        budgets.append(budget_walks)
        if len(budgets) < 3:
            time.sleep(1.0)
        return budget_walks

    out = dispatch(
        fn,
        policy=HedgePolicy(deadline_s=0.2, max_retries=4, shed_factor=0.1),
        budget=2,
    )
    assert out == 1
    assert budgets == [2, 1, 1]  # max(1, int(...)) floor per attempt


def test_dispatch_adaptive_passes_deadline_in_band():
    """The adaptive wrapper hands the POLICY deadline to the callable —
    the escalation loop clamps itself; no retry/shed machinery runs."""
    seen = {}

    def fn(spec, **kwargs):
        seen.update(kwargs, spec=spec)
        return "ok"

    out = dispatch_adaptive(
        fn, "spec", policy=HedgePolicy(deadline_s=2.5)
    )
    assert out == "ok"
    assert seen == {"spec": "spec", "deadline_s": 2.5}


def test_dispatch_adaptive_backstop_bounds_wedged_fn():
    """A callable that ignores its in-band deadline entirely is still
    bounded by the thread backstop at backstop_factor x deadline_s —
    the only way dispatch_adaptive ever raises."""
    with pytest.raises(DeadlineError):
        dispatch_adaptive(
            lambda **kw: time.sleep(5.0),
            policy=HedgePolicy(deadline_s=0.05),
            backstop_factor=2.0,
        )


def test_dispatch_adaptive_validates_backstop_factor():
    with pytest.raises(ValueError, match="backstop_factor"):
        dispatch_adaptive(
            lambda **kw: None,
            policy=HedgePolicy(deadline_s=1.0),
            backstop_factor=0.5,
        )


def test_dispatch_adaptive_deadline_miss_degrades_not_raises(toy):
    """End-to-end through the session: a missed in-band deadline freezes
    the best-so-far answer with certificate='deadline' instead of
    raising — the availability contract the adaptive path promises."""
    from repro.api import GraphHandle, QuerySpec, SimRankSession

    sess = SimRankSession(
        GraphHandle(g=toy["g"], eg=toy["eg"]), eps_a=0.3, top_k=3
    )
    # epsilon below the pruning/truncation floors is never certifiable,
    # so only the deadline can stop escalation before the budget cap;
    # pre-warm round 0's compile so the in-band 0.1ms window measures the
    # dispatch, then give the backstop 100s of headroom — it must NOT fire
    sess.query(QuerySpec(kind="single_source", node=0, epsilon=1e-6),
               deadline_s=0.0)
    env = dispatch_adaptive(
        sess.query,
        QuerySpec(kind="single_source", node=0, epsilon=1e-6),
        policy=HedgePolicy(deadline_s=1e-4),
        backstop_factor=1e6,
    )
    assert env.certificate == "deadline"
    assert env.rounds == 1  # round 0 always runs
    assert np.isfinite(env.certified_bound)


def test_retries_reported_through_session_stats_api(toy):
    """The serve-launcher wiring: on_retry -> session.record_retry, the
    public path into backend-owned EngineStats."""
    from repro.api import GraphHandle, SimRankSession

    sess = SimRankSession(
        GraphHandle(g=toy["g"], eg=toy["eg"]), eps_a=0.3, top_k=3
    )

    def flaky(spec, budget_walks=None):
        if budget_walks > 16:
            time.sleep(1.0)
        return sess.query(spec, budget_walks=budget_walks)

    from repro.api import QuerySpec

    # pre-warm both budget shapes so the deadline measures the injected
    # sleep, not CPU compile time
    sess.query(QuerySpec(kind="topk", node=0, k=3), budget_walks=32)
    sess.query(QuerySpec(kind="topk", node=0, k=3), budget_walks=16)
    res = dispatch(
        flaky, QuerySpec(kind="topk", node=0, k=3),
        policy=HedgePolicy(deadline_s=0.6, max_retries=2, shed_factor=0.5),
        budget=32,
        on_retry=lambda attempt: sess.record_retry(),
    )
    assert sess.stats.retries == 1
    assert res.walks_used == 16
    assert len(np.asarray(res.topk_nodes)) == 3
    with pytest.raises(ValueError):
        sess.record_retry(-1)
