"""Adaptive accuracy controller (core/accuracy.py) + session integration.

The oracle-gated tests compare every adaptively-served query against the
exact Power-Method SimRank (55 iterations — far past float32 resolution
of c^t): the measured max-abs-error must sit inside the CERTIFIED bound
the envelope reports, for every query, on every certificate.  The parity
tests pin the PRNG contract: an escalated run that stops at cumulative N
walks is bitwise identical to a one-shot run capped at N under the same
pinned key (per-round fold_in streams + walk-weighted combine), locally
and on the mesh-sharded backend (subprocess, 8 fake XLA devices).

Property tests ride tests/hypothesis_compat.py — with hypothesis
installed they fuzz; without it they skip, and the seeded fallback
versions of the same properties always run.
"""
import math
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.api import GraphHandle, QuerySpec, SimRankSession
from repro.core import (
    make_params,
    simrank_power,
)
from repro.core.accuracy import (
    AccuracyController,
    ProbeCache,
    empirical_error_bound,
    escalation_schedule,
    normal_quantile,
)
from repro.core.params import (
    abs_error_bound,
    bound_from_sampling_error,
    sampling_error,
    walks_for_error,
)
from tests.hypothesis_compat import given, settings, st

C = 0.6


# ---------------------------------------------------------------------------
# escalation_schedule: the parity backbone
# ---------------------------------------------------------------------------


def test_escalation_schedule_doubles_cumulative_to_cap():
    sched = escalation_schedule(64, 7131)
    assert sched == [64, 64, 128, 256, 512, 1024, 2048, 3035]
    assert sum(sched) == 7131
    cum = np.cumsum(sched)
    # cumulative doubles until the clipped final round
    assert cum[:-1].tolist() == [64, 128, 256, 512, 1024, 2048, 4096]


def test_escalation_schedule_cap_at_or_below_initial_is_one_shot():
    assert escalation_schedule(64, 64) == [64]
    assert escalation_schedule(64, 10) == [10]
    assert escalation_schedule(1, 1) == [1]


def test_escalation_schedule_validates():
    with pytest.raises(ValueError, match="initial"):
        escalation_schedule(0, 100)
    with pytest.raises(ValueError, match="cap"):
        escalation_schedule(8, 0)


def _assert_schedule_prefix_property(initial: int, cap: int) -> None:
    """Stopping an escalation at cumulative N must execute exactly the
    rounds a one-shot run with cap=N would — bitwise parity rests here."""
    sched = escalation_schedule(initial, cap)
    assert all(s >= 1 for s in sched)
    assert sum(sched) == cap
    cum = 0
    for r, size in enumerate(sched):
        cum += size
        assert escalation_schedule(initial, cum) == sched[: r + 1]


def test_escalation_schedule_prefix_property_seeded():
    rng = np.random.default_rng(0)
    for _ in range(50):
        initial = int(rng.integers(1, 512))
        cap = int(rng.integers(1, 100_000))
        _assert_schedule_prefix_property(initial, cap)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 512), st.integers(1, 100_000))
def test_escalation_schedule_prefix_property(initial, cap):
    _assert_schedule_prefix_property(initial, cap)


# ---------------------------------------------------------------------------
# normal_quantile / walks_for_error: dependency-free math
# ---------------------------------------------------------------------------


def test_normal_quantile_known_values():
    assert abs(normal_quantile(0.975) - 1.959964) < 1e-5
    assert abs(normal_quantile(0.995) - 2.575829) < 1e-5
    assert abs(normal_quantile(0.5)) < 1e-9
    # symmetry + round trip through the CDF
    for p in (0.01, 0.3, 0.7, 0.9999, 1 - 1e-9):
        z = normal_quantile(p)
        assert abs(normal_quantile(1.0 - p) + z) < 1e-9
        assert abs(0.5 * (1.0 + math.erf(z / math.sqrt(2.0))) - p) < 1e-10
    for bad in (0.0, 1.0, -1.0, 2.0):
        with pytest.raises(ValueError):
            normal_quantile(bad)


def test_walks_for_error_inverts_abs_error_bound():
    p = make_params(1000, c=C, eps_a=0.1, delta=0.01)
    for eps in (0.3, 0.2, 0.12, 0.1, 0.08):
        n_w = walks_for_error(p, n=1000, epsilon=eps)
        assert n_w is not None
        assert abs_error_bound(p, n=1000, n_r=n_w) <= eps + 1e-9
        if n_w > 1:  # minimality: one walk fewer misses the target
            assert abs_error_bound(p, n=1000, n_r=n_w - 1) > eps
    # the pruning + truncation floors are walk-count independent
    floor = p.eps_p / (1.0 - p.sqrt_c) + p.eps_t / 2.0
    assert walks_for_error(p, n=1000, epsilon=floor * 0.99) is None
    assert walks_for_error(p, n=1000, epsilon=0.0) is None
    assert walks_for_error(p, n=1000, epsilon=-0.1) is None


def _assert_bound_monotone_to_floor(params, n: int) -> None:
    floor = params.eps_p / (1.0 - params.sqrt_c) + params.eps_t / 2.0
    bounds = [
        abs_error_bound(params, n=n, n_r=2**i) for i in range(4, 24)
    ]
    assert all(b2 < b1 for b1, b2 in zip(bounds, bounds[1:]))
    assert all(b > floor for b in bounds)  # never below the floors
    assert bounds[-1] - floor < 0.01  # ...but tending to them


def test_bound_monotone_nonincreasing_to_floor_seeded():
    rng = np.random.default_rng(1)
    for _ in range(20):
        n = int(rng.integers(50, 5000))
        c = float(rng.uniform(0.3, 0.8))
        eps_a = float(rng.uniform(0.05, 0.3))
        _assert_bound_monotone_to_floor(
            make_params(n, c=c, eps_a=eps_a, delta=0.01), n
        )


@settings(max_examples=25, deadline=None)
@given(st.integers(50, 5000), st.floats(0.3, 0.8), st.floats(0.05, 0.3))
def test_bound_monotone_nonincreasing_to_floor(n, c, eps_a):
    _assert_bound_monotone_to_floor(
        make_params(n, c=c, eps_a=eps_a, delta=0.01), n
    )


# ---------------------------------------------------------------------------
# empirical_error_bound: the CLT certificate
# ---------------------------------------------------------------------------


def _assert_empirical_inside_analytic(seed: int) -> None:
    """With the variance clamped at the [0,1]-range worst case 1/4, the
    empirical sampling term is z/2/sqrt(N) vs the analytic
    sqrt(3c ln(n/delta))/sqrt(N) — strictly inside for realistic
    confidences, so escalation can only stop EARLIER than flat serving."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(50, 5000))
    c = float(rng.uniform(0.3, 0.8))
    conf = float(rng.uniform(0.9, 0.995))
    p = make_params(n, c=c, eps_a=float(rng.uniform(0.05, 0.3)), delta=0.01)
    r = int(rng.integers(2, 7))
    sizes = rng.integers(8, 512, size=r)
    scores = rng.uniform(0.0, 1.0, size=(r, n))  # worst-case scatter
    emp = empirical_error_bound(
        p, n=n, round_sizes=sizes, round_scores=scores, confidence=conf
    )
    ana = abs_error_bound(p, n=n, n_r=int(sizes.sum()))
    assert emp <= ana + 1e-12
    # both stack the same floors on the sampling term
    floor = bound_from_sampling_error(p, 0.0)
    assert emp > floor


def test_empirical_bound_inside_analytic_seeded():
    for seed in range(20):
        _assert_empirical_inside_analytic(seed)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**6))
def test_empirical_bound_inside_analytic(seed):
    _assert_empirical_inside_analytic(seed)


def test_empirical_bound_zero_variance_hits_floor():
    p = make_params(200, c=C, eps_a=0.1, delta=0.01)
    const = np.full((3, 200), 0.01)
    emp = empirical_error_bound(
        p, n=200, round_sizes=[64, 64, 128], round_scores=const,
        confidence=0.99,
    )
    assert abs(emp - bound_from_sampling_error(p, 0.0)) < 1e-12


def test_empirical_bound_validates():
    p = make_params(100, c=C, eps_a=0.1, delta=0.01)
    ok = np.zeros((2, 100))
    with pytest.raises(ValueError, match="2 rounds"):
        empirical_error_bound(p, n=100, round_sizes=[64],
                              round_scores=ok[:1], confidence=0.99)
    with pytest.raises(ValueError, match="round size"):
        empirical_error_bound(p, n=100, round_sizes=[64, 64, 64],
                              round_scores=ok, confidence=0.99)
    with pytest.raises(ValueError, match="confidence"):
        empirical_error_bound(p, n=100, round_sizes=[64, 64],
                              round_scores=ok, confidence=1.0)


def test_sampling_error_validates():
    p = make_params(100, c=C, eps_a=0.1, delta=0.01)
    with pytest.raises(ValueError, match="n_r"):
        sampling_error(p, n=100, n_r=0)


# ---------------------------------------------------------------------------
# AccuracyController: freeze semantics
# ---------------------------------------------------------------------------


def test_controller_freezes_queries_independently():
    """A query's answer is fixed the round ITS certificate fires — batch
    mates escalating further must not change it (batch invariance)."""
    p = make_params(100, c=C, eps_a=0.1, delta=0.01)
    ctrl = AccuracyController(
        p, n=100, q=2, epsilon=0.06, confidence=0.99, plan=[64, 64, 128]
    )
    flat = np.full(100, 0.01, np.float32)  # zero between-round variance
    noisy0 = np.zeros(100, np.float32)  # clamp-level variance
    noisy1 = np.ones(100, np.float32)
    ctrl.absorb(64, np.stack([flat, noisy0]))
    assert ctrl.certificates == [None, None]  # 1 round: no empirical yet
    ctrl.absorb(64, np.stack([flat, noisy1]))
    cert0 = ctrl.certificates[0]
    assert cert0 is not None and cert0.name == "empirical"
    assert cert0.walks == 128 and cert0.rounds == 2
    assert ctrl.certificates[1] is None
    assert not ctrl.all_frozen
    # a later round must not move the frozen answer
    ctrl.absorb(128, np.stack([flat * 3, noisy0]))
    scores0, again = ctrl.result(0)
    assert again is cert0
    np.testing.assert_array_equal(scores0, flat)
    with pytest.raises(RuntimeError, match="not frozen"):
        ctrl.result(1)
    assert ctrl.next_round() is None  # plan exhausted
    ctrl.finish("budget")
    scores1, cert1 = ctrl.result(1)
    assert cert1.name == "budget" and cert1.walks == 256 and cert1.rounds == 3
    # walk-weighted mean over all three rounds of query 1
    want = (64 * 0.0 + 64 * 1.0 + 128 * 0.0) / 256
    np.testing.assert_allclose(scores1, np.full(100, want, np.float32),
                               rtol=1e-6)


def test_controller_validates():
    p = make_params(100, c=C, eps_a=0.1, delta=0.01)
    with pytest.raises(ValueError, match="epsilon"):
        AccuracyController(p, n=100, q=1, epsilon=-0.1, confidence=0.99,
                           plan=[64])
    with pytest.raises(ValueError, match="plan"):
        AccuracyController(p, n=100, q=1, epsilon=0.1, confidence=0.99,
                           plan=[])
    ctrl = AccuracyController(p, n=100, q=2, epsilon=0.1, confidence=0.99,
                              plan=[64])
    with pytest.raises(RuntimeError, match="finish"):
        ctrl.finish()
    with pytest.raises(ValueError, match="shape"):
        ctrl.absorb(64, np.zeros((3, 100)))


# ---------------------------------------------------------------------------
# ProbeCache
# ---------------------------------------------------------------------------


def test_probe_cache_eviction_is_insertion_ordered():
    cache = ProbeCache(max_entries=2)
    k = lambda node: (node, 0, 0, 64, 1, 128)  # noqa: E731
    cache.put(k(1), np.ones(4))
    cache.put(k(2), np.ones(4) * 2)
    cache.put(k(3), np.ones(4) * 3)  # evicts node 1
    assert len(cache) == 2
    assert cache.get(k(1)) is None
    np.testing.assert_array_equal(cache.get(k(3)), np.ones(4) * 3)
    assert cache.hits == 1 and cache.misses == 1
    # re-putting a resident key is not an eviction
    cache.put(k(3), np.ones(4) * 3)
    assert cache.get(k(2)) is not None


def test_probe_cache_version_bump_clears():
    cache = ProbeCache(max_entries=8)
    cache.put((7, 0, 0, 64, 1, 128), np.ones(4))
    assert len(cache) == 1
    assert cache.get((7, 1, 0, 64, 1, 128)) is None  # new graph version
    assert len(cache) == 0  # every held row was stale
    with pytest.raises(ValueError, match="max_entries"):
        ProbeCache(max_entries=0)


# ---------------------------------------------------------------------------
# Oracle gate: adaptive serving vs exact Power-Method SimRank
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def oracle():
    from repro.graph import powerlaw_graph

    src, dst, n = powerlaw_graph(120, 900, seed=1)
    in_deg = np.bincount(dst, minlength=n)
    h = GraphHandle.from_edges(
        src, dst, n, capacity=len(src) + 64, k_max=int(in_deg.max()) + 8
    )
    truth = np.asarray(simrank_power(h.g, c=C, iters=55))
    rng = np.random.default_rng(0)
    nodes = rng.choice(np.where(in_deg > 0)[0], size=6, replace=False)
    return dict(h=h, truth=truth, nodes=nodes, in_deg=in_deg)


def _adaptive_session(h, eps_a, **kw):
    kw.setdefault("own_graph", False)
    return SimRankSession(h, c=C, eps_a=eps_a, delta=0.01, walk_chunk=128,
                          **kw)


def test_oracle_error_within_certified_bound_every_query(oracle):
    """THE acceptance gate: for every adaptively-served query the measured
    max abs error vs the exact oracle is <= the certified bound the
    envelope reports — and the controller spent fewer walks than flat."""
    h, truth = oracle["h"], oracle["truth"]
    for eps in (0.1, 0.05):
        sess = _adaptive_session(h, eps, seed=11)
        for u in oracle["nodes"]:
            env = sess.query(
                QuerySpec(kind="single_source", node=int(u), epsilon=eps)
            )
            e = np.abs(env.scores - truth[u])
            e[u] = 0.0
            assert float(e.max()) <= env.certified_bound, (
                eps, int(u), float(e.max()), env.certified_bound,
            )
            assert env.certificate in ("analytic", "empirical", "budget")
            if env.certificate != "budget":
                assert env.certified_bound <= eps
            assert env.walks_used <= sess.params.n_r
            assert env.epsilon == eps
            assert env.rounds >= 1


def test_oracle_precision_at_10(oracle):
    """Escalation certifies absolute error, which at eps=0.1 already pins
    the top of the ranking: precision@10 vs the oracle must stay >= 0.9."""
    h, truth = oracle["h"], oracle["truth"]
    sess = _adaptive_session(h, 0.1, seed=3)
    precs = []
    for u in oracle["nodes"]:
        env = sess.query(QuerySpec(kind="topk", node=int(u), k=10,
                                   epsilon=0.1))
        t = truth[u].copy()
        t[u] = -np.inf
        kk = min(10, int((t > 0).sum()))
        truth_top = set(np.argsort(-t, kind="stable")[:kk].tolist())
        est_top = set(env.topk_nodes[:kk].tolist())
        precs.append(len(est_top & truth_top) / kk if kk else 1.0)
    assert float(np.mean(precs)) >= 0.9, precs


def test_adaptive_saves_walks_vs_flat(oracle):
    """The headline: the empirical certificate fires far before the flat
    Thm-1 budget on real score distributions (and never after it)."""
    sess = _adaptive_session(oracle["h"], 0.1, seed=5)
    env = sess.query(QuerySpec(
        kind="single_source", node=int(oracle["nodes"][0]), epsilon=0.1,
    ))
    assert env.certificate == "empirical"
    assert env.walks_used * 4 <= sess.params.n_r  # >= 4x saved
    assert sess.stats.escalations >= 0


# ---------------------------------------------------------------------------
# Escalated == one-shot bitwise parity (the PRNG contract)
# ---------------------------------------------------------------------------


def test_escalated_equals_one_shot_bitwise_local(oracle):
    """An escalated run stopping at cumulative N is bitwise identical to a
    one-shot run whose cap is N under the same pinned key: epsilon=0.0
    never certifies, so the reference run executes the full schedule to
    its budget cap — the same rounds, the same fold_in keys."""
    h = oracle["h"]
    u = int(oracle["nodes"][1])
    key = jax.random.key(7)
    sess = _adaptive_session(h, 0.1, seed=0)
    env = sess.query(QuerySpec(kind="single_source", node=u, epsilon=0.1,
                               key=key))
    assert env.certificate in ("analytic", "empirical")
    ref = sess.query(QuerySpec(kind="single_source", node=u, epsilon=0.0,
                               budget_walks=env.walks_used, key=key))
    assert ref.certificate == "budget"
    assert ref.walks_used == env.walks_used
    assert ref.rounds == env.rounds
    assert np.array_equal(env.scores, ref.scores)  # bitwise, not close


def test_adaptive_topk_is_host_epilogue_over_scores(oracle):
    """topk under escalation = stable argsort of the combined scores with
    the query node masked (ties toward the lower index, like lax.top_k)."""
    h = oracle["h"]
    u = int(oracle["nodes"][2])
    key = jax.random.key(9)
    sess = _adaptive_session(h, 0.1, seed=0)
    ss = sess.query(QuerySpec(kind="single_source", node=u, epsilon=0.1,
                              key=key))
    tk = sess.query(QuerySpec(kind="topk", node=u, k=10, epsilon=0.1,
                              key=key))
    assert tk.walks_used == ss.walks_used
    masked = ss.scores.copy()
    masked[u] = -np.inf
    order = np.argsort(-masked, kind="stable")[:10]
    np.testing.assert_array_equal(tk.topk_nodes, order.astype(np.int32))
    np.testing.assert_array_equal(tk.topk_scores, masked[order])
    assert u not in tk.topk_nodes.tolist()
    assert tk.topk_nodes.dtype == np.int32


def test_adaptive_drain_batch_per_query_certificates(oracle):
    """Queued adaptive specs group into one escalating lane batch; every
    envelope carries its OWN certificate, and the batch answer for a
    pinned-key query is reproducible across drains."""
    h = oracle["h"]
    nodes = [int(u) for u in oracle["nodes"][:3]]
    keys = [jax.random.key(100 + u) for u in nodes]

    def drain_once():
        sess = _adaptive_session(h, 0.1, seed=0, batch_q=4)
        for u, k in zip(nodes, keys):
            sess.submit(QuerySpec(kind="single_source", node=u,
                                  epsilon=0.1, key=k))
        return sess, sess.drain()

    sess, envs = drain_once()
    assert len(envs) == 3
    for env, u in zip(envs, nodes):
        assert env.node == u
        assert env.certificate in ("analytic", "empirical", "budget")
        assert env.walks_used <= sess.params.n_r
        assert env.scores.shape == (h.n,)
    _, envs2 = drain_once()
    for a, b in zip(envs, envs2):
        assert np.array_equal(a.scores, b.scores)
        assert a.certificate == b.certificate and a.walks_used == b.walks_used


def test_adaptive_batched_nodes_spec_aggregates_worst(oracle):
    h = oracle["h"]
    nodes = [int(u) for u in oracle["nodes"][:2]]
    sess = _adaptive_session(h, 0.1, seed=2)
    env = sess.query(QuerySpec(kind="single_source", nodes=nodes,
                               epsilon=0.1))
    assert env.scores.shape == (2, h.n)
    assert env.certificate in ("analytic", "empirical", "budget")
    assert env.certified_bound > 0.0
    assert env.walks_used <= sess.params.n_r


# ---------------------------------------------------------------------------
# Hub probe cache
# ---------------------------------------------------------------------------


def test_hub_cache_skips_dispatches_bitwise(oracle):
    """Repeat queries against a hub node (no pinned key) ride node-keyed
    streams: their per-round rows come from the cache, whole dispatches
    are skipped, and the answers stay bitwise identical."""
    h, in_deg = oracle["h"], oracle["in_deg"]
    hub = int(np.argmax(in_deg))
    sess = _adaptive_session(h, 0.1, seed=0, hub_percentile=50.0)
    assert hub in sess.backend.hub_nodes(50.0)
    a = sess.query(QuerySpec(kind="single_source", node=hub, epsilon=0.1))
    steps_after_first = sess.stats.steps
    assert sess.stats.hub_hits == 0  # cold cache: every round dispatched
    b = sess.query(QuerySpec(kind="single_source", node=hub, epsilon=0.1))
    assert np.array_equal(a.scores, b.scores)
    assert a.certificate == b.certificate and a.walks_used == b.walks_used
    assert sess.stats.hub_hits == a.rounds  # every round skipped its step
    assert sess.stats.steps == steps_after_first
    # a pinned key bypasses the node-keyed stream AND the cache
    c_ = sess.query(QuerySpec(kind="single_source", node=hub, epsilon=0.1,
                              key=jax.random.key(1)))
    assert sess.stats.hub_hits == a.rounds
    assert not np.array_equal(a.scores, c_.scores)


def test_hub_cache_invalidated_by_graph_version(oracle):
    h, in_deg = oracle["h"], oracle["in_deg"]
    hub = int(np.argmax(in_deg))
    sess = _adaptive_session(h, 0.1, seed=0, hub_percentile=50.0,
                             own_graph=True)
    a = sess.query(QuerySpec(kind="single_source", node=hub, epsilon=0.1))
    sess.update(inserts=(np.array([0, 1], np.int32),
                         np.array([2, hub], np.int32)))
    b = sess.query(QuerySpec(kind="single_source", node=hub, epsilon=0.1))
    assert b.version == a.version + 1
    assert sess.stats.hub_hits == 0  # version bump cleared every row
    assert not np.array_equal(a.scores, b.scores)  # post-update snapshot


def test_hub_nodes_percentile_selection(oracle):
    h, in_deg = oracle["h"], oracle["in_deg"]
    sess = _adaptive_session(h, 0.1, seed=0)
    hubs = sess.backend.hub_nodes(90.0)
    assert hubs  # a power-law graph always has hubs
    thresh = min(in_deg[u] for u in hubs)
    assert all(in_deg[u] >= thresh for u in hubs)
    assert int(np.argmax(in_deg)) in hubs
    assert sess.backend.hub_nodes(90.0) is hubs  # cached per version
    with pytest.raises(ValueError, match="percentile"):
        sess.backend.hub_nodes(101.0)


# ---------------------------------------------------------------------------
# Deadline + epoch interaction
# ---------------------------------------------------------------------------


def test_deadline_miss_degrades_to_best_so_far(oracle):
    """A missed in-band deadline freezes the round-0 answer with
    certificate='deadline' — never an exception on the query path."""
    sess = _adaptive_session(oracle["h"], 0.1, seed=0)
    env = sess.query(
        QuerySpec(kind="single_source", node=int(oracle["nodes"][0]),
                  epsilon=1e-6),  # unreachable: floors exceed it
        deadline_s=0.0,
    )
    assert env.certificate == "deadline"
    assert env.rounds == 1  # round 0 always runs
    assert env.walks_used == sess.initial_budget
    assert np.isfinite(env.certified_bound)
    assert env.certified_bound > 1e-6  # honest: the miss is reported


def test_deadline_requires_epsilon(oracle):
    sess = _adaptive_session(oracle["h"], 0.1, seed=0)
    with pytest.raises(ValueError, match="epsilon"):
        sess.query(QuerySpec(kind="single_source", node=1), deadline_s=1.0)


def test_epoch_refuses_adaptive_specs(oracle):
    sess = _adaptive_session(oracle["h"], 0.1, seed=0, own_graph=True)
    sess.submit(QuerySpec(kind="single_source", node=1, epsilon=0.1))
    with pytest.raises(ValueError, match="epoch"):
        sess.epoch(inserts=(np.array([0], np.int32),
                            np.array([1], np.int32)))


def test_spec_validates_epsilon_confidence():
    with pytest.raises(ValueError, match="epsilon"):
        QuerySpec(kind="single_source", node=1, epsilon=-0.1)
    with pytest.raises(ValueError, match="confidence"):
        QuerySpec(kind="single_source", node=1, epsilon=0.1, confidence=1.0)
    with pytest.raises(ValueError, match="confidence"):
        QuerySpec(kind="single_source", node=1, confidence=0.99)
    # epsilon=0.0 is valid: never certifiable, runs the full schedule
    # (how the parity tests pin a one-shot reference run)
    QuerySpec(kind="single_source", node=1, epsilon=0.0)


# ---------------------------------------------------------------------------
# Sharded backend: adaptive parity on 8 fake devices (subprocess)
# ---------------------------------------------------------------------------

_SHARDED_ADAPTIVE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.api import GraphHandle, QuerySpec, SimRankSession
from repro.graph import powerlaw_graph

src, dst, n = powerlaw_graph(120, 900, seed=5)
in_deg = np.bincount(dst, minlength=n)
h = GraphHandle.from_edges(src, dst, n, capacity=len(src) + 256,
                           k_max=int(in_deg.max()) + 8)
sess = SimRankSession(h, c=0.6, eps_a=0.1, delta=0.01, seed=0, top_k=5,
                      walk_chunk=256, backend="sharded", shards=4)
assert len(jax.devices()) == 8
u = int(np.argmax(in_deg))
key = jax.random.key(31)
env = sess.query(QuerySpec(kind="single_source", node=u, epsilon=0.1,
                           key=key))
assert env.certificate in ("analytic", "empirical"), env.certificate
assert env.walks_used < sess.params.n_r, (env.walks_used, sess.params.n_r)
assert env.variant.startswith("sharded"), env.variant
ref = sess.query(QuerySpec(kind="single_source", node=u, epsilon=0.0,
                           budget_walks=env.walks_used, key=key))
assert ref.certificate == "budget"
assert ref.rounds == env.rounds
assert np.array_equal(env.scores, ref.scores), (
    np.abs(env.scores - ref.scores).max())
# hub cache on the mesh: repeat hub query skips whole sharded dispatches
a = sess.query(QuerySpec(kind="single_source", node=u, epsilon=0.1))
b = sess.query(QuerySpec(kind="single_source", node=u, epsilon=0.1))
assert np.array_equal(a.scores, b.scores)
assert sess.stats.hub_hits == a.rounds, (sess.stats.hub_hits, a.rounds)
print("ADAPTIVE_SHARDED_PARITY_OK")
"""


def test_adaptive_sharded_parity_on_fake_mesh():
    """Escalated == one-shot bitwise parity and the hub probe cache on
    the mesh-sharded backend, 8 fake XLA host devices (subprocess: the
    device-count flag must precede jax init)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_ADAPTIVE_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ADAPTIVE_SHARDED_PARITY_OK" in out.stdout


# ---------------------------------------------------------------------------
# UpdateBatch apply == rebuild under generated insert/delete mixes
# (promotes test_dynamic.py's hand-picked cases to a property)
# ---------------------------------------------------------------------------


def _assert_apply_equals_rebuild(seed: int) -> None:
    from repro.graph import (
        apply_update_batch_jit,
        ell_from_edges,
        erdos_renyi_graph,
        graph_from_edges,
        graph_to_host_edges,
        make_update_batch,
    )

    rng = np.random.default_rng(seed)
    src, dst, n = erdos_renyi_graph(60, 300, seed=5)
    g = graph_from_edges(src, dst, n, capacity=len(src) + 64)
    eg = ell_from_edges(
        src, dst, n, k_max=int(np.bincount(dst, minlength=n).max()) + 8
    )
    for _ in range(3):
        cs, cd = graph_to_host_edges(g)
        n_ins = int(rng.integers(0, 9))
        n_del = int(rng.integers(0, 9))
        live = rng.permutation(len(cs))[:n_del]  # deletes of LIVE edges...
        ds = np.concatenate([cs[live],  # ...plus possibly-absent ones
                             rng.integers(0, n, 2).astype(np.int32)])
        dd = np.concatenate([cd[live],
                             rng.integers(0, n, 2).astype(np.int32)])
        s = np.concatenate([rng.integers(0, n, n_ins).astype(np.int32), ds])
        d = np.concatenate([rng.integers(0, n, n_ins).astype(np.int32), dd])
        ins = np.concatenate([np.ones(n_ins, bool),
                              np.zeros(len(ds), bool)])
        batch = make_update_batch(s, d, ins, batch_size=32, n=n)
        g, eg, _ = apply_update_batch_jit(g, eg, batch)
        # incremental mirrors == from-scratch rebuild of the live edges
        s2, d2 = graph_to_host_edges(g)
        g_rb = graph_from_edges(s2, d2, n, capacity=g.capacity)
        eg_rb = ell_from_edges(s2, d2, n, k_max=eg.k_max)
        np.testing.assert_array_equal(np.asarray(g.src), np.asarray(g_rb.src))
        np.testing.assert_array_equal(np.asarray(g.dst), np.asarray(g_rb.dst))
        np.testing.assert_array_equal(np.asarray(g.in_deg),
                                      np.asarray(g_rb.in_deg))
        np.testing.assert_array_equal(np.asarray(eg.in_nbrs),
                                      np.asarray(eg_rb.in_nbrs))
        np.testing.assert_array_equal(np.asarray(eg.in_deg),
                                      np.asarray(eg_rb.in_deg))


def test_update_batch_apply_equals_rebuild_seeded():
    for seed in range(4):
        _assert_apply_equals_rebuild(seed)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10**6))
def test_update_batch_apply_equals_rebuild(seed):
    _assert_apply_equals_rebuild(seed)
