"""Baselines + pooling protocol: MC expert precision, TSF bias on cyclic
graphs, pooling evaluation mechanics, metrics sanity."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    build_oneway_index,
    build_pool,
    evaluate_with_pool,
    mc_pool_scores,
    mc_single_pair,
    simrank_power,
    tsf_single_source,
)
from repro.core.metrics import kendall_tau, ndcg_at_k, precision_at_k
from repro.graph import ell_from_edges, graph_from_edges, toy_graph


def test_mc_single_pair_converges(toy, key):
    truth = np.asarray(simrank_power(toy["g"], c=0.25, iters=60))
    est = float(mc_single_pair(key, toy["eg"], 0, 3, r=20_000, max_len=16,
                               sqrt_c=0.5))
    assert est == pytest.approx(truth[0, 3], abs=0.01)


def test_mc_pool_scores_match_truth(toy, key):
    truth = np.asarray(simrank_power(toy["g"], c=0.25, iters=60))
    pool = jnp.arange(1, 8, dtype=jnp.int32)
    scores = np.asarray(
        mc_pool_scores(key, toy["eg"], jnp.int32(0), pool, r=8000, max_len=16,
                       sqrt_c=0.5)
    )
    np.testing.assert_allclose(scores, truth[0, 1:8], atol=0.02)


def test_tsf_overestimates_on_cyclic_graph(key):
    """TSF sums meet probabilities over steps (not FIRST meets) — on a graph
    where reverse walks coincide forever after the first meeting this
    overestimates unboundedly (the paper's §2.3 critique).

    Graph: h -> a, h -> b, h <-> x.  Reverse walks from a and b both go
    a/b -> h -> x -> h -> ... deterministically: true s(a,b) = c (first
    meet), but TSF counts a meet at EVERY step: sum_i c^i >> c."""
    src = np.array([2, 2, 3, 2], dtype=np.int32)
    dst = np.array([0, 1, 2, 3], dtype=np.int32)
    g = graph_from_edges(src, dst, 4)
    eg = ell_from_edges(src, dst, 4)
    truth = np.asarray(simrank_power(g, c=0.8, iters=80))
    assert truth[0, 1] == pytest.approx(0.8, abs=1e-6)
    idx = build_oneway_index(jax.random.key(1), eg, r_g=50)
    est = np.asarray(
        tsf_single_source(jax.random.key(2), idx, eg, jnp.int32(0),
                          r_q=5, t=12, c=0.8)
    )
    assert est[1] > truth[0, 1] + 0.5, (est[1], truth[0, 1])


def test_pooling_protocol_end_to_end(toy, key):
    truth = np.asarray(simrank_power(toy["g"], c=0.25, iters=60))[0]
    good = np.argsort(-np.where(np.arange(8) == 0, -1.0, truth))[:3]
    bad = np.array([7, 6, 5], dtype=np.int32)
    out = evaluate_with_pool(
        key, toy["eg"], 0, {"good": good.astype(np.int32), "bad": bad}, 3,
        expert_r=4000, sqrt_c=0.5, max_len=12,
    )
    assert out["good"]["precision"] >= out["bad"]["precision"]
    assert out["good"]["ndcg"] >= out["bad"]["ndcg"]
    pool = build_pool({"a": good.astype(np.int32), "b": bad})
    assert set(pool) == set(good) | set(bad)


def test_metrics_definitions():
    truth = np.array([0.0, 0.5, 0.4, 0.3, 0.2, 0.1])
    true_top = np.array([1, 2, 3])
    assert precision_at_k(np.array([1, 2, 3]), true_top) == 1.0
    assert precision_at_k(np.array([1, 2, 5]), true_top) == pytest.approx(2 / 3)
    assert ndcg_at_k(np.array([1, 2, 3]), truth, true_top) == pytest.approx(1.0)
    assert ndcg_at_k(np.array([3, 2, 1]), truth, true_top) < 1.0
    assert kendall_tau(np.array([1, 2, 3]), truth) == 1.0
    assert kendall_tau(np.array([3, 2, 1]), truth) == -1.0


def test_anytime_accuracy_improves_with_budget(toy, key):
    """Serving's work-shedding contract: more walks -> lower error (Thm 1)."""
    from repro.core import make_params, single_source

    truth = np.asarray(simrank_power(toy["g"], c=0.25, iters=60))[0]
    errs = []
    for n_r in [64, 4096]:
        p = make_params(toy["n"], c=0.25, eps_a=0.1, n_r_override=n_r)
        est = np.asarray(
            single_source(key, toy["g"], toy["eg"], 0, p, variant="telescoped")
        )
        e = np.abs(est - truth); e[0] = 0
        errs.append(e.max())
    assert errs[1] < errs[0]


def test_mla_cache_smaller_than_gqa_cache():
    """The MLA latent cache is the arch's memory win — assert it."""
    from repro.configs.base import TransformerConfig
    from repro.models.transformer import model as M

    mla = TransformerConfig(
        name="m", n_layers=2, d_model=64, n_heads=16, n_kv_heads=16,
        d_head=128, d_ff=128, vocab=64, attention="mla", kv_lora_rank=64,
        qk_nope_head_dim=128, qk_rope_head_dim=32, v_head_dim=128,
        param_dtype="float32", compute_dtype="float32", remat=False,
    )
    gqa = TransformerConfig(
        name="g", n_layers=2, d_model=64, n_heads=16, n_kv_heads=16,
        d_head=128, d_ff=128, vocab=64,
        param_dtype="float32", compute_dtype="float32", remat=False,
    )
    size = lambda c: sum(
        x.size for x in jax.tree_util.tree_leaves(M.init_cache(c, 2, 128))
    )
    assert size(mla) * 10 < size(gqa)  # 512+... vs 2*16*128 per token
