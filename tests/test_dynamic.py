"""Dynamic-graph subsystem: masked batches, mirror consistency, overflow,
regrow, versioning, and the fused update->query epoch step (DESIGN.md §5)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import make_params, multi_source, simrank_power
from repro.graph import (
    apply_update_batch_jit,
    delete_edges,
    delete_edges_ell,
    ell_from_edges,
    erdos_renyi_graph,
    graph_from_edges,
    graph_to_host_edges,
    insert_edges,
    insert_edges_ell,
    make_update_batch,
    regrow,
)
from repro.serving.dynamic_engine import DynamicEngine


def _mirrors_equal_rebuild(g, eg):
    """Assert COO and ELL mirrors are consistent with each other AND
    bit-identical to a from-scratch rebuild of the live edge list."""
    n = g.n
    src, dst = graph_to_host_edges(g)
    g_rb = graph_from_edges(src, dst, n, capacity=g.capacity)
    eg_rb = ell_from_edges(src, dst, n, k_max=eg.k_max)
    np.testing.assert_array_equal(np.asarray(g.src), np.asarray(g_rb.src))
    np.testing.assert_array_equal(np.asarray(g.dst), np.asarray(g_rb.dst))
    np.testing.assert_array_equal(np.asarray(g.in_deg), np.asarray(g_rb.in_deg))
    np.testing.assert_array_equal(np.asarray(g.out_deg), np.asarray(g_rb.out_deg))
    np.testing.assert_array_equal(
        np.asarray(eg.in_nbrs), np.asarray(eg_rb.in_nbrs)
    )
    np.testing.assert_array_equal(np.asarray(eg.in_deg), np.asarray(eg_rb.in_deg))


@pytest.fixture()
def small():
    src, dst, n = erdos_renyi_graph(60, 300, seed=5)
    return dict(
        src=src, dst=dst, n=n,
        g=graph_from_edges(src, dst, n, capacity=len(src) + 64),
        eg=ell_from_edges(src, dst, n, k_max=int(np.bincount(dst, minlength=n).max()) + 8),
    )


# ---------------------------------------------------------------------------
# apply_update_batch: mirrors, masking, versioning
# ---------------------------------------------------------------------------


def test_masked_noop_batch_is_identity(small):
    g, eg, n = small["g"], small["eg"], small["n"]
    batch = make_update_batch([], [], True, batch_size=16, n=n)
    g2, eg2, applied = apply_update_batch_jit(g, eg, batch)
    assert not bool(applied.any())
    assert int(g2.version) == int(g.version)  # no applied op -> no bump
    np.testing.assert_array_equal(np.asarray(g2.src), np.asarray(g.src))
    np.testing.assert_array_equal(np.asarray(g2.dst), np.asarray(g.dst))
    np.testing.assert_array_equal(np.asarray(eg2.in_nbrs), np.asarray(eg.in_nbrs))
    np.testing.assert_array_equal(np.asarray(eg2.in_deg), np.asarray(eg.in_deg))
    assert int(g2.num_edges) == int(g.num_edges)


def test_version_increments_once_per_applied_batch(small):
    g, eg, n = small["g"], small["eg"], small["n"]
    rng = np.random.default_rng(0)
    for i in range(3):  # 3 batches of 8 ops each -> version advances by 3
        s = rng.integers(0, n, 8).astype(np.int32)
        d = rng.integers(0, n, 8).astype(np.int32)
        batch = make_update_batch(s, d, True, batch_size=16, n=n)
        g, eg, applied = apply_update_batch_jit(g, eg, batch)
        assert bool(applied.any())
        assert int(g.version) == i + 1
        assert int(eg.version) == i + 1


def test_insert_delete_roundtrip_mirrors_equal_rebuild(small):
    g, eg, n = small["g"], small["eg"], small["n"]
    rng = np.random.default_rng(1)
    new_s = rng.integers(0, n, 10).astype(np.int32)
    new_d = rng.integers(0, n, 10).astype(np.int32)
    b_ins = make_update_batch(new_s, new_d, True, batch_size=16, n=n)
    g2, eg2, ap = apply_update_batch_jit(g, eg, b_ins)
    assert bool(ap[:10].all())
    _mirrors_equal_rebuild(g2, eg2)
    # delete a mix of original and just-inserted edges
    del_s = np.concatenate([small["src"][:5], new_s[:5]])
    del_d = np.concatenate([small["dst"][:5], new_d[:5]])
    b_del = make_update_batch(del_s, del_d, False, batch_size=16, n=n)
    g3, eg3, ap2 = apply_update_batch_jit(g2, eg2, b_del)
    assert bool(ap2[:10].all())
    assert int(g3.num_edges) == int(g.num_edges)
    _mirrors_equal_rebuild(g3, eg3)
    # degrees consistent between mirrors after the round trip
    np.testing.assert_array_equal(np.asarray(g3.in_deg), np.asarray(eg3.in_deg))


def test_mixed_batch_applies_in_phases(small):
    """Deletes apply before inserts within one batch (documented order)."""
    g, eg, n = small["g"], small["eg"], small["n"]
    s0, d0 = int(small["src"][0]), int(small["dst"][0])
    # delete an existing edge and insert a fresh one in the same batch
    batch = make_update_batch(
        [s0, (s0 + 1) % n], [d0, (d0 + 1) % n], [False, True],
        batch_size=8, n=n,
    )
    assert batch.has_deletes
    g2, eg2, applied = apply_update_batch_jit(g, eg, batch)
    assert bool(applied[0]) and bool(applied[1])
    assert int(g2.num_edges) == int(g.num_edges)
    _mirrors_equal_rebuild(g2, eg2)


def test_duplicate_delete_applies_once(small):
    g, eg, n = small["g"], small["eg"], small["n"]
    s0, d0 = int(small["src"][0]), int(small["dst"][0])
    batch = make_update_batch([s0, s0], [d0, d0], False, batch_size=4, n=n)
    g2, eg2, applied = apply_update_batch_jit(g, eg, batch)
    assert list(np.asarray(applied)) == [True, False, False, False]
    assert int(g2.num_edges) == int(g.num_edges) - 1
    np.testing.assert_array_equal(np.asarray(g2.in_deg), np.asarray(eg2.in_deg))


# ---------------------------------------------------------------------------
# Overflow: explicit signal, consistent skip, regrow recovery
# ---------------------------------------------------------------------------


def test_insert_overflow_flag_and_consistent_skip():
    src = np.array([0, 1, 2], np.int32)
    dst = np.array([1, 2, 0], np.int32)
    n = 6
    g = graph_from_edges(src, dst, n, capacity=4)  # room for ONE more edge
    eg = ell_from_edges(src, dst, n, k_max=2)
    batch = make_update_batch([3, 4, 5], [0, 1, 2], True, batch_size=4, n=n)
    g2, eg2, applied = apply_update_batch_jit(g, eg, batch)
    assert bool(g2.overflow) and bool(eg2.overflow)  # detectable by callers
    assert int(applied.sum()) == 1  # only the edge that fit
    _mirrors_equal_rebuild(g2, eg2)  # the skip hit BOTH mirrors
    # overflow is sticky across a non-overflowing batch
    g3, eg3, _ = apply_update_batch_jit(
        g2, eg2, make_update_batch([], [], True, batch_size=4, n=n)
    )
    assert bool(g3.overflow)


def test_ell_row_overflow_flag():
    # COO has room but dst 0's ELL row is full -> skipped + flagged in both
    src = np.array([1, 2], np.int32)
    dst = np.array([0, 0], np.int32)
    n = 5
    g = graph_from_edges(src, dst, n, capacity=10)
    eg = ell_from_edges(src, dst, n, k_max=2)
    batch = make_update_batch([3], [0], True, batch_size=4, n=n)
    g2, eg2, applied = apply_update_batch_jit(g, eg, batch)
    assert bool(g2.overflow) and bool(eg2.overflow)
    assert not bool(applied.any())
    assert int(g2.num_edges) == 2 and int(eg2.in_deg[0]) == 2


def test_vectorized_fast_paths_overflow_and_masking(small):
    g, eg, n = small["g"], small["eg"], small["n"]
    sentinel = jnp.asarray([n], jnp.int32)
    # sentinel-only batch: identity, no version bump
    g2 = insert_edges(g, sentinel, sentinel)
    assert int(g2.version) == int(g.version)
    assert int(g2.num_edges) == int(g.num_edges)
    eg2 = insert_edges_ell(eg, sentinel, sentinel)
    assert int(eg2.version) == int(eg.version)
    g3 = delete_edges(g, sentinel, sentinel)
    assert int(g3.num_edges) == int(g.num_edges)
    eg3 = delete_edges_ell(eg, sentinel, sentinel)
    np.testing.assert_array_equal(np.asarray(eg3.in_deg), np.asarray(eg.in_deg))
    # COO overflow via the standalone path is flagged, not silently dropped
    free = g.capacity - int(g.num_edges)
    rng = np.random.default_rng(2)
    s = rng.integers(0, n, free + 3).astype(np.int32)
    d = rng.integers(0, n, free + 3).astype(np.int32)
    g4 = insert_edges(g, jnp.asarray(s), jnp.asarray(d))
    assert bool(g4.overflow)
    assert int(g4.num_edges) == g.capacity


def test_regrow_clears_overflow_preserves_edges_and_version():
    src = np.array([0, 1, 2], np.int32)
    dst = np.array([1, 2, 0], np.int32)
    n = 6
    g = graph_from_edges(src, dst, n, capacity=4)
    eg = ell_from_edges(src, dst, n, k_max=2)
    batch = make_update_batch([3, 4], [0, 1], True, batch_size=4, n=n)
    g2, eg2, _ = apply_update_batch_jit(g, eg, batch)
    assert bool(g2.overflow)
    g3, eg3 = regrow(g2, eg2)
    assert not bool(g3.overflow) and not bool(eg3.overflow)
    assert g3.capacity > g2.capacity and eg3.k_max > eg2.k_max
    assert int(g3.version) == int(g2.version)  # representation change only
    assert int(g3.num_edges) == int(g2.num_edges)
    _mirrors_equal_rebuild(g3, eg3)
    # the previously-skipped insert now fits
    g4, eg4, applied = apply_update_batch_jit(
        g3, eg3, make_update_batch([4], [1], True, batch_size=4, n=n)
    )
    assert bool(applied[0]) and not bool(g4.overflow)


# ---------------------------------------------------------------------------
# The fused epoch step (DynamicEngine)
# ---------------------------------------------------------------------------


def test_epoch_scores_equal_rebuild(small, key):
    """Epoch-step scores on the incrementally-updated graph are EXACTLY the
    fused multi-source scores on a from-scratch rebuild (same PRNG keys):
    stable compaction + append keep the mirrors bit-identical to a rebuild,
    so the sampled walks are identical too."""
    g, eg, n = small["g"], small["eg"], small["n"]
    rng = np.random.default_rng(3)
    # insert pairs disjoint from the deleted pairs, else the engine cuts the
    # batch at the insert->delete conflict (separately tested) and this
    # epoch would intentionally apply only a prefix of the stream
    del_pairs = set(zip(small["src"][:4].tolist(), small["dst"][:4].tolist()))
    pairs = []
    while len(pairs) < 12:
        s_, d_ = int(rng.integers(0, n)), int(rng.integers(0, n))
        if (s_, d_) not in del_pairs:
            pairs.append((s_, d_))
    new_s = np.array([p[0] for p in pairs], np.int32)
    new_d = np.array([p[1] for p in pairs], np.int32)
    seed = 11
    eng = DynamicEngine(
        g, eg, c=0.4, eps_a=0.2, top_k=5, batch_q=4, update_batch=16,
        seed=seed,
    )
    eng.insert(new_s, new_d)
    eng.delete(small["src"][:4], small["dst"][:4])
    queries = [1, 2, 3, 4]
    for u in queries:
        eng.submit(u)
    ep = eng.step(budget_walks=64)
    assert ep.version == 1 and len(ep.results) == 4

    # from-scratch rebuild of the same logical graph, same per-query streams
    src2 = np.concatenate([small["src"][4:], new_s])
    dst2 = np.concatenate([small["dst"][4:], new_d])
    g_rb = graph_from_edges(src2, dst2, n, capacity=g.capacity)
    eg_rb = ell_from_edges(src2, dst2, n, k_max=eg.k_max)
    _mirrors_equal_rebuild(eng.g, eng.eg)
    keys = jnp.stack(
        [jax.random.fold_in(jax.random.key(seed), i) for i in range(4)]
    )
    params = make_params(n, c=0.4, eps_a=0.2, delta=0.01)
    est = np.asarray(
        multi_source(None, g_rb, eg_rb, jnp.asarray(queries, jnp.int32),
                     params, lanes=256, n_r=64, keys=keys)
    )
    for i, res in enumerate(ep.results):
        expect = est[i].copy()
        expect[queries[i]] = -np.inf  # top-k excludes the query node
        order = np.argsort(-expect, kind="stable")[:5]
        np.testing.assert_allclose(
            res.topk_scores, expect[order], atol=1e-5
        )


def test_epoch_accuracy_against_power_method(toy, key):
    """Index-free freshness: after updates, epoch scores still satisfy the
    paper's error bound w.r.t. ground truth on the UPDATED graph."""
    n = toy["n"]
    g = graph_from_edges(toy["src"], toy["dst"], n, capacity=len(toy["src"]) + 8)
    eg = ell_from_edges(toy["src"], toy["dst"], n, k_max=8)
    eng = DynamicEngine(
        g, eg, c=0.25, eps_a=0.05, top_k=3, batch_q=2, update_batch=8,
        seed=0,
    )
    eng.insert(np.array([5, 5], np.int32), np.array([0, 1], np.int32))
    eng.submit(0)
    eng.submit(2)
    ep = eng.step()
    src2 = np.concatenate([toy["src"], [5, 5]]).astype(np.int32)
    dst2 = np.concatenate([toy["dst"], [0, 1]]).astype(np.int32)
    g2 = graph_from_edges(src2, dst2, n)
    truth = np.asarray(simrank_power(g2, c=0.25, iters=60))
    for res in ep.results:
        for node, score in zip(res.topk_nodes, res.topk_scores):
            assert abs(score - truth[res.node, node]) <= 0.05 + 1e-6


def test_engine_auto_regrow_retries_skipped_inserts():
    src = np.array([0, 1, 2], np.int32)
    dst = np.array([1, 2, 0], np.int32)
    n = 6
    g = graph_from_edges(src, dst, n, capacity=4)
    eg = ell_from_edges(src, dst, n, k_max=2)
    eng = DynamicEngine(
        g, eg, c=0.3, eps_a=0.3, top_k=2, batch_q=2, update_batch=8, seed=0
    )
    eng.insert([3, 4, 5], [0, 1, 2])
    eng.submit(0)
    ep1 = eng.step(budget_walks=16)
    assert ep1.overflow and ep1.regrown and ep1.updates_requeued == 2
    assert not eng.overflow  # cleared by regrow
    ep2 = eng.step(budget_walks=16)  # retried ops apply now
    assert ep2.updates_applied == 2 and not ep2.overflow
    assert int(eng.g.num_edges) == 6
    _mirrors_equal_rebuild(eng.g, eng.eg)


def test_engine_no_autoregrow_surfaces_skipped_ops():
    """auto_regrow=False: skipped inserts are surfaced, not silently lost —
    the caller regrows manually and re-submits them."""
    src = np.array([0, 1, 2], np.int32)
    dst = np.array([1, 2, 0], np.int32)
    n = 6
    g = graph_from_edges(src, dst, n, capacity=4)
    eg = ell_from_edges(src, dst, n, k_max=2)
    eng = DynamicEngine(
        g, eg, c=0.3, eps_a=0.3, top_k=2, batch_q=2, update_batch=8,
        seed=0, auto_regrow=False,
    )
    eng.insert([3, 4, 5], [0, 1, 2])
    eng.submit(0)
    ep = eng.step(budget_walks=16)
    assert ep.overflow and not ep.regrown and ep.updates_requeued == 0
    assert sorted(ep.skipped_ops) == [(4, 1, True), (5, 2, True)]
    assert eng.overflow  # sticky until the caller regrows
    eng.g, eng.eg = regrow(eng.g, eng.eg)
    for s, d, _ in ep.skipped_ops:
        eng.insert([s], [d])
    ep2 = eng.step(budget_walks=16)
    assert ep2.updates_applied == 2 and int(eng.g.num_edges) == 6
    _mirrors_equal_rebuild(eng.g, eng.eg)


def test_engine_owns_graph_state(small):
    """epoch_step donates the engine's graph buffers; the caller's arrays
    must stay valid because the engine copies at construction."""
    g, eg, n = small["g"], small["eg"], small["n"]
    src_before = np.asarray(g.src).copy()
    eng = DynamicEngine(
        g, eg, c=0.3, eps_a=0.3, top_k=2, batch_q=2, update_batch=4, seed=0
    )
    eng.insert([1], [2])
    eng.submit(1)
    eng.step(budget_walks=16)
    # the fixture's graph is untouched and still readable after donation
    np.testing.assert_array_equal(np.asarray(g.src), src_before)
    assert int(g.version) == 0


def test_engine_batch_cut_preserves_insert_then_delete_order(small):
    """An insert and delete of the same edge in one submission stream must
    not land in the same batch (delete phase runs first) — the engine cuts
    the batch and nets out to 'edge absent', matching stream order."""
    g, eg, n = small["g"], small["eg"], small["n"]
    eng = DynamicEngine(
        g, eg, c=0.3, eps_a=0.3, top_k=2, batch_q=2, update_batch=8, seed=0
    )
    fresh = (int(small["src"][0]) + 7) % n, int(small["dst"][0])
    eng.insert([fresh[0]], [fresh[1]])
    eng.delete([fresh[0]], [fresh[1]])
    ep1 = eng.step(budget_walks=16)
    assert ep1.updates_submitted == 1  # batch cut before the delete
    ep2 = eng.step(budget_walks=16)
    assert ep2.updates_submitted == 1 and ep2.updates_applied == 1
    assert int(eng.g.num_edges) == int(g.num_edges)
    _mirrors_equal_rebuild(eng.g, eng.eg)


def test_engine_rejects_out_of_range_ops(small):
    """Garbage node ids fail fast at enqueue — downstream they would be
    sentinel-masked and then mistaken for capacity-overflow skips."""
    g, eg, n = small["g"], small["eg"], small["n"]
    eng = DynamicEngine(
        g, eg, c=0.3, eps_a=0.3, top_k=2, batch_q=2, update_batch=4, seed=0
    )
    with pytest.raises(ValueError):
        eng.insert([n], [0])
    with pytest.raises(ValueError):
        eng.delete([0], [-1])
    assert eng.pending == (0, 0)


def test_engine_update_only_epochs(small):
    """Epochs with no queued queries apply updates without paying the
    fused probe, and drain() terminates with the right final state."""
    g, eg, n = small["g"], small["eg"], small["n"]
    eng = DynamicEngine(
        g, eg, c=0.3, eps_a=0.3, top_k=2, batch_q=2, update_batch=4, seed=0
    )
    rng = np.random.default_rng(4)
    eng.insert(rng.integers(0, n, 10).astype(np.int32),
               rng.integers(0, n, 10).astype(np.int32))
    eps = eng.drain(budget_walks=16)
    assert len(eps) == 3  # ceil(10 / 4) update-only epochs
    assert all(ep.results == [] for ep in eps)
    assert sum(ep.updates_applied for ep in eps) == 10
    assert eng.version == 3 and eng.pending == (0, 0)
    _mirrors_equal_rebuild(eng.g, eng.eg)


def test_engine_multigraph_duplicate_deletes(small):
    """Deleting both copies of a doubly-inserted edge removes both: the
    batcher cuts at a repeated delete pair so each batch removes one copy."""
    g, eg, n = small["g"], small["eg"], small["n"]
    base = int(g.num_edges)
    eng = DynamicEngine(
        g, eg, c=0.3, eps_a=0.3, top_k=2, batch_q=2, update_batch=8, seed=0
    )
    fresh = (int(small["src"][0]) + 9) % n, int(small["dst"][0])
    eng.insert([fresh[0], fresh[0]], [fresh[1], fresh[1]])
    eng.step(budget_walks=16)
    assert int(eng.g.num_edges) == base + 2
    eng.delete([fresh[0], fresh[0]], [fresh[1], fresh[1]])
    eng.drain(budget_walks=16)
    assert int(eng.g.num_edges) == base
    _mirrors_equal_rebuild(eng.g, eng.eg)


def test_simrank_engine_multigraph_duplicate_deletes(small):
    """SimRankEngine.delete removes one copy per op even for duplicate
    pairs in a single call (split into unique-pair sub-batches)."""
    from repro.serving.engine import SimRankEngine

    g, eg, n = small["g"], small["eg"], small["n"]
    base = int(g.num_edges)
    eng = SimRankEngine(g, eg, c=0.3, eps_a=0.3, top_k=2, seed=0)
    fresh = (int(small["src"][0]) + 11) % n, int(small["dst"][0])
    eng.insert(np.array([fresh[0]] * 2), np.array([fresh[1]] * 2))
    assert int(eng.g.num_edges) == base + 2
    eng.delete(np.array([fresh[0]] * 2), np.array([fresh[1]] * 2))
    assert int(eng.g.num_edges) == base
    np.testing.assert_array_equal(np.asarray(eng.g.in_deg),
                                  np.asarray(eng.eg.in_deg))


def test_engine_results_stamp_version(small):
    g, eg, n = small["g"], small["eg"], small["n"]
    eng = DynamicEngine(
        g, eg, c=0.3, eps_a=0.3, top_k=2, batch_q=2, update_batch=4, seed=0
    )
    eng.submit(1)
    ep0 = eng.step(budget_walks=16)
    assert ep0.version == 0 and ep0.results[0].version == 0
    eng.insert([1], [2])
    eng.submit(1)
    ep1 = eng.step(budget_walks=16)
    assert ep1.version == 1 and ep1.results[0].version == 1
