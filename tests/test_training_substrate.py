"""Training substrate: optimizer, train step, compression, checkpointing,
data pipeline, serving engine + straggler policy."""
import os
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import AsyncCheckpointer, latest_step, restore, save
from repro.data.pipeline import PrefetchPipeline
from repro.training.compression import TopKErrorFeedback, int8_compress
from repro.training.optimizer import AdamW, constant_schedule, warmup_cosine_schedule
from repro.training.step import make_train_step


def _quad_loss(params, batch):
    err = params["w"] - batch["target"]
    loss = jnp.sum(err * err)
    return loss, dict(err=loss)


def test_adamw_converges_on_quadratic():
    opt = AdamW(schedule=constant_schedule(0.1), weight_decay=0.0)
    params = dict(w=jnp.zeros(4))
    state = opt.init(params)
    batch = dict(target=jnp.array([1.0, -2.0, 3.0, 0.5]))
    step = make_train_step(_quad_loss, opt)
    for _ in range(300):
        params, state, metrics = step(params, state, batch)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(batch["target"]), atol=1e-2)


def test_grad_clip_and_schedule():
    opt = AdamW(schedule=warmup_cosine_schedule(1e-3, 10, 100), clip_norm=1.0)
    params = dict(w=jnp.ones(3) * 100)
    state = opt.init(params)
    grads = dict(w=jnp.ones(3) * 1e6)
    _, state2, m = opt.update(grads, state, params)
    assert float(m["grad_norm"]) > 1e5
    assert float(m["lr"]) == pytest.approx(1e-4, rel=1e-3)  # warmup step 1


def test_microbatch_accumulation_matches_full_batch():
    opt = AdamW(schedule=constant_schedule(0.01), weight_decay=0.0)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, dict()

    rng = np.random.default_rng(0)
    batch = dict(
        x=jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
        y=jnp.asarray(rng.normal(size=(8,)).astype(np.float32)),
    )
    params = dict(w=jnp.zeros(4))
    s_full = make_train_step(loss_fn, opt)
    s_micro = make_train_step(loss_fn, opt, microbatches=4)
    p1, _, m1 = s_full(params, opt.init(params), batch)
    p2, _, m2 = s_micro(params, opt.init(params), batch)
    # microbatch mean-of-means == full mean here (equal microbatch sizes)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               atol=1e-5)


def test_int8_compress_small_relative_error():
    rng = np.random.default_rng(0)
    g = dict(w=jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32)))
    gq = int8_compress(g)
    err = np.abs(np.asarray(gq["w"]) - np.asarray(g["w"])).max()
    assert err <= float(jnp.abs(g["w"]).max()) / 127.0 + 1e-6


def test_topk_error_feedback_conserves_mass():
    ef = TopKErrorFeedback(fraction=0.1)
    g = dict(w=jnp.arange(100, dtype=jnp.float32))
    res = ef.init(g)
    sent, res = ef(g, res)
    # sent + residual == original
    np.testing.assert_allclose(
        np.asarray(sent["w"]) + np.asarray(res["w"]), np.asarray(g["w"]),
        atol=1e-6,
    )
    assert float((np.asarray(sent["w"]) != 0).mean()) <= 0.11


def test_checkpoint_roundtrip(tmp_path):
    state = dict(
        a=jnp.arange(10, dtype=jnp.float32),
        nested=dict(b=jnp.ones((3, 3), jnp.bfloat16), step=jnp.asarray(7)),
    )
    path = os.path.join(tmp_path, "ckpt_5")
    save(path, state, step=5, extra=dict(note="x"))
    like = jax.tree_util.tree_map(jnp.zeros_like, state)
    restored, manifest = restore(path, like)
    assert manifest["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_async_checkpointer_gc_and_latest(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    state = dict(x=jnp.ones(4))
    for s in [1, 2, 3]:
        ck.save(state, step=s, block=True)
    assert latest_step(str(tmp_path)) == 3
    names = sorted(os.listdir(tmp_path))
    assert names == ["ckpt_2", "ckpt_3"]
    out = ck.restore_latest(dict(x=jnp.zeros(4)))
    assert out is not None and out[1]["step"] == 3


def test_train_restart_resumes_bitwise(tmp_path):
    """Full FT loop: fail mid-run, restart, final state matches a clean run."""
    from repro.launch.train import train

    d1 = os.path.join(tmp_path, "a")
    with pytest.raises(RuntimeError):
        train("gcn-cora", "full_graph_sm", smoke=True, steps=9,
              ckpt_dir=d1, ckpt_every=3, fail_at=7)
    out1 = train("gcn-cora", "full_graph_sm", smoke=True, steps=9,
                 ckpt_dir=d1, ckpt_every=3)
    out2 = train("gcn-cora", "full_graph_sm", smoke=True, steps=9,
                 ckpt_dir=None, ckpt_every=10**9)
    p1 = out1["state"][0]
    p2 = out2["state"][0]
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_prefetch_pipeline_deterministic_and_ordered():
    made = []

    def mk(step):
        made.append(step)
        return dict(step=np.asarray(step))

    pipe = PrefetchPipeline(mk, start_step=3, prefetch=2)
    got = []
    for step, batch in pipe:
        got.append((step, int(batch["step"])))
        if len(got) == 4:
            break
    pipe.close()
    assert got == [(3, 3), (4, 4), (5, 5), (6, 6)]


def test_straggler_dispatch_sheds_budget():
    from repro.serving.straggler import DeadlineError, HedgePolicy, dispatch

    calls = []

    def slow_then_fast(budget_walks=None):
        calls.append(budget_walks)
        if len(calls) == 1:
            time.sleep(0.5)
        return budget_walks

    out = dispatch(
        slow_then_fast,
        policy=HedgePolicy(deadline_s=0.2, max_retries=2, shed_factor=0.5),
        budget=100,
    )
    assert out == 50  # second attempt ran with shed budget
    with pytest.raises(DeadlineError):
        dispatch(
            lambda budget_walks=None: time.sleep(1.0),
            policy=HedgePolicy(deadline_s=0.05, max_retries=0),
            budget=10,
        )


def test_serving_engine_end_to_end(key):
    from repro.graph import ell_from_edges, graph_from_edges, powerlaw_graph
    from repro.serving.engine import SimRankEngine

    src, dst, n = powerlaw_graph(300, 2500, seed=0)
    in_deg = np.bincount(dst, minlength=n)
    g = graph_from_edges(src, dst, n, capacity=len(src) + 64)
    eg = ell_from_edges(src, dst, n, k_max=int(in_deg.max()) + 8)
    eng = SimRankEngine(g, eg, eps_a=0.2, top_k=5, walk_chunk=128)
    u = int(np.argmax(in_deg))
    res = eng.run_query(u, budget_walks=256)
    assert len(res.topk_nodes) == 5
    assert u not in res.topk_nodes
    eng.insert(np.array([1, 2], np.int32), np.array([u, u], np.int32))
    res2 = eng.run_query(u, budget_walks=256)
    assert eng.stats.updates == 2 and eng.stats.queries == 2
