"""GAT [arXiv:1710.10903] — BONUS architecture beyond the assigned ten,
exercising the SDDMM + segment-softmax kernel regime (taxonomy §GNN)."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gat-bonus", conv="gat", n_layers=2, d_hidden=64, aggregator="attn",
    n_classes=7,
)
SMOKE = GNNConfig(
    name="gat-bonus-smoke", conv="gat", n_layers=2, d_hidden=16, n_classes=4,
)
