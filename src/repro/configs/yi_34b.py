"""Yi-34B [arXiv:2403.04652; hf 01-ai/Yi-34B]: 60L d=7168 56H GQA kv=8."""
from repro.configs.base import TransformerConfig

CONFIG = TransformerConfig(
    name="yi-34b",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=20480,
    vocab=64000,
    rope_theta=5_000_000.0,
)

SMOKE = TransformerConfig(
    name="yi-34b-smoke",
    n_layers=2,
    d_model=56,
    n_heads=7,
    n_kv_heads=1,
    d_head=8,
    d_ff=160,
    vocab=512,
    param_dtype="float32",
    compute_dtype="float32",
    remat=False,
)
