"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf deepseek-ai/DeepSeek-V2-Lite].

Assignment line: 27L d_model=2048 16H d_ff=1408 vocab=102400, MoE 64e top-6,
MLA kv_lora=512, 2 shared experts.  (The assignment also mentions "160
routed" — that is full V2's expert count; V2-Lite has 64 routed experts and
the assignment's own "MoE 64e top-6" agrees, so we use 64.)
First layer is dense (first_k_dense_replace=1, width 10944).
"""
from repro.configs.base import MoEConfig, TransformerConfig

CONFIG = TransformerConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=10944,
    vocab=102400,
    attention="mla",
    kv_lora_rank=512,
    q_lora_rank=0,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    moe=MoEConfig(
        n_routed=64,
        top_k=6,
        d_ff_expert=1408,
        n_shared=2,
        d_ff_shared=2816,
        first_dense_layers=1,
        d_ff_dense=10944,
        norm_topk_prob=False,
    ),
    rope_theta=10000.0,
)

SMOKE = TransformerConfig(
    name="deepseek-v2-lite-16b-smoke",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=512,
    attention="mla",
    kv_lora_rank=32,
    q_lora_rank=0,
    qk_nope_head_dim=16,
    qk_rope_head_dim=8,
    v_head_dim=16,
    moe=MoEConfig(
        n_routed=8,
        top_k=2,
        d_ff_expert=32,
        n_shared=2,
        d_ff_shared=64,
        first_dense_layers=1,
        d_ff_dense=128,
        norm_topk_prob=False,
        capacity_factor=4.0,
    ),
    rope_theta=10000.0,
    param_dtype="float32",
    compute_dtype="float32",
    remat=False,
)
