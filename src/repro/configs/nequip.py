"""NequIP [arXiv:2101.03164]: 5L 32ch l_max=2 8 Bessel rbf cutoff 5A."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="nequip", conv="nequip", n_layers=5, d_hidden=32, l_max=2, n_rbf=8,
    cutoff=5.0, n_classes=1,
)
SMOKE = GNNConfig(
    name="nequip-smoke", conv="nequip", n_layers=2, d_hidden=8, l_max=2,
    n_rbf=4, cutoff=5.0, n_classes=1,
)
