"""Wide&Deep [arXiv:1606.07792]: 40 sparse fields, dim 32, MLP 1024-512-256."""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="wide-deep", n_sparse=40, embed_dim=32, mlp=(1024, 512, 256),
    vocab_per_field=1_000_000, n_dense=13,
)
SMOKE = RecsysConfig(
    name="wide-deep-smoke", n_sparse=6, embed_dim=8, mlp=(32, 16),
    vocab_per_field=1000, n_dense=4,
)
