"""GatedGCN [arXiv:2003.00982 benchmarking-gnns]: 16L d=70 gated agg."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gatedgcn", conv="gatedgcn", n_layers=16, d_hidden=70,
    aggregator="gated", n_classes=16,
)
SMOKE = GNNConfig(
    name="gatedgcn-smoke", conv="gatedgcn", n_layers=3, d_hidden=16, n_classes=4,
)
