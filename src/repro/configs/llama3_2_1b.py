"""Llama-3.2 1B [hf meta-llama/Llama-3.2-1B]: 16L d=2048 32H GQA kv=8."""
from repro.configs.base import TransformerConfig

CONFIG = TransformerConfig(
    name="llama3.2-1b",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_head=64,
    d_ff=8192,
    vocab=128256,
    rope_theta=500_000.0,
    tie_embeddings=True,
)

SMOKE = TransformerConfig(
    name="llama3.2-1b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=256,
    vocab=512,
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
    remat=False,
)
