"""Config dataclasses + registry for all architectures and input shapes.

Every assigned architecture registers one module in this package exposing
``CONFIG`` (full-scale, exact literature numbers) and ``SMOKE`` (reduced,
CPU-runnable).  ``launch/dryrun.py`` iterates REGISTRY x SHAPES.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace
from typing import Any


# ---------------------------------------------------------------------------
# LM transformers
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0  # total shared width (n_shared * d_ff_expert if 0)
    capacity_factor: float = 1.25
    norm_topk_prob: bool = True
    router_aux_weight: float = 0.001
    first_dense_layers: int = 0  # leading dense layers (DeepSeek style)
    d_ff_dense: int = 0  # width of those dense layers


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    attention: str = "gqa"  # "gqa" | "mla"
    # MLA (DeepSeek-V2) geometry
    kv_lora_rank: int = 0
    q_lora_rank: int = 0  # 0 = direct q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    moe: MoEConfig | None = None
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True  # False: unroll (depth-delta dry-run variants)
    attn_probs_dtype: str = "float32"  # bf16 = flash-kernel semantics
    logits_dtype: str = "float32"  # bf16 logits + f32 logsumexp accum
    microbatches: int = 1  # gradient-accumulation splits of the global batch
    # which sequence-length the KV cache is laid out for in serve steps
    family: str = "lm"

    @property
    def params_dense(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, L, v = self.d_model, self.n_layers, self.vocab
        if self.attention == "mla":
            attn = d * self.kv_lora_rank + self.kv_lora_rank * self.n_heads * (
                self.qk_nope_head_dim + self.v_head_dim
            ) + d * self.qk_rope_head_dim
            if self.q_lora_rank:
                attn += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                    self.qk_nope_head_dim + self.qk_rope_head_dim
                )
            else:
                attn += d * self.n_heads * (
                    self.qk_nope_head_dim + self.qk_rope_head_dim
                )
            attn += self.n_heads * self.v_head_dim * d
        else:
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
            attn += self.n_heads * self.d_head * d
        if self.moe is None:
            ffn = 3 * d * self.d_ff
            per_layer = attn + ffn
            total = L * per_layer
        else:
            m = self.moe
            shared_w = m.d_ff_shared or m.n_shared * m.d_ff_expert
            moe_ffn = 3 * d * (m.n_routed * m.d_ff_expert + shared_w) + d * m.n_routed
            dense_ffn = 3 * d * (m.d_ff_dense or self.d_ff)
            total = (
                L * attn
                + m.first_dense_layers * dense_ffn
                + (L - m.first_dense_layers) * moe_ffn
            )
        total += 2 * d * v if not self.tie_embeddings else d * v
        return int(total)

    @property
    def params_active(self) -> int:
        """Active params per token (MoE: only routed top-k count)."""
        if self.moe is None:
            return self.params_dense
        m = self.moe
        d, L = self.d_model, self.n_layers
        inactive_per_moe_layer = 3 * d * (m.n_routed - m.top_k) * m.d_ff_expert
        return int(
            self.params_dense - (L - m.first_dense_layers) * inactive_per_moe_layer
        )


# ---------------------------------------------------------------------------
# GNNs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GNNConfig:
    name: str
    conv: str  # "gcn" | "gin" | "gatedgcn" | "nequip"
    n_layers: int
    d_hidden: int
    d_feat: int = 0  # input feature dim (filled by shape)
    n_classes: int = 16
    aggregator: str = "sum"
    # nequip
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    eps_learnable: bool = True  # GIN epsilon
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    node_shard: str = "all"  # "all" axes | "model" (keep scatters TP-local)
    family: str = "gnn"


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    n_sparse: int
    embed_dim: int
    mlp: tuple[int, ...]
    vocab_per_field: int = 1_000_000
    n_dense: int = 13
    interaction: str = "concat"
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    family: str = "recsys"


# ---------------------------------------------------------------------------
# ProbeSim (the paper's own serving config)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ProbeSimConfig:
    name: str
    n: int
    m: int
    c: float = 0.6
    eps_a: float = 0.1
    delta: float = 0.01
    k_max_ell: int = 64  # ELL cap for walk sampling
    push_mode: str = "auto"  # "auto" (pjit) | "ring" (shard_map ppermute)
    frontier_dtype: str = "float32"  # "bfloat16" halves exchange volume
    family: str = "probesim"


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode" | "full_graph" | ...
    dims: dict[str, Any] = field(default_factory=dict)


LM_SHAPES = [
    ShapeSpec("train_4k", "train", dict(seq_len=4096, global_batch=256)),
    ShapeSpec("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
    ShapeSpec("decode_32k", "decode", dict(seq_len=32768, global_batch=128)),
    ShapeSpec("long_500k", "decode", dict(seq_len=524288, global_batch=1)),
]

GNN_SHAPES = [
    ShapeSpec(
        "full_graph_sm",
        "full_graph",
        dict(n_nodes=2708, n_edges=10556, d_feat=1433),
    ),
    ShapeSpec(
        "minibatch_lg",
        "minibatch",
        dict(
            n_nodes=232_965,
            n_edges=114_615_892,
            batch_nodes=1024,
            fanout=(15, 10),
            d_feat=602,
        ),
    ),
    ShapeSpec(
        "ogb_products",
        "full_graph",
        dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100),
    ),
    ShapeSpec(
        "molecule",
        "batched_graphs",
        dict(n_nodes=30, n_edges=64, batch=128, d_feat=16),
    ),
]

RECSYS_SHAPES = [
    ShapeSpec("train_batch", "train", dict(batch=65536)),
    ShapeSpec("serve_p99", "serve", dict(batch=512)),
    ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
    ShapeSpec(
        "retrieval_cand", "retrieval", dict(batch=1, n_candidates=1_000_000)
    ),
]

PROBESIM_SHAPES = [
    ShapeSpec("serve_batch", "simrank_serve", dict(queries=8, walk_chunk=256)),
    ShapeSpec("serve_online", "simrank_serve", dict(queries=1, walk_chunk=256)),
]


ARCH_IDS = [
    "deepseek-v2-lite-16b",
    "qwen2-moe-a2.7b",
    "llama3-405b",
    "yi-34b",
    "llama3.2-1b",
    "gin-tu",
    "gcn-cora",
    "gatedgcn",
    "nequip",
    "wide-deep",
    "probesim",  # the paper's own config
]

_MODULE_OF = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "llama3-405b": "llama3_405b",
    "yi-34b": "yi_34b",
    "llama3.2-1b": "llama3_2_1b",
    "gin-tu": "gin_tu",
    "gcn-cora": "gcn_cora",
    "gatedgcn": "gatedgcn",
    "nequip": "nequip",
    "wide-deep": "wide_deep",
    "probesim": "probesim",
    "gat-bonus": "gat_bonus",  # beyond the assigned ten
}


def get_config(arch: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{_MODULE_OF[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


def shapes_for(arch: str) -> list[ShapeSpec]:
    cfg = get_config(arch)
    fam = cfg.family
    if fam == "lm":
        return list(LM_SHAPES)
    if fam == "gnn":
        return list(GNN_SHAPES)
    if fam == "recsys":
        return list(RECSYS_SHAPES)
    if fam == "probesim":
        return list(PROBESIM_SHAPES)
    raise ValueError(fam)


def scale_down(cfg, **overrides):
    """Helper for SMOKE configs."""
    return replace(cfg, **overrides)
