"""GIN on TU datasets [arXiv:1810.00826]: 5L d=64 sum-agg learnable-eps."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gin-tu", conv="gin", n_layers=5, d_hidden=64, aggregator="sum",
    eps_learnable=True, n_classes=16,
)
SMOKE = GNNConfig(
    name="gin-tu-smoke", conv="gin", n_layers=2, d_hidden=16, n_classes=4,
)
