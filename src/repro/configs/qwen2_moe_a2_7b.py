"""Qwen1.5-MoE-A2.7B [hf Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (MHA: kv=16) moe_intermediate=1408, 60 routed top-4,
shared expert width 5632 ("4 shared" x 1408), vocab=151936.
"""
from repro.configs.base import MoEConfig, TransformerConfig

CONFIG = TransformerConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=5632,
    vocab=151936,
    attention="gqa",
    moe=MoEConfig(
        n_routed=60,
        top_k=4,
        d_ff_expert=1408,
        n_shared=4,
        d_ff_shared=5632,
        norm_topk_prob=True,
    ),
    rope_theta=1_000_000.0,
)

SMOKE = TransformerConfig(
    name="qwen2-moe-a2.7b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=512,
    moe=MoEConfig(
        n_routed=6,
        top_k=2,
        d_ff_expert=32,
        n_shared=1,
        d_ff_shared=64,
        capacity_factor=4.0,
    ),
    param_dtype="float32",
    compute_dtype="float32",
    remat=False,
)
