"""ProbeSim serving config — the paper's own architecture.

Twitter-scale graph (paper Table 3) for the dry-run; the serving step is a
batched single-source top-k query against the node/edge-sharded graph.
"""
from repro.configs.base import ProbeSimConfig

CONFIG = ProbeSimConfig(
    name="probesim",
    n=41_652_230,
    m=1_468_365_182,
    c=0.6,
    eps_a=0.1,
    delta=0.01,
    k_max_ell=64,
)
SMOKE = ProbeSimConfig(
    name="probesim-smoke", n=512, m=4096, c=0.6, eps_a=0.1, delta=0.1,
    k_max_ell=32,
)
