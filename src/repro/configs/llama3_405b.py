"""Llama-3.1 405B [arXiv:2407.21783]: 126L d=16384 128H GQA kv=8 d_ff=53248."""
from repro.configs.base import TransformerConfig

CONFIG = TransformerConfig(
    name="llama3-405b",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_head=128,
    d_ff=53248,
    vocab=128256,
    rope_theta=500_000.0,
)

SMOKE = TransformerConfig(
    name="llama3-405b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_head=8,
    d_ff=192,
    vocab=512,
    param_dtype="float32",
    compute_dtype="float32",
    remat=False,
)
