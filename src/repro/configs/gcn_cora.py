"""GCN on Cora [arXiv:1609.02907]: 2L d=16 sym-norm mean-agg."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gcn-cora", conv="gcn", n_layers=2, d_hidden=16, aggregator="mean",
    n_classes=7,
)
SMOKE = GNNConfig(
    name="gcn-cora-smoke", conv="gcn", n_layers=2, d_hidden=8, n_classes=4,
)
