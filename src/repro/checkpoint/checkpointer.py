"""Sharded checkpointing with async save and elastic restore.

Format: one ``.npz`` per flattened leaf group + a msgpack manifest holding
the treedef, shapes, dtypes and the mesh the state was saved under.  Restore
re-shards through host memory, so a checkpoint written on a 2x16x16 mesh
restores onto 16x16 (or 1 device) — the elastic-rescale path.

Fault-tolerance contract (launch/train.py):
* saves are atomic (write to ``.tmp`` dir, rename);
* the latest complete checkpoint wins; partial writes are ignored;
* save is async (background thread) — training continues immediately;
* the data-pipeline cursor and RNG key ride along, so restart resumes
  bit-identically (synthetic data is (seed, step)-deterministic).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import numpy as np

import jax


def _flatten(state: Any):
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return leaves, treedef


def save(path: str, state: Any, *, step: int, extra: dict | None = None) -> None:
    """Synchronous atomic checkpoint save."""
    leaves, treedef = _flatten(state)
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    arrays = {}
    meta_leaves = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        orig_dtype = str(arr.dtype)
        if arr.dtype.kind not in "biufc":  # ml_dtypes (bf16/fp8): npz-unsafe
            arr = arr.astype(np.float32)  # lossless upcast for bf16/fp8
        arrays[f"leaf_{i}"] = arr
        meta_leaves.append(dict(shape=list(arr.shape), dtype=orig_dtype))
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = dict(
        step=step,
        n_leaves=len(leaves),
        leaves=meta_leaves,
        treedef=str(treedef),
        extra=extra or {},
        time=time.time(),
    )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def restore(path: str, like: Any, *, shardings: Any | None = None) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (with optional resharding).

    ``like`` supplies the treedef; ``shardings`` (same structure) places each
    leaf — pass the current mesh's NamedShardings for the elastic path.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves), (
        f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves)}"
    )
    out = []
    shard_leaves = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        if shardings is not None
        else [None] * len(leaves)
    )
    import jax.numpy as jnp

    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = jnp.asarray(z[f"leaf_{i}"]).astype(ref.dtype)
        assert list(arr.shape) == list(ref.shape), f"leaf {i} shape mismatch"
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return treedef.unflatten(out), manifest


def latest_step(root: str) -> int | None:
    """Find the newest complete checkpoint under root (ckpt_<step> dirs)."""
    if not os.path.isdir(root):
        return None
    best = None
    for name in os.listdir(root):
        if not name.startswith("ckpt_") or name.endswith(".tmp"):
            continue
        if not os.path.exists(os.path.join(root, name, "manifest.json")):
            continue
        step = int(name.split("_", 1)[1])
        best = step if best is None else max(best, step)
    return best


class AsyncCheckpointer:
    """Background-thread checkpointer; keeps the last ``keep`` checkpoints."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: threading.Thread | None = None

    def save(self, state: Any, *, step: int, extra: dict | None = None,
             block: bool = False) -> None:
        self.wait()
        # snapshot to host BEFORE returning control (donated buffers safety)
        host_state = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), state
        )

        def work():
            path = os.path.join(self.root, f"ckpt_{step}")
            save(path, host_state, step=step, extra=extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like: Any, shardings: Any | None = None):
        step = latest_step(self.root)
        if step is None:
            return None
        state, manifest = restore(
            os.path.join(self.root, f"ckpt_{step}"), like, shardings=shardings
        )
        return state, manifest

    def _gc(self):
        steps = sorted(
            int(n.split("_", 1)[1])
            for n in os.listdir(self.root)
            if n.startswith("ckpt_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.root, n, "manifest.json"))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"ckpt_{s}"), ignore_errors=True)
