"""Serving launcher — the paper's end-to-end driver.

Runs a ``SimRankSession`` against a synthetic power-law graph with a
dynamic update stream interleaved between query dispatches (the paper's §1
motivation: index-free => updates are free).  Reports per-query latency and
top-k results; optional straggler policy wraps dispatch.

Usage:
  python -m repro.launch.serve --nodes 20000 --edges 200000 --queries 20 \
      --updates-per-batch 100 --eps-a 0.1
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.api import GraphHandle, QuerySpec, SimRankSession
from repro.serving.straggler import HedgePolicy, dispatch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=20_000)
    ap.add_argument("--edges", type=int, default=200_000)
    ap.add_argument("--queries", type=int, default=10)
    ap.add_argument("--updates-per-batch", type=int, default=64)
    ap.add_argument("--eps-a", type=float, default=0.1)
    ap.add_argument("--c", type=float, default=0.6)
    ap.add_argument("--top-k", type=int, default=50)
    ap.add_argument("--walk-budget", type=int, default=None,
                    help="cap walks per query (anytime mode)")
    ap.add_argument("--deadline-s", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.graph import powerlaw_graph

    rng = np.random.default_rng(args.seed)
    src, dst, n = powerlaw_graph(args.nodes, args.edges, seed=args.seed)
    in_deg = np.bincount(dst, minlength=n)
    handle = GraphHandle.from_edges(
        src, dst, n,
        capacity=len(src) + 100_000,
        k_max=int(in_deg.max()) + 8,
    )
    sess = SimRankSession(
        handle, c=args.c, eps_a=args.eps_a, top_k=args.top_k, seed=args.seed
    )
    print(f"graph: n={n} m={len(src)}; n_r={sess.params.n_r} walks/query "
          f"(eps_a={args.eps_a}), max_len={sess.params.max_len}")

    query_nodes = rng.choice(np.where(in_deg > 0)[0], size=args.queries)
    lat = []
    for i, u in enumerate(query_nodes):
        # interleave a dynamic update batch — no index rebuild
        ins_src = rng.integers(0, n, args.updates_per_batch).astype(np.int32)
        ins_dst = rng.integers(0, n, args.updates_per_batch).astype(np.int32)
        t0 = time.time()
        upd = sess.update(inserts=(ins_src, ins_dst))
        upd_t = time.time() - t0

        if args.deadline_s:
            def on_retry(attempt):
                sess.stats.retries += 1
                print(f"  retry {attempt} (shed budget)")

            # dispatch injects budget_walks per attempt (shed on retries)
            res = dispatch(
                sess.query, QuerySpec(kind="topk", node=int(u)),
                policy=HedgePolicy(deadline_s=args.deadline_s),
                budget=args.walk_budget or sess.params.n_r,
                on_retry=on_retry,
            )
        else:
            res = sess.query(QuerySpec(kind="topk", node=int(u),
                                       budget_walks=args.walk_budget))
        lat.append(res.latency_s)
        top3 = ", ".join(
            f"{nn}:{s:.4f}" for nn, s in
            zip(res.topk_nodes[:3], res.topk_scores[:3])
        )
        print(f"q{i} u={u}: update({upd.applied} edges)={upd_t*1e3:.1f}ms "
              f"query={res.latency_s:.2f}s v{res.version} top3=[{top3}]")
    lat = np.array(lat)
    print(f"latency: mean={lat.mean():.2f}s p50={np.percentile(lat,50):.2f}s "
          f"p99={np.percentile(lat,99):.2f}s; "
          f"updates applied: {sess.stats.updates}; "
          f"dispatches: {sess.stats.steps}; retries: {sess.stats.retries}")


if __name__ == "__main__":
    main()
