"""Serving launcher — the paper's end-to-end driver.

Runs a ``SimRankSession`` against a synthetic power-law graph with a
dynamic update stream interleaved between query dispatches (the paper's §1
motivation: index-free => updates are free).  Reports per-query latency and
top-k results; optional straggler policy wraps dispatch.

``--backend sharded --shards N`` serves the same stream through the
mesh-sharded backend (dst-partitioned graph over a local device mesh;
pair with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a
fake multi-device CPU run).  Updates then apply shard-wise — same
version/overflow semantics, no index rebuild either way.

``--epochs`` fuses each update burst WITH its query into one compiled
epoch dispatch (``SimRankSession.epoch``, zero host transfers between
update and query) — on the sharded backend the updates apply inside a
shard_map step against device-resident shard buffers (core/epoch.py).

``--epsilon`` serves every query through the adaptive accuracy controller
(``core/accuracy.py``): escalate walks geometrically until a certificate
meets the requested absolute error, capped at ``--walk-budget`` (or the
flat Thm-1 budget).  Combined with ``--deadline-s`` the deadline rides
in-band (``straggler.dispatch_adaptive``): a miss degrades to the
best-so-far certificate instead of a shed retry.

``--serve`` starts the network service instead of the driver loop: the
threaded HTTP front end (``serving/server.py``) over a
:class:`SimRankService` (micro-batching window, admission control,
per-tenant sessions) on ``--host``/``--port``, local or
``--backend sharded``.  The driver's graph flags build the served graph;
``--batch-window-ms`` / ``--max-batch-q`` / ``--max-inflight`` tune the
collector.  Ctrl-C shuts down gracefully (drains in-flight requests).

Usage:
  python -m repro.launch.serve --nodes 20000 --edges 200000 --queries 20 \
      --updates-per-batch 100 --eps-a 0.1
  python -m repro.launch.serve --queries 20 --epsilon 0.1 --deadline-s 2.0
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m repro.launch.serve --backend sharded --shards 4 --epochs
  python -m repro.launch.serve --serve --port 8311 --walk-budget 512
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.api import GraphHandle, QuerySpec, SimRankSession
from repro.serving.straggler import HedgePolicy, dispatch, dispatch_adaptive


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=20_000)
    ap.add_argument("--edges", type=int, default=200_000)
    ap.add_argument("--queries", type=int, default=10)
    ap.add_argument("--updates-per-batch", type=int, default=64)
    ap.add_argument("--eps-a", type=float, default=0.1)
    ap.add_argument("--c", type=float, default=0.6)
    ap.add_argument("--top-k", type=int, default=50)
    ap.add_argument("--walk-budget", type=int, default=None,
                    help="cap walks per query (anytime mode; with "
                         "--epsilon: the escalation cap)")
    ap.add_argument("--epsilon", type=float, default=None,
                    help="adaptive accuracy: escalate walks per query "
                         "until this absolute-error target is certified")
    ap.add_argument("--deadline-s", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=("local", "sharded"), default="local")
    ap.add_argument("--shards", type=int, default=None,
                    help="row-partition count for --backend sharded "
                         "(default: local device count)")
    ap.add_argument("--epochs", action="store_true",
                    help="serve each update burst + query as ONE fused "
                         "epoch dispatch instead of update() + query()")
    ap.add_argument("--serve", action="store_true",
                    help="start the HTTP serving front end instead of the "
                         "driver loop (POST /query, POST /update, "
                         "GET /stats, GET /healthz)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8311)
    ap.add_argument("--batch-window-ms", type=float, default=10.0,
                    help="--serve: micro-batch collector window")
    ap.add_argument("--max-batch-q", type=int, default=16,
                    help="--serve: fused-dispatch lane count (batch cut "
                         "fires early when this many queries wait)")
    ap.add_argument("--max-inflight", type=int, default=256,
                    help="--serve: admission bound; past it clients get "
                         "429 + Retry-After")
    args = ap.parse_args()
    if args.epsilon is not None and args.epochs:
        ap.error("--epsilon and --epochs are mutually exclusive: --epsilon "
                 "queries are served by the host-side escalation loop and "
                 "cannot ride inside a fused --epochs dispatch — drop one "
                 "of the two flags")

    from repro.graph import powerlaw_graph

    rng = np.random.default_rng(args.seed)
    src, dst, n = powerlaw_graph(args.nodes, args.edges, seed=args.seed)
    in_deg = np.bincount(dst, minlength=n)
    handle = GraphHandle.from_edges(
        src, dst, n,
        capacity=len(src) + 100_000,
        k_max=int(in_deg.max()) + 8,
    )
    import jax

    shards = args.shards
    if args.backend == "sharded" and shards is None:
        shards = len(jax.devices())

    if args.serve:
        _serve_forever(handle, args, shards, n=n, m=len(src))
        return

    sess = SimRankSession(
        handle, c=args.c, eps_a=args.eps_a, top_k=args.top_k, seed=args.seed,
        backend=args.backend, shards=shards,
        batch_q=1, update_batch=args.updates_per_batch,
    )
    # the batch dispatch label names the compiled step a Q-query burst
    # lands on (e.g. "sharded[ring,Q=16]"): backend + probe + lane count
    print(f"graph: n={n} m={len(src)}; n_r={sess.params.n_r} walks/query "
          f"(eps_a={args.eps_a}), max_len={sess.params.max_len}; "
          f"dispatch={sess.backend.batch_dispatch_label(sess.batch_q)}"
          + (f" shards={shards}" if args.backend == "sharded" else "")
          + (" [fused epochs]" if args.epochs else ""))

    query_nodes = rng.choice(np.where(in_deg > 0)[0], size=args.queries)
    lat = []
    for i, u in enumerate(query_nodes):
        # interleave a dynamic update batch — no index rebuild
        ins_src = rng.integers(0, n, args.updates_per_batch).astype(np.int32)
        ins_dst = rng.integers(0, n, args.updates_per_batch).astype(np.int32)

        if args.epochs:
            # ONE fused dispatch: apply the burst + serve the query on the
            # post-update snapshot (device-resident on either backend)
            ep = sess.epoch(
                inserts=(ins_src, ins_dst),
                queries=[QuerySpec(kind="topk", node=int(u),
                                   budget_walks=args.walk_budget)],
            )
            res = ep.results[0]
            lat.append(ep.latency_s)
            top3 = ", ".join(
                f"{nn}:{s:.4f}" for nn, s in
                zip(res.topk_nodes[:3], res.topk_scores[:3])
            )
            print(f"q{i} u={u}: epoch({ep.updates_applied} edges + query)"
                  f"={ep.latency_s:.2f}s v{res.version} top3=[{top3}]")
            continue

        t0 = time.time()
        upd = sess.update(inserts=(ins_src, ins_dst))
        upd_t = time.time() - t0

        if args.epsilon is not None:
            spec = QuerySpec(kind="topk", node=int(u), epsilon=args.epsilon,
                             budget_walks=args.walk_budget)
            if args.deadline_s:
                # deadline rides in-band: a miss freezes best-so-far
                # (certificate='deadline') instead of shedding + retrying
                res = dispatch_adaptive(
                    sess.query, spec,
                    policy=HedgePolicy(deadline_s=args.deadline_s),
                )
            else:
                res = sess.query(spec)
            lat.append(res.latency_s)
            top3 = ", ".join(
                f"{nn}:{s:.4f}" for nn, s in
                zip(res.topk_nodes[:3], res.topk_scores[:3])
            )
            print(f"q{i} u={u}: update({upd.applied} edges)={upd_t*1e3:.1f}ms "
                  f"query={res.latency_s:.2f}s v{res.version} "
                  f"walks={res.walks_used}/{sess.params.n_r} "
                  f"cert={res.certificate}@{res.certified_bound:.4f} "
                  f"rounds={res.rounds} top3=[{top3}]")
            continue

        if args.deadline_s:
            def on_retry(attempt):
                # report through the public stats API — EngineStats is
                # owned by the session/backend; external dispatch wrappers
                # must not mutate its fields directly
                sess.record_retry()
                print(f"  retry {attempt} (shed budget)")

            # dispatch injects budget_walks per attempt (shed on retries)
            res = dispatch(
                sess.query, QuerySpec(kind="topk", node=int(u)),
                policy=HedgePolicy(deadline_s=args.deadline_s),
                budget=args.walk_budget or sess.params.n_r,
                on_retry=on_retry,
            )
        else:
            res = sess.query(QuerySpec(kind="topk", node=int(u),
                                       budget_walks=args.walk_budget))
        lat.append(res.latency_s)
        top3 = ", ".join(
            f"{nn}:{s:.4f}" for nn, s in
            zip(res.topk_nodes[:3], res.topk_scores[:3])
        )
        print(f"q{i} u={u}: update({upd.applied} edges)={upd_t*1e3:.1f}ms "
              f"query={res.latency_s:.2f}s v{res.version} top3=[{top3}]")
    lat = np.array(lat)
    print(f"latency: mean={lat.mean():.2f}s p50={np.percentile(lat,50):.2f}s "
          f"p99={np.percentile(lat,99):.2f}s; "
          f"updates applied: {sess.stats.updates}; "
          f"dispatches: {sess.stats.steps}; retries: {sess.stats.retries}"
          + (f"; escalations: {sess.stats.escalations}; "
             f"hub hits: {sess.stats.hub_hits}"
             if args.epsilon is not None else ""))


def _serve_forever(handle, args, shards, *, n: int, m: int) -> None:
    """--serve mode: run the HTTP service until interrupted."""
    from repro.serving import ServiceConfig, SimRankService, start_server
    from repro.serving import stop_server

    svc = SimRankService(
        handle,
        backend=args.backend,
        shards=shards,
        config=ServiceConfig(
            batch_window_ms=args.batch_window_ms,
            max_batch_q=args.max_batch_q,
            max_inflight=args.max_inflight,
            default_budget_walks=args.walk_budget,
        ),
        seed=args.seed,
        session_kwargs=dict(c=args.c, eps_a=args.eps_a, top_k=args.top_k),
    )
    server, thread = start_server(svc, args.host, args.port)
    host, port = server.server_address
    print(f"serving n={n} m={m} on http://{host}:{port} "
          f"(backend={args.backend}"
          + (f" shards={shards}" if args.backend == "sharded" else "")
          + f", window={args.batch_window_ms}ms, "
          f"batch_q={args.max_batch_q}, max_inflight={args.max_inflight}); "
          "POST /query /update, GET /stats /healthz; Ctrl-C to stop",
          flush=True)
    try:
        # polling join: a bare join() parks in an uninterruptible C-level
        # acquire on some platforms; this stays responsive to Ctrl-C
        while thread.is_alive():
            thread.join(timeout=0.5)
    except KeyboardInterrupt:
        print("\nshutting down (draining in-flight requests)...", flush=True)
        stop_server(server, thread)


if __name__ == "__main__":
    main()
