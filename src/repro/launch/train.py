"""Training launcher: real (small-scale) runs on local devices with the full
fault-tolerance loop — checkpoint/restart, async saves, deterministic data,
failure injection for testing.

At production scale the same loop runs under the 16x16 / 2x16x16 mesh of
launch/mesh.py (the dry-run proves those cells compile); locally it runs on
whatever devices exist.

Usage:
  python -m repro.launch.train --arch llama3.2-1b --smoke --steps 50 \
      --ckpt-dir /tmp/ckpt --ckpt-every 10
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import arch as arch_mod
from repro.checkpoint.checkpointer import AsyncCheckpointer
from repro.data import synthetic
from repro.data.pipeline import PrefetchPipeline


def make_batch_fn(bundle, seed: int):
    cfg = bundle.cfg
    shape = bundle.shape

    if cfg.family == "lm":
        B = shape.dims["global_batch"]
        S = shape.dims["seq_len"]

        def fn(step):
            return synthetic.lm_batch(seed, step, B, S, cfg.vocab)

    elif cfg.family == "gnn":
        specs = bundle.input_specs()["batch"]

        def fn(step):
            d = shape.dims
            if shape.kind == "batched_graphs":
                b = synthetic.molecule_batch(
                    seed, step, d["batch"], d["n_nodes"], d["n_edges"],
                    d["d_feat"], with_pos=cfg.conv == "nequip",
                )
            else:
                n = specs["feats"].shape[0]
                e = specs["src"].shape[0]
                b = synthetic.gnn_full_graph_batch(
                    seed, n, e, d["d_feat"], cfg.n_classes
                )
                if cfg.conv == "nequip":
                    rng = np.random.default_rng((seed, step))
                    b["pos"] = rng.normal(size=(n, 3)).astype(np.float32) * 2
                    b["energy"] = rng.normal(size=(1,)).astype(np.float32)
                    b.pop("labels"), b.pop("label_mask")
            # conform to the bundle's padded specs
            out = {}
            for k, sds in specs.items():
                arr = b[k]
                pad = [(0, sds.shape[i] - arr.shape[i]) for i in range(arr.ndim)]
                out[k] = np.pad(arr, pad)[tuple(slice(0, s) for s in sds.shape)]
            return out

    elif cfg.family == "recsys":

        def fn(step):
            return synthetic.recsys_batch(
                seed, step, shape.dims["batch"], cfg.n_sparse,
                cfg.vocab_per_field, cfg.n_dense,
            )

    else:
        raise ValueError(f"no training loop for family {cfg.family}")
    return fn


def train(arch_id: str, shape_name: str, *, smoke: bool, steps: int,
          ckpt_dir: str | None, ckpt_every: int, seed: int = 0,
          fail_at: int | None = None) -> dict:
    bundle = arch_mod.build(arch_id, shape_name, smoke=smoke)
    assert bundle.shape.kind in ("train", "full_graph", "minibatch",
                                 "batched_graphs"), "not a training shape"
    params, opt_state = bundle.init(jax.random.key(seed))
    step_fn = jax.jit(bundle.step)
    start = 0

    ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    if ckpt is not None:
        restored = ckpt.restore_latest((params, opt_state))
        if restored is not None:
            (params, opt_state), manifest = restored
            start = manifest["step"] + 1
            print(f"restored checkpoint at step {manifest['step']}")

    batch_fn = make_batch_fn(bundle, seed)
    pipe = PrefetchPipeline(batch_fn, start_step=start)
    losses = []
    t0 = time.time()
    try:
        for step, batch in pipe:
            if step >= steps:
                break
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"injected failure at step {step}")
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % max(1, steps // 10) == 0:
                print(f"step {step}: loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f}")
            if ckpt is not None and step % ckpt_every == 0 and step > start:
                ckpt.save((params, opt_state), step=step)
    finally:
        pipe.close()
        if ckpt is not None:
            ckpt.wait()
    dt = time.time() - t0
    return dict(
        steps=len(losses), first_loss=losses[0] if losses else None,
        last_loss=losses[-1] if losses else None, seconds=dt,
        state=(params, opt_state),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (FT testing)")
    args = ap.parse_args()
    shape = args.shape or {
        "lm": "train_4k", "gnn": "full_graph_sm", "recsys": "train_batch",
    }[arch_mod.get_config(args.arch).family]
    out = train(
        args.arch, shape, smoke=args.smoke, steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        fail_at=args.fail_at,
    )
    print(f"trained {out['steps']} steps in {out['seconds']:.1f}s: "
          f"loss {out['first_loss']:.4f} -> {out['last_loss']:.4f}")


if __name__ == "__main__":
    main()
