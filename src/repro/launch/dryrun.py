import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST stay first: jax locks the device count on first
# initialization (which is why there is no `from __future__` here).

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape) cell on the 16x16 single-pod mesh and the
2x16x16 multi-pod mesh, print memory/cost analysis, and emit the roofline
records consumed by EXPERIMENTS.md.

The two lines above MUST stay first: jax locks the device count on first
initialization.

Methodology notes (see roofline/analysis.py):
* cost_analysis() is per-device and counts while bodies ONCE; scanned layer
  stacks are therefore measured by depth-delta extrapolation: compile the
  model at two small depths, extrapolate linearly per homogeneous stage
  (exact for scanned stacks), and take memory_analysis from the full-depth
  compile.
* collective bytes are parsed from optimized HLO with while-trip weighting.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro import arch as arch_mod
from repro.configs.base import ARCH_IDS, get_config, shapes_for
from repro.launch.mesh import HW, make_production_mesh
from repro.roofline import analysis as ra
from repro.utils.jaxcompat import set_mesh, specs_to_shardings


def abstract_state(bundle):
    """State as ShapeDtypeStructs without allocating anything."""
    try:
        return jax.eval_shape(bundle.init, jax.random.key(0))
    except Exception:
        # init already returns ShapeDtypeStructs (probesim at full scale)
        return bundle.init(jax.random.key(0))


def lower_and_compile(bundle, mesh):
    with set_mesh(mesh):
        state = abstract_state(bundle)
        state_specs = bundle.state_specs(state)
        in_shard = bundle.input_shardings()
        inputs = bundle.input_specs()
        input_order = list(inputs)
        jf = jax.jit(
            bundle.step,
            in_shardings=specs_to_shardings(
                (*state_specs, *(in_shard[k] for k in input_order)), mesh=mesh
            ),
        )
        t0 = time.time()
        lowered = jf.lower(*state, *(inputs[k] for k in input_order))
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    return compiled, dict(lower_s=t1 - t0, compile_s=t2 - t1)


def _depth_variants(cfg):
    """Two reduced-depth configs for delta extrapolation (per stage)."""
    if cfg.family != "lm":
        return None
    fd = cfg.moe.first_dense_layers if cfg.moe is not None else 0
    k1 = fd + 2
    k2 = fd + 3
    if cfg.n_layers <= k2:  # already shallow: no extrapolation needed
        return None
    # unrolled so cost_analysis sees every layer (scan bodies count once)
    mk = lambda k: dataclasses.replace(cfg, n_layers=k, scan_layers=False)
    return (k1, mk(k1)), (k2, mk(k2))


def run_cell(arch_id: str, shape_name: str, mesh_name: str, *,
             skip_full_compile: bool = False,
             overrides: dict | None = None) -> dict:
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = 512 if multi else 256
    applicable, why = arch_mod.is_applicable(arch_id, shape_name)
    record: dict = dict(arch=arch_id, shape=shape_name, mesh=mesh_name,
                        chips=chips, applicable=applicable)
    if not applicable:
        record["skip_reason"] = why
        # still attempt the compile as a bonus cell
    bundle = arch_mod.build(arch_id, shape_name)
    if overrides:
        top = {k: v for k, v in overrides.items() if "." not in k}
        moe_over = {k.split(".", 1)[1]: v for k, v in overrides.items()
                    if k.startswith("moe.")}
        cfg_o = dataclasses.replace(bundle.cfg, **top)
        if moe_over:
            cfg_o = dataclasses.replace(
                cfg_o, moe=dataclasses.replace(cfg_o.moe, **moe_over))
        # mesh context needed for probesim shard-count-dependent init
        with set_mesh(mesh):
            bundle = arch_mod.build_with_cfg(arch_id, cfg_o, bundle.shape)
        record["overrides"] = {k: str(v) for k, v in overrides.items()}
    cfg = bundle.cfg

    variants = _depth_variants(cfg)
    if variants is None:
        compiled, times = lower_and_compile(bundle, mesh)
        rep = ra.analyze(
            arch=arch_id, shape=shape_name, mesh_name=mesh_name, chips=chips,
            compiled=compiled, model_flops=bundle.model_flops(), hw=HW,
        )
        record.update(rep.to_dict(), **times)
        return record

    # depth-delta extrapolation for scanned LM stacks
    (k1, cfg1), (k2, cfg2) = variants
    shape = bundle.shape
    b1 = arch_mod.build_with_cfg(arch_id, cfg1, shape)
    b2 = arch_mod.build_with_cfg(arch_id, cfg2, shape)
    c1, t1 = lower_and_compile(b1, mesh)
    c2, t2 = lower_and_compile(b2, mesh)
    r1 = ra.analyze(arch=arch_id, shape=shape_name, mesh_name=mesh_name,
                    chips=chips, compiled=c1, model_flops=0.0, hw=HW)
    r2 = ra.analyze(arch=arch_id, shape=shape_name, mesh_name=mesh_name,
                    chips=chips, compiled=c2, model_flops=0.0, hw=HW)
    L = cfg.n_layers
    ext = lambda a, b: a + (b - a) * (L - k1) / (k2 - k1)
    rep = ra.RooflineReport(
        arch=arch_id, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=ext(r1.hlo_flops, r2.hlo_flops),
        hlo_bytes=ext(r1.hlo_bytes, r2.hlo_bytes),
        collective_bytes=ext(r1.collective_bytes, r2.collective_bytes),
        model_flops=bundle.model_flops(),
        collectives=dict(
            by_kind={
                k: ext(r1.collectives["by_kind"][k], r2.collectives["by_kind"][k])
                for k in r1.collectives["by_kind"]
            },
            counts=r2.collectives["counts"],
            total_bytes=ext(r1.collectives["total_bytes"],
                            r2.collectives["total_bytes"]),
        ),
    ).finalize(HW)
    record.update(rep.to_dict())
    record["extrapolated_from_depths"] = [k1, k2]
    record["lower_s"] = t1["lower_s"] + t2["lower_s"]
    record["compile_s"] = t1["compile_s"] + t2["compile_s"]

    if not skip_full_compile:
        # full-depth compile: proves the real cell compiles + true memory
        compiled, times = lower_and_compile(bundle, mesh)
        ma = compiled.memory_analysis()
        if ma is not None:
            record["memory_per_device"] = dict(
                argument_gb=ma.argument_size_in_bytes / 1e9,
                output_gb=ma.output_size_in_bytes / 1e9,
                temp_gb=ma.temp_size_in_bytes / 1e9,
                alias_gb=ma.alias_size_in_bytes / 1e9,
            )
        record["full_compile_s"] = times["compile_s"]
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-full-compile", action="store_true",
                    help="skip the full-depth compile (faster iteration)")
    ap.add_argument("--include-skipped", action="store_true",
                    help="also run inapplicable cells as bonus compiles")
    ap.add_argument("--set", nargs="*", default=[], metavar="K=V",
                    help="config overrides, e.g. push_mode=ring remat=False")
    ap.add_argument("--tag", default="",
                    help="suffix for output filenames (perf iterations)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        elif v.isdigit():
            v = int(v)
        else:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    os.makedirs(args.out, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in shapes_for(a):
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for a, s in cells:
        applicable, why = arch_mod.is_applicable(a, s)
        if not applicable and not args.include_skipped:
            print(f"SKIP {a} x {s}: {why}")
            rec = dict(arch=a, shape=s, applicable=False, skip_reason=why)
            with open(os.path.join(args.out, f"{a}__{s}__skip.json"), "w") as f:
                json.dump(rec, f, indent=2)
            continue
        for m in meshes:
            tag = f"{a}__{s}__{m}" + (f"__{args.tag}" if args.tag else "")
            t0 = time.time()
            try:
                rec = run_cell(a, s, m, skip_full_compile=args.skip_full_compile,
                               overrides=overrides or None)
                rec["wall_s"] = time.time() - t0
                with open(os.path.join(args.out, f"{tag}.json"), "w") as f:
                    json.dump(rec, f, indent=2, default=float)
                print(
                    f"OK   {tag}: flops/dev={rec.get('hlo_flops', 0):.3e} "
                    f"coll/dev={rec.get('collective_bytes', 0):.3e}B "
                    f"bottleneck={rec.get('bottleneck', '?')} "
                    f"({rec['wall_s']:.0f}s)"
                )
            except Exception as e:
                failures += 1
                print(f"FAIL {tag}: {e}")
                traceback.print_exc()
                with open(os.path.join(args.out, f"{tag}.FAILED.json"), "w") as f:
                    json.dump(dict(arch=a, shape=s, mesh=m, error=str(e)), f)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")
    print("dry-run complete")


if __name__ == "__main__":
    main()
