"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set XLA_FLAGS
before any jax initialization."""
from __future__ import annotations

import jax

from repro.utils.jaxcompat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = 1
    for s in shape:
        n *= s
    assert len(jax.devices()) >= n, f"need {n} devices"
    return make_mesh(shape, axes)


HW = dict(  # TPU v5e constants (per assignment)
    peak_flops_bf16=197e12,  # FLOP/s per chip
    hbm_bw=819e9,  # B/s per chip
    ici_bw=50e9,  # B/s per link
)
