"""Unified architecture API: one bundle per (arch x shape) cell.

``build(arch, shape_name, smoke=...)`` returns an ``ArchBundle`` exposing:

* ``init(key)``            -> state pytrees (params [+ opt state] or graph)
* ``input_specs()``        -> dict[name, ShapeDtypeStruct] for the step inputs
* ``step``                 -> the function to jit (train_step / serve_step)
* ``state_specs()/in_specs()/out_specs()`` -> PartitionSpecs for pjit
* ``model_flops()``        -> MODEL_FLOPS (6ND / 6 N_active D or family analogue)

This is the single surface consumed by launch/dryrun.py, launch/train.py,
launch/serve.py, the smoke tests and the benchmarks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (
    GNNConfig,
    ProbeSimConfig,
    RecsysConfig,
    ShapeSpec,
    TransformerConfig,
    get_config,
    shapes_for,
)
from repro.graph.sampler import block_shapes
from repro.models.common import resolve_axis
from repro.utils.jaxcompat import get_abstract_mesh
from repro.training.optimizer import AdamW, warmup_cosine_schedule

Array = jax.Array
SDS = jax.ShapeDtypeStruct


@dataclass
class ArchBundle:
    arch: str
    cfg: Any
    shape: ShapeSpec
    step: Callable  # fn(*state, **inputs) per family convention
    init: Callable  # fn(key) -> state tuple
    input_specs: Callable  # fn() -> dict[str, SDS]
    state_specs: Callable  # fn(state) -> specs pytree (same struct as state)
    input_shardings: Callable  # fn() -> dict[str, PartitionSpec]
    model_flops: Callable  # fn() -> float
    notes: str = ""


def _dp():
    return resolve_axis("dp")


def _tp():
    return resolve_axis("tp")


def _all_axes():
    axes = tuple(a for a in (_dp() if isinstance(_dp(), tuple) else (_dp(),))
                 if a) + ((_tp(),) if _tp() else ())
    flat = []
    for a in axes:
        if isinstance(a, tuple):
            flat.extend(a)
        elif a:
            flat.append(a)
    return tuple(flat) or None


def _extent(axes) -> int:
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty or axes is None:
        return 1
    out = 1
    for a in axes if isinstance(axes, tuple) else (axes,):
        out *= mesh.shape[a]
    return out


def _best_axes(dim: int, candidates=None):
    """Largest sharding (by extent) from a candidate list that divides dim.

    jit argument shardings REQUIRE even divisibility; this picks the widest
    legal layout and falls back to replication."""
    if candidates is None:
        candidates = [_all_axes(), _dp(), _tp(), None]
    best, best_e = None, 1
    for c in candidates:
        e = _extent(c)
        if c is not None and dim % e == 0 and e > best_e:
            best, best_e = c, e
    return best


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _make_optimizer(cfg) -> AdamW:
    state_dtype = jnp.bfloat16 if getattr(cfg, "param_dtype", "") == "bfloat16" else jnp.float32
    return AdamW(
        schedule=warmup_cosine_schedule(3e-4, 100, 10_000),
        state_dtype=state_dtype,
    )


def _opt_specs(param_specs):
    return dict(mu=param_specs, nu=param_specs, count=P())


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def _lm_bundle(arch: str, cfg: TransformerConfig, shape: ShapeSpec,
               use_kernel: bool = False) -> ArchBundle:
    from repro.models.transformer import model as M

    B = shape.dims["global_batch"]
    S = shape.dims["seq_len"]
    opt = _make_optimizer(cfg)

    def flops():
        if shape.kind == "train":
            return 6.0 * cfg.params_active * B * S
        if shape.kind == "prefill":
            return 2.0 * cfg.params_active * B * S
        # decode: one token per sequence + attention over the cache
        attn = 4.0 * B * S * cfg.n_heads * cfg.d_head
        return 2.0 * cfg.params_active * B + attn

    if shape.kind == "train":

        def step(params, opt_state, batch):
            from repro.training.step import make_train_step

            loss_fn = partial(M.lm_loss, cfg=cfg, use_kernel=use_kernel)
            ts = make_train_step(lambda p, b: loss_fn(p, b), opt,
                                 microbatches=getattr(cfg, "microbatches", 1))
            return ts(params, opt_state, batch)

        def init(key):
            params = M.init_lm(key, cfg)
            return (params, opt.init(params))

        def input_specs():
            return dict(
                batch=dict(
                    tokens=SDS((B, S), jnp.int32),
                    targets=SDS((B, S), jnp.int32),
                )
            )

        def input_shardings():
            ba = _best_axes(B, [_dp(), None])
            return dict(batch=dict(tokens=P(ba, None), targets=P(ba, None)))

        def state_specs(state):
            ps = M.param_specs(state[0], cfg)
            return (ps, _opt_specs(ps))

    elif shape.kind == "prefill":

        def step(params, batch):
            logits, _ = M.lm_forward(
                params, batch["tokens"], cfg, use_kernel=use_kernel,
                seq_shard=True, last_only=True,
            )
            return logits[:, 0]

        def init(key):
            return (M.init_lm(key, cfg),)

        def input_specs():
            return dict(batch=dict(tokens=SDS((B, S), jnp.int32)))

        def input_shardings():
            return dict(batch=dict(tokens=P(_best_axes(B, [_dp(), None]), None)))

        def state_specs(state):
            return (M.param_specs(state[0], cfg),)

    else:  # decode

        def step(params, caches, batch):
            caches, logits = M.lm_decode_step(
                params, caches, batch["tokens"], batch["positions"], cfg
            )
            return caches, logits

        def init(key):
            params = M.init_lm(key, cfg)
            caches = M.init_cache(cfg, B, S)
            return (params, caches)

        def input_specs():
            return dict(
                batch=dict(
                    tokens=SDS((B,), jnp.int32),
                    positions=SDS((B,), jnp.int32),
                )
            )

        def input_shardings():
            ba = _best_axes(B, [_dp(), None])
            return dict(batch=dict(tokens=P(ba), positions=P(ba)))

        def state_specs(state):
            return (
                M.param_specs(state[0], cfg),
                M.cache_specs(state[1], cfg),
            )

    return ArchBundle(
        arch=arch, cfg=cfg, shape=shape, step=step, init=init,
        input_specs=input_specs, state_specs=state_specs,
        input_shardings=input_shardings, model_flops=flops,
    )


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


def _gnn_batch_shapes(cfg: GNNConfig, shape: ShapeSpec) -> dict:
    d = shape.dims
    if shape.kind == "full_graph":
        N, E, df = d["n_nodes"], d["n_edges"], d["d_feat"]
        G = 1
    elif shape.kind == "minibatch":
        bs = block_shapes(d["batch_nodes"], tuple(d["fanout"]))
        N, E, df = bs["table"], sum(bs["edges"]), d["d_feat"]
        G = 1
    else:  # batched_graphs (molecule)
        N = d["n_nodes"] * d["batch"]
        E = d["n_edges"] * d["batch"]
        df = d["d_feat"]
        G = d["batch"]
    # pad to 8192 so jit argument shardings divide every mesh extent
    # (padding rows/edges are sentinel-masked by the layers)
    if N > 8192:
        N = _pad_to(N, 8192)
    if E > 8192:
        E = _pad_to(E, 8192)
    return dict(N=N, E=E, df=df, G=G)


def _gnn_bundle(arch: str, cfg: GNNConfig, shape: ShapeSpec) -> ArchBundle:
    from repro.models.gnn.model import gnn_loss, init_gnn
    from repro.training.step import make_train_step

    s = _gnn_batch_shapes(cfg, shape)
    N, E, df, G = s["N"], s["E"], s["df"], s["G"]
    opt = _make_optimizer(cfg)
    is_nequip = cfg.conv == "nequip"
    batched = shape.kind == "batched_graphs"

    def loss_fn(params, batch):
        return gnn_loss(params, batch, cfg, n_graphs=G)

    def step(params, opt_state, batch):
        ts = make_train_step(loss_fn, opt)
        return ts(params, opt_state, batch)

    def init(key):
        params = init_gnn(key, cfg, df)
        return (params, opt.init(params))

    def input_specs():
        b = dict(
            feats=SDS((N, df), jnp.float32),
            src=SDS((E,), jnp.int32),
            dst=SDS((E,), jnp.int32),
            mask=SDS((E,), jnp.bool_),
        )
        if is_nequip:
            b["pos"] = SDS((N, 3), jnp.float32)
            b["energy"] = SDS((G,), jnp.float32)
            if batched:
                b["graph_ids"] = SDS((N,), jnp.int32)
        else:
            if batched:
                b["graph_ids"] = SDS((N,), jnp.int32)
                b["labels"] = SDS((G,), jnp.int32)
                b["label_mask"] = SDS((G,), jnp.float32)
            else:
                b["labels"] = SDS((N,), jnp.int32)
                b["label_mask"] = SDS((N,), jnp.float32)
        return dict(batch=b)

    def input_shardings():
        if getattr(cfg, "node_shard", "all") == "model":
            na = _best_axes(N, [_tp(), None])
            ea = _best_axes(E, [_tp(), None])
        else:
            na = _best_axes(N)
            ea = _best_axes(E)
        ga = _best_axes(G, [_dp(), None])
        b = dict(
            feats=P(na, None),
            src=P(ea),
            dst=P(ea),
            mask=P(ea),
        )
        if is_nequip:
            b["pos"] = P(na, None)
            b["energy"] = P(ga)
            if batched:
                b["graph_ids"] = P(na)
        else:
            if batched:
                b["graph_ids"] = P(na)
                b["labels"] = P(ga)
                b["label_mask"] = P(ga)
            else:
                b["labels"] = P(na)
                b["label_mask"] = P(na)
        return dict(batch=b)

    def state_specs(state):
        ps = jax.tree_util.tree_map(lambda p: P(*([None] * p.ndim)), state[0])
        return (ps, _opt_specs(ps))

    def flops():
        d = cfg.d_hidden
        # messages ~ 2 E d, transforms ~ 2 N d^2 per layer (x3 for train)
        per_layer = 2.0 * E * d + 2.0 * N * d * d
        if is_nequip:
            per_layer = 16 * 2.0 * E * d * 9 + 2.0 * N * d * d * 9
        return 3.0 * cfg.n_layers * per_layer

    return ArchBundle(
        arch=arch, cfg=cfg, shape=shape, step=step, init=init,
        input_specs=input_specs, state_specs=state_specs,
        input_shardings=input_shardings, model_flops=flops,
    )


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------


def _recsys_bundle(arch: str, cfg: RecsysConfig, shape: ShapeSpec) -> ArchBundle:
    from repro.models.recsys.widedeep import (
        init_widedeep,
        retrieval_scores,
        widedeep_forward,
        widedeep_loss,
    )
    from repro.training.step import make_train_step

    d = shape.dims
    B = d.get("batch", 1)
    opt = _make_optimizer(cfg)

    def param_sharding(params):
        def spec(path, leaf):
            key = getattr(path[-1], "key", None)
            if key == "embed":  # [F, V, D] -> vocab rows over model
                return P(None, _tp(), None)
            if key == "wide":  # [F, V]
                return P(None, _tp())
            if key == "w" and leaf.ndim == 2:
                return P(None, _tp()) if leaf.shape[1] >= 256 else P(None, None)
            return P(*([None] * leaf.ndim))

        return jax.tree_util.tree_map_with_path(spec, params)

    if shape.kind == "train":

        def step(params, opt_state, batch):
            ts = make_train_step(lambda p, b: widedeep_loss(p, b, cfg), opt)
            return ts(params, opt_state, batch)

        def init(key):
            params = init_widedeep(key, cfg)
            return (params, opt.init(params))

        def input_specs():
            return dict(
                batch=dict(
                    sparse_ids=SDS((B, cfg.n_sparse), jnp.int32),
                    dense=SDS((B, cfg.n_dense), jnp.float32),
                    labels=SDS((B,), jnp.int32),
                )
            )

        def input_shardings():
            ba = _best_axes(B, [_dp(), None])
            return dict(batch=dict(
                sparse_ids=P(ba, None), dense=P(ba, None), labels=P(ba),
            ))

        def state_specs(state):
            ps = param_sharding(state[0])
            return (ps, _opt_specs(ps))

    elif shape.kind == "serve":

        def step(params, batch):
            return widedeep_forward(params, batch, cfg)

        def init(key):
            return (init_widedeep(key, cfg),)

        def input_specs():
            return dict(
                batch=dict(
                    sparse_ids=SDS((B, cfg.n_sparse), jnp.int32),
                    dense=SDS((B, cfg.n_dense), jnp.float32),
                )
            )

        def input_shardings():
            ba = _best_axes(B, [_dp(), None])
            return dict(batch=dict(sparse_ids=P(ba, None), dense=P(ba, None)))

        def state_specs(state):
            return (param_sharding(state[0]),)

    else:  # retrieval

        nc = _pad_to(d["n_candidates"], 8192) if d["n_candidates"] > 8192 else d["n_candidates"]

        def step(params, batch):
            scores = retrieval_scores(params, batch, cfg)
            return jax.lax.top_k(scores, 100)

        def init(key):
            return (init_widedeep(key, cfg),)

        def input_specs():
            return dict(
                batch=dict(
                    sparse_ids=SDS((B, cfg.n_sparse), jnp.int32),
                    dense=SDS((B, cfg.n_dense), jnp.float32),
                    cand_ids=SDS((nc,), jnp.int32),
                )
            )

        def input_shardings():
            return dict(batch=dict(
                sparse_ids=P(None, None), dense=P(None, None),
                cand_ids=P(_best_axes(nc)),
            ))

        def state_specs(state):
            return (param_sharding(state[0]),)

    def flops():
        mlp_flops = 0
        d_in = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
        for w in cfg.mlp:
            mlp_flops += 2 * d_in * w
            d_in = w
        mult = 3.0 if shape.kind == "train" else 1.0
        per_ex = mlp_flops + 2 * cfg.n_sparse * cfg.embed_dim
        total = mult * B * per_ex
        if shape.kind == "retrieval":
            total += 2.0 * d["n_candidates"] * cfg.embed_dim
        return total

    return ArchBundle(
        arch=arch, cfg=cfg, shape=shape, step=step, init=init,
        input_specs=input_specs, state_specs=state_specs,
        input_shardings=input_shardings, model_flops=flops,
    )


# ---------------------------------------------------------------------------
# ProbeSim family (the paper)
# ---------------------------------------------------------------------------


def _probesim_bundle(arch: str, cfg: ProbeSimConfig, shape: ShapeSpec) -> ArchBundle:
    from repro.core.distributed import (
        ShardedGraph,
        build_sharded_graph,
        graph_specs,
        make_serve_step,
    )
    from repro.core.params import make_params
    from repro.core.ring import (
        build_ring_graph,
        make_ring_serve_step,
        ring_graph_abstract,
        ring_graph_specs,
    )

    d = shape.dims
    Q = d["queries"]
    Bw = d["walk_chunk"]
    params = make_params(cfg.n, c=cfg.c, eps_a=cfg.eps_a, delta=cfg.delta)
    L = params.max_len
    n_pad_mult = 16 * 8
    m_pad_mult = 512 * 8  # divisible by all device counts x edge chunks
    ring = cfg.push_mode == "ring"
    fdt = jnp.bfloat16 if cfg.frontier_dtype == "bfloat16" else jnp.float32

    if ring:
        serve = make_ring_serve_step(cfg, queries=Q, walk_chunk=Bw,
                                     max_len=L, frontier_dtype=fdt)
    else:
        serve = make_serve_step(cfg, queries=Q, walk_chunk=Bw, max_len=L,
                                edge_chunks=8)

    def step(graph, batch):
        return serve(graph, batch["queries"], batch["key"])

    def init(key):
        # dry-run scale: build abstract graph (ShapeDtypeStructs); smoke
        # configs are small enough to build a real synthetic graph.
        shards = max(_extent(_tp()), 1)
        if cfg.n <= 100_000:
            from repro.graph.generators import powerlaw_graph

            src, dst, n = powerlaw_graph(cfg.n, cfg.m, seed=0)
            if ring:
                return (build_ring_graph(src, dst, n, shards=shards),)
            return (build_sharded_graph(src, dst, n, pad_nodes=n_pad_mult,
                                        pad_edges=m_pad_mult),)
        if ring:
            # bucket padding: expected m/S^2 per bucket, 1.5x skew slack
            # (production rebalances hub destinations across buckets)
            e_max = -(-cfg.m * 3 // (2 * shards * shards) // 8) * 8
            return (ring_graph_abstract(cfg.n, cfg.m, shards, e_max),)
        n_pad = -(-cfg.n // n_pad_mult) * n_pad_mult
        m_pad = -(-cfg.m // m_pad_mult) * m_pad_mult
        return (ShardedGraph(
            indptr=SDS((n_pad,), jnp.int32),
            in_deg=SDS((n_pad,), jnp.int32),
            indices=SDS((m_pad,), jnp.int32),
            src=SDS((m_pad,), jnp.int32),
            dst=SDS((m_pad,), jnp.int32),
            n=cfg.n, n_pad=n_pad, m=cfg.m, m_pad=m_pad,
        ),)

    def input_specs():
        return dict(batch=dict(
            queries=SDS((Q,), jnp.int32),
            key=SDS((2,), jnp.uint32),
        ))

    def input_shardings():
        return dict(batch=dict(queries=P(), key=P()))

    def state_specs(state):
        if ring:
            return (ring_graph_specs(state[0]),)
        return (graph_specs(state[0]),)

    def flops():
        # telescoped probe: (L-1) pushes x 2 flops/edge/column
        return 2.0 * cfg.m * Q * Bw * (L - 1)

    return ArchBundle(
        arch=arch, cfg=cfg, shape=shape, step=step, init=init,
        input_specs=input_specs, state_specs=state_specs,
        input_shardings=input_shardings, model_flops=flops,
        notes=f"n_r={params.n_r} walks/query; this step covers {Bw} of them",
    )


# ---------------------------------------------------------------------------


def build(arch: str, shape_name: str, *, smoke: bool = False,
          use_kernel: bool = False) -> ArchBundle:
    cfg = get_config(arch, smoke=smoke)
    shape = next(s for s in shapes_for(arch) if s.name == shape_name)
    if smoke:
        shape = _shrink_shape(cfg, shape)
    return build_with_cfg(arch, cfg, shape, use_kernel=use_kernel)


def build_with_cfg(arch: str, cfg, shape: ShapeSpec, *,
                   use_kernel: bool = False) -> ArchBundle:
    """Build a bundle for an explicit config (depth-extrapolation dry-runs)."""
    if cfg.family == "lm":
        return _lm_bundle(arch, cfg, shape, use_kernel=use_kernel)
    if cfg.family == "gnn":
        return _gnn_bundle(arch, cfg, shape)
    if cfg.family == "recsys":
        return _recsys_bundle(arch, cfg, shape)
    if cfg.family == "probesim":
        return _probesim_bundle(arch, cfg, shape)
    raise ValueError(cfg.family)


def _shrink_shape(cfg, shape: ShapeSpec) -> ShapeSpec:
    d = dict(shape.dims)
    if cfg.family == "lm":
        d.update(seq_len=min(d["seq_len"], 64), global_batch=min(d["global_batch"], 2))
    elif cfg.family == "gnn":
        if shape.kind == "full_graph":
            d.update(n_nodes=128, n_edges=512, d_feat=24)
        elif shape.kind == "minibatch":
            d.update(n_nodes=256, n_edges=2048, batch_nodes=8, fanout=(3, 2), d_feat=24)
        else:
            d.update(batch=4, n_nodes=10, n_edges=20, d_feat=8)
    elif cfg.family == "recsys":
        d.update(batch=min(d.get("batch", 1), 32))
        if "n_candidates" in d:
            d["n_candidates"] = 512
    elif cfg.family == "probesim":
        d.update(queries=2, walk_chunk=16)
    return ShapeSpec(shape.name, shape.kind, d)


def is_applicable(arch: str, shape_name: str) -> tuple[bool, str]:
    """Cell applicability (DESIGN.md §Arch-applicability / long_500k rule)."""
    cfg = get_config(arch)
    if cfg.family == "lm" and shape_name == "long_500k":
        return (
            False,
            "pure full-attention arch: long_500k skipped per assignment "
            "(decode itself is O(seq); reported as bonus cell)",
        )
    return True, ""
