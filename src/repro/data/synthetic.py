"""Deterministic synthetic batch generators for every family.

Determinism matters for fault tolerance: any host can regenerate any batch
from (seed, step), so restart-after-failure needs no data-state beyond the
step counter (checkpointed)."""
from __future__ import annotations

import numpy as np


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int) -> dict:
    rng = np.random.default_rng((seed, step))
    # a Zipf token stream with some local structure (repeated n-grams)
    toks = rng.zipf(1.3, size=(batch, seq + 1)).clip(0, vocab - 1)
    return dict(
        tokens=toks[:, :-1].astype(np.int32),
        targets=toks[:, 1:].astype(np.int32),
    )


def gnn_full_graph_batch(
    seed: int, n: int, m: int, d_feat: int, n_classes: int
) -> dict:
    from repro.graph.generators import powerlaw_graph

    rng = np.random.default_rng(seed)
    src, dst, n = powerlaw_graph(n, m, seed=seed)
    e = len(src)
    pad = m - e
    return dict(
        feats=rng.normal(size=(n, d_feat)).astype(np.float32),
        src=np.concatenate([src, np.full(pad, n - 1, np.int32)]).astype(np.int32),
        dst=np.concatenate([dst, np.full(pad, n - 1, np.int32)]).astype(np.int32),
        mask=np.concatenate([np.ones(e, bool), np.zeros(pad, bool)]),
        labels=rng.integers(0, n_classes, n).astype(np.int32),
        label_mask=np.ones(n, np.float32),
    )


def molecule_batch(
    seed: int, step: int, batch: int, nodes: int, edges: int, d_feat: int,
    with_pos: bool = True,
) -> dict:
    rng = np.random.default_rng((seed, step))
    N, E = batch * nodes, batch * edges
    offs = np.repeat(np.arange(batch) * nodes, edges)
    src = rng.integers(0, nodes, E) + offs
    dst = rng.integers(0, nodes, E) + offs
    out = dict(
        feats=rng.normal(size=(N, d_feat)).astype(np.float32),
        src=src.astype(np.int32),
        dst=dst.astype(np.int32),
        mask=np.ones(E, bool),
        graph_ids=np.repeat(np.arange(batch), nodes).astype(np.int32),
    )
    if with_pos:
        out["pos"] = (rng.normal(size=(N, 3)) * 2.0).astype(np.float32)
        out["energy"] = rng.normal(size=(batch,)).astype(np.float32)
    else:
        out["labels"] = rng.integers(0, 2, batch).astype(np.int32)
        out["label_mask"] = np.ones(batch, np.float32)
    return out


def recsys_batch(
    seed: int, step: int, batch: int, n_sparse: int, vocab: int, n_dense: int
) -> dict:
    rng = np.random.default_rng((seed, step))
    ids = rng.zipf(1.2, size=(batch, n_sparse)).clip(0, vocab - 1)
    return dict(
        sparse_ids=ids.astype(np.int32),
        dense=rng.normal(size=(batch, n_dense)).astype(np.float32),
        labels=rng.integers(0, 2, batch).astype(np.int32),
    )
