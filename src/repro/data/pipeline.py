"""Host data pipeline: background prefetch + device put, deterministic cursor.

Double-buffered: batch t+1 is generated (and transferred) while step t
computes — the standard input-pipeline/compute overlap."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax


class PrefetchPipeline:
    def __init__(
        self,
        make_batch: Callable[[int], dict],  # step -> host batch
        start_step: int = 0,
        prefetch: int = 2,
        sharding=None,
    ):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._sharding = sharding
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make(step)
            if self._sharding is not None:
                batch = jax.device_put(batch, self._sharding)
            try:
                self._q.put((step, batch), timeout=1.0)
            except queue.Full:
                if self._stop.is_set():
                    return
                continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while not self._stop.is_set():
            yield self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
