"""`GraphHandle` — ONE object owning the coordinated COO + ELL mirror pair.

ProbeSim needs the graph twice: the COO ``Graph`` is the *push*
representation (a PROBE level is a segment-sum scatter) and the ELL
``EllGraph`` is the *gather* representation (TPU-friendly SpMM; also O(1)
in-neighbor sampling for sqrt(c)-walks).  The seed API made every caller
thread the ``(g, eg)`` pair by hand through construction, queries, updates
and regrow — five call sites per benchmark, each a chance to desynchronize
the mirrors or silently pass the wrong one (``single_source_simple`` did
exactly that).

``GraphHandle`` owns both mirrors plus the dynamic-graph snapshot metadata
(``version``, ``overflow``) and the recovery path (``regrow``):

    h = GraphHandle.from_edges(src, dst, n, capacity=m + 1024, k_max=64)
    h.apply_batch(batch)      # coordinated update of BOTH mirrors
    if h.overflow:
        h.regrow()            # compaction + 2x buffers, clears the flag

The handle is a host-side *mutable* owner: ``apply_batch``/``regrow``
replace the (immutable, jit-ready) mirror pytrees in place, so one name
always refers to the current snapshot.  The mirrors themselves stay frozen
``@struct`` pytrees — pass ``h.g`` / ``h.eg`` to jitted code as before.
``SimRankSession`` (repro.api.session) is the query/update surface over a
handle; direct mirror access is the escape hatch for baselines and kernels.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.graph.dynamic import (
    UpdateBatch,
    apply_update_batch_jit,
    regrow as _regrow,
)
from repro.graph.structs import (
    EllGraph,
    Graph,
    ell_from_edges,
    graph_from_edges,
    graph_to_host_edges,
)

Array = jax.Array


@dataclasses.dataclass
class GraphHandle:
    """Owner of the coordinated ``(Graph, EllGraph)`` mirror pair.

    Construct via :meth:`from_edges` (one call builds both mirrors from the
    same edge list) or directly from an existing pair; ``__post_init__``
    normalizes legacy mirrors (``version``/``overflow`` = None) to concrete
    snapshot scalars so the dynamic update paths can thread them.
    """

    g: Graph
    eg: EllGraph

    def __post_init__(self) -> None:
        if self.g.n != self.eg.n:
            raise ValueError(
                f"mirror mismatch: COO n={self.g.n} vs ELL n={self.eg.n}"
            )
        if self.g.version is None:
            self.g = self.g.replace(
                version=jnp.asarray(0, jnp.int32), overflow=jnp.asarray(False)
            )
        if self.eg.version is None:
            self.eg = self.eg.replace(
                version=jnp.asarray(0, jnp.int32), overflow=jnp.asarray(False)
            )

    # -- construction --------------------------------------------------------

    @classmethod
    def from_edges(
        cls,
        src: np.ndarray,
        dst: np.ndarray,
        n: int,
        *,
        capacity: int | None = None,
        k_max: int | None = None,
    ) -> "GraphHandle":
        """Build BOTH mirrors from one host edge list.

        ``capacity`` (COO buffer) and ``k_max`` (ELL row width) reserve
        headroom for dynamic insertions — pass them whenever the graph will
        mutate.  Defaults match the bare constructors (exact fit), so a
        handle built without headroom is bit-identical to the legacy
        ``graph_from_edges`` + ``ell_from_edges`` pair.
        """
        return cls(
            g=graph_from_edges(src, dst, n, capacity=capacity),
            eg=ell_from_edges(src, dst, n, k_max=k_max),
        )

    def copy(self) -> "GraphHandle":
        """Deep device copy (buffers nobody else references).

        ``SimRankSession`` own-copies its handle at construction because the
        fused epoch step *donates* the mirror buffers.
        """
        return GraphHandle(
            g=jax.tree.map(lambda a: jnp.array(a, copy=True), self.g),
            eg=jax.tree.map(lambda a: jnp.array(a, copy=True), self.eg),
        )

    # -- snapshot metadata ---------------------------------------------------

    @property
    def n(self) -> int:
        return self.g.n

    @property
    def capacity(self) -> int:
        return self.g.capacity

    @property
    def k_max(self) -> int:
        return self.eg.k_max

    @property
    def num_edges(self) -> int:
        return int(self.g.num_edges)

    @property
    def version(self) -> int:
        """Snapshot id: +1 per applied update batch (mirrors in lockstep)."""
        return int(self.eg.version) if self.eg.version is not None else -1

    @property
    def overflow(self) -> bool:
        """Sticky capacity signal; cleared only by :meth:`regrow`."""
        return bool(self.g.overflow) if self.g.overflow is not None else False

    # -- updates -------------------------------------------------------------

    def apply_batch(self, batch: UpdateBatch) -> Array:
        """Apply a padded update batch to BOTH mirrors (coordinated path).

        Replaces the owned mirrors with the post-batch snapshot and returns
        the per-op ``applied`` mask.  An insert applies iff both mirrors
        have room; skips set the sticky ``overflow`` flag (never a silent
        drop) — see graph/dynamic.py for the full contracts.
        """
        self.g, self.eg, applied = apply_update_batch_jit(self.g, self.eg, batch)
        return applied

    def regrow(
        self,
        *,
        capacity: int | None = None,
        k_max: int | None = None,
        growth: float = 2.0,
    ) -> None:
        """Compact live edges and rebuild both mirrors with headroom.

        Preserves ``version`` (a representation change is not a graph
        change) and clears ``overflow`` on both mirrors.
        """
        self.g, self.eg = _regrow(
            self.g, self.eg, capacity=capacity, k_max=k_max, growth=growth
        )

    def to_host_edges(self) -> tuple[np.ndarray, np.ndarray]:
        """The live (non-padding) edge list on host — rebuild/IO escape hatch."""
        return graph_to_host_edges(self.g)

    def shard(
        self,
        *,
        shards: int | None = None,
        mesh=None,
        capacity_per_shard: int | None = None,
    ):
        """Destination-partitioned mirror of this handle's live edges.

        Returns a :class:`repro.api.backend.ShardedGraphState`: per-shard
        host edge buffers (``partition_edges_by_dst`` layout, plus
        capacity headroom matching this handle's spare COO capacity) from
        which the device-resident sharded mirrors are built lazily.  The
        state starts at this handle's ``version`` and keeps
        ``to_host_edges``/``version`` coherent through its own shard-wise
        updates; it does NOT track later mutations of this handle — it is
        a placement of the current snapshot, exactly like ``copy()`` is.

        ``shards`` defaults to the ``model`` extent of ``mesh`` (or the
        local device count when neither is given).
        """
        from repro.api.backend import ShardedGraphState

        if shards is None:
            if mesh is not None and "model" in mesh.axis_names:
                shards = int(mesh.shape["model"])
            else:
                shards = max(len(jax.devices()), 1)
        src, dst = self.to_host_edges()
        if capacity_per_shard is None and self.capacity > len(src):
            # carry the handle's insertion headroom over, spread per shard
            from repro.graph.partition import pad_to_multiple

            rows = pad_to_multiple(self.n, shards) // shards
            per_shard_live = (
                int(np.bincount(dst // rows, minlength=shards).max())
                if len(dst) else 0
            )
            spare = self.capacity - len(src)
            capacity_per_shard = per_shard_live + max(spare // shards, 1)
        return ShardedGraphState(
            src, dst, self.n,
            shards=shards,
            capacity_per_shard=capacity_per_shard,
            version=self.version,
        )

    def set_mirrors(
        self,
        g: Graph | None = None,
        eg: EllGraph | None = None,
        *,
        copy: bool = True,
    ) -> None:
        """Replace owned mirror(s) with externally-built ones, safely.

        Validates ``n``, normalizes missing snapshot fields, and (by
        default) own-copies the buffers — a handle driven by donated epoch
        steps must never share arrays with the caller, or donation would
        invalidate the caller's copies.  Direct field assignment skips all
        of this; use it only with buffers the handle may own outright.
        """
        if g is not None:
            if g.n != self.n:
                raise ValueError(f"COO mirror n={g.n} != handle n={self.n}")
            if g.version is None:
                g = g.replace(
                    version=jnp.asarray(0, jnp.int32),
                    overflow=jnp.asarray(False),
                )
            self.g = (
                jax.tree.map(lambda a: jnp.array(a, copy=True), g) if copy else g
            )
        if eg is not None:
            if eg.n != self.n:
                raise ValueError(f"ELL mirror n={eg.n} != handle n={self.n}")
            if eg.version is None:
                eg = eg.replace(
                    version=jnp.asarray(0, jnp.int32),
                    overflow=jnp.asarray(False),
                )
            self.eg = (
                jax.tree.map(lambda a: jnp.array(a, copy=True), eg) if copy else eg
            )
