"""repro.api — the one session surface over the live graph.

``GraphHandle`` owns the coordinated COO+ELL mirror pair (construction,
updates, regrow, snapshot metadata, mesh placement via ``shard()``);
``QuerySpec`` / ``ResultEnvelope`` are the typed request/response pair;
``SimRankSession`` is the single entrypoint unifying one-shot queries,
queued fused serving (``submit`` -> ``QueryTicket``; ``drain``),
immediate updates and fused update->query epochs.  Execution is
pluggable through ``repro.api.backend``: ``LocalBackend`` (single-device
fused path) and ``ShardedBackend`` (mesh-sharded execution) sit behind
the same contract.  The legacy engines in ``repro.serving`` are
deprecation shims over this package.
"""
from repro.api.backend import (
    Backend,
    LocalBackend,
    ShardedBackend,
    ShardedGraphState,
)
from repro.api.handle import GraphHandle
from repro.api.session import (
    EngineStats,
    EpochResult,
    QueryTicket,
    SimRankSession,
    UpdateReport,
)
from repro.api.spec import QuerySpec, ResultEnvelope, as_spec
from repro.core.params import abs_error_bound

__all__ = [
    "GraphHandle",
    "QuerySpec",
    "ResultEnvelope",
    "as_spec",
    "SimRankSession",
    "EngineStats",
    "EpochResult",
    "UpdateReport",
    "QueryTicket",
    "Backend",
    "LocalBackend",
    "ShardedBackend",
    "ShardedGraphState",
    "abs_error_bound",
]
