"""repro.api — the one session surface over the live graph.

``GraphHandle`` owns the coordinated COO+ELL mirror pair (construction,
updates, regrow, snapshot metadata); ``QuerySpec`` / ``ResultEnvelope``
are the typed request/response pair; ``SimRankSession`` is the single
entrypoint unifying one-shot queries, queued fused serving, immediate
updates and fused update->query epochs.  The legacy engines in
``repro.serving`` are deprecation shims over this package.
"""
from repro.api.handle import GraphHandle
from repro.api.session import (
    EngineStats,
    EpochResult,
    SimRankSession,
    UpdateReport,
)
from repro.api.spec import QuerySpec, ResultEnvelope, as_spec
from repro.core.params import abs_error_bound

__all__ = [
    "GraphHandle",
    "QuerySpec",
    "ResultEnvelope",
    "as_spec",
    "SimRankSession",
    "EngineStats",
    "EpochResult",
    "UpdateReport",
    "abs_error_bound",
]
