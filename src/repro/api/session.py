"""`SimRankSession` — the single query/update surface over a live graph.

ProbeSim's selling point is that index-free queries and graph updates are
the *same* object: a query runs against whatever the graph is NOW.  The
seed split that story across five query signatures, two engines with
incompatible result types, and a ``(g, eg)`` mirror pair every caller
threaded by hand.  The session unifies all of it:

    h = GraphHandle.from_edges(src, dst, n, capacity=m + 4096, k_max=64)
    sess = SimRankSession(h, eps_a=0.1, top_k=10, batch_q=8)

    env = sess.query(QuerySpec(kind="topk", node=u))     # one-shot
    for u in nodes:
        sess.submit(u)                                   # queued ...
    results = sess.drain(budget_walks=512)               # ... fused batches

    sess.update(inserts=(new_src, new_dst))              # apply NOW
    ep = sess.epoch(inserts=(s, d), queries=[u1, u2])    # fused upd->query

Three dispatch paths, one surface (each preserves its legacy engine's exact
PRNG and shape semantics — the deprecation shims in repro.serving delegate
here and are bit-identical to their pre-session behavior):

* ``query(spec)`` — one-shot, delegates to the core entry points
  (``single_source``/``topk``/``multi_source*``), so a spec with an
  explicit ``key`` is bit-identical to the legacy call under that key;
* ``submit``/``drain`` — the serving path: per-query PRNG streams assigned
  at submit time, fixed-size repeat-padded batches through the fused
  multi-query step (one compiled dispatch per batch); ``submit`` returns
  a :class:`QueryTicket` for async consumption (``poll``/``result``) —
  ``drain`` is the synchronous collect-everything special case;
* ``update``/``epoch`` — updates applied through the coordinated
  both-mirrors path; ``epoch`` fuses one update batch + one query batch
  into a single jitted step with zero host transfers in between, and
  auto-regrows on capacity overflow (nothing is ever silently dropped).

Execution is pluggable (repro.api.backend): the session owns specs, PRNG
streams, queues/tickets, stats and envelopes, and dispatches through a
``Backend`` — ``LocalBackend`` (the single-device fused path above,
bit-identical to the pre-backend session) or ``ShardedBackend`` (the
same contract over a device mesh).  The fused epoch is a Backend stage
too (``core.epoch``): local epochs donate the session-owned mirror pair,
mesh epochs update device-resident shard buffers inside a shard_map step
— both with zero host transfers between update and query.

The §4.4 "best of both worlds" switch lives in the session *planner*
(:meth:`plan`): ``variant='auto'`` picks the deterministic prefix-tree
probe when the walk pool shares prefixes heavily (n_r >> in-degree of the
query node — the host-static analogue of the paper's per-level cost
comparison) and the fused telescoped path otherwise; batched specs always
take the fused path (it is the only batched one).

Every result is a ``ResultEnvelope`` carrying the graph ``version`` it was
computed against, the walk budget actually spent, and the Thm-1/2 error
bound evaluated at that effective budget.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.api.backend import Backend, LocalBackend, ShardedBackend
from repro.api.handle import GraphHandle
from repro.api.spec import QuerySpec, ResultEnvelope, as_spec
from repro.core.accuracy import (
    AccuracyController,
    ProbeCache,
    escalation_schedule,
)
from repro.core.epoch import epoch_step  # noqa: F401  (re-exported: the
#   fused local epoch step now lives in core/epoch.py; legacy importers —
#   serving.dynamic_engine among them — keep finding it here)
from repro.core.params import ProbeSimParams, abs_error_bound, make_params
from repro.graph.dynamic import UpdateBatch, make_update_batch

Array = jax.Array


@dataclass
class EngineStats:
    """Dispatch counters, threaded through every session path.

    ``queries``/``updates`` count logical work (queries answered, edge ops
    applied); ``steps`` counts fused serve dispatches, ``epochs`` fused
    update->query epochs, ``regrows`` capacity recoveries, ``retries``
    straggler re-dispatches (incremented by serving.straggler callers);
    ``escalations`` counts accuracy-controller rounds beyond the first
    (extra dispatches adaptive queries paid), ``hub_hits`` whole serve
    dispatches skipped because every row of an escalation round was
    already in the hub probe cache.
    """

    queries: int = 0
    updates: int = 0
    steps: int = 0
    retries: int = 0
    epochs: int = 0
    regrows: int = 0
    escalations: int = 0
    hub_hits: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclass
class QueryTicket:
    """Async handle for one submitted query.

    ``submit()`` fixes the query's PRNG stream and returns a ticket;
    the answer materializes when a drain/epoch serves the ticket's batch.
    ``poll()`` is the non-blocking check (None while pending); ``result()``
    forces service — it drains queued batches (in submission order, so
    earlier tickets resolve on the way) until this ticket is answered.
    ``drain()`` remains the synchronous serve-everything special case.
    """

    spec: QuerySpec
    seq: int  # session submission sequence number (the PRNG stream id)
    _session: "SimRankSession" = field(repr=False, default=None)
    envelope: ResultEnvelope | None = field(default=None, repr=False)

    @property
    def done(self) -> bool:
        return self.envelope is not None

    def poll(self) -> ResultEnvelope | None:
        """The envelope if this ticket has been served, else None."""
        return self.envelope

    def result(self, *, budget_walks: int | None = None) -> ResultEnvelope:
        """Block until served: runs queued batches up to this ticket."""
        if self.envelope is None:
            self._session._drain_until(self, budget_walks=budget_walks)
        return self.envelope


@dataclass
class UpdateReport:
    """Outcome of one immediate ``update()`` call."""

    submitted: int = 0
    applied: int = 0
    regrows: int = 0
    # overflow-skipped inserts, as (src, dst, True) tuples — only populated
    # when auto_regrow=False (with it, skips are regrown and retried here)
    skipped: list = field(default_factory=list)
    version: int = -1
    overflow: bool = False


@dataclass
class EpochResult:
    """Outcome of one fused update→query epoch."""

    version: int  # graph snapshot id AFTER the update batch
    overflow: bool  # sticky capacity signal (pre-regrow value)
    regrown: bool  # True if auto_regrow ran after this epoch
    updates_submitted: int  # live (non-padding) ops in the batch
    updates_applied: int  # ops that changed the graph
    updates_requeued: int  # overflow-skipped inserts pushed back for retry
    # overflow-skipped inserts this epoch, as (src, dst, True) tuples.  With
    # auto_regrow they are also re-queued (updates_requeued); without, the
    # caller regrows manually and re-submits these — never silently lost
    skipped_ops: list[tuple[int, int, bool]] = field(default_factory=list)
    results: list[ResultEnvelope] = field(default_factory=list)
    latency_s: float = 0.0


def _occurrence_numbers(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    """occ[i] = #{j < i : (src[j], dst[j]) == (src[i], dst[i])}, vectorized.

    The np.unique/np.cumsum formulation of the multigraph split: stable-sort
    ops by pair, number each op by its offset from its pair group's start,
    scatter back to stream order.  Replaces the O(Q) python dict loop the
    seed engine used.
    """
    pairs = src.astype(np.int64) * np.int64(n + 1) + dst.astype(np.int64)
    _, inv, counts = np.unique(pairs, return_inverse=True, return_counts=True)
    if counts.max() <= 1:
        return np.zeros(len(pairs), np.int64)
    order = np.argsort(inv, kind="stable")  # stable: stream order per group
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    occ = np.empty(len(pairs), np.int64)
    occ[order] = np.arange(len(pairs)) - np.repeat(starts, counts)
    return occ


class SimRankSession:
    """SimRank serving session over a pluggable execution :class:`Backend`.

    ``backend`` selects the execution substrate behind the one
    ``QuerySpec -> ResultEnvelope`` surface: ``"local"`` (default) is the
    single-device fused path over an owned :class:`GraphHandle` —
    bit-identical to the pre-backend session under shared keys;
    ``"sharded"`` places the graph on a device mesh
    (:class:`repro.api.backend.ShardedBackend`: dst-partitioned shards,
    distributed probe, shard-wise updates; size the mesh with ``shards=``
    / ``mesh=``).  A ready-made :class:`Backend` instance can be passed
    directly as the first argument instead of a handle; if it advertises
    the epoch stage (``supports_epoch``), the session asks it to own-copy
    its graph state at construction so fused epochs stay donation-safe.

    ``walk_chunk`` is the total lane-column width of the fused serve step
    (per-query walk-chunk width on the sharded backend); ``batch_q`` the
    fixed query width of ``drain()``/``epoch()`` batches (short batches
    are repeat-padded so jit compiles one step per shape);
    ``update_batch`` the fixed op width of epoch update batches.
    ``top_k`` is the default k for specs that don't pin one.

    Adaptive accuracy (``core/accuracy.py``): specs with ``epsilon`` set
    escalate geometrically from ``initial_budget`` walks until a
    certificate meets the request; ``confidence`` is the default coverage
    of the empirical CLT certificate; ``hub_percentile`` selects the
    high in-degree hub set whose probe rows are cached and shared across
    queries and drain batches (``probe_cache_entries`` bounds the cache).

    With ``auto_regrow`` (default), capacity overflow triggers host-side
    compaction into 2x buffers and the skipped inserts are retried — no
    update is ever lost; with ``auto_regrow=False`` skips are surfaced in
    the ``UpdateReport``/``EpochResult`` for the caller to handle.

    The session OWNS its graph state (``own_graph=True`` copies the handle
    at construction): the fused epoch step donates the mirror buffers, so
    they must not be shared with the caller.  ``own_graph=False`` skips the
    copy for read-mostly use (queries/updates over a handle the caller
    keeps authoritative) — ``epoch()`` is disabled there, since donation
    would invalidate the caller's buffers.  Randomness: every query gets
    its own PRNG stream — ``fold_in(session_seed, submission_seq)`` — at
    submit/query time, so batch composition never changes an answer
    (docs/api.md, "PRNG-stream determinism contract").

    Thread safety: ``submit``/``drain``/``query``/``update``/``epoch``
    compose under concurrent callers — one re-entrant session lock
    serializes queue mutation, PRNG-stream assignment, ticket fills and
    graph mutation (the HTTP serving front end drives one session from
    handler and collector threads at once).  Dispatches run inside the
    lock, so a long drain blocks concurrent submitters for its duration;
    answers remain determined by each query's submit-time stream alone.
    """

    def __init__(
        self,
        handle: GraphHandle | Backend,
        *,
        c: float = 0.6,
        eps_a: float = 0.1,
        delta: float = 0.01,
        walk_chunk: int = 256,
        top_k: int = 50,
        seed: int = 0,
        batch_q: int = 8,
        update_batch: int = 64,
        auto_regrow: bool = True,
        use_kernel: bool = False,
        own_graph: bool = True,
        backend: str | Backend = "local",
        shards: int | None = None,
        mesh=None,
        backend_options: dict | None = None,
        initial_budget: int = 64,
        confidence: float = 0.99,
        hub_percentile: float = 90.0,
        probe_cache_entries: int = 256,
    ):
        if isinstance(handle, (LocalBackend, ShardedBackend)) or (
            not isinstance(handle, GraphHandle) and isinstance(handle, Backend)
        ):
            if backend != "local":  # the untouched default
                raise ValueError(
                    "pass either a Backend instance or backend=..., not both"
                )
            backend, handle = handle, None
        elif not isinstance(handle, GraphHandle):
            raise TypeError(
                "SimRankSession takes a GraphHandle — build one with "
                "GraphHandle.from_edges(src, dst, n)"
            )
        elif not isinstance(backend, str):
            # a GraphHandle positional + a ready Backend instance: the
            # handle would be silently shadowed by the backend's own graph
            raise ValueError(
                "a Backend instance brings its own graph state — pass it "
                "as the first argument instead of a GraphHandle"
            )
        self._plan_deg: tuple[int, np.ndarray] | None = None  # (version, in_deg)
        self.walk_chunk = walk_chunk
        self.top_k = top_k
        self.batch_q = batch_q
        self.update_batch = update_batch
        self.auto_regrow = auto_regrow
        self.use_kernel = use_kernel
        if isinstance(backend, str):
            if backend == "local":
                if shards is not None or mesh is not None or backend_options:
                    # a forgotten backend="sharded" must not silently
                    # build an unsharded session
                    raise ValueError(
                        "shards/mesh/backend_options only apply to "
                        "backend='sharded' — did you forget to set it?"
                    )
                self.handle = handle.copy() if own_graph else handle
                self._owns_graph = own_graph
                self.params = make_params(
                    handle.n, c=c, eps_a=eps_a, delta=delta
                )
                self.backend: Backend = LocalBackend(
                    self.handle, params=self.params,
                    walk_chunk=walk_chunk, use_kernel=use_kernel,
                )
            elif backend == "sharded":
                self.params = make_params(
                    handle.n, c=c, eps_a=eps_a, delta=delta
                )
                self.backend = ShardedBackend(
                    handle, params=self.params, shards=shards, mesh=mesh,
                    walk_chunk=walk_chunk, use_kernel=use_kernel,
                    **(backend_options or {}),
                )
                # the sharded state owns a partitioned copy of the edges;
                # the constructor handle is not kept (it would go stale on
                # the first shard-wise update)
                self.handle = None
                self._owns_graph = True
            else:
                raise ValueError(
                    f"backend must be 'local', 'sharded' or a Backend "
                    f"instance, got {backend!r}"
                )
        else:
            if shards is not None or mesh is not None or backend_options:
                raise ValueError(
                    "shards/mesh/backend_options configure session-built "
                    "backends; a ready Backend instance already carries "
                    "its geometry — construct it with those options"
                )
            self.backend = backend
            # capability detection: a backend advertising the epoch stage
            # (supports_epoch + epoch_batch) gets epochs even though the
            # caller built it — the session asks it to own-copy its graph
            # state NOW, so the donating epoch steps can never invalidate
            # buffers the caller still holds.  Backends without the stage
            # stay read-shared and epoch() refuses.
            if getattr(backend, "supports_epoch", False) and hasattr(
                backend, "own_buffers"
            ):
                backend.own_buffers()
                self._owns_graph = True
            else:
                self._owns_graph = False
            self.handle = getattr(backend, "handle", None)
            # adopt the backend's error-budget accounting when it has one,
            # so envelopes report the bound the executing substrate uses
            self.params = getattr(backend, "params", None) or make_params(
                backend.n, c=c, eps_a=eps_a, delta=delta
            )
        if initial_budget < 1:
            raise ValueError("initial_budget must be >= 1")
        if not 0.0 < confidence < 1.0:
            raise ValueError("confidence must be in (0, 1)")
        self.initial_budget = int(initial_budget)
        self.confidence = float(confidence)
        self.hub_percentile = float(hub_percentile)
        self.key = jax.random.key(seed)
        self.query_queue: deque[tuple[QuerySpec, Array, QueryTicket]] = deque()
        self.update_queue: deque[tuple[int, int, bool]] = deque()
        self.stats = EngineStats()
        self._seq = 0  # submission counter -> per-query PRNG stream
        # hub probe sharing (core/accuracy.py): adaptive queries on hub
        # nodes ride NODE-keyed PRNG streams (a salted fold_in of the
        # session key, not the submit-order stream), which makes their
        # per-round score rows identical across queries and drain batches
        # — the cache then skips whole dispatches when every row of a
        # round is resident.  Session-seed-deterministic like everything
        # else; caller-pinned spec.key bypasses both rekey and cache.
        self._probe_cache = ProbeCache(probe_cache_entries)
        self._hub_root = jax.random.fold_in(self.key, 0x5B5B)
        # one re-entrant lock serializes every path that mutates shared
        # session state — the submission queues, the seq counter behind the
        # PRNG streams, ticket fills, and graph mutation — so concurrent
        # callers (the serving front end's handler + collector threads)
        # compose safely.  Re-entrant because epoch() routes through
        # submit()/queue_update(), and drain() through _serve_next_batch().
        # Dispatches run INSIDE the lock: answers stay batch-composition
        # deterministic and two threads can never double-serve one ticket.
        self._lock = threading.RLock()

    # -- snapshot state ------------------------------------------------------

    @property
    def version(self) -> int:
        """Current graph snapshot id (bumped once per applied update batch)."""
        return self.backend.version

    @property
    def overflow(self) -> bool:
        """Sticky capacity signal (cleared by ``regrow``)."""
        return self.backend.overflow

    @property
    def pending(self) -> tuple[int, int]:
        """(queued update ops, queued queries)."""
        return len(self.update_queue), len(self.query_queue)

    def error_bound(self, n_r: int | None = None) -> float:
        """Thm 1+2 absolute-error bound at the effective walk count."""
        return abs_error_bound(self.params, n=self.backend.n, n_r=n_r)

    def regrow(self, **kwargs) -> None:
        """Manual capacity recovery (see :meth:`GraphHandle.regrow`)."""
        with self._lock:
            self.backend.regrow(**kwargs)
            self.stats.regrows += 1

    def record_retry(self, n: int = 1) -> None:
        """Public hook for dispatch-layer retries (straggler policies).

        ``EngineStats`` is owned by the session/backend pair; external
        dispatch wrappers (``repro.serving.straggler`` callers) report
        their re-dispatches through this method instead of mutating
        ``stats`` fields directly.
        """
        if n < 0:
            raise ValueError(f"retry count must be >= 0, got {n}")
        self.stats.retries += n

    # -- PRNG streams --------------------------------------------------------

    def _query_key(self) -> Array:
        with self._lock:
            k = jax.random.fold_in(self.key, self._seq)
            self._seq += 1
            return k

    # -- planner -------------------------------------------------------------

    def plan(self, spec: QuerySpec) -> str:
        """Resolve ``variant='auto'`` — the §4.4 best-of-both-worlds switch.

        Decided on host from static statistics (TPU control flow must be
        shape-static): batched specs take the fused telescoped path (the
        only batched one); a single query takes the deterministic
        prefix-tree probe when its walk pool must share first-step prefixes
        heavily — n_r >= 8 x in-degree(u), the host analogue of the paper's
        per-level deterministic-vs-randomized cost comparison — and the
        fused telescoped path otherwise.
        """
        if spec.variant != "auto":
            if spec.variant not in self.backend.variants:
                raise ValueError(
                    f"variant {spec.variant!r} is not available on the "
                    f"{self.backend.name!r} backend "
                    f"(supports {self.backend.variants})"
                )
            return spec.variant
        if spec.nodes is not None or "tree" not in self.backend.variants:
            return "telescoped"
        n_r = spec.budget_walks or self.params.n_r
        # host in-degree snapshot, refreshed once per graph version — the
        # planner must not pay a device->host sync per query on the hot path
        if self._plan_deg is None or self._plan_deg[0] != self.version:
            self._plan_deg = (self.version, self.backend.host_in_degrees())
        d = int(self._plan_deg[1][spec.node])
        if d > 0 and n_r >= 8 * d:
            return "tree"
        return "telescoped"

    # -- one-shot queries ----------------------------------------------------

    def query(
        self,
        spec: QuerySpec | int,
        *,
        budget_walks: int | None = None,
        deadline_s: float | None = None,
    ) -> ResultEnvelope:
        """Serve one spec now, bypassing the queue.

        Delegates to the core entry points, so results under an explicit
        ``spec.key`` are bit-identical to the legacy calls: single-node
        specs reproduce ``single_source(key, ...)`` / ``topk(key, ...)``
        (key-split semantics), batched specs ``multi_source(_topk)`` (a
        ``[Q]`` key array is passed through as per-query streams).  With
        ``spec.key=None`` the session assigns its own submit-order streams.

        A spec with ``epsilon`` set runs the adaptive accuracy controller
        instead (``core/accuracy.py``): escalate geometrically from the
        session's ``initial_budget`` until a certificate meets epsilon,
        capped at ``budget_walks`` (or the flat Thm-1 budget).
        ``deadline_s`` clamps escalation (adaptive specs only): a miss
        degrades to the best-so-far answer with ``certificate='deadline'``
        — it never raises.
        """
        spec = as_spec(spec, default_k=self.top_k)
        if budget_walks is not None and spec.budget_walks is None:
            spec = dataclasses.replace(spec, budget_walks=budget_walks)
        if spec.epsilon is not None:
            with self._lock:
                return self._query_adaptive(spec, deadline_s=deadline_s)
        if deadline_s is not None:
            raise ValueError(
                "deadline_s clamps the adaptive escalation loop — it "
                "requires a spec with epsilon set (for flat-budget specs "
                "use serving.straggler.dispatch around query())"
            )
        with self._lock:
            return self._query_flat(spec)

    def _query_flat(self, spec: QuerySpec) -> ResultEnvelope:
        variant = self.plan(spec)
        n_r = spec.budget_walks or self.params.n_r
        t0 = time.time()
        if spec.nodes is None:
            key = spec.key if spec.key is not None else self._query_key()
            out = self.backend.serve_one(spec, key, variant=variant, n_r=n_r)
        else:
            if variant != "telescoped":
                raise ValueError(
                    f"batched specs require the fused telescoped path, "
                    f"got variant={variant!r}"
                )
            key, keys = self._multi_keys(spec)
            est, idx, vals = self.backend.serve_batch(
                spec.kind, spec.nodes, keys, key=key, k=spec.k or 0, n_r=n_r
            )
            out = (
                dict(scores=est)
                if spec.kind == "single_source"
                else dict(topk_nodes=idx, topk_scores=vals)
            )
        dt = time.time() - t0
        self.stats.steps += 1
        self.stats.queries += spec.q
        return ResultEnvelope(
            kind=spec.kind,
            node=spec.node,
            nodes=spec.nodes,
            walks_used=n_r,
            latency_s=dt,
            version=self.version,
            error_bound=self.error_bound(n_r),
            variant=self.backend.dispatch_label(variant),
            **out,
        )

    def _multi_keys(self, spec: QuerySpec):
        """(key, keys) for a batched spec — exactly one of the two is set."""
        q = spec.q
        if spec.key is None:
            return None, jnp.stack([self._query_key() for _ in range(q)])
        k = spec.key
        if getattr(k, "ndim", 0) == 1:
            if k.shape[0] != q:
                raise ValueError(
                    f"per-query key array has {k.shape[0]} streams "
                    f"for {q} nodes"
                )
            return None, k
        return k, None  # scalar key: legacy split semantics

    # -- adaptive accuracy serving (core/accuracy.py) ------------------------

    def _query_adaptive(
        self, spec: QuerySpec, *, deadline_s: float | None = None
    ) -> ResultEnvelope:
        """One-shot adaptive spec: run the escalation loop now.

        Single-node specs return their per-query envelope directly; a
        batched ``nodes`` spec fans out to per-node items (a scalar
        ``spec.key`` is split into per-query streams — there is no legacy
        adaptive path to reproduce) and collapses to ONE envelope whose
        certificate is the batch's weakest member (``walks_used``/
        ``certified_bound``/``rounds`` are the per-query maxima).
        """
        if spec.nodes is None:
            key = spec.key if spec.key is not None else self._query_key()
            envs = self._serve_adaptive([(spec, key)], deadline_s=deadline_s)
            self.stats.queries += 1
            return envs[0]
        key, keys = self._multi_keys(spec)
        if keys is None:
            keys = jax.random.split(key, spec.q)
        subs = [
            dataclasses.replace(spec, node=int(u), nodes=None)
            for u in spec.nodes
        ]
        envs = self._serve_adaptive(
            list(zip(subs, list(keys))), deadline_s=deadline_s
        )
        self.stats.queries += spec.q
        worst = max(envs, key=lambda e: e.certified_bound)
        walks = max(e.walks_used for e in envs)
        is_ss = spec.kind == "single_source"
        return ResultEnvelope(
            kind=spec.kind,
            nodes=spec.nodes,
            scores=np.stack([e.scores for e in envs]) if is_ss else None,
            topk_nodes=(
                None if is_ss else np.stack([e.topk_nodes for e in envs])
            ),
            topk_scores=(
                None if is_ss else np.stack([e.topk_scores for e in envs])
            ),
            walks_used=walks,
            latency_s=envs[0].latency_s,
            version=self.version,
            error_bound=self.error_bound(walks),
            variant=envs[0].variant,
            epsilon=spec.epsilon,
            certified_bound=worst.certified_bound,
            certificate=worst.certificate,
            rounds=max(e.rounds for e in envs),
        )

    def _serve_adaptive(
        self,
        batch: list[tuple],
        budget_walks: int | None = None,
        *,
        deadline_s: float | None = None,
    ) -> list[ResultEnvelope]:
        """Escalate one (possibly repeat-padded) batch until epsilon is met.

        Items are ``(spec, key)`` or ``(spec, key, ticket)`` tuples sharing
        one batch group.  Each round dispatches ONE fused single-source
        step (the same compiled lane-batched program flat serving uses —
        the loop lives outside it) under per-round ``fold_in(stream, r)``
        keys and folds the ``[Q, n]`` rows into the controller's carried
        accumulator; a query freezes at the round its certificate fires,
        so its answer is independent of how long batch mates escalate.
        The cap is ``spec.budget_walks`` (or the flat Thm-1 budget), which
        bounds total spend at the flat budget structurally.

        Hub queries (in-degree above ``hub_percentile``, ``spec.key`` not
        pinned) ride node-keyed streams and their rows go through the
        probe cache: a round whose rows are ALL resident skips its
        dispatch entirely (``stats.hub_hits``) — bitwise identical to
        serving, because cached rows were produced by the same streams.

        ``deadline_s`` is checked before every round after the first; on a
        miss the still-live queries freeze with ``certificate='deadline'``
        and their best-so-far scores — degradation, never an exception.
        """
        spec0 = batch[0][0]
        q = len(batch)
        conf = (
            spec0.confidence
            if spec0.confidence is not None
            else self.confidence
        )
        cap = spec0.budget_walks or budget_walks or self.params.n_r
        ctrl = AccuracyController(
            self.params,
            n=self.backend.n,
            q=q,
            epsilon=spec0.epsilon,
            confidence=conf,
            plan=escalation_schedule(min(self.initial_budget, cap), cap),
        )
        us = [item[0].node for item in batch]
        hubs = self.backend.hub_nodes(self.hub_percentile)
        streams, cacheable = [], []
        for item in batch:
            sp = item[0]
            if sp.key is None and sp.node in hubs:
                streams.append(jax.random.fold_in(self._hub_root, sp.node))
                cacheable.append(True)
            else:
                streams.append(item[1])
                cacheable.append(False)
        ver = self.version
        t0 = time.time()
        while True:
            n_round = ctrl.next_round()
            if n_round is None:
                ctrl.finish("budget")
                break
            r = ctrl.rounds_done
            if (
                deadline_s is not None
                and r > 0
                and time.time() - t0 >= deadline_s
            ):
                ctrl.finish("deadline")
                break
            # the row is bitwise-determined by (node stream, version,
            # round, round size) plus the lane geometry (q, walk_chunk)
            ckeys = [
                (us[i], ver, r, n_round, q, self.walk_chunk)
                if cacheable[i]
                else None
                for i in range(q)
            ]
            rows = [
                None if ck is None else self._probe_cache.get(ck)
                for ck in ckeys
            ]
            if rows and all(row is not None for row in rows):
                est = np.stack(rows)
                self.stats.hub_hits += 1  # a whole dispatch skipped
            else:
                keys = jnp.stack(
                    [jax.random.fold_in(s, r) for s in streams]
                )
                est, _, _ = self.backend.serve_batch(
                    "single_source", us, keys, k=0, n_r=n_round
                )
                est = np.asarray(est)
                self.stats.steps += 1
                if r > 0:
                    self.stats.escalations += 1
                for i, ck in enumerate(ckeys):
                    if ck is not None:
                        self._probe_cache.put(ck, est[i])
            ctrl.absorb(n_round, est)
            if ctrl.all_frozen:
                break
        dt = time.time() - t0
        label = self.backend.dispatch_label("telescoped")
        out = []
        for i, item in enumerate(batch):
            sp = item[0]
            scores, cert = ctrl.result(i)
            env = ResultEnvelope(
                kind=sp.kind,
                node=sp.node,
                walks_used=cert.walks,
                latency_s=dt,
                version=ver,
                error_bound=self.error_bound(cert.walks),
                variant=label,
                epsilon=sp.epsilon,
                certified_bound=cert.bound,
                certificate=cert.name,
                rounds=cert.rounds,
            )
            if sp.kind == "single_source":
                env.scores = scores
            else:
                # host top-k over the combined vector, matching the fused
                # epilogue's conventions: query node masked out, ties break
                # toward the lower index (stable argsort == lax.top_k)
                k = sp.k or self.top_k
                masked = scores.copy()
                masked[sp.node] = -np.inf
                order = np.argsort(-masked, kind="stable")[:k]
                env.topk_nodes = order.astype(np.int32)
                env.topk_scores = masked[order]
            out.append(env)
        return out

    # -- queued serving (submit -> fused drain) ------------------------------

    def submit(self, spec: QuerySpec | int) -> QueryTicket:
        """Enqueue a single-node spec (PRNG stream fixed NOW: batch-invariant).

        Returns a :class:`QueryTicket` — poll it, ``result()`` it, or
        ignore it and collect everything with :meth:`drain` as before.
        """
        spec = as_spec(spec, default_k=self.top_k)
        if spec.nodes is not None:
            raise ValueError("submit takes single-node specs; use query() "
                             "for an explicit batch")
        if spec.variant not in ("auto", "telescoped"):
            raise ValueError(
                "queued serving uses the fused telescoped path; "
                f"variant={spec.variant!r} is only available via query()"
            )
        with self._lock:
            if spec.key is not None:
                key, seq = spec.key, -1  # caller-pinned stream
            else:
                seq = self._seq
                key = self._query_key()
            ticket = QueryTicket(spec=spec, seq=seq, _session=self)
            self.query_queue.append((spec, key, ticket))
            return ticket

    def _batch_group(self, spec: QuerySpec):
        """Specs that can share one fused dispatch (same shapes/budget).

        Adaptive specs additionally group on (epsilon, confidence): every
        query in an escalation batch shares one controller, and flat specs
        never mix with adaptive ones.
        """
        return (
            spec.kind, spec.k, spec.budget_walks,
            spec.epsilon, spec.confidence,
        )

    def _pop_query_batch(self) -> tuple[list[tuple[QuerySpec, Array]], int]:
        """Pop up to ``batch_q`` group-compatible specs; repeat-pad the rest."""
        gid = self._batch_group(self.query_queue[0][0])
        batch: list[tuple[QuerySpec, Array]] = []
        while (
            self.query_queue
            and len(batch) < self.batch_q
            and self._batch_group(self.query_queue[0][0]) == gid
        ):
            batch.append(self.query_queue.popleft())
        live = len(batch)
        while len(batch) < self.batch_q:
            batch.append(batch[-1])  # pad with repeats: static shape
        return batch, live

    def _serve_fused(
        self,
        batch: list[tuple],
        budget_walks: int | None,
    ) -> list[ResultEnvelope]:
        """One fused dispatch for a (possibly repeat-padded) query batch.

        Items are ``(spec, key)`` or ``(spec, key, ticket)`` tuples; the
        returned envelope list is positional (tickets — when present —
        are filled by the caller for the live slice only, so repeat
        padding never double-assigns).  Adaptive groups (``epsilon`` set)
        route to the escalation loop instead of one flat dispatch.
        """
        spec0 = batch[0][0]
        if spec0.epsilon is not None:
            return self._serve_adaptive(batch, budget_walks)
        n_r = spec0.budget_walks or budget_walks or self.params.n_r
        us = [item[0].node for item in batch]
        keys = jnp.stack([item[1] for item in batch])
        t0 = time.time()
        est, idx, vals = self.backend.serve_batch(
            spec0.kind, us, keys, k=spec0.k or 0, n_r=n_r
        )
        dt = time.time() - t0
        self.stats.steps += 1
        ver = self.version
        bound = self.error_bound(n_r)
        return [
            ResultEnvelope(
                kind=spec0.kind,
                node=item[0].node,
                scores=None if est is None else est[i],
                topk_nodes=None if est is not None else idx[i],
                topk_scores=None if est is not None else vals[i],
                walks_used=n_r,
                latency_s=dt,
                version=ver,
                error_bound=bound,
                variant=self.backend.dispatch_label("telescoped"),
            )
            for i, item in enumerate(batch)
        ]

    def _serve_next_batch(
        self, budget_walks: int | None
    ) -> list[ResultEnvelope]:
        """Pop + serve ONE fused batch; fills tickets for the live slice.

        Returns ``[]`` when the queue is already empty — a concurrent
        drain on another thread may have consumed it between our caller's
        check and the lock acquisition here.
        """
        with self._lock:
            if not self.query_queue:
                return []
            batch, live = self._pop_query_batch()
            served = self._serve_fused(batch, budget_walks)[:live]
            for item, env in zip(batch[:live], served):
                if len(item) > 2 and item[2] is not None:
                    item[2].envelope = env
            self.stats.queries += live
            return served

    def drain(self, *, budget_walks: int | None = None) -> list[ResultEnvelope]:
        """Serve every queued spec in fused batches of ``batch_q``.

        Consecutive group-compatible specs (same kind/k/budget) share a
        dispatch; short or cut batches are padded by repeating the last
        entry (padded slots recompute an already-served query and are
        discarded).  ``budget_walks`` caps specs that don't pin their own.
        Tickets already forced via ``result()`` have left the queue — the
        returned list covers what was still queued, in order.
        """
        with self._lock:
            out: list[ResultEnvelope] = []
            while self.query_queue:
                out.extend(self._serve_next_batch(budget_walks))
            return out

    def _drain_until(
        self, ticket: QueryTicket, *, budget_walks: int | None = None
    ) -> None:
        """Serve queued batches (submission order) until ``ticket`` is done."""
        with self._lock:
            while ticket.envelope is None and self.query_queue:
                self._serve_next_batch(budget_walks)
            if ticket.envelope is None:
                raise RuntimeError(
                    "ticket is not queued in this session (was the queue "
                    "consumed by an epoch of a different session?)"
                )

    # -- immediate updates ---------------------------------------------------

    def _validate_ops(self, src: np.ndarray, dst: np.ndarray) -> None:
        # validate HERE: out-of-range ids would be sentinel-masked to no-ops
        # downstream and then mistaken for capacity-overflow skips, feeding
        # an unbounded retry/regrow loop
        n = self.backend.n
        bad = (src < 0) | (src >= n) | (dst < 0) | (dst >= n)
        if bad.any():
            i = int(np.argmax(bad))
            raise ValueError(
                f"edge op ({src[i]}, {dst[i]}) out of range for n={n}"
            )

    @staticmethod
    def _as_ops(edges) -> tuple[np.ndarray, np.ndarray]:
        src, dst = edges
        return (np.asarray(src, np.int32).reshape(-1),
                np.asarray(dst, np.int32).reshape(-1))

    def update(self, inserts=None, deletes=None) -> UpdateReport:
        """Apply edge updates NOW through the coordinated both-mirrors path.

        ``inserts``/``deletes`` are ``(src, dst)`` array pairs; inserts
        apply before deletes within one call.  Deleting duplicate (s, d)
        pairs in one call removes one copy per op (multigraph semantics):
        the batch path deletes at most one copy per batch, so duplicates
        are split into per-occurrence sub-batches (vectorized — see
        ``_occurrence_numbers``).  Batches are padded to the next power of
        two so variable-size bursts reuse a log-bounded set of compiled
        shapes.  With ``auto_regrow``, overflow-skipped inserts trigger a
        regrow and are retried until applied; otherwise they are surfaced
        in ``UpdateReport.skipped``.
        """
        with self._lock:
            rep = UpdateReport()
            if inserts is not None:
                s, d = self._as_ops(inserts)
                self._validate_ops(s, d)
                self._apply_now(s, d, True, rep)
            if deletes is not None:
                s, d = self._as_ops(deletes)
                self._validate_ops(s, d)
                if s.shape[0]:
                    occ = _occurrence_numbers(s, d, self.backend.n)
                    for k in range(int(occ.max()) + 1):
                        m = occ == k
                        self._apply_now(s[m], d[m], False, rep)
            rep.version = self.version
            rep.overflow = self.overflow
            return rep

    def _apply_now(
        self, src: np.ndarray, dst: np.ndarray, insert: bool, rep: UpdateReport
    ) -> None:
        if src.shape[0] == 0:
            return
        rep.submitted += int(src.shape[0])
        while True:
            # the backend pads/buckets internally (pow-2 batches on the
            # local path; shard-wise re-partition on the sharded path)
            applied = self.backend.apply_ops(src, dst, insert)
            n_app = int(applied.sum())
            rep.applied += n_app
            self.stats.updates += n_app
            if not insert:
                return  # unapplied deletes were genuinely absent: no retry
            skipped = ~applied
            if not skipped.any():
                return
            if not self.auto_regrow:
                rep.skipped += [
                    (int(s), int(d), True)
                    for s, d in zip(src[skipped], dst[skipped])
                ]
                return
            self.backend.regrow()  # 2x buffers per round: terminates
            self.stats.regrows += 1
            rep.regrows += 1
            src, dst = src[skipped], dst[skipped]

    # -- fused update->query epochs ------------------------------------------

    def queue_update(self, src, dst, *, insert: bool = True) -> None:
        """Enqueue edge ops for the next :meth:`epoch` step(s)."""
        s, d = self._as_ops((src, dst))
        self._validate_ops(s, d)
        with self._lock:
            for a, b in zip(s, d):
                self.update_queue.append((int(a), int(b), insert))

    def _pop_updates(self) -> tuple[list[tuple[int, int, bool]], UpdateBatch]:
        # apply_update_batch runs its delete phase before its insert phase
        # and deletes at most one copy of a (s, d) pair per batch, so a batch
        # must not contain (a) a delete of an edge inserted earlier in the
        # SAME batch, nor (b) a second delete of the same pair (multigraph
        # copies) — cut the epoch's batch there (the delete waits for the
        # next epoch) to preserve exact stream order
        ops: list[tuple[int, int, bool]] = []
        inserted: set[tuple[int, int]] = set()
        deleted: set[tuple[int, int]] = set()
        while self.update_queue and len(ops) < self.update_batch:
            s, d, ins = self.update_queue[0]
            if not ins and ((s, d) in inserted or (s, d) in deleted):
                break
            (inserted if ins else deleted).add((s, d))
            ops.append(self.update_queue.popleft())
        batch = make_update_batch(
            [s for s, _, _ in ops],
            [d for _, d, _ in ops],
            [i for _, _, i in ops] if ops else True,
            batch_size=self.update_batch,
            n=self.backend.n,
        )
        return ops, batch

    def _pop_epoch_queries(self) -> tuple[int, list, QuerySpec]:
        qs, live = self._pop_query_batch()  # same grouping/padding as drain
        return live, qs, qs[0][0]

    def epoch(
        self,
        *,
        inserts=None,
        deletes=None,
        queries=None,
        budget_walks: int | None = None,
    ) -> EpochResult:
        """Run ONE fused epoch: up to ``update_batch`` queued ops + up to
        ``batch_q`` queued queries in a single compiled dispatch.

        ``inserts``/``deletes`` (``(src, dst)`` pairs) and ``queries``
        (node ids or single-node specs) are enqueued first — anything past
        one epoch's width stays queued (see :attr:`pending`; loop epochs to
        drain).  Scores are exact w.r.t. the post-update snapshot (zero
        host transfers between update and query); a top-k query batch runs
        the fused top-k epilogue, a single_source batch returns full score
        vectors.  Update-only epochs (empty query queue) dispatch just the
        batch application — no point paying the fused probe for discarded
        dummy queries.
        """
        if not getattr(self.backend, "supports_epoch", False) or not hasattr(
            self.backend, "epoch_batch"
        ):
            # capability detection: the epoch is a Backend-protocol stage
            # now — a backend that doesn't implement it gets update() +
            # drain() instead of a fused step
            raise NotImplementedError(
                f"the {self.backend.name!r} backend does not implement "
                "epoch_batch; apply update() and drain() separately"
            )
        if not self._owns_graph:
            # the epoch step DONATES graph buffers; with own_graph=False
            # the caller kept the handle authoritative and shares its
            # arrays with the session (CPU ignores donation, so this would
            # pass tests and corrupt in production)
            raise ValueError(
                "epoch() requires an owned graph: construct the session "
                "from a GraphHandle with own_graph=True (the default)"
            )
        with self._lock:
            return self._epoch_locked(
                inserts=inserts, deletes=deletes, queries=queries,
                budget_walks=budget_walks,
            )

    def _epoch_locked(
        self, *, inserts, deletes, queries, budget_walks
    ) -> EpochResult:
        if inserts is not None:
            self.queue_update(*self._as_ops(inserts), insert=True)
        if deletes is not None:
            self.queue_update(*self._as_ops(deletes), insert=False)
        if queries is not None:
            for q in queries:
                self.submit(q)
        if self.query_queue and self.query_queue[0][0].epsilon is not None:
            # the escalation loop lives OUTSIDE the compiled step (it must
            # inspect per-round scores on host), so it cannot ride the
            # fused update->query epoch; the specs stay queued
            raise ValueError(
                "adaptive (epsilon) specs cannot be served inside a fused "
                "epoch — apply the update, then serve them via drain() or "
                "query()"
            )
        ops, batch = self._pop_updates()
        p = self.params

        t0 = time.time()
        if self.query_queue:
            live_q, qs, spec0 = self._pop_epoch_queries()
            n_r = spec0.budget_walks or budget_walks or p.n_r
            tk = spec0.k if spec0.kind == "topk" else 0
            us = [item[0].node for item in qs]
            keys = jnp.stack([item[1] for item in qs])
            applied, est, idx, vals = self.backend.epoch_batch(
                batch, us, keys,
                n_r=n_r, top_k=tk,
                lanes=self.walk_chunk, use_kernel=self.use_kernel,
            )
        else:
            live_q, qs, spec0 = 0, [], None
            n_r = budget_walks or p.n_r
            applied, est, idx, vals = self.backend.epoch_batch(
                batch, None, None,
                n_r=n_r, top_k=0,
                lanes=self.walk_chunk, use_kernel=self.use_kernel,
            )
        applied = np.asarray(applied)[: len(ops)]
        dt = time.time() - t0

        version = self.version
        overflow = self.overflow
        regrown = False
        requeued = 0
        # skipped inserts (applied == False); unapplied deletes were
        # genuinely absent — those are not retried or surfaced
        skipped = [op for op, ok in zip(ops, applied) if not ok and op[2]]
        if skipped and self.auto_regrow:
            # retry on the regrown buffers next epoch
            for op in reversed(skipped):
                self.update_queue.appendleft(op)
            requeued = len(skipped)
            self.backend.regrow()
            self.stats.regrows += 1
            regrown = True

        bound = self.error_bound(n_r)
        variant = self.backend.epoch_dispatch_label()
        results = [
            ResultEnvelope(
                kind=spec0.kind,
                node=item[0].node,
                scores=None if est is None else est[i],
                topk_nodes=None if est is not None else idx[i],
                topk_scores=None if est is not None else vals[i],
                walks_used=n_r,
                latency_s=dt,
                version=version,
                error_bound=bound,
                variant=variant,
            )
            for i, item in enumerate(qs[:live_q])
        ]
        for item, env in zip(qs[:live_q], results):
            if len(item) > 2 and item[2] is not None:
                item[2].envelope = env
        self.stats.epochs += 1
        self.stats.steps += 1
        self.stats.queries += live_q
        self.stats.updates += int(applied.sum())
        return EpochResult(
            version=version,
            overflow=overflow,
            regrown=regrown,
            updates_submitted=len(ops),
            updates_applied=int(applied.sum()),
            updates_requeued=requeued,
            skipped_ops=skipped,
            results=results,
            latency_s=dt,
        )

    def drain_epochs(
        self, *, budget_walks: int | None = None
    ) -> list[EpochResult]:
        """Run epochs until both queues are empty."""
        with self._lock:
            out: list[EpochResult] = []
            while self.update_queue or self.query_queue:
                out.append(self.epoch(budget_walks=budget_walks))
            return out
