"""Typed request/response envelopes for the session API.

``QuerySpec`` is the one request type for every SimRank query the system
serves — single-source score vectors and top-k lists, one node or a fused
batch, full-accuracy or anytime-budgeted — and ``ResultEnvelope`` the one
response type, carrying the scores *and* the metadata a serving system
needs to trust them: the graph ``version`` the query ran against, the walk
budget actually spent, and the Theorem-1/2 absolute-error bound evaluated
at that *effective* budget (an anytime query reports the error it actually
guarantees, not the one the full budget would have).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

VARIANTS = ("auto", "telescoped", "tree", "reference", "randomized")
KINDS = ("single_source", "topk")


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """One SimRank query request.

    Exactly one of ``node`` (single query) or ``nodes`` (fused batch) must
    be set.  ``k`` is only meaningful for ``kind='topk'`` (None = the
    session default).  ``budget_walks`` caps the walk pool (anytime mode;
    None = the full Theorem-1 budget).  ``variant='auto'`` defers the
    deterministic-vs-batched probe choice (paper §4.4) to the session
    planner; explicit variants pin it.  ``key`` optionally fixes the PRNG
    stream — a scalar typed key reproduces the legacy ``single_source``/
    ``topk``/``multi_source`` key-split semantics exactly, a ``[Q]`` key
    array is passed through as per-query streams; None lets the session
    assign its own submit-order stream.

    ``epsilon`` requests *adaptive accuracy*: the session escalates the
    walk budget geometrically until the Thm-1/2 analytic bound or the
    empirical CLT certificate meets it (``core/accuracy.py``), with
    ``budget_walks`` (or the flat Thm-1 budget) as the cap — the envelope
    then reports the certified bound and which certificate fired.
    ``epsilon=0.0`` is valid and never certifiable: the controller runs
    the full schedule to the cap (how the parity tests pin escalated ==
    one-shot).  ``confidence`` sets the empirical certificate's coverage
    (None = the session default, 0.99).
    """

    kind: str = "topk"
    node: int | None = None
    nodes: tuple[int, ...] | None = None
    k: int | None = None
    budget_walks: int | None = None
    variant: str = "auto"
    key: Any = None
    epsilon: float | None = None
    confidence: float | None = None

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {self.kind!r}")
        if self.variant not in VARIANTS:
            raise ValueError(
                f"variant must be one of {VARIANTS}, got {self.variant!r}"
            )
        if (self.node is None) == (self.nodes is None):
            raise ValueError("exactly one of node / nodes must be set")
        if self.node is not None:
            object.__setattr__(self, "node", int(self.node))
        if self.nodes is not None:
            object.__setattr__(
                self,
                "nodes",
                tuple(int(u) for u in np.asarray(self.nodes).reshape(-1)),
            )
        if self.k is not None and self.k < 1:
            raise ValueError("k must be >= 1")
        if self.budget_walks is not None and self.budget_walks < 1:
            raise ValueError("budget_walks must be >= 1")
        if self.epsilon is not None and self.epsilon < 0.0:
            raise ValueError("epsilon must be >= 0")
        if self.confidence is not None and not 0.0 < self.confidence < 1.0:
            raise ValueError("confidence must be in (0, 1)")
        if self.confidence is not None and self.epsilon is None:
            raise ValueError("confidence requires epsilon (adaptive mode)")

    @property
    def q(self) -> int:
        """Number of queries this spec fans out to."""
        return 1 if self.nodes is None else len(self.nodes)


def as_spec(x: "QuerySpec | int", *, default_k: int | None = None) -> QuerySpec:
    """Coerce a bare node id to a default top-k spec; fill the default k."""
    spec = x if isinstance(x, QuerySpec) else QuerySpec(kind="topk", node=int(x))
    if spec.kind == "topk" and spec.k is None and default_k is not None:
        spec = dataclasses.replace(spec, k=default_k)
    return spec


@dataclasses.dataclass
class ResultEnvelope:
    """One SimRank query response (host-side numpy; device work is done).

    For ``kind='single_source'``: ``scores`` is the estimate vector ([n],
    or [Q, n] for a batched spec).  For ``kind='topk'``: ``topk_nodes`` /
    ``topk_scores`` are [k] (or [Q, k]); the query node itself is excluded.
    ``version`` attributes the scores to a graph snapshot; ``error_bound``
    is the Thm 1+2 absolute-error bound at the *effective* ``walks_used``
    (see ``repro.core.params.abs_error_bound``); ``variant`` records what
    the session planner actually dispatched.

    Adaptive queries (``spec.epsilon`` set) additionally report the
    accuracy-controller outcome: ``epsilon`` echoes the request,
    ``certified_bound`` is the tightest bound certified at the stopping
    point (min of the analytic and empirical certificates — may be below
    ``error_bound``, which stays the analytic bound at ``walks_used``),
    ``certificate`` names what fired (``analytic`` / ``empirical``) or why
    escalation stopped without meeting epsilon (``budget`` / ``deadline``),
    and ``rounds`` counts the escalation rounds executed.

    Field-superset of the legacy ``QueryResult`` — engine shims return
    envelopes directly.
    """

    kind: str = "topk"
    node: int | None = None
    nodes: tuple[int, ...] | None = None
    scores: np.ndarray | None = None
    topk_nodes: np.ndarray | None = None
    topk_scores: np.ndarray | None = None
    walks_used: int = 0
    latency_s: float = 0.0
    version: int = -1
    error_bound: float = float("nan")
    variant: str = "telescoped"
    epsilon: float | None = None
    certified_bound: float = float("nan")
    certificate: str | None = None
    rounds: int = 1
