"""Execution backends under :class:`~repro.api.session.SimRankSession`.

PR 3 unified the query/update surface into one session, but the session
could only *execute* one way: the single-device fused path.  The
distributed substrate (``core/distributed.py``'s auto-partitioned probe,
``core/ring.py``'s shard_map ring, ``graph/partition.py``) was a dead
island no user-facing API could reach.  This module is the bridge: a
``Backend`` protocol the session dispatches through, with two
implementations —

* :class:`LocalBackend` — the extraction of the session's original
  dispatch paths (``single_source``/``topk``/``multi_source*`` plus the
  coordinated :class:`GraphHandle` update path).  Bit-identical to the
  pre-backend session under shared keys: same core entry points, same
  pow-2 update bucketing, same compiled shapes.
* :class:`ShardedBackend` — the same ``QuerySpec -> ResultEnvelope``
  contract over a device mesh: destination-partitioned edge shards
  (:func:`repro.graph.partition.partition_edges_by_dst` bookkeeping via
  :class:`ShardedGraphState`), the distributed walk sampler + telescoped
  probe (``probe='spmd'``, the auto-partitioned baseline) or the
  shard_map ring push (``probe='ring'``), and dynamic updates applied
  shard-wise with the same version/overflow semantics as
  ``GraphHandle.apply_batch``.

The session stays the owner of everything *around* execution — specs,
PRNG streams, queues/tickets, stats, envelopes, the §4.4 planner — and
asks the backend only to (a) serve a batch, (b) apply an update
sub-batch, (c) recover capacity, (d) report snapshot state.  Both
backends batch differently behind that one surface: the local backend
fuses queries across lane columns of one compiled step; the sharded
backend loops ring walk-chunks over the mesh and folds partial counts on
host.

Randomness: both backends honor per-query PRNG streams.  The sharded
backend derives chunk keys as ``fold_in(stream, chunk_index)``, so its
answers are deterministic per (stream, graph snapshot) and independent
of batch composition — the same contract the local path tests pin —
but its draws are *different* draws than the local sampler's (different
walk-table layout), so cross-backend parity is tolerance-based, not
bit-identical (tests/test_backend.py).
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.api.handle import GraphHandle
from repro.api.spec import QuerySpec
from repro.core.multisource import multi_source, multi_source_topk
from repro.core.params import ProbeSimParams
from repro.core.probesim import single_source, topk
from repro.graph.dynamic import make_update_batch
from repro.graph.partition import pad_to_multiple, partition_ops_by_dst
from repro.utils.jaxcompat import make_mesh, set_mesh, specs_to_shardings

Array = jax.Array


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------


@runtime_checkable
class Backend(Protocol):
    """What the session needs from an execution substrate.

    Implementations own the graph state (device mirrors or sharded
    buffers) and the compiled serve steps; the session owns specs, PRNG
    streams, queues, stats and envelopes.  ``serve_batch`` is the one
    required query entry point (``serve_one`` has a default route through
    it on both shipped backends); updates arrive as homogeneous
    sub-batches (one ``insert`` flag per call, duplicate delete pairs
    already split by the session) and return a per-op applied mask with
    ``GraphHandle.apply_batch`` semantics: an unapplied insert means
    capacity overflow (sticky ``overflow``, recover via ``regrow``), an
    unapplied delete means the edge was absent.
    """

    name: str
    supports_epoch: bool
    variants: tuple[str, ...]

    @property
    def n(self) -> int: ...

    @property
    def version(self) -> int: ...

    @property
    def overflow(self) -> bool: ...

    def host_in_degrees(self) -> np.ndarray: ...

    def dispatch_label(self, variant: str) -> str: ...

    def serve_one(
        self, spec: QuerySpec, key, *, variant: str, n_r: int
    ) -> dict: ...

    def serve_batch(
        self, kind: str, us, keys, *, key=None, k: int = 0, n_r: int
    ) -> tuple: ...

    def apply_ops(
        self, src: np.ndarray, dst: np.ndarray, insert: bool
    ) -> np.ndarray: ...

    def regrow(self, **kwargs) -> None: ...

    def to_host_edges(self) -> tuple[np.ndarray, np.ndarray]: ...


# ---------------------------------------------------------------------------
# Local backend — the extracted single-device dispatch paths
# ---------------------------------------------------------------------------


class LocalBackend:
    """Single-device execution over an owned :class:`GraphHandle`.

    This is PR 3's session dispatch verbatim, moved behind the protocol:
    one-shot specs delegate to the core entry points (so an explicit
    ``spec.key`` reproduces the legacy calls bit-for-bit), batched specs
    run the fused multi-query step, updates go through the coordinated
    both-mirrors path with pow-2 bucketed batches.  The handle is shared
    with the session (``session.handle is backend.handle``), which keeps
    the fused epoch path — which donates and replaces the mirror buffers
    in place — working unchanged.
    """

    name = "local"
    supports_epoch = True
    variants = ("auto", "telescoped", "tree", "reference", "randomized")

    def __init__(
        self,
        handle: GraphHandle,
        *,
        params: ProbeSimParams,
        walk_chunk: int = 256,
        use_kernel: bool = False,
    ):
        if not isinstance(handle, GraphHandle):
            raise TypeError("LocalBackend takes a GraphHandle")
        self.handle = handle
        self.params = params
        self.walk_chunk = walk_chunk
        self.use_kernel = use_kernel

    # -- snapshot state ------------------------------------------------------

    @property
    def n(self) -> int:
        return self.handle.n

    @property
    def version(self) -> int:
        return self.handle.version

    @property
    def overflow(self) -> bool:
        return self.handle.overflow

    def host_in_degrees(self) -> np.ndarray:
        return np.asarray(self.handle.eg.in_deg)

    def dispatch_label(self, variant: str) -> str:
        """Envelope ``variant`` field: the legacy variant, verbatim."""
        return variant

    def to_host_edges(self) -> tuple[np.ndarray, np.ndarray]:
        return self.handle.to_host_edges()

    # -- queries -------------------------------------------------------------

    def serve_one(self, spec: QuerySpec, key, *, variant: str, n_r: int) -> dict:
        """One single-node spec via the legacy entry points (bit-identical
        to ``single_source``/``topk`` under the same key)."""
        g, eg = self.handle.g, self.handle.eg
        p = (
            self.params
            if n_r == self.params.n_r
            else dataclasses.replace(self.params, n_r=n_r)
        )
        if spec.kind == "single_source":
            est = single_source(
                key, g, eg, spec.node, p, variant=variant,
                walk_chunk=self.walk_chunk, use_kernel=self.use_kernel,
            )
            return dict(scores=np.asarray(est))
        idx, vals = topk(
            key, g, eg, spec.node, spec.k, p, variant=variant,
            walk_chunk=self.walk_chunk, use_kernel=self.use_kernel,
        )
        return dict(topk_nodes=np.asarray(idx), topk_scores=np.asarray(vals))

    def serve_batch(
        self, kind: str, us, keys, *, key=None, k: int = 0, n_r: int
    ) -> tuple:
        """One fused multi-query dispatch; returns ``(est, idx, vals)``
        (est for single_source kind, idx/vals for topk — the unused pair
        is None).  Exactly one of ``keys`` ([Q] per-query streams) /
        ``key`` (scalar: legacy split semantics) is set."""
        g, eg = self.handle.g, self.handle.eg
        us = jnp.asarray(us, jnp.int32)
        common = dict(
            lanes=self.walk_chunk, n_r=n_r, keys=keys,
            use_kernel=self.use_kernel,
        )
        if kind == "topk":
            idx, vals = multi_source_topk(
                key, g, eg, us, k, self.params, **common
            )
            return None, np.asarray(idx), np.asarray(vals)
        est = multi_source(key, g, eg, us, self.params, **common)
        return np.asarray(est), None, None

    # -- updates -------------------------------------------------------------

    def apply_ops(
        self, src: np.ndarray, dst: np.ndarray, insert: bool
    ) -> np.ndarray:
        """Apply one homogeneous sub-batch through the coordinated
        both-mirrors path; pow-2 padded so variable-size bursts reuse a
        log-bounded set of compiled shapes."""
        bucket = 1 << (int(src.shape[0]) - 1).bit_length()
        batch = make_update_batch(
            src, dst, insert, batch_size=bucket, n=self.handle.n
        )
        return np.asarray(self.handle.apply_batch(batch))[: src.shape[0]]

    def regrow(self, **kwargs) -> None:
        self.handle.regrow(**kwargs)


# ---------------------------------------------------------------------------
# Sharded graph state — dst-partitioned host buffers + device mirrors
# ---------------------------------------------------------------------------


class ShardedGraphState:
    """Destination-partitioned edge state with GraphHandle-style dynamics.

    The authoritative copy is a pair of host buffers ``[S, E]`` (global
    src/dst ids, per-shard FIFO order, ``counts[s]`` live entries each) —
    exactly the layout :func:`partition_edges_by_dst` produces, plus
    capacity headroom.  Updates are applied *shard-wise*: an incoming
    batch is re-partitioned by destination shard (``dst // rows``) and
    each shard appends/deletes in its own buffer.  Semantics mirror
    ``GraphHandle.apply_batch``:

    * an insert applies iff its shard has room; a skipped insert sets the
      sticky ``overflow`` flag and is reported unapplied (never dropped);
    * a delete removes at most one live copy of its (src, dst) pair per
      *batch* — exactly ``apply_update_batch``'s contract; the session's
      occurrence split feeds duplicate pairs in separate batches — with
      stable compaction (FIFO order preserved) and a per-op found mask;
    * ``version`` advances by exactly one per batch that changed the
      graph; ``regrow`` doubles per-shard capacity, clears ``overflow``
      and preserves ``version`` (a representation change, not a graph
      change).

    Device mirrors (:class:`~repro.core.distributed.ShardedGraph`, and a
    :class:`~repro.core.ring.RingGraph` for the ring probe) are built
    lazily from the host buffers and invalidated on every applied batch;
    because partitioning is deterministic and per-shard order is FIFO,
    the incremental mirrors are bit-identical to rebuilding from
    :meth:`to_host_edges` — the invariant tests/test_backend.py pins.
    """

    def __init__(
        self,
        src: np.ndarray,
        dst: np.ndarray,
        n: int,
        *,
        shards: int,
        capacity_per_shard: int | None = None,
        version: int = 0,
    ):
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        self.n = int(n)
        self.shards = int(shards)
        self.n_pad = pad_to_multiple(self.n, self.shards)
        self.rows = self.n_pad // self.shards
        shard_of = dst // self.rows
        counts = np.bincount(shard_of, minlength=self.shards).astype(np.int64)
        e_cap = int(capacity_per_shard or 0)
        e_cap = max(e_cap, int(counts.max()) if len(src) else 1, 1)
        self._src_sh = np.full((self.shards, e_cap), -1, dtype=np.int32)
        self._dst_sh = np.full((self.shards, e_cap), -1, dtype=np.int32)
        self._counts = counts
        order = np.argsort(shard_of, kind="stable")  # FIFO within shard
        src_o, dst_o = src[order], dst[order]
        starts = np.zeros(self.shards + 1, dtype=np.int64)
        np.cumsum(counts, out=starts[1:])
        for s in range(self.shards):
            lo, hi = starts[s], starts[s + 1]
            self._src_sh[s, : hi - lo] = src_o[lo:hi]
            self._dst_sh[s, : hi - lo] = dst_o[lo:hi]
        self.version = int(version)
        self.overflow = False
        self._device = None  # (ShardedGraph, RingGraph | None) cache

    # -- snapshot ------------------------------------------------------------

    @property
    def capacity_per_shard(self) -> int:
        return self._src_sh.shape[1]

    @property
    def num_edges(self) -> int:
        return int(self._counts.sum())

    def to_host_edges(self) -> tuple[np.ndarray, np.ndarray]:
        """Live edges, shard-major with per-shard FIFO order.

        This order is the fixpoint of the partitioner: re-partitioning it
        reproduces the exact per-shard sequences, so a state rebuilt from
        ``to_host_edges()`` has bit-identical device mirrors.
        """
        src = np.concatenate(
            [self._src_sh[s, : self._counts[s]] for s in range(self.shards)]
        )
        dst = np.concatenate(
            [self._dst_sh[s, : self._counts[s]] for s in range(self.shards)]
        )
        return src, dst

    def host_in_degrees(self) -> np.ndarray:
        _, dst = self.to_host_edges()
        return np.bincount(dst, minlength=self.n)[: self.n]

    # -- shard-wise updates --------------------------------------------------

    def apply_ops(
        self, src: np.ndarray, dst: np.ndarray, insert: bool
    ) -> np.ndarray:
        """Apply one re-partitioned homogeneous batch; per-op applied mask."""
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        applied = np.zeros(src.shape[0], dtype=bool)
        if src.shape[0] == 0:
            return applied
        shard_of, touched = partition_ops_by_dst(
            dst, self.n_pad, self.shards
        )
        for s in touched:
            idx = np.where(shard_of == s)[0]
            if insert:
                free = self.capacity_per_shard - int(self._counts[s])
                take = idx[:free]
                c = int(self._counts[s])
                self._src_sh[s, c : c + len(take)] = src[take]
                self._dst_sh[s, c : c + len(take)] = dst[take]
                self._counts[s] += len(take)
                applied[take] = True
                if len(take) < len(idx):
                    self.overflow = True  # sticky; skipped ops stay unapplied
            else:
                # vectorized first-match delete (same ``apply_batch``
                # batch semantics: at most ONE live copy removed per
                # (src, dst) pair per batch — the session's occurrence
                # split feeds duplicate pairs in separate batches).
                # Stable argsort + searchsorted finds each pair's
                # earliest (FIFO) live slot in one pass instead of an
                # O(ops x live) python scan.
                c = int(self._counts[s])
                live_s = self._src_sh[s, :c]
                live_d = self._dst_sh[s, :c]
                base = np.int64(self.n + 1)
                live_keys = live_s.astype(np.int64) * base + live_d
                op_keys = src[idx].astype(np.int64) * base + dst[idx]
                first_of_pair = np.zeros(len(idx), dtype=bool)
                first_of_pair[np.unique(op_keys, return_index=True)[1]] = True
                order = np.argsort(live_keys, kind="stable")
                pos = np.searchsorted(live_keys[order], op_keys)
                cand = np.where(first_of_pair & (pos < c))[0]
                hit = cand[live_keys[order[pos[cand]]] == op_keys[cand]]
                if len(hit):
                    kill = np.zeros(c, dtype=bool)
                    kill[order[pos[hit]]] = True
                    applied[idx[hit]] = True
                    keep = ~kill  # stable compaction: FIFO order preserved
                    nk = int(keep.sum())
                    self._src_sh[s, :nk] = live_s[keep]
                    self._dst_sh[s, :nk] = live_d[keep]
                    self._src_sh[s, nk:c] = -1
                    self._dst_sh[s, nk:c] = -1
                    self._counts[s] = nk
        if applied.any():
            self.version += 1  # once per batch that changed the graph
            self._device = None
        return applied

    def regrow(self, *, capacity_per_shard: int | None = None,
               growth: float = 2.0) -> None:
        """Double (or set) per-shard capacity; clears ``overflow``,
        preserves ``version`` and the per-shard FIFO order."""
        new_cap = int(
            capacity_per_shard
            or max(int(self.capacity_per_shard * growth),
                   self.capacity_per_shard + 1)
        )
        if new_cap > self.capacity_per_shard:
            grown_s = np.full((self.shards, new_cap), -1, dtype=np.int32)
            grown_d = np.full((self.shards, new_cap), -1, dtype=np.int32)
            grown_s[:, : self.capacity_per_shard] = self._src_sh
            grown_d[:, : self.capacity_per_shard] = self._dst_sh
            self._src_sh, self._dst_sh = grown_s, grown_d
            self._device = None
        self.overflow = False

    # -- device mirrors ------------------------------------------------------

    def device_graphs(self, *, edge_chunks: int, want_ring: bool):
        """The device-resident mirrors, rebuilt lazily after updates."""
        if self._device is None:
            from repro.core.distributed import build_sharded_graph

            src, dst = self.to_host_edges()
            dcount = max(len(jax.devices()), 1)
            # generous edge padding + m normalized to m_pad: the compiled
            # serve steps key on the device mirror's static metadata, so
            # update batches that stay within one padded capacity band
            # reuse the same executable instead of recompiling per edge
            sg = build_sharded_graph(
                src, dst, self.n,
                pad_nodes=self.shards,
                # the band floor must stay divisible by edge_chunks or
                # _push_chunked's reshape assertion fires
                pad_edges=max(edge_chunks * dcount,
                              pad_to_multiple(1024, edge_chunks)),
            )
            sg = sg.replace(m=sg.m_pad)
            rg = None
            if want_ring:
                rg = self._build_ring(src, dst)
            self._device = (sg, rg)
        elif want_ring and self._device[1] is None:
            src, dst = self.to_host_edges()
            self._device = (self._device[0], self._build_ring(src, dst))
        return self._device

    def _build_ring(self, src: np.ndarray, dst: np.ndarray):
        from repro.core.ring import build_ring_graph

        rg = build_ring_graph(src, dst, self.n, shards=self.shards)
        # m normalized to the padded indices length for the same
        # compiled-step-reuse reason as the ShardedGraph mirror above
        return rg.replace(m=int(rg.indices.shape[0]))


# ---------------------------------------------------------------------------
# Sharded backend — mesh execution behind the same contract
# ---------------------------------------------------------------------------


class ShardedBackend:
    """Mesh-sharded execution: dst-partitioned graph, distributed probe.

    Construct from a :class:`GraphHandle` (``GraphHandle.shard`` does
    exactly this) or an existing :class:`ShardedGraphState`.  ``shards``
    is the row-partition count = the mesh's ``model`` extent; the mesh
    defaults to ``(n_devices // shards, shards)`` over ``("data",
    "model")`` — walk columns shard over ``data``, frontier rows over
    ``model`` (the core/distributed.py layout).

    Serving loops *walk-chunks*: each chunk samples ``<= walk_chunk``
    walks per query on device (per-query streams via
    ``fold_in(stream, chunk)``), runs the distributed telescoped probe —
    auto-partitioned (``probe='spmd'``) or the shard_map ring
    (``probe='ring'``) — and folds per-query partial counts on host.
    The epilogue (1/n_r, truncation shift, diagonal fix, top-k) matches
    the local path's conventions so results are tolerance-comparable.

    The fused update->query epoch is not offered here
    (``supports_epoch=False``): its donated-buffer contract is a
    single-device optimization with no mesh analogue yet.
    """

    name = "sharded"
    supports_epoch = False
    variants = ("auto", "telescoped")

    def __init__(
        self,
        state: ShardedGraphState | GraphHandle,
        *,
        params: ProbeSimParams,
        shards: int | None = None,
        mesh=None,
        walk_chunk: int = 128,
        probe: str = "spmd",
        edge_chunks: int = 4,
        capacity_per_shard: int | None = None,
        use_kernel: bool = False,
    ):
        if probe not in ("spmd", "ring"):
            raise ValueError(f"probe must be 'spmd' or 'ring', got {probe!r}")
        if use_kernel:
            # refuse rather than silently serve the non-kernel mesh probe
            raise ValueError(
                "the sharded backend has no Pallas-kernel probe path; "
                "use_kernel=True is only available on the local backend"
            )
        if isinstance(state, GraphHandle):
            state = state.shard(
                shards=shards, mesh=mesh,
                capacity_per_shard=capacity_per_shard,
            )
        if shards is not None and shards != state.shards:
            raise ValueError(
                f"shards={shards} != state partitioned into {state.shards}"
            )
        self.state = state
        self.params = params
        self.walk_chunk = int(walk_chunk)
        self.probe = probe
        self.edge_chunks = int(edge_chunks)
        if mesh is None:
            ndev = len(jax.devices())
            s = state.shards
            if ndev % s:
                raise ValueError(
                    f"{s} shards need a device count divisible by {s}; "
                    f"have {ndev} (pass an explicit mesh= to override)"
                )
            mesh = make_mesh((ndev // s, s), ("data", "model"))
        if "model" not in mesh.axis_names:
            raise ValueError(
                f"ShardedBackend needs a mesh with a 'model' axis (frontier "
                f"rows shard over it); got axes {tuple(mesh.axis_names)}"
            )
        if mesh.shape["model"] != state.shards:
            raise ValueError(
                f"mesh model extent {mesh.shape['model']} != "
                f"shards {state.shards}"
            )
        self.mesh = mesh
        self._steps: dict = {}  # (Q, B) -> compiled chunk step

    # -- snapshot state ------------------------------------------------------

    @property
    def n(self) -> int:
        return self.state.n

    @property
    def version(self) -> int:
        return self.state.version

    @property
    def overflow(self) -> bool:
        return self.state.overflow

    def host_in_degrees(self) -> np.ndarray:
        return self.state.host_in_degrees()

    def dispatch_label(self, variant: str) -> str:
        """Envelope ``variant`` field: records the mesh path that served."""
        return f"sharded[{self.probe}]"

    def to_host_edges(self) -> tuple[np.ndarray, np.ndarray]:
        return self.state.to_host_edges()

    # -- updates (shard-wise) ------------------------------------------------

    def apply_ops(
        self, src: np.ndarray, dst: np.ndarray, insert: bool
    ) -> np.ndarray:
        return self.state.apply_ops(src, dst, insert)

    def regrow(self, **kwargs) -> None:
        # map GraphHandle.regrow's kwargs onto per-shard capacity; k_max
        # has no ELL analogue here and capacity is per shard already
        kwargs.pop("k_max", None)
        cap = kwargs.pop("capacity", None)
        if cap is not None and "capacity_per_shard" not in kwargs:
            kwargs["capacity_per_shard"] = pad_to_multiple(
                int(cap), self.state.shards
            ) // self.state.shards
        if "capacity_per_shard" in kwargs:
            # an explicit total is split evenly; on a skewed dst
            # distribution that split can undershoot the hot shard's
            # current buffer — clamp so regrow always makes progress
            # (never clear the overflow flag without adding room)
            kwargs["capacity_per_shard"] = max(
                int(kwargs["capacity_per_shard"]),
                self.state.capacity_per_shard + 1,
            )
        self.state.regrow(**kwargs)

    # -- queries -------------------------------------------------------------

    def serve_one(self, spec: QuerySpec, key, *, variant: str, n_r: int) -> dict:
        est, idx, vals = self.serve_batch(
            spec.kind, [spec.node], jnp.stack([key]),
            k=spec.k or 0, n_r=n_r,
        )
        if spec.kind == "single_source":
            return dict(scores=est[0])
        return dict(topk_nodes=idx[0], topk_scores=vals[0])

    def serve_batch(
        self, kind: str, us, keys, *, key=None, k: int = 0, n_r: int
    ) -> tuple:
        """Chunked mesh dispatches + host epilogue; see class docstring."""
        us = np.asarray(us, np.int32).reshape(-1)
        q = us.shape[0]
        if keys is None:
            if key is None:
                raise ValueError("serve_batch needs `key` or per-query `keys`")
            keys = jax.random.split(key, q)  # legacy scalar-key semantics
        sg, rg = self.state.device_graphs(
            edge_chunks=self.edge_chunks, want_ring=self.probe == "ring"
        )
        us_dev = jnp.asarray(us)
        acc = np.zeros((q, self.n), np.float64)
        done = 0
        chunk_i = 0
        while done < n_r:
            b = min(self.walk_chunk, n_r - done)
            step = self._chunk_step(q, b, sg, rg)
            chunk_keys = jax.vmap(
                lambda kq: jax.random.fold_in(kq, chunk_i)
            )(keys)
            with set_mesh(self.mesh):
                part = step(rg if self.probe == "ring" else sg,
                            us_dev, chunk_keys)
            acc += np.asarray(part, np.float64)[:, : self.n]
            done += b
            chunk_i += 1
        est = (acc / n_r).astype(np.float32)
        p = self.params
        if p.truncation_shift:
            est = np.where(est > 0, est + p.eps_t / 2, est)
        est[np.arange(q), us] = 1.0  # same diagonal convention as local
        if kind == "single_source":
            return est, None, None
        masked = est.copy()
        masked[np.arange(q), us] = -np.inf
        idx = np.argsort(-masked, axis=1, kind="stable")[:, :k]
        vals = np.take_along_axis(masked, idx, axis=1)
        return None, idx.astype(np.int32), vals.astype(np.float32)

    def _chunk_step(self, q: int, b: int, sg, rg):
        """Compiled mesh step: (graph, us [Q], keys [Q]) -> counts [Q, n_pad].

        One step samples ``b`` walks per query (each query from its own
        folded stream) and probes all ``Q*b`` walk columns through the
        distributed telescoped push; compiled once per (Q, b, graph
        capacity band) shape.
        """
        shape_band = (
            (rg.n_pad, rg.src_sh.shape) if self.probe == "ring"
            else (sg.n_pad, sg.m_pad)
        )
        cache_key = (q, b, self.probe, shape_band)
        if cache_key in self._steps:
            return self._steps[cache_key]
        from repro.core.distributed import (
            graph_specs,
            probe_walks_sharded,
            sample_walks_sharded,
        )

        p = self.params
        sqrt_c = p.sqrt_c
        max_len = p.max_len
        eps_p = p.eps_p
        edge_chunks = self.edge_chunks
        use_ring = self.probe == "ring"

        def step(graph, us, keys):
            def sample_one(kq, u):
                return sample_walks_sharded(
                    kq, graph, u[None], walks_per_query=b,
                    max_len=max_len, sqrt_c=sqrt_c,
                )  # [b, L]

            walks = jax.vmap(sample_one)(keys, us).reshape(q * b, max_len)
            if use_ring:
                from repro.core.ring import probe_walks_ring

                scores = probe_walks_ring(
                    graph, walks, sqrt_c=sqrt_c, eps_p=eps_p
                )  # [n_pad, Q*b]
            else:
                scores = probe_walks_sharded(
                    graph, walks, sqrt_c=sqrt_c, eps_p=eps_p,
                    edge_chunks=edge_chunks,
                )
            n_pad = scores.shape[0]
            return scores.reshape(n_pad, q, b).sum(axis=2).T  # [Q, n_pad]

        with set_mesh(self.mesh):
            if use_ring:
                from repro.core.ring import ring_graph_specs

                gspecs = ring_graph_specs(rg)
            else:
                gspecs = graph_specs(sg)
            jitted = jax.jit(
                step,
                in_shardings=specs_to_shardings(
                    (gspecs, P(), P()), mesh=self.mesh
                ),
            )
        self._steps[cache_key] = jitted
        return jitted
