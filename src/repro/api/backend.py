"""Execution backends under :class:`~repro.api.session.SimRankSession`.

PR 3 unified the query/update surface into one session, but the session
could only *execute* one way: the single-device fused path.  The
distributed substrate (``core/distributed.py``'s auto-partitioned probe,
``core/ring.py``'s shard_map ring, ``graph/partition.py``) was a dead
island no user-facing API could reach.  This module is the bridge: a
``Backend`` protocol the session dispatches through, with two
implementations —

* :class:`LocalBackend` — the extraction of the session's original
  dispatch paths (``single_source``/``topk``/``multi_source*`` plus the
  coordinated :class:`GraphHandle` update path).  Bit-identical to the
  pre-backend session under shared keys: same core entry points, same
  pow-2 update bucketing, same compiled shapes.
* :class:`ShardedBackend` — the same ``QuerySpec -> ResultEnvelope``
  contract over a device mesh: destination-partitioned edge shards
  (:func:`repro.graph.partition.partition_edges_by_dst` bookkeeping via
  :class:`ShardedGraphState`), the distributed walk sampler + telescoped
  probe (``probe='spmd'``, the auto-partitioned baseline) or the
  shard_map ring push (``probe='ring'``), and dynamic updates applied
  shard-wise with the same version/overflow semantics as
  ``GraphHandle.apply_batch``.

The session stays the owner of everything *around* execution — specs,
PRNG streams, queues/tickets, stats, envelopes, the §4.4 planner — and
asks the backend only to (a) serve a batch, (b) apply an update
sub-batch, (c) recover capacity, (d) report snapshot state.  Both
backends batch differently behind that one surface: the local backend
fuses queries across lane columns of one compiled step; the sharded
backend loops ring walk-chunks over the mesh and folds partial counts on
host.

Randomness: both backends honor per-query PRNG streams.  The sharded
backend derives chunk keys as ``fold_in(stream, chunk_index)``, so its
answers are deterministic per (stream, graph snapshot) and independent
of batch composition — the same contract the local path tests pin —
but its draws are *different* draws than the local sampler's (different
walk-table layout), so cross-backend parity is tolerance-based, not
bit-identical (tests/test_backend.py).
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np

import jax
import jax.numpy as jnp
from repro.api.handle import GraphHandle
from repro.api.spec import QuerySpec
from repro.core.epoch import (
    build_shard_epoch_graph,
    epoch_step,
    make_sharded_epoch_step,
    make_sharded_serve_step,
)
from repro.core.multisource import multi_source, multi_source_topk
from repro.core.params import ProbeSimParams
from repro.core.probesim import single_source, topk
from repro.graph.dynamic import (
    UpdateBatch,
    apply_update_batch_jit,
    make_update_batch,
)
from repro.graph.partition import pad_to_multiple, partition_ops_by_dst
from repro.utils.jaxcompat import make_mesh, set_mesh

Array = jax.Array


def _hub_nodes_from_degrees(deg: np.ndarray, percentile: float) -> frozenset:
    """Nodes at or above the ``percentile``-th in-degree among positive
    degrees — the hub set the accuracy controller's probe cache targets
    (PRSim's power-law analysis: a few heavy hitters absorb most query
    traffic on skewed graphs, so their probe rows are worth sharing)."""
    if not 0.0 <= percentile <= 100.0:
        raise ValueError(f"percentile must be in [0, 100], got {percentile}")
    deg = np.asarray(deg)
    pos = deg[deg > 0]
    if pos.size == 0:
        return frozenset()
    thr = max(float(np.percentile(pos, percentile)), 1.0)
    return frozenset(int(u) for u in np.flatnonzero(deg >= thr))


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------


@runtime_checkable
class Backend(Protocol):
    """What the session needs from an execution substrate.

    Implementations own the graph state (device mirrors or sharded
    buffers) and the compiled serve steps; the session owns specs, PRNG
    streams, queues, stats and envelopes.  ``serve_batch`` is the one
    required query entry point (``serve_one`` has a default route through
    it on both shipped backends); updates arrive as homogeneous
    sub-batches (one ``insert`` flag per call, duplicate delete pairs
    already split by the session) and return a per-op applied mask with
    ``GraphHandle.apply_batch`` semantics: an unapplied insert means
    capacity overflow (sticky ``overflow``, recover via ``regrow``), an
    unapplied delete means the edge was absent.

    Backends that set ``supports_epoch`` additionally implement the fused
    epoch stage (``core.epoch``): ``epoch_batch`` applies one padded
    ``UpdateBatch`` and serves one query batch in a single compiled
    dispatch (zero host transfers in between) and ``own_buffers`` makes
    the backend's graph state exclusively owned (deep copy) — the session
    calls it at construction so donated epoch steps can never invalidate
    caller-held buffers.
    """

    name: str
    supports_epoch: bool
    variants: tuple[str, ...]

    @property
    def n(self) -> int: ...

    @property
    def version(self) -> int: ...

    @property
    def overflow(self) -> bool: ...

    def host_in_degrees(self) -> np.ndarray: ...

    def hub_nodes(self, percentile: float) -> frozenset: ...

    def dispatch_label(self, variant: str) -> str: ...

    def batch_dispatch_label(self, q: int) -> str: ...

    def epoch_dispatch_label(self) -> str: ...

    def serve_one(
        self, spec: QuerySpec, key, *, variant: str, n_r: int
    ) -> dict: ...

    def serve_batch(
        self, kind: str, us, keys, *, key=None, k: int = 0, n_r: int
    ) -> tuple: ...

    def apply_ops(
        self, src: np.ndarray, dst: np.ndarray, insert: bool
    ) -> np.ndarray: ...

    def regrow(self, **kwargs) -> None: ...

    def to_host_edges(self) -> tuple[np.ndarray, np.ndarray]: ...

    def own_buffers(self) -> None: ...

    def epoch_batch(
        self,
        batch: UpdateBatch,
        us,
        keys,
        *,
        n_r: int,
        top_k: int,
        lanes: int | None = None,
        use_kernel: bool | None = None,
    ) -> tuple: ...


# ---------------------------------------------------------------------------
# Local backend — the extracted single-device dispatch paths
# ---------------------------------------------------------------------------


class LocalBackend:
    """Single-device execution over an owned :class:`GraphHandle`.

    This is PR 3's session dispatch verbatim, moved behind the protocol:
    one-shot specs delegate to the core entry points (so an explicit
    ``spec.key`` reproduces the legacy calls bit-for-bit), batched specs
    run the fused multi-query step, updates go through the coordinated
    both-mirrors path with pow-2 bucketed batches.  The handle is shared
    with the session (``session.handle is backend.handle``), which keeps
    the fused epoch path — which donates and replaces the mirror buffers
    in place — working unchanged.
    """

    name = "local"
    supports_epoch = True
    variants = ("auto", "telescoped", "tree", "reference", "randomized")

    def __init__(
        self,
        handle: GraphHandle,
        *,
        params: ProbeSimParams,
        walk_chunk: int = 256,
        use_kernel: bool = False,
        kernel_dtype: str = "float32",
    ):
        if not isinstance(handle, GraphHandle):
            raise TypeError("LocalBackend takes a GraphHandle")
        if kernel_dtype not in ("float32", "bfloat16"):
            raise ValueError(
                f"kernel_dtype must be 'float32' or 'bfloat16', "
                f"got {kernel_dtype!r}"
            )
        self.handle = handle
        self.params = params
        self.walk_chunk = walk_chunk
        self.use_kernel = use_kernel
        self.kernel_dtype = kernel_dtype
        self._hubs: tuple | None = None  # ((version, percentile), frozenset)

    # -- snapshot state ------------------------------------------------------

    @property
    def n(self) -> int:
        return self.handle.n

    @property
    def version(self) -> int:
        return self.handle.version

    @property
    def overflow(self) -> bool:
        return self.handle.overflow

    def host_in_degrees(self) -> np.ndarray:
        return np.asarray(self.handle.eg.in_deg)

    def hub_nodes(self, percentile: float) -> frozenset:
        """High in-degree hub set, cached per (graph version, percentile)."""
        ck = (self.version, float(percentile))
        if self._hubs is None or self._hubs[0] != ck:
            self._hubs = (
                ck, _hub_nodes_from_degrees(self.host_in_degrees(), percentile)
            )
        return self._hubs[1]

    def dispatch_label(self, variant: str) -> str:
        """Envelope ``variant`` field: the legacy variant, verbatim."""
        return variant

    def batch_dispatch_label(self, q: int) -> str:
        """The fused local step serving a Q-query burst, lane count
        annotated (mirrors ``ShardedBackend.batch_dispatch_label``)."""
        return f"local[fused,Q={int(q)}]"

    def epoch_dispatch_label(self) -> str:
        """Envelope ``variant`` for epoch results (the fused local path)."""
        return "telescoped"

    def to_host_edges(self) -> tuple[np.ndarray, np.ndarray]:
        return self.handle.to_host_edges()

    # -- queries -------------------------------------------------------------

    def serve_one(self, spec: QuerySpec, key, *, variant: str, n_r: int) -> dict:
        """One single-node spec via the legacy entry points (bit-identical
        to ``single_source``/``topk`` under the same key)."""
        g, eg = self.handle.g, self.handle.eg
        p = (
            self.params
            if n_r == self.params.n_r
            else dataclasses.replace(self.params, n_r=n_r)
        )
        if spec.kind == "single_source":
            est = single_source(
                key, g, eg, spec.node, p, variant=variant,
                walk_chunk=self.walk_chunk, use_kernel=self.use_kernel,
            )
            return dict(scores=np.asarray(est))
        idx, vals = topk(
            key, g, eg, spec.node, spec.k, p, variant=variant,
            walk_chunk=self.walk_chunk, use_kernel=self.use_kernel,
        )
        return dict(topk_nodes=np.asarray(idx), topk_scores=np.asarray(vals))

    def serve_batch(
        self, kind: str, us, keys, *, key=None, k: int = 0, n_r: int
    ) -> tuple:
        """One fused multi-query dispatch; returns ``(est, idx, vals)``
        (est for single_source kind, idx/vals for topk — the unused pair
        is None).  Exactly one of ``keys`` ([Q] per-query streams) /
        ``key`` (scalar: legacy split semantics) is set."""
        g, eg = self.handle.g, self.handle.eg
        us = jnp.asarray(us, jnp.int32)
        common = dict(
            lanes=self.walk_chunk, n_r=n_r, keys=keys,
            use_kernel=self.use_kernel, kernel_dtype=self.kernel_dtype,
        )
        if kind == "topk":
            idx, vals = multi_source_topk(
                key, g, eg, us, k, self.params, **common
            )
            return None, np.asarray(idx), np.asarray(vals)
        est = multi_source(key, g, eg, us, self.params, **common)
        return np.asarray(est), None, None

    # -- updates -------------------------------------------------------------

    def apply_ops(
        self, src: np.ndarray, dst: np.ndarray, insert: bool
    ) -> np.ndarray:
        """Apply one homogeneous sub-batch through the coordinated
        both-mirrors path; pow-2 padded so variable-size bursts reuse a
        log-bounded set of compiled shapes."""
        bucket = 1 << (int(src.shape[0]) - 1).bit_length()
        batch = make_update_batch(
            src, dst, insert, batch_size=bucket, n=self.handle.n
        )
        return np.asarray(self.handle.apply_batch(batch))[: src.shape[0]]

    def regrow(self, **kwargs) -> None:
        self.handle.regrow(**kwargs)

    # -- fused epochs --------------------------------------------------------

    def own_buffers(self) -> None:
        """Deep-copy the handle so donated epoch steps touch no caller arrays."""
        self.handle = self.handle.copy()

    def epoch_batch(
        self,
        batch: UpdateBatch,
        us,
        keys,
        *,
        n_r: int,
        top_k: int,
        lanes: int | None = None,
        use_kernel: bool | None = None,
    ) -> tuple:
        """One fused local epoch: ``core.epoch.epoch_step`` over the owned
        mirrors (donated; the handle is replaced with the post-epoch
        snapshot).  ``us=None`` runs the update-only variant.  Returns
        ``(applied [B], est, idx, vals)`` as host arrays (est for
        ``top_k == 0``, idx/vals otherwise; the unused side is None).
        """
        h = self.handle
        if us is None:
            g2, eg2, applied = apply_update_batch_jit(h.g, h.eg, batch)
            h.g, h.eg = g2, eg2
            return np.asarray(applied), None, None, None
        p = self.params
        q = len(us)
        acc = jnp.zeros((q, h.n), jnp.float32)
        g2, eg2, applied, est, idx, vals = epoch_step(
            h.g, h.eg, batch, keys, jnp.asarray(us, jnp.int32), acc,
            n_r=n_r,
            lanes_q=max(1, (lanes or self.walk_chunk) // q),
            max_len=p.max_len,
            sqrt_c=p.sqrt_c,
            eps_p=p.eps_p,
            eps_t=p.eps_t,
            truncation_shift=p.truncation_shift,
            use_kernel=(
                self.use_kernel if use_kernel is None else use_kernel
            ),
            top_k=top_k,
        )
        if top_k:
            idx = np.asarray(idx)  # device sync (materializes g2/eg2)
            vals = np.asarray(vals)
            est = None
        else:
            est = np.asarray(est)
            idx = vals = None
        h.g, h.eg = g2, eg2
        return np.asarray(applied), est, idx, vals


# ---------------------------------------------------------------------------
# Sharded graph state — dst-partitioned host buffers + device mirrors
# ---------------------------------------------------------------------------


class ShardedGraphState:
    """Destination-partitioned edge state with GraphHandle-style dynamics.

    The authoritative copy is a pair of host buffers ``[S, E]`` (global
    src/dst ids, per-shard FIFO order, ``counts[s]`` live entries each) —
    exactly the layout :func:`partition_edges_by_dst` produces, plus
    capacity headroom.  Updates are applied *shard-wise*: an incoming
    batch is re-partitioned by destination shard (``dst // rows``) and
    each shard appends/deletes in its own buffer.  Semantics mirror
    ``GraphHandle.apply_batch``:

    * an insert applies iff its shard has room; a skipped insert sets the
      sticky ``overflow`` flag and is reported unapplied (never dropped);
    * a delete removes at most one live copy of its (src, dst) pair per
      *batch* — exactly ``apply_update_batch``'s contract; the session's
      occurrence split feeds duplicate pairs in separate batches — with
      stable compaction (FIFO order preserved) and a per-op found mask;
    * ``version`` advances by exactly one per batch that changed the
      graph; ``regrow`` doubles per-shard capacity, clears ``overflow``
      and preserves ``version`` (a representation change, not a graph
      change).

    Device mirrors (:class:`~repro.core.distributed.ShardedGraph`, and a
    :class:`~repro.core.ring.RingGraph` for the ring probe) are built
    lazily from the host buffers and invalidated on every applied batch;
    because partitioning is deterministic and per-shard order is FIFO,
    the incremental mirrors are bit-identical to rebuilding from
    :meth:`to_host_edges` — the invariant tests/test_backend.py pins.
    """

    def __init__(
        self,
        src: np.ndarray,
        dst: np.ndarray,
        n: int,
        *,
        shards: int,
        capacity_per_shard: int | None = None,
        version: int = 0,
    ):
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        self.n = int(n)
        self.shards = int(shards)
        self.n_pad = pad_to_multiple(self.n, self.shards)
        self.rows = self.n_pad // self.shards
        shard_of = dst // self.rows
        counts = np.bincount(shard_of, minlength=self.shards).astype(np.int64)
        e_cap = int(capacity_per_shard or 0)
        e_cap = max(e_cap, int(counts.max()) if len(src) else 1, 1)
        self._src_sh = np.full((self.shards, e_cap), -1, dtype=np.int32)
        self._dst_sh = np.full((self.shards, e_cap), -1, dtype=np.int32)
        self._counts = counts
        order = np.argsort(shard_of, kind="stable")  # FIFO within shard
        src_o, dst_o = src[order], dst[order]
        starts = np.zeros(self.shards + 1, dtype=np.int64)
        np.cumsum(counts, out=starts[1:])
        for s in range(self.shards):
            lo, hi = starts[s], starts[s + 1]
            self._src_sh[s, : hi - lo] = src_o[lo:hi]
            self._dst_sh[s, : hi - lo] = dst_o[lo:hi]
        self.version = int(version)
        self.overflow = False
        self._device = None  # (ShardedGraph, RingGraph | None) cache
        # bumped on every buffer/geometry mutation; the epoch path keys
        # its carried device mirror on it (stale counter => rebuild)
        self.mutations = 0

    # -- snapshot ------------------------------------------------------------

    @property
    def capacity_per_shard(self) -> int:
        return self._src_sh.shape[1]

    @property
    def num_edges(self) -> int:
        return int(self._counts.sum())

    def to_host_edges(self) -> tuple[np.ndarray, np.ndarray]:
        """Live edges, shard-major with per-shard FIFO order.

        This order is the fixpoint of the partitioner: re-partitioning it
        reproduces the exact per-shard sequences, so a state rebuilt from
        ``to_host_edges()`` has bit-identical device mirrors.
        """
        src = np.concatenate(
            [self._src_sh[s, : self._counts[s]] for s in range(self.shards)]
        )
        dst = np.concatenate(
            [self._dst_sh[s, : self._counts[s]] for s in range(self.shards)]
        )
        return src, dst

    def host_in_degrees(self) -> np.ndarray:
        _, dst = self.to_host_edges()
        return np.bincount(dst, minlength=self.n)[: self.n]

    def copy(self) -> "ShardedGraphState":
        """Deep copy (buffers nobody else references).

        ``to_host_edges`` is shard-major per-shard-FIFO, the fixpoint of
        the partitioner, so the copy's buffers are bit-identical.
        """
        st = ShardedGraphState(
            *self.to_host_edges(), self.n,
            shards=self.shards,
            capacity_per_shard=self.capacity_per_shard,
            version=self.version,
        )
        st.overflow = self.overflow
        return st

    # -- shard-wise updates --------------------------------------------------

    def apply_ops(
        self, src: np.ndarray, dst: np.ndarray, insert: bool
    ) -> np.ndarray:
        """Apply one re-partitioned homogeneous batch; per-op applied mask."""
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        applied = np.zeros(src.shape[0], dtype=bool)
        if src.shape[0] == 0:
            return applied
        shard_of, touched = partition_ops_by_dst(
            dst, self.n_pad, self.shards
        )
        for s in touched:
            idx = np.where(shard_of == s)[0]
            if insert:
                free = self.capacity_per_shard - int(self._counts[s])
                take = idx[:free]
                c = int(self._counts[s])
                self._src_sh[s, c : c + len(take)] = src[take]
                self._dst_sh[s, c : c + len(take)] = dst[take]
                self._counts[s] += len(take)
                applied[take] = True
                if len(take) < len(idx):
                    self.overflow = True  # sticky; skipped ops stay unapplied
            else:
                # vectorized first-match delete (same ``apply_batch``
                # batch semantics: at most ONE live copy removed per
                # (src, dst) pair per batch — the session's occurrence
                # split feeds duplicate pairs in separate batches).
                # Stable argsort + searchsorted finds each pair's
                # earliest (FIFO) live slot in one pass instead of an
                # O(ops x live) python scan.
                c = int(self._counts[s])
                live_s = self._src_sh[s, :c]
                live_d = self._dst_sh[s, :c]
                base = np.int64(self.n + 1)
                live_keys = live_s.astype(np.int64) * base + live_d
                op_keys = src[idx].astype(np.int64) * base + dst[idx]
                first_of_pair = np.zeros(len(idx), dtype=bool)
                first_of_pair[np.unique(op_keys, return_index=True)[1]] = True
                order = np.argsort(live_keys, kind="stable")
                pos = np.searchsorted(live_keys[order], op_keys)
                cand = np.where(first_of_pair & (pos < c))[0]
                hit = cand[live_keys[order[pos[cand]]] == op_keys[cand]]
                if len(hit):
                    kill = np.zeros(c, dtype=bool)
                    kill[order[pos[hit]]] = True
                    applied[idx[hit]] = True
                    keep = ~kill  # stable compaction: FIFO order preserved
                    nk = int(keep.sum())
                    self._src_sh[s, :nk] = live_s[keep]
                    self._dst_sh[s, :nk] = live_d[keep]
                    self._src_sh[s, nk:c] = -1
                    self._dst_sh[s, nk:c] = -1
                    self._counts[s] = nk
        if applied.any():
            self.version += 1  # once per batch that changed the graph
            self._device = None
            self.mutations += 1
        return applied

    def replay_applied(
        self,
        src: np.ndarray,
        dst: np.ndarray,
        insert: np.ndarray,
        applied: np.ndarray,
    ) -> None:
        """Mirror a device-applied epoch batch into the host buffers.

        The mesh epoch step applies updates on device
        (``core.epoch._shard_apply``); this replays its per-op decisions —
        applied deletes first (first live FIFO match per op), then applied
        inserts (append in stream order) — so the host buffers stay
        bit-identical to the carried device state without re-deriving the
        room checks.  ``version`` advances once iff anything applied; the
        caller folds the device overflow flag into the sticky host flag.
        """
        src = np.asarray(src).astype(np.int64, copy=False)
        dst = np.asarray(dst).astype(np.int64, copy=False)
        insert = np.asarray(insert, bool)
        applied = np.asarray(applied, bool)
        if not applied.any():
            return
        for i in np.where(applied & ~insert)[0]:
            s, d = int(src[i]), int(dst[i])
            sh = d // self.rows
            c = int(self._counts[sh])
            hit = np.where(
                (self._src_sh[sh, :c] == s) & (self._dst_sh[sh, :c] == d)
            )[0]
            if not len(hit):  # device said applied: the edge was live
                raise RuntimeError(
                    f"epoch replay: delete ({s}, {d}) not found on host "
                    f"shard {sh} — device/host state diverged"
                )
            j = int(hit[0])
            self._src_sh[sh, j : c - 1] = self._src_sh[sh, j + 1 : c].copy()
            self._dst_sh[sh, j : c - 1] = self._dst_sh[sh, j + 1 : c].copy()
            self._src_sh[sh, c - 1] = -1
            self._dst_sh[sh, c - 1] = -1
            self._counts[sh] -= 1
        for i in np.where(applied & insert)[0]:
            s, d = int(src[i]), int(dst[i])
            sh = d // self.rows
            c = int(self._counts[sh])
            if c >= self.capacity_per_shard:
                raise RuntimeError(
                    f"epoch replay: shard {sh} full on host but the device "
                    "applied an insert — device/host state diverged"
                )
            self._src_sh[sh, c] = s
            self._dst_sh[sh, c] = d
            self._counts[sh] += 1
        self.version += 1
        self._device = None
        self.mutations += 1

    def ensure_capacity(self, capacity_per_shard: int) -> None:
        """Grow per-shard buffers to at least ``capacity_per_shard``.

        Unlike :meth:`regrow` this is pure headroom bookkeeping: it never
        clears ``overflow`` and never touches ``version`` (the epoch path
        uses it to round capacity up to the probe's edge-chunk multiple).
        """
        new_cap = int(capacity_per_shard)
        if new_cap <= self.capacity_per_shard:
            return
        grown_s = np.full((self.shards, new_cap), -1, dtype=np.int32)
        grown_d = np.full((self.shards, new_cap), -1, dtype=np.int32)
        grown_s[:, : self.capacity_per_shard] = self._src_sh
        grown_d[:, : self.capacity_per_shard] = self._dst_sh
        self._src_sh, self._dst_sh = grown_s, grown_d
        self._device = None
        self.mutations += 1

    def regrow(self, *, capacity_per_shard: int | None = None,
               growth: float = 2.0) -> None:
        """Double (or set) per-shard capacity; clears ``overflow``,
        preserves ``version`` and the per-shard FIFO order."""
        new_cap = int(
            capacity_per_shard
            or max(int(self.capacity_per_shard * growth),
                   self.capacity_per_shard + 1)
        )
        if new_cap > self.capacity_per_shard:
            self.ensure_capacity(new_cap)
        self.overflow = False

    # -- device mirrors ------------------------------------------------------

    def device_graphs(self, *, edge_chunks: int, want_ring: bool):
        """The device-resident mirrors, rebuilt lazily after updates."""
        if self._device is None:
            from repro.core.distributed import build_sharded_graph

            src, dst = self.to_host_edges()
            dcount = max(len(jax.devices()), 1)
            # generous edge padding + m normalized to m_pad: the compiled
            # serve steps key on the device mirror's static metadata, so
            # update batches that stay within one padded capacity band
            # reuse the same executable instead of recompiling per edge
            sg = build_sharded_graph(
                src, dst, self.n,
                pad_nodes=self.shards,
                # the band floor must stay divisible by edge_chunks or
                # _push_chunked's reshape assertion fires
                pad_edges=max(edge_chunks * dcount,
                              pad_to_multiple(1024, edge_chunks)),
            )
            sg = sg.replace(m=sg.m_pad)
            rg = None
            if want_ring:
                rg = self._build_ring(src, dst)
            self._device = (sg, rg)
        elif want_ring and self._device[1] is None:
            src, dst = self.to_host_edges()
            self._device = (self._device[0], self._build_ring(src, dst))
        return self._device

    def _build_ring(self, src: np.ndarray, dst: np.ndarray):
        from repro.core.ring import build_ring_graph

        rg = build_ring_graph(src, dst, self.n, shards=self.shards)
        # m normalized to the padded indices length for the same
        # compiled-step-reuse reason as the ShardedGraph mirror above
        return rg.replace(m=int(rg.indices.shape[0]))


# ---------------------------------------------------------------------------
# Sharded backend — mesh execution behind the same contract
# ---------------------------------------------------------------------------


class ShardedBackend:
    """Mesh-sharded execution: dst-partitioned graph, distributed probe.

    Construct from a :class:`GraphHandle` (``GraphHandle.shard`` does
    exactly this) or an existing :class:`ShardedGraphState`.  ``shards``
    is the row-partition count = the mesh's ``model`` extent; the mesh
    defaults to ``(n_devices // shards, shards)`` over ``("data",
    "model")`` — walk columns shard over ``data``, frontier rows over
    ``model`` (the core/distributed.py layout).

    Serving is *lane-batched*: one compiled step per (Q, n_r, k) samples
    the whole batch's walk pool off the carried device-resident
    :class:`~repro.core.epoch.ShardEpochGraph` (the epoch path's mirror,
    keyed on the host mutation counter — repeated ``drain()`` serving
    reuses resident device state), runs the compacted telescoped lane
    probe inside shard_map — all-gather push (``probe='spmd'``) or the
    double-buffered ring exchange (``probe='ring'``) — and reduces
    per-query counts + top-k in the same program.  Zero host transfers
    mid-query; each query owns ``walk_chunk // Q`` lane columns (the
    local fused path's schedule, shared via ``core.multisource``).
    The epilogue (1/n_r, truncation shift, diagonal fix, top-k) matches
    the local path's conventions so results are tolerance-comparable.

    The fused update->query epoch runs on the mesh too
    (``supports_epoch=True``): ``epoch_batch`` drives
    ``core.epoch.make_sharded_epoch_step`` — a carried device-resident
    :class:`~repro.core.epoch.ShardEpochGraph` (dst-sharded COO buffers +
    row-sharded ELL mirror) is updated inside a shard_map step and probed
    by the distributed telescoped push in the same compiled program, with
    no host transfer between update and query.  The host
    ``ShardedGraphState`` stays authoritative by replaying the applied
    mask (``replay_applied``) after each epoch; any host-path mutation
    (``apply_ops``/``regrow``) invalidates the carried mirror, which is
    rebuilt from host on the next epoch — bit-identical to the carried
    state by the stable-FIFO invariant.
    """

    name = "sharded"
    supports_epoch = True
    variants = ("auto", "telescoped")

    def __init__(
        self,
        state: ShardedGraphState | GraphHandle,
        *,
        params: ProbeSimParams,
        shards: int | None = None,
        mesh=None,
        walk_chunk: int = 128,
        probe: str = "spmd",
        edge_chunks: int = 4,
        capacity_per_shard: int | None = None,
        use_kernel: bool = False,
        frontier_dtype: str = "float32",
    ):
        if probe not in ("spmd", "ring"):
            raise ValueError(f"probe must be 'spmd' or 'ring', got {probe!r}")
        if frontier_dtype not in ("float32", "bfloat16"):
            raise ValueError(
                f"frontier_dtype must be 'float32' or 'bfloat16', "
                f"got {frontier_dtype!r}"
            )
        if isinstance(state, GraphHandle):
            state = state.shard(
                shards=shards, mesh=mesh,
                capacity_per_shard=capacity_per_shard,
            )
        if shards is not None and shards != state.shards:
            raise ValueError(
                f"shards={shards} != state partitioned into {state.shards}"
            )
        self.state = state
        self.params = params
        self.walk_chunk = int(walk_chunk)
        self.probe = probe
        self.edge_chunks = int(edge_chunks)
        self.use_kernel = bool(use_kernel)
        self.frontier_dtype = frontier_dtype
        if mesh is None:
            ndev = len(jax.devices())
            s = state.shards
            if ndev % s:
                raise ValueError(
                    f"{s} shards need a device count divisible by {s}; "
                    f"have {ndev} (pass an explicit mesh= to override)"
                )
            mesh = make_mesh((ndev // s, s), ("data", "model"))
        if "model" not in mesh.axis_names:
            raise ValueError(
                f"ShardedBackend needs a mesh with a 'model' axis (frontier "
                f"rows shard over it); got axes {tuple(mesh.axis_names)}"
            )
        if mesh.shape["model"] != state.shards:
            raise ValueError(
                f"mesh model extent {mesh.shape['model']} != "
                f"shards {state.shards}"
            )
        self.mesh = mesh
        self._steps: dict = {}  # serve config -> compiled batched step
        # the carried device-resident epoch mirror (ShardEpochGraph) and
        # the host-state mutation counter it was last synced against
        self._epoch_graph = None
        self._epoch_sync = -1
        self._epoch_steps: dict = {}  # config -> compiled epoch step
        self._hubs: tuple | None = None  # ((version, percentile), frozenset)

    # -- snapshot state ------------------------------------------------------

    @property
    def n(self) -> int:
        return self.state.n

    @property
    def version(self) -> int:
        return self.state.version

    @property
    def overflow(self) -> bool:
        return self.state.overflow

    def host_in_degrees(self) -> np.ndarray:
        return self.state.host_in_degrees()

    def hub_nodes(self, percentile: float) -> frozenset:
        """High in-degree hub set, cached per (graph version, percentile)."""
        ck = (self.version, float(percentile))
        if self._hubs is None or self._hubs[0] != ck:
            self._hubs = (
                ck, _hub_nodes_from_degrees(self.host_in_degrees(), percentile)
            )
        return self._hubs[1]

    def dispatch_label(self, variant: str) -> str:
        """Envelope ``variant`` field: records the mesh path that served."""
        return f"sharded[{self.probe}]"

    def batch_dispatch_label(self, q: int) -> str:
        """The dispatch label annotated with the batch lane count — names
        the compiled step that serves a Q-query burst (one executable per
        (Q, n_r, k, probe, capacity band))."""
        return f"sharded[{self.probe},Q={int(q)}]"

    def epoch_dispatch_label(self) -> str:
        """Epoch envelopes record the path that actually served: the mesh
        epoch always telescopes through the spmd push (the ring layout's
        2-D edge buckets have no incremental maintenance yet — ROADMAP),
        so a ``probe="ring"`` backend must not stamp ring on epochs."""
        return "sharded[spmd]"

    def to_host_edges(self) -> tuple[np.ndarray, np.ndarray]:
        return self.state.to_host_edges()

    # -- updates (shard-wise) ------------------------------------------------

    def apply_ops(
        self, src: np.ndarray, dst: np.ndarray, insert: bool
    ) -> np.ndarray:
        return self.state.apply_ops(src, dst, insert)

    def regrow(self, **kwargs) -> None:
        # map GraphHandle.regrow's kwargs onto per-shard capacity; k_max
        # has no ELL analogue here and capacity is per shard already
        kwargs.pop("k_max", None)
        cap = kwargs.pop("capacity", None)
        if cap is not None and "capacity_per_shard" not in kwargs:
            kwargs["capacity_per_shard"] = pad_to_multiple(
                int(cap), self.state.shards
            ) // self.state.shards
        if "capacity_per_shard" in kwargs:
            # an explicit total is split evenly; on a skewed dst
            # distribution that split can undershoot the hot shard's
            # current buffer — clamp so regrow always makes progress
            # (never clear the overflow flag without adding room)
            kwargs["capacity_per_shard"] = max(
                int(kwargs["capacity_per_shard"]),
                self.state.capacity_per_shard + 1,
            )
        self.state.regrow(**kwargs)

    # -- fused epochs (device-resident shard buffers) ------------------------

    def own_buffers(self) -> None:
        """Deep-copy the graph state so epochs never mutate caller buffers."""
        self.state = self.state.copy()
        self._epoch_graph = None
        self._epoch_sync = -1

    def _epoch_graph_state(self):
        """The carried device epoch mirror, rebuilt when host state moved.

        Rebuild sizes the per-shard capacity up to the probe's edge-chunk
        multiple (growing the host buffers to match, so device and host
        room checks agree) and the ELL width to the current max in-degree
        plus headroom — an ELL-full insert therefore reports unapplied,
        sets overflow, and the session's regrow/retry loop makes progress
        on the rebuilt (wider) mirror.
        """
        if (
            self._epoch_graph is not None
            and self._epoch_sync == self.state.mutations
        ):
            return self._epoch_graph
        E = pad_to_multiple(
            max(self.state.capacity_per_shard, self.edge_chunks),
            self.edge_chunks,
        )
        self.state.ensure_capacity(E)
        # materialize the edge list ONCE — it feeds both the k_max sizing
        # and the builder (to_host_edges is an O(m) concatenation)
        src, dst = self.state.to_host_edges()
        deg_cap = (
            int(np.bincount(dst, minlength=self.state.n).max())
            if len(dst) else 0
        )
        st = build_shard_epoch_graph(
            src, dst, self.state.n,
            shards=self.state.shards,
            capacity_per_shard=self.state.capacity_per_shard,
            k_max=max(deg_cap + 8, 16),
        )
        self._epoch_graph = st
        self._epoch_sync = self.state.mutations
        return st

    def epoch_batch(
        self,
        batch: UpdateBatch,
        us,
        keys,
        *,
        n_r: int,
        top_k: int,
        lanes: int | None = None,
        use_kernel: bool | None = None,
    ) -> tuple:
        """One fused MESH epoch: shard_map update apply + distributed probe
        in a single compiled dispatch against the carried device mirror
        (donated per shard; no host transfer between update and query).
        The applied mask is replayed into the host ``ShardedGraphState``
        afterwards, keeping ``to_host_edges``/``version``/serving mirrors
        coherent.  Same return contract as ``LocalBackend.epoch_batch``.
        """
        st = self._epoch_graph_state()
        q = 0 if us is None else len(us)
        uk = self.use_kernel if use_kernel is None else bool(use_kernel)
        cfg = (
            q, n_r if q else 0, top_k if q else 0,
            bool(batch.has_deletes), st.capacity, st.k_max, uk,
        )
        step = self._epoch_steps.get(cfg)
        if step is None:
            p = self.params
            step = make_sharded_epoch_step(
                st, self.mesh,
                q=q, n_r=n_r if q else 1, top_k=top_k,
                max_len=p.max_len, sqrt_c=p.sqrt_c, eps_p=p.eps_p,
                eps_t=p.eps_t, truncation_shift=p.truncation_shift,
                walk_chunk=self.walk_chunk, edge_chunks=self.edge_chunks,
                has_deletes=bool(batch.has_deletes),
                use_kernel=uk,
            )
            self._epoch_steps[cfg] = step
        # host copies of the op stream BEFORE the dispatch (the replay
        # below must not read donated device buffers)
        b_src = np.asarray(batch.src)
        b_dst = np.asarray(batch.dst)
        b_ins = np.asarray(batch.insert)
        with set_mesh(self.mesh):
            if q:
                out = step(st, batch, jnp.asarray(us, jnp.int32), keys)
            else:
                out = step(st, batch)
        st2, applied, overflow, est, idx, vals = out
        applied = np.asarray(applied)
        self.state.replay_applied(b_src, b_dst, b_ins, applied)
        if bool(np.asarray(overflow)):
            self.state.overflow = True
        self._epoch_graph = st2
        self._epoch_sync = self.state.mutations
        if top_k and q:
            return applied, None, np.asarray(idx), np.asarray(vals)
        if q:
            return applied, np.asarray(est), None, None
        return applied, None, None, None

    # -- queries -------------------------------------------------------------

    def serve_one(self, spec: QuerySpec, key, *, variant: str, n_r: int) -> dict:
        est, idx, vals = self.serve_batch(
            spec.kind, [spec.node], jnp.stack([key]),
            k=spec.k or 0, n_r=n_r,
        )
        if spec.kind == "single_source":
            return dict(scores=est[0])
        return dict(topk_nodes=idx[0], topk_scores=vals[0])

    def serve_batch(
        self, kind: str, us, keys, *, key=None, k: int = 0, n_r: int
    ) -> tuple:
        """ONE lane-batched mesh dispatch per query batch.

        Pooled walk sampling for the whole batch, the compacted telescoped
        lane probe inside shard_map, per-query reduction + top-k — all in a
        single compiled step against the carried device-resident
        :class:`~repro.core.epoch.ShardEpochGraph` (the same mirror the
        epoch path carries, keyed on the host mutation counter, so repeated
        ``drain()``/ticket serving reuses resident device state instead of
        rebuilding from host buffers).  Compiled once per
        (Q, k, n_r, probe, capacity band); zero host transfers mid-query.
        """
        us = np.asarray(us, np.int32).reshape(-1)
        q = us.shape[0]
        if keys is None:
            if key is None:
                raise ValueError("serve_batch needs `key` or per-query `keys`")
            keys = jax.random.split(key, q)  # legacy scalar-key semantics
        st = self._epoch_graph_state()
        wq = max(1, self.walk_chunk // q)
        ring_args = ()
        ring_band = None
        if self.probe == "ring":
            # ring buckets have no incremental maintenance yet (ROADMAP);
            # the mutation-keyed device cache rebuilds them lazily
            _, rg = self.state.device_graphs(
                edge_chunks=self.edge_chunks, want_ring=True
            )
            ring_args = (rg.src_sh, rg.dst_sh)
            ring_band = rg.src_sh.shape
        cfg = (
            q, int(k), int(n_r), wq, self.probe,
            st.capacity, st.k_max, ring_band,
            self.use_kernel, self.frontier_dtype,
        )
        step = self._steps.get(cfg)
        if step is None:
            p = self.params
            step = make_sharded_serve_step(
                st, self.mesh,
                q=q, n_r=int(n_r), lanes_q=wq, top_k=int(k),
                max_len=p.max_len, sqrt_c=p.sqrt_c, eps_p=p.eps_p,
                eps_t=p.eps_t, truncation_shift=p.truncation_shift,
                probe=self.probe,
                use_kernel=self.use_kernel,
                frontier_dtype=self.frontier_dtype,
            )
            self._steps[cfg] = step
        with set_mesh(self.mesh):
            est, idx, vals = step(
                st, *ring_args, jnp.asarray(us), jnp.asarray(keys)
            )
        if kind == "single_source":
            return np.asarray(est), None, None
        return None, np.asarray(idx), np.asarray(vals)
