"""`StreamDriver` — clock-driven replay of a temporal edge stream.

The driver advances a **virtual clock** over an :class:`EventStream` in
fixed ticks: arrivals due in a tick are ingested into the sliding window
and queued as insert ops, TTL expiries come back as delete ops, and the
resulting backlog is cut into bounded update bursts interleaved with
query traffic.  Everything dispatches through a transport:

* :class:`SessionTransport` — a ``SimRankSession`` (local or sharded
  backend).  ``mode='epoch'`` rides the fused update->query epoch step
  (one compiled dispatch applies a burst AND answers the queries that
  share it); ``mode='drain'`` uses the immediate ``update()`` +
  submit/drain serve path.
* :class:`ServiceTransport` — the PR-8 network service
  (``serving/service.py``): updates through ``apply_update``, queries
  through the micro-batching admission window (with 429 backoff).

Per query the driver records **staleness** — the wall age of the oldest
ingested-but-unapplied op at answer time (0 when the backlog is drained)
— and **version lag** (how many ops the answered snapshot is behind),
reported at p50/p99 against a :class:`FreshnessSLO`.  Periodic pooled
checkpoints (:mod:`repro.streams.churn`) freeze the live window and score
the served answers against the §6.2 expert pool, so effectiveness is
reported alongside throughput while the graph churns.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

import jax

from repro.api.spec import QuerySpec
from repro.streams.churn import churn_checkpoint
from repro.streams.events import EventStream, SlidingWindowExpirer

__all__ = [
    "FreshnessSLO",
    "ServiceTransport",
    "SessionTransport",
    "StreamCheckpoint",
    "StreamDriver",
    "StreamReport",
]


@dataclass(frozen=True)
class FreshnessSLO:
    """Targets the staleness distribution must meet (``None`` = unchecked)."""

    staleness_p99_s: float = 0.25
    staleness_p50_s: float | None = None
    version_lag_p99: float | None = None


@dataclass
class StreamCheckpoint:
    """One pooled effectiveness checkpoint on the frozen live window."""

    t: float  # virtual time of the freeze
    live_edges: int
    queries: int
    pool_size: float
    precision_at_k: float
    ndcg_at_k: float

    def as_dict(self) -> dict:
        return dict(vars(self))


@dataclass
class StreamReport:
    """Outcome of one :meth:`StreamDriver.run`."""

    ticks: int = 0
    duration_s: float = 0.0  # wall time spent replaying
    arrivals: int = 0
    expired: int = 0
    updates_applied: int = 0
    update_steps: int = 0
    queries: int = 0
    qps: float = 0.0
    staleness_p50_s: float = 0.0
    staleness_p99_s: float = 0.0
    version_lag_p50: float = 0.0
    version_lag_p99: float = 0.0
    rejected_429: int = 0
    final_live_edges: int = 0
    sticky_overflow: bool = False
    slo: FreshnessSLO | None = None
    slo_met: bool | None = None
    checkpoints: list[StreamCheckpoint] = field(default_factory=list)

    @property
    def final_precision_at_k(self) -> float | None:
        return (
            self.checkpoints[-1].precision_at_k if self.checkpoints else None
        )

    def as_dict(self) -> dict:
        d = dict(vars(self))
        d["slo"] = None if self.slo is None else dict(vars(self.slo))
        d["checkpoints"] = [cp.as_dict() for cp in self.checkpoints]
        d["final_precision_at_k"] = self.final_precision_at_k
        return d


def _check_slo(slo: FreshnessSLO, rep: StreamReport) -> bool:
    ok = rep.staleness_p99_s <= slo.staleness_p99_s
    if slo.staleness_p50_s is not None:
        ok = ok and rep.staleness_p50_s <= slo.staleness_p50_s
    if slo.version_lag_p99 is not None:
        ok = ok and rep.version_lag_p99 <= slo.version_lag_p99
    return ok


@dataclass
class StreamAnswer:
    """One served top-k answer plus the snapshot it observed."""

    node: int
    topk_nodes: np.ndarray
    version: int


class SessionTransport:
    """Dispatch stream traffic through a ``SimRankSession``.

    ``mode='epoch'`` (the default where supported) queues ops and queries
    and drains fused update->query epochs; ``mode='drain'`` applies
    updates immediately and serves queries through submit/drain.  Works
    unchanged on the local and sharded backends — the session hides the
    mesh.
    """

    def __init__(self, session, *, mode: str = "drain"):
        if mode not in ("drain", "epoch"):
            raise ValueError(f"mode must be 'drain' or 'epoch', got {mode!r}")
        if mode == "epoch" and not getattr(
            session.backend, "supports_epoch", False
        ):
            raise ValueError(
                f"backend {session.backend.name!r} does not support the "
                "fused epoch path; use mode='drain'"
            )
        self.session = session
        self.mode = mode

    @property
    def label(self) -> str:
        return f"session[{self.session.backend.name}/{self.mode}]"

    @property
    def n(self) -> int:
        return self.session.backend.n

    @property
    def version(self) -> int:
        return self.session.version

    @property
    def overflow(self) -> bool:
        return self.session.overflow

    @property
    def sqrt_c(self) -> float:
        return float(self.session.params.sqrt_c)

    def step(
        self, runs, nodes, *, k: int, budget_walks: int
    ) -> tuple[int, list[StreamAnswer]]:
        """Apply op runs (stream-ordered ``(src, dst, insert)`` array
        triples) and answer top-k ``nodes`` against the post-burst state;
        returns (ops applied, answers)."""
        sess = self.session
        specs = [
            QuerySpec(kind="topk", node=int(u), k=k,
                      budget_walks=budget_walks)
            for u in nodes
        ]
        applied = 0
        if self.mode == "epoch":
            for src, dst, insert in runs:
                sess.queue_update(src, dst, insert=insert)
            for spec in specs:
                sess.submit(spec)
            envs = []
            for er in sess.drain_epochs():
                applied += er.updates_applied
                envs.extend(er.results)
        else:
            for src, dst, insert in runs:
                rep = (
                    sess.update(inserts=(src, dst))
                    if insert
                    else sess.update(deletes=(src, dst))
                )
                applied += rep.applied
            tickets = [sess.submit(spec) for spec in specs]
            envs = []
            if tickets:
                sess.drain()
                envs = [tk.envelope for tk in tickets]
        return applied, [
            StreamAnswer(
                node=int(env.node),
                topk_nodes=np.asarray(env.topk_nodes),
                version=int(env.version),
            )
            for env in envs
        ]


class ServiceTransport:
    """Dispatch stream traffic through a ``SimRankService`` (PR-8 front
    end): updates via ``apply_update`` (serialized against dispatch),
    queries through the micro-batching admission window.  Admission 429s
    back off by the service's ``Retry-After`` hint and retry; the count
    lands in the report."""

    def __init__(self, service, *, tenant: str = "stream",
                 max_retries: int = 16):
        self.service = service
        self.tenant = tenant
        self.max_retries = int(max_retries)
        self.rejected_429 = 0

    @property
    def label(self) -> str:
        return f"service[{self.service.backend_kind}]"

    @property
    def n(self) -> int:
        return self.service.n

    @property
    def version(self) -> int:
        return self.service.version

    @property
    def overflow(self) -> bool:
        return self.service.session(self.tenant).overflow

    @property
    def sqrt_c(self) -> float:
        return float(self.service.session(self.tenant).params.sqrt_c)

    def _enqueue(self, req):
        from repro.serving.service import AdmissionError

        for _ in range(self.max_retries):
            try:
                return self.service.enqueue(req, self.tenant)
            except AdmissionError as e:
                self.rejected_429 += 1
                time.sleep(min(e.retry_after_s, 0.05))
        raise RuntimeError(
            f"query rejected {self.max_retries} times by admission control"
        )

    def step(
        self, runs, nodes, *, k: int, budget_walks: int
    ) -> tuple[int, list[StreamAnswer]]:
        from repro.serving.protocol import QueryRequest

        applied = 0
        for src, dst, insert in runs:
            ops = np.stack(
                [np.asarray(src, np.int64), np.asarray(dst, np.int64)],
                axis=1,
            )
            rep = (
                self.service.apply_update(inserts=ops)
                if insert
                else self.service.apply_update(deletes=ops)
            )
            applied += rep["applied"]
        items = [
            self._enqueue(QueryRequest(
                kind="topk", node=int(u), k=k, budget_walks=budget_walks,
            ))
            for u in nodes
        ]
        answers = []
        for item in items:
            item.event.wait(timeout=self.service.config.response_timeout_s)
            if item.status != 200:
                raise RuntimeError(
                    f"stream query failed ({item.status}): {item.payload}"
                )
            answers.append(StreamAnswer(
                node=int(item.payload["node"]),
                topk_nodes=np.asarray(item.payload["topk_nodes"]),
                version=int(item.payload["version"]),
            ))
        return applied, answers


class StreamDriver:
    """Replay an :class:`EventStream` against a transport under a TTL
    window, interleaving bounded update bursts with query traffic.

    ``tick_s`` is the virtual-clock step: each tick ingests the arrivals
    it covers, expires the window, cuts the backlog into
    ``update_burst``-sized bursts, and spreads ``queries_per_tick`` top-k
    queries (nodes sampled from the live window) across the bursts.
    ``checkpoint_every`` > 0 freezes the window every that many ticks and
    runs a pooled effectiveness checkpoint (after draining the backlog,
    so quality measures accuracy, not staleness).
    """

    def __init__(
        self,
        transport,
        stream: EventStream,
        *,
        ttl: float,
        tick_s: float,
        queries_per_tick: int = 4,
        update_burst: int = 64,
        k: int = 10,
        budget_walks: int = 512,
        slo: FreshnessSLO | None = None,
        checkpoint_every: int = 0,
        checkpoint_queries: int = 4,
        expert_r: int = 2_000,
        fresh_budget: int = 2_048,
        seed: int = 0,
    ):
        if tick_s <= 0:
            raise ValueError(f"tick_s must be > 0, got {tick_s}")
        if update_burst < 1:
            raise ValueError(f"update_burst must be >= 1, got {update_burst}")
        if transport.n != stream.n:
            raise ValueError(
                f"transport graph has n={transport.n} but the stream was "
                f"generated for n={stream.n}"
            )
        self.transport = transport
        self.stream = stream
        self.ttl = float(ttl)
        self.tick_s = float(tick_s)
        self.queries_per_tick = int(queries_per_tick)
        self.update_burst = int(update_burst)
        self.k = int(k)
        self.budget_walks = int(budget_walks)
        self.slo = slo
        self.checkpoint_every = int(checkpoint_every)
        self.checkpoint_queries = int(checkpoint_queries)
        self.expert_r = int(expert_r)
        self.fresh_budget = int(fresh_budget)
        self.seed = int(seed)

    # -- helpers -------------------------------------------------------------

    @staticmethod
    def _runs(ops: list[tuple[float, int, int, bool]]):
        """Maximal same-type runs of (wall_due, src, dst, insert) ops, as
        the (src, dst, insert) array triples transports take — preserving
        exact stream order across the type boundaries."""
        runs = []
        i = 0
        while i < len(ops):
            j = i
            while j < len(ops) and ops[j][3] == ops[i][3]:
                j += 1
            runs.append((
                np.asarray([op[1] for op in ops[i:j]], np.int32),
                np.asarray([op[2] for op in ops[i:j]], np.int32),
                ops[i][3],
            ))
            i = j
        return runs

    def _sample_live_nodes(self, rng, expirer, count: int) -> np.ndarray:
        """Query nodes drawn from the live window's destination set (the
        nodes whose similarity neighbourhoods the window defines)."""
        _, dst = expirer.live_edges()
        if len(dst) == 0:
            return np.empty(0, np.int64)
        cand = np.unique(dst)
        return rng.choice(cand, size=min(count, len(cand)), replace=False)

    def _drain_backlog(self, backlog, rep: StreamReport) -> None:
        while backlog:
            burst = [backlog.popleft() for _ in range(
                min(self.update_burst, len(backlog))
            )]
            applied, _ = self.transport.step(
                self._runs(burst), (), k=self.k,
                budget_walks=self.budget_walks,
            )
            rep.updates_applied += applied
            rep.update_steps += 1

    # -- the replay loop -----------------------------------------------------

    def run(
        self, *, max_ticks: int | None = None, final_expire: bool = False
    ) -> StreamReport:
        from collections import deque

        rng = np.random.default_rng(self.seed)
        expirer = SlidingWindowExpirer(self.ttl)
        backlog: deque[tuple[float, int, int, bool]] = deque()
        rep = StreamReport(slo=self.slo)
        stalenesses: list[float] = []
        lags: list[int] = []
        n_ticks = int(np.ceil(self.stream.horizon / self.tick_s)) or 1
        if max_ticks is not None:
            n_ticks = min(n_ticks, max_ticks)
        pos = 0
        t = self.stream.t
        wall0 = time.monotonic()
        for tick in range(n_ticks):
            now_v = (tick + 1) * self.tick_s
            wall_due = time.monotonic()
            # arrivals due this tick -> window + insert ops
            j = int(np.searchsorted(t, now_v, side="right"))
            if j > pos:
                expirer.ingest(t[pos:j], self.stream.src[pos:j],
                               self.stream.dst[pos:j])
                for i in range(pos, j):
                    backlog.append((wall_due, int(self.stream.src[i]),
                                    int(self.stream.dst[i]), True))
                rep.arrivals += j - pos
                pos = j
            # TTL expiries -> delete ops (oldest first: the FIFO order the
            # bitwise window==rebuild invariant rides on)
            es, ed = expirer.expire_until(now_v)
            for s, d in zip(es, ed):
                backlog.append((wall_due, int(s), int(d), False))
            rep.expired += len(es)
            # interleave: spread this tick's queries across the bursts
            q_nodes = self._sample_live_nodes(
                rng, expirer, self.queries_per_tick
            )
            n_sub = max(1, -(-len(backlog) // self.update_burst))
            q_splits = np.array_split(q_nodes, n_sub)
            for sub in range(n_sub):
                burst = [backlog.popleft() for _ in range(
                    min(self.update_burst, len(backlog))
                )]
                nodes = q_splits[sub] if sub < len(q_splits) else ()
                if not burst and len(nodes) == 0:
                    continue
                applied, answers = self.transport.step(
                    self._runs(burst), nodes, k=self.k,
                    budget_walks=self.budget_walks,
                )
                rep.updates_applied += applied
                if burst:
                    rep.update_steps += 1
                t_done = time.monotonic()
                stale = (t_done - backlog[0][0]) if backlog else 0.0
                for _ in answers:
                    stalenesses.append(stale)
                    lags.append(len(backlog))
                rep.queries += len(answers)
            # pooled effectiveness checkpoint on the frozen window
            if (
                self.checkpoint_every
                and (tick + 1) % self.checkpoint_every == 0
                and expirer.live
            ):
                self._drain_backlog(backlog, rep)
                self._checkpoint(rng, expirer, now_v, rep)
            rep.ticks += 1
        if final_expire:
            # retire the whole window (warmup hygiene / teardown): every
            # surviving edge expires and the backlog drains to empty
            wall_due = time.monotonic()
            es, ed = expirer.expire_until(n_ticks * self.tick_s + self.ttl)
            for s, d in zip(es, ed):
                backlog.append((wall_due, int(s), int(d), False))
            rep.expired += len(es)
            self._drain_backlog(backlog, rep)
        rep.duration_s = time.monotonic() - wall0
        rep.qps = rep.queries / rep.duration_s if rep.duration_s else 0.0
        if stalenesses:
            rep.staleness_p50_s = float(np.percentile(stalenesses, 50))
            rep.staleness_p99_s = float(np.percentile(stalenesses, 99))
            rep.version_lag_p50 = float(np.percentile(lags, 50))
            rep.version_lag_p99 = float(np.percentile(lags, 99))
        rep.rejected_429 = getattr(self.transport, "rejected_429", 0)
        rep.final_live_edges = expirer.live
        rep.sticky_overflow = bool(self.transport.overflow)
        if self.slo is not None:
            rep.slo_met = _check_slo(self.slo, rep)
        return rep

    def _checkpoint(self, rng, expirer, now_v, rep: StreamReport) -> None:
        nodes = self._sample_live_nodes(rng, expirer, self.checkpoint_queries)
        if len(nodes) == 0:
            return
        _, answers = self.transport.step(
            (), nodes, k=self.k, budget_walks=self.budget_walks,
        )
        src, dst = expirer.live_edges()
        out = churn_checkpoint(
            jax.random.key(self.seed + len(rep.checkpoints)),
            src, dst, self.transport.n,
            {a.node: a.topk_nodes for a in answers},
            self.k,
            sqrt_c=self.transport.sqrt_c,
            expert_r=self.expert_r,
            fresh_budget=self.fresh_budget,
        )
        rep.checkpoints.append(StreamCheckpoint(
            t=float(now_v),
            live_edges=out["live_edges"],
            queries=out["queries"],
            pool_size=out["pool_size"],
            precision_at_k=out["precision_at_k"],
            ndcg_at_k=out["ndcg_at_k"],
        ))
