"""Effectiveness under churn: pooled ground-truth checkpoints on the
frozen live window (paper §6.2 protocol, ``core/pooling.py``).

When the graph churns faster than any exact oracle can follow, quality is
judged the way the paper judges billion-edge runs: freeze the live
window, pool the candidates returned by the system under test together
with a fresh-rebuild scout (a from-scratch session over the frozen
window, so the pool contains whatever a non-stale system would have
found), score the pool with the high-precision Monte Carlo expert, and
report precision@k / NDCG of the served answers against the expert's
best-k.  A stale or under-budgeted server scores low because the scout
put the right candidates in the pool.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.api.handle import GraphHandle
from repro.api.session import SimRankSession
from repro.api.spec import QuerySpec
from repro.core.pooling import evaluate_with_pool

__all__ = ["churn_checkpoint", "frozen_window_handle"]


def _pow2(x: int) -> int:
    return 1 << max(0, int(np.ceil(np.log2(max(1, x)))))


def frozen_window_handle(
    src: np.ndarray, dst: np.ndarray, n: int
) -> GraphHandle:
    """A from-scratch handle over the frozen window, with pow-2 rounded
    capacity / k_max so successive checkpoints reuse compiled shapes."""
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    k_max = int(np.bincount(dst, minlength=n).max()) + 1 if len(dst) else 1
    return GraphHandle.from_edges(
        src, dst, n,
        capacity=_pow2(max(len(src), 16)),
        k_max=_pow2(k_max),
    )


def churn_checkpoint(
    key,
    src: np.ndarray,
    dst: np.ndarray,
    n: int,
    served: dict[int, np.ndarray],
    k: int,
    *,
    sqrt_c: float,
    expert_r: int = 2_000,
    fresh_budget: int = 2_048,
    max_len: int = 16,
    c: float | None = None,
) -> dict:
    """Pooled effectiveness of ``served`` top-k lists on one frozen window.

    ``served`` maps query node -> the top-k node ids the live system
    answered with (through whatever transport it serves).  Returns mean
    precision@k / NDCG over the queries plus the mean pool size.
    """
    if not served:
        raise ValueError("churn_checkpoint needs at least one served query")
    handle = frozen_window_handle(src, dst, n)
    cc = c if c is not None else sqrt_c * sqrt_c
    scout = SimRankSession(
        handle, c=cc, top_k=min(k, n - 1), seed=7, batch_q=len(served),
    )
    tickets = {
        u: scout.submit(QuerySpec(
            kind="topk", node=int(u), k=k, budget_walks=fresh_budget,
        ))
        for u in served
    }
    scout.drain()
    prec, ndcg, pools = [], [], []
    for i, (u, nodes) in enumerate(sorted(served.items())):
        fresh = np.asarray(tickets[u].envelope.topk_nodes)[:k]
        out = evaluate_with_pool(
            jax.random.fold_in(key, i),
            handle.eg,
            int(u),
            {"stream": np.asarray(nodes)[:k], "fresh": fresh},
            k,
            expert_r=expert_r,
            sqrt_c=sqrt_c,
            max_len=max_len,
        )
        prec.append(out["stream"]["precision"])
        ndcg.append(out["stream"]["ndcg"])
        pools.append(len(np.union1d(np.asarray(nodes)[:k], fresh)))
    return dict(
        queries=len(served),
        live_edges=int(len(src)),
        precision_at_k=float(np.mean(prec)),
        ndcg_at_k=float(np.mean(ndcg)),
        pool_size=float(np.mean(pools)),
    )
