"""Temporal event model: timestamped edge arrivals + sliding-window expiry.

ProbeSim is index-free, so a time-varying graph (the Dynamical SimRank
setting, arxiv 1711.00121) costs only the update batches themselves.  This
module supplies the workload half of that story:

* :class:`EventStream` — a time-ordered SoA of timestamped edge arrivals,
  produced by the arrival-process generators (:func:`poisson_edge_stream`,
  :func:`bursty_edge_stream`, :func:`preferential_attachment_stream`).

* :class:`SlidingWindowExpirer` — turns a TTL horizon into delete-heavy
  update batches: every edge older than ``ttl`` is expired FIFO (oldest
  first), so the deletes it derives hit the FIRST live copy of each pair
  in the edge buffer.  Because ``graph/dynamic.py``'s coordinated apply
  deletes by first match with stable compaction and appends inserts, the
  maintained COO+ELL mirrors stay **bit-identical** to rebuilding the live
  window from scratch in arrival order (the invariant
  ``tests/test_streams.py`` pins).

Everything here is host-side numpy — device work happens downstream in
whatever applies the derived batches (session, epoch step, or service).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

from repro.graph.dynamic import UpdateBatch, make_update_batch

__all__ = [
    "EdgeEvent",
    "EventStream",
    "SlidingWindowExpirer",
    "bursty_edge_stream",
    "poisson_edge_stream",
    "preferential_attachment_stream",
]


@dataclass(frozen=True)
class EdgeEvent:
    """One timestamped edge operation (``insert=False`` is a deletion)."""

    t: float
    src: int
    dst: int
    insert: bool = True


class EventStream:
    """Time-ordered edge arrivals in SoA form (``t`` float64, ids int32).

    Generators produce *arrival* streams (inserts only); deletions are
    derived downstream by a :class:`SlidingWindowExpirer` TTL horizon, so
    the stream itself stays a pure record of what arrived when.
    """

    __slots__ = ("t", "src", "dst", "n")

    def __init__(self, t, src, dst, n: int):
        self.t = np.asarray(t, np.float64).reshape(-1)
        self.src = np.asarray(src, np.int32).reshape(-1)
        self.dst = np.asarray(dst, np.int32).reshape(-1)
        self.n = int(n)
        if not (len(self.t) == len(self.src) == len(self.dst)):
            raise ValueError(
                f"ragged event stream: t={len(self.t)} src={len(self.src)} "
                f"dst={len(self.dst)}"
            )
        if len(self.t) and np.any(np.diff(self.t) < 0):
            raise ValueError("event times must be nondecreasing")
        if len(self.src):
            lo = min(int(self.src.min()), int(self.dst.min()))
            hi = max(int(self.src.max()), int(self.dst.max()))
            if lo < 0 or hi >= self.n:
                raise ValueError(
                    f"event endpoints out of range [0, {self.n}): "
                    f"saw [{lo}, {hi}]"
                )

    def __len__(self) -> int:
        return len(self.t)

    @property
    def horizon(self) -> float:
        """Timestamp of the last arrival (0.0 for an empty stream)."""
        return float(self.t[-1]) if len(self.t) else 0.0

    def events(self) -> Iterator[EdgeEvent]:
        for i in range(len(self.t)):
            yield EdgeEvent(
                float(self.t[i]), int(self.src[i]), int(self.dst[i])
            )

    def slice_time(self, lo: float, hi: float) -> "EventStream":
        """Arrivals with ``lo < t <= hi`` (half-open, replay-tick shaped)."""
        a = int(np.searchsorted(self.t, lo, side="right"))
        b = int(np.searchsorted(self.t, hi, side="right"))
        return EventStream(self.t[a:b], self.src[a:b], self.dst[a:b], self.n)


def _endpoints(rng: np.random.Generator, n: int, m: int):
    """m uniform self-loop-free (src, dst) pairs (dst resampled by offset)."""
    src = rng.integers(0, n, size=m, dtype=np.int64)
    # dst != src without rejection: a uniform nonzero offset mod n
    dst = (src + rng.integers(1, n, size=m, dtype=np.int64)) % n
    return src.astype(np.int32), dst.astype(np.int32)


def poisson_edge_stream(
    n: int, rate: float, horizon: float, *, seed: int = 0
) -> EventStream:
    """Steady-state arrivals: a Poisson process at ``rate`` edges per
    virtual second over ``[0, horizon]``, uniform self-loop-free endpoints.
    """
    if n < 2:
        raise ValueError(f"need n >= 2 nodes, got {n}")
    if rate <= 0 or horizon <= 0:
        raise ValueError("rate and horizon must be > 0")
    rng = np.random.default_rng(seed)
    # draw in chunks of the expected count until past the horizon
    t: list[np.ndarray] = []
    last = 0.0
    expect = max(16, int(rate * horizon * 1.1))
    while last <= horizon:
        gaps = rng.exponential(1.0 / rate, size=expect)
        chunk = last + np.cumsum(gaps)
        t.append(chunk)
        last = float(chunk[-1])
    ts = np.concatenate(t)
    ts = ts[ts <= horizon]
    src, dst = _endpoints(rng, n, len(ts))
    return EventStream(ts, src, dst, n)


def bursty_edge_stream(
    n: int,
    *,
    rate_on: float,
    rate_off: float = 0.0,
    mean_on: float,
    mean_off: float,
    horizon: float,
    seed: int = 0,
) -> EventStream:
    """On/off modulated Poisson arrivals: exponentially-distributed ON
    phases at ``rate_on`` alternate with OFF phases at ``rate_off``
    (default silent), starting ON at t=0.  Models burst ingest — the
    workload shape that stresses the admission/staleness path.
    """
    if n < 2:
        raise ValueError(f"need n >= 2 nodes, got {n}")
    if rate_on <= 0 or mean_on <= 0 or mean_off <= 0 or horizon <= 0:
        raise ValueError("rate_on, mean_on, mean_off, horizon must be > 0")
    rng = np.random.default_rng(seed)
    t: list[np.ndarray] = []
    now, on = 0.0, True
    while now < horizon:
        dur = float(rng.exponential(mean_on if on else mean_off))
        end = min(now + dur, horizon)
        rate = rate_on if on else rate_off
        if rate > 0:
            count = rng.poisson(rate * (end - now))
            if count:
                t.append(np.sort(rng.uniform(now, end, size=count)))
        now, on = end, not on
    ts = np.concatenate(t) if t else np.empty(0, np.float64)
    src, dst = _endpoints(rng, n, len(ts))
    return EventStream(ts, src, dst, n)


def preferential_attachment_stream(
    n: int,
    rate: float,
    horizon: float,
    *,
    seed: int = 0,
    p_uniform: float = 0.25,
) -> EventStream:
    """Growth arrivals with rich-get-richer destinations: each new edge
    copies the destination of a uniformly random earlier edge with
    probability ``1 - p_uniform`` (degree-proportional attachment without
    maintaining a degree table), else picks a uniform node — so the
    windowed in-degree distribution is heavy-tailed like real graphs.
    """
    if not 0.0 < p_uniform <= 1.0:
        raise ValueError(f"p_uniform must be in (0, 1], got {p_uniform}")
    base = poisson_edge_stream(n, rate, horizon, seed=seed)
    m = len(base)
    if m == 0:
        return base
    rng = np.random.default_rng(seed + 1)
    uniform = rng.random(m) < p_uniform
    ref = (rng.random(m) * np.arange(m)).astype(np.int64)  # ref[i] < i
    dst = base.dst.copy()
    for i in range(1, m):
        if not uniform[i]:
            dst[i] = dst[ref[i]]
    # keep self-loop-freedom after copying
    clash = dst == base.src
    if clash.any():
        dst[clash] = (base.src[clash] + 1) % n
    return EventStream(base.t, base.src, dst, n)


class SlidingWindowExpirer:
    """FIFO TTL window over an arrival stream, emitting delete batches.

    ``ingest`` records arrivals in stream order; ``expire_until(now)``
    pops every edge with ``t <= now - ttl`` **oldest first** and returns
    the (src, dst) delete ops.  Because deletion order matches buffer
    order, applying those ops through ``apply_update_batch`` (first-match
    delete, stable compaction) keeps the maintained mirrors bit-identical
    to a from-scratch rebuild of :meth:`live_edges` — the live window in
    arrival order.  ``expire_batches`` packages the same ops as
    sentinel-padded :class:`UpdateBatch` es directly.
    """

    def __init__(self, ttl: float):
        if not ttl > 0:
            raise ValueError(f"ttl must be > 0, got {ttl}")
        self.ttl = float(ttl)
        self._t: list[float] = []
        self._src: list[int] = []
        self._dst: list[int] = []
        self._head = 0  # first live index
        self._last_ingest = -np.inf
        self._last_now = -np.inf
        self.expired_total = 0

    # -- ingest --------------------------------------------------------------

    def ingest(self, t, src, dst) -> int:
        """Record arrivals (time-ordered within and across calls)."""
        t = np.asarray(t, np.float64).reshape(-1)
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        if not (len(t) == len(src) == len(dst)):
            raise ValueError("ragged ingest")
        if len(t) == 0:
            return 0
        if np.any(np.diff(t) < 0) or t[0] < self._last_ingest:
            raise ValueError("ingest times must be nondecreasing")
        self._last_ingest = float(t[-1])
        self._t.extend(t.tolist())
        self._src.extend(src.tolist())
        self._dst.extend(dst.tolist())
        return len(t)

    def ingest_stream(self, stream: EventStream) -> int:
        return self.ingest(stream.t, stream.src, stream.dst)

    # -- expiry --------------------------------------------------------------

    def expire_until(self, now: float) -> tuple[np.ndarray, np.ndarray]:
        """Delete ops (src, dst) for every edge with ``t <= now - ttl``,
        oldest first; advances the window."""
        if now < self._last_now:
            raise ValueError("expire_until times must be nondecreasing")
        self._last_now = float(now)
        cutoff = now - self.ttl
        h = self._head
        end = h
        total = len(self._t)
        while end < total and self._t[end] <= cutoff:
            end += 1
        src = np.asarray(self._src[h:end], np.int32)
        dst = np.asarray(self._dst[h:end], np.int32)
        self._head = end
        self.expired_total += end - h
        if self._head > 4096 and self._head * 2 > len(self._t):
            del self._t[: self._head]
            del self._src[: self._head]
            del self._dst[: self._head]
            self._head = 0
        return src, dst

    def expire_batches(
        self, now: float, *, batch_size: int, n: int
    ) -> list[UpdateBatch]:
        """The same expiry as sentinel-padded delete ``UpdateBatch`` es,
        ready for ``apply_update_batch`` / ``GraphHandle.apply_batch``."""
        src, dst = self.expire_until(now)
        return [
            make_update_batch(
                src[i: i + batch_size], dst[i: i + batch_size], False,
                batch_size=batch_size, n=n,
            )
            for i in range(0, len(src), batch_size)
        ]

    # -- the live window -----------------------------------------------------

    @property
    def live(self) -> int:
        return len(self._t) - self._head

    @property
    def oldest_t(self) -> float | None:
        return self._t[self._head] if self._head < len(self._t) else None

    def live_edges(self) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) of the live window in arrival order — the rebuild
        reference for the bitwise-equality invariant, and the frozen
        snapshot effectiveness checkpoints evaluate against."""
        return (
            np.asarray(self._src[self._head:], np.int32),
            np.asarray(self._dst[self._head:], np.int32),
        )

    def live_times(self) -> np.ndarray:
        return np.asarray(self._t[self._head:], np.float64)
