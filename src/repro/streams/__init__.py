"""Temporal graph-stream subsystem (DESIGN.md §9).

Turns the update/epoch/serving machinery into a clock-driven streaming
system: timestamped edge arrivals (:mod:`repro.streams.events`), a
TTL sliding window whose expiries stay bit-identical to a from-scratch
rebuild of the live window, a replay driver with freshness-SLO staleness
accounting (:mod:`repro.streams.driver`), and pooled effectiveness
checkpoints under churn (:mod:`repro.streams.churn`).
"""
from repro.streams.churn import churn_checkpoint, frozen_window_handle
from repro.streams.driver import (
    FreshnessSLO,
    ServiceTransport,
    SessionTransport,
    StreamCheckpoint,
    StreamDriver,
    StreamReport,
)
from repro.streams.events import (
    EdgeEvent,
    EventStream,
    SlidingWindowExpirer,
    bursty_edge_stream,
    poisson_edge_stream,
    preferential_attachment_stream,
)

__all__ = [
    "EdgeEvent",
    "EventStream",
    "FreshnessSLO",
    "ServiceTransport",
    "SessionTransport",
    "SlidingWindowExpirer",
    "StreamCheckpoint",
    "StreamDriver",
    "StreamReport",
    "bursty_edge_stream",
    "churn_checkpoint",
    "frozen_window_handle",
    "poisson_edge_stream",
    "preferential_attachment_stream",
]
