"""Reverse-reachability prefix tree (paper Alg. 3), host-side builder.

Batches the n_r sampled walks by deduplicating shared prefixes.  The device
consumes the tree as per-depth padded arrays (static shapes), processed
deepest-first by ``probe_tree_levels`` — one batched SpMM per depth with
column width = (padded) number of distinct prefixes at that depth, which is
typically far below n_r at shallow depths (bounded by |I(u)| at depth 0).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class PrefixTree:
    # per depth d (walk position p = d + 2):
    nodes: list[np.ndarray]  # int32 [W_d] graph node of the prefix end
    weights: list[np.ndarray]  # float32 [W_d] #walks sharing the prefix
    parent: list[np.ndarray]  # int32 [W_d] column index at depth d-1 (0 at d=0)
    parent_node: list[np.ndarray]  # int32 [W_d] graph node of the parent prefix end
    n_r: int
    total_columns: int


def _pad(arr: np.ndarray, width: int, fill) -> np.ndarray:
    out = np.full(width, fill, dtype=arr.dtype)
    out[: len(arr)] = arr
    return out


def build_prefix_tree(
    walks: np.ndarray, n: int, pad_to: int = 8
) -> PrefixTree:
    """Build the dedup tree from walks [n_r, L] (sentinel = n)."""
    walks = np.asarray(walks)
    n_r, L = walks.shape
    nodes, weights, parents, parent_nodes = [], [], [], []
    prev_index: dict[bytes, int] = {}  # prefix(<=p_len-1) bytes -> column id
    total = 0
    for p_len in range(2, L + 1):
        alive = walks[:, p_len - 1] < n
        if not alive.any():
            break
        rows = walks[alive, :p_len].astype(np.int32)
        uniq, counts = np.unique(rows, axis=0, return_counts=True)
        W = uniq.shape[0]
        node_d = uniq[:, -1].astype(np.int32)
        pnode_d = uniq[:, -2].astype(np.int32)
        if p_len == 2:
            par_d = np.zeros(W, dtype=np.int32)
        else:
            par_d = np.array(
                [prev_index[uniq[i, : p_len - 1].tobytes()] for i in range(W)],
                dtype=np.int32,
            )
        prev_index = {uniq[i].tobytes(): i for i in range(W)}
        width = max(pad_to, ((W + pad_to - 1) // pad_to) * pad_to)
        nodes.append(_pad(node_d, width, n))
        weights.append(_pad(counts.astype(np.float32), width, 0.0))
        parents.append(_pad(par_d, width, 0))
        parent_nodes.append(_pad(pnode_d, width, n))
        total += W
    return PrefixTree(
        nodes=nodes,
        weights=weights,
        parent=parents,
        parent_node=parent_nodes,
        n_r=n_r,
        total_columns=total,
    )


def tree_stats(tree: PrefixTree) -> dict:
    widths = [int((w > 0).sum()) for w in tree.weights]
    return dict(
        depths=len(widths),
        widths=widths,
        total_columns=tree.total_columns,
        dedup_ratio=(
            sum(int(w.sum()) for w in tree.weights) / max(tree.total_columns, 1)
        ),
    )
