"""ProbeSim core — the paper's primary contribution in JAX.

Public API:
    make_params         error-budget accounting (Thm 1 + 2)
    single_source       approximate single-source SimRank (Alg. 1 + §4)
    multi_source        fused multi-query serve path (one compiled step)
    multi_source_topk   fused batched top-k (Def. 2)
    topk                approximate top-k SimRank (Def. 2)
    sample_walks        sqrt(c)-walk generation (Def. 3)
    epoch_step          fused update->query epoch, local stage (core/epoch.py)
    make_sharded_epoch_step  the mesh epoch: shard_map apply + distributed probe
    simrank_power       ground-truth Power Method (small graphs)
    mc_single_source    Monte Carlo baseline
    tsf_single_source   TSF baseline
    evaluate_with_pool  pooling evaluation (§6.2)
    AccuracyController  adaptive per-query walk escalation (core/accuracy.py)
    walks_for_error     Thm-1/2 inversion: walks needed for a requested eps
"""
from repro.core.accuracy import (
    AccuracyController,
    Certificate,
    ProbeCache,
    empirical_error_bound,
    escalation_schedule,
    normal_quantile,
)
from repro.core.montecarlo import mc_pool_scores, mc_single_pair, mc_single_source
from repro.core.multisource import multi_source, multi_source_topk
from repro.core.params import (
    ProbeSimParams,
    abs_error_bound,
    bound_from_sampling_error,
    make_params,
    sampling_error,
    walks_for_error,
)
from repro.core.pooling import build_pool, evaluate_with_pool, pooled_ground_truth
from repro.core.power import (
    simrank_power,
    simrank_power_host,
    simrank_truncated_single_source,
)
from repro.core.epoch import (
    ShardEpochGraph,
    build_shard_epoch_graph,
    epoch_pipeline,
    epoch_step,
    make_sharded_epoch_step,
    shard_epoch_specs,
)
from repro.core.probe import (
    estimate_walk_reference,
    probe_prefix_reference,
    probe_tree_levels,
    probe_walks_telescoped,
    push_level,
)
from repro.core.probesim import single_source, single_source_simple, topk
from repro.core.tree import build_prefix_tree, tree_stats
from repro.core.tsf import build_oneway_index, tsf_single_source
from repro.core.walks import sample_walks, walk_lengths

__all__ = [
    "ProbeSimParams",
    "make_params",
    "abs_error_bound",
    "sampling_error",
    "bound_from_sampling_error",
    "walks_for_error",
    "AccuracyController",
    "Certificate",
    "ProbeCache",
    "empirical_error_bound",
    "escalation_schedule",
    "normal_quantile",
    "single_source",
    "single_source_simple",
    "multi_source",
    "multi_source_topk",
    "topk",
    "sample_walks",
    "walk_lengths",
    "simrank_power",
    "simrank_power_host",
    "simrank_truncated_single_source",
    "mc_single_pair",
    "mc_single_source",
    "mc_pool_scores",
    "tsf_single_source",
    "build_oneway_index",
    "build_pool",
    "evaluate_with_pool",
    "pooled_ground_truth",
    "build_prefix_tree",
    "tree_stats",
    "probe_prefix_reference",
    "probe_walks_telescoped",
    "probe_tree_levels",
    "estimate_walk_reference",
    "push_level",
    "epoch_pipeline",
    "epoch_step",
    "ShardEpochGraph",
    "build_shard_epoch_graph",
    "shard_epoch_specs",
    "make_sharded_epoch_step",
]
