"""PROBE — the paper's deterministic reverse-push (Alg. 2) on dense frontiers.

Two implementations:

* ``probe_prefix_reference`` — literal Algorithm 2 for one walk prefix.
  O(i) push levels per prefix, so Alg. 1 costs O(l^2) pushes per walk.
  Used as the correctness oracle (it reproduces the paper's worked example
  digit-for-digit) and in tests.

* ``probe_walks_telescoped`` — the TPU-native batched form.  One PROBE push
  level is the *linear* operator  T_p(s) = mask_{u_{p-1}}(M s)  with
  M[v, x] = sqrt(c)/|I(v)| for x in I(v).  Alg. 1's per-walk sum of
  per-prefix probes factors through linearity:

      sum_{i=2..l} (T_2 ∘ ... ∘ T_i)(e_{u_i})
        = T_2( e_{u_2} + T_3( e_{u_3} + ... T_l(e_{u_l}) ... ) )

  so one walk costs l-1 pushes instead of O(l^2) — exactly equal in value
  (verified against the reference to 1e-6 in tests).  A batch of B walks is
  processed as a score matrix S[n+1, B] (row n = sentinel dump row), one
  batched SpMM per level.

Pruning rule 2 appears as a per-level threshold: an entry at position p will
undergo p-1 more pushes, each scaling by <= sqrt(c), so entries with
``score * sqrt(c)^(p-1) <= eps_p`` are dropped (same one-sided error bound as
the paper, Lemma 6; pruning the *summed* telescoped vector is strictly more
conservative than per-prefix pruning, see DESIGN.md).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graph.structs import (
    EllGraph,
    Graph,
    push_coo,
    push_ell,
    push_ell_padded,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# One push level
# ---------------------------------------------------------------------------


def push_level(
    g: Graph | EllGraph,
    scores: Array,
    sqrt_c: float,
    *,
    use_kernel: bool = False,
) -> Array:
    """new[v] = sqrt(c)/|I(v)| * sum_{x in I(v)} scores[x];  scores [n] or [n,B]."""
    w = g.inv_in_deg * sqrt_c
    if isinstance(g, EllGraph):
        if use_kernel:
            from repro.kernels.spmm_ell import ops as spmm_ops

            return spmm_ops.spmm_ell(g.in_nbrs, scores, w)
        return push_ell(g, scores, weights=w)
    return push_coo(g, scores, weights=w)


def push_level_padded(
    g: Graph | EllGraph,
    scores: Array,
    sqrt_c: float,
    *,
    use_kernel: bool = False,
) -> Array:
    """One push level on an [n + 1, B] score buffer with a baked dump row.

    Row n is the sentinel dump row: scatter writes addressed by sentinel walk
    positions land there between pushes, so callers never mask or clip their
    scatter indices.  This function zeroes the dump row (one [B] row write)
    before the gather — making sentinel neighbor slots read an exact zero —
    and returns a fresh [n + 1, B] buffer with a zero dump row.  The ELL /
    Pallas path therefore consumes the buffer directly instead of re-padding
    ``scores`` on every push (DESIGN.md §2–3).
    """
    n = g.n
    w = g.inv_in_deg * sqrt_c
    scores = scores.at[n].set(0.0)
    if isinstance(g, EllGraph):
        if use_kernel:
            from repro.kernels.spmm_ell import ops as spmm_ops

            out = spmm_ops.spmm_ell_padded(g.in_nbrs, scores, w)
        else:
            out = push_ell_padded(g, scores, weights=w)
    else:
        out = push_coo(g, scores[:n], weights=w)
    return jnp.concatenate(
        [out, jnp.zeros((1,) + out.shape[1:], out.dtype)], axis=0
    )


# ---------------------------------------------------------------------------
# Reference: literal Algorithm 2
# ---------------------------------------------------------------------------


def probe_prefix_reference(
    g: Graph | EllGraph,
    prefix: Array,
    sqrt_c: float,
    eps_p: float = 0.0,
) -> Array:
    """Deterministic PROBE of one partial walk ``prefix`` = (u_1, ..., u_i).

    Returns Score [n] = first-meeting probability of every v w.r.t. prefix.
    ``prefix`` is a concrete 1-D int array (host loop — oracle only).
    """
    prefix = jnp.asarray(prefix)
    i = int(prefix.shape[0])
    n = g.n
    scores = jnp.zeros(n, dtype=jnp.float32).at[prefix[i - 1]].set(1.0)
    for j in range(i - 1):
        if eps_p > 0.0:
            # remaining pushes after this one: i-1 - j - 1 = i - j - 2;
            # rule applies *before descending* from H_j: score * sqrt_c^(i-j-1)
            thresh = eps_p / (sqrt_c ** (i - j - 1))
            scores = jnp.where(scores > thresh, scores, 0.0)
        scores = push_level(g, scores, sqrt_c)
        # exclusion: no score lands on u_{i-j-1}
        scores = scores.at[prefix[i - j - 2]].set(0.0)
    return scores


def estimate_walk_reference(
    g: Graph | EllGraph,
    walk: Array,
    sqrt_c: float,
    eps_p: float = 0.0,
) -> Array:
    """s~_k for one walk (Alg. 1 inner loop): sum of probes over prefixes."""
    walk = jnp.asarray(walk)
    n = g.n
    live = int((walk < n).sum())
    total = jnp.zeros(n, dtype=jnp.float32)
    for i in range(2, live + 1):
        total = total + probe_prefix_reference(g, walk[:i], sqrt_c, eps_p)
    return total


# ---------------------------------------------------------------------------
# Telescoped batched probe
# ---------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=("sqrt_c", "eps_p", "max_len", "use_kernel"),
)
def probe_walks_telescoped(
    g: Graph | EllGraph,
    walks: Array,  # int32 [B, max_len], sentinel = n
    *,
    sqrt_c: float,
    eps_p: float = 0.0,
    max_len: int | None = None,
    use_kernel: bool = False,
) -> Array:
    """Batched telescoped probe.  Returns per-walk estimates [n, B].

    Column k equals  sum_{i=2..l_k} Score(., W_k(u, i))  — the complete inner
    loop of Algorithm 1 for walk k.
    """
    n = g.n
    B, L = walks.shape
    if max_len is not None:
        L = max_len
    cols = jnp.arange(B)
    # [n + 1, B]: the sentinel dump row is baked in at allocation, so dead
    # walks (position id == n) scatter into row n instead of needing a
    # clip + validity-mask chain, and the push consumes the buffer directly.
    scores = jnp.zeros((n + 1, B), dtype=jnp.float32)

    def level(p, scores):
        # p runs L .. 2 (1-indexed walk positions)
        u_p = walks[:, p - 1]  # node at position p (sentinel n if dead)
        u_prev = walks[:, p - 2]  # mask node at position p-1
        # inject e_{u_p}; sentinel positions land in the dump row
        scores = scores.at[u_p, cols].add(1.0)
        # pruning rule 2: entries at position p face p-1 more pushes
        if eps_p > 0.0:
            thresh = eps_p / (sqrt_c ** (p - 1))
            scores = jnp.where(scores > thresh, scores, 0.0)
        # push (masks the dump row, returns it zeroed)
        scores = push_level_padded(g, scores, sqrt_c, use_kernel=use_kernel)
        # exclusion mask at position p-1; sentinel writes land in the dump row
        scores = scores.at[u_prev, cols].set(0.0)
        return scores

    # unrolled python loop over a static L keeps each level's eps_p threshold
    # a compile-time constant (XLA fuses the mask chain); L is small (<= ~16).
    for p in range(L, 1, -1):
        scores = level(p, scores)
    return scores[:n]


@partial(jax.jit, static_argnames=("sqrt_c", "eps_p", "use_kernel"))
def probe_tree_levels(
    g: Graph | EllGraph,
    level_nodes: tuple[Array, ...],  # per depth d: int32 [W_d] graph node ids
    level_weights: tuple[Array, ...],  # per depth d: float32 [W_d] (walk counts)
    level_parent: tuple[Array, ...],  # per depth d: int32 [W_d] parent col at d-1
    level_parent_node: tuple[Array, ...],  # per depth d: int32 [W_d] parent graph node
    *,
    sqrt_c: float,
    eps_p: float = 0.0,
    use_kernel: bool = False,
) -> Array:
    """Batch algorithm (paper Alg. 3) + telescoping over the prefix tree.

    Levels are ordered deepest-first; depth 0 entries are the children of the
    root (position 2 in walk coordinates).  Column widths W_d are static.
    Each level: inject weights, prune, push, mask at the parent's graph node,
    then merge children columns into parent columns (segment-sum).
    Returns the summed estimate vector [n] (divide by n_r outside).
    """
    n = g.n
    depths = len(level_nodes)
    carry = None  # [n, W_d] for current deepest level
    for d in range(depths - 1, -1, -1):
        nodes = level_nodes[d]
        W = nodes.shape[0]
        # walk-coordinate position of depth d is p = d + 2
        inject = jnp.zeros((n, W), jnp.float32).at[
            nodes.clip(0, n - 1), jnp.arange(W)
        ].add(jnp.where(nodes < n, level_weights[d], 0.0))
        scores = inject if carry is None else carry + inject
        if eps_p > 0.0:
            # position p = d + 2 -> p+1 pushes remain. Columns hold *sums*
            # over shared-prefix walks; pruning the sum at the per-walk
            # threshold is strictly more conservative than per-walk pruning
            # (each walk's share <= the sum), so Lemma 6's bound still holds.
            thresh = eps_p / (sqrt_c ** (d + 1))
            scores = jnp.where(scores > thresh, scores, 0.0)
        scores = push_level(g, scores, sqrt_c, use_kernel=use_kernel)
        # mask at parent's graph node, per column
        pn = level_parent_node[d]
        ok = pn < n
        scores = scores.at[pn.clip(0, n - 1), jnp.arange(W)].set(
            jnp.where(ok, 0.0, scores[pn.clip(0, n - 1), jnp.arange(W)])
        )
        # merge into parent columns
        if d > 0:
            W_parent = level_nodes[d - 1].shape[0]
            carry = jax.ops.segment_sum(
                scores.T, level_parent[d], num_segments=W_parent
            ).T
        else:
            carry = scores.sum(axis=1, keepdims=True)
    return carry[:, 0]
