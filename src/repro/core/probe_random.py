"""Randomized PROBE (paper Alg. 4) — O(n) per level in expectation.

Instead of deterministically pushing mass along every out-edge, every node x
samples ONE uniform in-edge (v, x); x enters the next frontier iff v is in
the current frontier and an independent Bernoulli(sqrt(c)) succeeds.  The
membership probability of v in the final frontier is exactly the
deterministic PROBE score (paper Lemma 5), so returning indicator scores
gives an unbiased Bernoulli estimator.

TPU adaptation: the per-node sampling is a *dense vectorized* operation over
all n nodes (gather one random in-neighbor per node from the ELL table +
boolean mask) — the irregular hash-set logic of the C++ version disappears.
Prefixes of one walk are laid out as boolean columns stepped synchronously by
walk position, with independent randomness per prefix (faithful to the
per-probe independence the paper's analysis requires).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graph.structs import EllGraph

Array = jax.Array


@partial(jax.jit, static_argnames=("sqrt_c",))
def randomized_probe_prefix(
    key: Array,
    eg: EllGraph,
    prefix: Array,  # int32 [i] concrete prefix (u_1..u_i)
    *,
    sqrt_c: float,
) -> Array:
    """Faithful Algorithm 4 for a single prefix; returns {0,1} scores [n]."""
    n = eg.n
    i = prefix.shape[0]
    frontier = jnp.zeros(n, dtype=bool).at[prefix[i - 1]].set(True)

    def body(j, carry):
        frontier, key = carry
        key, k_edge, k_bern = jax.random.split(key, 3)
        # every node x samples one in-neighbor v
        r = jax.random.uniform(k_edge, (n,))
        deg = eg.in_deg
        kk = jnp.floor(r * deg.astype(jnp.float32)).astype(jnp.int32)
        kk = kk.clip(0, jnp.maximum(deg - 1, 0))
        v = eg.in_nbrs[jnp.arange(n), kk]
        picked = jnp.where(deg > 0, frontier[v.clip(0, n - 1)], False)
        bern = jax.random.uniform(k_bern, (n,)) < sqrt_c
        new_frontier = picked & bern
        # exclusion: u_{i-j-1} cannot enter
        new_frontier = new_frontier.at[prefix[i - j - 2]].set(False)
        return new_frontier, key

    frontier, _ = jax.lax.fori_loop(0, i - 1, body, (frontier, key))
    return frontier.astype(jnp.float32)


@partial(jax.jit, static_argnames=("sqrt_c", "max_len"))
def randomized_probe_walk(
    key: Array,
    eg: EllGraph,
    walk: Array,  # int32 [max_len], sentinel = n
    *,
    sqrt_c: float,
    max_len: int,
) -> Array:
    """All prefixes of one walk, stepped synchronously by position.

    Columns = prefixes i = 2..L; column i activates at position i and steps
    down to position 1 with its own randomness.  Returns s~_k [n]: the sum of
    per-prefix indicator scores.
    """
    n = eg.n
    L = max_len
    ncols = L - 1  # prefix i occupies column i-2
    frontier = jnp.zeros((n, ncols), dtype=bool)
    col_ids = jnp.arange(ncols)

    def step(carry, inputs):
        frontier, key = carry
        p = inputs  # position p: L .. 2
        key, k_edge, k_bern = jax.random.split(key, 3)
        u_p = walk[p - 1]
        u_prev = walk[p - 2]
        # activate column p-2 with e_{u_p} (dead walks: sentinel -> no-op)
        act = (col_ids == (p - 2)) & (u_p < n)
        frontier = frontier.at[u_p.clip(0, n - 1), :].set(
            jnp.where(act, True, frontier[u_p.clip(0, n - 1), :])
        )
        # per-(node, column) independent edge sample
        r = jax.random.uniform(k_edge, (n, ncols))
        deg = eg.in_deg[:, None]
        kk = jnp.floor(r * deg.astype(jnp.float32)).astype(jnp.int32)
        kk = kk.clip(0, jnp.maximum(deg - 1, 0))
        v = jnp.take_along_axis(eg.in_nbrs, kk, axis=1)  # [n, ncols]
        vf = jnp.take_along_axis(frontier, v.clip(0, n - 1), axis=0)
        picked = jnp.where(deg > 0, vf, False)
        bern = jax.random.uniform(k_bern, (n, ncols)) < sqrt_c
        new_frontier = picked & bern
        # only columns already active (i >= p) step; others stay empty
        active = col_ids >= (p - 2)
        new_frontier = new_frontier & active[None, :]
        # exclusion at u_{p-1}
        new_frontier = new_frontier.at[u_prev.clip(0, n - 1), :].set(
            jnp.where(u_prev < n, False, new_frontier[u_prev.clip(0, n - 1), :])
        )
        return (new_frontier, key), None

    ps = jnp.arange(L, 1, -1)
    (frontier, _), _ = jax.lax.scan(step, (frontier, key), ps)
    return frontier.astype(jnp.float32).sum(axis=1)
