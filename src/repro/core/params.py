"""Error-budget accounting for ProbeSim (paper Thm 1 + Thm 2).

Theorem 2: with sampling error eps, pruning parameter eps_p and truncation
parameter eps_t, the total absolute error is bounded by eps_a when

    eps + (1 + eps) / (1 - sqrt(c)) * eps_p + eps_t / 2  <=  eps_a .

We split the budget eps_a as (1/2, 1/4, 1/4) over (sampling, pruning,
truncation) by default — the same shape as the paper's experimental settings
(eps_t = eps_p ~ eps_a/2 at eps_a = 0.1 in their running example).

Number of trials (Alg. 1 line 1):  n_r = ceil(3 c / eps^2 * ln(n / delta)).
Truncation depth (Pruning rule 1): l_t = ceil(log eps_t / log sqrt(c)).
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class ProbeSimParams:
    c: float  # SimRank decay factor
    eps_a: float  # total absolute error target
    delta: float  # failure probability
    eps: float  # sampling error share
    eps_p: float  # pruning-rule-2 threshold
    eps_t: float  # pruning-rule-1 (truncation) share
    n_r: int  # number of sqrt(c)-walk trials
    max_len: int  # l_t: max walk length (number of NODES, u_1..u_{l_t})
    truncation_shift: bool = False  # add eps_t/2 to estimates (one-sided fix)

    @property
    def sqrt_c(self) -> float:
        return math.sqrt(self.c)


def sampling_error(params: ProbeSimParams, *, n: int, n_r: int) -> float:
    """Thm-1 sampling error a pool of ``n_r`` walks actually guarantees:
    ``eps(n_r) = sqrt(3 c ln(n / delta) / n_r)`` (the inversion of
    ``n_r = ceil(3c/eps^2 ln(n/delta))``)."""
    if n_r < 1:
        raise ValueError(f"n_r must be >= 1, got {n_r}")
    return math.sqrt(3.0 * params.c * math.log(n / params.delta) / n_r)


def bound_from_sampling_error(params: ProbeSimParams, eps: float) -> float:
    """Thm-2 total bound for a given sampling error share ``eps``:
    the pruning and truncation shares stack on top as
    ``eps + (1 + eps) / (1 - sqrt(c)) * eps_p + eps_t / 2``.  Also how an
    *empirical* sampling CI converts into a total certified bound — the
    certificates differ only in the sampling term."""
    return (
        eps
        + (1.0 + eps) / (1.0 - params.sqrt_c) * params.eps_p
        + params.eps_t / 2.0
    )


def abs_error_bound(
    params: ProbeSimParams, *, n: int, n_r: int | None = None
) -> float:
    """Theorem 1+2 absolute-error bound at the EFFECTIVE walk count.

    Inverting Thm 1 (``n_r = ceil(3c/eps^2 ln(n/delta))``) gives the
    sampling error a pool of ``n_r`` walks actually guarantees,

        eps(n_r) = sqrt(3 c ln(n / delta) / n_r),

    and Thm 2 stacks the pruning and truncation shares on top.  Anytime
    queries (``budget_walks`` < the full Thm-1 budget) therefore report the
    looser bound they really provide; at the full budget this reproduces
    ``params.eps_a`` (up to the ceil slack in n_r).
    """
    r = int(params.n_r if n_r is None else n_r)
    return bound_from_sampling_error(params, sampling_error(params, n=n, n_r=r))


def walks_for_error(
    params: ProbeSimParams, *, n: int, epsilon: float
) -> int | None:
    """Smallest walk count whose Thm-1/2 bound meets ``epsilon`` — or None.

    Solving ``bound_from_sampling_error(params, e) <= epsilon`` for the
    sampling error gives

        e_max = (epsilon - eps_t/2 - kappa) / (1 + kappa),
        kappa = eps_p / (1 - sqrt(c)),

    which is the headroom left after the walk-count-independent pruning
    and truncation floors.  ``None`` when the floors alone exceed epsilon:
    no number of walks can certify it analytically (the adaptive
    controller may still certify via the empirical CI's smaller sampling
    term, but the floors are a hard limit for both certificates).
    """
    if epsilon <= 0.0:
        return None
    kappa = params.eps_p / (1.0 - params.sqrt_c)
    e_max = (epsilon - params.eps_t / 2.0 - kappa) / (1.0 + kappa)
    if e_max <= 0.0:
        return None
    return int(math.ceil(3.0 * params.c * math.log(n / params.delta) / e_max**2))


def make_params(
    n: int,
    c: float = 0.6,
    eps_a: float = 0.1,
    delta: float = 0.01,
    split: tuple[float, float, float] = (0.5, 0.25, 0.25),
    n_r_override: int | None = None,
    max_len_override: int | None = None,
    truncation_shift: bool = False,
) -> ProbeSimParams:
    if not (0.0 < c < 1.0):
        raise ValueError("decay factor c must be in (0,1)")
    ws, wp, wt = split
    assert abs(ws + wp + wt - 1.0) < 1e-9, "budget split must sum to 1"
    sqrt_c = math.sqrt(c)
    eps = eps_a * ws
    # (1+eps)/(1-sqrt(c)) * eps_p = eps_a * wp  =>  solve for eps_p
    eps_p = eps_a * wp * (1.0 - sqrt_c) / (1.0 + eps)
    # eps_t / 2 = eps_a * wt
    eps_t = 2.0 * eps_a * wt
    n_r = n_r_override or int(math.ceil(3.0 * c / eps**2 * math.log(n / delta)))
    max_len = max_len_override or max(
        2, int(math.ceil(math.log(eps_t) / math.log(sqrt_c)))
    )
    # sanity: Theorem 2 inequality holds
    assert eps + (1 + eps) / (1 - sqrt_c) * eps_p + eps_t / 2 <= eps_a + 1e-9
    return ProbeSimParams(
        c=c,
        eps_a=eps_a,
        delta=delta,
        eps=eps,
        eps_p=eps_p,
        eps_t=eps_t,
        n_r=n_r,
        max_len=max_len,
        truncation_shift=truncation_shift,
    )
