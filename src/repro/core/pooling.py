"""Pooling evaluation (paper §6.2) — the billion-edge effectiveness protocol.

When ground truth is unobtainable (Power Method needs O(n^2)), merge the
top-k candidates returned by all competing systems into a pool, score every
pooled node with a high-precision single-pair Monte Carlo "expert", and take
the best k pooled nodes as the reference ranking.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.core.metrics import kendall_tau, ndcg_at_k, precision_at_k
from repro.core.montecarlo import mc_pool_scores
from repro.graph.structs import EllGraph

Array = jax.Array


def build_pool(candidate_lists: dict[str, np.ndarray]) -> np.ndarray:
    """Union of every system's top-k lists, duplicates removed."""
    pool = np.unique(np.concatenate([np.asarray(v) for v in candidate_lists.values()]))
    return pool.astype(np.int32)


def pooled_ground_truth(
    key: Array,
    eg: EllGraph,
    u: int,
    pool: np.ndarray,
    k: int,
    *,
    expert_r: int = 10_000,
    max_len: int = 24,
    sqrt_c: float,
) -> tuple[np.ndarray, np.ndarray]:
    """Expert-scored pool -> (best-k nodes, full per-pool-node scores)."""
    scores = np.asarray(
        mc_pool_scores(
            key,
            eg,
            np.int32(u),
            np.asarray(pool, dtype=np.int32),
            r=expert_r,
            max_len=max_len,
            sqrt_c=sqrt_c,
        )
    )
    order = np.argsort(-scores, kind="stable")
    return pool[order[:k]], scores


def evaluate_with_pool(
    key: Array,
    eg: EllGraph,
    u: int,
    candidate_lists: dict[str, np.ndarray],
    k: int,
    *,
    expert_r: int = 10_000,
    sqrt_c: float,
    max_len: int = 24,
) -> dict[str, dict[str, float]]:
    """Precision@k / NDCG@k / Kendall tau for every system against the pool."""
    pool = build_pool(candidate_lists)
    best_k, pool_scores = pooled_ground_truth(
        key, eg, u, pool, k, expert_r=expert_r, max_len=max_len, sqrt_c=sqrt_c
    )
    # full-graph score lookup (0 outside the pool: those were never returned)
    truth = np.zeros(eg.n, dtype=np.float64)
    truth[pool] = pool_scores
    out = {}
    for name, nodes in candidate_lists.items():
        nodes = np.asarray(nodes)[:k]
        out[name] = dict(
            precision=precision_at_k(nodes, best_k),
            ndcg=ndcg_at_k(nodes, truth, best_k),
            kendall=kendall_tau(nodes, truth),
        )
    return out
