"""Evaluation metrics for single-source / top-k SimRank (paper §6)."""
from __future__ import annotations

import numpy as np


def abs_error(est: np.ndarray, truth: np.ndarray, exclude: int | None = None) -> float:
    """AbsError = max_v |s(u,v) - s~(u,v)| (paper §6.1), excluding u itself."""
    est = np.asarray(est, dtype=np.float64).copy()
    truth = np.asarray(truth, dtype=np.float64).copy()
    if exclude is not None:
        est[exclude] = truth[exclude]
    return float(np.abs(est - truth).max())


def precision_at_k(pred_nodes: np.ndarray, true_nodes: np.ndarray) -> float:
    """|V_k ∩ V'_k| / k."""
    k = len(true_nodes)
    return len(set(pred_nodes.tolist()) & set(true_nodes.tolist())) / max(k, 1)


def ndcg_at_k(
    pred_nodes: np.ndarray, truth_scores: np.ndarray, true_nodes: np.ndarray
) -> float:
    """NDCG@k with gains 2^s - 1 and log2(i+1) discounts (paper §6.1)."""
    k = len(pred_nodes)
    discounts = 1.0 / np.log2(np.arange(k) + 2.0)
    gains_pred = (2.0 ** truth_scores[pred_nodes] - 1.0) @ discounts
    gains_best = (2.0 ** truth_scores[true_nodes] - 1.0) @ discounts
    return float(gains_pred / gains_best) if gains_best > 0 else 1.0


def kendall_tau(
    pred_nodes: np.ndarray, truth_scores: np.ndarray
) -> float:
    """Kendall tau-b between the predicted order and the true-score order of
    the predicted set (the paper's tau_k over the returned list)."""
    s = truth_scores[pred_nodes]
    k = len(s)
    if k < 2:
        return 1.0
    concordant = discordant = 0
    for i in range(k):
        for j in range(i + 1, k):
            # predicted order says i ranks above j
            if s[i] > s[j]:
                concordant += 1
            elif s[i] < s[j]:
                discordant += 1
    total = k * (k - 1) / 2
    return float((concordant - discordant) / total) if total else 1.0
