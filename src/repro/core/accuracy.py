"""Adaptive accuracy controller — spend walks only where the bound needs them.

ProbeSim's headline guarantee (Thm 1/2) is an *absolute-error bound*, but a
flat walk budget buys the same n_r for every query regardless of how many
walks that query actually needs: ``error_bound_at_budget`` sits at ~0.28
for 512 walks while typical measured errors are 10-50x smaller, because the
analytic bound assumes worst-case per-walk variance (3c) and a union over
all n nodes.  The controller closes that gap per query:

* serve at a small initial budget, then **escalate geometrically** — round
  ``r`` draws ``fold_in(stream, r)`` walks on top of the carried
  accumulator, so the cumulative estimate after rounds 0..r is the exact
  weighted mean over all walks drawn so far (and an escalated run is
  bitwise identical to a one-shot run whose budget cap equals the same
  cumulative point: both execute the same round schedule under the same
  per-round keys);
* after every round, try to **certify** the requested epsilon with the
  cheapest certificate that fires:

  - ``analytic`` — the Thm-1/2 bound :func:`~repro.core.params.abs_error_bound`
    evaluated at the cumulative walk count (data-independent: known in
    advance via :func:`~repro.core.params.walks_for_error`);
  - ``empirical`` — a CLT confidence interval built from the *measured*
    between-round score variance (an unbiased estimate of the per-walk
    variance), union-bounded over nodes.  Real per-walk variance is far
    below the worst case, so this typically fires with 5-20x fewer walks
    than the analytic budget — the whole point of escalating;
  - ``budget`` — the schedule cap was reached without meeting epsilon:
    the query degrades to an anytime answer that honestly reports the
    bound it achieved;
  - ``deadline`` — escalation was clamped by a serving deadline
    (``serving.straggler`` shedding): best-so-far scores + the achieved
    bound, never an exception on the query path.

The schedule cap never exceeds the flat Thm-1 budget for the same epsilon,
so the controller *structurally* cannot spend more walks than flat serving
(``walks_saved_ratio >= 1`` is an invariant, not a measurement).

Hub sharing (PRSim's power-law analysis, arxiv 1905.02354): on skewed
graphs a few high in-degree hubs absorb a large fraction of query traffic.
:class:`ProbeCache` memoizes per-round probe score rows keyed on
``(node, graph version, round, round size, lane width)``; the session
routes hub queries (in-degree above a percentile) onto *node-keyed* PRNG
streams, which makes their per-round rows identical across queries and
drain batches — repeated hub probes then skip whole compiled dispatches.
A graph-version bump invalidates the cache (the key carries the version
and the cache clears itself on a new one).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.params import (
    ProbeSimParams,
    abs_error_bound,
    bound_from_sampling_error,
)

__all__ = [
    "AccuracyController",
    "Certificate",
    "ProbeCache",
    "empirical_error_bound",
    "escalation_schedule",
    "normal_quantile",
]

# Per-walk deposits are probabilities (telescoped probe pushes mass <= 1
# per walk per node), so the per-walk score variance cannot exceed the
# [0, 1]-range worst case of 1/4.  Clamping the estimate there keeps the
# empirical CI provably no looser than necessary when the between-round
# scatter is noisy at small round counts.
_VAR_CLAMP = 0.25


def escalation_schedule(initial: int, cap: int) -> list[int]:
    """Per-round walk counts whose cumulative sums double up to ``cap``.

    ``[b, b, 2b, 4b, ...]`` — cumulative ``b, 2b, 4b, 8b, ...`` with the
    final round clipped so the total equals ``cap`` exactly.  The schedule
    is a pure function of ``(initial, cap)``: an escalated run that stops
    at cumulative N executes the same rounds as a one-shot run with
    ``cap=N`` — the property the bitwise parity tests pin.
    """
    initial = int(initial)
    cap = int(cap)
    if initial < 1:
        raise ValueError(f"initial budget must be >= 1, got {initial}")
    if cap < 1:
        raise ValueError(f"budget cap must be >= 1, got {cap}")
    if cap <= initial:
        return [cap]
    sizes = [initial]
    cum = initial
    while cum < cap:
        nxt = min(cum * 2, cap)
        sizes.append(nxt - cum)
        cum = nxt
    return sizes


def normal_quantile(p: float) -> float:
    """Inverse standard-normal CDF via bisection on ``math.erf``.

    Dependency-free (no scipy in the container); monotone bisection to
    1e-12, plenty for confidence levels down to 1 - 1e-12.
    """
    if not 0.0 < p < 1.0:
        raise ValueError(f"quantile probability must be in (0, 1), got {p}")
    if p < 0.5:
        return -normal_quantile(1.0 - p)
    lo, hi = 0.0, 40.0
    for _ in range(100):
        mid = 0.5 * (lo + hi)
        if 0.5 * (1.0 + math.erf(mid / math.sqrt(2.0))) < p:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def empirical_error_bound(
    params: ProbeSimParams,
    *,
    n: int,
    round_sizes,
    round_scores,
    confidence: float,
) -> float:
    """CLT certificate: total abs-error bound from measured round variance.

    ``round_scores`` is ``[R, n]`` — one score vector per escalation round
    (each the mean of that round's walks).  For i.i.d. walks split into
    rounds of sizes ``n_i``, ``sum_i n_i (s_i - s_mean)^2 / (R - 1)`` is an
    (approximately) unbiased estimate of the per-walk variance; the
    sampling CI half-width at ``confidence`` — two-sided, union-bounded
    over the ``n`` nodes like the analytic Thm-1 bound — is
    ``z * sigma_hat_max / sqrt(N)``.  The pruning and truncation shares
    stack on top exactly as in Thm 2
    (:func:`~repro.core.params.bound_from_sampling_error`), so the
    empirical and analytic certificates differ only in the sampling term;
    with the variance estimate clamped at the [0, 1]-range worst case 1/4,
    the empirical sampling term is never above ``~0.5 z / sqrt(N)`` while
    the analytic one pays ``sqrt(3 c ln(n / delta)) / sqrt(N)`` — the
    empirical certificate is conservative in coverage yet strictly inside
    the analytic bound (the property tests pin both).

    Requires ``R >= 2`` (one round has no variance information): raises
    ValueError otherwise — callers gate on round count.
    """
    if not 0.0 < confidence < 1.0:
        raise ValueError(f"confidence must be in (0, 1), got {confidence}")
    sizes = np.asarray(round_sizes, np.float64)
    scores = np.asarray(round_scores, np.float64)
    r = sizes.shape[0]
    if r < 2:
        raise ValueError(f"empirical CI needs >= 2 rounds, got {r}")
    if scores.shape[0] != r:
        raise ValueError(
            f"{r} round sizes vs {scores.shape[0]} round score vectors"
        )
    total = sizes.sum()
    mean = (sizes[:, None] * scores).sum(axis=0) / total
    var_walk = (sizes[:, None] * (scores - mean[None, :]) ** 2).sum(
        axis=0
    ) / (r - 1)
    sigma_max = math.sqrt(min(float(var_walk.max()), _VAR_CLAMP))
    alpha = (1.0 - confidence) / max(int(n), 1)  # union over nodes
    z = normal_quantile(1.0 - alpha / 2.0)  # two-sided
    h = z * sigma_max / math.sqrt(total)
    return bound_from_sampling_error(params, h)


@dataclasses.dataclass(frozen=True)
class Certificate:
    """What the controller certified for one query when it stopped.

    ``name`` is which certificate fired (``analytic`` / ``empirical``) or
    why escalation stopped without one (``budget`` / ``deadline``);
    ``bound`` the certified absolute-error bound (the min of both
    certificates at the stopping point — for budget/deadline stops this is
    the best achieved bound, honestly above the requested epsilon);
    ``walks`` the cumulative walks spent, ``rounds`` the rounds executed.
    """

    name: str
    bound: float
    walks: int
    rounds: int


class AccuracyController:
    """Carried-accumulator escalation state for one (batched) query group.

    The session drives it round by round — ``next_round()`` names the
    round to serve, the caller dispatches that round's walks through the
    backend (the compiled lane-batched step, reused per round unchanged)
    and feeds the resulting ``[Q, n]`` score matrix to :meth:`absorb`.
    The controller carries the walk-weighted score sum, evaluates both
    certificates per query, and *freezes* a query the round its requested
    epsilon is met: frozen scores/certificates never change in later
    rounds (so a query's answer is independent of how long its batch mates
    keep escalating — the batch-invariance the PRNG contract promises).
    ``finish()`` freezes whatever is still live (budget cap exhausted or
    deadline shed) with the best achieved bound.
    """

    def __init__(
        self,
        params: ProbeSimParams,
        *,
        n: int,
        q: int,
        epsilon: float,
        confidence: float,
        plan: list[int],
        min_empirical_rounds: int = 2,
    ):
        if epsilon < 0.0:
            raise ValueError(f"epsilon must be >= 0, got {epsilon}")
        if not plan:
            raise ValueError("empty escalation plan")
        self.params = params
        self.n = int(n)
        self.q = int(q)
        self.epsilon = float(epsilon)
        self.confidence = float(confidence)
        self.plan = [int(s) for s in plan]
        self.min_empirical_rounds = int(min_empirical_rounds)
        self.round_sizes: list[int] = []
        self._history: list[np.ndarray] = []  # per-round [q, n] float32
        self._carry = np.zeros((q, n), np.float64)  # walk-weighted score sum
        self.walks = 0
        self.certificates: list[Certificate | None] = [None] * q
        self._scores: list[np.ndarray | None] = [None] * q

    # -- round scheduling ----------------------------------------------------

    @property
    def rounds_done(self) -> int:
        return len(self.round_sizes)

    @property
    def all_frozen(self) -> bool:
        return all(c is not None for c in self.certificates)

    def next_round(self) -> int | None:
        """Walk count of the next scheduled round (None = plan exhausted)."""
        r = self.rounds_done
        return self.plan[r] if r < len(self.plan) else None

    # -- escalation ----------------------------------------------------------

    def _bounds(self, i: int) -> tuple[float, float]:
        """(analytic, empirical) total bounds for query ``i`` right now."""
        analytic = abs_error_bound(self.params, n=self.n, n_r=self.walks)
        empirical = math.inf
        if self.rounds_done >= self.min_empirical_rounds:
            empirical = empirical_error_bound(
                self.params,
                n=self.n,
                round_sizes=self.round_sizes,
                round_scores=[h[i] for h in self._history],
                confidence=self.confidence,
            )
        return analytic, empirical

    def _freeze(self, i: int, name: str, bound: float) -> None:
        self._scores[i] = (self._carry[i] / self.walks).astype(np.float32)
        self.certificates[i] = Certificate(
            name=name, bound=float(bound),
            walks=self.walks, rounds=self.rounds_done,
        )

    def absorb(self, n_round: int, rows: np.ndarray) -> None:
        """Fold one served round into the carry; certify + freeze queries.

        ``rows`` is the backend's ``[Q, n]`` single-source score matrix for
        this round alone (each row the mean over ``n_round`` fresh walks).
        Frozen queries ignore their row — their answer was fixed the round
        their certificate fired.
        """
        rows = np.asarray(rows, np.float32)
        if rows.shape != (self.q, self.n):
            raise ValueError(
                f"round rows have shape {rows.shape}, "
                f"want {(self.q, self.n)}"
            )
        self.round_sizes.append(int(n_round))
        self.walks += int(n_round)
        self._history.append(rows)
        self._carry += float(n_round) * rows.astype(np.float64)
        for i in range(self.q):
            if self.certificates[i] is not None:
                continue
            analytic, empirical = self._bounds(i)
            if analytic <= self.epsilon:
                self._freeze(i, "analytic", min(analytic, empirical))
            elif empirical <= self.epsilon:
                self._freeze(i, "empirical", empirical)

    def finish(self, reason: str = "budget") -> None:
        """Freeze every still-live query with the best achieved bound.

        ``reason`` is ``budget`` (schedule cap reached without certifying)
        or ``deadline`` (escalation clamped by straggler shedding) — the
        query degrades to its best-so-far answer instead of raising.
        """
        if self.rounds_done == 0:
            raise RuntimeError("cannot finish before any round was absorbed")
        for i in range(self.q):
            if self.certificates[i] is None:
                analytic, empirical = self._bounds(i)
                self._freeze(i, reason, min(analytic, empirical))

    def result(self, i: int) -> tuple[np.ndarray, Certificate]:
        """(combined scores [n] float32, certificate) for query ``i``."""
        cert = self.certificates[i]
        if cert is None:
            raise RuntimeError(
                f"query {i} is not frozen yet (call finish() after the "
                "escalation loop)"
            )
        return self._scores[i], cert


class ProbeCache:
    """Per-round probe score rows for hub nodes, shared across queries.

    Keyed on ``(node, graph version, round, round size, lane width)`` —
    everything that determines the row bitwise for a node-keyed PRNG
    stream.  Insertion-ordered eviction bounds memory; a new graph version
    clears the whole cache (every held row is stale by construction).
    """

    def __init__(self, max_entries: int = 256):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = int(max_entries)
        self._rows: dict[tuple, np.ndarray] = {}
        self._version: int | None = None
        self.hits = 0
        self.misses = 0

    def _sync_version(self, version: int) -> None:
        if self._version != version:
            self._rows.clear()
            self._version = version

    def get(self, key: tuple) -> np.ndarray | None:
        self._sync_version(key[1])
        row = self._rows.get(key)
        if row is None:
            self.misses += 1
            return None
        self.hits += 1
        return row

    def put(self, key: tuple, row: np.ndarray) -> None:
        self._sync_version(key[1])
        if key not in self._rows and len(self._rows) >= self.max_entries:
            # evict the oldest insertion: hub traffic is heavy-tailed, so
            # the hot keys re-enter immediately and stay resident
            self._rows.pop(next(iter(self._rows)))
        self._rows[key] = row

    def __len__(self) -> int:
        return len(self._rows)
