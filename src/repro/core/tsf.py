"""TSF baseline (Shao et al., PVLDB'15) — two-stage random-walk framework.

Index stage: R_g "one-way graphs", each sampling ONE in-neighbor per node
(a functional pointer array).  Query stage: walks inside a one-way graph are
deterministic pointer chases; each one-way graph is reused R_q times for the
query-side randomness.

Faithful to the paper's description *including its two known biases* (which
ProbeSim's §2.3 criticizes and our experiments reproduce):

1. it estimates  sum_i Pr[walks meet at step i]  — an over-estimate of
   s(u, v) = Pr[first meet] when walks can meet multiple times;
2. it assumes one-way-graph walks are acyclic, which fails on cyclic/
   undirected graphs.

The index is a dense [R_g, n] int32 array — the "two-to-three orders of
magnitude larger than the graph" space cost shows up naturally.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graph.structs import EllGraph

Array = jax.Array


@partial(jax.jit, static_argnames=("r_g",))
def build_oneway_index(key: Array, eg: EllGraph, *, r_g: int) -> Array:
    """R_g one-way graphs: nxt[g, v] = sampled in-neighbor (sentinel n if none)."""
    n = eg.n
    r = jax.random.uniform(key, (r_g, n))
    deg = eg.in_deg[None, :]
    k = jnp.floor(r * deg.astype(jnp.float32)).astype(jnp.int32)
    k = k.clip(0, jnp.maximum(deg - 1, 0))
    nxt = jnp.take_along_axis(
        jnp.broadcast_to(eg.in_nbrs, (r_g, n, eg.k_max)), k[..., None], axis=2
    )[..., 0]
    return jnp.where(deg > 0, nxt, n).astype(jnp.int32)


@partial(jax.jit, static_argnames=("r_q", "t", "c"))
def tsf_single_source(
    key: Array,
    index: Array,  # [R_g, n] one-way graphs
    eg: EllGraph,
    u: Array,
    *,
    r_q: int,
    t: int,
    c: float,
) -> Array:
    """TSF single-source estimate [n].

    For each one-way graph: chase u's walk r_q times with fresh query-side
    randomness (u's walk re-samples in-neighbors; the candidate side v
    follows the one-way pointers deterministically).  Meeting at step i
    contributes c^i (the over-estimating sum over i).
    """
    n = eg.n
    r_g = index.shape[0]
    sqrt_c = jnp.sqrt(c)

    def per_graph(carry, g_idx):
        total = carry
        nxt = index[g_idx]

        def per_query(carry2, q_idx):
            tot2 = carry2
            kq = jax.random.fold_in(jax.random.fold_in(key, g_idx), q_idx)
            ks = jax.random.split(kq, t)
            # u's walk: fresh uniform in-neighbor sampling, t steps
            # candidate walks: all nodes chase one-way pointers
            def step(c3, inp):
                u_cur, v_cur, score = c3
                i, kk = inp
                rr = jax.random.uniform(kk)
                deg = eg.in_deg[u_cur.clip(0, n - 1)]
                j = jnp.floor(rr * deg.astype(jnp.float32)).astype(jnp.int32)
                j = j.clip(0, jnp.maximum(deg - 1, 0))
                u_nxt = jnp.where(
                    (u_cur < n) & (deg > 0), eg.in_nbrs[u_cur.clip(0, n - 1), j], n
                )
                v_nxt = jnp.where(v_cur < n, nxt[v_cur.clip(0, n - 1)], n)
                meet = (v_nxt == u_nxt) & (u_nxt < n)
                score = score + jnp.where(meet, c ** (i + 1.0), 0.0)
                return (u_nxt, v_nxt, score), None

            v0 = jnp.arange(n, dtype=jnp.int32)
            u0 = jnp.broadcast_to(jnp.asarray(u, jnp.int32), ())
            (u_f, v_f, score), _ = jax.lax.scan(
                step,
                (u0, v0, jnp.zeros(n, jnp.float32)),
                (jnp.arange(t, dtype=jnp.float32), ks),
            )
            return tot2 + score, None

        tot2, _ = jax.lax.scan(per_query, total, jnp.arange(r_q))
        return tot2, None

    total, _ = jax.lax.scan(
        per_graph, jnp.zeros(n, jnp.float32), jnp.arange(r_g)
    )
    est = total / (r_g * r_q)
    return est.at[jnp.asarray(u)].set(1.0)
