"""Fused multi-query ProbeSim serving path (DESIGN.md §3).

The seed query path was host-bound: each walk chunk was two separate jitted
dispatches (``sample_walks`` then ``probe_walks_telescoped``) with a host
round-trip between chunks, every query ran alone, and every walk paid
``max_len - 1`` full-width push levels even though the mean sqrt(c)-walk is
only ~1/(1 - sqrt(c)) nodes long.  ``multi_source`` replaces all of that with
ONE compiled step per query batch:

* **query batching across the lane dimension** — Q queries share a single
  [n + 1, W] score buffer; each query owns a contiguous block of W/Q lane
  columns, so every push level is one SpMM dispatch for the whole batch;
* **pooled walk sampling** — the entire walk pool (Q x n_r walks) is drawn
  by one vmapped sampler call inside the same jit.  Per-chunk sampling pays
  a large fixed dispatch cost (the ELL-table walk); pooling amortizes it;
* **compacted walk scheduling** — instead of marching all lanes through the
  same global level p (leaving columns of short/dead walks pushing zeros for
  most levels), each lane column runs the telescoped probe of *its own* walk
  at its own position.  When a column's walk finishes (position 1), its
  telescoped estimate is deposited into a per-column accumulator and the
  column is refilled with the next walk from its query's pool partition.
  Total push work drops from ``n_r * (max_len - 1)`` column-levels per query
  to ``n_r * E[len - 1]`` — the dominant term of the measured speedup;
* **baked sentinel dump row** — score buffers are allocated once as
  [n + 1, W] (row n = dump row), so sentinel scatter/gather indices need no
  clipping and the SpMM kernel path never re-pads ``scores``
  (``push_level_padded`` / ``spmm_ell_padded``);
* **fused epilogue** — per-query segment reduction (lane-block sum), the
  1/n_r normalization, the diagonal fix-up and ``lax.top_k`` all run inside
  the same compiled step, with the [Q, n] accumulator donated by the caller.

Per-column correctness: for a single walk of length l, the batched telescoped
probe reduces to "for p = l..2: inject e_{u_p}; prune at eps_p/sqrt(c)^(p-1);
push; mask u_{p-1}" — positions beyond l contribute nothing.  The compacted
schedule runs exactly that per-column recurrence with a per-column position
(and hence a per-column prune threshold), so each walk's estimate is
identical to its column in ``probe_walks_telescoped`` up to float summation
order (tested to 1e-5).

Randomness contract: query q's walks depend only on (keys[q], us[q]).  With
explicit per-query ``keys``, a batched call is therefore equivalent to Q
single-query calls — the property the serving engine's batched ``drain()``
relies on (and the tests assert).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.params import ProbeSimParams
from repro.core.probe import push_level_padded
from repro.core.walks import walk_uniforms, walks_from_uniforms
from repro.graph.structs import EllGraph, Graph

Array = jax.Array


# ---------------------------------------------------------------------------
# Lane-compaction helpers — shared by the local fused serve and the
# distributed lane probes (core/distributed.py, core/ring.py)
# ---------------------------------------------------------------------------
#
# The compacted schedule is backend-independent bookkeeping: per-lane-column
# walk positions, pool cursors and refill ranks are tiny replicated vectors,
# identical whether the score buffer is a single [n + 1, W] array (local) or
# a [rows, W] row block per mesh shard (distributed).  Keeping ONE set of
# helpers guarantees the schedules agree step-for-step, which is what makes
# batched-vs-per-query and sharded-vs-local parity tolerance-boundable by
# float summation order alone.


def lane_columns(q: int, wq: int) -> tuple[Array, Array]:
    """Column ids [W] and the owning query of each lane column [W]."""
    cols = jnp.arange(q * wq)
    return cols, cols // wq


def lane_max_steps(n_r: int, max_len: int) -> int:
    """Safety-net trip bound for the compacted loop (it exits early)."""
    return n_r * max_len + max_len + 8


def lane_continue(step, pos, next_q, *, n_r: int, max_steps: int):
    """Loop-continue predicate: walks in flight or pools undrained."""
    return (step < max_steps) & (jnp.any(pos >= 1) | jnp.any(next_q < n_r))


def lane_refill(pos, widx, next_q, pool_len, qid, *, q, wq, n_r):
    """Column bookkeeping for one level: finished-column detection plus
    sticky per-query refill from the pool.

    Pure [W]-vector arithmetic — no score movement — so the fused Pallas
    level kernel and the XLA level composition share it verbatim.  Returns
    ``(fin, pos, widx, next_q)``; ``fin`` marks the columns whose walk just
    finished (the caller deposits their scores into ``total``).  Refill
    pulls walks from each query's pool partition in pool order — selection
    is content-independent, so the estimator stays unbiased.
    """
    w = q * wq
    fin = pos == 1
    pos = jnp.where(fin, 0, pos)
    idle = (pos == 0).astype(jnp.int32).reshape(q, wq)
    rank = (jnp.cumsum(idle, axis=1) - idle).reshape(w)
    take = (pos == 0) & (rank < (n_r - next_q)[qid])
    new_widx = qid * n_r + jnp.minimum(next_q[qid] + rank, n_r - 1)
    widx = jnp.where(take, new_widx, widx)
    pos = jnp.where(take, pool_len[new_widx], pos)
    next_q = next_q + take.astype(jnp.int32).reshape(q, wq).sum(axis=1)
    return fin, pos, widx, next_q


def lane_deposit_refill(
    pos, widx, next_q, scores, total, pool_len, qid, *, q, wq, n_r
):
    """Deposit finished columns into ``total`` and refill idle columns.

    ``scores``/``total`` are [rows, W] blocks (any row count — the helpers
    only touch them columnwise); ``pos``/``widx`` are per-column int32 [W],
    ``next_q`` the per-query pool cursor [Q].  Composition of
    ``lane_refill`` with the columnwise score movement; kept for callers
    that fuse the deposit into their own level (the Pallas kernel path
    calls ``lane_refill`` directly and deposits on-chip).
    """
    fin, pos, widx, next_q = lane_refill(
        pos, widx, next_q, pool_len, qid, q=q, wq=wq, n_r=n_r
    )
    total = total + jnp.where(fin[None, :], scores, 0.0)
    scores = jnp.where(fin[None, :], 0.0, scores)
    return pos, widx, next_q, scores, total


def lane_frontier(pool, widx, pos, sentinel: int):
    """Per-column frontier for one telescoped level at each column's own
    position: ``(active, u_p, u_prev)``; inactive columns get ``sentinel``
    (the local path scatters it into the dump row, the distributed path's
    row-iota compare never matches it)."""
    active = pos >= 2
    u_p = jnp.where(active, pool[widx, jnp.maximum(pos - 1, 0)], sentinel)
    u_prev = jnp.where(active, pool[widx, jnp.maximum(pos - 2, 0)], sentinel)
    return active, u_p, u_prev


def lane_thresholds(pos, *, sqrt_c: float, eps_p: float):
    """Per-column prune threshold (pruning rule 2 at the column's level):
    ``eps_p / sqrt(c)^(pos - 1)`` as [W] f32."""
    return eps_p * jnp.power(
        jnp.float32(sqrt_c), (1 - pos).astype(jnp.float32)
    )


def fused_serve_impl(
    keys: Array,  # [Q] typed PRNG keys, one stream per query
    g: Graph | EllGraph,
    eg: EllGraph,
    us: Array,  # int32 [Q]
    acc: Array,  # f32 [Q, n] donated accumulator (usually zeros)
    *,
    n_r: int,
    lanes_q: int,
    max_len: int,
    sqrt_c: float,
    eps_p: float,
    eps_t: float,
    truncation_shift: bool,
    use_kernel: bool,
    top_k: int,
    kernel_dtype: str = "float32",
):
    """One fused serve step: sample pool -> compacted probe -> estimates.

    ``use_kernel=True`` runs each probe level through the fused Pallas
    lane-probe kernel (``kernels/lane_probe``) against the ELL push table;
    ``kernel_dtype="bfloat16"`` additionally stores the score/accumulator
    buffers in bf16 (accumulation stays fp32 on-chip).  Returns
    ``(acc, est, topk_idx, topk_vals)``; the top-k outputs are None when
    ``top_k == 0``.
    """
    n = eg.n
    q = us.shape[0]
    wq = lanes_q
    w = q * wq
    cols, qid = lane_columns(q, wq)
    dtype = (
        jnp.bfloat16
        if (use_kernel and kernel_dtype == "bfloat16")
        else jnp.float32
    )

    # --- walk pool, pipelined against the first push level ----------------
    # All per-(walk, step) uniforms are drawn up front (bit-identical to a
    # single pooled sample_walks_batch call); only the first wq walks per
    # query — the ones the first refill can possibly claim — are
    # materialized before level 1.  The remaining (n_r - wq) walks'
    # ELL-table scans carry no data dependency on the level loop, so they
    # overlap the first push level instead of serializing ahead of it
    # (~20% of the step, ROADMAP).
    h = min(wq, n_r)
    cont, pick = jax.vmap(
        lambda k: walk_uniforms(k, n_r=n_r, max_len=max_len, sqrt_c=sqrt_c)
    )(keys)
    walks_of = jax.vmap(lambda u1, c, p: walks_from_uniforms(eg, u1, c, p))
    head = walks_of(us, cont[:, :h], pick[:, :h])  # [Q, h, max_len]

    # --- one probe level: deposit + inject + prune + push + exclude -------
    if use_kernel:
        from repro.kernels.lane_probe.ops import lane_probe_level

        ell = g if isinstance(g, EllGraph) else eg
        w_push = ell.inv_in_deg * sqrt_c
        zrow = jnp.zeros((1, w), dtype)

        def level_fn(scores, total, fin, u_p, u_prev, thr):
            out, tot = lane_probe_level(
                ell.in_nbrs, w_push, scores, scores[:n], total[:n],
                fin, u_p, u_prev, thr,
                row0=0, tab0=0, n_live=n, prune=eps_p > 0.0,
            )
            return (
                jnp.concatenate([out, zrow]),
                jnp.concatenate([tot, zrow]),
            )
    else:

        def level_fn(scores, total, fin, u_p, u_prev, thr):
            total = total + jnp.where(fin[None, :], scores, 0.0)
            scores = jnp.where(fin[None, :], 0.0, scores)
            scores = scores.at[u_p, cols].add(1.0)  # sentinel -> dump row
            if eps_p > 0.0:
                scores = jnp.where(scores > thr[None, :], scores, 0.0)
            scores = push_level_padded(g, scores, sqrt_c, use_kernel=False)
            scores = scores.at[u_prev, cols].set(0.0)  # exclusion mask
            return scores, total

    # --- compacted probe loop ---------------------------------------------
    # Per-column state: pos (current walk position; 1/0 = finished/idle),
    # widx (walk id in the flattened pool), next_q (per-query pool cursor).
    # `total` accumulates finished columns; per-query reduction happens once
    # at the end (columns are query-sticky, so lane-block sums separate).
    max_steps = lane_max_steps(n_r, max_len)

    def cond(state):
        step, pos, widx, next_q, scores, total = state
        return lane_continue(step, pos, next_q, n_r=n_r, max_steps=max_steps)

    def body(state, pool, pool_len):
        step, pos, widx, next_q, scores, total = state
        fin, pos, widx, next_q = lane_refill(
            pos, widx, next_q, pool_len, qid, q=q, wq=wq, n_r=n_r
        )
        # one telescoped level per active column, at its own position
        active, u_p, u_prev = lane_frontier(pool, widx, pos, n)
        thr = lane_thresholds(pos, sqrt_c=sqrt_c, eps_p=eps_p)
        scores, total = level_fn(scores, total, fin, u_p, u_prev, thr)
        pos = jnp.where(active, pos - 1, pos)
        return step + 1, pos, widx, next_q, scores, total

    state = (
        jnp.int32(0),
        jnp.zeros(w, jnp.int32),  # pos: all idle -> first iteration refills
        jnp.zeros(w, jnp.int32),  # widx
        jnp.zeros(q, jnp.int32),  # next_q
        jnp.zeros((n + 1, w), dtype),  # scores (baked dump row)
        jnp.zeros((n + 1, w), dtype),  # total (baked dump row)
    )
    # First level runs against the head-only pool (the first refill can only
    # claim head walks, so this is bit-identical to the full-pool level);
    # the tail walks materialize concurrently with it.
    if h < n_r:
        head_pool = jnp.concatenate(
            [head, jnp.full((q, n_r - h, max_len), n, jnp.int32)], axis=1
        ).reshape(q * n_r, max_len)
        head_len = (head_pool < n).sum(axis=1).astype(jnp.int32)
        state = body(state, head_pool, head_len)
        tail = walks_of(us, cont[:, h:], pick[:, h:])
        pool = jnp.concatenate([head, tail], axis=1).reshape(
            q * n_r, max_len
        )
        pool_len = (pool < n).sum(axis=1).astype(jnp.int32)
    else:
        pool = head.reshape(q * n_r, max_len)
        pool_len = (pool < n).sum(axis=1).astype(jnp.int32)
        state = body(state, pool, pool_len)
    step, pos, _, _, scores, total = jax.lax.while_loop(
        cond, lambda s: body(s, pool, pool_len), state
    )
    # safety-net flush (no-op unless max_steps was hit)
    total = total + jnp.where((pos == 1)[None, :], scores, 0.0)

    # --- per-query segment reduction + epilogue ---------------------------
    acc = acc + total[:n].astype(jnp.float32).reshape(n, q, wq).sum(axis=2).T
    est = acc / n_r
    if truncation_shift:
        est = jnp.where(est > 0, est + eps_t / 2, est)
    est = est.at[jnp.arange(q), us].set(1.0)
    if top_k > 0:
        masked = est.at[jnp.arange(q), us].set(-jnp.inf)
        vals, idx = jax.lax.top_k(masked, top_k)
        return acc, est, idx, vals
    return acc, est, None, None


# The standalone jitted entry point.  ``fused_serve_impl`` stays un-jitted so
# larger fused steps can trace it inline — the dynamic epoch step
# (serving/dynamic_engine.py) composes `apply_update_batch -> fused_serve_impl`
# inside ONE jit, which a nested jitted call with donated operands would
# complicate for no benefit.
_fused_serve = partial(
    jax.jit,
    static_argnames=(
        "n_r",
        "lanes_q",
        "max_len",
        "sqrt_c",
        "eps_p",
        "eps_t",
        "truncation_shift",
        "use_kernel",
        "top_k",
        "kernel_dtype",
    ),
    donate_argnames=("acc",),
)(fused_serve_impl)


def _query_keys(key: Array | None, keys: Array | None, q: int) -> Array:
    if keys is not None:
        return keys
    if key is None:
        raise ValueError("multi_source needs `key` or per-query `keys`")
    return jax.random.split(key, q)


def multi_source(
    key: Array | None,
    g: Graph | EllGraph,
    eg: EllGraph,
    us: Array,
    params: ProbeSimParams,
    *,
    lanes: int = 256,
    use_kernel: bool = False,
    kernel_dtype: str = "float32",
    n_r: int | None = None,
    keys: Array | None = None,
) -> Array:
    """Fused multi-query single-source SimRank: estimates [Q, n].

    ``us`` is int32 [Q]; ``g`` is the push representation (COO or ELL), ``eg``
    the ELL table used for walk sampling.  ``lanes`` is the total lane-column
    width shared by the batch (each query owns ``lanes // Q`` columns).
    ``use_kernel=True`` serves every probe level through the fused Pallas
    lane-probe kernel (bitwise-equal to the XLA ELL path in fp32);
    ``kernel_dtype="bfloat16"`` stores the lane buffers bf16 with fp32
    accumulation.  ``n_r`` overrides ``params.n_r`` (anytime/budgeted
    serving).  Pass per-query ``keys`` ([Q] typed key array) for
    batch-vs-serial determinism; otherwise ``key`` is split into Q streams.
    """
    us = jnp.asarray(us, jnp.int32)
    q = int(us.shape[0])
    n_walks = int(n_r or params.n_r)
    acc = jnp.zeros((q, g.n), jnp.float32)
    _, est, _, _ = _fused_serve(
        _query_keys(key, keys, q), g, eg, us, acc,
        n_r=n_walks,
        lanes_q=max(1, lanes // q),
        max_len=params.max_len,
        sqrt_c=params.sqrt_c,
        eps_p=params.eps_p,
        eps_t=params.eps_t,
        truncation_shift=params.truncation_shift,
        use_kernel=use_kernel,
        top_k=0,
        kernel_dtype=kernel_dtype,
    )
    return est


def multi_source_topk(
    key: Array | None,
    g: Graph | EllGraph,
    eg: EllGraph,
    us: Array,
    k: int,
    params: ProbeSimParams,
    *,
    lanes: int = 256,
    use_kernel: bool = False,
    kernel_dtype: str = "float32",
    n_r: int | None = None,
    keys: Array | None = None,
) -> tuple[Array, Array]:
    """Fused batched top-k (paper Def. 2): (nodes [Q, k], estimates [Q, k]).

    The query node itself is excluded; ``top_k`` runs inside the same
    compiled step as sampling and the probe.
    """
    us = jnp.asarray(us, jnp.int32)
    q = int(us.shape[0])
    n_walks = int(n_r or params.n_r)
    acc = jnp.zeros((q, g.n), jnp.float32)
    _, _, idx, vals = _fused_serve(
        _query_keys(key, keys, q), g, eg, us, acc,
        n_r=n_walks,
        lanes_q=max(1, lanes // q),
        max_len=params.max_len,
        sqrt_c=params.sqrt_c,
        eps_p=params.eps_p,
        eps_t=params.eps_t,
        truncation_shift=params.truncation_shift,
        use_kernel=use_kernel,
        top_k=int(k),
        kernel_dtype=kernel_dtype,
    )
    return idx, vals
