"""Vectorized sqrt(c)-walk generation (paper Def. 3).

A sqrt(c)-walk from u follows a uniformly random **in**-neighbor at each step
and terminates with probability 1 - sqrt(c) per step (or at a node with no
in-neighbors).  We generate a batch of walks as a dense int32 matrix
``walks[n_r, max_len]`` with ``walks[:, 0] = u`` and sentinel ``n`` after
termination.  Walks are truncated at ``max_len`` = l_t (Pruning rule 1).

Sampling uses the ELL in-neighbor table: next = in_nbrs[v, floor(r * deg(v))].

Two entry points:

* ``sample_walks``       — n_r walks from a single source (one PRNG stream).
* ``sample_walks_batch`` — Q independent per-query streams, one vmapped
  dispatch.  This is the fused-serving path (DESIGN.md §3): the whole walk
  pool for a query batch is drawn in ONE call, because per-chunk sampling
  pays a large fixed cost per dispatch (the ELL table walk) that a pooled
  call amortizes to noise.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graph.structs import EllGraph

Array = jax.Array


def walk_uniforms(
    key: Array,
    *,
    n_r: int,
    max_len: int,
    sqrt_c: float,
) -> tuple[Array, Array]:
    """Draw the per-(walk, step) randomness for ``n_r`` walks up front.

    Returns ``(cont, pick)``, both [n_r, max_len - 1]: the continue/stop
    coins (bool, continue w.p. sqrt(c)) and the neighbor-pick uniforms.
    Walks are row-independent given these draws, so any row subset can be
    materialized separately (``walks_from_uniforms``) and still be
    bit-identical to a full-pool ``sample_walks`` call — the property the
    pipelined serve path (DESIGN.md §3) relies on to overlap tail-walk
    sampling with the first push level.
    """
    k_cont, k_step = jax.random.split(key)
    cont = jax.random.uniform(k_cont, (n_r, max_len - 1)) < sqrt_c
    pick = jax.random.uniform(k_step, (n_r, max_len - 1))
    return cont, pick


def walks_from_uniforms(
    eg: EllGraph,
    u: Array,
    cont: Array,
    pick: Array,
) -> Array:
    """Materialize walks [R, max_len] from pre-drawn uniforms (any row
    subset of a ``walk_uniforms`` batch)."""
    n = eg.n
    n_r = cont.shape[0]

    def step(carry, inputs):
        cur, alive = carry  # cur: [n_r] current node; alive: [n_r] bool
        cont_t, pick_t = inputs
        deg = eg.in_deg[cur.clip(0, n - 1)]
        can_move = alive & cont_t & (deg > 0)
        k = jnp.floor(pick_t * deg.astype(jnp.float32)).astype(jnp.int32)
        k = k.clip(0, jnp.maximum(deg - 1, 0))
        nxt = eg.in_nbrs[cur.clip(0, n - 1), k]
        nxt = jnp.where(can_move, nxt, n)
        return (nxt, can_move), nxt

    u_col = jnp.broadcast_to(jnp.asarray(u, jnp.int32), (n_r,))
    (_, _), cols = jax.lax.scan(
        step, (u_col, jnp.ones(n_r, dtype=bool)), (cont.T, pick.T)
    )
    walks = jnp.concatenate([u_col[:, None], cols.T], axis=1)
    return walks.astype(jnp.int32)


def _sample_walks_impl(
    key: Array,
    eg: EllGraph,
    u: Array,
    *,
    n_r: int,
    max_len: int,
    sqrt_c: float,
) -> Array:
    """Trace-level body shared by the single- and multi-query entry points."""
    cont, pick = walk_uniforms(key, n_r=n_r, max_len=max_len, sqrt_c=sqrt_c)
    return walks_from_uniforms(eg, u, cont, pick)


@partial(jax.jit, static_argnames=("n_r", "max_len", "sqrt_c"))
def sample_walks(
    key: Array,
    eg: EllGraph,
    u: Array,
    *,
    n_r: int,
    max_len: int,
    sqrt_c: float,
) -> Array:
    """Sample ``n_r`` sqrt(c)-walks from node ``u``.

    Returns int32 [n_r, max_len]; walks[:, 0] == u; sentinel = n.
    """
    return _sample_walks_impl(
        key, eg, u, n_r=n_r, max_len=max_len, sqrt_c=sqrt_c
    )


@partial(jax.jit, static_argnames=("n_r", "max_len", "sqrt_c"))
def sample_walks_batch(
    keys: Array,
    eg: EllGraph,
    us: Array,
    *,
    n_r: int,
    max_len: int,
    sqrt_c: float,
) -> Array:
    """Sample ``n_r`` walks from each of Q sources, one per-query PRNG stream.

    ``keys`` is a [Q] typed key array; ``us`` is int32 [Q].  Returns int32
    [Q, n_r, max_len].  Query q's walks depend only on (keys[q], us[q]), so a
    batched serve produces bit-identical walks to Q separate single-query
    calls with the same per-query keys (exercised by the engine tests).
    """
    us = jnp.asarray(us, jnp.int32)
    return jax.vmap(
        lambda k, u: _sample_walks_impl(
            k, eg, u, n_r=n_r, max_len=max_len, sqrt_c=sqrt_c
        )
    )(keys, us)


def walk_lengths(walks: Array, n: int) -> Array:
    """Number of live nodes per walk (l in the paper)."""
    return (walks < n).sum(axis=1).astype(jnp.int32)
