"""Distributed ProbeSim — the multi-pod serving path.

Layout (production mesh ("pod", "data", "model")):

* graph: in-CSR offsets + in-degrees row-sharded on ``model``; the flat
  ``indices``/COO ``src``/``dst`` edge arrays sharded over all axes (they are
  the bulk of the footprint: m * 12 B);
* score frontier S [n_pad, Q*B]: rows on ``model``, walk columns on
  ``data`` (2-D sharding keeps the per-device block ~100s of MB at
  billion-edge scale);
* queries on ``data`` via the column dimension.

This module is the *baseline* distribution: pjit + sharding constraints,
letting the SPMD partitioner place the collectives (recorded by the
roofline).  The §Perf hillclimb adds a manual shard_map ring variant
(`probe_level_ring`) that pipelines the source-score exchange with the
per-block gather/scatter compute.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import constrain, logical_spec, mesh_axis_names
from repro.utils.jaxcompat import legacy_auto_partitioner
from repro.utils.pytree import static, struct

Array = jax.Array


def _constrain(x: Array, *logical: str | None) -> Array:
    """Frontier placement hint for the auto partitioner.

    Old jax's SPMD partitioner double-counts scatter contributions when the
    scatter operand is row-sharded by an explicit constraint (see
    jaxcompat.legacy_auto_partitioner) — there the hints are dropped and
    placement is left to the partitioner, which is correct (tested in
    tests/test_distributed.py) if less deliberate.
    """
    if legacy_auto_partitioner():
        return x
    return constrain(x, *logical)


@struct
class ShardedGraph:
    """Device-resident graph for distributed ProbeSim."""

    indptr: Array  # int32 [n_pad] in-CSR start offset per node (m < 2^31;
    #   friendster-scale (m=2.6e9) requires int64 + jax_enable_x64)
    in_deg: Array  # int32 [n_pad]
    indices: Array  # int32 [m_pad] in-neighbor lists (CSR values)
    src: Array  # int32 [m_pad] COO (for the push)
    dst: Array  # int32 [m_pad]
    n: int = static()
    n_pad: int = static()
    m: int = static()
    m_pad: int = static()

    @property
    def inv_in_deg(self) -> Array:
        d = self.in_deg.astype(jnp.float32)
        return jnp.where(d > 0, 1.0 / jnp.maximum(d, 1.0), 0.0)


def build_sharded_graph(
    src: np.ndarray, dst: np.ndarray, n: int, *, pad_nodes: int = 1,
    pad_edges: int = 1,
) -> ShardedGraph:
    """Host-side constructor (also used with ShapeDtypeStruct for dry-run)."""
    m = len(src)
    n_pad = ((n + pad_nodes - 1) // pad_nodes) * pad_nodes
    m_pad = ((m + pad_edges - 1) // pad_edges) * pad_edges
    order = np.argsort(dst, kind="stable")
    indices = np.full(m_pad, n_pad, dtype=np.int32)
    indices[:m] = src[order]
    in_deg = np.zeros(n_pad, dtype=np.int32)
    cnt = np.bincount(dst, minlength=n)
    in_deg[:n] = cnt[:n]
    indptr = np.zeros(n_pad, dtype=np.int32)
    np.cumsum(cnt[: n - 1], out=indptr[1:n])
    src_p = np.full(m_pad, n_pad, dtype=np.int32)
    dst_p = np.full(m_pad, n_pad, dtype=np.int32)
    src_p[:m] = src
    dst_p[:m] = dst
    return ShardedGraph(
        indptr=jnp.asarray(indptr),
        in_deg=jnp.asarray(in_deg),
        indices=jnp.asarray(indices),
        src=jnp.asarray(src_p),
        dst=jnp.asarray(dst_p),
        n=n, n_pad=n_pad, m=m, m_pad=m_pad,
    )


def graph_specs(sg: ShardedGraph) -> ShardedGraph:
    """PartitionSpec pytree matching ShardedGraph (static fields copied —
    pytree treedefs include the static metadata).

    On old jax ``in_deg`` is replicated: the legacy partitioner mis-scales
    the probe's ``concat(inv_in_deg, pad) * acc`` renormalization by the
    axis extent when ``in_deg`` arrives row-sharded (same family of bug as
    the ``_constrain`` gate above; [n_pad] int32 is cheap to replicate).
    """
    tp = "model" if "model" in mesh_axis_names() else None
    all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh_axis_names())
    return ShardedGraph(
        indptr=P(tp),
        in_deg=P(None) if legacy_auto_partitioner() else P(tp),
        indices=P(all_axes if all_axes else None),
        src=P(all_axes if all_axes else None),
        dst=P(all_axes if all_axes else None),
        n=sg.n, n_pad=sg.n_pad, m=sg.m, m_pad=sg.m_pad,
    )


# ---------------------------------------------------------------------------
# Distributed walk sampling (CSR gathers; frontier is tiny and replicated)
# ---------------------------------------------------------------------------


def sample_walks_sharded(
    key: Array,
    sg: ShardedGraph,
    queries: Array,  # int32 [Q]
    *,
    walks_per_query: int,
    max_len: int,
    sqrt_c: float,
) -> Array:
    """Returns walks int32 [Q * B, max_len] (sentinel = n_pad)."""
    Q = queries.shape[0]
    B = walks_per_query
    n_pad = sg.n_pad
    cur = jnp.repeat(queries, B).astype(jnp.int32)  # [Q*B]
    k_cont, k_pick = jax.random.split(key)
    cont = jax.random.uniform(k_cont, (max_len - 1, Q * B)) < sqrt_c
    pick = jax.random.uniform(k_pick, (max_len - 1, Q * B))

    def step(carry, inputs):
        cur, alive = carry
        cont_t, pick_t = inputs
        cc = cur.clip(0, n_pad - 1)
        deg = sg.in_deg[cc]
        start = sg.indptr[cc]
        can = alive & cont_t & (deg > 0)
        k = jnp.floor(pick_t * deg.astype(jnp.float32)).astype(jnp.int32)
        k = k.clip(0, jnp.maximum(deg - 1, 0))
        g = (start + k).clip(0, sg.indices.shape[0] - 1)
        nxt = sg.indices[g]
        nxt = jnp.where(can, nxt, n_pad)
        return (nxt, can), nxt

    (_, _), cols = jax.lax.scan(
        step, (cur, jnp.ones(Q * B, bool)), (cont, pick)
    )
    return jnp.concatenate([cur[None, :], cols], axis=0).T  # [Q*B, L]


# ---------------------------------------------------------------------------
# Distributed telescoped probe (edge-chunked COO pushes)
# ---------------------------------------------------------------------------


def _push_chunked(
    sg: ShardedGraph, scores: Array, sqrt_c: float, edge_chunks: int
) -> Array:
    """scores [rows_total, C] -> pushed [rows_total, C] over edge chunks."""
    n_pad = sg.n_pad
    C = scores.shape[1]
    m_pad = sg.m_pad
    assert m_pad % edge_chunks == 0
    mc = m_pad // edge_chunks
    src = sg.src.reshape(edge_chunks, mc)
    dst = sg.dst.reshape(edge_chunks, mc)

    # python loop (not lax.scan): cost_analysis counts loop bodies once,
    # and the dry-run's flop/collective numbers must see every chunk
    rows_total = scores.shape[0]
    acc = jnp.zeros_like(scores)
    for ci in range(edge_chunks):
        msgs = scores[src[ci].clip(0, n_pad)]  # [mc, C]; sentinel row zero
        msgs = _constrain(msgs, "tp", "dp")
        acc = acc + jax.ops.segment_sum(
            msgs, dst[ci], num_segments=rows_total
        )
    w = jnp.concatenate([
        sg.inv_in_deg,
        jnp.zeros((rows_total - n_pad,), jnp.float32),
    ]) * sqrt_c
    return acc * w[:, None]


def probe_walks_sharded(
    sg: ShardedGraph,
    walks: Array,  # [C, L] (C = Q*B columns)
    *,
    sqrt_c: float,
    eps_p: float = 0.0,
    edge_chunks: int = 8,
) -> Array:
    """Telescoped batched probe with 2-D-sharded scores; returns [n_pad, C].

    Injections and exclusion masks are *broadcast-compare* arithmetic (a row
    iota against the per-column walk node), not scatters: elementwise ops
    partition trivially under 2-D sharding, where (row, col)-indexed scatters
    trip the SPMD partitioner and serialize on TPU.
    The score matrix carries one extra padding row-block; row ``n_pad`` is
    the sentinel dump row (always zero)."""
    n_pad = sg.n_pad
    C, L = walks.shape
    rows_total = n_pad + _row_pad(sg)
    rows = jax.lax.broadcasted_iota(jnp.int32, (rows_total, C), 0)
    scores = jnp.zeros((rows_total, C), jnp.float32)
    scores = _constrain(scores, "tp", "dp")
    for p in range(L, 1, -1):
        u_p = walks[:, p - 1]  # sentinel (>= n_pad) never matches a live row
        u_prev = walks[:, p - 2]
        scores = scores + (rows == u_p[None, :]).astype(jnp.float32)
        if eps_p > 0.0:
            thresh = eps_p / (sqrt_c ** (p - 1))
            scores = jnp.where(scores > thresh, scores, 0.0)
        scores = _push_chunked(sg, scores, sqrt_c, edge_chunks)
        scores = jnp.where(rows == u_prev[None, :], 0.0, scores)
        scores = _constrain(scores, "tp", "dp")
    return scores[:n_pad]


# ---------------------------------------------------------------------------
# Lane-batched distributed probe (compacted schedule inside shard_map)
# ---------------------------------------------------------------------------


def lane_level_xla(push_block, *, row0, rows, w, eps_p: float):
    """Build the XLA level function for one shard's [rows, W] block.

    The level is the same deposit + inject + prune + push + exclude
    sequence the local serve runs, with injection/exclusion as row-iota
    compares (elementwise — no cross-shard scatters).  ``push_block``
    performs one renormalized push level over the full graph for this row
    block (all-gather or ring exchange — the caller owns the collective
    pattern).
    """
    rid = jax.lax.broadcasted_iota(jnp.int32, (rows, w), 0) + row0

    def level_fn(scores, total, fin, u_p, u_prev, thr):
        total = total + jnp.where(fin[None, :], scores, 0.0)
        scores = jnp.where(fin[None, :], 0.0, scores)
        scores = scores + (rid == u_p[None, :]).astype(jnp.float32)
        if eps_p > 0.0:
            scores = jnp.where(scores > thr[None, :], scores, 0.0)
        scores = push_block(scores)
        scores = jnp.where(rid == u_prev[None, :], 0.0, scores)
        return scores, total

    return level_fn


def lane_probe_block(
    level_fn,
    pool: Array,  # int32 [Q*n_r, L] replicated walk pool (sentinel >= n)
    pool_len: Array,  # int32 [Q*n_r] replicated
    *,
    rows: int,
    q: int,
    wq: int,
    n_r: int,
    max_len: int,
    sqrt_c: float,
    eps_p: float,
    sentinel: int,
) -> Array:
    """Compacted lane probe over ONE row block; returns ``total`` [rows, W].

    The distributed counterpart of ``fused_serve_impl``'s loop: the same
    shared lane-compaction bookkeeping (``core.multisource``) drives a
    caller-supplied level function.  The bookkeeping operands
    (``pool_len``, cursors, positions) are replicated, so every shard takes
    the identical trip count and the collectives inside ``level_fn`` line
    up across the mesh.

    ``level_fn(scores, total, fin, u_p, u_prev, thr) -> (scores, total)``
    executes one full probe level — deposit of finishing columns, unit
    injection at ``u_p``, pruning at ``thr``, the renormalized push, and
    the ``u_prev`` exclusion — either as the XLA composition
    (``lane_level_xla``) or fused on-chip (``kernels/lane_probe``).
    ``sentinel`` is the pool's walk-end marker; sentinel ids either hit a
    padding row (whose pushed mass is sliced away by the caller's ``[:n]``)
    or nothing.
    """
    from repro.core.multisource import (
        lane_columns,
        lane_continue,
        lane_frontier,
        lane_max_steps,
        lane_refill,
        lane_thresholds,
    )

    w = q * wq
    _, qid = lane_columns(q, wq)
    max_steps = lane_max_steps(n_r, max_len)

    def cond(state):
        step, pos, widx, next_q, scores, total = state
        return lane_continue(step, pos, next_q, n_r=n_r, max_steps=max_steps)

    def body(state):
        step, pos, widx, next_q, scores, total = state
        fin, pos, widx, next_q = lane_refill(
            pos, widx, next_q, pool_len, qid, q=q, wq=wq, n_r=n_r
        )
        active, u_p, u_prev = lane_frontier(pool, widx, pos, sentinel)
        thr = lane_thresholds(pos, sqrt_c=sqrt_c, eps_p=eps_p)
        scores, total = level_fn(scores, total, fin, u_p, u_prev, thr)
        pos = jnp.where(active, pos - 1, pos)
        return step + 1, pos, widx, next_q, scores, total

    state = (
        jnp.int32(0),
        jnp.zeros(w, jnp.int32),  # pos: all idle -> first iteration refills
        jnp.zeros(w, jnp.int32),  # widx
        jnp.zeros(q, jnp.int32),  # next_q
        jnp.zeros((rows, w), jnp.float32),  # scores block
        jnp.zeros((rows, w), jnp.float32),  # total block
    )
    step, pos, _, _, scores, total = jax.lax.while_loop(cond, body, state)
    # safety-net flush (no-op unless max_steps was hit)
    return total + jnp.where((pos == 1)[None, :], scores, 0.0)


def probe_lanes_sharded(
    src_sh: Array,  # int32 [S, E] global src ids per shard (sentinel n_pad)
    dst_sh: Array,  # int32 [S, E] global dst ids per shard (sentinel n_pad)
    counts: Array,  # int32 [S] live edges per shard (prefix of the buffer)
    w_full: Array,  # f32 [n_pad] sqrt(c)/in_deg renorm weights (0 if deg 0)
    pool: Array,  # int32 [Q*n_r, L] replicated (sentinel n — ELL sampler)
    pool_len: Array,  # int32 [Q*n_r] replicated
    mesh,
    *,
    n_pad: int,
    rows: int,
    q: int,
    wq: int,
    n_r: int,
    max_len: int,
    sqrt_c: float,
    eps_p: float,
    sentinel: int,
    edge_chunk: int = 2048,
    use_kernel: bool = False,
    in_nbrs: Array | None = None,
    frontier_dtype: str = "float32",
) -> Array:
    """Lane-batched telescoped probe, all-gather push; returns [n_pad, W].

    One fully-manual shard_map program: each model shard runs the compacted
    lane loop over its own [rows, W] frontier block; a push level all-gathers
    the frontier once, gathers its resident COO bucket's source rows and
    segment-sums into its destination rows.  Lane columns are REPLICATED over
    the data axes (the batch is one program — no per-chunk column sharding,
    hence no divisibility constraint on Q*W).

    The push walks each shard's bucket in fixed-width slices (width
    ``max(edge_chunk, E/8)``) with a per-shard dynamic trip count — live edges
    are a prefix of the buffer (FIFO compaction), so capacity padding and
    dst-skew headroom cost nothing: total gather/scatter work is the LIVE
    edge count, not shards x max-bucket capacity.  The dynamic bound is
    safe under shard_map because no collective sits inside the chunk loop
    (the all-gather happens once per level, before it); shards with fewer
    edges simply finish their level sooner.  Sentinel slots inside the last
    live chunk gather a garbage row but scatter into the dropped segment
    ``rows`` (their dst is the sentinel), so no zero-row append is needed.

    ``use_kernel=True`` replaces the COO chunk loop with the fused Pallas
    lane-probe level (``kernels/lane_probe``) gathering from the all-gathered
    frontier through the row-sharded ELL table ``in_nbrs`` ([n_pad, k_max],
    sentinel ``sentinel``) — deposit/inject/prune/push/exclude in one pass
    per level.  ``frontier_dtype="bfloat16"`` halves the per-level
    all_gather wire volume (the dominant collective, ROADMAP): the frontier
    is rounded to bf16 and bitcast to uint16 for the exchange (the same
    wire trick as ``core/ring.py``), then widened back — accumulation,
    deposits and the carried block stay fp32, and the single-shard
    degenerate path skips the exchange (and the rounding) entirely.
    """
    from repro.utils.jaxcompat import shard_map

    # sort each shard's bucket by source id, once per serve call: the push
    # gathers frontier rows in ascending-address order (cache-line reuse on
    # the [n_pad, W] gathered table) instead of FIFO-random, and sentinel
    # slots (src = n_pad) sort to the tail so the live prefix the chunk
    # loop relies on is preserved.  The carried mirror itself stays FIFO —
    # this is a derived view inside the compiled step, so epoch-path
    # bitwise invariants are untouched.
    perm = jnp.argsort(src_sh, axis=1)
    src_sh = jnp.take_along_axis(src_sh, perm, axis=1)
    dst_sh = jnp.take_along_axis(dst_sh, perm, axis=1)

    E = src_sh.shape[1]
    # edge_chunk is a FLOOR on the slice width, not the width itself: the
    # chunk loop's job is skipping dead tail slots on skewed shards, and
    # its granularity only needs to resolve the count skew.  Tiny chunks
    # are pure overhead (each one re-touches the [rows+1, W] accumulator:
    # at 1 shard a 2048-wide chunking of a 90k-edge bucket measured 2.3x
    # slower than one whole-bucket segment_sum), so cap the trip count at
    # ~8 and let the width grow with the bucket.
    ch = min(max(edge_chunk, -(-E // 8)), E)
    e_pad = -(-E // ch) * ch
    if e_pad != E:
        fill = jnp.full((src_sh.shape[0], e_pad - E), n_pad, jnp.int32)
        src_sh = jnp.concatenate([src_sh, fill], axis=1)
        dst_sh = jnp.concatenate([dst_sh, fill], axis=1)

    wire_bf16 = frontier_dtype == "bfloat16"

    def _exchange(scores):
        """Per-level frontier all_gather, optionally on a bf16 wire."""
        if rows == n_pad:
            # one model shard owns every row: the local block IS the full
            # frontier, and the degenerate all_gather is a pure [n_pad, W]
            # copy per level — skip it (no bf16 rounding either: the wire
            # format only exists where there is a wire)
            return scores
        if wire_bf16:
            bits = jax.lax.bitcast_convert_type(
                scores.astype(jnp.bfloat16), jnp.uint16
            )
            bits = jax.lax.all_gather(bits, "model", axis=0, tiled=True)
            return jax.lax.bitcast_convert_type(
                bits, jnp.bfloat16
            ).astype(jnp.float32)
        return jax.lax.all_gather(scores, "model", axis=0, tiled=True)

    def local(src_b, dst_b, cnt_b, w_l, pool_l, plen_l, ell_l=None):
        # src_b/dst_b [1, e_pad]; cnt_b [1]; w_l [rows]; pool replicated
        me = jax.lax.axis_index("model")
        row0 = me * rows

        if use_kernel:
            from repro.kernels.lane_probe.ops import lane_probe_level

            def level_fn(scores, total, fin, u_p, u_prev, thr):
                # deposit reads the exact local block; only the gathered
                # frontier rides the (possibly bf16) wire
                full = _exchange(scores)
                return lane_probe_level(
                    ell_l, w_l, full, scores, total,
                    fin, u_p, u_prev, thr,
                    row0=row0, tab0=row0, n_live=sentinel,
                    prune=eps_p > 0.0,
                )
        else:
            # clip into the real row range: sentinel srcs read a garbage
            # row whose message lands in the dropped segment (sentinel dst)
            sb = src_b[0].clip(0, n_pad - 1)
            db = (dst_b[0] - row0).clip(0, rows)
            n_chunks = (cnt_b[0] + ch - 1) // ch

            def push_block(scores):
                full = _exchange(scores)

                def chunk(i, acc):
                    s_c = jax.lax.dynamic_slice(sb, (i * ch,), (ch,))
                    d_c = jax.lax.dynamic_slice(db, (i * ch,), (ch,))
                    return acc + jax.ops.segment_sum(
                        full[s_c], d_c, num_segments=rows + 1
                    )

                acc = jax.lax.fori_loop(
                    0, n_chunks, chunk,
                    jnp.zeros((rows + 1, scores.shape[1]), jnp.float32),
                )[:rows]
                return acc * w_l[:, None]

            level_fn = lane_level_xla(
                push_block, row0=row0, rows=rows, w=q * wq, eps_p=eps_p
            )

        return lane_probe_block(
            level_fn, pool_l, plen_l,
            rows=rows, q=q, wq=wq, n_r=n_r,
            max_len=max_len, sqrt_c=sqrt_c, eps_p=eps_p, sentinel=sentinel,
        )

    in_specs = [
        P("model", None), P("model", None), P("model"), P("model"),
        P(), P(),
    ]
    args = [src_sh, dst_sh, counts, w_full, pool, pool_len]
    if use_kernel:
        if in_nbrs is None:
            raise ValueError("use_kernel=True needs the row-sharded ELL "
                             "table (in_nbrs)")
        in_specs.append(P("model", None))
        args.append(in_nbrs)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=P("model", None),
        # fully manual (same reason as the epoch apply step: leftover auto
        # axes lower axis_index to a PartitionId old-jax rejects); inputs
        # and compute replicate over the data axes
        axis_names=set(mesh.axis_names),
    )
    return fn(*args)


def _row_pad(sg: ShardedGraph) -> int:
    """Extra score rows so (n_pad + pad) stays mesh-divisible; >= 1 so the
    sentinel row n_pad exists."""
    from repro.models.common import axis_size

    block = max(axis_size("tp"), 1)
    return block - (sg.n_pad % block) if sg.n_pad % block else block


def make_serve_step(cfg, *, queries: int, walk_chunk: int, max_len: int,
                    top_k: int = 50, edge_chunks: int = 8):
    """Build the jit-able ProbeSim serving step for the production mesh.

    step(graph, query_nodes [Q], key) -> (topk_idx [Q, k], topk_val [Q, k])
    One step processes `walk_chunk` walks per query; the serving engine loops
    steps, folding results (estimates are means over walk chunks).
    """
    import math

    sqrt_c = math.sqrt(cfg.c)

    def serve_step(sg: ShardedGraph, query_nodes: Array, key: Array):
        walks = sample_walks_sharded(
            key, sg, query_nodes, walks_per_query=walk_chunk,
            max_len=max_len, sqrt_c=sqrt_c,
        )
        scores = probe_walks_sharded(
            sg, walks, sqrt_c=sqrt_c, edge_chunks=edge_chunks
        )  # [n_pad, Q*B]
        est = scores.reshape(sg.n_pad, queries, walk_chunk).sum(-1) / walk_chunk
        est = _constrain(est, "tp", None)
        # exclude the query nodes themselves (compare, not scatter)
        rows = jax.lax.broadcasted_iota(jnp.int32, est.shape, 0)
        est = jnp.where(rows == query_nodes[None, :], -jnp.inf, est)
        vals, idx = jax.lax.top_k(est.T, top_k)  # [Q, k]
        return idx, vals

    return serve_step
