"""ProbeSim single-source & top-k drivers (paper Alg. 1 + Alg. 3 + §4).

Variants (all estimate the same unbiased quantity; tested for agreement):

* ``reference``   — literal Alg. 1/2, python loops (oracle; small inputs).
* ``telescoped``  — the fused serve path (default): the Q = 1 specialization
                    of ``core.multisource.multi_source`` — pooled walk
                    sampling, compacted telescoped probe and the epilogue all
                    in one compiled step (DESIGN.md §3).
* ``tree``        — Alg. 3 prefix-tree batching + telescoping (fastest when
                    n_r is large relative to the distinct-prefix count).
* ``randomized``  — Alg. 4 Bernoulli probes, O(n) per level.

The "best of both worlds" switch (§4.4) is exposed as ``variant='auto'``: it
compares the deterministic cost model (edges touched per level, from degree
stats) against the randomized one (n per level x tree weight) per depth and
picks the cheaper — decided on host from static degree statistics, since TPU
control flow must be shape-static (see DESIGN.md §2).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.multisource import multi_source
from repro.core.params import ProbeSimParams, make_params
from repro.core.probe import (
    estimate_walk_reference,
    probe_tree_levels,
    probe_walks_telescoped,
)
from repro.core.probe_random import randomized_probe_walk
from repro.core.tree import build_prefix_tree
from repro.core.walks import sample_walks
from repro.graph.structs import EllGraph, Graph

Array = jax.Array


def _walk_chunks(n_r: int, chunk: int) -> list[int]:
    sizes = []
    left = n_r
    while left > 0:
        sizes.append(min(chunk, left))
        left -= chunk
    return sizes


def single_source(
    key: Array,
    g: Graph | EllGraph,
    eg: EllGraph,
    u: int,
    params: ProbeSimParams,
    *,
    variant: str = "telescoped",
    walk_chunk: int = 512,
    use_kernel: bool = False,
) -> Array:
    """Approximate single-source SimRank: returns estimates [n] (entry u = 1).

    ``g`` is the push representation (COO or ELL), ``eg`` the ELL table used
    for walk sampling (they may be the same object).
    """
    n = eg.n
    sqrt_c = params.sqrt_c
    total = jnp.zeros(n, dtype=jnp.float32)

    if variant == "reference":
        walks = sample_walks(
            key, eg, u, n_r=params.n_r, max_len=params.max_len, sqrt_c=sqrt_c
        )
        for k in range(params.n_r):
            total = total + estimate_walk_reference(
                g, walks[k], sqrt_c, eps_p=params.eps_p
            )
    elif variant == "telescoped":
        # Q = 1 specialization of the fused multi-query serve path: one
        # compiled step samples the whole walk pool, runs the compacted
        # telescoped probe and finalizes the estimate (DESIGN.md §3).
        return multi_source(
            key, g, eg, jnp.asarray([u], jnp.int32), params,
            lanes=walk_chunk, use_kernel=use_kernel,
        )[0]
    elif variant in ("tree", "auto"):
        for ci, b in enumerate(_walk_chunks(params.n_r, walk_chunk)):
            ck = jax.random.fold_in(key, ci)
            # the final partial chunk samples exactly b walks (the seed
            # sampled a full walk_chunk and masked the surplus with a
            # sentinel fill — wasted sampling work)
            walks = sample_walks(
                ck, eg, u, n_r=b, max_len=params.max_len, sqrt_c=sqrt_c
            )
            tree = build_prefix_tree(np.asarray(walks), n)
            if not tree.nodes:  # every walk terminated at u immediately
                continue
            if variant == "auto":
                # best-of-both-worlds (paper §4.4), shape-static form: the
                # tree pays one SpMM per *distinct* prefix column; when the
                # dedup ratio is low the fixed-shape telescoped batch wins
                # (and avoids per-tree recompilation).  Decided per chunk on
                # host from the tree statistics — cf. the paper's dynamic
                # out-degree-sum switch, which is untraceable on TPU.
                from repro.core.tree import tree_stats

                dedup = tree_stats(tree)["dedup_ratio"]
                if dedup < 1.5:
                    total = total + probe_walks_telescoped(
                        g, walks, sqrt_c=sqrt_c, eps_p=params.eps_p,
                        use_kernel=use_kernel,
                    ).sum(axis=1)
                    continue
            total = total + probe_tree_levels(
                g,
                tuple(jnp.asarray(x) for x in tree.nodes),
                tuple(jnp.asarray(x) for x in tree.weights),
                tuple(jnp.asarray(x) for x in tree.parent),
                tuple(jnp.asarray(x) for x in tree.parent_node),
                sqrt_c=sqrt_c,
                eps_p=params.eps_p,
                use_kernel=use_kernel,
            )
    elif variant == "randomized":
        walks = sample_walks(
            key, eg, u, n_r=params.n_r, max_len=params.max_len, sqrt_c=sqrt_c
        )
        for k in range(params.n_r):
            wk = jax.random.fold_in(key, 10_000 + k)
            total = total + randomized_probe_walk(
                wk, eg, walks[k], sqrt_c=sqrt_c, max_len=params.max_len
            )
    else:
        raise ValueError(f"unknown variant {variant!r}")

    est = total / params.n_r
    if params.truncation_shift:
        est = jnp.where(est > 0, est + params.eps_t / 2, est)
    est = est.at[u].set(1.0)
    return est


def topk(
    key: Array,
    g: Graph | EllGraph,
    eg: EllGraph,
    u: int,
    k: int,
    params: ProbeSimParams,
    **kwargs,
) -> tuple[Array, Array]:
    """Approximate top-k query (paper Def. 2): (nodes [k], estimates [k])."""
    est = single_source(key, g, eg, u, params, **kwargs)
    est = est.at[u].set(-jnp.inf)  # exclude the query node itself
    vals, idx = jax.lax.top_k(est, k)
    return idx, vals


def single_source_simple(
    key: Array,
    eg,
    u: int,
    *,
    n: int | None = None,
    c: float = 0.6,
    eps_a: float = 0.1,
    delta: float = 0.01,
    **kwargs,
) -> Array:
    """DEPRECATED convenience wrapper — prefer a ``GraphHandle``.

    The legacy form takes a bare ``EllGraph`` and silently uses it as BOTH
    the push and the gather representation (i.e. it is exactly
    ``single_source(key, eg, eg, u, ...)`` — correct, but it forfeits the
    COO push mirror without saying so).  Pass a
    :class:`repro.api.GraphHandle` instead and the mirror choice is
    explicit: the handle's COO ``g`` pushes, its ELL ``eg`` gathers.
    """
    from repro.api.handle import GraphHandle  # local: core <-> api layering

    if isinstance(eg, GraphHandle):
        params = make_params(n or eg.n, c=c, eps_a=eps_a, delta=delta)
        return single_source(key, eg.g, eg.eg, u, params, **kwargs)
    import warnings

    warnings.warn(
        "single_source_simple(eg) uses the ELL table as both the push and "
        "gather mirror; pass a repro.api.GraphHandle (explicit mirrors) or "
        "call single_source / SimRankSession.query directly",
        DeprecationWarning,
        stacklevel=2,
    )
    params = make_params(n or eg.n, c=c, eps_a=eps_a, delta=delta)
    return single_source(key, eg, eg, u, params, **kwargs)
