"""Power Method for SimRank (Jeh & Widom) — ground truth on small graphs.

Uses the correct formulation (paper Eq. 10):  S = (c P^T S P) v I  with the
element-wise maximum against I, iterated from S = I.  O(n^2) memory — only
for graphs small enough to verify against (the paper uses 55 iterations for
1e-12 accuracy on its four small datasets).

Also provides the *truncated* power method single-source column, which is
exactly the accuracy envelope of the TopSim family (paper §2.3: TopSim-SM's
estimate equals the Power Method with T iterations, error up to c^T).
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.graph.structs import Graph

Array = jax.Array


def _transition_dense(g: Graph) -> Array:
    """P[x, v] = 1/|I(v)| if (x -> v) else 0 (column-stochastic over in-edges)."""
    n = g.n
    mask = g.edge_mask()
    src = jnp.where(mask, g.src, 0)
    dst = jnp.where(mask, g.dst, 0)
    A = jnp.zeros((n, n), jnp.float32).at[src, dst].add(
        mask.astype(jnp.float32)
    )
    return A * g.inv_in_deg[None, :]


@partial(jax.jit, static_argnames=("iters", "c"))
def simrank_power(g: Graph, *, c: float = 0.6, iters: int = 55) -> Array:
    """All-pairs SimRank S [n, n] by the Power Method."""
    P = _transition_dense(g)
    n = g.n
    eye = jnp.eye(n, dtype=jnp.float32)

    def body(_, S):
        S = c * (P.T @ S @ P)
        return jnp.maximum(S, eye)

    return jax.lax.fori_loop(0, iters, body, eye)


def simrank_power_host(
    src: np.ndarray, dst: np.ndarray, n: int, *, c: float = 0.6, iters: int = 55
) -> np.ndarray:
    """Numpy variant for host-side test fixtures."""
    A = np.zeros((n, n), dtype=np.float64)
    np.add.at(A, (src, dst), 1.0)
    in_deg = A.sum(axis=0)
    P = A / np.maximum(in_deg[None, :], 1.0)
    S = np.eye(n)
    for _ in range(iters):
        S = np.maximum(c * (P.T @ S @ P), np.eye(n))
    return S


@partial(jax.jit, static_argnames=("iters", "c"))
def simrank_truncated_single_source(
    g: Graph, u: Array, *, c: float = 0.6, iters: int = 3
) -> Array:
    """s_T(u, .) — Power Method truncated at T iterations (TopSim accuracy).

    This is the estimate quality of TopSim-SM with walk depth T (paper §2.3);
    the absolute error can reach c^T (= 0.216 at T=3, c=0.6), which is the
    effect the paper's Figure 4 demonstrates.
    """
    S = simrank_power(g, c=c, iters=iters)
    return S[u]
