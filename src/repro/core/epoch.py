"""The fused update->query *epoch* pipeline, backend-agnostic (DESIGN.md §5).

ProbeSim's index-free claim means a query is exact against whatever the
graph is NOW — so the natural serving unit on a dynamic graph is an
*epoch*: ONE compiled dispatch that applies an update batch to the
device-resident graph state and serves a query batch against the
just-written buffers, with zero host transfers in between.

PR 2/3 implemented that for the single-device mirror pair only (a
donated-buffer jit private to the session); this module promotes the
epoch to a first-class pipeline over *pluggable stages*

    (graph_state, update_batch, query_batch) -> (graph_state', scores)

so every execution backend composes the same two stages:

* **apply stage** — ``graph_state, UpdateBatch -> graph_state', applied``
  with the coordinated-mirror contracts of ``graph/dynamic.py`` (per-op
  applied mask, sticky overflow, stable delete compaction, version +1
  per changed batch — version/overflow bookkeeping lives with the state
  owner, outside the compiled step where noted);
* **probe stage** — ``graph_state', (keys, us) -> estimates`` running the
  telescoped probe against the post-update buffers.

Two concrete instantiations live here:

* :func:`epoch_step` — the LOCAL epoch: ``apply_update_batch`` composed
  with ``fused_serve_impl`` in one jit with the mirror buffers donated.
  This is the PR-3 session step moved verbatim (same trace, same
  donation, bit-identical results under shared keys);
* :func:`make_sharded_epoch_step` — the MESH epoch over a
  :class:`ShardEpochGraph`: destination-sharded COO buffers + a
  row-sharded ELL table, updated *inside a shard_map step* (each shard
  applies its re-partitioned ops to its own device-resident buffers,
  donation per shard) and probed by the distributed telescoped push in
  the same compiled program.  ``repro.api.backend.ShardedBackend``
  drives it and keeps its host bookkeeping in sync by replaying the
  applied mask.

Layering: this is a *core* module — it knows graph structs, the update
batch format and the probes, but nothing about sessions, specs or
backends (those live in ``repro.api`` and call down into here).
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.multisource import fused_serve_impl
from repro.graph.dynamic import UpdateBatch, apply_update_batch
from repro.graph.partition import pad_to_multiple
from repro.graph.structs import EllGraph
from repro.utils.jaxcompat import shard_map, specs_to_shardings
from repro.utils.pytree import static, struct

Array = jax.Array


# ---------------------------------------------------------------------------
# The pipeline composer
# ---------------------------------------------------------------------------


def epoch_pipeline(apply_stage, probe_stage):
    """Compose an apply stage and a probe stage into one traceable epoch.

    ``apply_stage(graph_state, batch) -> (graph_state', applied)`` and
    ``probe_stage(graph_state', query_batch) -> outputs`` are plain
    traceable callables; the composed function is what a backend jits
    (with its own donation/sharding policy).  ``probe_stage`` may be
    ``None`` for update-only epochs.
    """

    def run(graph_state, batch: UpdateBatch, query_batch=None):
        graph_state, applied = apply_stage(graph_state, batch)
        if probe_stage is None or query_batch is None:
            return graph_state, applied, None
        return graph_state, applied, probe_stage(graph_state, query_batch)

    return run


# ---------------------------------------------------------------------------
# Local epoch step (single-device, donated mirror buffers)
# ---------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=(
        "n_r",
        "lanes_q",
        "max_len",
        "sqrt_c",
        "eps_p",
        "eps_t",
        "truncation_shift",
        "use_kernel",
        "top_k",
    ),
    # g/eg are donated so the update scan writes the graph buffers in place
    # (backends that support donation) instead of copying capacity-sized
    # arrays every epoch — the owning backend always replaces its mirrors
    # with the returned g'/eg', and the session own-copies at construction
    # so no caller shares the donated buffers
    donate_argnames=("acc", "g", "eg"),
)
def epoch_step(
    g,
    eg,
    batch: UpdateBatch,
    keys: Array,  # [Q] typed PRNG keys, one stream per query
    us: Array,  # int32 [Q]
    acc: Array,  # f32 [Q, n] donated accumulator
    *,
    n_r: int,
    lanes_q: int,
    max_len: int,
    sqrt_c: float,
    eps_p: float,
    eps_t: float,
    truncation_shift: bool,
    use_kernel: bool,
    top_k: int,
):
    """One fused LOCAL epoch: apply the update batch, serve the query batch.

    The local instantiation of the pipeline: ``apply_update_batch`` writes
    the new COO/ELL buffers and ``fused_serve_impl`` reads them in the same
    compiled program — no host round-trip in between.  Returns
    ``(g', eg', applied, est, idx, vals)`` (``idx``/``vals`` are None when
    ``top_k == 0``); ``g'.version`` / ``g'.overflow`` carry the snapshot id
    and capacity signal.
    """

    def probe(state, qb):
        g2, eg2 = state
        keys_b, us_b, acc_b = qb
        return fused_serve_impl(
            keys_b, g2, eg2, us_b, acc_b,
            n_r=n_r,
            lanes_q=lanes_q,
            max_len=max_len,
            sqrt_c=sqrt_c,
            eps_p=eps_p,
            eps_t=eps_t,
            truncation_shift=truncation_shift,
            use_kernel=use_kernel,
            top_k=top_k,
        )

    run = epoch_pipeline(
        lambda state, b: _pair_apply(state, b), probe
    )
    (g2, eg2), applied, out = run((g, eg), batch, (keys, us, acc))
    acc, est, idx, vals = out
    return g2, eg2, applied, est, idx, vals


def _pair_apply(state, batch: UpdateBatch):
    g, eg = state
    g2, eg2, applied = apply_update_batch(g, eg, batch)
    return (g2, eg2), applied


# ---------------------------------------------------------------------------
# Sharded epoch graph — device-resident dst-partitioned COO + ELL mirrors
# ---------------------------------------------------------------------------


@struct
class ShardEpochGraph:
    """Device-resident graph state for the mesh epoch.

    The same coordinated mirror pair as the local ``(Graph, EllGraph)``,
    laid out for a ``("data", "model")`` mesh:

    * ``src_sh``/``dst_sh`` int32 [S, E] — per-shard COO buffers holding
      GLOBAL node ids, destination-partitioned (shard s owns every edge
      with ``dst // rows == s``), per-shard FIFO order, sentinel padding
      ``n_pad``.  Flattened they are exactly the COO push operand of the
      distributed telescoped probe;
    * ``counts`` int32 [S] — live edges per shard;
    * ``in_nbrs`` int32 [n_pad, k_max] — the ELL in-neighbor table,
      row-sharded over ``model`` (a shard owns the rows of its node
      block).  Sentinel ``n`` — the LOCAL ELL convention — so the walk
      sampler (``core.walks.sample_walks_batch``) consumes a sliced view
      directly and draws bit-identical walks to the local mirror under
      shared keys;
    * ``in_deg`` int32 [n_pad] — replicated (it is the probe's
      renormalization operand; [n_pad] int32 is cheap, and the legacy
      auto partitioner mis-scales the renorm when it arrives sharded —
      see ``core.distributed.graph_specs``).

    Updates preserve the invariant that the buffers are bit-identical to
    :func:`build_shard_epoch_graph` rebuilt from the equivalently-updated
    shard-major host edge list (stable FIFO compaction + append-in-stream
    -order, per shard) — the mesh analogue of ``apply_update_batch``'s
    rebuild equality, and what makes carried device state testable
    against a from-scratch rebuild.
    """

    src_sh: Array  # int32 [S, E] global src ids (sentinel n_pad)
    dst_sh: Array  # int32 [S, E] global dst ids (sentinel n_pad)
    counts: Array  # int32 [S]
    in_nbrs: Array  # int32 [n_pad, k_max] (sentinel n)
    in_deg: Array  # int32 [n_pad]
    n: int = static()
    n_pad: int = static()
    rows: int = static()
    shards: int = static()
    capacity: int = static()  # E, per shard
    k_max: int = static()


def build_shard_epoch_graph(
    src: np.ndarray,
    dst: np.ndarray,
    n: int,
    *,
    shards: int,
    capacity_per_shard: int,
    k_max: int,
) -> ShardEpochGraph:
    """Build the device epoch state from a shard-major host edge list.

    ``(src, dst)`` must be in shard-major per-shard-FIFO order (what
    ``ShardedGraphState.to_host_edges`` produces — re-partitioning that
    order is the identity, so incremental maintenance and this builder
    agree bit-for-bit).  ``k_max`` caps ELL rows; the max in-degree must
    fit.
    """
    src = np.asarray(src, np.int32).reshape(-1)
    dst = np.asarray(dst, np.int32).reshape(-1)
    n_pad = pad_to_multiple(n, shards)
    rows = n_pad // shards
    E = int(capacity_per_shard)
    shard_of = dst // rows
    counts = np.bincount(shard_of, minlength=shards).astype(np.int32)
    if counts.max(initial=0) > E:
        raise ValueError(
            f"shard holds {int(counts.max())} edges > capacity {E}"
        )
    src_sh = np.full((shards, E), n_pad, dtype=np.int32)
    dst_sh = np.full((shards, E), n_pad, dtype=np.int32)
    order = np.argsort(shard_of, kind="stable")  # FIFO within shard
    src_o, dst_o = src[order], dst[order]
    starts = np.zeros(shards + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for s in range(shards):
        lo, hi = starts[s], starts[s + 1]
        src_sh[s, : hi - lo] = src_o[lo:hi]
        dst_sh[s, : hi - lo] = dst_o[lo:hi]
    in_deg = np.bincount(dst, minlength=n_pad).astype(np.int32)[:n_pad]
    deg_cap = int(in_deg.max()) if in_deg.size else 0
    if deg_cap > k_max:
        raise ValueError(f"max in-degree {deg_cap} exceeds k_max {k_max}")
    # ELL rows in per-dst stream order — identical to the local
    # ``ell_from_edges`` rows, because shard-major reordering never
    # permutes two edges of the SAME destination
    table = np.full((n_pad, k_max), n, dtype=np.int32)
    d_order = np.argsort(dst, kind="stable")
    d_sorted = dst[d_order]
    s_sorted = src[d_order]
    group_start = np.searchsorted(d_sorted, np.arange(n))
    idx_within = np.arange(len(d_sorted)) - group_start[d_sorted]
    table[d_sorted, idx_within] = s_sorted
    return ShardEpochGraph(
        src_sh=jnp.asarray(src_sh),
        dst_sh=jnp.asarray(dst_sh),
        counts=jnp.asarray(counts),
        in_nbrs=jnp.asarray(table),
        in_deg=jnp.asarray(in_deg),
        n=int(n), n_pad=int(n_pad), rows=int(rows), shards=int(shards),
        capacity=E, k_max=int(k_max),
    )


def shard_epoch_specs(st: ShardEpochGraph) -> ShardEpochGraph:
    """PartitionSpec pytree for :class:`ShardEpochGraph` (statics copied)."""
    return ShardEpochGraph(
        src_sh=P("model", None),
        dst_sh=P("model", None),
        counts=P("model"),
        in_nbrs=P("model", None),
        in_deg=P(None),  # replicated: probe renorm operand (see class doc)
        n=st.n, n_pad=st.n_pad, rows=st.rows, shards=st.shards,
        capacity=st.capacity, k_max=st.k_max,
    )


# ---------------------------------------------------------------------------
# Sharded apply stage — the shard_map update step
# ---------------------------------------------------------------------------


def _shard_apply(st: ShardEpochGraph, batch: UpdateBatch, mesh):
    """Apply a mixed batch to the per-shard device buffers, in shard_map.

    Each model shard applies the ops whose destination lands in its row
    block, against its OWN buffers — the device-side analogue of
    re-partitioning the batch with ``partition_ops_by_dst`` and applying
    per shard, with ``apply_update_batch``'s exact semantics: deletes
    match the pre-batch buffers (at most one live copy per (s, d) pair
    per batch) and are removed by stable compaction; inserts append in
    stream order iff there is room in BOTH the shard's COO buffer and
    the destination's ELL row.  Returns
    ``(st', applied [B] bool, overflow bool)`` — ``applied`` is the
    OR-fold of the per-shard masks (each op belongs to exactly one
    shard), ``overflow`` is the fresh per-batch capacity signal (the
    sticky fold and the version bump are the state owner's bookkeeping,
    host-side).
    """
    n, n_pad, rows = st.n, st.n_pad, st.rows
    S, E, k_max = st.shards, st.capacity, st.k_max
    has_deletes = batch.has_deletes

    def local(src_b, dst_b, cnt, ell, ideg, bsrc, bdst, bins):
        # src_b/dst_b [1, E]; cnt [1]; ell [rows, k_max]; ideg [n_pad]
        # (replicated, read-only); bsrc/bdst/bins [B] (replicated)
        me = jax.lax.axis_index("model")
        sb, db = src_b[0], dst_b[0]
        valid = (bsrc >= 0) & (bsrc < n) & (bdst >= 0) & (bdst < n)
        mine = valid & (bdst // rows == me)
        d_c = jnp.where(mine, bdst, 0)
        d_loc = jnp.where(mine, bdst - me * rows, 0)
        tri = jnp.tril(jnp.ones((bsrc.shape[0],) * 2, jnp.int32), k=-1)

        if has_deletes:
            is_del = mine & ~bins
            same_pair = (
                (bsrc[None, :] == bsrc[:, None])
                & (bdst[None, :] == bdst[:, None])
                & is_del[None, :]
            )
            del_live = is_del & (
                (same_pair.astype(jnp.int32) * tri).sum(1) == 0
            )
            hits = (
                (sb[None, :] == bsrc[:, None])
                & (db[None, :] == bdst[:, None])
                & del_live[:, None]
            )
            found = hits.any(axis=1)
            pos = jnp.argmax(hits, axis=1)
            del_mask = (
                jnp.zeros(E, bool)
                .at[jnp.where(found, pos, E)]
                .set(True, mode="drop")
            )
            keep = (sb < n_pad) & ~del_mask
            kint = keep.astype(jnp.int32)
            kpos = jnp.cumsum(kint) - kint  # stable compaction
            csrc = (
                jnp.full(E, n_pad, jnp.int32)
                .at[jnp.where(keep, kpos, E)]
                .set(sb, mode="drop")
            )
            cdst = (
                jnp.full(E, n_pad, jnp.int32)
                .at[jnp.where(keep, kpos, E)]
                .set(db, mode="drop")
            )
            cnt2 = kint.sum()
            # ELL mirror: mark deleted slots, stable-compact each touched
            # row once (first op per row rewrites it)
            rows_g = ell[d_loc]  # [B, k_max] pre-batch rows
            s_c = jnp.where(mine, bsrc, n)
            rhit = (rows_g == s_c[:, None]) & found[:, None]
            rfound = rhit.any(axis=1)
            kslot = jnp.argmax(rhit, axis=1)
            dmask = (
                jnp.zeros((rows, k_max), bool)
                .at[jnp.where(rfound, d_loc, rows),
                    jnp.where(rfound, kslot, 0)]
                .set(True, mode="drop")
            )
            same_row = (bdst[None, :] == bdst[:, None]) & rfound[None, :]
            urow = rfound & ((same_row.astype(jnp.int32) * tri).sum(1) == 0)
            live_r = (rows_g < n) & ~dmask[d_loc]
            lint = live_r.astype(jnp.int32)
            new_slot = jnp.cumsum(lint, axis=1) - lint
            b_rows = jnp.broadcast_to(
                jnp.arange(live_r.shape[0])[:, None], live_r.shape
            )
            comp = (
                jnp.full_like(rows_g, n)
                .at[b_rows, jnp.where(live_r, new_slot, k_max)]
                .set(rows_g, mode="drop")
            )
            ell = ell.at[jnp.where(urow, d_loc, rows)].set(comp, mode="drop")
            # post-delete in-degrees, local working copy (each shard only
            # reads entries of its own destinations)
            ideg_w = ideg.at[jnp.where(found, d_c, n_pad)].add(
                -1, mode="drop"
            )
        else:
            found = jnp.zeros_like(valid)
            csrc, cdst, cnt2 = sb, db, cnt[0]
            ideg_w = ideg

        # inserts: append in stream order, coordinated COO+ELL room check
        is_ins = mine & bins
        same_d = (bdst[None, :] == bdst[:, None]) & is_ins[None, :]
        occ = (same_d.astype(jnp.int32) * tri).sum(1)
        slot = ideg_w[d_c] + occ
        ok_ell = is_ins & (slot < k_max)
        oint = ok_ell.astype(jnp.int32)
        cpos = cnt2 + jnp.cumsum(oint) - oint
        ok = ok_ell & (cpos < E)
        csrc = csrc.at[jnp.where(ok, cpos, E)].set(bsrc, mode="drop")
        cdst = cdst.at[jnp.where(ok, cpos, E)].set(bdst, mode="drop")
        ell = ell.at[
            jnp.where(ok, d_loc, rows), jnp.where(ok, slot, k_max)
        ].set(jnp.where(mine, bsrc, n), mode="drop")
        cnt3 = (cnt2 + ok.sum()).astype(jnp.int32)
        ovf = (is_ins & ~ok).any()
        applied = jnp.where(bins, ok, found)
        return (
            csrc[None], cdst[None], cnt3[None], ell,
            applied[None], ovf[None],
        )

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P("model", None), P("model", None), P("model"),
            P("model", None), P(), P(), P(), P(),
        ),
        out_specs=(
            P("model", None), P("model", None), P("model"),
            P("model", None), P("model", None), P("model"),
        ),
        # fully manual: with auto axes left over, axis_index lowers to a
        # PartitionId instruction old-jax's SPMD partitioner rejects (the
        # ring probe runs fully manual for the same reason).  Inputs and
        # compute are replicated over the data axes, so every data shard
        # produces identical output tiles
        axis_names=set(mesh.axis_names),
    )
    src2, dst2, cnt2, ell2, applied_sh, ovf_sh = fn(
        st.src_sh, st.dst_sh, st.counts, st.in_nbrs, st.in_deg,
        jnp.asarray(batch.src, jnp.int32),
        jnp.asarray(batch.dst, jnp.int32),
        batch.insert,
    )
    applied = applied_sh.any(axis=0)  # ops land on exactly one shard
    overflow = ovf_sh.any()
    # in_deg is replicated (the probe's renorm operand): fold the applied
    # deltas back in the auto region rather than diverging per shard
    ins = jnp.asarray(batch.insert)
    dst_b = jnp.asarray(batch.dst, jnp.int32)
    ideg = st.in_deg.at[
        jnp.where(applied & ~ins, dst_b, st.n_pad)
    ].add(-1, mode="drop")
    ideg = ideg.at[
        jnp.where(applied & ins, dst_b, st.n_pad)
    ].add(1, mode="drop")
    st2 = st.replace(
        src_sh=src2, dst_sh=dst2, counts=cnt2, in_nbrs=ell2, in_deg=ideg
    )
    return st2, applied, overflow


# ---------------------------------------------------------------------------
# Sharded epoch step factory — apply + sample + distributed probe, one jit
# ---------------------------------------------------------------------------


def make_sharded_epoch_step(
    st: ShardEpochGraph,
    mesh,
    *,
    q: int,
    n_r: int,
    top_k: int,
    max_len: int,
    sqrt_c: float,
    eps_p: float,
    eps_t: float,
    truncation_shift: bool,
    walk_chunk: int,
    edge_chunks: int,
    has_deletes: bool,
    use_kernel: bool = False,
):
    """Compile the mesh epoch step for one (geometry, Q, n_r, k) config.

    ``step(state, batch, us [Q], keys [Q]) ->
    (state', applied [B], overflow, est, idx, vals)`` — update application
    (shard_map, donated per-shard buffers), walk sampling off the updated
    ELL mirror (bit-identical draws to the local sampler under shared
    keys), and the distributed telescoped probe over the updated COO
    shards all trace into ONE compiled program: no host transfer between
    update and query.  ``q == 0`` (``us``/``keys`` None) compiles the
    update-only variant.  Pass ``has_deletes`` matching the batches this
    step will see (it is part of the jit cache key via the static
    ``UpdateBatch`` field anyway; passing it here keeps the factory's
    cache keys honest).

    The probe marches per-query column chunks of ``walk_chunk`` walks
    through ``probe_walks_sharded`` under ``lax.scan`` (bounded frontier
    memory at large ``n_r``); padding columns are sentinel walks that
    contribute exact zeros.  Epilogue (1/n_r, truncation shift, diagonal
    fix, top-k) matches ``fused_serve_impl``'s conventions, so
    local-vs-sharded epoch parity under shared keys is tolerance-bounded
    by float summation order alone.

    ``use_kernel=True`` routes the query stage through the compacted lane
    probe with the fused Pallas level kernel (``probe_lanes_sharded`` with
    ``use_kernel``) instead of the chunk-scanned ``probe_walks_sharded`` —
    the kernel cannot run inside the auto-partitioned scan region, but the
    fully-manual lane probe hosts it directly; ``walk_chunk`` becomes the
    per-query lane width.  Estimates match the default path to float
    summation order (the paths schedule pushes differently by design).
    """
    from repro.core.distributed import probe_lanes_sharded, probe_walks_sharded
    from repro.core.walks import sample_walks_batch

    n, n_pad = st.n, st.n_pad
    S, E = st.shards, st.capacity
    if (S * E) % edge_chunks:
        raise ValueError(
            f"per-shard capacity {E} x {S} shards must divide "
            f"edge_chunks={edge_chunks} (pad capacity up)"
        )
    cc = max(1, min(walk_chunk, n_r)) if q else 1
    n_chunks = -(-n_r // cc) if q else 0
    n_r_pad = n_chunks * cc

    def apply_stage(state, batch):
        state2, applied, overflow = _shard_apply(state, batch, mesh)
        return state2, (applied, overflow)

    def probe_stage(state2, qb):
        us, keys = qb
        # the sampler consumes the updated ELL mirror through a plain
        # EllGraph view — same function, same table rows, same draws as
        # the local epoch under shared keys
        eg_view = EllGraph(
            in_nbrs=state2.in_nbrs[:n],
            in_deg=state2.in_deg[:n],
            n=n, k_max=st.k_max,
        )
        pool = sample_walks_batch(
            keys, eg_view, us, n_r=n_r, max_len=max_len, sqrt_c=sqrt_c
        )  # [Q, n_r, L]
        if use_kernel:
            # fused Pallas lane probe (cannot trace into the auto-region
            # scan below — shard_map hosts it instead); walk_chunk becomes
            # the per-query lane width
            wq = cc
            pool_f = pool.reshape(q * n_r, max_len)
            pool_len = (pool_f < n).sum(axis=1).astype(jnp.int32)
            d = state2.in_deg.astype(jnp.float32)
            w_full = (
                jnp.where(d > 0, 1.0 / jnp.maximum(d, 1.0), 0.0) * sqrt_c
            )
            total = probe_lanes_sharded(
                state2.src_sh, state2.dst_sh, state2.counts, w_full,
                pool_f, pool_len, mesh,
                n_pad=n_pad, rows=st.rows, q=q, wq=wq, n_r=n_r,
                max_len=max_len, sqrt_c=sqrt_c, eps_p=eps_p, sentinel=n,
                use_kernel=True, in_nbrs=state2.in_nbrs,
            )  # [n_pad, W]
            counts = total[:n].reshape(n, q, wq).sum(axis=2).T
            est = counts / n_r
            if truncation_shift:
                est = jnp.where(est > 0, est + eps_t / 2, est)
            est = est.at[jnp.arange(q), us].set(1.0)
            if top_k > 0:
                masked = est.at[jnp.arange(q), us].set(-jnp.inf)
                vals, idx = jax.lax.top_k(masked, top_k)
                return est, idx, vals
            return est, None, None
        if n_r_pad != n_r:
            pool = jnp.concatenate(
                [pool,
                 jnp.full((q, n_r_pad - n_r, max_len), n, jnp.int32)],
                axis=1,
            )  # sentinel walks: exact-zero columns
        chunks = pool.reshape(q * n_chunks, cc, max_len)
        # probe view: the flattened per-shard COO buffers ARE the push
        # operand (sentinel n_pad edges gather/scatter into zeroed pad
        # rows); indptr/indices are sampler-only fields, unused here
        from repro.core.distributed import ShardedGraph

        sgv = ShardedGraph(
            indptr=state2.in_deg,
            in_deg=state2.in_deg,
            indices=state2.in_deg,
            src=state2.src_sh.reshape(S * E),
            dst=state2.dst_sh.reshape(S * E),
            n=n, n_pad=n_pad, m=S * E, m_pad=S * E,
        )

        def probe_chunk(carry, wchunk):
            scores = probe_walks_sharded(
                sgv, wchunk, sqrt_c=sqrt_c, eps_p=eps_p,
                edge_chunks=edge_chunks,
            )  # [n_pad, cc]
            return carry, scores.sum(axis=1)

        _, sums = jax.lax.scan(probe_chunk, 0, chunks)  # [Q*n_chunks, n_pad]
        counts = sums.reshape(q, n_chunks, n_pad).sum(axis=1)[:, :n]
        est = counts / n_r
        if truncation_shift:
            est = jnp.where(est > 0, est + eps_t / 2, est)
        est = est.at[jnp.arange(q), us].set(1.0)
        if top_k > 0:
            masked = est.at[jnp.arange(q), us].set(-jnp.inf)
            vals, idx = jax.lax.top_k(masked, top_k)
            return est, idx, vals
        return est, None, None

    run = epoch_pipeline(apply_stage, probe_stage if q else None)

    def step(state, batch, us=None, keys=None):
        state2, (applied, overflow), out = run(
            state, batch, (us, keys) if q else None
        )
        if out is None:
            return state2, applied, overflow, None, None, None
        est, idx, vals = out
        return state2, applied, overflow, est, idx, vals

    specs = shard_epoch_specs(st)
    in_specs = (specs, P(), P(), P()) if q else (specs, P())
    return jax.jit(
        step,
        in_shardings=specs_to_shardings(in_specs, mesh=mesh),
        donate_argnums=(0,),
    )


# ---------------------------------------------------------------------------
# Sharded serve step factory — lane-batched distributed serving, one jit
# ---------------------------------------------------------------------------


def make_sharded_serve_step(
    st: ShardEpochGraph,
    mesh,
    *,
    q: int,
    n_r: int,
    lanes_q: int,
    top_k: int,
    max_len: int,
    sqrt_c: float,
    eps_p: float,
    eps_t: float,
    truncation_shift: bool,
    probe: str = "spmd",
    use_kernel: bool = False,
    frontier_dtype: str = "float32",
):
    """Compile the mesh SERVE step for one (geometry, Q, n_r, k) config.

    ``step(state, us [Q], keys [Q]) -> (est, idx, vals)`` (ring:
    ``step(state, ring_src, ring_dst, us, keys)``) — pooled walk sampling
    for the whole query batch off the carried :class:`ShardEpochGraph`'s
    ELL mirror (bit-identical draws to the local sampler under shared
    keys), the compacted telescoped lane probe inside shard_map
    (``probe_lanes_sharded`` / ``probe_lanes_ring``), and the per-query
    reduction + epilogue + top-k, all in ONE compiled program with zero
    host transfers mid-query.  The state is NOT donated: serving reuses
    the resident mirror across calls (``ShardedBackend`` keys it on the
    host mutation counter).

    Epilogue conventions match ``fused_serve_impl`` exactly, and the lane
    schedule is the shared ``core.multisource`` bookkeeping — a batched
    sharded serve therefore equals Q single-query sharded serves bitwise
    (same ``lanes_q``) and matches the local path to float-summation
    tolerance.

    ``use_kernel=True`` runs every probe level through the fused Pallas
    lane-probe kernel (per-shard ELL gather off the all-gathered frontier
    for spmd; fused level prologue for ring).  The spmd kernel path shares
    the local kernel path's push-weight formulation and gather reduction
    order, so a sharded kernel serve is BITWISE-equal to a local
    ``use_kernel=True`` serve under shared keys (fp32).
    ``frontier_dtype="bfloat16"`` (spmd only) halves the per-level
    all_gather wire volume; parity vs fp32 is ~1e-3 on estimates.
    """
    from repro.core.distributed import probe_lanes_sharded
    from repro.core.walks import sample_walks_batch

    if probe not in ("spmd", "ring"):
        raise ValueError(f"probe must be 'spmd' or 'ring', got {probe!r}")
    n, n_pad, rows, S = st.n, st.n_pad, st.rows, st.shards
    wq = lanes_q

    def serve(state, ring_src, ring_dst, us, keys):
        eg_view = EllGraph(
            in_nbrs=state.in_nbrs[:n],
            in_deg=state.in_deg[:n],
            n=n, k_max=st.k_max,
        )
        pool = sample_walks_batch(
            keys, eg_view, us, n_r=n_r, max_len=max_len, sqrt_c=sqrt_c
        ).reshape(q * n_r, max_len)
        pool_len = (pool < n).sum(axis=1).astype(jnp.int32)
        d = state.in_deg.astype(jnp.float32)
        if use_kernel and probe == "spmd":
            # the local kernel path's formulation (inv_in_deg * sqrt_c):
            # same rounding per weight, so sharded-kernel == local-kernel
            # serves are bitwise under shared keys
            w_full = jnp.where(d > 0, 1.0 / jnp.maximum(d, 1.0), 0.0) * sqrt_c
        else:
            w_full = jnp.where(d > 0, sqrt_c / jnp.maximum(d, 1.0), 0.0)
        if probe == "ring":
            from repro.core.ring import probe_lanes_ring

            total = probe_lanes_ring(
                ring_src, ring_dst, w_full, pool, pool_len, mesh,
                rows=rows, shards=S, q=q, wq=wq, n_r=n_r,
                max_len=max_len, sqrt_c=sqrt_c, eps_p=eps_p, sentinel=n,
                use_kernel=use_kernel,
            )
        else:
            total = probe_lanes_sharded(
                state.src_sh, state.dst_sh, state.counts, w_full,
                pool, pool_len, mesh,
                n_pad=n_pad, rows=rows, q=q, wq=wq, n_r=n_r,
                max_len=max_len, sqrt_c=sqrt_c, eps_p=eps_p, sentinel=n,
                use_kernel=use_kernel, in_nbrs=state.in_nbrs,
                frontier_dtype=frontier_dtype,
            )  # [n_pad, W]
        acc = total[:n].reshape(n, q, wq).sum(axis=2).T  # [Q, n]
        est = acc / n_r
        if truncation_shift:
            est = jnp.where(est > 0, est + eps_t / 2, est)
        est = est.at[jnp.arange(q), us].set(1.0)
        if top_k > 0:
            masked = est.at[jnp.arange(q), us].set(-jnp.inf)
            vals, idx = jax.lax.top_k(masked, top_k)
            return est, idx, vals
        return est, None, None

    specs = shard_epoch_specs(st)
    if probe == "ring":
        in_specs = (
            specs, P("model", None, None), P("model", None, None), P(), P(),
        )
        return jax.jit(
            serve, in_shardings=specs_to_shardings(in_specs, mesh=mesh)
        )
    in_specs = (specs, P(), P())

    def serve_spmd(state, us, keys):
        return serve(state, None, None, us, keys)

    return jax.jit(
        serve_spmd, in_shardings=specs_to_shardings(in_specs, mesh=mesh)
    )
