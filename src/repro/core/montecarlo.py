"""Monte Carlo SimRank baselines (Fogaras & Racz; paper §2.2).

* ``mc_single_pair`` — the pooling "expert": estimate s(u, v) by sampling r
  pairs of sqrt(c)-walks and counting meets.  r >= 1/(2 eps^2) ln(2/delta)
  gives |err| <= eps w.p. 1-delta; the paper's pooling uses eps = 1e-4-ish
  precision with very large r (we expose r directly).

* ``mc_single_source`` — the index-free MC baseline the paper compares
  against: sample r walks from *every* node, estimate s(u, v) as the meet
  frequency between u's walks and v's walks (pairing walk i of u with walk i
  of v, the unbiased coupling used in [6]).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.walks import sample_walks
from repro.graph.structs import EllGraph

Array = jax.Array


@partial(jax.jit, static_argnames=("r", "max_len", "sqrt_c"))
def mc_single_pair(
    key: Array,
    eg: EllGraph,
    u: Array,
    v: Array,
    *,
    r: int,
    max_len: int,
    sqrt_c: float,
) -> Array:
    """Estimate s(u, v) from r independent sqrt(c)-walk pairs."""
    ku, kv = jax.random.split(key)
    wu = sample_walks(ku, eg, u, n_r=r, max_len=max_len, sqrt_c=sqrt_c)
    wv = sample_walks(kv, eg, v, n_r=r, max_len=max_len, sqrt_c=sqrt_c)
    same = (wu == wv) & (wu < eg.n)
    meet = same.any(axis=1)
    return meet.mean()


@partial(jax.jit, static_argnames=("r", "max_len", "sqrt_c", "batch"))
def mc_pool_scores(
    key: Array,
    eg: EllGraph,
    u: Array,
    pool: Array,  # int32 [P] candidate nodes
    *,
    r: int,
    max_len: int,
    sqrt_c: float,
    batch: int = 64,
) -> Array:
    """Single-pair MC scores s(u, v) for every v in the pool (the 'expert')."""
    ku, kv = jax.random.split(key)
    wu = sample_walks(ku, eg, u, n_r=r, max_len=max_len, sqrt_c=sqrt_c)

    def one(carry, v):
        kv2 = jax.random.fold_in(kv, v)
        wv = sample_walks(kv2, eg, v, n_r=r, max_len=max_len, sqrt_c=sqrt_c)
        same = (wu == wv) & (wu < eg.n)
        return carry, same.any(axis=1).mean()

    _, scores = jax.lax.scan(one, 0, pool)
    return scores


@partial(jax.jit, static_argnames=("r", "max_len", "sqrt_c"))
def mc_single_source(
    key: Array,
    eg: EllGraph,
    u: Array,
    *,
    r: int,
    max_len: int,
    sqrt_c: float,
) -> Array:
    """MC single-source baseline: walks from ALL nodes; s~(u, v) [n].

    Memory/time O(n * r): this is the 'considerable query overhead' method
    the paper improves on — implemented for the Figure-4 comparison.
    """
    n = eg.n
    ku, kv = jax.random.split(key)
    wu = sample_walks(ku, eg, u, n_r=r, max_len=max_len, sqrt_c=sqrt_c)

    # walks from every node: [n, r, L] is too big; scan over trial index
    def trial(carry, t):
        total = carry
        kt = jax.random.fold_in(kv, t)
        k_cont, k_step = jax.random.split(kt)
        cur = jnp.arange(n, dtype=jnp.int32)  # one walk per node
        meet = jnp.zeros(n, dtype=bool)
        uw = wu[t]

        def step(c, inputs):
            cur, meet, alive = c
            p, (cont, pick) = inputs
            # compare at position p
            meet = meet | (alive & (cur == uw[p]) & (uw[p] < n))
            deg = eg.in_deg[cur.clip(0, n - 1)]
            can = alive & cont & (deg > 0)
            kk = jnp.floor(pick * deg.astype(jnp.float32)).astype(jnp.int32)
            kk = kk.clip(0, jnp.maximum(deg - 1, 0))
            nxt = jnp.where(can, eg.in_nbrs[cur.clip(0, n - 1), kk], n)
            return (nxt, meet, can), None

        L = wu.shape[1]
        cont = jax.random.uniform(k_cont, (L, n)) < sqrt_c
        pick = jax.random.uniform(k_step, (L, n))
        # position 0: both walks at their start; meet iff v == u handled via cur==uw[0]
        (cur, meet, _), _ = jax.lax.scan(
            step,
            (cur, jnp.zeros(n, bool), jnp.ones(n, bool)),
            (jnp.arange(L), (cont, pick)),
        )
        return total + meet.astype(jnp.float32), None

    total, _ = jax.lax.scan(trial, jnp.zeros(n, jnp.float32), jnp.arange(r))
    est = total / r
    return est.at[u].set(1.0)
