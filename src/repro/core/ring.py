"""Ring-SpMM probe (§Perf hillclimb): shard_map + ppermute pipeline.

The auto-partitioned push (core/distributed.py) re-gathers frontier rows
per edge chunk and pays a full resharding per segment_sum.  The ring variant
makes the exchange explicit: each model shard holds one row block of the
frontier and an edge bucket per (dst_shard=me, src_block); per step it
processes the resident block's bucket and ppermutes the block onward — the
classic 1-D SpMM ring, whose collective volume is exactly ONE frontier pass
per level and whose permutes overlap with the bucket gather/scatter.

Also supports a bf16 frontier (halves the ring traffic; pushes still
accumulate in fp32).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import mesh_axis_names
from repro.utils.jaxcompat import get_abstract_mesh, shard_map
from repro.utils.pytree import static, struct

Array = jax.Array


@struct
class RingGraph:
    """2-D partitioned edges (partition_edges_2d) + sampling CSR."""

    src_sh: Array  # int32 [S, S, E] src ids relative to their src block
    dst_sh: Array  # int32 [S, S, E] dst ids relative to the dst shard
    in_deg: Array  # int32 [n_pad]
    indptr: Array  # int32 [n_pad]
    indices: Array  # int32 [m_pad]
    n: int = static()
    n_pad: int = static()
    m: int = static()
    shards: int = static()


def build_ring_graph(src: np.ndarray, dst: np.ndarray, n: int, *,
                     shards: int) -> RingGraph:
    from repro.graph.partition import partition_edges_2d

    part = partition_edges_2d(src, dst, n, shards)
    n_pad = part["n_pad"]
    m = len(src)
    m_pad = -(-m // 4096) * 4096  # divisible over every mesh extent
    order = np.argsort(dst, kind="stable")
    indices = np.full(m_pad, n_pad, dtype=np.int32)
    indices[:m] = src[order]
    cnt = np.bincount(dst, minlength=n)
    in_deg = np.zeros(n_pad, dtype=np.int32)
    in_deg[:n] = cnt[:n]
    indptr = np.zeros(n_pad, dtype=np.int32)
    np.cumsum(cnt[: n - 1], out=indptr[1:n])
    return RingGraph(
        src_sh=jnp.asarray(part["src_sh"]),
        dst_sh=jnp.asarray(part["dst_sh"]),
        in_deg=jnp.asarray(in_deg),
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(indices),
        n=n, n_pad=n_pad, m=m, shards=shards,
    )


def ring_graph_abstract(n: int, m: int, shards: int, e_max: int) -> RingGraph:
    """ShapeDtypeStruct RingGraph for the dry-run."""
    from repro.graph.partition import pad_to_multiple

    SDS = jax.ShapeDtypeStruct
    n_pad = pad_to_multiple(n, shards)
    m_pad = -(-m // 4096) * 4096
    return RingGraph(
        src_sh=SDS((shards, shards, e_max), jnp.int32),
        dst_sh=SDS((shards, shards, e_max), jnp.int32),
        in_deg=SDS((n_pad,), jnp.int32),
        indptr=SDS((n_pad,), jnp.int32),
        indices=SDS((m_pad,), jnp.int32),
        n=n, n_pad=n_pad, m=m, shards=shards,
    )


def ring_graph_specs(rg: RingGraph) -> RingGraph:
    # in_deg replicated on old jax: see core.distributed.graph_specs (the
    # legacy auto partitioner mis-scales the inv-in-degree renormalization
    # when it arrives row-sharded; w_full is computed in the auto region)
    from repro.utils.jaxcompat import legacy_auto_partitioner

    tp = "model" if "model" in mesh_axis_names() else None
    all_axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh_axis_names())
    return RingGraph(
        src_sh=P(tp, None, None),
        dst_sh=P(tp, None, None),
        in_deg=P(None) if legacy_auto_partitioner() else P(tp),
        indptr=P(tp),
        indices=P(all_axes if all_axes else None),
        n=rg.n, n_pad=rg.n_pad, m=rg.m, shards=rg.shards,
    )


def _ring_push_level(buf, src_l, dst_l, me, *, shards: int, rows: int,
                     counts_l=None, edge_chunk: int = 2048):
    """One full frontier pass of the double-buffered ring SpMM.

    ``buf`` [rows, C] is this shard's resident frontier block; per step the
    resident block's bucket (dst_shard=me, src_block=blk) is gathered and
    segment-summed into ``acc`` while the block is ppermuted onward — the
    permute overlaps the next bucket's gather/scatter compute.  Returns the
    un-renormalized push accumulator [rows, C] in f32 (callers apply the
    sqrt(c)/in_deg weights).  Shared by the per-level walk probe and the
    lane-batched serve kernel.

    With ``counts_l`` (int32 [S], live edges per resident bucket) each
    bucket is walked in ``edge_chunk`` slices with a dynamic trip count, so
    the rectangular [S, S, E] padding costs nothing: live edges are a
    prefix of every bucket and sentinel slots inside the last chunk scatter
    into the dropped segment (their dst is the sentinel ``rows``).  The
    dynamic bound is safe because the ppermute sits OUTSIDE the chunk loop
    — the ring stays in lockstep while skewed buckets finish early.
    """
    C = buf.shape[1]
    acc = jnp.zeros((rows, C), jnp.float32)
    for step in range(shards):
        blk = (me - step) % shards
        src_b = jnp.take(src_l[0], blk, axis=0)  # [E]
        dst_b = jnp.take(dst_l[0], blk, axis=0)
        if counts_l is None:
            bufp = jnp.concatenate(
                [buf, jnp.zeros((1, C), buf.dtype)], axis=0
            )
            msgs = bufp[src_b.clip(0, rows)].astype(jnp.float32)
            acc = acc + jax.ops.segment_sum(
                msgs, dst_b, num_segments=rows + 1
            )[:rows]
        else:
            ch = min(edge_chunk, src_b.shape[0])
            sb = src_b.clip(0, rows - 1)  # sentinel -> garbage row, dropped
            n_chunks = (counts_l[blk] + ch - 1) // ch
            frontier = buf.astype(jnp.float32)

            def chunk(i, a):
                s_c = jax.lax.dynamic_slice(sb, (i * ch,), (ch,))
                d_c = jax.lax.dynamic_slice(dst_b, (i * ch,), (ch,))
                return a + jax.ops.segment_sum(
                    frontier[s_c], d_c, num_segments=rows + 1
                )

            acc = acc + jax.lax.fori_loop(
                0, n_chunks, chunk, jnp.zeros((rows + 1, C), jnp.float32)
            )[:rows]
        if step < shards - 1:
            # permute raw bits: XLA's algebraic simplifier otherwise
            # elides the f32->bf16->f32 round-trip and widens the
            # permute back to f32 (2x wire bytes)
            perm = [(i, (i + 1) % shards) for i in range(shards)]
            if buf.dtype == jnp.bfloat16:
                bits = jax.lax.bitcast_convert_type(buf, jnp.uint16)
                bits = jax.lax.ppermute(bits, "model", perm)
                buf = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
            else:
                buf = jax.lax.ppermute(buf, "model", perm)
    return acc


def probe_walks_ring(
    rg: RingGraph,
    walks: Array,  # [C, L] replicated
    *,
    sqrt_c: float,
    eps_p: float = 0.0,
    frontier_dtype=jnp.float32,
) -> Array:
    """Telescoped probe with the ring push; returns scores [n_pad, C]."""
    S = rg.shards
    n_pad = rg.n_pad
    rows = n_pad // S
    C, L = walks.shape
    mesh = get_abstract_mesh()

    w_full = jnp.where(
        rg.in_deg > 0,
        sqrt_c / jnp.maximum(rg.in_deg.astype(jnp.float32), 1.0),
        0.0,
    )

    def local(walks_l, src_l, dst_l, w_l):
        # walks_l [C_loc, L] (columns sharded over data); src_l/dst_l
        # [1, S, E]; w_l [rows]
        C_loc = walks_l.shape[0]
        me = jax.lax.axis_index("model")
        row0 = me * rows
        scores = jnp.zeros((rows, C_loc), frontier_dtype)

        def rid():
            return jax.lax.broadcasted_iota(jnp.int32, (rows, C_loc), 0) + row0

        for p in range(L, 1, -1):
            scores = scores + (rid() == walks_l[:, p - 1][None, :]).astype(
                scores.dtype
            )
            if eps_p > 0.0:
                thresh = eps_p / (sqrt_c ** (p - 1))
                scores = jnp.where(scores > thresh, scores, 0.0)
            acc = _ring_push_level(scores, src_l, dst_l, me,
                                   shards=S, rows=rows)
            scores = (acc * w_l[:, None]).astype(frontier_dtype)
            scores = jnp.where(rid() == walks_l[:, p - 2][None, :], 0.0, scores)
        return scores

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    col_spec = data_axes if data_axes else None
    manual = {"model"} | set(data_axes)
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(col_spec, None), P("model", None, None),
                  P("model", None, None), P("model")),
        out_specs=P("model", col_spec),
        axis_names=manual,
    )
    return fn(walks, rg.src_sh, rg.dst_sh, w_full)


def probe_lanes_ring(
    src_sh: Array,  # int32 [S, S, E] block-relative src ids (sentinel rows)
    dst_sh: Array,  # int32 [S, S, E] block-relative dst ids (sentinel rows)
    w_full: Array,  # f32 [n_pad] sqrt(c)/in_deg renorm weights
    pool: Array,  # int32 [Q*n_r, L] replicated walk pool (sentinel n)
    pool_len: Array,  # int32 [Q*n_r] replicated
    mesh,
    *,
    rows: int,
    shards: int,
    q: int,
    wq: int,
    n_r: int,
    max_len: int,
    sqrt_c: float,
    eps_p: float,
    sentinel: int,
    use_kernel: bool = False,
) -> Array:
    """Lane-batched telescoped probe with the ring push; returns [n_pad, W].

    The ring counterpart of ``core.distributed.probe_lanes_sharded``: the
    same compacted lane loop over this shard's frontier block, but each push
    level runs the double-buffered ring exchange (``_ring_push_level``) so
    the collective permute overlaps the per-bucket gather/scatter compute.
    Lane columns replicate over the data axes — the batched program has no
    per-chunk column sharding, so ring serving composes with ANY (Q, n_r)
    instead of falling back on divisibility remainders.

    ``use_kernel=True`` fuses the level prologue (deposit + inject + prune)
    through the Pallas lane-probe kernel in its identity-gather form — the
    push itself must stay the ring exchange (the kernel cannot gather
    through a ppermute), so the renormalize + exclusion epilogue follows it
    as before.  Bitwise-equal to the XLA ring level in fp32: the only
    prepped values that differ (padding rows the kernel zeroes where the
    XLA compare injects) land in the dropped scatter segment.
    """
    from repro.core.distributed import lane_level_xla, lane_probe_block
    from repro.utils.jaxcompat import shard_map

    edge_chunk = 2048
    E = src_sh.shape[2]
    # floor, not width: cap the per-bucket trip count at ~8 so chunking
    # only pays for itself where it skips dead tail slots (same rule as
    # probe_lanes_sharded — tiny chunks re-touch the accumulator)
    ch = min(max(edge_chunk, -(-E // 8)), E)
    e_pad = -(-E // ch) * ch
    if e_pad != E:
        fill = jnp.full(src_sh.shape[:2] + (e_pad - E,), rows, jnp.int32)
        src_sh = jnp.concatenate([src_sh, fill], axis=2)
        dst_sh = jnp.concatenate([dst_sh, fill], axis=2)

    def local(src_l, dst_l, w_l, pool_l, plen_l):
        # src_l/dst_l [1, S, E]; w_l [rows]; pool_l/plen_l replicated
        me = jax.lax.axis_index("model")
        row0 = me * rows
        w = q * wq
        # live edges per resident bucket: sentinel slots (src == rows) are
        # a suffix of every bucket by construction (partition_edges_2d
        # packs each bucket's live prefix first)
        counts_l = (src_l[0] != rows).sum(axis=1).astype(jnp.int32)  # [S]

        def push_block(scores):
            acc = _ring_push_level(scores, src_l, dst_l, me,
                                   shards=shards, rows=rows,
                                   counts_l=counts_l, edge_chunk=ch)
            return acc * w_l[:, None]

        if use_kernel:
            from repro.kernels.lane_probe.ops import lane_probe_level

            ident = row0 + jax.lax.broadcasted_iota(
                jnp.int32, (rows, 1), 0
            )  # own-row identity "neighbors" (global ids)
            ones = jnp.ones((rows,), jnp.float32)
            no_excl = jnp.full((w,), sentinel, jnp.int32)
            rid = jax.lax.broadcasted_iota(jnp.int32, (rows, w), 0) + row0

            def level_fn(scores, total, fin, u_p, u_prev, thr):
                # fused prologue: deposit + inject + prune, identity gather
                # over the resident block (table IS the block -> tab0 = 0);
                # exclusion is deferred past the ring push
                prep, total = lane_probe_level(
                    ident, ones, scores, scores, total,
                    fin, u_p, no_excl, thr,
                    row0=row0, tab0=0, n_live=sentinel,
                    prune=eps_p > 0.0,
                )
                scores = push_block(prep)
                scores = jnp.where(rid == u_prev[None, :], 0.0, scores)
                return scores, total
        else:
            level_fn = lane_level_xla(
                push_block, row0=row0, rows=rows, w=w, eps_p=eps_p
            )

        return lane_probe_block(
            level_fn, pool_l, plen_l,
            rows=rows, q=q, wq=wq, n_r=n_r,
            max_len=max_len, sqrt_c=sqrt_c, eps_p=eps_p, sentinel=sentinel,
        )

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P("model", None, None), P("model", None, None),
                  P("model"), P(), P()),
        out_specs=P("model", None),
        # fully manual, like the epoch apply step and the spmd lane probe
        axis_names=set(mesh.axis_names),
    )
    return fn(src_sh, dst_sh, w_full, pool, pool_len)


def make_ring_serve_step(cfg, *, queries: int, walk_chunk: int, max_len: int,
                         top_k: int = 50,
                         frontier_dtype=jnp.float32):
    import math

    from repro.core.distributed import sample_walks_sharded

    sqrt_c = math.sqrt(cfg.c)

    def serve_step(rg: RingGraph, query_nodes: Array, key: Array):
        # reuse the CSR sampler via a duck-typed view
        class _V:
            n_pad = rg.n_pad
            in_deg = rg.in_deg
            indptr = rg.indptr
            indices = rg.indices

        walks = sample_walks_sharded(
            key, _V, query_nodes, walks_per_query=walk_chunk,
            max_len=max_len, sqrt_c=sqrt_c,
        )
        scores = probe_walks_ring(
            rg, walks, sqrt_c=sqrt_c, frontier_dtype=frontier_dtype
        )
        est = scores.reshape(rg.n_pad, queries, walk_chunk).sum(-1) / walk_chunk
        rows = jax.lax.broadcasted_iota(jnp.int32, est.shape, 0)
        est = jnp.where(rows == query_nodes[None, :], -jnp.inf, est)
        vals, idx = jax.lax.top_k(est.T, top_k)
        return idx, vals

    return serve_step
