"""Public op: one fused compacted-lane probe level with kernel dispatch.

``lane_probe_level`` executes deposit + inject + prune + ELL push +
exclusion for one level of the compacted lane schedule (DESIGN.md §3/§10)
in a single fused pass.  The wrapper owns the TPU shape discipline so
callers never see it:

* rows pad up to the block size (sentinel neighbor ids, zero weights —
  padded rows compute exact zeros and are sliced off);
* lane columns pad up to the 128-wide lane dimension (sentinel u_p/u_prev,
  ``fin`` false, zero thresholds — padded columns are no-ops);
* ``fin`` booleans widen to int32 for the kernel operand;
* ``row0``/``tab0`` (global id of output row 0 / its table row) may be
  python ints or traced values (the sharded paths call this inside
  shard_map with a per-shard ``row0``).

Storage dtype follows ``table`` (float32, or bfloat16 for the bf16-storage
/ fp32-accumulate option); ``dep``/``total`` must match.  Runs the Pallas
kernel natively on TPU and in interpret mode elsewhere, keeping the path
CI-testable on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.lane_probe.lane_probe import lane_probe_pallas

Array = jax.Array

_LANE = 128  # TPU lane width: pad W up to a multiple of this


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(r: int, block_rows: int) -> tuple[int, int]:
    """(padded_rows, block) — rows pad to a sublane multiple, large row
    counts tile by ``block_rows``."""
    rp = -(-r // 8) * 8
    if rp >= block_rows:
        return -(-rp // block_rows) * block_rows, block_rows
    return rp, rp


def lane_probe_level(
    nbrs: Array,     # int32 [R, K] global in-neighbor ids (sentinel >= n_live)
    weights: Array,  # f32 [R] push weights (inv_in_deg * sqrt_c)
    table: Array,    # [T, W] gather source (full frontier or own block)
    dep: Array,      # [R, W] pre-level scores of these rows (deposit source)
    total: Array,    # [R, W] per-column accumulator
    fin: Array,      # bool [W] columns depositing this level
    u_p: Array,      # int32 [W] injection ids (>= n_live: no-op)
    u_prev: Array,   # int32 [W] exclusion ids (>= n_live: no-op)
    thr: Array,      # f32 [W] prune thresholds (ignored unless ``prune``)
    *,
    row0,
    tab0,
    n_live: int,
    prune: bool,
    block_rows: int = 128,
    interpret: bool | None = None,
) -> tuple[Array, Array]:
    """Returns ``(scores_out [R, W], total_out [R, W])`` for one level."""
    r, _ = nbrs.shape
    w = table.shape[1]
    if interpret is None:
        interpret = not _on_tpu()

    rp, bn = _pad_rows(r, block_rows)
    wp = -(-w // _LANE) * _LANE
    dtype = table.dtype

    if rp != r:
        pad = rp - r
        nbrs = jnp.concatenate(
            [nbrs, jnp.full((pad, nbrs.shape[1]), n_live, jnp.int32)], axis=0
        )
        weights = jnp.concatenate([weights, jnp.zeros(pad, weights.dtype)])
        dep = jnp.concatenate([dep, jnp.zeros((pad, w), dtype)], axis=0)
        total = jnp.concatenate([total, jnp.zeros((pad, w), dtype)], axis=0)
    if wp != w:
        pad = wp - w
        sent = jnp.full(pad, n_live, jnp.int32)
        fin = jnp.concatenate([fin.astype(jnp.int32), jnp.zeros(pad, jnp.int32)])
        u_p = jnp.concatenate([u_p, sent])
        u_prev = jnp.concatenate([u_prev, sent])
        thr = jnp.concatenate([thr, jnp.zeros(pad, thr.dtype)])
        table = jnp.concatenate(
            [table, jnp.zeros((table.shape[0], pad), dtype)], axis=1
        )
        dep = jnp.concatenate([dep, jnp.zeros((rp, pad), dtype)], axis=1)
        total = jnp.concatenate([total, jnp.zeros((rp, pad), dtype)], axis=1)
    else:
        fin = fin.astype(jnp.int32)

    offs = jnp.stack(
        [jnp.asarray(row0, jnp.int32), jnp.asarray(tab0, jnp.int32)]
    )
    out, tot = lane_probe_pallas(
        nbrs, weights, offs, fin, u_p, u_prev, thr, table, dep, total,
        n_live=n_live, prune=prune, block_rows=bn, interpret=interpret,
    )
    if rp != r or wp != w:
        out = out[:r, :w]
        tot = tot[:r, :w]
    return out, tot
