"""Pallas TPU kernel: one fused compacted-lane probe level on-chip.

The compacted telescoped probe (DESIGN.md §3/§10) runs, per level and per
lane column c:

    deposit   total[:, c] += scores[:, c]            if fin[c]
    inject    scores[u_p[c], c] += 1                  (sentinel = no-op)
    prune     scores[:, c] = 0 where <= thr[c]
    push      out[v, c] = w[v] * sum_k scores[nbrs[v, k], c]
    exclude   out[u_prev[c], c] = 0

The XLA lowering issues these as five separate scatter/gather/select HLOs,
each streaming the whole [rows, W] block through HBM.  This kernel fuses
the level into ONE pass over the output block: the deposit is a block read
of the pre-level scores, and inject/prune/exclude become per-gathered-element
arithmetic folded into the SpMM gather — the injected unit mass is
reconstructed at gather time from ``u_p`` (the gather address equals the
injection address), so no scatter ever materializes.

TPU mapping (same shape discipline as ``kernels/spmm_ell``):
* output rows tile in blocks of BN; the lane-column dim W rides the 128-wide
  lane dimension (the op wrapper pads W up);
* the frontier ``table`` stays whole (ANY/HBM space) and is gathered
  row-by-row with dynamic slices;
* the per-column lane state (fin/u_p/u_prev/thr) is tiny and replicated to
  every block;
* accumulation is always fp32; ``table``/``total`` may be stored bf16
  (bf16-storage / fp32-accumulate option) — gathered rows are upcast before
  the inject/prune arithmetic and the outputs cast back on store.

Reduction-order contract: each output row reduces its K gathered lanes with
a single ``jnp.sum`` over a stacked [K, W] tile — the same reduction XLA
emits for ``push_ell_padded``'s ``gathered.sum(axis=1)``.  That (not a
serial fori-loop accumulate, which XLA reassociates differently on CPU)
is what makes the fused path bitwise-equal to the XLA ELL lane probe in
fp32 (tests/test_lane_kernel.py).

Addressing: neighbor ids are GLOBAL node ids.  ``offs = [row0, tab0]`` maps
them into the table: global id x lives at table row ``x - row0 + tab0``.
The local/spmd paths gather from a full frontier (``tab0 == row0``, so the
address is the id itself); the ring path gathers from its own [rows, W]
block (``tab0 == 0``).  Ids >= n_live (ELL sentinel, mesh padding rows)
contribute exact zeros — value masking replaces the dump-row zeroing of the
XLA path, so the kernel needs no [n + 1] buffer convention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(
    nbrs_ref,    # int32 [bn, K] global neighbor ids for this row block
    w_ref,       # f32   [bn]    push weights (already scaled by sqrt_c)
    offs_ref,    # int32 [2]     (row0, tab0)
    fin_ref,     # int32 [W]     1 where the column deposits this level
    up_ref,      # int32 [W]     injection node id (global; >= n_live: no-op)
    uprev_ref,   # int32 [W]     exclusion node id (global; >= n_live: no-op)
    thr_ref,     # f32   [W]     per-column prune threshold
    table_ref,   # [T, W]        gather source (full frontier or own block)
    dep_ref,     # [bn, W]       pre-level scores of this block (deposit src)
    total_ref,   # [bn, W]       per-column accumulator block
    out_ref,     # [bn, W]       pushed scores out
    tot_ref,     # [bn, W]       updated accumulator out
    *,
    bn: int,
    k_slots: int,
    n_live: int,
    table_rows: int,
    prune: bool,
):
    pid = pl.program_id(0)
    row0 = offs_ref[0]
    tab0 = offs_ref[1]
    fin = fin_ref[...] != 0
    u_p = up_ref[...]
    u_prev = uprev_ref[...]
    thr = thr_ref[...]
    w_cols = out_ref.shape[1]

    # deposit: fp32 accumulate, storage-dtype store
    tot = total_ref[...].astype(jnp.float32)
    dep = dep_ref[...].astype(jnp.float32)
    tot_ref[...] = (tot + jnp.where(fin[None, :], dep, 0.0)).astype(
        tot_ref.dtype
    )

    base_g = row0 + pid * bn  # global node id of this block's row 0

    def row_body(i, acc):
        def k_body(k, stack):
            idx = nbrs_ref[i, k]
            addr = jnp.clip(idx - row0 + tab0, 0, table_rows - 1)
            row = table_ref[pl.dslice(addr, 1), :][0].astype(jnp.float32)
            # deposit-zeroing + injection, per gathered element
            eff = jnp.where(fin, 0.0, row) + (u_p == idx).astype(jnp.float32)
            if prune:
                eff = jnp.where(eff > thr, eff, 0.0)
            # sentinel / padding ids contribute exact zeros
            eff = jnp.where(idx >= n_live, 0.0, eff)
            return stack.at[k, :].set(eff)

        stack = jax.lax.fori_loop(
            0, k_slots, k_body, jnp.zeros((k_slots, w_cols), jnp.float32)
        )
        # single jnp.sum over the K stack == XLA's gathered.sum(axis=1)
        row_out = stack.sum(axis=0) * w_ref[i]
        row_out = jnp.where(u_prev == base_g + i, 0.0, row_out)
        return acc.at[i, :].set(row_out)

    acc = jax.lax.fori_loop(
        0, bn, row_body, jnp.zeros((bn, w_cols), jnp.float32)
    )
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_live", "prune", "block_rows", "interpret"),
)
def lane_probe_pallas(
    nbrs: Array,     # int32 [R, K]
    weights: Array,  # f32 [R]
    offs: Array,     # int32 [2] = (row0, tab0); may be traced under shard_map
    fin: Array,      # int32 [W]
    u_p: Array,      # int32 [W]
    u_prev: Array,   # int32 [W]
    thr: Array,      # f32 [W]
    table: Array,    # [T, W] storage dtype (f32 or bf16)
    dep: Array,      # [R, W] same dtype as table
    total: Array,    # [R, W] same dtype as table
    *,
    n_live: int,
    prune: bool,
    block_rows: int = 128,
    interpret: bool = True,
) -> tuple[Array, Array]:
    R, K = nbrs.shape
    T, W = table.shape
    assert R % block_rows == 0, f"R={R} must tile by block_rows={block_rows}"
    grid = (R // block_rows,)
    kernel = functools.partial(
        _kernel,
        bn=block_rows,
        k_slots=K,
        n_live=n_live,
        table_rows=T,
        prune=prune,
    )
    out, tot = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),  # nbrs tile
            pl.BlockSpec((block_rows,), lambda i: (i,)),      # weights tile
            pl.BlockSpec((2,), lambda i: (0,)),               # offs
            pl.BlockSpec((W,), lambda i: (0,)),               # fin
            pl.BlockSpec((W,), lambda i: (0,)),               # u_p
            pl.BlockSpec((W,), lambda i: (0,)),               # u_prev
            pl.BlockSpec((W,), lambda i: (0,)),               # thr
            pl.BlockSpec((T, W), lambda i: (0, 0)),           # full table
            pl.BlockSpec((block_rows, W), lambda i: (i, 0)),  # deposit tile
            pl.BlockSpec((block_rows, W), lambda i: (i, 0)),  # total tile
        ],
        out_specs=[
            pl.BlockSpec((block_rows, W), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, W), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, W), table.dtype),
            jax.ShapeDtypeStruct((R, W), total.dtype),
        ],
        interpret=interpret,
    )(nbrs, weights, offs, fin, u_p, u_prev, thr, table, dep, total)
    return out, tot
