"""jnp oracle for the fused lane-probe level kernel.

Mirrors ``lane_probe.py`` element-for-element, INCLUDING the reduction
order: the K gathered neighbor lanes reduce through one ``jnp.sum`` over
the stacked axis — the same reduction ``push_ell_padded`` lowers to — so
the oracle, the kernel (interpret mode) and the XLA ELL lane probe are
mutually bitwise-equal in fp32.  Used by tests and as the roofline
comparison baseline in ``benchmarks/bench_kernels.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def lane_probe_level_ref(
    nbrs: Array,     # int32 [R, K] global neighbor ids
    weights: Array,  # f32 [R]
    table: Array,    # [T, W] gather source (f32 or bf16 storage)
    dep: Array,      # [R, W] pre-level scores of this block
    total: Array,    # [R, W] accumulator block
    fin: Array,      # bool/int32 [W]
    u_p: Array,      # int32 [W]
    u_prev: Array,   # int32 [W]
    thr: Array,      # f32 [W]
    *,
    row0,
    tab0,
    n_live: int,
    prune: bool,
) -> tuple[Array, Array]:
    R = nbrs.shape[0]
    T = table.shape[0]
    fin = fin.astype(bool)
    row0 = jnp.asarray(row0, jnp.int32)
    tab0 = jnp.asarray(tab0, jnp.int32)

    # deposit: fp32 accumulate, storage-dtype store
    tot = total.astype(jnp.float32) + jnp.where(
        fin[None, :], dep.astype(jnp.float32), 0.0
    )

    addr = jnp.clip(nbrs - row0 + tab0, 0, T - 1)  # [R, K]
    rows = table[addr].astype(jnp.float32)  # [R, K, W]
    idx = nbrs[:, :, None]
    eff = jnp.where(fin[None, None, :], 0.0, rows) + (
        idx == u_p[None, None, :]
    ).astype(jnp.float32)
    if prune:
        eff = jnp.where(eff > thr[None, None, :], eff, 0.0)
    eff = jnp.where(idx >= n_live, 0.0, eff)

    out = eff.sum(axis=1) * weights[:, None]
    gids = row0 + jnp.arange(R, dtype=jnp.int32)
    out = jnp.where(u_prev[None, :] == gids[:, None], 0.0, out)
    return out.astype(table.dtype), tot.astype(total.dtype)
