"""Pallas TPU kernel: fused PROBE push level.

Fuses three HBM round-trips of the unfused path into one pass:
prune-threshold (rule 2) on the *gathered* source rows, the weighted ELL
gather-sum, and the per-column exclusion mask (first-meeting constraint)
applied in-register before the store.

Same tiling as spmm_ell; the exclusion ids ride along as one extra
scalar-prefetch vector [B] compared against the absolute row id of each
output row."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(nbrs_ref, w_ref, excl_ref, scores_ref, out_ref, *, bn: int,
            k_slots: int, n_rows: int, thresh: float):
    pid = pl.program_id(0)
    B = out_ref.shape[1]

    def row_body(i, acc):
        def k_body(k, row_acc):
            idx = nbrs_ref[i, k]
            idx = jnp.where(idx > n_rows, n_rows, idx)
            row = scores_ref[pl.dslice(idx, 1), :][0]
            row = row.astype(jnp.float32)
            if thresh > 0.0:
                row = jnp.where(row > thresh, row, 0.0)  # fused prune
            return row_acc + row

        row_acc = jax.lax.fori_loop(
            0, k_slots, k_body, jnp.zeros((B,), jnp.float32)
        )
        row_acc = row_acc * w_ref[i]
        # fused exclusion mask: zero the columns whose excluded row is THIS row
        abs_row = pid * bn + i
        excl = excl_ref[...]  # [B]
        row_acc = jnp.where(excl == abs_row, 0.0, row_acc)
        return acc.at[i, :].set(row_acc)

    acc = jax.lax.fori_loop(0, bn, row_body, jnp.zeros(out_ref.shape, jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "prune_thresh")
)
def probe_push_pallas(
    nbrs: Array,  # int32 [n, K]
    scores: Array,  # [n + 1, B] (sentinel zero row at n)
    weights: Array,  # f32 [n]
    exclude: Array,  # int32 [B]
    *,
    prune_thresh: float = 0.0,
    block_rows: int = 128,
    interpret: bool = True,
) -> Array:
    n, K = nbrs.shape
    B = scores.shape[1]
    assert scores.shape[0] == n + 1
    assert n % block_rows == 0
    kernel = functools.partial(
        _kernel, bn=block_rows, k_slots=K, n_rows=n, thresh=prune_thresh
    )
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((B,), lambda i: (0,)),  # exclusion ids (replicated)
            pl.BlockSpec((n + 1, B), lambda i: (0, 0)),  # scores (gathered)
        ],
        out_specs=pl.BlockSpec((block_rows, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, B), scores.dtype),
        interpret=interpret,
    )(nbrs, weights, exclude, scores)
