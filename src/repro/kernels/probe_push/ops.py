"""Public op: fused PROBE push level."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.probe_push.probe_push import probe_push_pallas
from repro.kernels.probe_push.ref import probe_push_ref

Array = jax.Array


def probe_push(
    nbrs: Array,
    scores: Array,  # [n, B]
    weights: Array,
    exclude: Array,
    *,
    prune_thresh: float = 0.0,
    block_rows: int = 128,
) -> Array:
    n = weights.shape[0]
    if n % block_rows != 0 or scores.shape[1] % 8 != 0:
        return probe_push_ref(nbrs, scores, weights, exclude, prune_thresh)
    padded = jnp.concatenate(
        [scores, jnp.zeros((1, scores.shape[1]), scores.dtype)], axis=0
    )
    return probe_push_pallas(
        nbrs, padded, weights, exclude,
        prune_thresh=prune_thresh, block_rows=block_rows,
        interpret=jax.default_backend() != "tpu",
    )
