"""Oracle for the fused PROBE push level (push + weights + exclusion + prune)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def probe_push_ref(
    nbrs: Array,  # int32 [n, K], sentinel = n
    scores: Array,  # [n, B]
    weights: Array,  # f32 [n] (= sqrt_c / in_deg)
    exclude: Array,  # int32 [B] per-column excluded row (sentinel -> none)
    prune_thresh: float = 0.0,  # pruning-rule-2 threshold for THIS level
) -> Array:
    """One fused PROBE level:

    1. prune:  s = where(s > thresh, s, 0)
    2. push:   t[v] = w[v] * sum_k s[nbrs[v, k]]
    3. mask:   t[exclude[b], b] = 0
    """
    n, B = scores.shape
    if prune_thresh > 0.0:
        scores = jnp.where(scores > prune_thresh, scores, 0.0)
    padded = jnp.concatenate([scores, jnp.zeros((1, B), scores.dtype)], axis=0)
    out = padded[nbrs.clip(0, n)].sum(axis=1) * weights[:, None]
    cols = jnp.arange(B)
    ok = exclude < n
    out = out.at[exclude.clip(0, n - 1), cols].set(
        jnp.where(ok, 0.0, out[exclude.clip(0, n - 1), cols])
    )
    return out
