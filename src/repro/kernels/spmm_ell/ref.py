"""Pure-jnp oracle for the ELL SpMM (the PROBE push / GCN aggregation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def spmm_ell_ref(nbrs: Array, scores: Array, weights: Array) -> Array:
    """out[v] = weights[v] * sum_k scores[nbrs[v, k]].

    nbrs: int32 [n, K] with sentinel == n (maps to an implicit zero row).
    scores: [n, B] (or [n]); weights: [n].
    """
    n = weights.shape[0]
    squeeze = scores.ndim == 1
    if squeeze:
        scores = scores[:, None]
    padded = jnp.concatenate(
        [scores, jnp.zeros((1,) + scores.shape[1:], scores.dtype)], axis=0
    )
    gathered = padded[nbrs.clip(0, n)]  # [n, K, B]
    out = gathered.sum(axis=1) * weights[:, None]
    return out[:, 0] if squeeze else out
