"""Pallas TPU kernel: row-blocked ELL SpMM (gather + reduce, no scatter).

The PROBE push / GCN hot loop: ``out[v] = w[v] * sum_k S[nbrs[v, k]]``.

TPU mapping (DESIGN.md §2 hardware adaptation):
* rows tile in blocks of BN (sublane-aligned); the walk-column dim B rides
  the 128-wide lane dimension, so each gathered row is one VREG-aligned
  vector load;
* the neighbor-id block is a *scalar-prefetch* operand (SMEM) — ids must be
  available before the gather addresses can be issued;
* the score matrix stays in ANY/HBM space and is gathered row-by-row with
  ``pl.load`` dynamic slices — SpMM is gather-bound by nature, and the VMEM
  budget is BN x B accumulator + one gathered row;
* K (neighbor slots) is an unrolled static loop;
* the kernel consumes scores WITH the sentinel dump row ([n + 1, B], row n
  zero).  The serving path bakes that row into its score buffers at
  construction (``ops.spmm_ell_padded``), so sentinel neighbor ids gather a
  true zero and no per-push re-pad of the operand is issued.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(nbrs_ref, w_ref, scores_ref, out_ref, *, bn: int, k_slots: int,
            n_rows: int):
    pid = pl.program_id(0)
    acc = jnp.zeros(out_ref.shape, jnp.float32)

    def row_body(i, acc):
        def k_body(k, row_acc):
            idx = nbrs_ref[i, k]
            idx = jnp.where(idx > n_rows, n_rows, idx)  # clamp to zero row
            row = scores_ref[pl.dslice(idx, 1), :]
            return row_acc + row[0].astype(jnp.float32)

        row_acc = jax.lax.fori_loop(
            0, k_slots, k_body, jnp.zeros((out_ref.shape[1],), jnp.float32)
        )
        return acc.at[i, :].set(row_acc * w_ref[i])

    acc = jax.lax.fori_loop(0, bn, row_body, acc)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def spmm_ell_pallas(
    nbrs: Array,  # int32 [n, K], sentinel = n (or larger -> clamped)
    scores: Array,  # [n + 1, B]; row n must be zeros (sentinel dump row)
    weights: Array,  # f32 [n]
    *,
    block_rows: int = 128,
    interpret: bool = True,
) -> Array:
    n, K = nbrs.shape
    B = scores.shape[1]
    assert scores.shape[0] == n + 1, "scores needs the sentinel zero row"
    assert n % block_rows == 0, f"n={n} must tile by block_rows={block_rows}"
    grid = (n // block_rows,)
    kernel = functools.partial(
        _kernel, bn=block_rows, k_slots=K, n_rows=n
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),  # nbrs tile
            pl.BlockSpec((block_rows,), lambda i: (i,)),  # weights tile
            pl.BlockSpec(
                (n + 1, B), lambda i: (0, 0)
            ),  # full scores (ANY space; gathered)
        ],
        out_specs=pl.BlockSpec((block_rows, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, B), scores.dtype),
        interpret=interpret,
    )(nbrs, weights, scores)
