"""Public op: ELL SpMM with kernel/oracle dispatch.

Two entry points:

* ``spmm_ell``        — classic [n, B] scores; pads the sentinel dump row on
  every call (kept for the GNN layers and ad-hoc callers).
* ``spmm_ell_padded`` — serving hot path: scores arrive as [n + 1, B] with
  the zero dump row already baked in at buffer construction, so the kernel
  consumes them directly and no per-push re-pad/copy happens (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.spmm_ell.spmm_ell import spmm_ell_pallas

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def spmm_ell_padded(
    nbrs: Array,
    scores: Array,
    weights: Array,
    *,
    block_rows: int = 128,
) -> Array:
    """out[v] = w[v] * sum_k scores[nbrs[v,k]]; scores [n + 1, B].

    Row n of ``scores`` is the sentinel dump row and MUST be zero — sentinel
    neighbor slots (id >= n) gather from it.  Dispatches to the Pallas kernel
    when the shapes tile (TPU target; interpret-mode on CPU), falling back to
    a direct-gather oracle otherwise.  Returns [n, B] (callers re-append the
    dump row once per level, not once per operand).
    """
    n = weights.shape[0]
    if n % block_rows != 0 or scores.shape[1] % 8 != 0:
        gathered = scores[nbrs.clip(0, n)]  # [n, K, B]
        return gathered.sum(axis=1) * weights[:, None]
    return spmm_ell_pallas(
        nbrs, scores, weights, block_rows=block_rows, interpret=not _on_tpu()
    )


def spmm_ell(nbrs: Array, scores: Array, weights: Array,
             *, block_rows: int = 128) -> Array:
    """out[v] = w[v] * sum_k scores[nbrs[v,k]]; scores [n, B] (no dump row).

    Appends the sentinel dump row and defers to ``spmm_ell_padded``.
    """
    squeeze = scores.ndim == 1
    if squeeze:
        scores = scores[:, None]
    padded = jnp.concatenate(
        [scores, jnp.zeros((1,) + scores.shape[1:], scores.dtype)], axis=0
    )
    out = spmm_ell_padded(nbrs, padded, weights, block_rows=block_rows)
    return out[:, 0] if squeeze else out
