"""Public op: ELL SpMM with kernel/oracle dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.spmm_ell.ref import spmm_ell_ref
from repro.kernels.spmm_ell.spmm_ell import spmm_ell_pallas

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def spmm_ell(nbrs: Array, scores: Array, weights: Array,
             *, block_rows: int = 128) -> Array:
    """out[v] = w[v] * sum_k scores[nbrs[v,k]]; scores [n, B] (no dump row).

    Dispatches to the Pallas kernel when the shapes tile (TPU target;
    interpret-mode on CPU), falling back to the jnp oracle otherwise.
    """
    n = weights.shape[0]
    squeeze = scores.ndim == 1
    if squeeze:
        scores = scores[:, None]
    if n % block_rows != 0 or scores.shape[1] % 8 != 0:
        out = spmm_ell_ref(nbrs, scores, weights)
        return out[:, 0] if squeeze else out
    padded = jnp.concatenate(
        [scores, jnp.zeros((1,) + scores.shape[1:], scores.dtype)], axis=0
    )
    out = spmm_ell_pallas(
        nbrs, padded, weights, block_rows=block_rows, interpret=not _on_tpu()
    )
    return out[:, 0] if squeeze else out
