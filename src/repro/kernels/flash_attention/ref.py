"""Oracle: plain softmax attention (GQA-aware), fp32 accumulation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def attention_ref(
    q: Array,  # [B, S, H, dh]
    k: Array,  # [B, T, Hkv, dh]
    v: Array,  # [B, T, Hkv, dh]
    *,
    causal: bool = True,
    scale: float | None = None,
) -> Array:
    B, S, H, dh = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else dh**-0.5
    qg = q.reshape(B, S, Hkv, g, dh).astype(jnp.float32)
    logits = jnp.einsum("bsngd,btnd->bngst", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(k.shape[1])[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bngst,btnd->bsngd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, dh).astype(q.dtype)
