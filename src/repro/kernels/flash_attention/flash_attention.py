"""Pallas TPU kernel: FlashAttention (causal, GQA) with online softmax.

Tiling: grid = (B*H, S_q/BQ, T_kv/BK); the innermost grid dim streams KV
blocks while running-max / running-sum / output accumulators live in VMEM
scratch (classic FlashAttention-2 schedule — one output tile is revisited
across the KV grid dim and finalized on the last block).

GQA is handled in the BlockSpec index maps: query head h reads kv head
h // (H / Hkv) — no repeated KV materialization.

VMEM budget per instance: q tile BQ x dh + kv tiles BK x dh x 2 + acc
BQ x dh + 2 vectors — with BQ=BK=128, dh=128 fp32 that is ~260 KB, well
under the ~16 MB VMEM target."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, scale: float, causal: bool, kv_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale  # [bq, dh]
    k = k_ref[0].astype(jnp.float32)  # [bk, dh]
    v = v_ref[0].astype(jnp.float32)  # [bk, dh]
    s = q @ k.T  # [bq, bk]
    if causal:
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)
    m_prev = m_scr[...]  # [bq, 1]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == kv_blocks - 1)
    def _final():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: Array,  # [B, H, S, dh]
    k: Array,  # [B, Hkv, T, dh]
    v: Array,  # [B, Hkv, T, dh]
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> Array:
    B, H, S, dh = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = scale if scale is not None else dh**-0.5
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0
    kv_blocks = T // bk
    grid = (B * H, S // bq, kv_blocks)

    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, scale=scale, causal=causal, kv_blocks=kv_blocks
    )
    qs = q.reshape(B * H, S, dh)
    ks = k.reshape(B * Hkv, T, dh)
    vs = v.reshape(B * Hkv, T, dh)

    def kv_index(bh, i, j):
        b = bh // H
        h = bh % H
        return (b * Hkv + h // group, j, 0)

    from jax.experimental.pallas import tpu as pltpu

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, dh), kv_index),
            pl.BlockSpec((1, bk, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running max
            pltpu.VMEM((bq, 1), jnp.float32),  # running denominator
            pltpu.VMEM((bq, dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qs, ks, vs)
    return out.reshape(B, H, S, dh)
