"""Public op: flash attention in [B, S, H, dh] layout (model convention)."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref

Array = jax.Array


def flash_attention(
    q: Array,  # [B, S, H, dh]
    k: Array,  # [B, T, Hkv, dh]
    v: Array,  # [B, T, Hkv, dh]
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
) -> Array:
    B, S, H, dh = q.shape
    T = k.shape[1]
    bq = min(block_q, S)
    bk = min(block_k, T)
    if S % bq != 0 or T % bk != 0 or dh % 8 != 0:
        return attention_ref(q, k, v, causal=causal, scale=scale)
    out = flash_attention_pallas(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal,
        scale=scale,
        block_q=bq,
        block_k=bk,
        interpret=jax.default_backend() != "tpu",
    )
    return out.transpose(0, 2, 1, 3)
