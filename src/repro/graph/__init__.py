from repro.graph.structs import (
    CsrGraph,
    EllGraph,
    Graph,
    csr_from_edges,
    ell_from_edges,
    graph_from_edges,
    graph_to_host_edges,
    push_coo,
    push_ell,
)
from repro.graph.generators import (
    TOY_TABLE2,
    bipartite_graph,
    erdos_renyi_graph,
    paper_dataset,
    powerlaw_graph,
    toy_graph,
)

__all__ = [
    "CsrGraph",
    "EllGraph",
    "Graph",
    "csr_from_edges",
    "ell_from_edges",
    "graph_from_edges",
    "graph_to_host_edges",
    "push_coo",
    "push_ell",
    "TOY_TABLE2",
    "bipartite_graph",
    "erdos_renyi_graph",
    "paper_dataset",
    "powerlaw_graph",
    "toy_graph",
]
