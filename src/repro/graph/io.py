"""Edge-list IO (SNAP text format and a fast binary format)."""
from __future__ import annotations

import os

import numpy as np


def read_edgelist(path: str) -> tuple[np.ndarray, np.ndarray, int]:
    """Parse a SNAP-style whitespace edge list ('# ' comments allowed).

    Node ids are compacted to [0, n).
    """
    srcs: list[int] = []
    dsts: list[int] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(("#", "%")):
                continue
            parts = line.split()
            srcs.append(int(parts[0]))
            dsts.append(int(parts[1]))
    src = np.asarray(srcs, dtype=np.int64)
    dst = np.asarray(dsts, dtype=np.int64)
    uniq, inv = np.unique(np.concatenate([src, dst]), return_inverse=True)
    m = len(src)
    return inv[:m].astype(np.int32), inv[m:].astype(np.int32), len(uniq)


def write_edgelist(path: str, src: np.ndarray, dst: np.ndarray) -> None:
    with open(path, "w") as f:
        f.write("# src dst\n")
        for s, d in zip(src.tolist(), dst.tolist()):
            f.write(f"{s} {d}\n")


def save_graph_npz(path: str, src: np.ndarray, dst: np.ndarray, n: int) -> None:
    np.savez_compressed(path, src=src.astype(np.int32), dst=dst.astype(np.int32), n=n)


def load_graph_npz(path: str) -> tuple[np.ndarray, np.ndarray, int]:
    z = np.load(path)
    return z["src"], z["dst"], int(z["n"])


def cache_dir() -> str:
    d = os.environ.get("REPRO_CACHE", "/tmp/repro_cache")
    os.makedirs(d, exist_ok=True)
    return d
