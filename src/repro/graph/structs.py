"""Graph data structures for TPU-resident graph algorithms.

Three complementary device representations, all capacity-padded so shapes are
static under jit:

* ``Graph`` — COO edge list (``src``, ``dst``) padded with the sentinel node
  id ``n``; per-node in/out degrees.  This is the *push* representation: a
  PROBE / GCN propagation level is ``segment_sum(scores[src] * w, dst)``.
* ``EllGraph`` — padded in-neighbor table ``in_nbrs[n, k_max]`` (ELL format).
  This is the *gather* representation: propagation becomes a dense gather +
  masked reduce (no scatter), which is the TPU-preferred layout and the one
  our Pallas SpMM kernel consumes.  Also used for O(1) uniform in-neighbor
  sampling in sqrt(c)-walk generation.  The sentinel id ``n`` doubles as the
  row index of the *dump row* in [n + 1, B] score buffers: serving-path
  buffers bake that extra zero row in at construction so sentinel gathers
  and scatters need no per-push masking or re-padding (``push_ell_padded``).
* ``CsrGraph`` — classic indptr/indices (host-built), used by the host-side
  neighbor sampler and IO.

All node ids are int32.  The sentinel id for padding is ``n`` (one past the
last real node); arrays that may be indexed by sentinel carry one extra row.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.utils.pytree import static, struct

Array = jax.Array


def _snapshot_field():
    """Traced kw-only field for the dynamic-graph snapshot metadata.

    ``version`` / ``overflow`` default to ``None`` (legacy construction sites
    keep working; ``None`` is an empty pytree subtree) and are set to concrete
    scalars by the constructors below so ``graph/dynamic.py`` can thread them
    through jitted update/epoch steps.
    """
    return dataclasses.field(default=None, kw_only=True)


@struct
class Graph:
    """COO graph, capacity padded.  Padding edges have src = dst = n.

    ``version`` is a monotonically increasing int32 scalar bumped once per
    applied update batch (graph/dynamic.py) so query results can be
    attributed to a graph snapshot; ``overflow`` is a sticky bool scalar set
    when an insert was skipped for lack of capacity (COO buffer or the ELL
    mirror's row) — callers detect it and run the host-side ``regrow`` path.
    """

    src: Array  # int32 [capacity]
    dst: Array  # int32 [capacity]
    in_deg: Array  # int32 [n]
    out_deg: Array  # int32 [n]
    num_edges: Array  # int32 scalar (actual edges)
    n: int = static()
    capacity: int = static()
    version: Array | None = _snapshot_field()  # int32 scalar
    overflow: Array | None = _snapshot_field()  # bool scalar

    @property
    def inv_in_deg(self) -> Array:
        """1/|I(v)| with 0 for dangling nodes (float32 [n])."""
        d = self.in_deg.astype(jnp.float32)
        return jnp.where(d > 0, 1.0 / jnp.maximum(d, 1.0), 0.0)

    def edge_mask(self) -> Array:
        """bool [capacity]: True for real (non-padding) edges."""
        return self.src < self.n


@struct
class EllGraph:
    """Padded in-neighbor table (ELL).  in_nbrs[v, k] = k-th in-neighbor of v
    for k < in_deg[v], else sentinel n."""

    in_nbrs: Array  # int32 [n, k_max], padded with n
    in_deg: Array  # int32 [n]
    n: int = static()
    k_max: int = static()
    version: Array | None = _snapshot_field()  # int32 scalar
    overflow: Array | None = _snapshot_field()  # bool scalar

    @property
    def inv_in_deg(self) -> Array:
        d = self.in_deg.astype(jnp.float32)
        return jnp.where(d > 0, 1.0 / jnp.maximum(d, 1.0), 0.0)


class CsrGraph:
    """Host-side CSR (numpy).  indptr[n+1], indices[m] sorted by row."""

    def __init__(self, indptr: np.ndarray, indices: np.ndarray, n: int):
        self.indptr = np.asarray(indptr, dtype=np.int64)
        self.indices = np.asarray(indices, dtype=np.int32)
        self.n = int(n)

    @property
    def m(self) -> int:
        return int(self.indices.shape[0])

    def degree(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------


def graph_from_edges(
    src: np.ndarray,
    dst: np.ndarray,
    n: int,
    capacity: int | None = None,
) -> Graph:
    """Build a device COO ``Graph`` from host edge arrays.

    ``capacity`` reserves head-room for dynamic insertions (defaults to m).
    """
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    m = src.shape[0]
    if capacity is None:
        capacity = m
    if capacity < m:
        raise ValueError(f"capacity {capacity} < num edges {m}")
    pad = capacity - m
    src_p = np.concatenate([src, np.full(pad, n, dtype=np.int32)])
    dst_p = np.concatenate([dst, np.full(pad, n, dtype=np.int32)])
    in_deg = np.bincount(dst, minlength=n).astype(np.int32)
    out_deg = np.bincount(src, minlength=n).astype(np.int32)
    return Graph(
        src=jnp.asarray(src_p),
        dst=jnp.asarray(dst_p),
        in_deg=jnp.asarray(in_deg[:n]),
        out_deg=jnp.asarray(out_deg[:n]),
        num_edges=jnp.asarray(m, dtype=jnp.int32),
        n=int(n),
        capacity=int(capacity),
        version=jnp.asarray(0, dtype=jnp.int32),
        overflow=jnp.asarray(False),
    )


def ell_from_edges(
    src: np.ndarray,
    dst: np.ndarray,
    n: int,
    k_max: int | None = None,
) -> EllGraph:
    """Pack in-neighbors into an ELL table.  k_max defaults to max in-degree."""
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    in_deg = np.bincount(dst, minlength=n).astype(np.int32)[:n]
    deg_cap = int(in_deg.max()) if in_deg.size else 0
    if k_max is None:
        k_max = max(deg_cap, 1)
    if deg_cap > k_max:
        raise ValueError(f"max in-degree {deg_cap} exceeds k_max {k_max}")
    table = np.full((n, k_max), n, dtype=np.int32)
    # stable counting fill
    order = np.argsort(dst, kind="stable")
    slot = np.zeros(n, dtype=np.int64)
    d_sorted = dst[order]
    s_sorted = src[order]
    # vectorized slot assignment: position within each dst group
    group_start = np.searchsorted(d_sorted, np.arange(n))
    idx_within = np.arange(len(d_sorted)) - group_start[d_sorted]
    table[d_sorted, idx_within] = s_sorted
    del slot
    return EllGraph(
        in_nbrs=jnp.asarray(table),
        in_deg=jnp.asarray(in_deg),
        n=int(n),
        k_max=int(k_max),
        version=jnp.asarray(0, dtype=jnp.int32),
        overflow=jnp.asarray(False),
    )


def csr_from_edges(src: np.ndarray, dst: np.ndarray, n: int, by: str = "dst") -> CsrGraph:
    """Host CSR grouped by ``dst`` (in-CSR, default) or ``src`` (out-CSR)."""
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    key, val = (dst, src) if by == "dst" else (src, dst)
    order = np.argsort(key, kind="stable")
    counts = np.bincount(key, minlength=n)[:n]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CsrGraph(indptr, val[order], n)


def graph_to_host_edges(g: Graph) -> tuple[np.ndarray, np.ndarray]:
    """Extract the real (non-padding) edges to host numpy."""
    m = int(g.num_edges)
    return np.asarray(g.src[:m]), np.asarray(g.dst[:m])


# ---------------------------------------------------------------------------
# Propagation primitives (the substrate shared by PROBE and the GNN layers)
# ---------------------------------------------------------------------------


def push_coo(
    g: Graph,
    scores: Array,
    weights: Array | None = None,
) -> Array:
    """One propagation level over the COO edges.

    ``new[v] = sum_{x in I(v)} scores[x] * w[v]`` where ``w`` defaults to 1.
    ``scores`` is [n, ...] or [n]; returns same shape.  Padding edges scatter
    into the sentinel row which is dropped.
    """
    msgs = scores[g.src.clip(0, g.n - 1)]
    msgs = jnp.where(
        (g.src < g.n)[(...,) + (None,) * (msgs.ndim - 1)], msgs, 0.0
    )
    out = jax.ops.segment_sum(msgs, g.dst, num_segments=g.n + 1)[: g.n]
    if weights is not None:
        out = out * weights[(...,) + (None,) * (out.ndim - 1)].reshape(
            (g.n,) + (1,) * (out.ndim - 1)
        )
    return out


def push_ell(
    eg: EllGraph,
    scores: Array,
    weights: Array | None = None,
) -> Array:
    """Gather-based propagation level over the ELL in-neighbor table.

    ``new[v] = w[v] * sum_{k < in_deg[v]} scores[in_nbrs[v, k]]``.
    ``scores``: [n] or [n, B].  TPU-friendly: pure gather + reduce, no scatter.
    """
    padded = jnp.concatenate(
        [scores, jnp.zeros((1,) + scores.shape[1:], scores.dtype)], axis=0
    )
    return push_ell_padded(eg, padded, weights)


def push_ell_padded(
    eg: EllGraph,
    scores: Array,
    weights: Array | None = None,
) -> Array:
    """``push_ell`` over a score buffer with the sentinel dump row baked in.

    ``scores`` is [n + 1, ...] and row n (the dump row) MUST be zero: the ELL
    sentinel id ``n`` then gathers an exact zero, so no per-push re-pad of the
    score matrix is needed (DESIGN.md §2/§3 — buffers are allocated once with
    the dump row and carried through all push levels).  Returns [n, ...].
    """
    gathered = scores[eg.in_nbrs]  # [n, k_max, ...]
    out = gathered.sum(axis=1)
    if weights is not None:
        out = out * weights.reshape((eg.n,) + (1,) * (out.ndim - 1))
    return out
