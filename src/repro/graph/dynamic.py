"""Dynamic graph maintenance — the index-free story of the paper.

ProbeSim precomputes nothing, so supporting a dynamic graph only requires
that the *graph representation itself* absorbs updates cheaply.  Both device
representations do (contrast the paper's index-based competitors — TSF must
rebuild its R_g one-way graphs, SLING rebuilds entirely):

* COO (``Graph``): insertion appends into the capacity-padded edge buffer
  (O(1) per edge); deletion removes by stable compaction in the coordinated
  batch path (``apply_update_batch``) or swap-remove in the legacy
  per-struct path (``delete_edges``).
* ELL (``EllGraph``): insertion writes slot ``in_deg[dst]`` of row ``dst``;
  deletion compacts (or swap-removes) within the row.

All updates are functional (return new pytrees) and jit-compatible, so the
serving loop can interleave ``update -> query -> update`` entirely on device
(`serving/dynamic_engine.py` fuses one update batch + one query batch into a
single jitted *epoch step*).

Three contracts every update path honors (DESIGN.md §5):

**Masked no-op padding.**  Update batches are fixed-size so epoch shapes are
static under jit; short batches are padded with the sentinel node id ``n``
(see ``make_update_batch``).  Entries with ``src`` or ``dst`` outside
``[0, n)`` are no-ops everywhere — an all-sentinel batch leaves the graph
bit-identical (tested).

**Explicit overflow, never a silent drop.**  An insert that finds no room
(COO buffer full, or the destination's ELL row at ``k_max``) is *skipped in
both mirrors* and recorded in the sticky ``overflow`` flag of the returned
struct(s).  Callers poll the flag and run the host-side ``regrow`` path
(compaction + larger buffers); nothing is ever half-applied or silently
lost.  ``apply_update_batch`` additionally returns a per-op ``applied`` mask
so skipped ops can be retried after regrowing.

**Versioned snapshots.**  ``version`` increments exactly once per batch that
changed the graph (masked-out and skipped ops don't count), so engine
results can attribute scores to a graph snapshot.  The coordinated
``apply_update_batch`` keeps both mirrors' versions in lockstep; the
standalone per-struct functions below bump their own struct only.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.graph.structs import (
    EllGraph,
    Graph,
    ell_from_edges,
    graph_from_edges,
    graph_to_host_edges,
)
from repro.utils.pytree import static, struct

Array = jax.Array


@struct
class UpdateBatch:
    """Fixed-size padded edge-update batch (static shapes under jit).

    Sentinel entries (``src`` or ``dst`` >= n, as produced by
    ``make_update_batch``) are no-ops; ``insert[i]`` selects insert (True)
    vs delete (False) for op i.  ``has_deletes`` is STATIC (part of the jit
    cache key): insert-only batches — the common serving workload — compile
    to an O(B) append step with no O(capacity) delete matching or
    compaction, so at most two epoch-step variants ever compile.
    """

    src: Array  # int32 [B]
    dst: Array  # int32 [B]
    insert: Array  # bool [B]
    has_deletes: bool = static(True)

    @property
    def size(self) -> int:
        return int(self.src.shape[0])


def make_update_batch(
    src,
    dst,
    insert,
    *,
    batch_size: int,
    n: int,
) -> UpdateBatch:
    """Host helper: pad an edge-op list to ``batch_size`` with sentinel no-ops.

    ``insert`` is a scalar bool (whole batch) or a per-edge bool array.
    """
    src = np.asarray(src, dtype=np.int32).reshape(-1)
    dst = np.asarray(dst, dtype=np.int32).reshape(-1)
    b = src.shape[0]
    if dst.shape[0] != b:
        raise ValueError(f"src/dst length mismatch: {b} vs {dst.shape[0]}")
    if b > batch_size:
        raise ValueError(f"{b} ops exceed batch_size {batch_size}")
    ins = np.broadcast_to(np.asarray(insert, dtype=bool), (b,))
    pad = batch_size - b
    return UpdateBatch(
        src=jnp.asarray(np.concatenate([src, np.full(pad, n, np.int32)])),
        dst=jnp.asarray(np.concatenate([dst, np.full(pad, n, np.int32)])),
        insert=jnp.asarray(np.concatenate([ins, np.zeros(pad, bool)])),
        has_deletes=bool((~ins).any()),
    )


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def _valid_mask(src: Array, dst: Array, n: int) -> Array:
    """True for real ops; sentinel-padded (masked no-op) entries are False."""
    return (src >= 0) & (src < n) & (dst >= 0) & (dst < n)


def _bump(version: Array | None, applied_any: Array) -> Array | None:
    """version + 1 iff the batch changed the graph (None passes through)."""
    if version is None:
        return None
    return version + applied_any.astype(jnp.int32)


def _sticky(overflow: Array | None, new: Array) -> Array:
    """Overflow is sticky: once set it stays set until ``regrow`` clears it."""
    if overflow is None:
        return new
    return overflow | new


@jax.jit
def _occurrence_index(x: Array, valid: Array) -> Array:
    """occ[i] = #{j < i : x[j] == x[i] and valid[j]} (O(B^2); batches small)."""
    eq = (x[None, :] == x[:, None]) & valid[None, :]
    tri = jnp.tril(jnp.ones_like(eq, dtype=jnp.int32), k=-1)
    return (eq.astype(jnp.int32) * tri).sum(axis=1)


# ---------------------------------------------------------------------------
# Per-struct vectorized updates (fast paths; bump their own struct only)
# ---------------------------------------------------------------------------


def insert_edges(g: Graph, src: Array, dst: Array) -> Graph:
    """Append a batch of edges (src[i] -> dst[i]) to the COO buffer.

    Sentinel entries are no-ops.  Inserts past ``capacity`` are skipped and
    set the sticky ``overflow`` flag on the returned graph (no silent drop).
    """
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    valid = _valid_mask(src, dst, g.n)
    vint = valid.astype(jnp.int32)
    pos = g.num_edges + jnp.cumsum(vint) - vint  # exclusive prefix over valid
    ok = valid & (pos < g.capacity)
    # mode="drop": skipped ops scatter out of bounds and vanish
    new_src = g.src.at[jnp.where(ok, pos, g.capacity)].set(src, mode="drop")
    new_dst = g.dst.at[jnp.where(ok, pos, g.capacity)].set(dst, mode="drop")
    in_deg = g.in_deg.at[jnp.where(ok, dst, g.n)].add(1, mode="drop")
    out_deg = g.out_deg.at[jnp.where(ok, src, g.n)].add(1, mode="drop")
    return g.replace(
        src=new_src,
        dst=new_dst,
        in_deg=in_deg,
        out_deg=out_deg,
        num_edges=g.num_edges + ok.astype(jnp.int32).sum(),
        version=_bump(g.version, ok.any()),
        overflow=_sticky(g.overflow, (valid & ~ok).any()),
    )


def insert_edges_ell(eg: EllGraph, src: Array, dst: Array) -> EllGraph:
    """Mirror insertion into the ELL in-neighbor table (same contracts)."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    valid = _valid_mask(src, dst, eg.n)
    occ = _occurrence_index(dst, valid)
    dst_c = dst.clip(0, eg.n - 1)
    slot = eg.in_deg[dst_c] + occ
    ok = valid & (slot < eg.k_max)
    table = eg.in_nbrs.at[
        jnp.where(ok, dst, eg.n), jnp.where(ok, slot, eg.k_max)
    ].set(src, mode="drop")
    in_deg = eg.in_deg.at[jnp.where(ok, dst, eg.n)].add(1, mode="drop")
    return eg.replace(
        in_nbrs=table,
        in_deg=in_deg,
        version=_bump(eg.version, ok.any()),
        overflow=_sticky(eg.overflow, (valid & ~ok).any()),
    )


def delete_edges(g: Graph, src: Array, dst: Array) -> Graph:
    """Swap-remove a batch of edges (sequential scan; batches are small).

    Sentinel entries and edges not present are no-ops.  Removes the first
    match per op (graphs are simple).
    """
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    valid = _valid_mask(src, dst, g.n)

    def body(carry, op):
        cur_src, cur_dst, in_deg, out_deg, ne = carry
        s, d, v = op
        match = (cur_src == s) & (cur_dst == d) & v
        found = match.any()
        pos = jnp.argmax(match)
        last = jnp.maximum(ne - 1, 0)
        # move the last live edge into pos, stamp sentinel at last
        moved_s = cur_src[last]
        moved_d = cur_dst[last]
        p_idx = jnp.where(found, pos, g.capacity)
        l_idx = jnp.where(found, last, g.capacity)
        cur_src = cur_src.at[p_idx].set(moved_s, mode="drop")
        cur_dst = cur_dst.at[p_idx].set(moved_d, mode="drop")
        cur_src = cur_src.at[l_idx].set(g.n, mode="drop")
        cur_dst = cur_dst.at[l_idx].set(g.n, mode="drop")
        in_deg = in_deg.at[jnp.where(found, d, g.n)].add(-1, mode="drop")
        out_deg = out_deg.at[jnp.where(found, s, g.n)].add(-1, mode="drop")
        return (cur_src, cur_dst, in_deg, out_deg, ne - found.astype(jnp.int32)), found

    init = (g.src, g.dst, g.in_deg, g.out_deg, g.num_edges)
    (new_src, new_dst, in_deg, out_deg, ne), found = jax.lax.scan(
        body, init, (src, dst, valid)
    )
    return g.replace(
        src=new_src,
        dst=new_dst,
        in_deg=in_deg,
        out_deg=out_deg,
        num_edges=ne,
        version=_bump(g.version, found.any()),
        overflow=g.overflow,
    )


def delete_edges_ell(eg: EllGraph, src: Array, dst: Array) -> EllGraph:
    """Swap-remove within ELL rows (sequential scan; same contracts)."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    valid = _valid_mask(src, dst, eg.n)

    def body(carry, op):
        table, in_deg = carry
        s, d, v = op
        d_c = jnp.where(v, d, 0)
        row = table[d_c]
        match = (row == s) & v
        found = match.any()
        k = jnp.argmax(match)
        last = jnp.maximum(in_deg[d_c] - 1, 0).clip(0, eg.k_max - 1)
        moved = row[last]
        row = row.at[jnp.where(found, k, eg.k_max)].set(moved, mode="drop")
        row = row.at[jnp.where(found, last, eg.k_max)].set(eg.n, mode="drop")
        table = table.at[jnp.where(found, d, eg.n)].set(row, mode="drop")
        in_deg = in_deg.at[jnp.where(found, d, eg.n)].add(-1, mode="drop")
        return (table, in_deg), found

    (table, in_deg), found = jax.lax.scan(
        body, (eg.in_nbrs, eg.in_deg), (src, dst, valid)
    )
    return eg.replace(
        in_nbrs=table,
        in_deg=in_deg,
        version=_bump(eg.version, found.any()),
        overflow=eg.overflow,
    )


# ---------------------------------------------------------------------------
# Coordinated batch application (the epoch-step update path)
# ---------------------------------------------------------------------------


def apply_update_batch(
    g: Graph, eg: EllGraph, batch: UpdateBatch
) -> tuple[Graph, EllGraph, Array]:
    """Apply a mixed insert/delete batch to BOTH mirrors, fully vectorized.

    This is the consistency-preserving path used inside the jitted epoch
    step: an insert is applied iff there is room in *both* the COO buffer
    and the destination's ELL row, so the mirrors never diverge (the
    per-struct fast paths above cannot coordinate that check).  Returns
    ``(g', eg', applied)`` where ``applied[i]`` says op i changed the graph;
    skipped inserts set the sticky ``overflow`` flag on both mirrors and can
    be retried after ``regrow``.  ``version`` advances by exactly one on both
    mirrors iff any op applied.

    Two phases, no per-op scan (a scan pays O(capacity) per delete probe and
    XLA carry traffic per step; phases pay O(capacity + B·k_max + B²) per
    BATCH — sub-10ms on the bench graphs vs ~100ms for the scan form):

    1. **deletes** — all requested edges are matched against the pre-batch
       buffers at once ([B, capacity] compare), marked, and removed by a
       *stable compaction* of the COO buffer and of each touched ELL row;
    2. **inserts** — appended en bloc at the compacted tail / row ends, with
       the coordinated room check (COO capacity AND destination row).

    Deletes therefore apply before inserts within one batch; a delete can
    never see an edge inserted by the *same* batch (``DynamicEngine`` cuts
    its epoch batches at such conflicts to preserve stream order), and at
    most one copy of a given (src, dst) edge is deleted per batch.

    Because compaction is stable and inserts append, the maintained mirrors
    stay BIT-IDENTICAL to ``graph_from_edges`` / ``ell_from_edges`` rebuilt
    from the equivalently-updated host edge list — which keeps walk sampling
    (and therefore epoch scores) exactly equal to a from-scratch rebuild
    (tested in tests/test_dynamic.py).
    """
    n, cap, k_max = g.n, g.capacity, eg.k_max
    src_b = jnp.asarray(batch.src, jnp.int32)
    dst_b = jnp.asarray(batch.dst, jnp.int32)
    valid = _valid_mask(src_b, dst_b, n)
    is_ins = valid & batch.insert
    s_c = jnp.where(valid, src_b, 0)
    d_c = jnp.where(valid, dst_b, 0)
    tri = jnp.tril(jnp.ones((src_b.shape[0],) * 2, jnp.int32), k=-1)

    if batch.has_deletes:
        # ---- phase 1: deletes (match against pre-batch buffers, compact) --
        is_del = valid & ~batch.insert
        # at most one copy of a pair per batch: later duplicates are no-ops
        same_pair = (
            (src_b[None, :] == src_b[:, None])
            & (dst_b[None, :] == dst_b[:, None])
            & is_del[None, :]
        )
        del_live = is_del & ((same_pair.astype(jnp.int32) * tri).sum(1) == 0)
        hits = (
            (g.src[None, :] == s_c[:, None])
            & (g.dst[None, :] == d_c[:, None])
            & del_live[:, None]
        )
        found = hits.any(axis=1)
        pos = jnp.argmax(hits, axis=1)
        del_mask = (
            jnp.zeros(cap, bool)
            .at[jnp.where(found, pos, cap)]
            .set(True, mode="drop")
        )
        keep = (g.src < n) & ~del_mask
        kint = keep.astype(jnp.int32)
        kpos = jnp.cumsum(kint) - kint  # exclusive prefix: stable compaction
        csrc = (
            jnp.full(cap, n, jnp.int32)
            .at[jnp.where(keep, kpos, cap)]
            .set(g.src, mode="drop")
        )
        cdst = (
            jnp.full(cap, n, jnp.int32)
            .at[jnp.where(keep, kpos, cap)]
            .set(g.dst, mode="drop")
        )
        ne = kint.sum()
        gin = g.in_deg.at[jnp.where(found, d_c, n)].add(-1, mode="drop")
        gout = g.out_deg.at[jnp.where(found, s_c, n)].add(-1, mode="drop")

        # ELL mirror: mark the deleted slot per op, then stable-compact each
        # touched row exactly once (first op per row rewrites it)
        rows_g = eg.in_nbrs[d_c]  # [B, k_max] — pre-batch rows
        rhit = (rows_g == s_c[:, None]) & found[:, None]
        rfound = rhit.any(axis=1)
        kslot = jnp.argmax(rhit, axis=1)
        dmask = (
            jnp.zeros((n, k_max), bool)
            .at[jnp.where(rfound, d_c, n), jnp.where(rfound, kslot, 0)]
            .set(True, mode="drop")
        )
        same_row = (dst_b[None, :] == dst_b[:, None]) & rfound[None, :]
        urow = rfound & ((same_row.astype(jnp.int32) * tri).sum(1) == 0)
        live_r = (rows_g < n) & ~dmask[d_c]
        lint = live_r.astype(jnp.int32)
        new_slot = jnp.cumsum(lint, axis=1) - lint  # exclusive prefix/row
        b_rows = jnp.broadcast_to(
            jnp.arange(live_r.shape[0])[:, None], live_r.shape
        )
        comp = (
            jnp.full_like(rows_g, n)
            .at[b_rows, jnp.where(live_r, new_slot, k_max)]
            .set(rows_g, mode="drop")
        )
        table = eg.in_nbrs.at[jnp.where(urow, d_c, n)].set(comp, mode="drop")
        edeg = eg.in_deg.at[jnp.where(rfound, d_c, n)].add(-1, mode="drop")
    else:
        # insert-only batch (static fact): O(B) append, nothing to match
        found = jnp.zeros_like(valid)
        csrc, cdst, ne = g.src, g.dst, g.num_edges
        gin, gout = g.in_deg, g.out_deg
        table, edeg = eg.in_nbrs, eg.in_deg

    # ---- phase 2: inserts (append; coordinated room check) ----------------
    # ELL slot: row end + #same-dst predecessors in the batch.  Counting ALL
    # insert predecessors (not just applied ones) is exact: a predecessor
    # only fails if its slot/position already overflowed, in which case this
    # op's larger slot/position overflows too.
    same_d = (dst_b[None, :] == dst_b[:, None]) & is_ins[None, :]
    occ = (same_d.astype(jnp.int32) * tri).sum(1)
    slot = edeg[d_c] + occ
    ok_ell = is_ins & (slot < k_max)
    oint = ok_ell.astype(jnp.int32)
    cpos = ne + jnp.cumsum(oint) - oint
    ok = ok_ell & (cpos < cap)
    csrc = csrc.at[jnp.where(ok, cpos, cap)].set(s_c, mode="drop")
    cdst = cdst.at[jnp.where(ok, cpos, cap)].set(d_c, mode="drop")
    table = table.at[
        jnp.where(ok, d_c, n), jnp.where(ok, slot, 0)
    ].set(s_c, mode="drop")
    gin = gin.at[jnp.where(ok, d_c, n)].add(1, mode="drop")
    gout = gout.at[jnp.where(ok, s_c, n)].add(1, mode="drop")
    edeg = edeg.at[jnp.where(ok, d_c, n)].add(1, mode="drop")
    ne = ne + ok.sum()
    ovf = (is_ins & ~ok).any()

    applied = jnp.where(batch.insert, ok, found)
    any_applied = applied.any()
    g2 = g.replace(
        src=csrc, dst=cdst, in_deg=gin, out_deg=gout,
        num_edges=ne.astype(jnp.int32),
        version=_bump(g.version, any_applied),
        overflow=_sticky(g.overflow, ovf),
    )
    eg2 = eg.replace(
        in_nbrs=table, in_deg=edeg,
        version=_bump(eg.version, any_applied),
        overflow=_sticky(eg.overflow, ovf),
    )
    return g2, eg2, applied


apply_update_batch_jit = jax.jit(apply_update_batch)
"""Standalone jitted batch application (benchmarks measure this directly;
the epoch step traces ``apply_update_batch`` inline instead)."""


# ---------------------------------------------------------------------------
# Host-side regrow / compaction (the overflow recovery path)
# ---------------------------------------------------------------------------


def regrow(
    g: Graph,
    eg: EllGraph,
    *,
    capacity: int | None = None,
    k_max: int | None = None,
    growth: float = 2.0,
) -> tuple[Graph, EllGraph]:
    """Compact the live edges to host and rebuild both mirrors with headroom.

    The recovery path for the ``overflow`` flag: pulls the live edge list
    (O(m) host copy — amortized O(1) per insert under geometric growth),
    rebuilds COO with ``capacity`` (default: ``growth`` x old) and the ELL
    table with ``k_max`` (default: max(growth x old, max in-degree + 1)).
    ``version`` is preserved — regrowing is a representation change, not a
    graph change — and ``overflow`` is cleared on both mirrors.

    Note: rebuilding re-packs ELL rows in edge-list order, so walk sampling
    on the regrown graph draws a different (equally valid) neighbor
    permutation than the incrementally maintained table (docs/api.md:
    determinism is per-snapshot-representation, not per-logical-graph).
    """
    src, dst = graph_to_host_edges(g)
    n = g.n
    if capacity is None:
        capacity = max(int(g.capacity * growth), g.capacity + 1)
    if capacity < len(src):
        raise ValueError(f"capacity {capacity} < live edges {len(src)}")
    if k_max is None:
        deg_cap = int(np.bincount(dst, minlength=n).max()) if len(dst) else 0
        k_max = max(int(eg.k_max * growth), deg_cap + 1, 1)
    g2 = graph_from_edges(src, dst, n, capacity=capacity)
    eg2 = ell_from_edges(src, dst, n, k_max=k_max)
    return (
        g2.replace(version=g.version, overflow=jnp.asarray(False)),
        eg2.replace(version=eg.version, overflow=jnp.asarray(False)),
    )
