"""Dynamic graph maintenance — the index-free story of the paper.

ProbeSim precomputes nothing, so supporting a dynamic graph only requires
that the *graph representation itself* absorbs updates cheaply.  Both device
representations do:

* COO (``Graph``): insertion appends into the capacity-padded edge buffer
  (O(1) per edge); deletion swap-removes with the last live edge.
* ELL (``EllGraph``): insertion writes slot ``in_deg[dst]`` of row ``dst``;
  deletion swap-removes within the row.

All updates are functional (return new pytrees) and jit-compatible, so a
serving loop can interleave `update -> query -> update` entirely on device.
Contrast with the paper's index-based competitors (TSF: rebuild R_g one-way
graphs; SLING: full rebuild).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graph.structs import EllGraph, Graph

Array = jax.Array


@jax.jit
def _occurrence_index(x: Array) -> Array:
    """occ[i] = #{j < i : x[j] == x[i]} (O(B^2); update batches are small)."""
    eq = x[None, :] == x[:, None]
    tri = jnp.tril(jnp.ones_like(eq, dtype=jnp.int32), k=-1)
    return (eq.astype(jnp.int32) * tri).sum(axis=1)


def insert_edges(g: Graph, src: Array, dst: Array) -> Graph:
    """Append a batch of edges (src[i] -> dst[i]) to the COO buffer."""
    b = src.shape[0]
    pos = g.num_edges + jnp.arange(b, dtype=jnp.int32)
    ok = pos < g.capacity  # silently drop past capacity (callers size buffers)
    pos_c = jnp.where(ok, pos, g.capacity - 1)
    new_src = g.src.at[pos_c].set(jnp.where(ok, src, g.src[pos_c]))
    new_dst = g.dst.at[pos_c].set(jnp.where(ok, dst, g.dst[pos_c]))
    ones = ok.astype(jnp.int32)
    in_deg = g.in_deg.at[dst.clip(0, g.n - 1)].add(ones)
    out_deg = g.out_deg.at[src.clip(0, g.n - 1)].add(ones)
    return g.replace(
        src=new_src,
        dst=new_dst,
        in_deg=in_deg,
        out_deg=out_deg,
        num_edges=g.num_edges + ones.sum(),
    )


def insert_edges_ell(eg: EllGraph, src: Array, dst: Array) -> EllGraph:
    """Mirror insertion into the ELL in-neighbor table."""
    occ = _occurrence_index(dst)
    slot = eg.in_deg[dst] + occ
    ok = slot < eg.k_max
    slot_c = jnp.where(ok, slot, eg.k_max - 1)
    prev = eg.in_nbrs[dst, slot_c]
    table = eg.in_nbrs.at[dst, slot_c].set(jnp.where(ok, src, prev))
    in_deg = eg.in_deg.at[dst].add(ok.astype(jnp.int32))
    return eg.replace(in_nbrs=table, in_deg=in_deg)


def delete_edges(g: Graph, src: Array, dst: Array) -> Graph:
    """Swap-remove a batch of edges (sequential scan; batches are small)."""

    def body(carry, sd):
        cur_src, cur_dst, in_deg, out_deg, ne = carry
        s, d = sd
        match = (cur_src == s) & (cur_dst == d)
        found = match.any()
        pos = jnp.argmax(match)
        last = ne - 1
        # move the last live edge into pos, stamp sentinel at last
        moved_s = cur_src[last]
        moved_d = cur_dst[last]
        cur_src = cur_src.at[pos].set(jnp.where(found, moved_s, cur_src[pos]))
        cur_dst = cur_dst.at[pos].set(jnp.where(found, moved_d, cur_dst[pos]))
        cur_src = cur_src.at[last].set(jnp.where(found, g.n, cur_src[last]))
        cur_dst = cur_dst.at[last].set(jnp.where(found, g.n, cur_dst[last]))
        dec = found.astype(jnp.int32)
        in_deg = in_deg.at[d.clip(0, g.n - 1)].add(-dec)
        out_deg = out_deg.at[s.clip(0, g.n - 1)].add(-dec)
        return (cur_src, cur_dst, in_deg, out_deg, ne - dec), found

    init = (g.src, g.dst, g.in_deg, g.out_deg, g.num_edges)
    (new_src, new_dst, in_deg, out_deg, ne), _ = jax.lax.scan(
        body, init, (src, dst)
    )
    return g.replace(
        src=new_src, dst=new_dst, in_deg=in_deg, out_deg=out_deg, num_edges=ne
    )


def delete_edges_ell(eg: EllGraph, src: Array, dst: Array) -> EllGraph:
    """Swap-remove within ELL rows (sequential scan)."""

    def body(carry, sd):
        table, in_deg = carry
        s, d = sd
        row = table[d]
        match = row == s
        found = match.any()
        k = jnp.argmax(match)
        last = in_deg[d] - 1
        moved = row[last.clip(0, eg.k_max - 1)]
        row = row.at[k].set(jnp.where(found, moved, row[k]))
        row = row.at[last.clip(0, eg.k_max - 1)].set(
            jnp.where(found, eg.n, row[last.clip(0, eg.k_max - 1)])
        )
        table = table.at[d].set(row)
        in_deg = in_deg.at[d].add(-found.astype(jnp.int32))
        return (table, in_deg), found

    (table, in_deg), _ = jax.lax.scan(body, (eg.in_nbrs, eg.in_deg), (src, dst))
    return eg.replace(in_nbrs=table, in_deg=in_deg)
