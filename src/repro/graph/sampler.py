"""Layer-wise uniform neighbor sampler (GraphSAGE-style) for minibatch GNNs.

Host-side (numpy) sampling over in-CSR, emitting **fixed-shape** padded
blocks so the device step is jit-stable:

  frontier_0 = seeds                                  [B]
  hop h:   for every node in frontier_{h-1} sample fanout_h in-neighbors
           (with replacement if deg > fanout; sentinel-padded if deg == 0)
  edges_h: COO (src_pos, dst_pos) into the *node table*  [|frontier_{h-1}| * f_h]

The node table concatenates [seeds, hop1 samples, hop2 samples, ...]; node
features are gathered once by the data pipeline.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.graph.structs import CsrGraph


@dataclass
class SampledBlocks:
    nodes: np.ndarray  # int32 [N_table]  global node ids (sentinel = n)
    edge_src: list[np.ndarray]  # per hop: int32 positions into nodes
    edge_dst: list[np.ndarray]  # per hop: int32 positions into nodes
    edge_mask: list[np.ndarray]  # per hop: bool (live edge)
    seed_count: int
    frontier_sizes: list[int]


def block_shapes(batch: int, fanouts: tuple[int, ...]) -> dict:
    """Static shapes of the padded sample for (batch, fanouts)."""
    frontier = batch
    table = batch
    edges = []
    for f in fanouts:
        edges.append(frontier * f)
        table += frontier * f
        frontier = frontier * f
    return dict(table=table, edges=edges)


def sample_blocks(
    csr_in: CsrGraph,
    seeds: np.ndarray,
    fanouts: tuple[int, ...],
    rng: np.random.Generator,
) -> SampledBlocks:
    n = csr_in.n
    seeds = np.asarray(seeds, dtype=np.int32)
    batch = len(seeds)
    nodes = [seeds]
    pos_of_frontier = np.arange(batch, dtype=np.int32)
    frontier = seeds
    table_len = batch
    edge_src, edge_dst, edge_mask = [], [], []
    sizes = [batch]
    for f in fanouts:
        fr = np.clip(frontier, 0, n - 1).astype(np.int64)
        alive = frontier < n  # sentinel nodes from dead branches sample nothing
        deg = (csr_in.indptr[fr + 1] - csr_in.indptr[fr]).astype(np.int64)
        deg = np.where(alive, deg, 0)
        # sample f in-neighbors per frontier node (with replacement)
        r = rng.integers(0, 1 << 62, size=(len(frontier), f))
        idx = csr_in.indptr[fr][:, None] + (r % np.maximum(deg, 1)[:, None])
        idx = np.minimum(idx, max(csr_in.m - 1, 0))  # deg==0 rows are masked
        samp = csr_in.indices[idx].astype(np.int32)  # [F, f]
        live = (deg > 0)[:, None] & np.ones((1, f), dtype=bool)
        samp = np.where(live, samp, n)
        new_pos = table_len + np.arange(samp.size, dtype=np.int32)
        # edge: sampled in-neighbor (src) -> frontier node (dst)
        edge_src.append(new_pos)
        edge_dst.append(np.repeat(pos_of_frontier, f).astype(np.int32))
        edge_mask.append(live.reshape(-1))
        nodes.append(samp.reshape(-1))
        pos_of_frontier = new_pos
        frontier = samp.reshape(-1)
        table_len += samp.size
        sizes.append(samp.size)
    return SampledBlocks(
        nodes=np.concatenate(nodes).astype(np.int32),
        edge_src=edge_src,
        edge_dst=edge_dst,
        edge_mask=edge_mask,
        seed_count=batch,
        frontier_sizes=sizes,
    )
