"""Synthetic graph generators (host-side, numpy).

The container is offline, so the paper's SNAP/LAW datasets are replaced by
synthetic graphs with matched (n, m, degree-skew): a discrete power-law
configuration model for the web/social graphs and Erdos-Renyi for controls.
Also ships the paper's Figure-1 toy graph, reconstructed exactly from the
running example in Section 3.2 (verified against Table 2 to 5e-4, which is
Table-2's own rounding).
"""
from __future__ import annotations

import numpy as np

TOY_NODES = "abcdefgh"

# Directed edges of the paper's Figure-1 toy graph.  All but one edge are
# forced by the worked PROBE example (scores 0.167/0.5/0.25/0.115/0.153/...);
# the remaining in-neighbor of b is pinned to `e` by matching Table 2 with
# the Power Method at c = 0.25.
TOY_EDGES = [
    ("a", "b"), ("a", "c"),
    ("b", "a"), ("b", "c"), ("b", "d"), ("b", "e"),
    ("c", "a"), ("c", "f"), ("c", "g"), ("c", "h"),
    ("d", "f"), ("d", "g"), ("d", "h"),
    ("e", "b"), ("e", "f"), ("e", "g"), ("e", "h"),
    ("g", "c"), ("g", "e"),
    ("h", "f"),
]

# Table 2 of the paper: SimRank of every node w.r.t. a, decay c = 0.25.
TOY_TABLE2 = {
    "a": 1.0, "b": 0.0096, "c": 0.049, "d": 0.131,
    "e": 0.070, "f": 0.041, "g": 0.051, "h": 0.051,
}


def toy_graph() -> tuple[np.ndarray, np.ndarray, int]:
    """The paper's Figure-1 graph as (src, dst, n)."""
    idx = {ch: i for i, ch in enumerate(TOY_NODES)}
    src = np.array([idx[s] for s, _ in TOY_EDGES], dtype=np.int32)
    dst = np.array([idx[d] for _, d in TOY_EDGES], dtype=np.int32)
    return src, dst, len(TOY_NODES)


def _dedupe(src: np.ndarray, dst: np.ndarray, n: int):
    """Remove self-loops and duplicate edges."""
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src.astype(np.int64) * n + dst
    _, first = np.unique(key, return_index=True)
    first.sort()
    return src[first], dst[first]


def powerlaw_graph(
    n: int,
    m: int,
    seed: int = 0,
    alpha: float = 2.1,
    max_deg: int | None = None,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Directed power-law graph via a Zipf configuration model.

    Node popularity ~ Zipf(alpha); each edge picks (src, dst) independently
    from the popularity distribution (dst) and uniform (src), giving the
    heavy-tailed *in*-degree profile that dominates SimRank workloads
    (web graphs / social follows).  Self-loops and duplicates are dropped, so
    the realized m is slightly below the request.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    perm = rng.permutation(n)  # decouple popularity from node id
    # oversample to compensate dedup loss
    m_try = int(m * 1.15) + 16
    dst = perm[rng.choice(n, size=m_try, p=probs)]
    src = rng.integers(0, n, size=m_try)
    src, dst = _dedupe(src.astype(np.int32), dst.astype(np.int32), n)
    if max_deg is not None:
        # clip in-degree at max_deg (keep first max_deg edges per dst)
        order = np.argsort(dst, kind="stable")
        dsts = dst[order]
        start = np.searchsorted(dsts, np.arange(n))
        within = np.arange(len(dsts)) - start[dsts]
        keep = order[within < max_deg]
        keep.sort()
        src, dst = src[keep], dst[keep]
    if len(src) > m:
        src, dst = src[:m], dst[:m]
    return src.astype(np.int32), dst.astype(np.int32), n


def erdos_renyi_graph(
    n: int, m: int, seed: int = 0
) -> tuple[np.ndarray, np.ndarray, int]:
    rng = np.random.default_rng(seed)
    m_try = int(m * 1.1) + 16
    src = rng.integers(0, n, size=m_try, dtype=np.int64)
    dst = rng.integers(0, n, size=m_try, dtype=np.int64)
    src, dst = _dedupe(src.astype(np.int32), dst.astype(np.int32), n)
    if len(src) > m:
        src, dst = src[:m], dst[:m]
    return src.astype(np.int32), dst.astype(np.int32), n


def bipartite_graph(
    n_users: int, n_items: int, m: int, seed: int = 0, alpha: float = 1.8
) -> tuple[np.ndarray, np.ndarray, int]:
    """User->item bipartite interaction graph (recsys retrieval example).

    Nodes [0, n_users) are users, [n_users, n_users+n_items) items.  Edges run
    both directions (u->i and i->u) so SimRank's in-neighbor recursion sees
    co-consumption structure.
    """
    rng = np.random.default_rng(seed)
    n = n_users + n_items
    ranks = np.arange(1, n_items + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    m_half = m // 2
    items = rng.choice(n_items, size=int(m_half * 1.2) + 16, p=probs) + n_users
    users = rng.integers(0, n_users, size=len(items))
    u, i = _dedupe(users.astype(np.int32), items.astype(np.int32), n)
    if len(u) > m_half:
        u, i = u[:m_half], i[:m_half]
    src = np.concatenate([u, i])
    dst = np.concatenate([i, u])
    return src.astype(np.int32), dst.astype(np.int32), n


# Synthetic stand-ins for the paper's datasets (Table 3), scaled to run on
# this container's CPU for benchmarks; the dry-run exercises full scale.
PAPER_DATASETS = {
    # name: (n, m, kind)   -- small graphs (ground truth via Power Method)
    "wiki-vote": (7_155, 103_689, "powerlaw"),
    "hepth": (9_877, 25_998, "er"),
    "as": (26_475, 106_762, "powerlaw"),
    "hepph": (34_546, 421_578, "powerlaw"),
    # large graphs, CPU-scaled by default factor in loaders
    "livejournal": (4_847_571, 68_993_773, "powerlaw"),
    "it-2004": (41_291_594, 1_150_725_436, "powerlaw"),
    "twitter": (41_652_230, 1_468_365_182, "powerlaw"),
    "friendster": (68_349_466, 2_586_147_869, "powerlaw"),
}


def paper_dataset(
    name: str, scale: float = 1.0, seed: int = 0
) -> tuple[np.ndarray, np.ndarray, int]:
    """Synthetic stand-in for a paper dataset, optionally down-scaled."""
    n, m, kind = PAPER_DATASETS[name]
    n = max(int(n * scale), 64)
    m = max(int(m * scale), 256)
    if kind == "er":
        return erdos_renyi_graph(n, m, seed=seed)
    return powerlaw_graph(n, m, seed=seed)
