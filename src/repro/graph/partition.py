"""Graph partitioning for the production mesh.

Model-axis layout (used by distributed ProbeSim and full-graph GNNs):

* nodes are range-partitioned into ``num_shards`` equal blocks of
  ``n_pad / num_shards`` rows (n padded up);
* each shard owns the **in-edges of its node block** (destination
  partitioning): a propagation level gathers remote source scores
  (all-gather over `model`) and scatters strictly locally, so the only
  collective per level is the source-score all-gather — analyzed in
  EXPERIMENTS §Roofline and attacked in §Perf with a ppermute ring.

Edge shards are padded to the max shard size so the result is a rectangular
[S, E_shard] array suitable for shard_map.
"""
from __future__ import annotations

import numpy as np


def pad_to_multiple(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def partition_edges_by_dst(
    src: np.ndarray,
    dst: np.ndarray,
    n: int,
    num_shards: int,
) -> dict:
    """Destination-partitioned edge shards.

    Returns dict with:
      src_sh   int32 [S, E]  global source ids (padding = n_pad)
      dst_sh   int32 [S, E]  *local* destination ids in [0, rows) (padding = rows)
      counts   int64 [S]     live edges per shard
      n_pad    int           padded node count
      rows     int           rows per shard (= n_pad / S)
    """
    n_pad = pad_to_multiple(n, num_shards)
    rows = n_pad // num_shards
    shard_of = dst // rows
    order = np.argsort(shard_of, kind="stable")
    src_o, dst_o = src[order], dst[order]
    shard_o = shard_of[order]
    counts = np.bincount(shard_o, minlength=num_shards).astype(np.int64)
    e_max = int(counts.max()) if len(src) else 1
    src_sh = np.full((num_shards, e_max), n_pad, dtype=np.int32)
    dst_sh = np.full((num_shards, e_max), rows, dtype=np.int32)
    starts = np.zeros(num_shards + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for s in range(num_shards):
        lo, hi = starts[s], starts[s + 1]
        src_sh[s, : hi - lo] = src_o[lo:hi]
        dst_sh[s, : hi - lo] = dst_o[lo:hi] - s * rows
    return dict(src_sh=src_sh, dst_sh=dst_sh, counts=counts, n_pad=n_pad, rows=rows)


def partition_nodes(
    values: np.ndarray, num_shards: int, fill=0
) -> np.ndarray:
    """Split a per-node array into [S, rows] blocks (padding with ``fill``)."""
    n = values.shape[0]
    n_pad = pad_to_multiple(n, num_shards)
    rows = n_pad // num_shards
    out = np.full((n_pad,) + values.shape[1:], fill, dtype=values.dtype)
    out[:n] = values
    return out.reshape((num_shards, rows) + values.shape[1:])


def partition_edges_2d(
    src: np.ndarray,
    dst: np.ndarray,
    n: int,
    num_shards: int,
) -> dict:
    """2-D edge partition for the ring-SpMM (§Perf hillclimb).

    Bucket (dst_shard, src_block): edges whose destination lives in
    dst_shard's rows and whose source lives in src_block's rows.  The ring
    schedule processes bucket (me, r) while the rows of block r are resident,
    then ppermutes the row block — collective volume equals one full frontier
    pass per level but overlaps with the per-bucket gather/scatter compute.

    Returns:
      src_sh  int32 [S, S, E]  source ids relative to their src block
      dst_sh  int32 [S, S, E]  destination ids relative to the dst shard
      n_pad, rows, e_max
    """
    n_pad = pad_to_multiple(n, num_shards)
    rows = n_pad // num_shards
    dshard = dst // rows
    sblock = src // rows
    key = dshard.astype(np.int64) * num_shards + sblock
    order = np.argsort(key, kind="stable")
    src_o, dst_o, key_o = src[order], dst[order], key[order]
    counts = np.bincount(key_o, minlength=num_shards * num_shards)
    e_max = max(int(counts.max()), 8)
    e_max = pad_to_multiple(e_max, 8)
    src_sh = np.full((num_shards, num_shards, e_max), rows, dtype=np.int32)
    dst_sh = np.full((num_shards, num_shards, e_max), rows, dtype=np.int32)
    starts = np.zeros(num_shards * num_shards + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for ds in range(num_shards):
        for sb in range(num_shards):
            k = ds * num_shards + sb
            lo, hi = starts[k], starts[k + 1]
            src_sh[ds, sb, : hi - lo] = src_o[lo:hi] - sb * rows
            dst_sh[ds, sb, : hi - lo] = dst_o[lo:hi] - ds * rows
    return dict(src_sh=src_sh, dst_sh=dst_sh, n_pad=n_pad, rows=rows,
                e_max=e_max, counts=counts.reshape(num_shards, num_shards))


def partition_ops_by_dst(
    dst: np.ndarray, n_pad: int, num_shards: int
) -> tuple[np.ndarray, np.ndarray]:
    """Re-partition a stream of edge ops onto destination shards.

    The dynamic-update analogue of :func:`partition_edges_by_dst`: maps
    each op to ``shard = dst // rows`` under the same range partition the
    static build used, so shard-wise update application lands every op on
    the shard that owns its destination row block.

    Returns ``(shard_of [len(dst)], shard_ids)`` — the per-op shard and
    the sorted unique shards touched (iterate those to apply per shard).
    """
    rows = n_pad // num_shards
    shard_of = np.asarray(dst) // rows
    return shard_of, np.unique(shard_of)


def edge_balance_stats(counts: np.ndarray) -> dict:
    """Load-balance diagnostics for a destination partition."""
    c = np.asarray(counts, dtype=np.float64)
    return dict(
        max=float(c.max()),
        mean=float(c.mean()),
        imbalance=float(c.max() / max(c.mean(), 1.0)),
    )
