"""Shared model building blocks (pure-function style, explicit param pytrees).

No flax/haiku in this container — modules are (init, apply) function pairs
over nested dicts.  Sharding is expressed with logical axes resolved against
the active mesh:  "dp" -> ("pod","data") folded, "tp" -> "model".
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils.jaxcompat import get_abstract_mesh

Array = jax.Array


# ---------------------------------------------------------------------------
# Logical sharding
# ---------------------------------------------------------------------------


def mesh_axis_names() -> tuple[str, ...]:
    mesh = get_abstract_mesh()
    return tuple(mesh.axis_names) if mesh is not None and not mesh.empty else ()


def resolve_axis(logical: str | None):
    """Map a logical axis name to concrete mesh axes (None if mesh lacks it)."""
    names = mesh_axis_names()
    if logical is None:
        return None
    if logical == "dp":
        axes = tuple(a for a in ("pod", "data") if a in names)
        return axes if axes else None
    if logical == "tp":
        return "model" if "model" in names else None
    raise ValueError(logical)


def logical_spec(*logical: str | None) -> P:
    return P(*[resolve_axis(a) for a in logical])


def axis_size(logical: str) -> int:
    """Product of mesh extents behind a logical axis (1 if absent)."""
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    concrete = resolve_axis(logical)
    if concrete is None:
        return 1
    if isinstance(concrete, tuple):
        out = 1
        for a in concrete:
            out *= mesh.shape[a]
        return out
    return mesh.shape[concrete]


def tp_if_divisible(dim: int):
    """'model' iff dim divides evenly over the TP extent (else replicate)."""
    return resolve_axis("tp") if dim % max(axis_size("tp"), 1) == 0 else None


def dp_if_divisible(dim: int):
    return resolve_axis("dp") if dim % max(axis_size("dp"), 1) == 0 else None


def constrain(x: Array, *logical: str | None) -> Array:
    """with_sharding_constraint on logical axes; no-op without a mesh.

    Divisibility-guarded: a dim that does not divide its axis extent is left
    unconstrained (e.g. 8 KV heads under 16-way TP)."""
    if not mesh_axis_names():
        return x
    mesh = get_abstract_mesh()
    spec = []
    for dim, name in zip(x.shape, logical):
        ax = resolve_axis(name)
        if ax is None:
            spec.append(None)
            continue
        extent = 1
        for a in ax if isinstance(ax, tuple) else (ax,):
            extent *= mesh.shape[a]
        spec.append(ax if dim % extent == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# Initializers / layers
# ---------------------------------------------------------------------------


def dense_init(key: Array, d_in: int, d_out: int, dtype, scale: float | None = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)


def rms_norm(x: Array, gamma: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float) -> Array:
    exps = jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head
    return 1.0 / (theta**exps)  # [d_head/2]


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., S, H, dh] (dh even); positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # [dh/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,dh/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def count_params(params: Any) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


def cast_tree(params: Any, dtype) -> Any:
    return jax.tree_util.tree_map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p,
        params,
    )
