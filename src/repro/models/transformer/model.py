"""LM transformer: scan-over-layers, GQA or MLA attention, dense or MoE FFN.

Layers are grouped into homogeneous *stages* (e.g. DeepSeek-V2: 1 dense
layer then 26 MoE layers) so each stage scans over stacked params — keeping
the HLO size O(1) in depth, which matters for 126-layer dry-run compiles.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro.models.common as cm
from repro.models.common import constrain, rms_norm
from repro.models.transformer import attention as attn
from repro.models.transformer import moe as moe_mod

Array = jax.Array


def _dtype(name: str):
    return dict(float32=jnp.float32, bfloat16=jnp.bfloat16, float16=jnp.float16)[
        name
    ]


def stages_of(cfg) -> list[tuple[int, str]]:
    if cfg.moe is None:
        return [(cfg.n_layers, "dense")]
    fd = cfg.moe.first_dense_layers
    out = []
    if fd:
        out.append((fd, "dense"))
    out.append((cfg.n_layers - fd, "moe"))
    return out


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(key: Array, cfg, kind: str, dtype) -> dict:
    k_attn, k_ffn, k_n1, k_n2 = jax.random.split(key, 4)
    if cfg.attention == "mla":
        a = attn.init_mla(k_attn, cfg, dtype)
    else:
        a = attn.init_gqa(k_attn, cfg, dtype)
    if kind == "moe":
        f = moe_mod.init_moe(k_ffn, cfg, dtype)
    else:
        d_ff = (
            cfg.moe.d_ff_dense
            if (cfg.moe is not None and cfg.moe.d_ff_dense)
            else cfg.d_ff
        )
        ks = jax.random.split(k_ffn, 3)
        f = dict(
            w_gate=cm.dense_init(ks[0], cfg.d_model, d_ff, dtype),
            w_up=cm.dense_init(ks[1], cfg.d_model, d_ff, dtype),
            w_down=cm.dense_init(ks[2], d_ff, cfg.d_model, dtype),
        )
    return dict(
        attn=a,
        ffn=f,
        norm_attn=jnp.ones((cfg.d_model,), dtype),
        norm_ffn=jnp.ones((cfg.d_model,), dtype),
    )


def init_lm(key: Array, cfg) -> dict:
    dtype = _dtype(cfg.param_dtype)
    k_embed, k_head, *_ = jax.random.split(key, 4)
    params: dict[str, Any] = dict(
        embed=(jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) * 0.02).astype(
            dtype
        ),
        final_norm=jnp.ones((cfg.d_model,), dtype),
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.dense_init(k_head, cfg.d_model, cfg.vocab, dtype)
    for si, (depth, kind) in enumerate(stages_of(cfg)):
        blocks = []
        for li in range(depth):
            kb = jax.random.fold_in(key, si * 1000 + li)
            blocks.append(_init_block(kb, cfg, kind, dtype))
        params[f"stage{si}"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *blocks
        )
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _block_forward(blk: dict, x: Array, positions: Array, cfg, kind: str,
                   use_kernel: bool) -> tuple[Array, Array]:
    h = rms_norm(x, blk["norm_attn"], cfg.norm_eps)
    if cfg.attention == "mla":
        a = attn.mla_forward(blk["attn"], h, positions, cfg, use_kernel=use_kernel)
    else:
        a = attn.gqa_forward(blk["attn"], h, positions, cfg, use_kernel=use_kernel)
    x = x + a
    h = rms_norm(x, blk["norm_ffn"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if kind == "moe":
        f, aux = moe_mod.moe_forward(blk["ffn"], h, cfg)
    else:
        f = cm.swiglu(h, blk["ffn"]["w_gate"], blk["ffn"]["w_up"],
                      blk["ffn"]["w_down"])
    x = x + f
    x = constrain(x, "dp", None, None)
    return x, aux


def lm_forward(
    params: dict,
    tokens: Array,  # int32 [B, S]
    cfg,
    *,
    use_kernel: bool = False,
    seq_shard: bool = False,
    last_only: bool = False,
) -> tuple[Array, Array]:
    """Returns (logits [B, S, V] fp32, aux_loss); last_only -> [B, 1, V]."""
    cdt = _dtype(cfg.compute_dtype)
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cdt)
    x = constrain(x, "dp", "tp" if seq_shard else None, None)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    aux_total = jnp.zeros((), jnp.float32)

    for si, (depth, kind) in enumerate(stages_of(cfg)):
        stacked = params[f"stage{si}"]

        def body(x, blk, kind=kind):
            blk = cm.cast_tree(blk, cdt) if cfg.param_dtype != cfg.compute_dtype else blk
            return _block_forward(blk, x, positions, cfg, kind, use_kernel)

        if cfg.remat:
            body = jax.checkpoint(body)

        def scan_fn(x, blk):
            x, aux = body(x, blk)
            return x, aux

        if cfg.scan_layers:
            x, auxs = jax.lax.scan(scan_fn, x, stacked)
            aux_total = aux_total + auxs.sum()
        else:  # unrolled: every layer visible to cost_analysis (dry-run)
            for li in range(depth):
                blk = jax.tree_util.tree_map(lambda t: t[li], stacked)
                x, aux = scan_fn(x, blk)
                aux_total = aux_total + aux

    if last_only:
        x = x[:, -1:, :]  # serving: only the next-token logits matter
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head", None)
    if head is None:
        head = params["embed"].T
    ldt = _dtype(getattr(cfg, "logits_dtype", "float32"))
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cdt)).astype(ldt)
    logits = constrain(logits, "dp", None, "tp")
    return logits, aux_total


def lm_loss(params: dict, batch: dict, cfg, *, use_kernel: bool = False):
    """Causal-LM cross entropy.

    batch: either {tokens [B, S+1]} (shift internally) or
    {tokens [B, S], targets [B, S]} (pre-shifted by the data pipeline).
    """
    tokens = batch["tokens"]
    if "targets" in batch:
        inp, tgt = tokens, batch["targets"]
    else:
        inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits, aux = lm_forward(params, inp, cfg, use_kernel=use_kernel)
    # f32 accumulation fuses into the reduce; bf16 logits never hit HBM twice
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = (logz - gold.astype(jnp.float32)).mean()
    return nll + aux, dict(nll=nll, aux=aux)


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, s_max: int) -> list:
    cdt = _dtype(cfg.compute_dtype)
    if cfg.attention == "mla":
        one = lambda: attn.mla_init_cache(cfg, batch, s_max, cdt)
    else:
        one = lambda: attn.gqa_init_cache(cfg, batch, s_max, cdt)
    caches = []
    for si, (depth, kind) in enumerate(stages_of(cfg)):
        caches.append(
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *[one() for _ in range(depth)])
        )
    return caches


def lm_decode_step(
    params: dict,
    caches: list,
    tokens: Array,  # int32 [B] current token
    position: Array,  # int32 [B] its position
    cfg,
) -> tuple[list, Array]:
    """One decode step; returns (new caches, logits [B, V])."""
    cdt = _dtype(cfg.compute_dtype)
    x = params["embed"][tokens][:, None, :].astype(cdt)  # [B, 1, D]
    new_caches = []
    for si, (depth, kind) in enumerate(stages_of(cfg)):
        stacked = params[f"stage{si}"]
        cache = caches[si]

        def step(x, blk_cache, kind=kind):
            blk, c = blk_cache
            blk = cm.cast_tree(blk, cdt) if cfg.param_dtype != cfg.compute_dtype else blk
            h = rms_norm(x, blk["norm_attn"], cfg.norm_eps)
            if cfg.attention == "mla":
                c, a = attn.mla_decode(blk["attn"], c, h, position, cfg)
            else:
                c, a = attn.gqa_decode(blk["attn"], c, h, position, cfg)
            x = x + a
            h = rms_norm(x, blk["norm_ffn"], cfg.norm_eps)
            if kind == "moe":
                f, _ = moe_mod.moe_forward(blk["ffn"], h, cfg)
            else:
                f = cm.swiglu(h, blk["ffn"]["w_gate"], blk["ffn"]["w_up"],
                              blk["ffn"]["w_down"])
            return x + f, c

        if cfg.scan_layers:
            x, new_cache = jax.lax.scan(step, x, (stacked, cache))
        else:
            outs = []
            for li in range(depth):
                blk = jax.tree_util.tree_map(lambda t: t[li], stacked)
                c = jax.tree_util.tree_map(lambda t: t[li], cache)
                x, c_new = step(x, (blk, c))
                outs.append(c_new)
            new_cache = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *outs
            )
        new_caches.append(new_cache)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"].T)
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cdt))[:, 0]
    return new_caches, logits.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Sharding specs
# ---------------------------------------------------------------------------


def param_specs(params: dict, cfg) -> dict:
    """PartitionSpec tree: TP on heads/ffn/experts ('model'), FSDP on 'dp'.

    Every rule is divisibility-guarded: a dim that does not divide the mesh
    extent falls back to replication on that axis (e.g. 8 KV heads on a
    16-way model axis -> KV projections replicated, the standard GQA layout
    when Hkv < TP; 60 Qwen experts on 16-way EP -> shard the expert *matmul*
    dims instead)."""
    dp = cm.resolve_axis("dp")

    def dpd(dim: int):
        return cm.dp_if_divisible(dim)

    def tpd(dim: int):
        return cm.tp_if_divisible(dim)

    def spec_for(path: tuple, leaf) -> P:
        names = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
        key = names[-1] if names else None
        pstr = "/".join(str(x) for x in names)
        nd = leaf.ndim
        stacked = pstr.startswith("stage")  # leading layer dim L
        lead = (None,) if stacked else ()
        sh = leaf.shape[1:] if stacked else leaf.shape
        if key in ("embed",):
            return P(tpd(sh[0]), dpd(sh[1]))
        if key in ("lm_head",):
            return P(dpd(sh[0]), tpd(sh[1]))
        if key in ("final_norm", "norm_attn", "norm_ffn"):
            return P(*lead, None)
        if "shared" in pstr and key in ("w_gate", "w_up"):
            return P(*lead, dpd(sh[0]), tpd(sh[1]))
        if "shared" in pstr and key == "w_down":
            return P(*lead, tpd(sh[0]), dpd(sh[1]))
        if key in ("w_gate", "w_up", "w_down") and nd == (4 if stacked else 3):
            # MoE experts [L, E, d, f]: expert-parallel on model when E
            # divides; otherwise TP inside the expert matmuls
            e_ax = tpd(sh[0])
            if e_ax is not None:
                return P(*lead, e_ax, dpd(sh[1]), None)
            if key == "w_down":  # [E, f, d]
                return P(*lead, None, tpd(sh[1]), dpd(sh[2]))
            return P(*lead, None, dpd(sh[1]), tpd(sh[2]))
        if key in ("w_gate", "w_up"):  # dense mlp [L, d, f]
            return P(*lead, dpd(sh[0]), tpd(sh[1]))
        if key == "w_down":
            return P(*lead, tpd(sh[0]), dpd(sh[1]))
        if key == "router":
            return P(*lead, dpd(sh[0]), None)
        # attention
        if key in ("wq", "wk", "wv"):  # [L, d, H, dh]
            return P(*lead, dpd(sh[0]), tpd(sh[1]), None)
        if key == "wo":  # [L, H, dh, d]
            return P(*lead, tpd(sh[0]), None, dpd(sh[2]))
        if key in ("w_dkv", "w_kr", "w_dq"):  # [L, d, r]
            return P(*lead, dpd(sh[0]), None)
        if key in ("w_uk", "w_uv", "w_uq"):  # [L, r, H, dh]
            return P(*lead, None, tpd(sh[1]), None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def cache_specs(caches, cfg) -> Any:
    dp = cm.resolve_axis("dp")

    def spec_for(path: tuple, leaf):
        key = getattr(path[-1], "key", None)
        if key in ("k", "v"):  # [L, B, S, Hkv, dh]
            hkv_ax = cm.tp_if_divisible(leaf.shape[3])
            if hkv_ax is not None:
                return P(None, cm.dp_if_divisible(leaf.shape[1]), None, hkv_ax, None)
            return P(None, cm.dp_if_divisible(leaf.shape[1]), None, None,
                     cm.tp_if_divisible(leaf.shape[4]))
        if key == "c_kv":  # [L, B, S, r] latent is shared across heads
            return P(None, cm.dp_if_divisible(leaf.shape[1]), None,
                     cm.tp_if_divisible(leaf.shape[3]))
        if key == "k_rope":  # [L, B, S, dr]
            return P(None, cm.dp_if_divisible(leaf.shape[1]), None, None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_for, caches)
