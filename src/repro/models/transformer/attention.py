"""Attention: GQA (llama/yi/qwen) and MLA (DeepSeek-V2), train + decode.

Decode paths are cache-resident:
* GQA caches k/v per kv-head: [B, S_max, Hkv, dh].
* MLA caches the *compressed latent* c_kv [B, S_max, r] plus the shared
  rope key [B, S_max, d_rope] — the whole point of MLA — and runs decode in
  the absorbed form (q projected into latent space; values expanded only
  after the attention-weighted latent sum).

The Pallas flash-attention kernel is switchable via ``use_kernel`` (training
/prefill shapes); the pure-jnp path is the oracle and the dry-run path
(Pallas custom-calls do not lower to the CPU dry-run backend).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, constrain

Array = jax.Array

NEG_INF = -1e30


def _causal_mask(s_q: int, s_k: int, offset: int = 0) -> Array:
    """[s_q, s_k] True where query i may attend key j (j <= i + offset)."""
    qi = jnp.arange(s_q)[:, None] + offset
    kj = jnp.arange(s_k)[None, :]
    return kj <= qi


def sdpa(
    q: Array,  # [B, S, H, dh]
    k: Array,  # [B, T, Hkv, dh]
    v: Array,  # [B, T, Hkv, dhv]
    *,
    causal_offset: int | None = 0,
    kv_len: Array | None = None,
    scale: float | None = None,
    use_kernel: bool = False,
    chunk_q: int = 1024,
    unroll_chunks: bool = False,
    probs_dtype=jnp.float32,
) -> Array:
    """Grouped-query scaled-dot-product attention (pure jnp or Pallas).

    Long sequences (S > chunk_q) scan over query chunks so the peak logits
    buffer is [*, chunk_q, T] instead of [*, S, T] — the pure-jnp analogue of
    the flash kernel's tiling (32k prefill would otherwise need an S x T
    buffer: 32768^2 x heads x 4B per device)."""
    B, S, H, dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    if use_kernel and causal_offset is not None and S > 1:
        from repro.kernels.flash_attention import ops as fa_ops

        return fa_ops.flash_attention(q, k, v, causal=True, scale=scale)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def block(q_blk: Array, row0) -> Array:
        # q_blk: [B, bq, H, dh]; rows are global positions row0..row0+bq
        bq = q_blk.shape[1]
        qg = q_blk.reshape(B, bq, Hkv, group, dh).astype(jnp.float32)
        logits = jnp.einsum("bsngd,btnd->bngst", qg, kf) * scale
        if causal_offset is not None:
            rows = row0 + jnp.arange(bq)[:, None] + causal_offset
            cols = jnp.arange(T)[None, :]
            logits = jnp.where((cols <= rows)[None, None, None], logits,
                               NEG_INF)
        if kv_len is not None:
            valid = jnp.arange(T)[None, :] < kv_len[:, None]  # [B, T]
            logits = jnp.where(valid[:, None, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(probs_dtype)
        out = jnp.einsum("bngst,btnd->bsngd", probs,
                         vf.astype(probs_dtype)).astype(jnp.float32)
        return out.reshape(B, bq, H, v.shape[-1]).astype(q.dtype)

    if S <= chunk_q or S % chunk_q != 0:
        return block(q, 0)

    n_blocks = S // chunk_q
    qb = q.reshape(B, n_blocks, chunk_q, H, dh).transpose(1, 0, 2, 3, 4)

    if unroll_chunks:  # dry-run variants: every chunk visible to cost_analysis
        outs = jnp.stack([block(qb[i], i * chunk_q) for i in range(n_blocks)])
    else:
        def scan_fn(_, inp):
            i, q_blk = inp
            return None, block(q_blk, i * chunk_q)

        _, outs = jax.lax.scan(scan_fn, None, (jnp.arange(n_blocks), qb))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_gqa(key: Array, cfg, dtype) -> dict:
    import repro.models.common as cm

    d, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    return dict(
        wq=cm.dense_init(ks[0], d, H * dh, dtype).reshape(d, H, dh),
        wk=cm.dense_init(ks[1], d, Hkv * dh, dtype).reshape(d, Hkv, dh),
        wv=cm.dense_init(ks[2], d, Hkv * dh, dtype).reshape(d, Hkv, dh),
        wo=cm.dense_init(ks[3], H * dh, d, dtype).reshape(H, dh, d),
    )


def gqa_forward(
    p: dict,
    x: Array,  # [B, S, D]
    positions: Array,  # [B, S]
    cfg,
    *,
    use_kernel: bool = False,
) -> Array:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    o = sdpa(q, k, v, causal_offset=0, use_kernel=use_kernel,
             unroll_chunks=not getattr(cfg, "scan_layers", True),
             probs_dtype=jnp.bfloat16
             if cfg.attn_probs_dtype == "bfloat16" else jnp.float32)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def gqa_init_cache(cfg, batch: int, s_max: int, dtype) -> dict:
    Hkv, dh = cfg.n_kv_heads, cfg.d_head
    return dict(
        k=jnp.zeros((batch, s_max, Hkv, dh), dtype),
        v=jnp.zeros((batch, s_max, Hkv, dh), dtype),
    )


def gqa_decode(
    p: dict,
    cache: dict,
    x: Array,  # [B, 1, D]
    position: Array,  # [B] current position (== cache fill length)
    cfg,
) -> tuple[dict, Array]:
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, position[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, position[:, None], cfg.rope_theta)
    # in-place cache update at position
    bidx = jnp.arange(B)
    k = cache["k"].at[bidx, position].set(k_new[:, 0])
    v = cache["v"].at[bidx, position].set(v_new[:, 0])
    o = sdpa(q, k, v, causal_offset=None, kv_len=position + 1)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return dict(k=k, v=v), out


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key: Array, cfg, dtype) -> dict:
    import repro.models.common as cm

    d = cfg.d_model
    H = cfg.n_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = dict(
        # down-projection to the kv latent + shared rope key
        w_dkv=cm.dense_init(ks[0], d, r, dtype),
        w_kr=cm.dense_init(ks[1], d, dr, dtype),
        # up-projections from latent
        w_uk=cm.dense_init(ks[2], r, H * dn, dtype).reshape(r, H, dn),
        w_uv=cm.dense_init(ks[3], r, H * dv, dtype).reshape(r, H, dv),
        wo=cm.dense_init(ks[4], H * dv, d, dtype).reshape(H, dv, d),
    )
    if cfg.q_lora_rank:
        p["w_dq"] = cm.dense_init(ks[5], d, cfg.q_lora_rank, dtype)
        p["w_uq"] = cm.dense_init(
            ks[6], cfg.q_lora_rank, H * (dn + dr), dtype
        ).reshape(cfg.q_lora_rank, H, dn + dr)
    else:
        p["wq"] = cm.dense_init(ks[7], d, H * (dn + dr), dtype).reshape(
            d, H, dn + dr
        )
    return p


def _mla_q(p: dict, x: Array, positions: Array, cfg) -> tuple[Array, Array]:
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        ql = jnp.einsum("bsd,dr->bsr", x, p["w_dq"])
        q = jnp.einsum("bsr,rhk->bshk", ql, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(
    p: dict,
    x: Array,
    positions: Array,
    cfg,
    *,
    use_kernel: bool = False,
) -> Array:
    """Training / prefill MLA: latent is expanded to per-head k, v."""
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_nope, q_rope = _mla_q(p, x, positions, cfg)
    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])  # [B, S, r]
    k_rope = apply_rope(
        jnp.einsum("bsd,dk->bsk", x, p["w_kr"])[:, :, None, :], positions,
        cfg.rope_theta,
    )  # [B, S, 1, dr] shared across heads
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    H = cfg.n_heads
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (dr,))], axis=-1
    )
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    scale = 1.0 / math.sqrt(dn + dr)
    o = sdpa(q, k, v, causal_offset=0, scale=scale, use_kernel=use_kernel,
             unroll_chunks=not getattr(cfg, "scan_layers", True),
             probs_dtype=jnp.bfloat16
             if cfg.attn_probs_dtype == "bfloat16" else jnp.float32)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def mla_init_cache(cfg, batch: int, s_max: int, dtype) -> dict:
    return dict(
        c_kv=jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, s_max, cfg.qk_rope_head_dim), dtype),
    )


def mla_decode(
    p: dict,
    cache: dict,
    x: Array,  # [B, 1, D]
    position: Array,  # [B]
    cfg,
) -> tuple[dict, Array]:
    """Absorbed-form MLA decode: attention runs in the latent space.

    score[t] = <W_uk^T q_nope, c_t> + <q_rope, k_rope_t>
    out      = W_uv (sum_t p_t c_t)
    so the per-step FLOPs and cache traffic scale with r + d_rope, not H*dh.
    """
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    B = x.shape[0]
    q_nope, q_rope = _mla_q(p, x, position[:, None], cfg)  # [B,1,H,*]
    c_new = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])[:, 0]  # [B, r]
    kr_new = apply_rope(
        jnp.einsum("bsd,dk->bsk", x, p["w_kr"])[:, :, None, :],
        position[:, None],
        cfg.rope_theta,
    )[:, 0, 0]  # [B, dr]
    bidx = jnp.arange(B)
    c_kv = cache["c_kv"].at[bidx, position].set(c_new)
    k_rope = cache["k_rope"].at[bidx, position].set(kr_new)
    # absorb: q_lat [B, H, r]
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], p["w_uk"])
    scores = jnp.einsum("bhr,btr->bht", q_lat.astype(jnp.float32),
                        c_kv.astype(jnp.float32))
    scores += jnp.einsum(
        "bhk,btk->bht", q_rope[:, 0].astype(jnp.float32),
        k_rope.astype(jnp.float32),
    )
    scores *= 1.0 / math.sqrt(dn + dr)
    T = c_kv.shape[1]
    valid = jnp.arange(T)[None, :] < (position + 1)[:, None]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bht,btr->bhr", probs, c_kv.astype(jnp.float32))
    o = jnp.einsum("bhr,rhk->bhk", o_lat, p["w_uv"].astype(jnp.float32))
    out = jnp.einsum("bhk,hkd->bd", o.astype(x.dtype), p["wo"])[:, None]
    return dict(c_kv=c_kv, k_rope=k_rope), out
