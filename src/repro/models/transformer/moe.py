"""Mixture-of-Experts FFN with shared experts (DeepSeek-V2 / Qwen-MoE style).

Sort-based capacity dispatch (production JAX MoE pattern, not the O(T*E*C)
one-hot einsum): token->expert assignments are sorted by expert id, ranked
within their expert group, and dropped past the capacity C.  Expert weights
are stacked [E, ...] and sharded on the ``model`` axis (expert parallelism);
the gather/scatter across the token (data) and expert (model) shardings is
partitioned by XLA into the canonical all-to-all pair.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.models.common as cm
from repro.models.common import constrain

Array = jax.Array


def init_moe(key: Array, cfg, dtype) -> dict:
    m = cfg.moe
    d = cfg.d_model
    f = m.d_ff_expert
    E = m.n_routed
    ks = jax.random.split(key, 7)
    p = dict(
        router=cm.dense_init(ks[0], d, E, jnp.float32),
        w_gate=jax.random.normal(ks[1], (E, d, f)).astype(dtype) * (d**-0.5),
        w_up=jax.random.normal(ks[2], (E, d, f)).astype(dtype) * (d**-0.5),
        w_down=jax.random.normal(ks[3], (E, f, d)).astype(dtype) * (f**-0.5),
    )
    shared_w = m.d_ff_shared or m.n_shared * m.d_ff_expert
    if shared_w:
        p["shared"] = dict(
            w_gate=cm.dense_init(ks[4], d, shared_w, dtype),
            w_up=cm.dense_init(ks[5], d, shared_w, dtype),
            w_down=cm.dense_init(ks[6], shared_w, d, dtype),
        )
    return p


def _capacity(tokens: int, cfg) -> int:
    m = cfg.moe
    c = int(tokens * m.top_k / m.n_routed * m.capacity_factor)
    return max(8, (c + 7) // 8 * 8)


def moe_forward(p: dict, x: Array, cfg) -> tuple[Array, Array]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    K = m.top_k
    E = m.n_routed
    C = _capacity(T, cfg)
    xf = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)  # [T, K]
    if m.norm_topk_prob:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- sort-based dispatch -------------------------------------------------
    e_flat = top_i.reshape(-1)  # [T*K]
    sort_idx = jnp.argsort(e_flat)  # XLA sort is stable
    e_sorted = e_flat[sort_idx]
    tok_sorted = sort_idx // K
    gate_sorted = top_p.reshape(-1)[sort_idx]
    group_start = jnp.searchsorted(e_sorted, jnp.arange(E))
    rank = jnp.arange(T * K) - group_start[e_sorted]
    keep = rank < C
    rank_c = rank.clip(0, C - 1)
    # token buffer [E, C] (sentinel T -> zero row)
    buf = jnp.full((E, C), T, dtype=jnp.int32)
    buf = buf.at[e_sorted, rank_c].set(
        jnp.where(keep, tok_sorted, T).astype(jnp.int32)
    )
    gate_buf = jnp.zeros((E, C), jnp.float32)
    gate_buf = gate_buf.at[e_sorted, rank_c].add(jnp.where(keep, gate_sorted, 0.0))

    xa = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
    xe = xa[buf]  # [E, C, D]
    xe = constrain(xe, "tp", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w_up"]
    )
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # [E, C, D]
    ye = ye * gate_buf[..., None].astype(ye.dtype)

    out = jnp.zeros((T + 1, D), ye.dtype)
    out = out.at[buf.reshape(-1)].add(ye.reshape(E * C, D))
    out = out[:T].reshape(B, S, D)
    out = constrain(out, "dp", None, None)

    # shared experts (always-on)
    if "shared" in p:
        sh = p["shared"]
        out = out + cm.swiglu(x, sh["w_gate"], sh["w_up"], sh["w_down"])

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    assign_frac = jnp.mean(
        (jax.nn.one_hot(top_i, E, dtype=jnp.float32)).sum(1), axis=0
    ) / K
    prob_frac = probs.mean(axis=0)
    aux = E * jnp.sum(assign_frac * prob_frac) * m.router_aux_weight
    return out, aux
