"""Message-passing GNN layers on the segment_sum substrate.

All layers consume COO edges (src, dst int32 [E], mask bool [E]) over a
padded node table [N(+1), d] — the same gather/scatter machinery as the
ProbeSim PROBE push (DESIGN.md §2).  JAX has no CSR SpMM; per the assignment
this scatter-based message passing IS the system.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.models.common as cm

Array = jax.Array


def scatter_sum(values: Array, dst: Array, num_nodes: int) -> Array:
    """segment-sum messages [E, d] into nodes [N, d] (sentinel dst dropped)."""
    return jax.ops.segment_sum(values, dst, num_segments=num_nodes + 1)[:num_nodes]


def degree(dst: Array, mask: Array, num_nodes: int) -> Array:
    return jax.ops.segment_sum(
        mask.astype(jnp.float32), dst, num_segments=num_nodes + 1
    )[:num_nodes]


# ---------------------------------------------------------------------------
# GCN (Kipf & Welling) — symmetric-normalized SpMM
# ---------------------------------------------------------------------------


def init_gcn_layer(key: Array, d_in: int, d_out: int, dtype) -> dict:
    return dict(
        w=cm.dense_init(key, d_in, d_out, dtype),
        b=jnp.zeros((d_out,), dtype),
    )


def gcn_layer(
    p: dict, h: Array, src: Array, dst: Array, mask: Array, *, act=jax.nn.relu
) -> Array:
    n = h.shape[0]
    deg = degree(dst, mask, n) + degree(src, mask, n) * 0.0 + 1.0  # +self loop
    inv_sqrt = jax.lax.rsqrt(deg)
    hw = jnp.einsum("nd,df->nf", h, p["w"])
    msg = hw[src.clip(0, n - 1)] * (inv_sqrt[src.clip(0, n - 1)])[:, None]
    msg = jnp.where(mask[:, None], msg, 0.0)
    agg = scatter_sum(msg, dst, n) * inv_sqrt[:, None]
    out = agg + hw * (inv_sqrt * inv_sqrt)[:, None] + p["b"]  # self loop
    return act(out) if act is not None else out


# ---------------------------------------------------------------------------
# GIN (Xu et al.) — sum aggregation + MLP, learnable eps
# ---------------------------------------------------------------------------


def init_gin_layer(key: Array, d_in: int, d_out: int, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return dict(
        w1=cm.dense_init(k1, d_in, d_out, dtype),
        b1=jnp.zeros((d_out,), dtype),
        w2=cm.dense_init(k2, d_out, d_out, dtype),
        b2=jnp.zeros((d_out,), dtype),
        eps=jnp.zeros((), jnp.float32),
    )


def gin_layer(p: dict, h: Array, src: Array, dst: Array, mask: Array) -> Array:
    n = h.shape[0]
    msg = jnp.where(mask[:, None], h[src.clip(0, n - 1)], 0.0)
    agg = scatter_sum(msg, dst, n)
    z = (1.0 + p["eps"]) * h + agg
    z = jax.nn.relu(jnp.einsum("nd,df->nf", z, p["w1"]) + p["b1"])
    return jnp.einsum("nd,df->nf", z, p["w2"]) + p["b2"]


# ---------------------------------------------------------------------------
# GatedGCN (Bresson & Laurent; benchmarking-GNNs config) — edge gates
# ---------------------------------------------------------------------------


def init_gatedgcn_layer(key: Array, d: int, dtype) -> dict:
    ks = jax.random.split(key, 5)
    return {
        "A": cm.dense_init(ks[0], d, d, dtype),
        "B": cm.dense_init(ks[1], d, d, dtype),
        "C": cm.dense_init(ks[2], d, d, dtype),
        "U": cm.dense_init(ks[3], d, d, dtype),
        "V": cm.dense_init(ks[4], d, d, dtype),
        "ln_h": jnp.ones((d,), dtype),
        "ln_e": jnp.ones((d,), dtype),
    }


def gatedgcn_layer(
    p: dict,
    h: Array,  # [N, d]
    e: Array,  # [E, d] edge features
    src: Array,
    dst: Array,
    mask: Array,
) -> tuple[Array, Array]:
    n = h.shape[0]
    s = src.clip(0, n - 1)
    d_ = dst.clip(0, n - 1)
    # edge update: e' = e + ReLU(LN(A h_i + B h_j + C e))
    e_raw = (
        jnp.einsum("nd,df->nf", h, p["A"])[d_]
        + jnp.einsum("nd,df->nf", h, p["B"])[s]
        + jnp.einsum("ed,df->ef", e, p["C"])
    )
    e_new = e + jax.nn.relu(cm.rms_norm(e_raw, p["ln_e"]))
    gate = jax.nn.sigmoid(e_new)
    gate = jnp.where(mask[:, None], gate, 0.0)
    # normalized gated aggregation
    vh = jnp.einsum("nd,df->nf", h, p["V"])
    num = scatter_sum(gate * vh[s], dst, n)
    den = scatter_sum(gate, dst, n) + 1e-6
    h_raw = jnp.einsum("nd,df->nf", h, p["U"]) + num / den
    h_new = h + jax.nn.relu(cm.rms_norm(h_raw, p["ln_h"]))
    return h_new, e_new


# ---------------------------------------------------------------------------
# GAT (Velickovic et al., arXiv:1710.10903) — bonus arch: the SDDMM +
# segment-softmax regime (kernel_taxonomy §GNN)
# ---------------------------------------------------------------------------


def segment_softmax(scores: Array, segments: Array, num_segments: int,
                    mask: Array) -> Array:
    """Softmax of edge scores within each destination segment."""
    scores = jnp.where(mask, scores, -1e30)
    seg_max = jax.ops.segment_max(scores, segments, num_segments=num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    ex = jnp.exp(scores - seg_max[segments.clip(0, num_segments - 1)])
    ex = jnp.where(mask, ex, 0.0)
    denom = jax.ops.segment_sum(ex, segments, num_segments=num_segments)
    return ex / jnp.maximum(denom[segments.clip(0, num_segments - 1)], 1e-16)


def init_gat_layer(key: Array, d_in: int, d_out: int, heads: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return dict(
        w=cm.dense_init(k1, d_in, heads * d_out, dtype).reshape(d_in, heads, d_out),
        a_src=(jax.random.normal(k2, (heads, d_out)) * 0.1).astype(dtype),
        a_dst=(jax.random.normal(k3, (heads, d_out)) * 0.1).astype(dtype),
    )


def gat_layer(
    p: dict, h: Array, src: Array, dst: Array, mask: Array,
    *, negative_slope: float = 0.2, concat: bool = True,
) -> Array:
    n = h.shape[0]
    s = src.clip(0, n - 1)
    d_ = dst.clip(0, n - 1)
    hw = jnp.einsum("nd,dhf->nhf", h, p["w"])  # [N, H, F]
    # SDDMM: per-edge attention logits from source and destination halves
    e_src = jnp.einsum("nhf,hf->nh", hw, p["a_src"])[s]  # [E, H]
    e_dst = jnp.einsum("nhf,hf->nh", hw, p["a_dst"])[d_]
    logits = jax.nn.leaky_relu(e_src + e_dst, negative_slope)
    # per-head segment softmax over incoming edges of each destination
    segs = dst  # sentinel dst scatters into the dropped tail
    alpha = jax.vmap(
        lambda col: segment_softmax(col, segs, n + 1, mask), in_axes=1,
        out_axes=1,
    )(logits)  # [E, H]
    msgs = hw[s] * alpha[..., None]  # [E, H, F]
    out = jax.ops.segment_sum(
        msgs.reshape(msgs.shape[0], -1), dst, num_segments=n + 1
    )[:n].reshape(n, *hw.shape[1:])
    return out.reshape(n, -1) if concat else out.mean(axis=1)
