"""Minimal real-SO(3) representation machinery for NequIP (l <= 4).

No e3nn in this container — we build it from scratch:

* complex Clebsch-Gordan coefficients via the Racah formula,
* the unitary complex->real spherical-harmonic basis change,
* real coupling coefficients C[l1, l2, l3][m1, m2, m3] used by the
  equivariant tensor product,
* real spherical harmonics Y_lm evaluated from Cartesian unit vectors
  (closed forms for l <= 2, the NequIP assignment's l_max).

Verified in tests by the rotation-equivariance property: the Wigner-D of a
random rotation is recovered numerically from Y(R r) = D Y(r) and the tensor
product must commute with it.
"""
from __future__ import annotations

import math
from functools import lru_cache

import numpy as np


def _fact(n: int) -> float:
    return math.factorial(n)


@lru_cache(maxsize=None)
def cg_complex(l1: int, l2: int, l3: int) -> np.ndarray:
    """Complex CG <l1 m1; l2 m2 | l3 m3> as [2l1+1, 2l2+1, 2l3+1] (m = -l..l)."""
    out = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1))
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return out
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            m3 = m1 + m2
            if abs(m3) > l3:
                continue
            # Racah formula
            pre = math.sqrt(
                (2 * l3 + 1)
                * _fact(l3 + l1 - l2)
                * _fact(l3 - l1 + l2)
                * _fact(l1 + l2 - l3)
                / _fact(l1 + l2 + l3 + 1)
            )
            pre *= math.sqrt(
                _fact(l3 + m3)
                * _fact(l3 - m3)
                * _fact(l1 - m1)
                * _fact(l1 + m1)
                * _fact(l2 - m2)
                * _fact(l2 + m2)
            )
            s = 0.0
            for k in range(0, l1 + l2 - l3 + 1):
                denom_terms = [
                    k,
                    l1 + l2 - l3 - k,
                    l1 - m1 - k,
                    l2 + m2 - k,
                    l3 - l2 + m1 + k,
                    l3 - l1 - m2 + k,
                ]
                if any(t < 0 for t in denom_terms):
                    continue
                denom = 1.0
                for t in denom_terms:
                    denom *= _fact(t)
                s += (-1.0) ** k / denom
            out[m1 + l1, m2 + l2, m3 + l3] = pre * s
    return out


@lru_cache(maxsize=None)
def complex_to_real(l: int) -> np.ndarray:
    """Unitary U with Y_real = U @ Y_complex (Condon-Shortley phases)."""
    d = 2 * l + 1
    U = np.zeros((d, d), dtype=np.complex128)
    rt2 = 1.0 / math.sqrt(2.0)
    for m in range(-l, l + 1):
        i = m + l
        if m < 0:
            U[i, m + l] = 1j * rt2
            U[i, -m + l] = -1j * rt2 * (-1.0) ** (-m)
        elif m == 0:
            U[i, l] = 1.0
        else:
            U[i, -m + l] = rt2
            U[i, m + l] = rt2 * (-1.0) ** m
    return U


@lru_cache(maxsize=None)
def cg_real(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real coupling coefficients: contraction of real irreps l1 x l2 -> l3.

    Defined so that if a transforms as D_{l1}, b as D_{l2}, then
    t[m3] = sum_{m1,m2} C[m1,m2,m3] a[m1] b[m2] transforms as D_{l3}.
    """
    C = cg_complex(l1, l2, l3).astype(np.complex128)
    U1 = complex_to_real(l1)
    U2 = complex_to_real(l2)
    U3 = complex_to_real(l3)
    # C_real[a,b,c] = sum U1[a,m1] U2[b,m2] conj(U3[c,m3]) C[m1,m2,m3]
    Cr = np.einsum("am,bn,co,mno->abc", U1, U2, np.conj(U3), C)
    # real up to a global phase: rotate it away
    flat = Cr.reshape(-1)
    j = np.argmax(np.abs(flat))
    phase = flat[j] / abs(flat[j]) if abs(flat[j]) > 1e-12 else 1.0
    Cr = Cr / phase
    assert np.abs(Cr.imag).max() < 1e-9, f"CG({l1},{l2},{l3}) not real"
    return np.ascontiguousarray(Cr.real)


def sh_l0(vec: np.ndarray) -> np.ndarray:
    return np.full(vec.shape[:-1] + (1,), 1.0 / math.sqrt(4 * math.pi))


def real_sh(l: int, vec) -> "np.ndarray":
    """Real spherical harmonics of unit vectors (numpy or jax.numpy arrays).

    Basis order m = -l..l; normalization: orthonormal on the sphere.
    """
    import jax.numpy as jnp

    xp = jnp if not isinstance(vec, np.ndarray) else np
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    pi = math.pi
    if l == 0:
        return (0.5 / math.sqrt(pi)) * xp.ones_like(x)[..., None]
    if l == 1:
        c = math.sqrt(3.0 / (4 * pi))
        return xp.stack([c * y, c * z, c * x], axis=-1)
    if l == 2:
        c0 = 0.5 * math.sqrt(15.0 / pi)
        c1 = 0.5 * math.sqrt(15.0 / pi)
        c2 = 0.25 * math.sqrt(5.0 / pi)
        return xp.stack(
            [
                c0 * x * y,
                c1 * y * z,
                c2 * (3 * z * z - 1.0),
                c1 * x * z,
                0.5 * c0 * (x * x - y * y),
            ],
            axis=-1,
        )
    raise NotImplementedError(f"l={l}")


def tp_paths(l_max: int) -> list[tuple[int, int, int]]:
    """All (l_in, l_filter, l_out) paths with every l <= l_max."""
    paths = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                paths.append((l1, l2, l3))
    return paths
