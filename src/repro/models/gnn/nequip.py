"""NequIP (Batzner et al., arXiv:2101.03164) — E(3)-equivariant interatomic
potential, implemented from first principles (no e3nn dependency).

Node features are a stack of real irreps with a uniform channel count:
``h = {l: [N, C, 2l+1] for l in 0..l_max}``.  An interaction layer:

1. edge geometry: r_ij = x_j - x_i, Bessel radial basis with a smooth
   polynomial cutoff envelope, real spherical harmonics Y^l(r_hat),
2. per-path radial weights  R^{(l1,l2,l3)}(|r|) = MLP(bessel)  (per channel),
3. tensor-product message  m^{l3}_i = sum_j sum_paths R * CG(h_j^{l1}, Y^{l2}),
4. scatter-sum over in-edges + linear self-interaction mix per l,
5. gated nonlinearity: scalars -> SiLU; l>0 gated by sigmoid(scalar gates).

Energy readout: per-atom MLP on the l=0 channels, summed per graph; forces
would be -grad(E, positions) (exposed via jax.grad in the example).
"""
from __future__ import annotations

import math

import numpy as np

import jax
import jax.numpy as jnp

import repro.models.common as cm
from repro.models.gnn.layers import scatter_sum
from repro.models.gnn.so3 import cg_real, real_sh, tp_paths

Array = jax.Array


def bessel_basis(r: Array, n_rbf: int, cutoff: float) -> Array:
    """Sine-Bessel radial basis [E, n_rbf] with smooth cutoff envelope."""
    r = jnp.maximum(r, 1e-6)
    ks = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(ks * math.pi * r[:, None] / cutoff) / r[:, None]
    # polynomial envelope (p = 6)
    x = jnp.clip(r / cutoff, 0.0, 1.0)
    env = 1.0 - 28.0 * x**6 + 48.0 * x**7 - 21.0 * x**8
    return basis * env[:, None]


def init_nequip(key: Array, cfg, d_feat: int, dtype) -> dict:
    C = cfg.d_hidden
    lmax = cfg.l_max
    paths = tp_paths(lmax)
    layers = []
    for li in range(cfg.n_layers):
        kl = jax.random.fold_in(key, li)
        radial = {}
        self_mix = {}
        gates = {}
        for pi, (l1, l2, l3) in enumerate(paths):
            kp = jax.random.fold_in(kl, pi)
            k1, k2 = jax.random.split(kp)
            radial[f"{l1}_{l2}_{l3}"] = dict(
                w1=cm.dense_init(k1, cfg.n_rbf, 16, dtype),
                w2=cm.dense_init(k2, 16, C, dtype),
            )
        for l in range(lmax + 1):
            km = jax.random.fold_in(kl, 100 + l)
            self_mix[str(l)] = cm.dense_init(km, C, C, dtype)
            if l > 0:
                gates[str(l)] = cm.dense_init(
                    jax.random.fold_in(kl, 200 + l), C, C, dtype
                )
        layers.append(dict(radial=radial, self_mix=self_mix, gates=gates))
    k_emb, k_out1, k_out2 = jax.random.split(jax.random.fold_in(key, 999), 3)
    return dict(
        embed=cm.dense_init(k_emb, d_feat, C, dtype),
        layers=layers,
        out_w1=cm.dense_init(k_out1, C, C, dtype),
        out_w2=cm.dense_init(k_out2, C, 1, dtype),
    )


def nequip_forward(
    params: dict,
    feats: Array,  # [N, d_feat] scalar node attributes
    pos: Array,  # [N, 3]
    src: Array,
    dst: Array,
    mask: Array,
    cfg,
    graph_ids: Array | None = None,
    n_graphs: int = 1,
) -> Array:
    """Returns per-graph energies [n_graphs]."""
    N = feats.shape[0]
    C = cfg.d_hidden
    lmax = cfg.l_max
    s = src.clip(0, N - 1)
    d_ = dst.clip(0, N - 1)

    # edge geometry
    rvec = pos[s] - pos[d_]
    r = jnp.linalg.norm(rvec + 1e-12, axis=-1)
    rhat = rvec / jnp.maximum(r, 1e-6)[:, None]
    rb = bessel_basis(r, cfg.n_rbf, cfg.cutoff)  # [E, n_rbf]
    rb = jnp.where(mask[:, None], rb, 0.0)
    Y = {l: real_sh(l, rhat) for l in range(lmax + 1)}  # [E, 2l+1]

    # initial features: scalars only
    h = {0: jnp.einsum("nd,dc->nc", feats, params["embed"])[:, :, None]}
    for l in range(1, lmax + 1):
        h[l] = jnp.zeros((N, C, 2 * l + 1), feats.dtype)

    paths = tp_paths(lmax)
    for layer in params["layers"]:
        msgs = {l: 0.0 for l in range(lmax + 1)}
        for (l1, l2, l3) in paths:
            rp = layer["radial"][f"{l1}_{l2}_{l3}"]
            R = jnp.einsum(
                "ek,kc->ec", jax.nn.silu(jnp.einsum("eb,bk->ek", rb, rp["w1"])),
                rp["w2"],
            )  # [E, C]
            cg = jnp.asarray(cg_real(l1, l2, l3), feats.dtype)  # [m1, m2, m3]
            hj = h[l1][s]  # [E, C, 2l1+1]
            edge_msg = jnp.einsum("eca,eb,abm->ecm", hj, Y[l2], cg)  # [E,C,2l3+1]
            edge_msg = edge_msg * R[:, :, None]
            msgs[l3] = msgs[l3] + scatter_sum(
                edge_msg.reshape(edge_msg.shape[0], -1), dst, N
            ).reshape(N, C, 2 * l3 + 1)
        # self-interaction + residual + gated nonlinearity
        new_h = {}
        scal = None
        for l in range(lmax + 1):
            z = h[l] + msgs[l]
            z = jnp.einsum("ncm,cf->nfm", z, layer["self_mix"][str(l)])
            if l == 0:
                z = jax.nn.silu(z)
                scal = z[:, :, 0]
            else:
                gate = jax.nn.sigmoid(
                    jnp.einsum("nc,cf->nf", scal, layer["gates"][str(l)])
                )
                z = z * gate[:, :, None]
            new_h[l] = z
        h = new_h

    atom_e = jnp.einsum(
        "nc,co->no", jax.nn.silu(jnp.einsum("nc,cf->nf", h[0][:, :, 0],
                                            params["out_w1"])),
        params["out_w2"],
    )[:, 0]
    if graph_ids is None:
        return atom_e.sum(keepdims=True)
    return jax.ops.segment_sum(atom_e, graph_ids, num_segments=n_graphs)
