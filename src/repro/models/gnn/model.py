"""GNN model drivers: init/forward/loss for the four assigned architectures.

Input convention (all shapes padded/static):
    feats   [N, d_feat] float  (N includes a padding tail; sentinel rows 0)
    pos     [N, 3]             (nequip only)
    src/dst [E] int32, mask [E] bool
    labels  [N] int32 (node classification) or [G] float (graph regression)
    graph_ids [N] int32 (batched_graphs readout)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.models.common as cm
from repro.models.common import constrain
from repro.models.gnn import layers as L
from repro.models.gnn.nequip import init_nequip, nequip_forward

Array = jax.Array


def init_gnn(key: Array, cfg, d_feat: int) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    if cfg.conv == "nequip":
        return init_nequip(key, cfg, d_feat, dtype)
    ks = jax.random.split(key, cfg.n_layers + 2)
    p: dict = {"layers": []}
    d_in = d_feat
    for i in range(cfg.n_layers):
        if cfg.conv == "gcn":
            p["layers"].append(L.init_gcn_layer(ks[i], d_in, cfg.d_hidden, dtype))
        elif cfg.conv == "gat":
            heads = 4
            p["layers"].append(
                L.init_gat_layer(ks[i], d_in, cfg.d_hidden // heads, heads, dtype)
            )
        elif cfg.conv == "gin":
            p["layers"].append(L.init_gin_layer(ks[i], d_in, cfg.d_hidden, dtype))
        elif cfg.conv == "gatedgcn":
            if d_in != cfg.d_hidden:
                p["in_proj"] = cm.dense_init(ks[-2], d_in, cfg.d_hidden, dtype)
            p["layers"].append(L.init_gatedgcn_layer(ks[i], cfg.d_hidden, dtype))
        else:
            raise ValueError(cfg.conv)
        d_in = cfg.d_hidden
    p["head"] = cm.dense_init(ks[-1], cfg.d_hidden, cfg.n_classes, dtype)
    return p


def gnn_forward(
    params: dict,
    batch: dict,
    cfg,
    *,
    n_graphs: int = 1,
) -> Array:
    """Returns node logits [N, n_classes] (or graph outputs for nequip)."""
    feats = batch["feats"]
    src, dst, mask = batch["src"], batch["dst"], batch["mask"]
    if cfg.conv == "nequip":
        return nequip_forward(
            params,
            feats,
            batch["pos"],
            src,
            dst,
            mask,
            cfg,
            graph_ids=batch.get("graph_ids"),
            n_graphs=n_graphs,
        )
    h = feats
    h = constrain(h, "dp", None)
    if "in_proj" in params:
        h = jnp.einsum("nd,df->nf", h, params["in_proj"])
    if cfg.conv == "gatedgcn":
        e = jnp.zeros((src.shape[0], cfg.d_hidden), h.dtype) + 0.1
        for lp in params["layers"]:
            h, e = L.gatedgcn_layer(lp, h, e, src, dst, mask)
    else:
        for i, lp in enumerate(params["layers"]):
            if cfg.conv == "gcn":
                act = jax.nn.relu if i < cfg.n_layers - 1 else None
                h = L.gcn_layer(lp, h, src, dst, mask, act=act)
            elif cfg.conv == "gat":
                h = L.gat_layer(lp, h, src, dst, mask)
                if i < cfg.n_layers - 1:
                    h = jax.nn.elu(h)
            else:
                h = L.gin_layer(lp, h, src, dst, mask)
        h = constrain(h, "dp", None)
    logits = jnp.einsum("nd,dc->nc", h, params["head"])
    if "graph_ids" in batch and batch["graph_ids"] is not None:
        logits = jax.ops.segment_sum(
            logits, batch["graph_ids"], num_segments=n_graphs
        )
    return logits


def gnn_loss(params: dict, batch: dict, cfg, *, n_graphs: int = 1):
    out = gnn_forward(params, batch, cfg, n_graphs=n_graphs)
    if cfg.conv == "nequip":
        # energy regression per graph
        tgt = batch["energy"]
        loss = jnp.mean((out - tgt) ** 2)
        return loss, dict(mse=loss)
    labels = batch["labels"]
    lmask = batch.get("label_mask", jnp.ones_like(labels, jnp.float32))
    logz = jax.nn.logsumexp(out, axis=-1)
    gold = jnp.take_along_axis(out, labels[:, None].clip(0), axis=-1)[:, 0]
    nll = ((logz - gold) * lmask).sum() / jnp.maximum(lmask.sum(), 1.0)
    return nll, dict(nll=nll)
