"""Wide & Deep (Cheng et al., arXiv:1606.07792).

Wide: a (sparse) linear model over the categorical ids — per-field scalar
weight tables.  Deep: per-field dense embeddings (dim 32) concatenated with
dense features, through an MLP 1024-512-256.  Output: sigmoid CTR logit.

Embedding substrate: JAX has no nn.EmbeddingBag — lookup is ``jnp.take``
and multi-hot bags are ``take + segment_sum`` (``embedding_bag`` below),
built here as part of the system per the assignment.

Sharding: embedding tables are the dominant state (n_sparse x vocab x dim);
they shard on the vocab dim over ``model`` (table-row parallelism).  The
lookup gather then induces the canonical recsys all-to-all from
batch-sharded ids to table-sharded rows and back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.models.common as cm
from repro.models.common import constrain

Array = jax.Array


def embedding_bag(
    table: Array,  # [V, D]
    ids: Array,  # [T] int32 flat ids
    segments: Array,  # [T] int32 bag index
    num_bags: int,
    *,
    mode: str = "sum",
    weights: Array | None = None,
) -> Array:
    """torch.nn.EmbeddingBag equivalent: gather rows, segment-reduce to bags."""
    rows = table[ids.clip(0, table.shape[0] - 1)]
    if weights is not None:
        rows = rows * weights[:, None]
    out = jax.ops.segment_sum(rows, segments, num_segments=num_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(ids, jnp.float32), segments, num_segments=num_bags
        )
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def init_widedeep(key: Array, cfg) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4 + len(cfg.mlp))
    F, V, D = cfg.n_sparse, cfg.vocab_per_field, cfg.embed_dim
    p: dict = dict(
        embed=(jax.random.normal(ks[0], (F, V, D)) * 0.01).astype(dtype),
        wide=(jax.random.normal(ks[1], (F, V)) * 0.01).astype(dtype),
        wide_dense=cm.dense_init(ks[2], cfg.n_dense, 1, dtype),
        bias=jnp.zeros((), dtype),
    )
    d_in = F * D + cfg.n_dense
    mlp = []
    for i, width in enumerate(cfg.mlp):
        mlp.append(
            dict(
                w=cm.dense_init(ks[3 + i], d_in, width, dtype),
                b=jnp.zeros((width,), dtype),
            )
        )
        d_in = width
    p["mlp"] = mlp
    p["head"] = cm.dense_init(ks[-1], d_in, 1, dtype)
    return p


def deep_tower(p: dict, sparse_ids: Array, dense: Array, cfg) -> Array:
    """[B, F] ids + [B, n_dense] -> deep representation [B, mlp[-1]]."""
    B, F = sparse_ids.shape
    # vectorized per-field gather: emb[b, f] = embed[f, ids[b, f]]
    emb = p["embed"][jnp.arange(F)[None, :], sparse_ids]  # [B, F, D]
    emb = constrain(emb, "dp", None, None)
    x = jnp.concatenate([emb.reshape(B, -1), dense], axis=-1)
    for layer in p["mlp"]:
        x = jax.nn.relu(jnp.einsum("bd,df->bf", x, layer["w"]) + layer["b"])
    return x


def widedeep_forward(p: dict, batch: dict, cfg) -> Array:
    """Returns CTR logits [B]."""
    sparse_ids = batch["sparse_ids"]  # [B, F] int32
    dense = batch["dense"]  # [B, n_dense]
    B, F = sparse_ids.shape
    wide = p["wide"][jnp.arange(F)[None, :], sparse_ids].sum(axis=1)  # [B]
    wide = wide + jnp.einsum("bd,do->bo", dense, p["wide_dense"])[:, 0]
    deep = deep_tower(p, sparse_ids, dense, cfg)
    logit = jnp.einsum("bd,do->bo", deep, p["head"])[:, 0]
    return logit + wide + p["bias"]


def widedeep_loss(p: dict, batch: dict, cfg):
    logits = widedeep_forward(p, batch, cfg)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    return loss, dict(bce=loss)


def retrieval_scores(
    p: dict, batch: dict, cfg, *, field: int = 0
) -> Array:
    """Score one query against n_candidates items (retrieval_cand shape).

    Query tower: deep MLP on the user's features; candidates: rows of one
    embedding table projected by the head — a batched dot, not a loop.
    """
    deep = deep_tower(p, batch["sparse_ids"], batch["dense"], cfg)  # [1, d]
    cand_ids = batch["cand_ids"]  # [n_candidates]
    cand = p["embed"][field][cand_ids.clip(0, cfg.vocab_per_field - 1)]  # [nc, D]
    # project query into the embedding space via the head's first D dims
    q = deep[:, : cfg.embed_dim]  # [1, D]
    return jnp.einsum("qd,nd->qn", q, cand)[0]  # [n_candidates]
