"""Version-compat shims over jax's mesh / sharding surface.

The distributed substrate was written against the post-0.5 jax mesh API
(`jax.set_mesh`, `jax.shard_map`, `jax.sharding.get_abstract_mesh`,
`jax.make_mesh(..., axis_types=...)`).  The pinned container jax (0.4.x)
predates all four, which left every meshed code path — the sharded probe,
the ring push, the arch-bundle sharding helpers — unimportable.  This
module is the ONE place that knows which spelling the running jax uses;
everything else imports from here:

    from repro.utils.jaxcompat import (
        get_abstract_mesh, make_mesh, set_mesh, shard_map, specs_to_shardings,
    )

Semantics (identical on both jax generations):

* ``make_mesh(shape, axes)`` — a mesh over the local devices with Auto
  axis types (explicit-sharding mode is never used here);
* ``set_mesh(mesh)`` — context manager making ``mesh`` the active mesh for
  spec resolution (`jax.set_mesh` when it exists, the legacy ``with mesh:``
  resource env otherwise);
* ``get_abstract_mesh()`` — the active mesh or None when there is none
  (old jax has no always-empty AbstractMesh to return, hence the None
  convention; callers treat None and ``mesh.empty`` alike);
* ``shard_map(f, mesh=..., in_specs=..., out_specs=..., axis_names=...)``
  — the new-style signature; on old jax ``axis_names`` is translated to
  the complementary ``auto=`` set and per-output replication checking is
  disabled (old check_rep rejects collectives the new checker accepts);
* ``specs_to_shardings(tree, mesh=...)`` — maps a PartitionSpec pytree to
  NamedShardings.  New jax accepts bare specs in ``jit``'s
  ``in_shardings`` under an active mesh; old jax requires concrete
  ``Sharding`` objects, so meshed ``jit`` call sites route their spec
  trees through this helper (a no-op wrap on new jax too — NamedSharding
  is accepted everywhere).
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_HAS_SET_MESH = hasattr(jax, "set_mesh")
_HAS_GET_ABSTRACT = hasattr(jax.sharding, "get_abstract_mesh")
_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")


def legacy_auto_partitioner() -> bool:
    """True on old jax, whose auto (SPMD) partitioner double-counts scatter
    contributions when the scatter operand carries an explicit row-sharding
    constraint (observed: segment_sum results scaled by the axis extent).

    Callers that add placement *hints* for the auto partitioner (the
    distributed probe's frontier constraints) skip them on old jax — the
    partitioner then picks placements itself, which is slower but correct.
    Manual paths (shard_map ring) are unaffected.
    """
    return not _HAS_SET_MESH


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Auto-axis mesh over the local devices, on either jax generation."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


@contextlib.contextmanager
def set_mesh(mesh: jax.sharding.Mesh):
    """Make ``mesh`` the active mesh for PartitionSpec resolution."""
    if _HAS_SET_MESH:
        with jax.set_mesh(mesh):
            yield mesh
    else:
        # legacy resource env: activates the mesh for pjit/shard_map spec
        # resolution and for get_abstract_mesh() below
        with mesh:
            yield mesh


def get_abstract_mesh():
    """The active mesh, or None if none is set (old jax has no empty
    AbstractMesh singleton to hand back)."""
    if _HAS_GET_ABSTRACT:
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as mesh_lib

    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
    """New-style shard_map signature on either jax generation.

    ``axis_names`` is the set of mesh axes that are MANUAL inside ``f``
    (the new-jax meaning); old jax expresses the same thing as the
    complementary ``auto`` set.
    """
    if _HAS_NEW_SHARD_MAP:
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = (
        frozenset(mesh.axis_names) - set(axis_names)
        if axis_names is not None
        else frozenset()
    )
    # check_rep=False: the legacy replication checker rejects patterns
    # (psum-of-segment_sum, bitcast ppermute) the new one accepts
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        auto=auto, check_rep=False,
    )


def specs_to_shardings(tree, *, mesh=None):
    """PartitionSpec pytree -> NamedSharding pytree against ``mesh``.

    ``mesh`` defaults to the active mesh.  None leaves mean "replicated"
    (NamedSharding(mesh, P())), matching what new jax infers for a bare
    None in ``in_shardings`` under a mesh.
    """
    mesh = mesh if mesh is not None else get_abstract_mesh()
    if mesh is None:
        raise ValueError("specs_to_shardings needs a mesh (none active)")
    # old jax: the thread-resource mesh is already concrete; new jax may
    # hand back an AbstractMesh — NamedSharding wants the concrete one
    concrete = getattr(mesh, "_concrete_mesh", None) or mesh
    return jax.tree.map(
        lambda s: NamedSharding(concrete, s if s is not None else P()),
        tree,
        is_leaf=lambda x: x is None or isinstance(x, P),
    )
