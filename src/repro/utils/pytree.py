"""Small pytree helpers: a frozen-dataclass pytree decorator.

Usage::

    @struct
    class Foo:
        x: jax.Array                 # pytree leaf
        n: int = static()            # static / aux field

Static fields participate in the pytree treedef (so they can differ between
traced calls without shape confusion) and are hashable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, TypeVar

import jax

_T = TypeVar("_T")

_STATIC_MARK = "__repro_static__"


def static(default: Any = dataclasses.MISSING, **kwargs):
    """Mark a dataclass field as static (pytree aux data)."""
    metadata = dict(kwargs.pop("metadata", {}) or {})
    metadata[_STATIC_MARK] = True
    if default is dataclasses.MISSING:
        return dataclasses.field(metadata=metadata, **kwargs)
    return dataclasses.field(default=default, metadata=metadata, **kwargs)


def struct(cls: type[_T]) -> type[_T]:
    """Decorator: frozen dataclass registered as a JAX pytree."""
    cls = dataclasses.dataclass(frozen=True)(cls)
    data_fields = []
    meta_fields = []
    for f in dataclasses.fields(cls):
        if f.metadata.get(_STATIC_MARK, False):
            meta_fields.append(f.name)
        else:
            data_fields.append(f.name)
    jax.tree_util.register_dataclass(
        cls, data_fields=data_fields, meta_fields=meta_fields
    )

    def replace(self, **updates):
        return dataclasses.replace(self, **updates)

    cls.replace = replace  # type: ignore[attr-defined]
    return cls
