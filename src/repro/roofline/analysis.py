"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes is parsed from the post-SPMD optimized HLO
(``compiled.as_text()``): we sum the RESULT buffer sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(for all-reduce the result equals the operand; for all-gather the result is
the full gathered buffer — an upper bound on per-device wire bytes, i.e. a
conservative collective term).

Note: cost_analysis on the CPU backend reports *per-program* (global)
FLOPs/bytes for the SPMD module, which is already per-device-partitioned —
so the numbers are per-device; we multiply by chips where a global number
is needed and keep everything per-device otherwise (documented per use).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# matches: %x = TYPE all-gather(...)   or   x.1 = (TYPE, TYPE) all-reduce-start(
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        size = DTYPE_BYTES[dtype]
        if dims:
            for d in dims.split(","):
                size *= int(d)
        total += size
    return total


_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$", re.M)
_WHILE_RE = re.compile(
    r"while\([^)]*\), condition=%?([\w.\-]+), body=%?([\w.\-]+)"
)
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')


def _split_computations(hlo_text: str) -> tuple[dict[str, str], str]:
    """{comp_name: body_text}, entry_name."""
    headers = list(_COMP_RE.finditer(hlo_text))
    comps: dict[str, str] = {}
    entry = ""
    for i, h in enumerate(headers):
        end = headers[i + 1].start() if i + 1 < len(headers) else len(hlo_text)
        comps[h.group(2)] = hlo_text[h.start():end]
        if h.group(1):
            entry = h.group(2)
    return comps, entry


def _loop_multipliers(comps: dict[str, str], entry: str) -> dict[str, int]:
    """Execution-count multiplier per computation (while bodies x trip)."""
    edges = []  # (parent, child, trip)
    for name, body in comps.items():
        for line in body.splitlines():
            wm = _WHILE_RE.search(line)
            if not wm:
                continue
            tm = _TRIP_RE.search(line)
            trip = int(tm.group(1)) if tm else 1
            edges.append((name, wm.group(2), trip))  # body
            edges.append((name, wm.group(1), trip))  # cond (cheap anyway)
    mult = {entry: 1} if entry else {}
    for _ in range(64):  # fixpoint over nesting depth
        changed = False
        for parent, child, trip in edges:
            pm = mult.get(parent)
            if pm is None:
                continue
            new = pm * max(trip, 1)
            if mult.get(child, 0) < new:
                mult[child] = new
                changed = True
        if not changed:
            break
    return mult


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-buffer bytes per collective kind from optimized HLO.

    Collectives inside while-loop bodies (lax.scan over layers / edge
    chunks) are weighted by the loop's known_trip_count, so a 126-layer
    scanned transformer counts its per-layer all-reduce 126 times."""
    comps, entry = _split_computations(hlo_text)
    if not comps:
        comps, entry = {"__all__": hlo_text}, "__all__"
    mults = _loop_multipliers(comps, entry)
    by_kind: dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    counts: dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    for name, body in comps.items():
        mult = mults.get(name, 1)
        for m in _OP_RE.finditer(body):
            type_str, kind, suffix = m.group(1), m.group(2), m.group(3)
            if suffix == "-done":
                continue  # async pairs: count the -start only
            by_kind[kind] += _shape_bytes(type_str) * mult
            counts[kind] += mult
    total = sum(by_kind.values())
    return dict(by_kind=by_kind, counts=counts, total_bytes=total)


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # per-device
    hlo_bytes: float  # per-device
    collective_bytes: float  # per-device (result-buffer sum)
    model_flops: float  # global MODEL_FLOPS (6ND etc.)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_flops_ratio: float = 0.0
    collectives: dict = field(default_factory=dict)
    memory_per_device: dict = field(default_factory=dict)

    def finalize(self, hw: dict) -> "RooflineReport":
        self.compute_s = self.hlo_flops / hw["peak_flops_bf16"]
        self.memory_s = self.hlo_bytes / hw["hbm_bw"]
        self.collective_s = self.collective_bytes / hw["ici_bw"]
        terms = dict(
            compute=self.compute_s, memory=self.memory_s,
            collective=self.collective_s,
        )
        self.bottleneck = max(terms, key=terms.get)
        global_hlo_flops = self.hlo_flops * self.chips
        self.useful_flops_ratio = (
            self.model_flops / global_hlo_flops if global_hlo_flops else 0.0
        )
        return self

    def to_dict(self) -> dict:
        return {
            k: v for k, v in self.__dict__.items()
        }


def analyze(
    *, arch: str, shape: str, mesh_name: str, chips: int, compiled,
    model_flops: float, hw: dict,
) -> RooflineReport:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per program
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    colls = parse_collectives(compiled.as_text())
    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        mem = dict(
            argument_gb=getattr(ma, "argument_size_in_bytes", 0) / 1e9,
            output_gb=getattr(ma, "output_size_in_bytes", 0) / 1e9,
            temp_gb=getattr(ma, "temp_size_in_bytes", 0) / 1e9,
            alias_gb=getattr(ma, "alias_size_in_bytes", 0) / 1e9,
        )
    rep = RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=byts,
        collective_bytes=float(colls["total_bytes"]),
        model_flops=model_flops,
        collectives=colls,
        memory_per_device=mem,
    )
    return rep.finalize(hw)
