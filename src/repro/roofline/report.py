"""Render EXPERIMENTS.md tables from results/dryrun/*.json records.

Usage:  PYTHONPATH=src python -m repro.roofline.report results/dryrun
"""
from __future__ import annotations

import json
import os
import sys


MOVE_HINTS = {
    ("lm", "compute"): "more MXU-efficient attention kernel (flash) / bf16 logits",
    ("lm", "memory"): "fuse softmax+loss, bf16 intermediates, tighter remat policy",
    ("lm", "collective"): "overlap TP all-reduces with compute; 1-axis-less sharding of lm_head",
    ("gnn", "memory"): "fuse gather+segment_sum (probe_push-style kernel); bf16 features",
    ("gnn", "collective"): "partition edges by destination so scatters stay local",
    ("gnn", "compute"): "ELL-pack hot rows for the MXU",
    ("recsys", "memory"): "embedding-row gather is the hot path: cache hot rows",
    ("recsys", "collective"): "two-phase all-to-all for table-parallel lookups",
    ("recsys", "compute"): "batch MLP is tiny; nothing to do",
    ("probesim", "collective"): "ring ppermute over node shards + bf16 frontier",
    ("probesim", "memory"): "fused probe_push kernel (one HBM pass/level)",
    ("probesim", "compute"): "frontier sparsity-aware early levels",
}


def family_of(arch: str) -> str:
    if arch in ("gin-tu", "gcn-cora", "gatedgcn", "nequip"):
        return "gnn"
    if arch == "wide-deep":
        return "recsys"
    if arch == "probesim":
        return "probesim"
    return "lm"


def load_records(out_dir: str) -> list[dict]:
    recs = []
    for name in sorted(os.listdir(out_dir)):
        if not name.endswith(".json") or "FAILED" in name:
            continue
        with open(os.path.join(out_dir, name)) as f:
            recs.append(json.load(f))
    return recs


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(recs: list[dict], mesh: str = "single") -> str:
    rows = [
        "| arch | shape | compute | memory | collective | bottleneck | "
        "MODEL_FLOPS | useful/HLO | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or not r.get("applicable", True):
            continue
        if "compute_s" not in r:
            continue
        fam = family_of(r["arch"])
        hint = MOVE_HINTS.get((fam, r["bottleneck"]), "")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['bottleneck']}** | {r['model_flops']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | {hint} |"
        )
    return "\n".join(rows)


def skip_table(out_dir: str) -> str:
    rows = ["| arch | shape | reason |", "|---|---|---|"]
    for name in sorted(os.listdir(out_dir)):
        if name.endswith("__skip.json"):
            with open(os.path.join(out_dir, name)) as f:
                r = json.load(f)
            rows.append(f"| {r['arch']} | {r['shape']} | {r['skip_reason']} |")
    return "\n".join(rows)


def dryrun_table(recs: list[dict]) -> str:
    rows = [
        "| arch | shape | mesh | flops/dev | bytes/dev | coll bytes/dev | "
        "mem/dev (arg+tmp GB) | compile |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if "hlo_flops" not in r:
            continue
        mem = r.get("memory_per_device") or {}
        mem_s = (
            f"{mem.get('argument_gb', 0):.1f}+{mem.get('temp_gb', 0):.1f}"
            if mem else "-"
        )
        ct = r.get("full_compile_s", r.get("compile_s", 0))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['hlo_flops']:.2e} | {r['hlo_bytes']:.2e} | "
            f"{r['collective_bytes']:.2e} | {mem_s} | {ct:.0f}s |"
        )
    return "\n".join(rows)


def pick_hillclimb(recs: list[dict]) -> list[str]:
    singles = [
        r for r in recs
        if r.get("mesh") == "single" and "compute_s" in r
        and r.get("applicable", True)
    ]
    if not singles:
        return []
    worst_useful = min(
        (r for r in singles if r["model_flops"] > 0),
        key=lambda r: r["useful_flops_ratio"],
    )
    coll_bound = max(
        singles,
        key=lambda r: r["collective_s"] / max(
            r["compute_s"] + r["memory_s"], 1e-12),
    )
    paper = next((r for r in singles if r["arch"] == "probesim"), None)
    out = []
    for label, r in [("worst useful-flops ratio", worst_useful),
                     ("most collective-bound", coll_bound),
                     ("paper-representative", paper)]:
        if r is not None:
            out.append(f"{label}: {r['arch']} x {r['shape']} "
                       f"(bottleneck={r['bottleneck']}, "
                       f"useful={r['useful_flops_ratio']:.2f})")
    return out


def main() -> None:
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load_records(out_dir)
    print("## Dry-run records\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16, 256 chips)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline (multi-pod 2x16x16, 512 chips)\n")
    print(roofline_table(recs, "multi"))
    print("\n## Skipped cells\n")
    print(skip_table(out_dir))
    print("\n## Hillclimb candidates\n")
    for line in pick_hillclimb(recs):
        print("*", line)


if __name__ == "__main__":
    main()
