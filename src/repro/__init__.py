"""repro: ProbeSim (PVLDB'17) as a production-grade JAX framework.

Scalable single-source and top-k SimRank on dynamic graphs, plus the
multi-architecture substrate (LM transformers, GNNs, recsys) required by the
assignment.  See DESIGN.md for the system map.
"""

__version__ = "1.0.0"
