"""repro.serving — deprecated engines (shims over ``repro.api``) + straggler.

``SimRankEngine`` and ``DynamicEngine`` delegate to
``repro.api.SimRankSession``; new code should use the session directly.
``serving.straggler`` (deadline/hedge/shed dispatch policies) remains the
canonical home for tail-latency mitigation around any query callable —
callers that track re-dispatches against a session report them through
``SimRankSession.record_retry()`` (the stats object is owned by the
session/backend pair; never mutate its fields from outside).
"""
from repro.serving.dynamic_engine import DynamicEngine, DynamicStats, EpochResult
from repro.serving.engine import EngineStats, QueryResult, SimRankEngine

__all__ = [
    "SimRankEngine",
    "DynamicEngine",
    "QueryResult",
    "EpochResult",
    "EngineStats",
    "DynamicStats",
]
