"""repro.serving — the network serving subsystem (+ deprecated engines).

The serving stack is three layers, thin to thick:

* ``serving.protocol`` — the JSON wire schema (requests, responses,
  :class:`ProtocolError`); stdlib + numpy only, importable by clients.
* ``serving.service`` — :class:`SimRankService`: micro-batching window,
  admission control/backpressure, per-tenant sessions over shared graph
  state, serialized updates.  All policy, no sockets.
* ``serving.server`` — the threaded HTTP front end
  (:func:`start_server` / :class:`SimRankHTTPServer`) and the matching
  keep-alive :class:`ServiceClient`.

``serving.straggler`` (deadline/hedge/shed dispatch policies) remains the
canonical home for tail-latency mitigation around any query callable —
callers that track re-dispatches against a session report them through
``SimRankSession.record_retry()`` (the stats object is owned by the
session/backend pair; never mutate its fields from outside).

``SimRankEngine`` and ``DynamicEngine`` are deprecated shims over
``repro.api.SimRankSession``; new code should use the session directly.
"""
from repro.serving.dynamic_engine import DynamicEngine, DynamicStats, EpochResult
from repro.serving.engine import EngineStats, QueryResult, SimRankEngine
from repro.serving.protocol import (
    ProtocolError,
    QueryRequest,
    envelope_to_wire,
    parse_query_request,
    parse_update_request,
    update_report_to_wire,
)
from repro.serving.server import (
    ServiceClient,
    SimRankHTTPServer,
    start_server,
    stop_server,
)
from repro.serving.service import (
    AdmissionError,
    ServiceClosed,
    ServiceConfig,
    ServiceStats,
    SimRankService,
)

__all__ = [
    "SimRankEngine",
    "DynamicEngine",
    "QueryResult",
    "EpochResult",
    "EngineStats",
    "DynamicStats",
    "ProtocolError",
    "QueryRequest",
    "parse_query_request",
    "parse_update_request",
    "envelope_to_wire",
    "update_report_to_wire",
    "SimRankService",
    "ServiceConfig",
    "ServiceStats",
    "AdmissionError",
    "ServiceClosed",
    "SimRankHTTPServer",
    "ServiceClient",
    "start_server",
    "stop_server",
]
