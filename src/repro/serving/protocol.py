"""Wire schemas for the HTTP serving front end (``serving/server.py``).

One place owns the JSON contract: what a client may POST, what the
service responds, and what a malformed request looks like.  Everything
here is stdlib + numpy — no jax, no HTTP — so the schemas are importable
from clients, benchmarks and tests without touching the serving stack.

Request schema (``POST /query``)::

    {"kind": "topk" | "single_source",   # default "topk"
     "node": <int>,                       # required: the query node
     "k": <int>,                          # topk width (default: server's)
     "budget_walks": <int>,               # walk cap (anytime mode)
     "epsilon": <float>,                  # adaptive accuracy target
     "confidence": <float>,               # empirical-certificate coverage
     "deadline_s": <float>,               # relative deadline from arrival
     "seed": <int>}                       # pin the PRNG stream (parity /
                                          # reproducibility; else the
                                          # tenant session assigns one)

Batches are NOT part of the wire schema on purpose: cross-connection
micro-batching is the server's job (``serving/service.py`` cuts windows
across concurrent clients), so a client wanting Q answers opens Q
requests and lets the collector fuse them.

Update schema (``POST /update``)::

    {"inserts": [[src, dst], ...], "deletes": [[src, dst], ...]}

Responses are :func:`envelope_to_wire` dicts (the ``ResultEnvelope``
fields plus service-side metadata: queue delay, the micro-batch size the
query rode in, the tenant).  Errors are ``{"error": <message>}`` with the
HTTP status carrying the class (400 malformed, 404 route, 413 too large,
429 admission, 503 shutdown, 504 deadline shed).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

KINDS = ("single_source", "topk")

# bounds a hostile/buggy request body before numpy sees it
MAX_UPDATE_OPS = 1_000_000


class ProtocolError(ValueError):
    """Malformed wire request — maps to HTTP 400."""


@dataclasses.dataclass(frozen=True)
class QueryRequest:
    """Validated ``POST /query`` body (see module docstring for the JSON)."""

    kind: str = "topk"
    node: int = 0
    k: int | None = None
    budget_walks: int | None = None
    epsilon: float | None = None
    confidence: float | None = None
    deadline_s: float | None = None
    seed: int | None = None


def _require_int(obj: dict, name: str, *, minimum: int | None = None):
    v = obj[name]
    if v is None:
        return None
    if isinstance(v, bool) or not isinstance(v, int):
        raise ProtocolError(f"{name!r} must be an integer, got {v!r}")
    if minimum is not None and v < minimum:
        raise ProtocolError(f"{name!r} must be >= {minimum}, got {v}")
    return int(v)


def _require_float(obj: dict, name: str, *, minimum: float | None = None):
    v = obj[name]
    if v is None:
        return None
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        raise ProtocolError(f"{name!r} must be a number, got {v!r}")
    v = float(v)
    if not math.isfinite(v):
        raise ProtocolError(f"{name!r} must be finite, got {v!r}")
    if minimum is not None and v < minimum:
        raise ProtocolError(f"{name!r} must be >= {minimum}, got {v}")
    return v


_QUERY_FIELDS = frozenset(
    f.name for f in dataclasses.fields(QueryRequest)
)


def parse_query_request(obj) -> QueryRequest:
    """Validate a decoded ``POST /query`` body into a :class:`QueryRequest`.

    Unknown fields are rejected (a typo'd ``"budget_walk"`` silently
    serving the full Thm-1 budget is the failure mode this guards).
    """
    if not isinstance(obj, dict):
        raise ProtocolError(
            f"query body must be a JSON object, got {type(obj).__name__}"
        )
    unknown = sorted(set(obj) - _QUERY_FIELDS)
    if unknown:
        raise ProtocolError(
            f"unknown query field(s) {unknown} "
            f"(schema: {sorted(_QUERY_FIELDS)})"
        )
    kind = obj.get("kind", "topk")
    if kind not in KINDS:
        raise ProtocolError(f"kind must be one of {KINDS}, got {kind!r}")
    if "node" not in obj:
        raise ProtocolError("query requires a 'node' field")
    node = _require_int(obj, "node", minimum=0)
    if node is None:
        raise ProtocolError("'node' must not be null")
    merged = {**{f: None for f in _QUERY_FIELDS}, **obj}
    epsilon = _require_float(merged, "epsilon", minimum=0.0)
    confidence = _require_float(merged, "confidence")
    if confidence is not None and not 0.0 < confidence < 1.0:
        raise ProtocolError(f"confidence must be in (0, 1), got {confidence}")
    if confidence is not None and epsilon is None:
        raise ProtocolError("confidence requires epsilon (adaptive mode)")
    deadline_s = _require_float(merged, "deadline_s")
    if deadline_s is not None and deadline_s < 0.0:
        raise ProtocolError(f"deadline_s must be >= 0, got {deadline_s}")
    return QueryRequest(
        kind=kind,
        node=node,
        k=_require_int(merged, "k", minimum=1),
        budget_walks=_require_int(merged, "budget_walks", minimum=1),
        epsilon=epsilon,
        confidence=confidence,
        deadline_s=deadline_s,
        seed=_require_int(merged, "seed"),
    )


def _parse_ops(obj: dict, name: str) -> np.ndarray | None:
    ops = obj.get(name)
    if ops is None:
        return None
    if not isinstance(ops, list):
        raise ProtocolError(f"{name!r} must be a list of [src, dst] pairs")
    if len(ops) > MAX_UPDATE_OPS:
        raise ProtocolError(
            f"{name!r} carries {len(ops)} ops (limit {MAX_UPDATE_OPS}); "
            "split the batch"
        )
    out = np.empty((len(ops), 2), np.int64)
    for i, pair in enumerate(ops):
        if (
            not isinstance(pair, (list, tuple))
            or len(pair) != 2
            or any(isinstance(x, bool) or not isinstance(x, int) for x in pair)
        ):
            raise ProtocolError(
                f"{name}[{i}] must be an integer [src, dst] pair, "
                f"got {pair!r}"
            )
        out[i] = pair
    if out.size and out.min() < 0:
        raise ProtocolError(f"{name!r} contains a negative node id")
    return out


def parse_update_request(obj) -> tuple[np.ndarray | None, np.ndarray | None]:
    """Validate a ``POST /update`` body into (inserts, deletes) op arrays.

    Each is an ``[B, 2]`` int array of (src, dst) pairs, or ``None`` when
    the field is absent.  At least one must be present and non-empty.
    """
    if not isinstance(obj, dict):
        raise ProtocolError(
            f"update body must be a JSON object, got {type(obj).__name__}"
        )
    unknown = sorted(set(obj) - {"inserts", "deletes"})
    if unknown:
        raise ProtocolError(
            f"unknown update field(s) {unknown} "
            "(schema: ['deletes', 'inserts'])"
        )
    inserts = _parse_ops(obj, "inserts")
    deletes = _parse_ops(obj, "deletes")
    if (inserts is None or not len(inserts)) and (
        deletes is None or not len(deletes)
    ):
        raise ProtocolError("update carries no ops (inserts/deletes empty)")
    return inserts, deletes


def _jsonable(x):
    """Host-side scalars/arrays -> JSON-clean values (NaN -> None)."""
    if x is None:
        return None
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, (np.floating, float)):
        x = float(x)
        return x if math.isfinite(x) else None
    if isinstance(x, (np.integer, int)):
        return int(x)
    return x


def envelope_to_wire(env, **extra) -> dict:
    """``ResultEnvelope`` -> response dict (module docstring schema).

    ``extra`` carries the service-side fields (``tenant``,
    ``queue_delay_s``, ``batch_size``).  Score arrays are emitted as JSON
    lists; float32 values survive the round trip exactly (JSON ``repr``
    of the exact float64 widening), so clients can reproduce bitwise
    parity against a local session under matched streams.
    """
    out = dict(
        kind=env.kind,
        node=_jsonable(env.node),
        walks_used=_jsonable(env.walks_used),
        latency_s=_jsonable(env.latency_s),
        version=_jsonable(env.version),
        error_bound=_jsonable(env.error_bound),
        variant=env.variant,
    )
    if env.scores is not None:
        out["scores"] = _jsonable(np.asarray(env.scores))
    if env.topk_nodes is not None:
        out["topk_nodes"] = _jsonable(np.asarray(env.topk_nodes))
        out["topk_scores"] = _jsonable(np.asarray(env.topk_scores))
    if env.epsilon is not None:
        out["epsilon"] = _jsonable(env.epsilon)
        out["certified_bound"] = _jsonable(env.certified_bound)
        out["certificate"] = env.certificate
        out["rounds"] = _jsonable(env.rounds)
    out.update({k: _jsonable(v) for k, v in extra.items()})
    return out


def update_report_to_wire(rep, **extra) -> dict:
    """``UpdateReport`` -> ``POST /update`` response dict."""
    out = dict(
        submitted=int(rep.submitted),
        applied=int(rep.applied),
        regrows=int(rep.regrows),
        skipped=len(rep.skipped),
        version=int(rep.version),
        overflow=bool(rep.overflow),
    )
    out.update({k: _jsonable(v) for k, v in extra.items()})
    return out
