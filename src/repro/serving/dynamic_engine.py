"""DEPRECATED: ``DynamicEngine`` is a thin shim over ``repro.api``.

The session API absorbs the fused update->query epoch path: the jitted
``epoch_step`` is the local stage of the backend-agnostic epoch pipeline
in ``repro.core.epoch`` (re-exported through ``repro.api.session`` for
legacy importers), and the epoch loop (batch cutting, overflow requeue,
auto-regrow) lives in ``repro.api.session``; ``SimRankSession.epoch`` is
the one entrypoint for "apply an update batch and serve a query batch in
a single compiled dispatch" — on any backend that implements the stage.
This module remains so existing callers keep working; it delegates to an
owned session and is bit-identical to the pre-session engine under the
same PRNG seed.

Migration:

    eng = DynamicEngine(g, eg, top_k=10, batch_q=4, update_batch=64)  # old
    eng.insert(s, d); eng.submit(u); ep = eng.step()

    sess = SimRankSession(GraphHandle(g=g, eg=eg),                    # new
                          top_k=10, batch_q=4, update_batch=64)
    ep = sess.epoch(inserts=(s, d), queries=[u])
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

from repro.api.handle import GraphHandle
from repro.api.session import (  # re-exported for legacy importers
    EpochResult,
    SimRankSession,
    epoch_step,
)
from repro.graph.structs import EllGraph, Graph

__all__ = ["DynamicEngine", "DynamicStats", "EpochResult", "epoch_step"]


@dataclass
class DynamicStats:
    """Legacy stats view (superseded by ``repro.api.EngineStats``)."""

    epochs: int = 0
    queries: int = 0
    updates_applied: int = 0
    regrows: int = 0


class DynamicEngine:
    """Deprecated shim — use :class:`repro.api.SimRankSession.epoch`.

    Same constructor and methods as the PR-2 engine; every call delegates
    to a session constructed over ``GraphHandle(g=g, eg=eg)`` (own-copied;
    the epoch step donates the session's buffers, the caller's arrays stay
    valid).
    """

    def __init__(
        self,
        g: Graph,
        eg: EllGraph,
        *,
        c: float = 0.6,
        eps_a: float = 0.1,
        delta: float = 0.01,
        walk_chunk: int = 256,
        top_k: int = 50,
        seed: int = 0,
        batch_q: int = 8,
        update_batch: int = 64,
        auto_regrow: bool = True,
        use_kernel: bool = False,
    ):
        warnings.warn(
            "DynamicEngine is deprecated; use repro.api.SimRankSession.epoch "
            "over a GraphHandle (see docs/api.md)",
            DeprecationWarning,
            stacklevel=2,
        )
        if top_k < 1:
            # legacy contract: this engine always built top-k results
            raise ValueError("DynamicEngine requires top_k >= 1")
        self._session = SimRankSession(
            GraphHandle(g=g, eg=eg),
            c=c, eps_a=eps_a, delta=delta, walk_chunk=walk_chunk,
            top_k=top_k, seed=seed, batch_q=batch_q,
            update_batch=update_batch, auto_regrow=auto_regrow,
            use_kernel=use_kernel,
        )
        self._stats = DynamicStats()  # ONE live object (legacy contract)

    # -- delegated state -----------------------------------------------------

    @property
    def session(self) -> SimRankSession:
        """The underlying session (migration escape hatch)."""
        return self._session

    @property
    def g(self) -> Graph:
        return self._session.handle.g

    @g.setter
    def g(self, value: Graph) -> None:
        # own-copy + validate: epoch_step donates the session's buffers, so
        # they must never be shared with the caller (legacy contract: the
        # caller's arrays stay valid)
        self._session.handle.set_mirrors(g=value)

    @property
    def eg(self) -> EllGraph:
        return self._session.handle.eg

    @eg.setter
    def eg(self, value: EllGraph) -> None:
        self._session.handle.set_mirrors(eg=value)

    @property
    def params(self):
        return self._session.params

    # legacy engines exposed these as plain mutable attributes
    @property
    def update_batch(self) -> int:
        return self._session.update_batch

    @update_batch.setter
    def update_batch(self, value: int) -> None:
        self._session.update_batch = int(value)

    @property
    def batch_q(self) -> int:
        return self._session.batch_q

    @batch_q.setter
    def batch_q(self, value: int) -> None:
        self._session.batch_q = int(value)

    @property
    def walk_chunk(self) -> int:
        return self._session.walk_chunk

    @walk_chunk.setter
    def walk_chunk(self, value: int) -> None:
        self._session.walk_chunk = int(value)

    @property
    def top_k(self) -> int:
        return self._session.top_k

    @top_k.setter
    def top_k(self, value: int) -> None:
        self._session.top_k = int(value)

    @property
    def auto_regrow(self) -> bool:
        return self._session.auto_regrow

    @auto_regrow.setter
    def auto_regrow(self, value: bool) -> None:
        self._session.auto_regrow = bool(value)

    @property
    def use_kernel(self) -> bool:
        return self._session.use_kernel

    @use_kernel.setter
    def use_kernel(self, value: bool) -> None:
        self._session.use_kernel = bool(value)

    def _refresh_stats(self) -> None:
        s = self._session.stats
        self._stats.epochs = s.epochs
        self._stats.queries = s.queries
        self._stats.updates_applied = s.updates
        self._stats.regrows = s.regrows

    @property
    def stats(self) -> DynamicStats:
        # one persistent object, refreshed from the session counters — a
        # reference held across step()/drain() stays current, as with the
        # pre-session engine's mutable stats field
        self._refresh_stats()
        return self._stats

    @property
    def version(self) -> int:
        return self._session.version

    @property
    def overflow(self) -> bool:
        return self._session.overflow

    @property
    def pending(self) -> tuple[int, int]:
        """(queued updates, queued queries)."""
        return self._session.pending

    # -- enqueue -------------------------------------------------------------

    def insert(self, src, dst) -> None:
        """Enqueue edge insertions (applied by the next epoch step(s))."""
        self._session.queue_update(src, dst, insert=True)

    def delete(self, src, dst) -> None:
        """Enqueue edge deletions."""
        self._session.queue_update(src, dst, insert=False)

    def submit(self, node: int) -> None:
        """Enqueue a top-k query (PRNG stream fixed NOW: batch-invariant)."""
        self._session.submit(int(node))

    # -- the epoch loop ------------------------------------------------------

    def step(self, *, budget_walks: int | None = None) -> EpochResult:
        """Run ONE fused update->query epoch (see ``SimRankSession.epoch``)."""
        ep = self._session.epoch(budget_walks=budget_walks)
        self._refresh_stats()
        return ep

    def drain(self, *, budget_walks: int | None = None) -> list[EpochResult]:
        """Run epochs until both queues are empty."""
        out = self._session.drain_epochs(budget_walks=budget_walks)
        self._refresh_stats()
        return out
