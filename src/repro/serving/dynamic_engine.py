"""Dynamic serving engine — fused update→query *epochs* on device.

The paper's headline claim is that ProbeSim is index-free and therefore
"can naturally support real-time SimRank queries on dynamic graphs".
``SimRankEngine`` serves a graph that mutates *between* dispatches;
``DynamicEngine`` goes one step further and makes the update part of the
serve step itself: one jitted **epoch step**

    (graph_state, update_batch, query_batch) -> (graph_state', scores)

applies a fixed-size padded batch of edge insertions/deletions to both
device mirrors (COO + ELL, ``graph.dynamic.apply_update_batch``) and then
runs the fused multi-query probe (``core.multisource.fused_serve_impl``) on
the *updated* graph — with **zero host transfers between update and query**.
Scores returned by an epoch are therefore exact w.r.t. the post-update
snapshot; the snapshot's ``version`` is stamped on every result.

Contrast with the paper's index-based competitors: TSF must rebuild its R_g
one-way graphs and SLING its whole index before the first fresh query; here
update→queryable latency is one O(B) on-device batch application
(``benchmarks/bench_dynamic.py`` measures both paths).

Shapes are static per (update_batch, batch_q, …) configuration, so jit
compiles ONE epoch step and every epoch reuses it:

* update batches are padded to ``update_batch`` ops with sentinel no-op
  edges (masked everywhere — an all-padding batch is an identity update);
* query batches are padded to ``batch_q`` by repeating the last live query
  (padded slots recompute an already-answered query and are discarded);
* capacity overflow is an explicit signal, not a silent drop: inserts that
  find no room (COO buffer or ELL row) are skipped in both mirrors, flagged
  sticky on the returned state, and — with ``auto_regrow`` — retried on the
  next epoch after a host-side ``regrow`` (compaction + 2x buffers).

Randomness: like ``SimRankEngine``, every query gets its own PRNG stream at
submit time (fold_in of the engine seed and the submission sequence number),
so epoch batching never changes a query's answer (docs/api.md).

Usage::

    eng = DynamicEngine(g, eg, top_k=10, batch_q=4, update_batch=64)
    eng.insert(new_src, new_dst)      # enqueue updates ...
    eng.delete(old_src, old_dst)
    for u in nodes:
        eng.submit(u)                 # ... and queries
    ep = eng.step()                   # ONE compiled dispatch: update + query
    for res in ep.results:
        print(res.node, res.version, res.topk_nodes)
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.multisource import fused_serve_impl
from repro.core.params import ProbeSimParams, make_params
from repro.graph.dynamic import (
    UpdateBatch,
    apply_update_batch,
    apply_update_batch_jit,
    make_update_batch,
    regrow,
)
from repro.graph.structs import EllGraph, Graph
from repro.serving.engine import QueryResult

Array = jax.Array


@partial(
    jax.jit,
    static_argnames=(
        "n_r",
        "lanes_q",
        "max_len",
        "sqrt_c",
        "eps_p",
        "eps_t",
        "truncation_shift",
        "use_kernel",
        "top_k",
    ),
    # g/eg are donated so the update scan writes the graph buffers in place
    # (backends that support donation) instead of copying capacity-sized
    # arrays every epoch — the engine owns its graph state (see __init__)
    # and always replaces it with the returned g'/eg'
    donate_argnames=("acc", "g", "eg"),
)
def epoch_step(
    g: Graph,
    eg: EllGraph,
    batch: UpdateBatch,
    keys: Array,  # [Q] typed PRNG keys, one stream per query
    us: Array,  # int32 [Q]
    acc: Array,  # f32 [Q, n] donated accumulator
    *,
    n_r: int,
    lanes_q: int,
    max_len: int,
    sqrt_c: float,
    eps_p: float,
    eps_t: float,
    truncation_shift: bool,
    use_kernel: bool,
    top_k: int,
):
    """One fused epoch: apply the update batch, then serve the query batch.

    Everything happens inside one compiled step on device — the query probe
    reads the graph buffers the update scan just wrote, with no host
    round-trip in between.  Returns ``(g', eg', applied, est, idx, vals)``
    (``idx``/``vals`` are None when ``top_k == 0``); ``g'.version`` /
    ``g'.overflow`` carry the snapshot id and capacity signal.
    """
    g2, eg2, applied = apply_update_batch(g, eg, batch)
    acc, est, idx, vals = fused_serve_impl(
        keys, g2, eg2, us, acc,
        n_r=n_r,
        lanes_q=lanes_q,
        max_len=max_len,
        sqrt_c=sqrt_c,
        eps_p=eps_p,
        eps_t=eps_t,
        truncation_shift=truncation_shift,
        use_kernel=use_kernel,
        top_k=top_k,
    )
    return g2, eg2, applied, est, idx, vals


@dataclass
class EpochResult:
    """Outcome of one fused update→query epoch."""

    version: int  # graph snapshot id AFTER the update batch
    overflow: bool  # sticky capacity signal (pre-regrow value)
    regrown: bool  # True if auto_regrow ran after this epoch
    updates_submitted: int  # live (non-padding) ops in the batch
    updates_applied: int  # ops that changed the graph
    updates_requeued: int  # overflow-skipped inserts pushed back for retry
    # overflow-skipped inserts this epoch, as (src, dst, True) tuples.  With
    # auto_regrow they are also re-queued (updates_requeued); without, the
    # caller regrows manually and re-submits these — never silently lost
    skipped_ops: list[tuple[int, int, bool]] = field(default_factory=list)
    results: list[QueryResult] = field(default_factory=list)
    latency_s: float = 0.0


@dataclass
class DynamicStats:
    epochs: int = 0
    queries: int = 0
    updates_applied: int = 0
    regrows: int = 0


class DynamicEngine:
    """Single-host engine interleaving edge updates and queries per epoch.

    ``update_batch`` is the fixed op-batch width of the epoch step (short
    batches are sentinel-padded), ``batch_q`` the fixed query width (padded
    with repeats), ``walk_chunk`` the total lane-column width shared by the
    query batch — one compiled epoch per configuration.

    With ``auto_regrow`` (default), a capacity overflow triggers host-side
    compaction into 2x buffers after the epoch and re-queues the skipped
    inserts at the front, so no update is ever lost; the epoch that hit the
    limit still served its queries on the partially-updated snapshot (its
    ``EpochResult.overflow`` says so).  With ``auto_regrow=False`` the
    skipped inserts are surfaced in ``EpochResult.skipped_ops`` instead —
    the caller regrows (``graph.dynamic.regrow`` on ``self.g``/``self.eg``)
    and re-submits them; either way nothing is silently dropped.

    The engine OWNS its graph state: ``g``/``eg`` are copied at
    construction and the epoch step donates the copies, so graph buffers
    update in place on backends with donation while the caller's arrays
    stay valid.
    """

    def __init__(
        self,
        g: Graph,
        eg: EllGraph,
        *,
        c: float = 0.6,
        eps_a: float = 0.1,
        delta: float = 0.01,
        walk_chunk: int = 256,
        top_k: int = 50,
        seed: int = 0,
        batch_q: int = 8,
        update_batch: int = 64,
        auto_regrow: bool = True,
        use_kernel: bool = False,
    ):
        if top_k < 1:
            # step() builds top-k QueryResults; the top_k == 0 (full
            # estimate vector) mode of epoch_step has no result shape here
            raise ValueError("DynamicEngine requires top_k >= 1")
        if g.version is None:
            g = g.replace(
                version=jnp.asarray(0, jnp.int32), overflow=jnp.asarray(False)
            )
        if eg.version is None:
            eg = eg.replace(
                version=jnp.asarray(0, jnp.int32), overflow=jnp.asarray(False)
            )
        # own-copy the graph state: epoch_step donates g/eg, so the engine
        # must hold buffers nobody else references (a one-time O(graph)
        # copy; the caller's arrays stay valid)
        self.g = jax.tree.map(lambda a: jnp.array(a, copy=True), g)
        self.eg = jax.tree.map(lambda a: jnp.array(a, copy=True), eg)
        self.params: ProbeSimParams = make_params(
            g.n, c=c, eps_a=eps_a, delta=delta
        )
        self.walk_chunk = walk_chunk
        self.top_k = top_k
        self.batch_q = batch_q
        self.update_batch = update_batch
        self.auto_regrow = auto_regrow
        self.use_kernel = use_kernel
        self.key = jax.random.key(seed)
        self.update_queue: deque[tuple[int, int, bool]] = deque()
        self.query_queue: deque[tuple[int, Array]] = deque()
        self.stats = DynamicStats()
        self._seq = 0  # submission counter -> per-query PRNG stream

    # -- enqueue ------------------------------------------------------------

    def _enqueue(self, src, dst, insert: bool) -> None:
        src = np.asarray(src).reshape(-1)
        dst = np.asarray(dst).reshape(-1)
        # validate HERE: out-of-range ids would be sentinel-masked to no-ops
        # downstream and then mistaken for capacity-overflow skips, feeding
        # an unbounded requeue/regrow loop
        n = self.g.n
        bad = (src < 0) | (src >= n) | (dst < 0) | (dst >= n)
        if bad.any():
            i = int(np.argmax(bad))
            raise ValueError(
                f"edge op ({src[i]}, {dst[i]}) out of range for n={n}"
            )
        for s, d in zip(src, dst):
            self.update_queue.append((int(s), int(d), insert))

    def insert(self, src, dst) -> None:
        """Enqueue edge insertions (applied by the next epoch step(s))."""
        self._enqueue(src, dst, True)

    def delete(self, src, dst) -> None:
        """Enqueue edge deletions."""
        self._enqueue(src, dst, False)

    def _query_key(self) -> Array:
        k = jax.random.fold_in(self.key, self._seq)
        self._seq += 1
        return k

    def submit(self, node: int) -> None:
        """Enqueue a top-k query (PRNG stream fixed NOW: batch-invariant)."""
        self.query_queue.append((int(node), self._query_key()))

    # -- state --------------------------------------------------------------

    @property
    def version(self) -> int:
        return int(self.eg.version)

    @property
    def overflow(self) -> bool:
        return bool(self.g.overflow)

    @property
    def pending(self) -> tuple[int, int]:
        """(queued updates, queued queries)."""
        return len(self.update_queue), len(self.query_queue)

    # -- the epoch loop -----------------------------------------------------

    def _pop_updates(self) -> tuple[list[tuple[int, int, bool]], UpdateBatch]:
        # apply_update_batch runs its delete phase before its insert phase
        # and deletes at most one copy of a (s, d) pair per batch, so a batch
        # must not contain (a) a delete of an edge inserted earlier in the
        # SAME batch, nor (b) a second delete of the same pair (multigraph
        # copies) — cut the epoch's batch there (the delete waits for the
        # next epoch) to preserve exact stream order
        ops: list[tuple[int, int, bool]] = []
        inserted: set[tuple[int, int]] = set()
        deleted: set[tuple[int, int]] = set()
        while self.update_queue and len(ops) < self.update_batch:
            s, d, ins = self.update_queue[0]
            if not ins and ((s, d) in inserted or (s, d) in deleted):
                break
            (inserted if ins else deleted).add((s, d))
            ops.append(self.update_queue.popleft())
        batch = make_update_batch(
            [s for s, _, _ in ops],
            [d for _, d, _ in ops],
            [i for _, _, i in ops] if ops else True,
            batch_size=self.update_batch,
            n=self.g.n,
        )
        return ops, batch

    def _pop_queries(self) -> tuple[int, list[tuple[int, Array]]]:
        live = min(self.batch_q, len(self.query_queue))
        qs = [self.query_queue.popleft() for _ in range(live)]
        while len(qs) < self.batch_q:
            # repeat-pad (recomputes a served query; results discarded) —
            # node 0 with a throwaway stream when the queue was empty
            qs.append(qs[-1] if qs else (0, self._query_key()))
        return live, qs

    def step(self, *, budget_walks: int | None = None) -> EpochResult:
        """Run ONE fused epoch: up to ``update_batch`` queued ops + up to
        ``batch_q`` queued queries in a single compiled dispatch.

        Update-only epochs (empty query queue) dispatch just the batch
        application — no point paying the fused probe for discarded dummy
        queries."""
        ops, batch = self._pop_updates()
        n_r = budget_walks or self.params.n_r
        p = self.params

        t0 = time.time()
        if self.query_queue:
            live_q, qs = self._pop_queries()
            us = jnp.asarray([u for u, _ in qs], jnp.int32)
            keys = jnp.stack([k for _, k in qs])
            acc = jnp.zeros((self.batch_q, self.g.n), jnp.float32)
            g2, eg2, applied, _, idx, vals = epoch_step(
                self.g, self.eg, batch, keys, us, acc,
                n_r=n_r,
                lanes_q=max(1, self.walk_chunk // self.batch_q),
                max_len=p.max_len,
                sqrt_c=p.sqrt_c,
                eps_p=p.eps_p,
                eps_t=p.eps_t,
                truncation_shift=p.truncation_shift,
                use_kernel=self.use_kernel,
                top_k=self.top_k,
            )
            idx = np.asarray(idx)  # device sync (also materializes g2/eg2)
            vals = np.asarray(vals)
        else:
            live_q, qs = 0, []
            g2, eg2, applied = apply_update_batch_jit(self.g, self.eg, batch)
        applied = np.asarray(applied)[: len(ops)]
        dt = time.time() - t0
        self.g, self.eg = g2, eg2

        version = self.version
        overflow = self.overflow
        regrown = False
        requeued = 0
        # skipped inserts (applied == False); unapplied deletes were
        # genuinely absent — those are not retried or surfaced
        skipped = [op for op, ok in zip(ops, applied) if not ok and op[2]]
        if skipped and self.auto_regrow:
            # retry on the regrown buffers next epoch
            for op in reversed(skipped):
                self.update_queue.appendleft(op)
            requeued = len(skipped)
            self.g, self.eg = regrow(self.g, self.eg)
            self.stats.regrows += 1
            regrown = True

        results = [
            QueryResult(
                node=u,
                topk_nodes=idx[i],
                topk_scores=vals[i],
                walks_used=n_r,
                latency_s=dt,
                version=version,
            )
            for i, (u, _) in enumerate(qs[:live_q])
        ]
        self.stats.epochs += 1
        self.stats.queries += live_q
        self.stats.updates_applied += int(applied.sum())
        return EpochResult(
            version=version,
            overflow=overflow,
            regrown=regrown,
            updates_submitted=len(ops),
            updates_applied=int(applied.sum()),
            updates_requeued=requeued,
            skipped_ops=skipped,
            results=results,
            latency_s=dt,
        )

    def drain(self, *, budget_walks: int | None = None) -> list[EpochResult]:
        """Run epochs until both queues are empty."""
        out: list[EpochResult] = []
        while self.update_queue or self.query_queue:
            out.append(self.step(budget_walks=budget_walks))
        return out
