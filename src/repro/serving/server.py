"""Threaded HTTP/JSON front end over :class:`SimRankService` (stdlib only).

The server is deliberately thin: sockets, routing, JSON framing, and the
HTTP translation of service outcomes (200 envelope, 400 protocol, 429
admission + ``Retry-After``, 503 shutdown, 504 deadline shed).  All
serving policy — micro-batch windows, admission bounds, tenant routing,
update serialization — lives in ``serving/service.py``; all wire schema
lives in ``serving/protocol.py``.

Routes::

    POST /query    body: protocol.parse_query_request schema
    POST /update   body: protocol.parse_update_request schema
    GET  /stats    service counters + per-tenant session stats
    GET  /healthz  liveness / backend / graph version

Tenancy rides the ``X-Tenant`` header (default tenant when absent); each
tenant gets its own session/PRNG/stats namespace over the one shared
graph (see ``SimRankService.session``).

Concurrency model: ``ThreadingHTTPServer`` gives every connection a
handler thread, but handler threads only parse, enqueue and wait — every
jax dispatch happens on the service's single collector thread, so N
concurrent clients never trace concurrently and their queries fuse into
lane-batched steps.  ``request_queue_size`` is raised well above the
admission bound so a thundering herd meets the 429 path, not a TCP RST.

:class:`ServiceClient` is the matching stdlib client (keep-alive
``http.client`` with retry-on-429 honoring ``Retry-After``) used by the
load bench, the README quickstart and the tests.
"""
from __future__ import annotations

import http.client
import json
import random
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.serving.protocol import (
    ProtocolError,
    parse_query_request,
    parse_update_request,
)
from repro.serving.service import (
    DEFAULT_TENANT,
    AdmissionError,
    ServiceClosed,
    SimRankService,
    validate_tenant,
)

MAX_BODY_BYTES = 64 * 1024 * 1024  # 413 past this, before reading it all


class SimRankHTTPServer(ThreadingHTTPServer):
    """One service behind a threading HTTP server.

    ``daemon_threads`` so a hung client never blocks shutdown;
    ``request_queue_size`` sized for a connect herd larger than
    ``max_inflight`` (backpressure is the service's 429, not a refused
    TCP connection).
    """

    daemon_threads = True
    allow_reuse_address = True
    request_queue_size = 1024

    def __init__(self, addr, service: SimRankService):
        self.service = service
        super().__init__(addr, _Handler)


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"  # keep-alive: the bench reuses sockets
    server: SimRankHTTPServer

    # -- plumbing ------------------------------------------------------------

    def log_message(self, fmt, *args):  # noqa: D102 — stderr spam off
        pass

    def _send_json(self, status: int, payload: dict, headers=()) -> None:
        body = json.dumps(payload).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in headers:
            self.send_header(k, v)
        self.end_headers()
        try:
            self.wfile.write(body)
        except (BrokenPipeError, ConnectionResetError):
            pass  # client gave up (deadline'd out); nothing to salvage

    def _read_json(self):
        length = int(self.headers.get("Content-Length") or 0)
        if length > MAX_BODY_BYTES:
            raise ProtocolError(
                f"body of {length} bytes exceeds the {MAX_BODY_BYTES} limit"
            )
        raw = self.rfile.read(length)
        try:
            return json.loads(raw) if raw else {}
        except json.JSONDecodeError as e:
            raise ProtocolError(f"body is not valid JSON: {e}") from None

    def _tenant(self) -> str:
        return validate_tenant(
            self.headers.get("X-Tenant", DEFAULT_TENANT)
        )

    # -- routes --------------------------------------------------------------

    def do_GET(self) -> None:  # noqa: N802 — http.server API
        svc = self.server.service
        if self.path == "/healthz":
            self._send_json(200, svc.healthz())
        elif self.path == "/stats":
            self._send_json(200, svc.stats_snapshot())
        else:
            self._send_json(404, {"error": f"no such route: GET {self.path}"})

    def do_POST(self) -> None:  # noqa: N802 — http.server API
        svc = self.server.service
        try:
            if self.path == "/query":
                req = parse_query_request(self._read_json())
                status, payload = svc.serve_request(req, self._tenant())
                self._send_json(status, payload)
            elif self.path == "/update":
                inserts, deletes = parse_update_request(self._read_json())
                self._send_json(200, svc.apply_update(inserts, deletes))
            else:
                self._send_json(
                    404, {"error": f"no such route: POST {self.path}"}
                )
        except ProtocolError as e:
            self._send_json(400, {"error": str(e)})
        except AdmissionError as e:
            self._send_json(
                429,
                {"error": str(e), "retry_after_s": e.retry_after_s},
                headers=[("Retry-After", str(max(1, round(e.retry_after_s))))],
            )
        except ServiceClosed as e:
            self._send_json(503, {"error": str(e)})
        except Exception as e:  # a handler thread must never die silently
            svc.stats.errors_5xx += 1
            self._send_json(500, {"error": f"{type(e).__name__}: {e}"})


def start_server(
    service: SimRankService,
    host: str = "127.0.0.1",
    port: int = 0,
) -> tuple[SimRankHTTPServer, threading.Thread]:
    """Bind and serve in a daemon thread; returns (server, thread).

    ``port=0`` picks a free port (read it back from
    ``server.server_address``).  Shut down with :func:`stop_server` —
    it closes the service (flushing in-flight requests) before the
    socket, so no accepted request is dropped on the floor.
    """
    server = SimRankHTTPServer((host, port), service)
    thread = threading.Thread(
        target=server.serve_forever, daemon=True,
        name="probesim-http",
    )
    thread.start()
    return server, thread


def stop_server(
    server: SimRankHTTPServer, thread: threading.Thread | None = None
) -> None:
    """Graceful shutdown: drain the service, then stop accepting."""
    server.service.close()
    server.shutdown()
    server.server_close()
    if thread is not None:
        thread.join(timeout=10.0)


class ServiceClient:
    """Keep-alive stdlib client for one server — the bench/test harness.

    One instance per client thread (``http.client`` connections are not
    thread-safe).  ``query()`` retries 429s honoring the service's
    ``retry_after_s`` hint up to ``max_retries`` times, then surfaces the
    429 — so closed-loop load generators exercise backpressure without
    hand-rolling backoff.
    """

    def __init__(
        self,
        host: str,
        port: int,
        tenant: str = DEFAULT_TENANT,
        timeout_s: float = 120.0,
        max_retries: int = 64,
    ):
        self.host = host
        self.port = port
        self.tenant = tenant
        self.timeout_s = timeout_s
        self.max_retries = max_retries
        self._conn: http.client.HTTPConnection | None = None

    def _connection(self) -> http.client.HTTPConnection:
        if self._conn is None:
            self._conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout_s
            )
        return self._conn

    def _request(
        self, method: str, path: str, body: dict | None = None
    ) -> tuple[int, dict]:
        payload = None if body is None else json.dumps(body).encode()
        headers = {"X-Tenant": self.tenant}
        if payload is not None:
            headers["Content-Type"] = "application/json"
        for attempt in (0, 1):  # one transparent reconnect on a stale socket
            conn = self._connection()
            try:
                conn.request(method, path, body=payload, headers=headers)
                resp = conn.getresponse()
                data = resp.read()
                return resp.status, (json.loads(data) if data else {})
            except (
                http.client.HTTPException, ConnectionError, OSError,
            ):
                self.close()
                if attempt:
                    raise
        raise AssertionError("unreachable")

    def query(self, **fields) -> dict:
        """POST /query; kwargs are the wire fields (node=, kind=, ...).

        Returns the response payload; raises ``RuntimeError`` on any
        terminal non-200 (after 429 retries are exhausted)."""
        for _ in range(self.max_retries + 1):
            status, payload = self._request("POST", "/query", fields)
            if status == 429:
                # jitter on top of the service's hint: a herd of clients
                # rejected together must not retry together
                hint = float(payload.get("retry_after_s", 0.05))
                time.sleep(max(hint, 0.02) * (0.75 + 0.5 * random.random()))
                continue
            if status != 200:
                raise RuntimeError(
                    f"POST /query -> {status}: {payload.get('error')}"
                )
            return payload
        raise RuntimeError(
            f"POST /query still 429 after {self.max_retries} retries"
        )

    def query_raw(self, **fields) -> tuple[int, dict]:
        """POST /query without retries: (status, payload) as-is."""
        return self._request("POST", "/query", fields)

    def update(self, inserts=None, deletes=None) -> dict:
        body = {}
        if inserts is not None:
            body["inserts"] = [[int(s), int(d)] for s, d in inserts]
        if deletes is not None:
            body["deletes"] = [[int(s), int(d)] for s, d in deletes]
        status, payload = self._request("POST", "/update", body)
        if status != 200:
            raise RuntimeError(
                f"POST /update -> {status}: {payload.get('error')}"
            )
        return payload

    def stats(self) -> dict:
        status, payload = self._request("GET", "/stats")
        if status != 200:
            raise RuntimeError(f"GET /stats -> {status}")
        return payload

    def healthz(self) -> dict:
        status, payload = self._request("GET", "/healthz")
        if status != 200:
            raise RuntimeError(f"GET /healthz -> {status}")
        return payload

    def close(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            finally:
                self._conn = None

    def __enter__(self) -> "ServiceClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
