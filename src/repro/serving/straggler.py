"""Straggler mitigation for distributed query serving.

On a large mesh a single slow/failed worker stalls the whole SPMD step.
Mitigations implemented here (host-side policy around the jit'd step):

* **deadline + retry**: dispatch with a wall-clock deadline; on miss, retry
  on the replica group (queries are pure -> idempotent);
* **hedged dispatch**: optionally launch the same batch on two replica
  groups and take the first result (classic tail-latency hedging);
* **work shedding**: under deadline pressure, reduce the walk budget of the
  retry (ProbeSim is an anytime estimator — fewer walks = graceful accuracy
  degradation, bounded by Thm 1 with the reduced n_r).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable


@dataclass
class HedgePolicy:
    deadline_s: float = 5.0
    max_retries: int = 2
    shed_factor: float = 0.5  # walk-budget multiplier per retry
    hedge: bool = False


class DeadlineError(TimeoutError):
    pass


def run_with_deadline(fn: Callable, *args, deadline_s: float, **kwargs):
    """Run fn in a worker thread; raise DeadlineError if it misses."""
    result: list = []
    err: list = []

    def work():
        try:
            result.append(fn(*args, **kwargs))
        except Exception as e:  # pragma: no cover - propagated below
            err.append(e)

    t = threading.Thread(target=work, daemon=True)
    t.start()
    t.join(timeout=deadline_s)
    if err:
        raise err[0]
    if not result:
        raise DeadlineError(f"missed {deadline_s}s deadline")
    return result[0]


def dispatch(
    fn: Callable,
    *args,
    policy: HedgePolicy,
    budget_key: str = "budget_walks",
    budget: int | None = None,
    on_retry: Callable[[int], None] | None = None,
    **kwargs,
):
    """Deadline + retry-with-shedding wrapper around a query function."""
    attempt = 0
    cur_budget = budget
    while True:
        try:
            if cur_budget is not None:
                kwargs[budget_key] = max(1, int(cur_budget))
            return run_with_deadline(
                fn, *args, deadline_s=policy.deadline_s, **kwargs
            )
        except DeadlineError:
            attempt += 1
            if attempt > policy.max_retries:
                raise
            if on_retry is not None:
                on_retry(attempt)
            if cur_budget is not None:
                cur_budget = int(cur_budget * policy.shed_factor)
