"""Straggler mitigation for distributed query serving.

On a large mesh a single slow/failed worker stalls the whole SPMD step.
Mitigations implemented here (host-side policy around the jit'd step):

* **deadline + retry**: dispatch with a wall-clock deadline; on miss, retry
  on the replica group (queries are pure -> idempotent);
* **hedged dispatch**: optionally launch the same batch on two replica
  groups and take the first result (classic tail-latency hedging);
* **work shedding**: under deadline pressure, reduce the walk budget of the
  retry (ProbeSim is an anytime estimator — fewer walks = graceful accuracy
  degradation, bounded by Thm 1 with the reduced n_r);
* **adaptive clamping** (:func:`dispatch_adaptive`): an adaptive (epsilon)
  query carries the deadline IN-BAND — the accuracy controller checks it
  between escalation rounds and freezes still-live queries with their
  best-so-far certificate (``certificate='deadline'``) instead of raising,
  so a deadline miss degrades accuracy, not availability.  A thread
  backstop still bounds a genuinely wedged dispatch.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable


@dataclass
class HedgePolicy:
    deadline_s: float = 5.0
    max_retries: int = 2
    shed_factor: float = 0.5  # walk-budget multiplier per retry
    hedge: bool = False


class DeadlineError(TimeoutError):
    pass


def run_with_deadline(fn: Callable, *args, deadline_s: float, **kwargs):
    """Run fn in a worker thread; raise DeadlineError if it misses."""
    result: list = []
    err: list = []

    def work():
        try:
            result.append(fn(*args, **kwargs))
        except Exception as e:  # pragma: no cover - propagated below
            err.append(e)

    t = threading.Thread(target=work, daemon=True)
    t.start()
    t.join(timeout=deadline_s)
    if err:
        raise err[0]
    if not result:
        raise DeadlineError(f"missed {deadline_s}s deadline")
    return result[0]


def dispatch(
    fn: Callable,
    *args,
    policy: HedgePolicy,
    budget_key: str = "budget_walks",
    budget: int | None = None,
    on_retry: Callable[[int], None] | None = None,
    **kwargs,
):
    """Deadline + retry-with-shedding wrapper around a query function."""
    attempt = 0
    cur_budget = budget
    while True:
        try:
            if cur_budget is not None:
                kwargs[budget_key] = max(1, int(cur_budget))
            return run_with_deadline(
                fn, *args, deadline_s=policy.deadline_s, **kwargs
            )
        except DeadlineError:
            attempt += 1
            if attempt > policy.max_retries:
                raise
            if on_retry is not None:
                on_retry(attempt)
            if cur_budget is not None:
                cur_budget = int(cur_budget * policy.shed_factor)


def dispatch_adaptive(
    fn: Callable,
    *args,
    policy: HedgePolicy,
    backstop_factor: float = 4.0,
    **kwargs,
):
    """Deadline wrapper for ADAPTIVE queries: degrade, don't retry.

    Flat-budget dispatch (:func:`dispatch`) can only enforce a deadline
    from outside — kill and re-dispatch with a shed budget.  An adaptive
    query already contains the graceful version of that policy: passing
    ``deadline_s`` in-band lets the escalation loop stop BETWEEN rounds
    and freeze still-live queries with ``certificate='deadline'`` and
    their best-so-far scores, so the caller gets an answer with an honest
    bound instead of an exception.  ``fn`` is typically
    ``session.query`` and must accept a ``deadline_s`` kwarg.

    The worker thread keeps a backstop at ``backstop_factor x deadline_s``
    (a single escalation round that wedges past the whole in-band window
    still gets bounded) — only THAT raises :class:`DeadlineError`.
    """
    if backstop_factor < 1.0:
        raise ValueError(
            f"backstop_factor must be >= 1, got {backstop_factor}"
        )
    def clamped():
        # the IN-BAND deadline the escalation loop honors; the outer
        # deadline_s below is the thread backstop only
        return fn(*args, deadline_s=policy.deadline_s, **kwargs)

    return run_with_deadline(
        clamped, deadline_s=policy.deadline_s * backstop_factor
    )
