"""DEPRECATED: ``SimRankEngine`` is a thin shim over ``repro.api``.

The session API (``GraphHandle`` + ``QuerySpec`` -> ``SimRankSession``)
unifies this engine, the dynamic epoch engine and the five legacy query
signatures behind one surface — see docs/api.md.  This module remains so
existing callers keep working; it delegates every operation to an owned
``SimRankSession`` and is bit-identical to the pre-session engine under the
same PRNG seed (the session's drain path preserves the submit-time stream
assignment, fixed-size repeat-padded batches and the fused dispatch
exactly — asserted by tests/test_session_api.py).

Migration:

    eng = SimRankEngine(g, eg, top_k=10, batch_q=8)      # old
    sess = SimRankSession(GraphHandle(g=g, eg=eg),       # new
                          top_k=10, batch_q=8)
    sess.submit(u); sess.drain(budget_walks=512)
"""
from __future__ import annotations

import warnings

import numpy as np

from repro.api.handle import GraphHandle
from repro.api.session import EngineStats, SimRankSession
from repro.api.spec import QuerySpec, ResultEnvelope
from repro.graph.structs import EllGraph, Graph

def QueryResult(
    node=None,
    topk_nodes=None,
    topk_scores=None,
    walks_used=0,
    latency_s=0.0,
    version=-1,
    **kwargs,
) -> ResultEnvelope:
    """Legacy constructor shim: the OLD positional field order, returning a
    ``ResultEnvelope`` (its field-superset).  Kept as a function rather than
    an alias so pre-session positional construction keeps binding the right
    fields; isinstance checks should use ``ResultEnvelope``.
    """
    return ResultEnvelope(
        kind="topk", node=node, topk_nodes=topk_nodes,
        topk_scores=topk_scores, walks_used=walks_used,
        latency_s=latency_s, version=version, **kwargs,
    )


__all__ = ["SimRankEngine", "QueryResult", "EngineStats"]


class SimRankEngine:
    """Deprecated shim — use :class:`repro.api.SimRankSession`.

    Same constructor and methods as the PR-2 engine; every call delegates
    to a session constructed over ``GraphHandle(g=g, eg=eg)`` (own-copied;
    the caller's arrays stay valid).  ``auto_regrow=False`` preserves the
    legacy behavior of surfacing capacity overflow via the sticky
    ``overflow`` flag instead of regrowing.
    """

    def __init__(
        self,
        g: Graph,
        eg: EllGraph,
        *,
        c: float = 0.6,
        eps_a: float = 0.1,
        delta: float = 0.01,
        walk_chunk: int = 256,
        top_k: int = 50,
        seed: int = 0,
        batch_q: int = 8,
    ):
        warnings.warn(
            "SimRankEngine is deprecated; use repro.api.SimRankSession over "
            "a GraphHandle (see docs/api.md)",
            DeprecationWarning,
            stacklevel=2,
        )
        self._session = SimRankSession(
            GraphHandle(g=g, eg=eg),
            c=c, eps_a=eps_a, delta=delta, walk_chunk=walk_chunk,
            top_k=top_k, seed=seed, batch_q=batch_q, auto_regrow=False,
        )

    # -- delegated state -----------------------------------------------------

    @property
    def session(self) -> SimRankSession:
        """The underlying session (migration escape hatch)."""
        return self._session

    @property
    def g(self) -> Graph:
        return self._session.handle.g

    @g.setter
    def g(self, value: Graph) -> None:
        # own-copy + validate: the session may donate its buffers, so it
        # must never share arrays with the caller (legacy contract: the
        # caller's arrays stay valid)
        self._session.handle.set_mirrors(g=value)

    @property
    def eg(self) -> EllGraph:
        return self._session.handle.eg

    @eg.setter
    def eg(self, value: EllGraph) -> None:
        self._session.handle.set_mirrors(eg=value)

    @property
    def params(self):
        return self._session.params

    @property
    def stats(self) -> EngineStats:
        return self._session.stats

    # legacy engines exposed these as plain mutable attributes
    @property
    def walk_chunk(self) -> int:
        return self._session.walk_chunk

    @walk_chunk.setter
    def walk_chunk(self, value: int) -> None:
        self._session.walk_chunk = int(value)

    @property
    def top_k(self) -> int:
        return self._session.top_k

    @top_k.setter
    def top_k(self, value: int) -> None:
        self._session.top_k = int(value)

    @property
    def batch_q(self) -> int:
        return self._session.batch_q

    @batch_q.setter
    def batch_q(self, value: int) -> None:
        self._session.batch_q = int(value)

    @property
    def version(self) -> int:
        return self._session.version

    @property
    def overflow(self) -> bool:
        return self._session.overflow

    # -- updates -------------------------------------------------------------

    def insert(self, src: np.ndarray, dst: np.ndarray) -> None:
        """Insert edges into BOTH mirrors atomically (skip-on-overflow)."""
        self._session.update(inserts=(src, dst))

    def delete(self, src: np.ndarray, dst: np.ndarray) -> None:
        """Delete edges from BOTH mirrors atomically (absent edges: no-op)."""
        self._session.update(deletes=(src, dst))

    # -- queries -------------------------------------------------------------

    def submit(self, node: int) -> None:
        self._session.submit(int(node))

    def run_query(self, u: int, *, budget_walks: int | None = None) -> QueryResult:
        """Serve one query now (Q = 1 fused step), bypassing the queue."""
        sess = self._session
        spec = QuerySpec(kind="topk", node=int(u), k=sess.top_k,
                         variant="telescoped")
        res = sess._serve_fused([(spec, sess._query_key())], budget_walks)[0]
        sess.stats.queries += 1
        return res

    def drain(self, *, budget_walks: int | None = None) -> list[QueryResult]:
        """Serve every queued query in fused batches of ``batch_q``."""
        return self._session.drain(budget_walks=budget_walks)
