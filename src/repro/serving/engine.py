"""SimRank query serving engine — the paper's end-to-end deployment story.

Index-free means the engine holds only the (dynamic) graph; queries run
against whatever the graph is *now*:

* dynamic batching: queries are queued and dispatched in fixed-size batches
  (padding with repeats) so the jit'd serve step sees static shapes;
* interleaved updates: edge insert/delete ops are applied between batches —
  O(1) buffer writes (graph/dynamic.py), never an index rebuild;
* incremental refinement: each serve step covers ``walk_chunk`` walks per
  query; the engine folds chunks until the eps_a budget's n_r is reached,
  and can return early results (anytime property of Monte-Carlo estimators);
* straggler mitigation: serving.straggler wraps step dispatch with a
  deadline + retry-on-replica policy (queries are pure functions: idempotent
  re-execution is safe).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.params import ProbeSimParams, make_params
from repro.core.probe import probe_walks_telescoped
from repro.core.walks import sample_walks
from repro.graph.dynamic import (
    delete_edges,
    delete_edges_ell,
    insert_edges,
    insert_edges_ell,
)
from repro.graph.structs import EllGraph, Graph


@dataclass
class QueryResult:
    node: int
    topk_nodes: np.ndarray
    topk_scores: np.ndarray
    walks_used: int
    latency_s: float


@dataclass
class EngineStats:
    queries: int = 0
    updates: int = 0
    steps: int = 0
    retries: int = 0


class SimRankEngine:
    """Single-host engine over the in-memory dynamic graph.

    The multi-pod variant swaps the local probe for
    ``core.distributed.make_serve_step`` (same loop structure); see
    launch/serve.py.
    """

    def __init__(
        self,
        g: Graph,
        eg: EllGraph,
        *,
        c: float = 0.6,
        eps_a: float = 0.1,
        delta: float = 0.01,
        walk_chunk: int = 256,
        top_k: int = 50,
        seed: int = 0,
    ):
        self.g = g
        self.eg = eg
        self.params: ProbeSimParams = make_params(
            g.n, c=c, eps_a=eps_a, delta=delta
        )
        self.walk_chunk = walk_chunk
        self.top_k = top_k
        self.key = jax.random.key(seed)
        self.queue: deque[int] = deque()
        self.stats = EngineStats()

    # -- updates ------------------------------------------------------------

    def insert(self, src: np.ndarray, dst: np.ndarray) -> None:
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)
        self.g = insert_edges(self.g, src, dst)
        self.eg = insert_edges_ell(self.eg, src, dst)
        self.stats.updates += int(src.shape[0])

    def delete(self, src: np.ndarray, dst: np.ndarray) -> None:
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)
        self.g = delete_edges(self.g, src, dst)
        self.eg = delete_edges_ell(self.eg, src, dst)
        self.stats.updates += int(src.shape[0])

    # -- queries ------------------------------------------------------------

    def submit(self, node: int) -> None:
        self.queue.append(int(node))

    def _single_source(self, u: int, *, budget_walks: int | None = None):
        p = self.params
        n_r = budget_walks or p.n_r
        total = jnp.zeros(self.g.n, jnp.float32)
        done = 0
        ci = 0
        while done < n_r:
            self.key, sub = jax.random.split(self.key)
            walks = sample_walks(
                sub, self.eg, u, n_r=self.walk_chunk, max_len=p.max_len,
                sqrt_c=p.sqrt_c,
            )
            live = min(self.walk_chunk, n_r - done)
            if live < self.walk_chunk:
                walks = walks.at[live:, :].set(self.g.n)
            cols = probe_walks_telescoped(
                self.g, walks, sqrt_c=p.sqrt_c, eps_p=p.eps_p
            )
            total = total + cols.sum(axis=1)
            done += live
            ci += 1
            self.stats.steps += 1
        est = total / n_r
        est = est.at[u].set(-jnp.inf)
        return est

    def run_query(self, u: int, *, budget_walks: int | None = None) -> QueryResult:
        t0 = time.time()
        est = self._single_source(u, budget_walks=budget_walks)
        vals, idx = jax.lax.top_k(est, self.top_k)
        self.stats.queries += 1
        return QueryResult(
            node=u,
            topk_nodes=np.asarray(idx),
            topk_scores=np.asarray(vals),
            walks_used=budget_walks or self.params.n_r,
            latency_s=time.time() - t0,
        )

    def drain(self, *, budget_walks: int | None = None) -> list[QueryResult]:
        out = []
        while self.queue:
            out.append(self.run_query(self.queue.popleft(),
                                       budget_walks=budget_walks))
        return out
