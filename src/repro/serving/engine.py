"""SimRank query serving engine — the paper's end-to-end deployment story.

Index-free means the engine holds only the (dynamic) graph; queries run
against whatever the graph is *now*:

* dynamic batching: queued queries are dispatched in fixed-size batches of
  ``batch_q`` (padding with repeats) through the fused multi-query serve
  step (``core.multisource``), so jit compiles ONE shape per batch size and
  every push level is shared by the whole batch across the lane dimension;
* interleaved updates: edge insert/delete ops are applied between batches —
  O(1) buffer writes (graph/dynamic.py), never an index rebuild;
* anytime serving: ``budget_walks`` caps the walk pool per query (Thm 1
  still bounds the error at the reduced n_r);
* straggler mitigation: serving.straggler wraps step dispatch with a
  deadline + retry-on-replica policy (queries are pure functions: idempotent
  re-execution is safe).

Randomness: every submitted query is assigned its own PRNG stream (derived
from the engine seed and the submission sequence number) at submit time, so
batched ``drain()`` results are identical to serving the same queries one at
a time — batch composition never changes a query's answer.

Batched usage::

    eng = SimRankEngine(g, eg, top_k=10, batch_q=8)
    for u in query_nodes:
        eng.submit(u)
    for res in eng.drain(budget_walks=512):   # fused: 8 queries per dispatch
        print(res.node, res.topk_nodes)

The multi-pod variant swaps the local fused step for
``core.distributed.make_serve_step`` (same loop structure); see
launch/serve.py.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.multisource import multi_source_topk
from repro.core.params import ProbeSimParams, make_params
from repro.graph.dynamic import (
    delete_edges,
    delete_edges_ell,
    insert_edges,
    insert_edges_ell,
)
from repro.graph.structs import EllGraph, Graph


@dataclass
class QueryResult:
    node: int
    topk_nodes: np.ndarray
    topk_scores: np.ndarray
    walks_used: int
    latency_s: float


@dataclass
class EngineStats:
    queries: int = 0
    updates: int = 0
    steps: int = 0
    retries: int = 0


class SimRankEngine:
    """Single-host engine over the in-memory dynamic graph.

    ``walk_chunk`` is the total lane-column width of the fused serve step
    (shared by the whole batch); ``batch_q`` is the fixed query batch size
    used by ``drain()`` — short batches are padded with repeats so the
    compiled step is cached per shape.
    """

    def __init__(
        self,
        g: Graph,
        eg: EllGraph,
        *,
        c: float = 0.6,
        eps_a: float = 0.1,
        delta: float = 0.01,
        walk_chunk: int = 256,
        top_k: int = 50,
        seed: int = 0,
        batch_q: int = 8,
    ):
        self.g = g
        self.eg = eg
        self.params: ProbeSimParams = make_params(
            g.n, c=c, eps_a=eps_a, delta=delta
        )
        self.walk_chunk = walk_chunk
        self.top_k = top_k
        self.batch_q = batch_q
        self.key = jax.random.key(seed)
        self.queue: deque[tuple[int, jax.Array]] = deque()
        self.stats = EngineStats()
        self._seq = 0  # submission counter -> per-query PRNG stream

    # -- updates ------------------------------------------------------------

    def insert(self, src: np.ndarray, dst: np.ndarray) -> None:
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)
        self.g = insert_edges(self.g, src, dst)
        self.eg = insert_edges_ell(self.eg, src, dst)
        self.stats.updates += int(src.shape[0])

    def delete(self, src: np.ndarray, dst: np.ndarray) -> None:
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)
        self.g = delete_edges(self.g, src, dst)
        self.eg = delete_edges_ell(self.eg, src, dst)
        self.stats.updates += int(src.shape[0])

    # -- queries ------------------------------------------------------------

    def _query_key(self) -> jax.Array:
        k = jax.random.fold_in(self.key, self._seq)
        self._seq += 1
        return k

    def submit(self, node: int) -> None:
        self.queue.append((int(node), self._query_key()))

    def _serve_batch(
        self,
        batch: list[tuple[int, jax.Array]],
        budget_walks: int | None,
    ) -> list[QueryResult]:
        """One fused dispatch for a (possibly repeat-padded) query batch."""
        n_r = budget_walks or self.params.n_r
        us = jnp.asarray([u for u, _ in batch], jnp.int32)
        keys = jnp.stack([k for _, k in batch])
        t0 = time.time()
        idx, vals = multi_source_topk(
            None, self.g, self.eg, us, self.top_k, self.params,
            lanes=self.walk_chunk, n_r=n_r, keys=keys,
        )
        idx = np.asarray(idx)  # device sync
        vals = np.asarray(vals)
        dt = time.time() - t0
        self.stats.steps += 1
        return [
            QueryResult(
                node=u,
                topk_nodes=idx[i],
                topk_scores=vals[i],
                walks_used=n_r,
                latency_s=dt,
            )
            for i, (u, _) in enumerate(batch)
        ]

    def run_query(self, u: int, *, budget_walks: int | None = None) -> QueryResult:
        """Serve one query now (Q = 1 fused step), bypassing the queue."""
        res = self._serve_batch([(int(u), self._query_key())], budget_walks)[0]
        self.stats.queries += 1
        return res

    def drain(self, *, budget_walks: int | None = None) -> list[QueryResult]:
        """Serve every queued query in fused batches of ``batch_q``.

        Short final batches are padded by repeating the last entry (the
        padded slots recompute an already-served query and are discarded),
        so every dispatch reuses the same compiled step.
        """
        out: list[QueryResult] = []
        while self.queue:
            live = min(self.batch_q, len(self.queue))
            batch = [self.queue.popleft() for _ in range(live)]
            while len(batch) < self.batch_q:
                batch.append(batch[-1])  # pad with repeats: static shape
            out.extend(self._serve_batch(batch, budget_walks)[:live])
            self.stats.queries += live
        return out
