"""SimRank query serving engine — the paper's end-to-end deployment story.

Index-free means the engine holds only the (dynamic) graph; queries run
against whatever the graph is *now*:

* dynamic batching: queued queries are dispatched in fixed-size batches of
  ``batch_q`` (padding with repeats) through the fused multi-query serve
  step (``core.multisource``), so jit compiles ONE shape per batch size and
  every push level is shared by the whole batch across the lane dimension;
* interleaved updates: edge insert/delete ops are applied between batches
  through the coordinated both-mirrors path (graph/dynamic.py) — O(1)
  buffer writes, never an index rebuild; skipped-for-capacity inserts are
  surfaced via ``overflow`` (see serving/dynamic_engine.py for the engine
  that fuses updates INTO the serve step and auto-regrows);
* versioned snapshots: every result carries the graph ``version`` it was
  computed against;
* anytime serving: ``budget_walks`` caps the walk pool per query (Thm 1
  still bounds the error at the reduced n_r);
* straggler mitigation: serving.straggler wraps step dispatch with a
  deadline + retry-on-replica policy (queries are pure functions: idempotent
  re-execution is safe).

Randomness: every submitted query is assigned its own PRNG stream (derived
from the engine seed and the submission sequence number) at submit time, so
batched ``drain()`` results are identical to serving the same queries one at
a time — batch composition never changes a query's answer.

Batched usage::

    eng = SimRankEngine(g, eg, top_k=10, batch_q=8)
    for u in query_nodes:
        eng.submit(u)
    for res in eng.drain(budget_walks=512):   # fused: 8 queries per dispatch
        print(res.node, res.topk_nodes)

The multi-pod variant swaps the local fused step for
``core.distributed.make_serve_step`` (same loop structure); see
launch/serve.py.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.multisource import multi_source_topk
from repro.core.params import ProbeSimParams, make_params
from repro.graph.dynamic import apply_update_batch_jit, make_update_batch
from repro.graph.structs import EllGraph, Graph


@dataclass
class QueryResult:
    node: int
    topk_nodes: np.ndarray
    topk_scores: np.ndarray
    walks_used: int
    latency_s: float
    version: int = -1  # graph snapshot the scores are attributed to


@dataclass
class EngineStats:
    queries: int = 0
    updates: int = 0
    steps: int = 0
    retries: int = 0


class SimRankEngine:
    """Single-host engine over the in-memory dynamic graph.

    ``walk_chunk`` is the total lane-column width of the fused serve step
    (shared by the whole batch); ``batch_q`` is the fixed query batch size
    used by ``drain()`` — short batches are padded with repeats so the
    compiled step is cached per shape.
    """

    def __init__(
        self,
        g: Graph,
        eg: EllGraph,
        *,
        c: float = 0.6,
        eps_a: float = 0.1,
        delta: float = 0.01,
        walk_chunk: int = 256,
        top_k: int = 50,
        seed: int = 0,
        batch_q: int = 8,
    ):
        self.g = g
        self.eg = eg
        self.params: ProbeSimParams = make_params(
            g.n, c=c, eps_a=eps_a, delta=delta
        )
        self.walk_chunk = walk_chunk
        self.top_k = top_k
        self.batch_q = batch_q
        self.key = jax.random.key(seed)
        self.queue: deque[tuple[int, jax.Array]] = deque()
        self.stats = EngineStats()
        self._seq = 0  # submission counter -> per-query PRNG stream

    # -- updates ------------------------------------------------------------

    @property
    def version(self) -> int:
        """Current graph snapshot id (bumped once per applied update batch)."""
        return int(self.eg.version) if self.eg.version is not None else -1

    @property
    def overflow(self) -> bool:
        """True iff an insert was ever skipped for lack of capacity.

        Sticky until the caller regrows (``graph.dynamic.regrow``); the
        ``DynamicEngine`` automates that — this engine only surfaces it.
        """
        return bool(self.g.overflow) if self.g.overflow is not None else False

    def _apply(self, src, dst, insert: bool) -> None:
        if src.shape[0] == 0:
            return
        # pad to the next power of two so variable-size update bursts reuse
        # a log-bounded set of compiled batch shapes
        bucket = 1 << (int(src.shape[0]) - 1).bit_length()
        batch = make_update_batch(
            src, dst, insert, batch_size=bucket, n=self.g.n
        )
        self.g, self.eg, _ = apply_update_batch_jit(self.g, self.eg, batch)
        self.stats.updates += int(src.shape[0])

    def insert(self, src: np.ndarray, dst: np.ndarray) -> None:
        """Insert edges into BOTH mirrors atomically (skip-on-overflow)."""
        self._apply(np.asarray(src, np.int32).reshape(-1),
                    np.asarray(dst, np.int32).reshape(-1), True)

    def delete(self, src: np.ndarray, dst: np.ndarray) -> None:
        """Delete edges from BOTH mirrors atomically (absent edges: no-op).

        ``apply_update_batch`` removes at most one copy of a (s, d) pair per
        batch, so duplicate pairs in one call (multigraph copies) are split
        into sequential unique-pair sub-batches — one copy removed per op,
        matching the pre-batch sequential semantics.
        """
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        if src.shape[0] == 0:
            return
        seen: dict[tuple[int, int], int] = {}
        occ = np.empty(src.shape[0], np.int64)
        for i, (s, d) in enumerate(zip(src.tolist(), dst.tolist())):
            occ[i] = seen.get((s, d), 0)
            seen[(s, d)] = occ[i] + 1
        for k in range(int(occ.max()) + 1):
            m = occ == k
            self._apply(src[m], dst[m], False)

    # -- queries ------------------------------------------------------------

    def _query_key(self) -> jax.Array:
        k = jax.random.fold_in(self.key, self._seq)
        self._seq += 1
        return k

    def submit(self, node: int) -> None:
        self.queue.append((int(node), self._query_key()))

    def _serve_batch(
        self,
        batch: list[tuple[int, jax.Array]],
        budget_walks: int | None,
    ) -> list[QueryResult]:
        """One fused dispatch for a (possibly repeat-padded) query batch."""
        n_r = budget_walks or self.params.n_r
        us = jnp.asarray([u for u, _ in batch], jnp.int32)
        keys = jnp.stack([k for _, k in batch])
        t0 = time.time()
        idx, vals = multi_source_topk(
            None, self.g, self.eg, us, self.top_k, self.params,
            lanes=self.walk_chunk, n_r=n_r, keys=keys,
        )
        idx = np.asarray(idx)  # device sync
        vals = np.asarray(vals)
        dt = time.time() - t0
        self.stats.steps += 1
        ver = self.version
        return [
            QueryResult(
                node=u,
                topk_nodes=idx[i],
                topk_scores=vals[i],
                walks_used=n_r,
                latency_s=dt,
                version=ver,
            )
            for i, (u, _) in enumerate(batch)
        ]

    def run_query(self, u: int, *, budget_walks: int | None = None) -> QueryResult:
        """Serve one query now (Q = 1 fused step), bypassing the queue."""
        res = self._serve_batch([(int(u), self._query_key())], budget_walks)[0]
        self.stats.queries += 1
        return res

    def drain(self, *, budget_walks: int | None = None) -> list[QueryResult]:
        """Serve every queued query in fused batches of ``batch_q``.

        Short final batches are padded by repeating the last entry (the
        padded slots recompute an already-served query and are discarded),
        so every dispatch reuses the same compiled step.
        """
        out: list[QueryResult] = []
        while self.queue:
            live = min(self.batch_q, len(self.queue))
            batch = [self.queue.popleft() for _ in range(live)]
            while len(batch) < self.batch_q:
                batch.append(batch[-1])  # pad with repeats: static shape
            out.extend(self._serve_batch(batch, budget_walks)[:live])
            self.stats.queries += live
        return out
