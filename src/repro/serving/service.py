"""`SimRankService` — multi-tenant micro-batched serving over sessions.

The network-facing half of the serving story lives in two layers:
``serving/server.py`` owns the HTTP surface (sockets, routes, JSON), and
this module owns everything between "a request was accepted" and "its
envelope is ready":

* **micro-batching window** — concurrent connections each carry ONE query,
  but the execution substrate's sweet spot is the lane-batched fused step
  (one compiled dispatch for Q queries, DESIGN.md §3/§6).  A collector
  thread cuts cross-connection batches: the first request arms a
  ``batch_window_ms`` timer, the cut happens at the timer or as soon as
  ``max_batch_q`` requests are pending, and each cut drains through the
  tenant session's fused path — so N concurrent clients cost
  ``steps ≪ N`` compiled dispatches.

* **admission control + backpressure** — the pending queue is bounded by
  ``max_inflight``; past it, requests are rejected at the door with an
  :class:`AdmissionError` (HTTP 429 + ``Retry-After``) instead of growing
  an unbounded queue whose tail would miss every deadline anyway.
  Requests whose relative ``deadline_s`` expires while still queued are
  shed at cut time (504) — an expired request never occupies a lane slot.
  Adaptive (``epsilon``) requests with deadlines degrade instead of
  shedding: they ride ``serving.straggler.dispatch_adaptive``, so the
  in-band deadline freezes best-so-far certificates
  (``certificate='deadline'``) and only a wedged dispatch past the
  backstop 504s.

* **per-tenant sessions over shared graph state** — each tenant id maps to
  its own ``SimRankSession`` (separate PRNG namespace, stats, planner
  caches) over ONE shared graph: on the local backend every tenant session
  holds the same ``GraphHandle`` (``own_graph=False``), on the sharded
  backend they share one ``ShardedBackend``.  ``apply_update`` is
  serialized against query dispatch and bumps the version every tenant's
  next answer observes.

Everything device-side is untouched: the service is host-side policy
around ``SimRankSession``, and all jax dispatch happens on the collector
thread (handler threads only enqueue and wait), so the compiled-step
caches never see concurrent tracing.
"""
from __future__ import annotations

import threading
import time
import zlib
from collections import deque
from dataclasses import dataclass, field

import numpy as np

import jax

from repro.api.handle import GraphHandle
from repro.api.session import SimRankSession
from repro.api.spec import QuerySpec
from repro.serving.protocol import (
    ProtocolError,
    QueryRequest,
    envelope_to_wire,
    update_report_to_wire,
)
from repro.serving.straggler import (
    DeadlineError,
    HedgePolicy,
    dispatch_adaptive,
)

DEFAULT_TENANT = "default"
_TENANT_CHARS = frozenset(
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789._-"
)


class AdmissionError(RuntimeError):
    """Admission queue full — HTTP 429 with a ``Retry-After`` hint."""

    def __init__(self, retry_after_s: float, depth: int):
        super().__init__(
            f"admission queue full ({depth} in flight); "
            f"retry after {retry_after_s:.1f}s"
        )
        self.retry_after_s = retry_after_s
        self.depth = depth


class ServiceClosed(RuntimeError):
    """Service is shutting down — HTTP 503."""


@dataclass
class ServiceConfig:
    """Knobs for the micro-batching window and admission policy.

    ``batch_window_ms`` is the collector's cut timer, armed by the first
    pending request (a cut fires early when ``max_batch_q`` requests are
    waiting, so a saturated service never idles the window).
    ``max_batch_q`` is also the tenant sessions' ``batch_q`` — one full
    cut for one tenant is exactly one fused dispatch.  ``max_inflight``
    bounds accepted-but-unanswered requests across all tenants; past it,
    enqueue raises :class:`AdmissionError` (429).
    ``tenant_max_inflight`` additionally bounds any SINGLE tenant's share
    of those slots (None = no per-tenant cap): a greedy tenant 429s at its
    own quota while a quiet tenant's requests still admit, so one hot
    tenant cannot starve the rest of the fleet.
    ``default_budget_walks`` caps queries that don't pin their own budget
    (None = the session's flat Thm-1 budget — usually far too many walks
    for interactive serving, so set this).  ``min_adaptive_deadline_s``
    is the in-band deadline handed to an adaptive query that arrives at
    dispatch already expired: round 0 still runs, so it degrades to a
    best-so-far certificate instead of shedding (flat queries 504).
    """

    batch_window_ms: float = 10.0
    max_batch_q: int = 16
    max_inflight: int = 256
    tenant_max_inflight: int | None = None
    default_budget_walks: int | None = None
    response_timeout_s: float = 600.0
    adaptive_backstop_factor: float = 4.0
    min_adaptive_deadline_s: float = 1e-3

    def __post_init__(self) -> None:
        if self.batch_window_ms < 0:
            raise ValueError("batch_window_ms must be >= 0")
        if self.max_batch_q < 1:
            raise ValueError("max_batch_q must be >= 1")
        if self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        if (
            self.tenant_max_inflight is not None
            and self.tenant_max_inflight < 1
        ):
            raise ValueError("tenant_max_inflight must be >= 1 (or None)")


@dataclass
class ServiceStats:
    """Service-level counters (tenant sessions keep their own
    ``EngineStats``; see :meth:`SimRankService.stats_snapshot`).

    ``batch_hist`` maps micro-batch size -> count of fused dispatches that
    served exactly that many live queries (adaptive-with-deadline requests
    dispatch individually and land in bucket 1)."""

    accepted: int = 0
    served: int = 0
    rejected_429: int = 0
    shed_504: int = 0
    errors_5xx: int = 0
    batches: int = 0
    updates_applied: int = 0
    batch_hist: dict[int, int] = field(default_factory=dict)

    def as_dict(self) -> dict:
        d = dict(vars(self))
        d["batch_hist"] = {str(k): v for k, v in sorted(self.batch_hist.items())}
        return d


class _PendingQuery:
    """One accepted request waiting for its micro-batch to dispatch."""

    __slots__ = (
        "req", "spec", "tenant", "t_enq", "t_deadline",
        "event", "status", "payload",
    )

    def __init__(self, req, spec, tenant, t_enq, t_deadline):
        self.req = req
        self.spec = spec
        self.tenant = tenant
        self.t_enq = t_enq
        self.t_deadline = t_deadline
        self.event = threading.Event()
        self.status: int = 500
        self.payload: dict = {"error": "internal: response never filled"}


def _tenant_seed(tenant: str, seed: int) -> int:
    """Stable per-tenant PRNG namespace: crc32 of the name, salted."""
    return (zlib.crc32(tenant.encode()) ^ (seed * 0x9E3779B1)) & 0x7FFFFFFF


def validate_tenant(tenant: str) -> str:
    if not tenant or len(tenant) > 64 or not set(tenant) <= _TENANT_CHARS:
        raise ProtocolError(
            "tenant must be 1-64 chars of [A-Za-z0-9._-], "
            f"got {tenant!r}"
        )
    return tenant


class SimRankService:
    """Multi-tenant micro-batched SimRank serving over shared graph state.

    ``handle`` is copied once at construction (the service owns its graph;
    the caller's handle stays authoritative for the caller).  Tenants are
    created lazily on first use: each gets its own ``SimRankSession`` —
    its own PRNG namespace (``_tenant_seed(name, seed)``), stats and
    planner caches — over the ONE service-owned graph, so an update any
    tenant observes is the update every tenant observes.
    ``backend='sharded'`` builds one ``ShardedBackend`` (``shards=`` /
    ``mesh=``) that all tenant sessions share the same way.

    ``session_kwargs`` forwards session knobs (``c``, ``eps_a``,
    ``walk_chunk``, ``top_k``, ...) to every tenant session; ``batch_q``
    is pinned to ``config.max_batch_q`` (the micro-batch IS the session
    batch).  Use :func:`serving.server.start_server` to put the HTTP
    surface in front of this object, or drive :meth:`serve_request` /
    :meth:`apply_update` directly from tests.
    """

    def __init__(
        self,
        handle: GraphHandle,
        *,
        backend: str = "local",
        shards: int | None = None,
        mesh=None,
        config: ServiceConfig | None = None,
        seed: int = 0,
        session_kwargs: dict | None = None,
    ):
        if not isinstance(handle, GraphHandle):
            raise TypeError("SimRankService takes a GraphHandle")
        if backend not in ("local", "sharded"):
            raise ValueError(
                f"backend must be 'local' or 'sharded', got {backend!r}"
            )
        self.config = config or ServiceConfig()
        self.seed = int(seed)
        self._session_kwargs = dict(session_kwargs or {})
        for k in ("batch_q", "own_graph", "backend", "shards", "mesh"):
            if k in self._session_kwargs:
                raise ValueError(
                    f"session_kwargs[{k!r}] is owned by the service "
                    "(batch_q = config.max_batch_q; graph sharing and "
                    "backend selection are constructor arguments)"
                )
        self.backend_kind = backend
        # a wire query with no k falls back to the session top_k; clamp
        # the default below the graph size so small graphs don't 500
        if "top_k" not in self._session_kwargs:
            self._session_kwargs["top_k"] = max(1, min(50, handle.n - 1))
        if backend == "local":
            self._handle = handle.copy()  # service-owned; caller's is safe
            self._root_backend = None
        else:
            from repro.api.backend import ShardedBackend
            from repro.core.params import make_params

            kw = self._session_kwargs
            params = make_params(
                handle.n,
                c=kw.get("c", 0.6),
                eps_a=kw.get("eps_a", 0.1),
                delta=kw.get("delta", 0.01),
            )
            self._root_backend = ShardedBackend(
                handle, params=params, shards=shards, mesh=mesh,
                walk_chunk=kw.get("walk_chunk", 256),
            )
            self._handle = None
        self.stats = ServiceStats()
        self._sessions: dict[str, SimRankSession] = {}
        self._sessions_lock = threading.Lock()
        # serializes graph mutation (apply_update) against query dispatch:
        # a fused drain must never observe a half-applied mirror pair
        self._graph_lock = threading.RLock()
        self._cond = threading.Condition()
        # observed per-batch service time (collector-thread EWMA; reads
        # from handler threads are racy-but-monotonic floats, fine)
        self._ewma_batch_s = max(self.config.batch_window_ms / 1e3, 1e-3)
        self._pending: deque[_PendingQuery] = deque()
        self._inflight = 0
        self._tenant_inflight: dict[str, int] = {}
        self._closed = False
        self._collector = threading.Thread(
            target=self._collector_loop, daemon=True,
            name="probesim-collector",
        )
        self._collector.start()

    # -- tenants -------------------------------------------------------------

    @property
    def n(self) -> int:
        be = self._root_backend
        return be.n if be is not None else self._handle.n

    @property
    def version(self) -> int:
        be = self._root_backend
        return be.version if be is not None else self._handle.version

    @property
    def inflight(self) -> int:
        with self._cond:
            return self._inflight

    @property
    def tenants(self) -> tuple[str, ...]:
        with self._sessions_lock:
            return tuple(self._sessions)

    def session(self, tenant: str = DEFAULT_TENANT) -> SimRankSession:
        """The tenant's session, created lazily on first use."""
        validate_tenant(tenant)
        with self._sessions_lock:
            sess = self._sessions.get(tenant)
            if sess is None:
                tseed = _tenant_seed(tenant, self.seed)
                if self._root_backend is not None:
                    sess = SimRankSession(
                        self._root_backend, seed=tseed,
                        batch_q=self.config.max_batch_q,
                        **{
                            k: v for k, v in self._session_kwargs.items()
                            if k not in ("c", "eps_a", "delta")
                            # params come from the shared backend
                        },
                    )
                else:
                    sess = SimRankSession(
                        self._handle, seed=tseed, own_graph=False,
                        batch_q=self.config.max_batch_q,
                        **self._session_kwargs,
                    )
                self._sessions[tenant] = sess
            return sess

    # -- query path ----------------------------------------------------------

    def _retry_after_s(self, depth: int, limit: int | None = None) -> float:
        """How long a 429'd client should back off: the time until an
        admission slot frees, i.e. enough cuts to work off the overshoot
        past the violated bound (``max_inflight`` globally, or the
        tenant's quota when ``limit`` is passed) — one batch completion
        usually frees a whole batch of slots.  Each cut is costed at the
        OBSERVED batch service time (EWMA, floored at the window): a
        window-only hint under-estimates badly once dispatch time
        dominates (retry storms), while a drain-the-whole-queue hint
        over-sleeps the herd and idles the collector."""
        window_s = max(self.config.batch_window_ms / 1e3, 1e-3)
        bound = self.config.max_inflight if limit is None else limit
        overshoot = max(1, depth - bound + 1)
        cuts = -(-overshoot // self.config.max_batch_q) or 1  # ceil
        return cuts * max(window_s, self._ewma_batch_s)

    def _observe_batch_s(self, dt: float) -> None:
        self._ewma_batch_s += 0.3 * (dt - self._ewma_batch_s)

    def _spec_for(self, req: QueryRequest) -> QuerySpec:
        if req.node >= self.n:
            raise ProtocolError(
                f"node {req.node} out of range for n={self.n}"
            )
        budget = req.budget_walks
        if budget is None:
            budget = self.config.default_budget_walks
        key = None
        if req.seed is not None:
            # wire-pinned PRNG stream: bitwise-reproducible against a
            # local session under the same key (the parity tests' hook)
            key = jax.random.key(req.seed)
        return QuerySpec(
            kind=req.kind,
            node=req.node,
            k=req.k,
            budget_walks=budget,
            epsilon=req.epsilon,
            confidence=req.confidence,
            key=key,
        )

    def enqueue(
        self, req: QueryRequest, tenant: str = DEFAULT_TENANT
    ) -> _PendingQuery:
        """Admit one request into the micro-batching window (non-blocking).

        Raises :class:`AdmissionError` (429) past ``max_inflight``,
        :class:`ServiceClosed` (503) during shutdown, and
        :class:`ProtocolError` (400) on a bad tenant/node.  The returned
        item's ``event`` fires when ``status``/``payload`` are filled.
        """
        validate_tenant(tenant)
        spec = self._spec_for(req)  # validates before occupying a slot
        now = time.monotonic()
        deadline = None if req.deadline_s is None else now + req.deadline_s
        item = _PendingQuery(req, spec, tenant, now, deadline)
        with self._cond:
            if self._closed:
                raise ServiceClosed("service is shutting down")
            if self._inflight >= self.config.max_inflight:
                self.stats.rejected_429 += 1
                raise AdmissionError(
                    self._retry_after_s(self._inflight), self._inflight
                )
            cap = self.config.tenant_max_inflight
            mine = self._tenant_inflight.get(tenant, 0)
            if cap is not None and mine >= cap:
                # the tenant blew its own share while global slots remain:
                # reject it without touching anyone else's admission
                self.stats.rejected_429 += 1
                raise AdmissionError(self._retry_after_s(mine, cap), mine)
            self._inflight += 1
            self._tenant_inflight[tenant] = mine + 1
            self.stats.accepted += 1
            self._pending.append(item)
            self._cond.notify_all()
        return item

    def serve_request(
        self, req: QueryRequest, tenant: str = DEFAULT_TENANT
    ) -> tuple[int, dict]:
        """Blocking convenience: enqueue + wait -> (http_status, payload)."""
        item = self.enqueue(req, tenant)
        if not item.event.wait(timeout=self.config.response_timeout_s):
            return 500, {"error": "response timed out inside the service"}
        return item.status, item.payload

    def _finish(self, item: _PendingQuery, status: int, payload: dict) -> None:
        item.status = status
        item.payload = payload
        with self._cond:
            self._inflight -= 1
            left = self._tenant_inflight.get(item.tenant, 0) - 1
            if left > 0:
                self._tenant_inflight[item.tenant] = left
            else:
                self._tenant_inflight.pop(item.tenant, None)
        item.event.set()

    # -- the collector -------------------------------------------------------

    def _collector_loop(self) -> None:
        window_s = self.config.batch_window_ms / 1e3
        while True:
            with self._cond:
                while not self._pending:
                    if self._closed:
                        return
                    self._cond.wait(timeout=0.25)
                # the first pending request armed the window; cut at the
                # timer or as soon as a full batch is waiting
                cut_at = self._pending[0].t_enq + window_s
                while (
                    len(self._pending) < self.config.max_batch_q
                    and not self._closed
                ):
                    rem = cut_at - time.monotonic()
                    if rem <= 0:
                        break
                    self._cond.wait(timeout=rem)
                batch = self._cut_window()
            try:
                self._serve_cut(batch)
            except BaseException as e:  # the collector must survive anything
                for it in batch:
                    if not it.event.is_set():
                        self.stats.errors_5xx += 1
                        self._finish(
                            it, 500,
                            {"error": f"{type(e).__name__}: {e}"},
                        )

    def _cut_window(self) -> list[_PendingQuery]:
        """Cut up to ``max_batch_q`` pending requests (under ``_cond``).

        When everything pending fits one cut (the common case) this is
        plain FIFO.  When the window OVERFLOWS a cut, deadline-bearing
        queries take the lane slots first (earliest deadline wins) and
        deadline-free ones keep FIFO order behind them — the extra window
        of waiting lands on the queries that can afford it, instead of a
        deadline query shedding (504) because FIFO queued it behind
        best-effort traffic.  The un-cut remainder keeps arrival order.
        """
        q = self.config.max_batch_q
        if len(self._pending) <= q:
            batch = list(self._pending)
            self._pending.clear()
            return batch
        items = list(self._pending)
        order = sorted(
            range(len(items)),
            key=lambda i: (
                (0, items[i].t_deadline)
                if items[i].t_deadline is not None
                else (1, items[i].t_enq)
            ),
        )
        chosen = set(order[:q])
        self._pending.clear()
        self._pending.extend(
            items[i] for i in range(len(items)) if i not in chosen
        )
        return [items[i] for i in order[:q]]

    @staticmethod
    def _group_key(spec: QuerySpec):
        # mirror SimRankSession._batch_group: specs sharing one fused
        # dispatch must agree on shapes and escalation parameters
        return (
            spec.kind, spec.k, spec.budget_walks,
            spec.epsilon, spec.confidence,
        )

    def _serve_cut(self, batch: list[_PendingQuery]) -> None:
        """Serve one window cut: shed expired, group, fuse, respond."""
        now = time.monotonic()
        groups: dict[tuple, list[_PendingQuery]] = {}
        solo: list[_PendingQuery] = []
        for it in batch:
            expired = it.t_deadline is not None and now >= it.t_deadline
            if it.spec.epsilon is not None and it.t_deadline is not None:
                # adaptive + deadline: the in-band escalation clamp is the
                # graceful version of shedding — dispatch individually
                solo.append(it)
            elif expired:
                self.stats.shed_504 += 1
                self._finish(it, 504, {
                    "error": "deadline expired before dispatch "
                    f"(queued {now - it.t_enq:.3f}s of "
                    f"{it.req.deadline_s:.3f}s)",
                })
            else:
                groups.setdefault(
                    (it.tenant, self._group_key(it.spec)), []
                ).append(it)
        for (tenant, _), items in groups.items():
            self._serve_group(tenant, items)
        for it in solo:
            self._serve_adaptive_solo(it)

    def _serve_group(self, tenant: str, items: list[_PendingQuery]) -> None:
        """One tenant-homogeneous group through the fused submit/drain."""
        t0 = time.monotonic()
        try:
            sess = self.session(tenant)
            with self._graph_lock:
                tickets = [sess.submit(it.spec) for it in items]
                sess.drain()
        except Exception as e:
            for it in items:
                self.stats.errors_5xx += 1
                self._finish(it, 500, {"error": f"{type(e).__name__}: {e}"})
            return
        self._observe_batch_s(time.monotonic() - t0)
        self.stats.batches += 1
        self.stats.batch_hist[len(items)] = (
            self.stats.batch_hist.get(len(items), 0) + 1
        )
        self.stats.served += len(items)
        for it, tk in zip(items, tickets):
            self._finish(it, 200, envelope_to_wire(
                tk.envelope,
                tenant=tenant,
                batch_size=len(items),
                queue_delay_s=t0 - it.t_enq,
            ))

    def _serve_adaptive_solo(self, it: _PendingQuery) -> None:
        """Adaptive + deadline: in-band clamp via dispatch_adaptive."""
        t0 = time.monotonic()
        rem = max(
            it.t_deadline - t0, self.config.min_adaptive_deadline_s
        )
        try:
            sess = self.session(it.tenant)
            with self._graph_lock:
                env = dispatch_adaptive(
                    sess.query, it.spec,
                    policy=HedgePolicy(deadline_s=rem),
                    backstop_factor=self.config.adaptive_backstop_factor,
                )
        except DeadlineError:
            # even the thread backstop blew: a genuinely wedged dispatch
            self.stats.shed_504 += 1
            self._finish(it, 504, {
                "error": "adaptive dispatch exceeded the backstop "
                f"deadline ({rem * self.config.adaptive_backstop_factor:.3f}s)",
            })
            return
        except Exception as e:
            self.stats.errors_5xx += 1
            self._finish(it, 500, {"error": f"{type(e).__name__}: {e}"})
            return
        self._observe_batch_s(time.monotonic() - t0)
        self.stats.batches += 1
        self.stats.batch_hist[1] = self.stats.batch_hist.get(1, 0) + 1
        self.stats.served += 1
        self._finish(it, 200, envelope_to_wire(
            env,
            tenant=it.tenant,
            batch_size=1,
            queue_delay_s=t0 - it.t_enq,
        ))

    # -- updates -------------------------------------------------------------

    def apply_update(
        self,
        inserts: np.ndarray | None = None,
        deletes: np.ndarray | None = None,
    ) -> dict:
        """Apply one coordinated update batch to the shared graph (serialized).

        ``inserts``/``deletes`` are ``[B, 2]`` (src, dst) arrays (the
        ``parse_update_request`` output).  Runs under the graph lock, so
        it is atomic w.r.t. query dispatch: every query is answered
        against a consistent pre- or post-update snapshot, and the bumped
        ``version`` in its envelope says which.  All tenants share the
        graph state, so they all observe the new version immediately.
        """
        with self._graph_lock:
            sess = self.session(DEFAULT_TENANT)
            rep = sess.update(
                inserts=None if inserts is None else (
                    inserts[:, 0], inserts[:, 1]
                ),
                deletes=None if deletes is None else (
                    deletes[:, 0], deletes[:, 1]
                ),
            )
            self.stats.updates_applied += rep.applied
        return update_report_to_wire(rep, n=self.n)

    # -- observability -------------------------------------------------------

    def stats_snapshot(self) -> dict:
        """``GET /stats`` payload: service counters + per-tenant sessions."""
        with self._cond:
            service = self.stats.as_dict()
            service["inflight"] = self._inflight
            service["pending"] = len(self._pending)
            service["tenant_inflight"] = dict(self._tenant_inflight)
        service["max_inflight"] = self.config.max_inflight
        service["tenant_max_inflight"] = self.config.tenant_max_inflight
        service["batch_window_ms"] = self.config.batch_window_ms
        service["max_batch_q"] = self.config.max_batch_q
        with self._sessions_lock:
            tenants = {
                name: dict(sess.stats.as_dict(), version=sess.version)
                for name, sess in self._sessions.items()
            }
        return {"service": service, "tenants": tenants}

    def healthz(self) -> dict:
        """``GET /healthz`` payload: liveness + the shared snapshot id."""
        return {
            "status": "closed" if self._closed else "ok",
            "backend": self.backend_kind,
            "n": self.n,
            "version": self.version,
            "tenants": len(self._sessions),
            "inflight": self.inflight,
        }

    # -- lifecycle -----------------------------------------------------------

    def close(self, timeout_s: float = 30.0) -> None:
        """Stop admitting, flush pending requests, stop the collector."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        self._collector.join(timeout=timeout_s)
        # anything the collector could not flush fails loudly, not silently
        with self._cond:
            leftovers = list(self._pending)
            self._pending.clear()
        for it in leftovers:
            self._finish(it, 503, {"error": "service closed before dispatch"})

    def __enter__(self) -> "SimRankService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
