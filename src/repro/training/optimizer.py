"""AdamW with configurable state dtype + schedules (no optax offline).

``state_dtype='bfloat16'`` halves optimizer memory — required to fit
llama3-405b on a single 256-chip v5e pod (see EXPERIMENTS §Dry-run memory
table); master params stay fp32.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
Schedule = Callable[[Array], Array]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_schedule(
    peak: float, warmup: int, total: int, floor: float = 0.0
) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(math.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return fn


@dataclass(frozen=True)
class AdamW:
    schedule: Schedule
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32

    def init(self, params):
        zeros = lambda p: jnp.zeros_like(p, dtype=self.state_dtype)
        return dict(
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(self, grads, state, params):
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        # global-norm clip
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads)
            )
        )
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        lr = self.schedule(count)
        bc1 = 1.0 - self.b1**cf
        bc2 = 1.0 - self.b2**cf

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m32 = m.astype(jnp.float32) * self.b1 + (1 - self.b1) * g
            v32 = v.astype(jnp.float32) * self.b2 + (1 - self.b2) * g * g
            step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step
            return (
                new_p.astype(p.dtype),
                m32.astype(self.state_dtype),
                v32.astype(self.state_dtype),
            )

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["mu"])
        flat_v = treedef.flatten_up_to(state["nu"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, dict(mu=new_m, nu=new_v, count=count), dict(
            grad_norm=gnorm, lr=lr
        )
