"""Gradient compression for cross-pod (DCN) links.

Two composable transforms applied to the gradient pytree inside the train
step (before the optimizer):

* ``int8_compress`` — per-tensor scale + int8 quantization with stochastic
  rounding; the all-reduce then moves 4x fewer bytes (in SPMD the quantized
  tensor is what crosses the ``pod`` axis).
* ``TopKErrorFeedback`` — keeps the top-k fraction of entries per tensor,
  accumulating the residual locally (error feedback, Stich et al.), the
  standard convergence-preserving sparsification.

Both are exact-shape transforms so they drop into ``make_train_step``'s
``grad_transform`` hook.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def int8_compress(grads, key=None):
    """Quantize-dequantize every leaf at int8 (simulates the wire format)."""

    def q(g):
        if g.dtype not in (jnp.float32, jnp.bfloat16, jnp.float16):
            return g
        gf = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.abs(gf).max(), 1e-12) / 127.0
        qv = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        return (qv.astype(jnp.float32) * scale).astype(g.dtype)

    return jax.tree_util.tree_map(q, grads)


class TopKErrorFeedback:
    """Stateful top-k sparsification with error feedback.

    state = residual pytree (same shapes as grads).  Call as
    ``grads, state = ef(grads, state)`` inside the host step loop, or use
    ``make_transform`` for a pure-funactional pairing with the train step.
    """

    def __init__(self, fraction: float = 0.01):
        self.fraction = fraction

    def init(self, params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

    def __call__(self, grads, residual):
        frac = self.fraction

        def one(g, r):
            gf = g.astype(jnp.float32) + r
            flat = gf.reshape(-1)
            k = max(1, int(flat.shape[0] * frac))
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            keep = jnp.abs(gf) >= thresh
            sent = jnp.where(keep, gf, 0.0)
            new_r = gf - sent
            return sent.astype(g.dtype), new_r

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_r = treedef.flatten_up_to(residual)
        outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        return (
            treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]),
        )
