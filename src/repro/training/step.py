"""Train-step factory: value_and_grad + AdamW (+ microbatch accumulation,
optional gradient compression for cross-pod links)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def make_train_step(
    loss_fn: Callable,
    optimizer,
    *,
    microbatches: int = 1,
    grad_transform: Callable | None = None,
):
    """loss_fn(params, batch) -> (loss, metrics).

    Returns step(params, opt_state, batch) -> (params, opt_state, metrics).
    With ``microbatches`` > 1 the leading batch dim is split and gradients
    accumulated in a scan (activation memory / global-batch decoupling).
    ``grad_transform`` hooks gradient compression (training/compression.py).
    """

    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = vg(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            mb = jax.tree_util.tree_map(split, batch)

            def body(acc, b):
                (l, m), g = vg(params, b)
                acc_g, acc_l = acc
                acc_g = jax.tree_util.tree_map(jnp.add, acc_g, g)
                return (acc_g, acc_l + l), m

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), ms = jax.lax.scan(body, (zero_g, 0.0), mb)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree_util.tree_map(lambda x: x.mean(), ms)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, opt_metrics = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return step
