"""Kernel micro-benchmarks: Pallas (interpret on CPU; compiled on TPU) vs the
jnp oracle, plus the telescoped-vs-per-prefix probe algorithmic win."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.api import GraphHandle
from repro.core import estimate_walk_reference, probe_walks_telescoped, sample_walks
from repro.graph import powerlaw_graph
from repro.kernels.spmm_ell.ref import spmm_ell_ref


def run(quick: bool = True) -> None:
    rng = np.random.default_rng(0)
    n, K, B = (1024, 8, 64) if quick else (8192, 16, 128)
    nbrs = jnp.asarray(rng.integers(0, n + 1, (n, K)).astype(np.int32))
    scores = jnp.asarray(rng.normal(size=(n, B)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.1, 1, n).astype(np.float32))
    ref_jit = jax.jit(spmm_ell_ref)
    _, t_ref = timed(ref_jit, nbrs, scores, w, reps=10)
    emit("kernel/spmm_ell_oracle", t_ref * 1e6,
         f"n={n};K={K};B={B};note=pallas_interpret_on_cpu_not_timed")

    # algorithmic win: telescoped O(l) vs per-prefix O(l^2) pushes
    src, dst, gn = powerlaw_graph(2000, 16_000, seed=1)
    h = GraphHandle.from_edges(src, dst, gn)
    u = int(dst[0])
    walks = sample_walks(jax.random.key(0), h.eg, u, n_r=32, max_len=10,
                         sqrt_c=0.775)
    _, t_tel = timed(
        probe_walks_telescoped, h.g, walks, sqrt_c=0.775, reps=3
    )

    def per_prefix_all():
        outs = []
        for k in range(8):  # subset: reference is the slow oracle
            outs.append(estimate_walk_reference(h.g, walks[k], 0.775))
        return outs

    _, t_ref_probe = timed(per_prefix_all)
    t_ref_scaled = t_ref_probe * (32 / 8)
    emit("probe/telescoped_32walks", t_tel * 1e6, "pushes=L-1_per_batch")
    emit("probe/per_prefix_32walks_est", t_ref_scaled * 1e6,
         f"speedup={t_ref_scaled / max(t_tel, 1e-9):.1f}x")


if __name__ == "__main__":
    run(quick=False)
