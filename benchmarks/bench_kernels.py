"""Kernel micro-benchmarks — a promoted structured suite (PR 10).

Three legs:

* the spmm_ell oracle timing + telescoped-vs-per-prefix probe win
  (unchanged CSV rows from the original suite);
* the fused lane-probe level kernel vs the XLA lane-level oracle at LEVEL
  granularity (one deposit+inject+prune+push+exclude pass over a [R, K]
  ELL block and [T, W] score table) — ``fused_vs_xla_speedup`` is the
  ratio CI gates on.  On CPU the kernel runs in interpret mode, so the
  ratio is an availability/parity check there (< 1 is expected); on TPU
  it is the real fused-vs-scatter speedup;
* a roofline record for BOTH programs via ``roofline/analysis.py``
  (per-device HLO FLOPs/bytes from ``compiled.cost_analysis()`` against
  the v5e peaks, plus the ideal model FLOPs/bytes of the level so
  achieved-vs-ideal ratios are in the artifact).

Exports ``RESULTS["kernels"]`` and (via run.py) ``BENCH_kernels.json``.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import RESULTS, emit, timed
from repro.api import GraphHandle
from repro.core import estimate_walk_reference, probe_walks_telescoped, sample_walks
from repro.graph import powerlaw_graph
from repro.kernels.spmm_ell.ref import spmm_ell_ref


def _lane_level_operands(rng, *, r, k, w):
    """A random mid-probe level: live and finished lanes, injections,
    sentinel ELL slots — the shapes the serve path dispatches."""
    t = r + 1  # local layout: score table carries the sentinel dump row
    nbrs = jnp.asarray(rng.integers(0, r + 1, (r, k)).astype(np.int32))
    weights = jnp.asarray(rng.uniform(0.1, 1, r).astype(np.float32))
    table = jnp.asarray(rng.random((t, w)).astype(np.float32))
    dep = jnp.asarray(rng.random((r, w)).astype(np.float32))
    total = jnp.asarray(rng.random((r, w)).astype(np.float32))
    fin = jnp.asarray(rng.random(w) < 0.3)
    u_p = jnp.asarray(np.where(rng.random(w) < 0.5,
                               rng.integers(0, r, w), r).astype(np.int32))
    u_prev = jnp.asarray(np.where(rng.random(w) < 0.5,
                                  rng.integers(0, r, w), r).astype(np.int32))
    thr = jnp.asarray((rng.random(w) * 1e-3).astype(np.float32))
    return nbrs, weights, table, dep, total, fin, u_p, u_prev, thr


def _lane_probe_leg(quick: bool) -> None:
    from repro.kernels.lane_probe.ops import _on_tpu, lane_probe_level
    from repro.kernels.lane_probe.ref import lane_probe_level_ref
    from repro.launch.mesh import HW
    from repro.roofline.analysis import analyze

    rng = np.random.default_rng(0)
    r, k, w = (512, 8, 128) if quick else (4096, 16, 256)
    args = _lane_level_operands(rng, r=r, k=k, w=w)

    fused = jax.jit(
        lambda *a: lane_probe_level(*a, row0=0, tab0=0, n_live=r, prune=True)
    )
    oracle = jax.jit(
        lambda *a: lane_probe_level_ref(
            *a, row0=0, tab0=0, n_live=r, prune=True
        )
    )
    reps = 5 if quick else 10
    (out_f, _), t_fused = timed(fused, *args, reps=reps)
    (out_x, _), t_xla = timed(oracle, *args, reps=reps)
    assert np.array_equal(np.asarray(out_f), np.asarray(out_x)), \
        "fused kernel diverged from the XLA oracle"
    mode = "compiled" if _on_tpu() else "interpret"
    speedup = t_xla / max(t_fused, 1e-12)
    shape = f"r{r}_k{k}_w{w}"
    emit(f"kernel/lane_probe_fused_{mode}", t_fused * 1e6, f"shape={shape}")
    emit("kernel/lane_probe_xla_oracle", t_xla * 1e6,
         f"shape={shape};fused_vs_xla_speedup={speedup:.3f}x")

    # roofline: both programs against the v5e peaks. Ideal terms for one
    # level: 2 flops per (row, slot, lane) gather-accumulate plus the
    # weight multiply/exclusion, and one pass over every operand/result.
    model_flops = 2.0 * r * k * w + 2.0 * r * w
    ideal_bytes = 4.0 * (
        r * k              # nbrs (int32)
        + r                # weights
        + r * k * w        # gathered table rows (no-reuse upper bound)
        + 4 * r * w        # dep + total in, scores + total out
        + 4 * w            # lane vectors
    )
    roofline = {}
    for name, fn in (("fused", fused), ("xla", oracle)):
        compiled = fn.lower(*args).compile()
        rep = analyze(
            arch=f"lane_probe_{name}", shape=shape, mesh_name="single",
            chips=1, compiled=compiled, model_flops=model_flops, hw=HW,
        )
        d = rep.to_dict()
        d["ideal_bytes"] = ideal_bytes
        d["bytes_vs_ideal"] = (
            rep.hlo_bytes / ideal_bytes if ideal_bytes else 0.0
        )
        roofline[name] = d
        emit(f"kernel/lane_probe_roofline_{name}",
             (rep.compute_s + rep.memory_s) * 1e6,
             f"bottleneck={rep.bottleneck};"
             f"flops_vs_ideal={rep.hlo_flops / model_flops:.2f};"
             f"bytes_vs_ideal={d['bytes_vs_ideal']:.2f}")

    RESULTS["kernels"] = dict(
        backend=jax.default_backend(),
        mode=mode,
        shape=dict(rows=r, k_slots=k, lanes=w),
        fused_us=t_fused * 1e6,
        xla_us=t_xla * 1e6,
        fused_vs_xla_speedup=speedup,
        model_flops=model_flops,
        ideal_bytes=ideal_bytes,
        roofline=roofline,
    )


def run(quick: bool = True) -> None:
    rng = np.random.default_rng(0)
    n, K, B = (1024, 8, 64) if quick else (8192, 16, 128)
    nbrs = jnp.asarray(rng.integers(0, n + 1, (n, K)).astype(np.int32))
    scores = jnp.asarray(rng.normal(size=(n, B)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.1, 1, n).astype(np.float32))
    ref_jit = jax.jit(spmm_ell_ref)
    _, t_ref = timed(ref_jit, nbrs, scores, w, reps=10)
    emit("kernel/spmm_ell_oracle", t_ref * 1e6,
         f"n={n};K={K};B={B};note=pallas_interpret_on_cpu_not_timed")

    _lane_probe_leg(quick)

    # algorithmic win: telescoped O(l) vs per-prefix O(l^2) pushes
    src, dst, gn = powerlaw_graph(2000, 16_000, seed=1)
    h = GraphHandle.from_edges(src, dst, gn)
    u = int(dst[0])
    walks = sample_walks(jax.random.key(0), h.eg, u, n_r=32, max_len=10,
                         sqrt_c=0.775)
    _, t_tel = timed(
        probe_walks_telescoped, h.g, walks, sqrt_c=0.775, reps=3
    )

    def per_prefix_all():
        outs = []
        for k in range(8):  # subset: reference is the slow oracle
            outs.append(estimate_walk_reference(h.g, walks[k], 0.775))
        return outs

    _, t_ref_probe = timed(per_prefix_all)
    t_ref_scaled = t_ref_probe * (32 / 8)
    emit("probe/telescoped_32walks", t_tel * 1e6, "pushes=L-1_per_batch")
    emit("probe/per_prefix_32walks_est", t_ref_scaled * 1e6,
         f"speedup={t_ref_scaled / max(t_tel, 1e-9):.1f}x")


if __name__ == "__main__":
    run(quick=False)
