"""Paper Figure 4: single-source AbsError vs query time on small graphs.

Systems: ProbeSim (eps_a sweep), the adaptive accuracy controller
(epsilon-certified escalation, ``core/accuracy.py``), MC baseline,
truncated Power Method (= TopSim accuracy envelope, T=3), TSF.  Ground
truth: Power Method (55 iterations).  Graphs: synthetic stand-ins for the
paper's four small datasets, CPU-scaled.

Exports ``RESULTS["abserror"]`` (-> ``BENCH_abserror.json``): per
(dataset, epsilon) the walks used, the oracle max-abs-error vs the
certified bound, precision@10 and time per query, plus the aggregate
``walks_saved_ratio`` (flat Thm-1 budget / walks the controller actually
spent — structurally >= 1) and ``bound_violations`` (queries whose
measured error exceeded their certificate — must be 0) that CI's
accuracy-gate job enforces."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import RESULTS, emit, pick_query_nodes, timed
from repro.api import GraphHandle, QuerySpec, SimRankSession
from repro.core import (
    build_oneway_index,
    mc_single_source,
    simrank_power,
    simrank_truncated_single_source,
    tsf_single_source,
)
from repro.graph import paper_dataset

DATASETS = [("wiki-vote", 0.15), ("hepth", 0.1), ("as", 0.04), ("hepph", 0.03)]
C = 0.6
N_QUERIES = 3


def _precision_at_k(scores: np.ndarray, truth_u: np.ndarray, u: int,
                    k: int = 10) -> float:
    """|est top-k ∩ truth top-k| / k, query node excluded; truth ties at
    zero are not credited (k shrinks to the positive-truth count)."""
    s = np.asarray(scores, np.float64).copy()
    t = np.asarray(truth_u, np.float64).copy()
    s[u] = -np.inf
    t[u] = -np.inf
    kk = min(k, int((t > 0).sum()))
    if kk == 0:
        return 1.0
    est_top = set(np.argsort(-s, kind="stable")[:kk].tolist())
    truth_top = set(np.argsort(-t, kind="stable")[:kk].tolist())
    return len(est_top & truth_top) / kk


def _controller_sweep(
    name: str,
    h: GraphHandle,
    truth: np.ndarray,
    queries: np.ndarray,
    epsilons: list[float],
) -> dict:
    """Adaptive epsilon sweep on one dataset -> per-epsilon metric rows."""
    sweep = {}
    for eps in epsilons:
        sess = SimRankSession(h, c=C, eps_a=eps, delta=0.01,
                              own_graph=False, seed=17)
        flat = sess.params.n_r  # what flat serving pays to promise eps
        walks, errs, certs, precs, ts = [], [], [], [], []
        violations = 0
        for u in queries:
            spec = QuerySpec(kind="single_source", node=int(u), epsilon=eps)
            env, dt = timed(sess.query, spec)
            e = np.abs(env.scores - truth[u])
            e[u] = 0
            err = float(e.max())
            walks.append(env.walks_used)
            errs.append(err)
            certs.append(env.certified_bound)
            precs.append(_precision_at_k(env.scores, truth[u], int(u)))
            ts.append(dt)
            if err > env.certified_bound:
                violations += 1
        ratio = flat / float(np.mean(walks))
        row = dict(
            epsilon=eps,
            flat_budget=flat,
            walks_used_mean=float(np.mean(walks)),
            walks_used_max=int(np.max(walks)),
            walks_saved_ratio=ratio,
            max_abs_error=float(np.max(errs)),
            certified_bound_max=float(np.max(certs)),
            precision_at_10=float(np.mean(precs)),
            time_per_query_s=float(np.mean(ts)),
            bound_violations=violations,
        )
        sweep[f"{eps}"] = row
        emit(
            f"abserr/{name}/adaptive_eps{eps}",
            float(np.mean(ts)) * 1e6,
            f"walks={np.mean(walks):.0f}/{flat};saved={ratio:.1f}x;"
            f"abserr={np.max(errs):.4f};cert={np.max(certs):.4f};"
            f"p@10={np.mean(precs):.2f}",
        )
    return sweep


def run(quick: bool = True) -> None:
    datasets = DATASETS[:2] if quick else DATASETS
    controller = {}
    for name, scale in datasets:
        jax.clear_caches()  # bound XLA-CPU JIT dylib growth across shape sweeps
        src, dst, n = paper_dataset(name, scale=scale)
        in_deg = np.bincount(dst, minlength=n)
        h = GraphHandle.from_edges(src, dst, n, k_max=int(in_deg.max()) + 1)
        truth = np.asarray(simrank_power(h.g, c=C, iters=55))
        queries = pick_query_nodes(in_deg, N_QUERIES)

        controller[name] = _controller_sweep(
            name, h, truth, queries,
            epsilons=[0.1, 0.05] if quick else [0.1, 0.05, 0.025],
        )

        for eps_a in ([0.1, 0.05] if quick else [0.1, 0.05, 0.025, 0.0125]):
            sess = SimRankSession(h, c=C, eps_a=eps_a, delta=0.01,
                                  own_graph=False)
            errs, ts = [], []
            for u in queries:
                spec = QuerySpec(kind="single_source", node=int(u),
                                 key=jax.random.key(int(u)),
                                 variant="telescoped")
                env, dt = timed(sess.query, spec)
                e = np.abs(env.scores - truth[u])
                e[u] = 0
                errs.append(e.max())
                ts.append(dt)
            emit(
                f"abserr/{name}/probesim_eps{eps_a}",
                float(np.mean(ts)) * 1e6,
                f"abserr={np.mean(errs):.4f};bound={eps_a};"
                f"n_r={sess.params.n_r}",
            )

        # MC baseline (same walk budget class)
        r = 200 if quick else 1000
        errs, ts = [], []
        for u in queries:
            est, dt = timed(
                mc_single_source, jax.random.key(int(u)), h.eg, np.int32(u),
                r=r, max_len=16, sqrt_c=float(np.sqrt(C)),
            )
            e = np.abs(np.asarray(est) - truth[u]); e[u] = 0
            errs.append(e.max()); ts.append(dt)
        emit(f"abserr/{name}/mc_r{r}", float(np.mean(ts)) * 1e6,
             f"abserr={np.mean(errs):.4f}")

        # truncated power method (TopSim accuracy envelope, T=3)
        errs, ts = [], []
        for u in queries:
            est, dt = timed(
                simrank_truncated_single_source, h.g, int(u), c=C, iters=3
            )
            e = np.abs(np.asarray(est) - truth[u]); e[u] = 0
            errs.append(e.max()); ts.append(dt)
        emit(f"abserr/{name}/topsim_T3", float(np.mean(ts)) * 1e6,
             f"abserr={np.mean(errs):.4f};c^T={C**3:.3f}")

        # TSF (R_g scaled down for CPU)
        rg, rq = (50, 5) if quick else (300, 40)
        idx = build_oneway_index(jax.random.key(1), h.eg, r_g=rg)
        errs, ts = [], []
        for u in queries:
            est, dt = timed(
                tsf_single_source, jax.random.key(int(u)), idx, h.eg,
                np.int32(u), r_q=rq, t=10, c=C,
            )
            e = np.abs(np.asarray(est) - truth[u]); e[u] = 0
            errs.append(e.max()); ts.append(dt)
        index_bytes = idx.size * 4
        emit(f"abserr/{name}/tsf_rg{rg}", float(np.mean(ts)) * 1e6,
             f"abserr={np.mean(errs):.4f};index_bytes={index_bytes}")

    rows = [r for sweep in controller.values() for r in sweep.values()]
    RESULTS["abserror"] = dict(
        datasets=controller,
        # aggregate gates CI enforces: the controller never spends more
        # than the flat budget for equal epsilon, and no measured error
        # ever exceeds its certificate
        walks_saved_ratio=min(r["walks_saved_ratio"] for r in rows),
        bound_violations=sum(r["bound_violations"] for r in rows),
        max_abs_error=max(r["max_abs_error"] for r in rows),
        certified_bound=max(r["certified_bound_max"] for r in rows),
        precision_at_10=min(r["precision_at_10"] for r in rows),
    )


if __name__ == "__main__":
    run(quick=False)
