"""Paper Figure 4: single-source AbsError vs query time on small graphs.

Systems: ProbeSim (eps_a sweep), MC baseline, truncated Power Method
(= TopSim accuracy envelope, T=3), TSF.  Ground truth: Power Method
(55 iterations).  Graphs: synthetic stand-ins for the paper's four small
datasets, CPU-scaled."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, pick_query_nodes, timed
from repro.api import GraphHandle, QuerySpec, SimRankSession
from repro.core import (
    build_oneway_index,
    mc_single_source,
    simrank_power,
    simrank_truncated_single_source,
    tsf_single_source,
)
from repro.graph import paper_dataset

DATASETS = [("wiki-vote", 0.15), ("hepth", 0.1), ("as", 0.04), ("hepph", 0.03)]
C = 0.6
N_QUERIES = 3


def run(quick: bool = True) -> None:
    datasets = DATASETS[:2] if quick else DATASETS
    for name, scale in datasets:
        jax.clear_caches()  # bound XLA-CPU JIT dylib growth across shape sweeps
        src, dst, n = paper_dataset(name, scale=scale)
        in_deg = np.bincount(dst, minlength=n)
        h = GraphHandle.from_edges(src, dst, n, k_max=int(in_deg.max()) + 1)
        truth = np.asarray(simrank_power(h.g, c=C, iters=55))
        queries = pick_query_nodes(in_deg, N_QUERIES)

        for eps_a in ([0.1, 0.05] if quick else [0.1, 0.05, 0.025, 0.0125]):
            sess = SimRankSession(h, c=C, eps_a=eps_a, delta=0.01,
                                  own_graph=False)
            errs, ts = [], []
            for u in queries:
                spec = QuerySpec(kind="single_source", node=int(u),
                                 key=jax.random.key(int(u)),
                                 variant="telescoped")
                env, dt = timed(sess.query, spec)
                e = np.abs(env.scores - truth[u])
                e[u] = 0
                errs.append(e.max())
                ts.append(dt)
            emit(
                f"abserr/{name}/probesim_eps{eps_a}",
                float(np.mean(ts)) * 1e6,
                f"abserr={np.mean(errs):.4f};bound={eps_a};"
                f"n_r={sess.params.n_r}",
            )

        # MC baseline (same walk budget class)
        r = 200 if quick else 1000
        errs, ts = [], []
        for u in queries:
            est, dt = timed(
                mc_single_source, jax.random.key(int(u)), h.eg, np.int32(u),
                r=r, max_len=16, sqrt_c=float(np.sqrt(C)),
            )
            e = np.abs(np.asarray(est) - truth[u]); e[u] = 0
            errs.append(e.max()); ts.append(dt)
        emit(f"abserr/{name}/mc_r{r}", float(np.mean(ts)) * 1e6,
             f"abserr={np.mean(errs):.4f}")

        # truncated power method (TopSim accuracy envelope, T=3)
        errs, ts = [], []
        for u in queries:
            est, dt = timed(
                simrank_truncated_single_source, h.g, int(u), c=C, iters=3
            )
            e = np.abs(np.asarray(est) - truth[u]); e[u] = 0
            errs.append(e.max()); ts.append(dt)
        emit(f"abserr/{name}/topsim_T3", float(np.mean(ts)) * 1e6,
             f"abserr={np.mean(errs):.4f};c^T={C**3:.3f}")

        # TSF (R_g scaled down for CPU)
        rg, rq = (50, 5) if quick else (300, 40)
        idx = build_oneway_index(jax.random.key(1), h.eg, r_g=rg)
        errs, ts = [], []
        for u in queries:
            est, dt = timed(
                tsf_single_source, jax.random.key(int(u)), idx, h.eg,
                np.int32(u), r_q=rq, t=10, c=C,
            )
            e = np.abs(np.asarray(est) - truth[u]); e[u] = 0
            errs.append(e.max()); ts.append(dt)
        index_bytes = idx.size * 4
        emit(f"abserr/{name}/tsf_rg{rg}", float(np.mean(ts)) * 1e6,
             f"abserr={np.mean(errs):.4f};index_bytes={index_bytes}")


if __name__ == "__main__":
    run(quick=False)
