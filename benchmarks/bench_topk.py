"""Paper Figures 5-7: Precision@k / NDCG@k / Kendall tau vs query time for
top-k queries (k=50) on small graphs, vs MC / truncated-power / TSF."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, pick_query_nodes, timed
from repro.api import GraphHandle, QuerySpec, SimRankSession
from repro.core import (
    build_oneway_index,
    mc_single_source,
    simrank_power,
    simrank_truncated_single_source,
    tsf_single_source,
)
from repro.core.metrics import kendall_tau, ndcg_at_k, precision_at_k
from repro.graph import paper_dataset

C = 0.6
K = 20


def _topk_from_est(est: np.ndarray, u: int, k: int) -> np.ndarray:
    est = est.copy()
    est[u] = -np.inf
    return np.argsort(-est, kind="stable")[:k]


def _eval(pred, truth_row, u):
    t = truth_row.copy()
    t[u] = -np.inf
    true_top = np.argsort(-t, kind="stable")[: len(pred)]
    return (
        precision_at_k(pred, true_top),
        ndcg_at_k(pred, np.maximum(truth_row, 0.0), true_top),
        kendall_tau(pred, truth_row),
    )


def run(quick: bool = True) -> None:
    datasets = [("wiki-vote", 0.15)] if quick else [
        ("wiki-vote", 0.15), ("hepth", 0.1), ("as", 0.04), ("hepph", 0.03)
    ]
    for name, scale in datasets:
        jax.clear_caches()  # bound XLA-CPU JIT dylib growth across shape sweeps
        src, dst, n = paper_dataset(name, scale=scale)
        in_deg = np.bincount(dst, minlength=n)
        h = GraphHandle.from_edges(src, dst, n, k_max=int(in_deg.max()) + 1)
        truth = np.asarray(simrank_power(h.g, c=C, iters=55))
        queries = pick_query_nodes(in_deg, 3)

        systems = {}
        sess = SimRankSession(h, c=C, eps_a=0.05, delta=0.01, own_graph=False)
        systems["probesim"] = lambda u: sess.query(QuerySpec(
            kind="single_source", node=int(u), key=jax.random.key(int(u)),
            variant="telescoped",
        )).scores
        systems["mc"] = lambda u: mc_single_source(
            jax.random.key(int(u)), h.eg, np.int32(u), r=200, max_len=16,
            sqrt_c=float(np.sqrt(C)),
        )
        systems["topsim_T3"] = lambda u: simrank_truncated_single_source(
            h.g, int(u), c=C, iters=3
        )
        idx = build_oneway_index(jax.random.key(1), h.eg, r_g=50)
        systems["tsf"] = lambda u: tsf_single_source(
            jax.random.key(int(u)), idx, h.eg, np.int32(u), r_q=5, t=10, c=C
        )

        for sysname, fn in systems.items():
            precs, ndcgs, taus, ts = [], [], [], []
            for u in queries:
                est, dt = timed(fn, u)
                pred = _topk_from_est(np.asarray(est), int(u), K)
                p, nd, tau = _eval(pred, truth[u], int(u))
                precs.append(p); ndcgs.append(nd); taus.append(tau); ts.append(dt)
            emit(
                f"topk/{name}/{sysname}", float(np.mean(ts)) * 1e6,
                f"P@{K}={np.mean(precs):.3f};NDCG={np.mean(ndcgs):.3f};"
                f"tau={np.mean(taus):.3f}",
            )


if __name__ == "__main__":
    run(quick=False)
