"""Serving throughput: seed-style serial drain vs the fused batched drain.

Protocol (acceptance: fused >= 5x serial queries/sec at Q = 16 on the
bench_large quick config, CPU):

* graph: the ``bench_large.py`` quick config (livejournal stand-in,
  scale 0.004 — n ~ 19k, m ~ 90k, heavy-hub in-degree profile), owned by
  one ``GraphHandle``;
* Q = 16 queries drawn by the paper protocol, anytime walk budget per query
  (512 quick / 2048 full);
* **serial** replicates the seed engine's ``drain()`` exactly: one query at
  a time, a host chunk loop of ``walk_chunk`` walks with separate
  ``sample_walks`` / ``probe_walks_telescoped`` dispatches per chunk,
  surplus-walk masking in the final chunk, then ``top_k``;
* **fused** is ``SimRankSession.drain()`` on the multi-query serve path:
  the whole batch in one compiled step (pooled sampling + compacted
  telescoped probe + top-k, DESIGN.md §3).

Results land in ``benchmarks.common.RESULTS['serve']`` — including the
session's ``EngineStats`` dispatch counters (queries per fused step etc.)
— and are written to ``BENCH_serve.json`` by ``run.py`` (or by
``__main__`` here).
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import RESULTS, emit, pick_query_nodes, read_prior_json
from repro.api import GraphHandle, SimRankSession
from repro.core import make_params
from repro.core.probe import probe_walks_telescoped
from repro.core.walks import sample_walks
from repro.graph import paper_dataset

C = 0.6
Q = 16
TOP_K = 50
SEED_WALK_CHUNK = 256  # the seed engine's default


def _seed_serial_query(key, g, eg, params, u, *, budget, walk_chunk, top_k):
    """The seed ``SimRankEngine._single_source`` + ``run_query``, verbatim:
    host chunk loop, two dispatches per chunk, surplus masking, top-k."""
    total = jnp.zeros(g.n, jnp.float32)
    done = 0
    while done < budget:
        key, sub = jax.random.split(key)
        walks = sample_walks(
            sub, eg, u, n_r=walk_chunk, max_len=params.max_len,
            sqrt_c=params.sqrt_c,
        )
        live = min(walk_chunk, budget - done)
        if live < walk_chunk:
            walks = walks.at[live:, :].set(g.n)
        cols = probe_walks_telescoped(
            g, walks, sqrt_c=params.sqrt_c, eps_p=params.eps_p
        )
        total = total + cols.sum(axis=1)
        done += live
    est = total / budget
    est = est.at[u].set(-jnp.inf)
    vals, idx = jax.lax.top_k(est, top_k)
    return np.asarray(idx), np.asarray(vals)


def run(quick: bool = True, backend: str = "local") -> dict:
    """``backend='local'`` (default) is the serial-vs-fused protocol;
    ``'sharded'`` additionally times the mesh-sharded drain on the local
    device set and exports a ``backend`` comparison row (CI runs this
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
    name, scale = ("livejournal", 0.004)  # bench_large quick config
    budget = 512 if quick else 2048
    src, dst, n = paper_dataset(name, scale=scale)
    in_deg = np.bincount(dst, minlength=n)
    handle = GraphHandle.from_edges(src, dst, n, k_max=int(in_deg.max()) + 1)
    queries = pick_query_nodes(in_deg, Q)
    params = make_params(n, c=C, eps_a=0.1, delta=0.01)
    key = jax.random.key(0)

    # --- serial: the seed algorithm, one query at a time -------------------
    # (skipped in the sharded-comparison mode: the backend row compares the
    # fused local drain against the mesh drain, and the serial leg is by
    # far the slowest part of the suite)
    if backend == "local":
        # warm the compile caches on one query, then time the full batch
        _seed_serial_query(key, handle.g, handle.eg, params, int(queries[0]),
                           budget=budget, walk_chunk=SEED_WALK_CHUNK,
                           top_k=TOP_K)
        t0 = time.time()
        serial_results = [
            _seed_serial_query(jax.random.fold_in(key, i), handle.g,
                               handle.eg, params, int(u), budget=budget,
                               walk_chunk=SEED_WALK_CHUNK, top_k=TOP_K)
            for i, u in enumerate(queries)
        ]
        t_serial = time.time() - t0
        qps_serial = Q / t_serial
    else:
        serial_results, t_serial, qps_serial = None, None, None
        # the sharded leg skips the (slow) serial replay — carry the last
        # committed serial measurement forward instead of nulling the
        # serve rows in BENCH_serve.json
        prior = read_prior_json("BENCH_serve.json").get("serve", {})
        if prior.get("budget_walks") == budget:
            qps_serial = prior.get("serial_qps")
            t_serial = (
                None if prior.get("serial_s_per_query") is None
                else prior["serial_s_per_query"] * Q
            )

    # --- fused: batched session drain through the multi-query serve step ---
    sess = SimRankSession(handle, c=C, eps_a=0.1, walk_chunk=SEED_WALK_CHUNK,
                          top_k=TOP_K, batch_q=Q, seed=0)
    for u in queries:  # warm-up drain compiles the fused step for this shape
        sess.submit(int(u))
    sess.drain(budget_walks=budget)
    for u in queries:
        sess.submit(int(u))
    t0 = time.time()
    fused_results = sess.drain(budget_walks=budget)
    t_fused = time.time() - t0
    qps_fused = Q / t_fused
    speedup = None if qps_serial is None else qps_fused / qps_serial

    # sanity: both paths rank the same strong neighbors (estimates are
    # independent Monte-Carlo draws, so compare top-sets loosely)
    if serial_results is not None:
        overlap = np.mean([
            len(set(serial_results[i][0][:10]) & set(fused_results[i].topk_nodes[:10])) / 10
            for i in range(Q)
        ])
    else:  # carried forward with the serial rows above
        prior = read_prior_json("BENCH_serve.json").get("serve", {})
        overlap = (
            prior.get("top10_overlap")
            if prior.get("budget_walks") == budget else None
        )

    stats = sess.stats.as_dict()
    if qps_serial is not None:
        emit(f"serve/{name}/serial_drain_q{Q}", t_serial / Q * 1e6,
             f"qps={qps_serial:.3f};budget={budget}")
    emit(f"serve/{name}/fused_drain_q{Q}", t_fused / Q * 1e6,
         f"qps={qps_fused:.3f};budget={budget};"
         + (f"speedup={speedup:.2f}x;" if speedup is not None else "")
         + (f"top10_overlap={overlap:.2f};" if overlap is not None else "")
         + f"steps={stats['steps']};queries_per_step="
         f"{stats['queries'] / max(stats['steps'], 1):.1f}")
    RESULTS["serve"] = dict(
        dataset=name,
        scale=scale,
        n=int(n),
        m=int(len(src)),
        queries=Q,
        budget_walks=budget,
        walk_chunk=SEED_WALK_CHUNK,
        top_k=TOP_K,
        serial_qps=qps_serial,
        fused_qps=qps_fused,
        speedup=speedup,
        serial_s_per_query=None if t_serial is None else t_serial / Q,
        fused_s_per_query=t_fused / Q,
        top10_overlap=None if overlap is None else float(overlap),
        # per-step dispatch accounting from the session (2 drains: warmup +
        # timed), so the artifact records how many queries each compiled
        # dispatch amortized, alongside the qps it bought
        session_stats=stats,
        error_bound_at_budget=float(sess.error_bound(budget)),
    )
    if backend == "sharded":
        RESULTS["serve"]["backend"] = _run_sharded_leg(
            handle, queries, budget, qps_fused, fused_results
        )
    return RESULTS["serve"]


def _time_sharded_drain(handle, queries, budget, shards):
    """One lane-batched sharded drain on the SAME workload as the fused
    local leg (all Q queries, full budget, same lane width): warm-up drain
    compiles the batched step, the second drain is timed.  The mesh spans
    exactly ``shards`` devices (1 x shards over ("data", "model")) so a
    scaling row measures the row-partition width, not data-axis
    replication of the lane columns."""
    from jax.sharding import Mesh

    mesh = Mesh(
        np.array(jax.devices()[:shards]).reshape(1, shards),
        ("data", "model"),
    )
    sess = SimRankSession(
        handle, c=C, eps_a=0.1, walk_chunk=SEED_WALK_CHUNK, top_k=TOP_K,
        batch_q=Q, seed=0, backend="sharded", shards=shards, mesh=mesh,
    )
    sub = [int(u) for u in queries]
    for u in sub:
        sess.submit(u)
    sess.drain(budget_walks=budget)
    for u in sub:
        sess.submit(u)
    t0 = time.time()
    results = sess.drain(budget_walks=budget)
    t_sharded = time.time() - t0
    return results, t_sharded, sess


def _run_sharded_leg(handle, queries, budget, qps_fused, fused_results) -> dict:
    """Time the mesh-sharded drain on the same graph, queries, budget and
    lane width as the fused local leg, and emit the backend comparison row.

    The headline figure is ``sharded_vs_fused`` — sharded qps over local
    fused qps on the IDENTICAL workload (one lane-batched compiled step
    against the carried device mirror vs one local fused step).  A
    ``scaling`` list adds the same measurement at 1/2/4/8 shards, each on
    a mesh of exactly that many devices.  On the CI smoke mesh the fake
    host devices share one CPU, so the ratio is an integration/overhead
    datapoint, not a same-silicon parallel-speedup claim.
    """
    ndev = len(jax.devices())
    shards = ndev
    results, t_sharded, sess = _time_sharded_drain(
        handle, queries, budget, shards
    )
    qps_sharded = Q / t_sharded
    sharded_vs_fused = qps_sharded / qps_fused
    overlap = np.mean([
        len(set(results[i].topk_nodes[:10].tolist())
            & set(fused_results[i].topk_nodes[:10].tolist())) / 10
        for i in range(Q)
    ])
    emit(f"serve/{RESULTS['serve']['dataset']}/sharded_drain_q{Q}",
         t_sharded / Q * 1e6,
         f"qps={qps_sharded:.3f};shards={shards};budget={budget};"
         f"sharded_vs_fused={sharded_vs_fused:.2f};"
         f"top10_overlap_vs_fused={overlap:.2f}")
    scaling = []
    for s in (1, 2, 4, 8):
        if s > ndev or ndev % s:
            continue
        if s == shards:
            t_s = t_sharded  # reuse the headline measurement
        else:
            _, t_s, _ = _time_sharded_drain(handle, queries, budget, s)
        row = dict(
            shards=s,
            sharded_qps=float(Q / t_s),
            sharded_vs_fused=float((Q / t_s) / qps_fused),
        )
        scaling.append(row)
        emit(f"serve/{RESULTS['serve']['dataset']}/sharded_scaling_s{s}",
             t_s / Q * 1e6,
             f"qps={row['sharded_qps']:.3f};"
             f"sharded_vs_fused={row['sharded_vs_fused']:.2f}")
    return dict(
        backend="sharded",
        shards=int(shards),
        probe="spmd",
        queries=Q,
        budget_walks=int(budget),
        walk_chunk=SEED_WALK_CHUNK,
        batch_q=Q,
        sharded_qps=float(qps_sharded),
        sharded_s_per_query=float(t_sharded / Q),
        local_fused_qps=float(qps_fused),
        sharded_vs_fused=float(sharded_vs_fused),
        top10_overlap_vs_fused=float(overlap),
        scaling=scaling,
        session_stats=sess.stats.as_dict(),
    )


if __name__ == "__main__":
    import argparse

    from benchmarks.common import write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("local", "sharded"),
                    default="local")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(quick=not args.full, backend=args.backend)
    write_json("BENCH_serve.json", quick=not args.full, suites=["serve"])
