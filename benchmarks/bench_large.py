"""Paper Table 4 + Figures 8-10: large-graph scalability + pooling
effectiveness.  Graphs are CPU-scaled stand-ins for LiveJournal/IT-2004/
Twitter/Friendster; ground truth via pooling with the single-pair MC expert
(the paper's protocol — Power Method is infeasible at this scale, which is
the point)."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, pick_query_nodes, timed
from repro.api import GraphHandle, QuerySpec, SimRankSession
from repro.core import (
    build_oneway_index,
    evaluate_with_pool,
    simrank_truncated_single_source,
    tsf_single_source,
)
from repro.graph import paper_dataset

C = 0.6
K = 20


def run(quick: bool = True) -> None:
    datasets = [("livejournal", 0.004)] if quick else [
        ("livejournal", 0.004), ("it-2004", 0.0005),
        ("twitter", 0.0005), ("friendster", 0.0003),
    ]
    for name, scale in datasets:
        jax.clear_caches()  # bound XLA-CPU JIT dylib growth across shape sweeps
        src, dst, n = paper_dataset(name, scale=scale)
        in_deg = np.bincount(dst, minlength=n)
        h = GraphHandle.from_edges(src, dst, n, k_max=int(in_deg.max()) + 1)
        graph_bytes = len(src) * 8
        queries = pick_query_nodes(in_deg, 2)
        sess = SimRankSession(h, c=C, eps_a=0.1, delta=0.01, own_graph=False)

        candidates: dict[str, dict] = {}
        # ProbeSim — index-free: space overhead == 0
        ts = []
        for u in queries:
            env, dt = timed(
                sess.query,
                QuerySpec(kind="single_source", node=int(u),
                          key=jax.random.key(int(u)), variant="telescoped"),
            )
            e = env.scores.copy(); e[u] = -np.inf
            candidates.setdefault("probesim", {})[int(u)] = np.argsort(-e)[:K]
            ts.append(dt)
        emit(f"large/{name}/probesim_query", float(np.mean(ts)) * 1e6,
             f"space_overhead_bytes=0;graph_bytes={graph_bytes}")

        # TSF — index space is R_g one-way graphs = R_g * n * 4 bytes
        rg, rq = (50, 5) if quick else (300, 40)
        idx, t_build = timed(build_oneway_index, jax.random.key(1), h.eg, r_g=rg)
        ts = []
        for u in queries:
            est, dt = timed(
                tsf_single_source, jax.random.key(int(u)), idx, h.eg,
                np.int32(u), r_q=rq, t=10, c=C,
            )
            e = np.array(est); e[u] = -np.inf
            candidates.setdefault("tsf", {})[int(u)] = np.argsort(-e)[:K]
            ts.append(dt)
        emit(
            f"large/{name}/tsf_query", float(np.mean(ts)) * 1e6,
            f"index_bytes={idx.size * 4};preproc_us={t_build*1e6:.0f};"
            f"index_vs_graph={idx.size * 4 / graph_bytes:.1f}x",
        )

        # truncated power (TopSim-accuracy stand-in): dense [n,n] matmuls,
        # CPU-feasible only on small stand-ins
        if n <= 4000:
            ts = []
            for u in queries:
                est, dt = timed(
                    simrank_truncated_single_source, h.g, int(u), c=C, iters=3
                )
                e = np.array(est); e[u] = -np.inf
                candidates.setdefault("topsim", {})[int(u)] = np.argsort(-e)[:K]
                ts.append(dt)
            emit(f"large/{name}/topsim_query", float(np.mean(ts)) * 1e6, "")

        # pooling effectiveness (paper §6.2)
        for u in queries:
            lists = {s: candidates[s][int(u)] for s in candidates}
            scores = evaluate_with_pool(
                jax.random.key(777), h.eg, int(u), lists, K,
                expert_r=2000 if quick else 10_000,
                sqrt_c=float(np.sqrt(C)), max_len=16,
            )
            for s, m in scores.items():
                emit(
                    f"large/{name}/pool_u{u}_{s}", 0.0,
                    f"P@{K}={m['precision']:.3f};NDCG={m['ndcg']:.3f};"
                    f"tau={m['kendall']:.3f}",
                )


if __name__ == "__main__":
    run(quick=False)
