"""Temporal stream workload: freshness SLO + pooled effectiveness under churn.

The ProbeSim claim made operational: index-free SimRank should stay fresh
and accurate while the graph itself is a *stream* — timestamped arrivals,
a TTL sliding window shedding delete-heavy expiry batches, and query
traffic interleaved with the ingest.  Four scenarios through
``repro.streams`` (DESIGN.md §9):

* **steady** — Poisson arrivals at a sustained rate through the fused
  epoch path; per-query staleness (wall age of the oldest unapplied op at
  answer time) at p50/p99 against the scenario's freshness SLO.
* **turnover** — TTL of a couple of ticks, so nearly every arrival comes
  back as an expiry delete: the delete-heavy window-maintenance regime.
* **bursty** — on/off modulated arrivals through the PR-8 network service
  (micro-batch window + admission control): burst ingest vs qps, with
  any admission 429s counted.
* **pooled** — periodic checkpoints freeze the live window and score the
  served top-10 against the §6.2 expert pool (with a fresh-rebuild scout
  contributing candidates), so precision@10 is tracked as the graph
  churns.

Results land in ``benchmarks.common.RESULTS['stream']`` and are written to
``BENCH_stream.json`` by ``run.py``.  CI's stream-smoke job gates
staleness_p99 under the quick SLO, zero sticky overflow, and a final
pooled p@10 >= 0.8.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import RESULTS, emit
from repro.api import GraphHandle, SimRankSession
from repro.streams import (
    FreshnessSLO,
    ServiceTransport,
    SessionTransport,
    StreamDriver,
    bursty_edge_stream,
    poisson_edge_stream,
)

C = 0.6
K = 10


def _empty_handle(n: int, capacity: int, k_max: int) -> GraphHandle:
    return GraphHandle.from_edges(
        np.empty(0, np.int32), np.empty(0, np.int32), n,
        capacity=capacity, k_max=k_max,
    )


def _session(n, capacity, k_max, *, backend="local", batch_q=4, seed=0):
    return SimRankSession(
        _empty_handle(n, capacity, k_max), c=C, top_k=K, seed=seed,
        batch_q=batch_q, backend=backend,
    )


def _rep_row(rep) -> str:
    return (
        f"qps={rep.qps:.1f},stale_p50={rep.staleness_p50_s * 1e3:.1f}ms,"
        f"stale_p99={rep.staleness_p99_s * 1e3:.1f}ms,"
        f"lag_p99={rep.version_lag_p99:.0f},applied={rep.updates_applied},"
        f"expired={rep.expired},overflow={rep.sticky_overflow}"
    )


def _rep_dict(rep) -> dict:
    return dict(
        qps=rep.qps,
        queries=rep.queries,
        staleness_p50_s=rep.staleness_p50_s,
        staleness_p99_s=rep.staleness_p99_s,
        version_lag_p50=rep.version_lag_p50,
        version_lag_p99=rep.version_lag_p99,
        arrivals=rep.arrivals,
        expired=rep.expired,
        updates_applied=rep.updates_applied,
        update_steps=rep.update_steps,
        rejected_429=rep.rejected_429,
        duration_s=rep.duration_s,
        final_live_edges=rep.final_live_edges,
        sticky_overflow=rep.sticky_overflow,
        slo_met=rep.slo_met,
    )


def run(quick: bool = True, backend: str = "local") -> None:
    if quick:
        n, rate, horizon = 500, 4_000, 1.5
        capacity, k_max = 8_192, 128
        tick_s, burst = 0.05, 128
        budget, slo_p99 = 256, 1.0
        expert_r, fresh_budget = 2_000, 2_048
    else:
        n, rate, horizon = 2_000, 20_000, 3.0
        capacity, k_max = 65_536, 256
        tick_s, burst = 0.05, 512
        budget, slo_p99 = 512, 0.5
        expert_r, fresh_budget = 20_000, 8_192
    slo = FreshnessSLO(staleness_p99_s=slo_p99)
    common = dict(tick_s=tick_s, update_burst=burst, k=K,
                  budget_walks=budget)

    # -- warmup: compile every shape the scenarios reuse (update buckets,
    # fused epoch/serve steps) on a throwaway window that drains to empty,
    # so compile time never pollutes a staleness percentile
    warm = poisson_edge_stream(n, rate=rate, horizon=4 * tick_s, seed=99)
    for mode in ("epoch", "drain"):
        StreamDriver(
            SessionTransport(_session(n, capacity, k_max), mode=mode),
            warm, ttl=2 * tick_s, queries_per_tick=2, **common,
        ).run(final_expire=True)

    results: dict = dict(n=n, rate=rate, horizon=horizon, k=K,
                         tick_s=tick_s, update_burst=burst,
                         budget_walks=budget, backend="local")

    # -- steady: sustained Poisson load through the fused epoch path
    stream = poisson_edge_stream(n, rate=rate, horizon=horizon, seed=0)
    drv = StreamDriver(
        SessionTransport(_session(n, capacity, k_max), mode="epoch"),
        stream, ttl=0.5, queries_per_tick=2, slo=slo, **common,
    )
    rep = drv.run()
    emit("stream/steady_staleness_p99", rep.staleness_p99_s * 1e6,
         _rep_row(rep))
    results["steady"] = dict(
        _rep_dict(rep), ttl=0.5, slo_staleness_p99_s=slo_p99,
        transport="session/epoch",
    )

    # -- turnover: TTL of two ticks -> nearly every arrival expires
    drv = StreamDriver(
        SessionTransport(_session(n, capacity, k_max), mode="epoch"),
        stream, ttl=2 * tick_s, queries_per_tick=2, slo=slo, **common,
    )
    rep = drv.run(final_expire=True)
    delete_frac = rep.expired / max(1, rep.updates_applied)
    emit("stream/turnover_staleness_p99", rep.staleness_p99_s * 1e6,
         _rep_row(rep) + f",delete_frac={delete_frac:.2f}")
    results["turnover"] = dict(
        _rep_dict(rep), ttl=2 * tick_s, delete_fraction=delete_frac,
        slo_staleness_p99_s=slo_p99, transport="session/epoch",
    )

    # -- bursty: on/off ingest through the PR-8 service front end
    from repro.serving import ServiceConfig, SimRankService

    bstream = bursty_edge_stream(
        n, rate_on=2 * rate, mean_on=0.15, mean_off=0.3,
        horizon=horizon, seed=1,
    )
    with SimRankService(
        _empty_handle(n, capacity, k_max),
        config=ServiceConfig(batch_window_ms=2.0, max_batch_q=4,
                             default_budget_walks=budget),
        session_kwargs=dict(c=C, top_k=K),
    ) as svc:
        drv = StreamDriver(
            ServiceTransport(svc, tenant="stream"), bstream,
            ttl=0.3, queries_per_tick=2, slo=slo, **common,
        )
        rep = drv.run()
    emit("stream/bursty_qps", 1e6 / max(rep.qps, 1e-9),
         _rep_row(rep) + f",rejected_429={rep.rejected_429}")
    results["bursty"] = dict(
        _rep_dict(rep), ttl=0.3, slo_staleness_p99_s=slo_p99,
        transport="service",
    )

    # -- pooled effectiveness trajectory under churn
    n_ticks = int(np.ceil(horizon / tick_s))
    drv = StreamDriver(
        SessionTransport(_session(n, capacity, k_max), mode="drain"),
        stream, ttl=0.5, queries_per_tick=1,
        checkpoint_every=max(1, n_ticks // 3), checkpoint_queries=4,
        expert_r=expert_r, fresh_budget=fresh_budget, slo=slo,
        **dict(common, budget_walks=max(budget, 1_024)),
    )
    rep = drv.run()
    traj = [cp.as_dict() for cp in rep.checkpoints]
    final_p = rep.final_precision_at_k
    emit("stream/pooled_precision_at_10", (1.0 - (final_p or 0.0)) * 1e6,
         ",".join(f"t={cp.t:.2f}:p@{K}={cp.precision_at_k:.2f}"
                  for cp in rep.checkpoints))
    results["pooled"] = dict(
        k=K, expert_r=expert_r, fresh_budget=fresh_budget,
        trajectory=traj,
        final_precision_at_10=final_p,
        final_ndcg_at_10=(rep.checkpoints[-1].ndcg_at_k
                          if rep.checkpoints else None),
        sticky_overflow=rep.sticky_overflow,
    )

    # -- sharded leg: the same steady scenario over the mesh backend
    if backend == "sharded":
        sh_stream = poisson_edge_stream(
            n, rate=rate // 2, horizon=horizon / 2, seed=0
        )
        sess = _session(n, capacity, k_max, backend="sharded")
        shards = sess.backend.state.shards
        drv = StreamDriver(
            SessionTransport(sess, mode="drain"), sh_stream,
            ttl=0.5, queries_per_tick=1, slo=slo, **common,
        )
        # warm the mesh programs, then drain back to the empty window
        drv.run(max_ticks=2, final_expire=True)
        rep = drv.run()
        emit("stream/sharded_staleness_p99", rep.staleness_p99_s * 1e6,
             _rep_row(rep) + f",shards={shards}")
        results["sharded"] = dict(
            _rep_dict(rep), ttl=0.5, shards=shards,
            slo_staleness_p99_s=slo_p99, transport="session-sharded/drain",
        )
        results["backend"] = "sharded"

    RESULTS["stream"] = results


if __name__ == "__main__":  # run as `python -m benchmarks.bench_stream`
    import argparse

    from benchmarks.common import write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("local", "sharded"),
                    default="local")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(quick=not args.full, backend=args.backend)
    write_json("BENCH_stream.json", quick=not args.full, suites=["stream"])
